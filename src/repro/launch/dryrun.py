import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST run before any other import (jax locks the
# device count on first initialization). Everything else follows.

import argparse  # noqa: E402
import functools  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import base as configs  # noqa: E402
from repro.distributed import partition  # noqa: E402
from repro.launch import analysis  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.shapes import SHAPES, applicable  # noqa: E402
from repro.launch import steps as steps_lib  # noqa: E402
from repro.models import layers as L  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.optim import adamw  # noqa: E402

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes, prove memory fits, and extract roofline terms.

For every cell this lowers the REAL step function (train_step with
AdamW, prefill, or serve_step) against ShapeDtypeStruct inputs — no
allocation — with the full 2D/3D sharding rules, then:

    compiled = jax.jit(step, in_shardings=..., out_shardings=...)\
        .lower(*specs).compile()
    compiled.memory_analysis()   # proves it fits per device
    compiled.cost_analysis()     # FLOPs/bytes for the roofline

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json; the
roofline table in EXPERIMENTS.md is generated from those files by
benchmarks/roofline.py.
"""

DT = L.Dtypes(param=jnp.bfloat16, compute=jnp.bfloat16, accum=jnp.float32)


def _struct(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg, shape, mesh, dt=DT):
    """ShapeDtypeStruct stand-ins + NamedShardings for one cell.

    Returns (args tuple, in_shardings tuple, out_shardings, donate)."""
    key_s = _struct((2,), jnp.uint32)
    params_s = jax.eval_shape(lambda k: T.init_params(k, cfg, dt), key_s)
    pspecs = partition.validate_divisibility(
        partition.param_specs(params_s), params_s, mesh
    )
    p_sh = partition.shardings_of(pspecs, mesh)
    long_ctx = shape.name == "long_500k"
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)

    if shape.kind == "train":
        opt_s = jax.eval_shape(adamw.init_state, params_s)
        ospecs = partition.validate_divisibility(
            {"m": pspecs, "v": pspecs, "step": P()}, opt_s, mesh
        )
        o_sh = partition.shardings_of(ospecs, mesh)
        batch = {
            "tokens": _struct((shape.global_batch, shape.seq_len), jnp.int32),
            "targets": _struct((shape.global_batch, shape.seq_len), jnp.int32),
        }
        b_sh = {
            "tokens": NamedSharding(mesh, P(dp, None)),
            "targets": NamedSharding(mesh, P(dp, None)),
        }
        if cfg.frontend:
            batch["frontend"] = _struct(
                (shape.global_batch, cfg.frontend_len, cfg.d_model), dt.compute
            )
            b_sh["frontend"] = NamedSharding(mesh, P(dp, None, None))
        args = (params_s, opt_s, batch)
        in_sh = (p_sh, o_sh, b_sh)
        out_sh = (p_sh, o_sh, None)
        return args, in_sh, out_sh, (0, 1)

    if shape.kind == "prefill":
        batch = {
            "tokens": _struct((shape.global_batch, shape.seq_len), jnp.int32)
        }
        b_sh = {"tokens": NamedSharding(mesh, P(dp, None))}
        if cfg.frontend:
            batch["frontend"] = _struct(
                (shape.global_batch, cfg.frontend_len, cfg.d_model), dt.compute
            )
            b_sh["frontend"] = NamedSharding(mesh, P(dp, None, None))
        args = (params_s, batch)
        in_sh = (p_sh, b_sh)
        # output cache must be sharded like the decode cache it feeds —
        # unconstrained, XLA replicates it (measured: internvl2 prefill
        # at 352 GiB/device before the fix)
        cache_s = jax.eval_shape(
            lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len, dt)
        )
        cspecs = partition.validate_divisibility(
            partition.cache_specs(cache_s, mesh, long_context=False),
            cache_s, mesh,
        )
        out_sh = (None, partition.shardings_of(cspecs, mesh))
        return args, in_sh, out_sh, ()

    # decode: one new token against a seq_len-deep cache
    b = shape.global_batch
    cache_s = jax.eval_shape(
        lambda: T.init_cache(cfg, b, shape.seq_len, dt)
    )
    cspecs = partition.validate_divisibility(
        partition.cache_specs(cache_s, mesh, long_context=long_ctx),
        cache_s, mesh,
    )
    c_sh = partition.shardings_of(cspecs, mesh)
    tokens = _struct((b, 1), jnp.int32)
    lengths = _struct((b,), jnp.int32)
    tok_sh = NamedSharding(mesh, P(dp, None) if not long_ctx else P(None, None))
    len_sh = NamedSharding(mesh, P(dp) if not long_ctx else P(None))
    args = [params_s, tokens, cache_s, lengths]
    in_sh = [p_sh, tok_sh, c_sh, len_sh]
    if cfg.enc_dec:
        enc = _struct((b, cfg.frontend_len, cfg.d_model), dt.compute)
        args.append(enc)
        in_sh.append(NamedSharding(mesh, P(dp, None, None) if not long_ctx else P(None, None, None)))
    else:
        args.append(None)
        in_sh.append(None)
    out_sh = (None, c_sh, None)
    return tuple(args), tuple(in_sh), out_sh, (2,)


def lower_cell(cfg, shape, mesh, dt=DT):
    """Lower + compile one (arch, shape, mesh) cell. Returns results dict."""
    from repro.models import shardctx

    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    shardctx.set_mesh_ctx(mesh, dp)
    if shape.kind == "train":
        # Megatron-SP at layer boundaries: batch over data, seq over model
        T.set_activation_sharding(NamedSharding(mesh, P(dp, "model", None)))
    else:
        T.set_activation_sharding(None)
    args, in_sh, out_sh, donate = input_specs(cfg, shape, mesh, dt)
    if shape.kind == "train":
        opt_cfg = adamw.AdamWConfig()
        fn = steps_lib.make_train_step(cfg, opt_cfg, dt)
    elif shape.kind == "prefill":
        fn = steps_lib.make_prefill_step(cfg, dt, max_seq=shape.seq_len)
    else:
        fn = steps_lib.make_serve_step(cfg, dt)

    jitted = jax.jit(
        fn,
        in_shardings=in_sh,
        out_shardings=out_sh,
        donate_argnums=donate,
    )
    t0 = time.time()
    lowered = jitted.lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    n_dev = mesh.devices.size
    mf = analysis.model_flops(cfg, shape) / n_dev
    roof = analysis.roofline(compiled, n_dev, model_flops_per_device=mf)
    mem = analysis.memory_report(compiled)
    return {
        "arch": cfg.name,
        "shape": shape.name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "n_devices": n_dev,
        "lower_s": t1 - t0,
        "compile_s": t2 - t1,
        "memory": mem,
        "roofline": roof,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, outdir: str,
             dt=DT) -> dict:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}__{shape_name}__{mesh_tag}"
    if not ok:
        res = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "skipped": why}
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        try:
            res = lower_cell(cfg, shape, mesh, dt)
        except Exception as e:  # noqa: BLE001 — recorded, surfaced by caller
            res = {
                "arch": arch, "shape": shape_name, "mesh": mesh_tag,
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:],
            }
    if outdir:
        os.makedirs(outdir, exist_ok=True)
        with open(os.path.join(outdir, tag + ".json"), "w") as f:
            json.dump(res, f, indent=1, default=float)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="16x16", choices=["16x16", "2x16x16", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = configs.all_names() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = (
        [False, True] if args.mesh == "both" else [args.mesh == "2x16x16"]
    )

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                t0 = time.time()
                res = run_cell(arch, shape_name, mp, args.out)
                dt_s = time.time() - t0
                if "error" in res:
                    failures += 1
                    status = "ERROR " + res["error"][:120]
                elif "skipped" in res:
                    status = res["skipped"]
                else:
                    r = res["roofline"]
                    status = (
                        f"ok compute={r['compute_s']*1e3:.1f}ms "
                        f"mem={r['memory_s']*1e3:.1f}ms "
                        f"coll={r['collective_s']*1e3:.1f}ms "
                        f"dominant={r['dominant']} "
                        f"hbm={res['memory'].get('peak_bytes_per_device_est',0)/2**30:.2f}GiB"
                    )
                mesh_tag = "2x16x16" if mp else "16x16"
                print(f"[{dt_s:7.1f}s] {arch:24s} {shape_name:12s} "
                      f"{mesh_tag:8s} {status}", flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
