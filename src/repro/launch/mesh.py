"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing never touches jax
device state. Single pod: (16, 16) = 256 chips as ("data", "model");
multi-pod: (2, 16, 16) with a leading "pod" axis (data parallelism
across pods; params replicated pod-wise, gradients reduced over
("pod", "data"))."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 4):
    """Small mesh for multi-device CPU tests (subprocess-launched with
    XLA_FLAGS=--xla_force_host_platform_device_count=N)."""
    return jax.make_mesh((data, model), ("data", "model"))
