"""Step-function builders: train_step / prefill_step / serve_step.

These are the exact functions the dry-run lowers and the drivers run.
Activation sharding constraints (Megatron-style sequence parallelism at
layer boundaries) are applied here so the model code stays
mesh-agnostic.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.optim import adamw


def make_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                    dt: L.Dtypes = L.FP32):
    def train_step(params, opt_state, batch):
        def loss(p):
            return T.loss_fn(p, batch, cfg, dt)

        l, grads = jax.value_and_grad(loss)(params)
        params2, opt2, metrics = adamw.apply_updates(
            params, grads, opt_state, opt_cfg
        )
        metrics["loss"] = l
        return params2, opt2, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, dt: L.Dtypes = L.FP32,
                      max_seq: Optional[int] = None):
    def prefill_step(params, batch):
        return T.prefill(
            params, batch["tokens"], cfg, dt,
            frontend=batch.get("frontend"), max_seq=max_seq,
        )

    return prefill_step


def make_serve_step(cfg: ArchConfig, dt: L.Dtypes = L.FP32):
    def serve_step(params, tokens, cache, lengths, enc_out=None):
        logits, new_cache = T.decode_step(
            params, tokens, cache, lengths, cfg, dt, enc_out=enc_out
        )
        return logits, new_cache, lengths + 1

    return serve_step
