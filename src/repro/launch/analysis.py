"""Compiled-artifact analysis: roofline terms from the dry-run, plus
design-space sweep summarization (Pareto fronts, per-kernel speedups).

Hardware constants (assignment-specified, TPU v5e-like):
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

Terms per (arch, shape, mesh):
  compute    = HLO_FLOPs_per_device / peak_FLOPs
  memory     = HLO_bytes_per_device / HBM_bw
  collective = per-device collective bytes (parsed from optimized HLO) / link_bw

Collective byte conventions (ring-algorithm bytes per device):
  all-gather       out * (g-1)/g
  all-reduce       2 * out * (g-1)/g
  reduce-scatter   out * (g-1)          (input = g * out)
  all-to-all       out * (g-1)/g
  collective-permute  out
"""

from __future__ import annotations

import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DT_SIZE = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = {
    "all-gather": lambda out, g: out * (g - 1) / max(g, 1),
    "all-reduce": lambda out, g: 2 * out * (g - 1) / max(g, 1),
    "reduce-scatter": lambda out, g: out * (g - 1),
    "all-to-all": lambda out, g: out * (g - 1) / max(g, 1),
    "collective-permute": lambda out, g: out,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\}?")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DT_SIZE:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DT_SIZE[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0]
        return max(1, len([x for x in first.replace("{", "").split(",") if x.strip() != ""]))
    return 1


def collective_bytes(hlo_text: str) -> dict:
    """Per-device collective bytes by op kind, from optimized HLO."""
    out: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if " = " not in line:
            continue
        rhs = line.split(" = ", 1)[1]
        for op, fn in _COLLECTIVES.items():
            # match the op applied as instruction (e.g. "all-reduce(")
            m = re.search(rf"\b{op}(?:-start|-done)?\(", rhs)
            if not m:
                continue
            if op == "all-gather" and "all-gather-done" in rhs:
                continue  # done ops carry no new bytes
            if op == "all-reduce" and "all-reduce-done" in rhs:
                continue
            if op == "collective-permute" and "collective-permute-done" in rhs:
                continue
            # output shapes: everything before the op name
            out_bytes = _shape_bytes(rhs[: m.start()])
            g = _group_size(rhs)
            out[op] += fn(out_bytes, g)
            counts[op] += 1
            break
    total = sum(out.values())
    return {"per_op": out, "counts": counts, "total_bytes": total}


def roofline(compiled, n_devices: int, model_flops_per_device: float = 0.0):
    """All three terms + dominant classification from a compiled exe.

    FLOPs/bytes/collectives come from the while-trip-aware HLO cost
    model (launch/hlo_cost.py) — XLA's own cost_analysis counts loop
    bodies once (verified) and is recorded only as a reference field.
    """
    from repro.launch import hlo_cost

    my = hlo_cost.cost_from_compiled(compiled)
    xla_cost = compiled.cost_analysis()
    if isinstance(xla_cost, list):
        xla_cost = xla_cost[0]

    flops = float(my["flops"])
    bytes_accessed = float(my["bytes"])
    coll_total = float(my["collective_bytes"])

    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_accessed / HBM_BW
    t_collective = coll_total / LINK_BW
    terms = {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_collective,
    }
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    util = t_compute / bound if bound > 0 else 0.0
    out = {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "hlo_flops_per_device": flops,
        "hlo_flops_elementwise": float(my["flops_elementwise"]),
        "hlo_bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": coll_total,
        "collective_per_op": my["collective_per_op"],
        "xla_cost_analysis_flops": float(xla_cost.get("flops", 0.0)),
        "roofline_fraction": util,  # compute-time share of the bound
    }
    if model_flops_per_device:
        out["model_flops_per_device"] = model_flops_per_device
        out["useful_flops_ratio"] = model_flops_per_device / max(flops, 1.0)
    return out


def memory_report(compiled) -> dict:
    m = compiled.memory_analysis()
    keys = [
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ]
    rep = {}
    for k in keys:
        v = getattr(m, k, None)
        if v is not None:
            rep[k] = int(v)
    args = rep.get("argument_size_in_bytes", 0)
    alias = rep.get("alias_size_in_bytes", 0)
    rep["peak_bytes_per_device_est"] = (
        args + rep.get("temp_size_in_bytes", 0)
        + rep.get("output_size_in_bytes", 0) - alias
    )
    return rep


# ---------------------------------------------------------------------------
# design-space sweep summarization (consumed by benchmarks/sweep.py on
# repro.dse.SweepResult.rows(); operates on plain dict rows so it has no
# dependency on the dse package)
# ---------------------------------------------------------------------------


def harmonic_mean(xs) -> float:
    xs = [x for x in xs if x > 0]
    return len(xs) / sum(1.0 / x for x in xs) if xs else 0.0


def sweep_speedups(rows: list, base_modes=("STA", "LSQ")) -> dict:
    """Per-kernel and harmonic-mean FUS2 speedups from sweep rows.

    ``rows`` are ``dse.SweepResult.rows()`` dicts (needs ``kernel``,
    ``mode``, ``sizing``, ``cycles``). Speedups compare FUS2 against
    each base mode *at the same kernel/sizing/scale*; kernels or
    sizings missing either side are skipped. Returns
    ``{"per_kernel": {kernel: {"FUS2_vs_STA": ...}}, "hmean": {...}}``
    computed at the ``"base"`` sizing when present (else the first
    sizing seen), mirroring paper Table 1's headline structure.
    """
    cyc: dict[tuple, int] = {}
    sizings: list = []
    for r in rows:
        key = (r["kernel"], r["scale"], r["sizing"], r["mode"])
        cyc.setdefault(key, r["cycles"])
        if r["sizing"] not in sizings:
            sizings.append(r["sizing"])
    ref_sizing = "base" if "base" in sizings else (sizings[0] if sizings else "base")
    # one scale per kernel keys rows by kernel name; multi-scale sweeps
    # key by "kernel@scale" so scales don't overwrite each other
    kernel_scales: dict = {}
    for (kernel, scale, _sizing, _mode) in cyc:
        kernel_scales.setdefault(kernel, set()).add(scale)
    per_kernel: dict = {}
    for (kernel, scale, sizing, mode) in list(cyc):
        if sizing != ref_sizing or mode != "FUS2":
            continue
        f2 = cyc[(kernel, scale, sizing, "FUS2")]
        name = (
            kernel if len(kernel_scales[kernel]) == 1 else f"{kernel}@{scale}"
        )
        ks = per_kernel.setdefault(name, {})
        for base in base_modes:
            b = cyc.get((kernel, scale, sizing, base))
            if b is not None and f2 > 0:
                ks[f"FUS2_vs_{base}"] = round(b / f2, 3)
    hmean = {}
    for base in base_modes:
        vals = [
            k[f"FUS2_vs_{base}"]
            for k in per_kernel.values()
            if f"FUS2_vs_{base}" in k
        ]
        if vals:
            hmean[f"FUS2_vs_{base}_hmean"] = round(harmonic_mean(vals), 3)
    return {"per_kernel": per_kernel, "hmean": hmean, "sizing": ref_sizing}


def pareto_front(rows: list, objectives=("cycles", "dram_bursts")) -> list:
    """Indices of the Pareto-optimal rows (all objectives minimized).

    A row is kept when no other row is <= on every objective and < on
    at least one. Ties (exactly equal vectors) keep the first
    occurrence. Typical use: per kernel, find the DU sizings that trade
    simulated cycles against DRAM traffic."""
    vecs = [tuple(r[o] for o in objectives) for r in rows]
    keep = []
    for i, v in enumerate(vecs):
        dominated = False
        for j, w in enumerate(vecs):
            if j == i:
                continue
            if all(a <= b for a, b in zip(w, v)) and (
                any(a < b for a, b in zip(w, v)) or (w == v and j < i)
            ):
                dominated = True
                break
        if not dominated:
            keep.append(i)
    return keep


class ParetoTracker:
    """Incremental partial Pareto front over streamed sweep rows.

    The live-observability companion of ``pareto_front``: feed it rows
    as ``dse.sweep(on_point=...)`` / ``dse.iter_points()`` deliver
    them and read ``front()`` at any moment. The dominance rule (and
    the keep-first tie rule) match ``pareto_front`` exactly, so after
    any prefix of updates ``front()`` equals
    ``[rows[i] for i in pareto_front(rows_so_far, objectives)]`` —
    pinned per-prefix by tests/test_sweep_service.py and at benchmark
    scale by ``benchmarks/sweep.py --stream``.
    """

    def __init__(self, objectives=("cycles", "dram_bursts")):
        self.objectives = tuple(objectives)
        self._front: list = []  # (vector, row), insertion-ordered
        self.n_seen = 0

    def _vec(self, row) -> tuple:
        return tuple(row[o] for o in self.objectives)

    def update(self, row) -> bool:
        """Offer one row; returns True when the front changed."""
        self.n_seen += 1
        v = self._vec(row)
        for w, _r in self._front:
            # w dominates v, or ties it (earlier row wins ties)
            if all(a <= b for a, b in zip(w, v)):
                return False
        survivors = [
            (w, r)
            for w, r in self._front
            if not (
                all(a <= b for a, b in zip(v, w))
                and any(a < b for a, b in zip(v, w))
            )
        ]
        survivors.append((v, row))
        self._front = survivors
        return True

    def front(self) -> list:
        """Current Pareto-optimal rows, in first-seen order."""
        return [r for _v, r in self._front]


def summarize_sweep(rows: list) -> dict:
    """Sweep-level digest: speedups + per-kernel Pareto sizings.

    The Pareto set is computed over FUS2 rows per kernel (one per
    sizing) on (cycles, dram_bursts) — the DU cost/performance
    trade-off the paper's LSQ-sizing discussion gestures at."""
    out = {"speedups": sweep_speedups(rows)}
    pareto: dict = {}
    by_kernel: dict = {}
    if not rows:
        out["pareto_fus2"] = pareto
        return out
    for r in rows:
        if r["mode"] == "FUS2":
            by_kernel.setdefault(r["kernel"], []).append(r)
    for kernel, krows in by_kernel.items():
        seen: dict = {}
        for r in krows:
            seen.setdefault(r["sizing"], r)
        krows = list(seen.values())
        idx = pareto_front(krows)
        pareto[kernel] = [
            {
                "sizing": krows[i]["sizing"],
                "cycles": krows[i]["cycles"],
                "dram_bursts": krows[i]["dram_bursts"],
            }
            for i in idx
        ]
    out["pareto_fus2"] = pareto
    return out


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D for training (fwd+bwd), 2·N·D for inference,
    with N = active params (MoE-aware)."""
    n = cfg.n_active_params()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    return float(mult * n * tokens)
