"""While-trip-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, which
under-reports FLOPs/bytes/collectives for scan-over-layers models by the
trip count (verified empirically: L=1 and L=4 starcoder2 report the same
FLOPs). This module re-derives costs from the *optimized* HLO text,
multiplying loop bodies by their ``known_trip_count`` backend config:

  * flops: dot_general = 2 * prod(output) * prod(contracting dims)
    (from the operand symbol table); elementwise/reduce = prod(shape);
    called computations (fusion/call/while/conditional) recurse.
  * bytes: kernel-level HBM traffic model = sum of operand+output sizes
    of top-level instructions (post-fusion, fusion internals excluded).
  * collectives: per-device bytes with ring-algorithm conventions
    (analysis.py), multiplied by enclosing loop trips.

This is the FLOPs/bytes source for the §Roofline tables; XLA's own
numbers are recorded alongside for reference.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DT_SIZE = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_CALL_RE = re.compile(r"(?:calls=|condition=|body=|to_apply=)%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count.*?"n":"(\d+)"')
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\}?")

_ELEMWISE = (
    "add", "subtract", "multiply", "divide", "tanh", "exponential", "log",
    "maximum", "minimum", "power", "rsqrt", "sqrt", "negate", "abs",
    "compare", "select", "and", "or", "not", "xor", "convert", "floor",
    "cosine", "sine", "logistic", "remainder", "sign", "clamp",
    "expm1", "log1p", "atan2",
)

def _ZF():
    return {"dot": 0.0, "elem": 0.0}


_COLLECTIVE_FACTORS = {
    "all-gather": lambda out, g: out * (g - 1) / max(g, 1),
    "all-reduce": lambda out, g: 2 * out * (g - 1) / max(g, 1),
    "reduce-scatter": lambda out, g: out * (g - 1),
    "all-to-all": lambda out, g: out * (g - 1) / max(g, 1),
    "collective-permute": lambda out, g: out,
}


def _shapes(text: str):
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DT_SIZE:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out.append((dt, n))
    return out


def _elems(text: str) -> int:
    return sum(n for _, n in _shapes(text))


def _bytes(text: str) -> int:
    return sum(n * _DT_SIZE[dt] for dt, n in _shapes(text))


@dataclasses.dataclass
class Instr:
    name: str
    out_text: str  # output shape portion
    op: str
    rhs: str  # full right-hand side
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    defs: dict  # name -> output shape text


_OP_RE = re.compile(r"^(\([^)]*\)|[\w\[\],{}]+)\s+([\w\-]+)\(")


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        header = re.match(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->.*\{", stripped)
        if header and not stripped.startswith("%s32"):
            cur = Computation(header.group(1), [], {})
            comps[cur.name] = cur
            if stripped.startswith("ENTRY") or line.startswith("ENTRY"):
                comps["__entry__"] = cur
            continue
        if line.startswith("ENTRY"):
            m = re.match(r"^ENTRY\s+%([\w.\-]+)", line)
            if m:
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
                comps["__entry__"] = cur
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # rhs = "<shape> <op>(...)" or "(<tuple shapes>) <op>(...)"
        om = _OP_RE.match(rhs)
        if om:
            out_text, op = om.group(1), om.group(2)
        else:
            parts = rhs.split(" ", 1)
            out_text, op = parts[0], (parts[1].split("(")[0] if len(parts) > 1 else "")
        cur.defs[name] = out_text
        cur.instrs.append(Instr(name, out_text, op, rhs, line))
    return comps


def _operands(rhs: str) -> list[str]:
    m = re.search(r"\((.*)\)", rhs)
    if not m:
        return []
    inner = m.group(1)
    return re.findall(r"%([\w.\-]+)", inner.split("), ")[0])


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0]
        return max(
            1,
            len([x for x in first.replace("{", "").split(",") if x.strip()]),
        )
    return 1


class HloCost:
    def __init__(self, hlo_text: str):
        self.comps = parse_computations(hlo_text)
        self._cache: dict[str, tuple] = {}

    # ------------------------------------------------------------------

    def total(self) -> dict:
        entry = self.comps.get("__entry__")
        if entry is None:  # pragma: no cover
            raise ValueError("no ENTRY computation found")
        flops, bytes_, coll = self._comp_cost(entry.name, top=True)
        return {
            "flops": flops["dot"],  # MFU convention: matmul/conv flops
            "flops_elementwise": flops["elem"],
            "bytes": bytes_,
            "collective_bytes": coll["total"],
            "collective_per_op": coll["per_op"],
        }

    # ------------------------------------------------------------------

    def _comp_cost(self, name: str, top: bool = False):
        if name in self._cache:
            return self._cache[name]
        comp = self.comps.get(name)
        if comp is None:
            return {"dot": 0.0, "elem": 0.0}, 0.0, {"total": 0.0, "per_op": {}}
        flops = {"dot": 0.0, "elem": 0.0}
        bytes_ = 0.0
        coll = {"total": 0.0, "per_op": {k: 0.0 for k in _COLLECTIVE_FACTORS}}
        for ins in comp.instrs:
            f, b, c = self._instr_cost(ins, comp)
            flops["dot"] += f["dot"]
            flops["elem"] += f["elem"]
            bytes_ += b
            coll["total"] += c["total"]
            for k, v in c["per_op"].items():
                coll["per_op"][k] = coll["per_op"].get(k, 0.0) + v
        self._cache[name] = (flops, bytes_, coll)
        return self._cache[name]

    def _instr_cost(self, ins: Instr, comp: Computation):
        zero_coll = {"total": 0.0, "per_op": {}}
        op = ins.op
        out_elems = _elems(ins.out_text)
        out_bytes = _bytes(ins.out_text)

        if op == "while":
            trip = 1
            m = _TRIP_RE.search(ins.line)
            if m:
                trip = int(m.group(1))
            calls = _CALL_RE.findall(ins.line)
            f = {"dot": 0.0, "elem": 0.0}
            b = 0.0
            c = {"total": 0.0, "per_op": {}}
            for cname in calls:
                cf, cb, cc = self._comp_cost(cname)
                f["dot"] += cf["dot"]
                f["elem"] += cf["elem"]
                b += cb
                c["total"] += cc["total"]
                for k, v in cc["per_op"].items():
                    c["per_op"][k] = c["per_op"].get(k, 0.0) + v
            return (
                {"dot": f["dot"] * trip, "elem": f["elem"] * trip},
                b * trip,
                {
                    "total": c["total"] * trip,
                    "per_op": {k: v * trip for k, v in c["per_op"].items()},
                },
            )

        if op in ("fusion", "call", "conditional", "custom-call", "map"):
            calls = _CALL_RE.findall(ins.line)
            f = {"dot": 0.0, "elem": 0.0}
            c = {"total": 0.0, "per_op": {}}
            for cname in calls:
                cf, _, cc = self._comp_cost(cname)
                f["dot"] += cf["dot"]
                f["elem"] += cf["elem"]
                c["total"] += cc["total"]
                for k, v in cc["per_op"].items():
                    c["per_op"][k] = c["per_op"].get(k, 0.0) + v
            # kernel-level traffic: operands + outputs of the fusion
            b = out_bytes + self._operand_bytes(ins, comp)
            return f, b, c

        for cop, fn in _COLLECTIVE_FACTORS.items():
            if op == cop or op == cop + "-start":
                g = _group_size(ins.line)
                cb = fn(out_bytes, g)
                return _ZF(), out_bytes + self._operand_bytes(ins, comp), {
                    "total": cb, "per_op": {cop: cb},
                }

        if op == "dot":
            k_elems = self._contracting_elems(ins, comp)
            f = {"dot": 2.0 * out_elems * k_elems, "elem": 0.0}
            b = out_bytes + self._operand_bytes(ins, comp)
            return f, b, zero_coll

        if op == "convolution":
            # rough: 2 * out * (kernel spatial * in_features)
            ops_ = _operands(ins.rhs)
            kshape = comp.defs.get(ops_[1]) if len(ops_) > 1 else None
            kelem = _elems(kshape) if kshape else 1
            f = 2.0 * out_elems * max(1, kelem // max(out_elems, 1))
            f = max(f, 2.0 * kelem)  # floor
            return (
                {"dot": f, "elem": 0.0},
                out_bytes + self._operand_bytes(ins, comp),
                zero_coll,
            )

        if op in ("reduce", "reduce-window"):
            red_in = self._operand_bytes(ins, comp) // 4 or out_elems
            return (
                {"dot": 0.0, "elem": float(red_in)},
                out_bytes + self._operand_bytes(ins, comp),
                zero_coll,
            )

        if op in _ELEMWISE:
            return {"dot": 0.0, "elem": float(out_elems)}, 0.0, zero_coll

        if op in ("copy", "copy-start", "transpose", "reshape", "broadcast",
                  "concatenate", "slice", "dynamic-slice",
                  "dynamic-update-slice", "gather", "scatter", "pad",
                  "iota", "sort", "bitcast", "reverse", "rng",
                  "get-tuple-element", "tuple", "parameter", "constant",
                  "compare", "convert", "after-all", "partition-id",
                  "replica-id", "optimization-barrier", "domain",
                  "send", "recv", "infeed", "outfeed"):
            heavy = op in ("copy", "transpose", "concatenate", "gather",
                           "scatter", "dynamic-update-slice", "sort", "pad",
                           "reverse", "dynamic-slice")
            b = out_bytes + (self._operand_bytes(ins, comp) if heavy else 0)
            if op in ("get-tuple-element", "tuple", "parameter", "constant",
                      "bitcast", "reshape", "after-all",
                      "optimization-barrier", "domain"):
                b = 0.0
            return _ZF(), float(b), zero_coll

        # default: count output traffic only
        return _ZF(), float(out_bytes), zero_coll

    # ------------------------------------------------------------------

    def _operand_bytes(self, ins: Instr, comp: Computation) -> int:
        total = 0
        for name in _operands(ins.rhs):
            shape = comp.defs.get(name)
            if shape:
                total += _bytes(shape)
        return total

    def _contracting_elems(self, ins: Instr, comp: Computation) -> int:
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
        ops_ = _operands(ins.rhs)
        if not m or not ops_:
            return 1
        dims = [int(x) for x in m.group(1).split(",") if x != ""]
        lhs_shape = comp.defs.get(ops_[0])
        if not lhs_shape:
            return 1
        sm = _SHAPE_RE.search(lhs_shape)
        if not sm:
            return 1
        sizes = [int(x) for x in sm.group(2).split(",") if x != ""]
        k = 1
        for d in dims:
            if d < len(sizes):
                k *= sizes[d]
        return k


def cost_from_compiled(compiled) -> dict:
    return HloCost(compiled.as_text()).total()
