"""Assigned input-shape set (one per arch × shape cell)."""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runnable, reason-if-skipped). long_500k needs sub-quadratic
    attention: run for SSM/hybrid/sliding-window archs, skip for pure
    full-attention (DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "SKIP(full-attn)"
    return True, ""


def cells(configs: list[ArchConfig]):
    for cfg in configs:
        for shape in SHAPES.values():
            yield cfg, shape, applicable(cfg, shape)
