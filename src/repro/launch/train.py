"""Training driver: end-to-end fault-tolerant train loop.

Usage (CPU-scale example — examples/train_tiny_e2e.py drives this):

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen3-14b --reduced --steps 200 --batch 8 --seq 128

On a real fleet the same driver runs under the production mesh with the
full config; here the reduced config demonstrates the complete loop:
sharded data pipeline -> jit'd train step (FSDP+TP partitioning) ->
AdamW -> async checkpoints -> fault-tolerant resume.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as configs
from repro.data.pipeline import DataConfig, ShardedLoader
from repro.distributed import partition
from repro.distributed.fault import FaultConfig, FaultTolerantLoop
from repro.launch import steps as steps_lib
from repro.models import layers as L
from repro.models import transformer as T
from repro.optim import adamw


def build_state(cfg, dt, seed: int = 0):
    params = T.init_params(jax.random.PRNGKey(seed), cfg, dt)
    opt = adamw.init_state(params)
    return {"params": params, "opt": opt}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--total-steps", type=int, default=0,
                    help="LR-schedule horizon if it differs from --steps "
                         "(multi-leg runs that resume must share it)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    dt = L.FP32  # CPU runs in f32; TPU configs use BF16 params

    horizon = args.total_steps or args.steps
    opt_cfg = adamw.AdamWConfig(
        lr=args.lr, warmup_steps=max(horizon // 20, 5),
        total_steps=horizon,
    )
    step_fn_inner = steps_lib.make_train_step(cfg, opt_cfg, dt)

    @jax.jit
    def step_fn(state, batch):
        params, opt, metrics = step_fn_inner(
            state["params"], state["opt"], batch
        )
        return {"params": params, "opt": opt}, metrics

    data_cfg = DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch
    )
    loader = ShardedLoader(data_cfg)
    state = build_state(cfg, dt)

    def wrapped(state, batch):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.frontend:
            b["frontend"] = jnp.zeros(
                (args.batch, cfg.frontend_len, cfg.d_model), jnp.float32
            )
        new_state, metrics = step_fn(state, b)
        return new_state, {k: float(v) for k, v in metrics.items()}

    loop = FaultTolerantLoop(
        wrapped, state, loader,
        FaultConfig(checkpoint_dir=args.ckpt_dir,
                    checkpoint_every=args.ckpt_every),
    )
    if args.resume and loop.try_restore():
        print(f"resumed from step {loop.step}")

    t0 = time.time()
    metrics = loop.run(args.steps)
    dt_s = time.time() - t0
    losses = [m["loss"] for m in metrics]
    print(
        f"arch={cfg.name} steps={len(metrics)} "
        f"loss {losses[0]:.4f} -> {losses[-1]:.4f} "
        f"({dt_s:.1f}s, {dt_s / max(len(metrics),1) * 1e3:.0f} ms/step)"
    )
    return losses


if __name__ == "__main__":
    main()
