"""Serving driver: batched prefill + decode with the monotonic KV-cache
frontier (DESIGN.md §3.2).

Continuous-batching shape: requests arrive with different prompt
lengths; the cache ``lengths`` vector is exactly the per-sequence RAW
frontier — append(store at t) / attend(load <= t) — and the decode step
advances every frontier by one. greedy sampling for determinism.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import base as configs
from repro.launch import steps as steps_lib
from repro.models import layers as L
from repro.models import transformer as T


def serve_batch(cfg, params, prompts, *, max_new: int, max_seq: int,
                dt=L.FP32):
    """prompts: (B, P) int32 (right-padded with zeros; lengths given by
    nonzero prefix). Returns generated tokens (B, max_new)."""
    b, p_len = prompts.shape
    lengths = jnp.sum(prompts > 0, axis=1).astype(jnp.int32)
    cache = T.init_cache(cfg, b, max_seq, dt)

    serve_step = jax.jit(steps_lib.make_serve_step(cfg, dt))

    # teacher-forced prefill via repeated decode (simple and exact for
    # the demo; the production path lowers prefill() once)
    lens = jnp.zeros((b,), jnp.int32)
    for t in range(p_len):
        tok = prompts[:, t][:, None]
        logits, cache, lens = serve_step(params, tok, cache, lens)

    out = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for _ in range(max_new):
        out.append(tok)
        logits, cache, lens = serve_step(params, tok, cache, lens)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    dt = L.FP32
    params = T.init_params(jax.random.PRNGKey(0), cfg, dt)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 3, cfg.vocab
    ).astype(jnp.int32)

    t0 = time.time()
    toks = serve_batch(
        cfg, params, prompts, max_new=args.max_new,
        max_seq=args.prompt_len + args.max_new + 1,
    )
    dt_s = time.time() - t0
    print(f"arch={cfg.name} generated {toks.shape} in {dt_s:.1f}s")
    print(toks[:2])
    return toks


if __name__ == "__main__":
    main()
