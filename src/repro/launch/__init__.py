"""Drivers and analysis: training/serving entry points, dry-run HLO
cost model, roofline + design-space sweep summarization
(``repro.launch.analysis``)."""
