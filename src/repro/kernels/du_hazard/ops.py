"""Public jit'd wrappers for the du_hazard kernel.

``hazard_frontier`` — Pallas kernel (TPU target; interpret=True on CPU),
with ``side`` selecting the hazard merge ("right": RAW/WAR/WAW — all
wait for the equal-address producer) vs the strict-precedence variant
("left"; kernel module docstring).
``hazard_frontier_batch`` — K independent stream pairs in one launch
(the multi-array shape of a fused program).
``hazard_frontier_ref`` / ``hazard_frontier_batch_ref`` — pure-jnp
oracles.
``wave_partition`` — composition used by the fused executor / MoE path:
given per-pair frontiers, assign each consumer request the earliest wave
in which all its producers have committed.
"""

import jax
import jax.numpy as jnp

from repro.kernels.du_hazard.kernel import (
    hazard_frontier,
    hazard_frontier_batch,
)
from repro.kernels.du_hazard.ref import (
    hazard_frontier_batch_ref,
    hazard_frontier_ref,
)

__all__ = [
    "hazard_frontier",
    "hazard_frontier_batch",
    "hazard_frontier_ref",
    "hazard_frontier_batch_ref",
    "wave_partition",
]


@jax.jit
def wave_partition(frontiers: jax.Array, src_waves: jax.Array) -> jax.Array:
    """Given each dst's required src commit count (``frontiers``, from
    hazard_frontier) and the wave index of every src request, the wave of
    each dst = 1 + wave of its last required producer (0 if none).

    This is the TPU replacement for per-cycle DU stalling: the stall
    condition becomes an index computation (DESIGN.md §2, "stalling →
    partitioning")."""
    last = jnp.maximum(frontiers - 1, 0)
    producer_wave = jnp.where(
        frontiers > 0, jnp.take(src_waves, last, mode="clip"), -1
    )
    return producer_wave + 1
