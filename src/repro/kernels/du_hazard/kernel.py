"""Pallas TPU kernel: DU hazard frontier merge (paper §5 → DESIGN.md §2).

Computes, for every consumer (dst) request, the number of producer (src)
requests that must commit first. For a *monotonically non-decreasing*
source address stream — the paper's §3.1 requirement — this is

    frontier[j] = |{ i : src_addr[i] <= dst_addr[j] }|     (side="right")
    frontier[j] = |{ i : src_addr[i] <  dst_addr[j] }|     (side="left")

which is exactly the Hazard Safety Check's address disjunct
(``req.addr_dst < ack.addr_src``) solved for the minimal safe frontier,
evaluated for the whole stream at once instead of stalling per request.

``side="right"`` is the hazard-merge direction for *all three*
dependency kinds — RAW, WAR and WAW each require the consumer to wait
for the producer at its own address (a WAR store waits for the
equal-address load; see the crosschecks in ``benchmarks/bench_pallas.py``).
``side="left"`` is the strict-precedence variant — producers strictly
below the address, e.g. a forwarding frontier that must *exclude* the
equal-address producer itself. It is NOT a WAR merge: used as one it
under-counts the equal-address load and admits the overwrite a wave
early.

TPU mapping: one kernel serves both the single-pair and the batched
shape (K independent (src, dst) stream pairs — e.g. one per protected
array of a fused program — in one launch; the single-pair wrapper is
the K=1 row). The grid tiles (stream, dst block); each program iterates
its stream's src row in VMEM-sized blocks, accumulating block-local
counts with a broadcast compare + row reduction (VPU work, 8x128-lane
friendly). No address *history* is materialized — only
(block_d, block_s) tiles, mirroring how the paper's DU needs only
frontier registers, not history CAMs. Streams are length-padded (src
with +INT_MAX: never counted; dst with -INT_MAX: count 0).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hazard_kernel(src_ref, dst_ref, out_ref, *, src_len: int,
                   block_s: int, strict: bool):
    """One (stream k, dst block) tile vs stream k's whole src row."""
    dst = dst_ref[...][0]  # (block_d,)
    n_sblocks = src_len // block_s

    def body(s, acc):
        blk = jax.lax.dynamic_slice(
            src_ref[...], (0, s * block_s), (1, block_s)
        )[0]
        # count src entries <= (or <, side="left") each dst element
        if strict:
            le = (blk[None, :] < dst[:, None]).astype(jnp.int32)
        else:
            le = (blk[None, :] <= dst[:, None]).astype(jnp.int32)
        return acc + jnp.sum(le, axis=1)

    acc = jax.lax.fori_loop(
        0, n_sblocks, body, jnp.zeros(dst.shape, dtype=jnp.int32)
    )
    out_ref[...] = acc[None, :]


_BIG = jnp.iinfo(jnp.int32).max


@functools.partial(
    jax.jit, static_argnames=("side", "block_d", "block_s", "interpret")
)
def hazard_frontier_batch(
    src_addr: jax.Array,  # (K, S) int32, each row monotonic
    dst_addr: jax.Array,  # (K, D) int32
    *,
    side: str = "right",
    block_d: int = 256,
    block_s: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """K independent frontier merges in one launch — the multi-array /
    multi-PE shape of a fused program (module docstring). Returns
    (K, D) int32 frontiers; padded lanes count 0 by the pad convention.
    """
    assert side in ("right", "left"), side
    assert src_addr.ndim == 2 and dst_addr.ndim == 2
    assert src_addr.shape[0] == dst_addr.shape[0]
    k, s = src_addr.shape
    d = dst_addr.shape[1]
    s_pad = -s % block_s
    d_pad = -d % block_d
    src_p = jnp.pad(src_addr.astype(jnp.int32), ((0, 0), (0, s_pad)),
                    constant_values=_BIG)
    dst_p = jnp.pad(dst_addr.astype(jnp.int32), ((0, 0), (0, d_pad)),
                    constant_values=-_BIG)
    grid = (k, dst_p.shape[1] // block_d)
    out = pl.pallas_call(
        functools.partial(
            _hazard_kernel, src_len=src_p.shape[1], block_s=block_s,
            strict=(side == "left"),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, src_p.shape[1]), lambda kk, i: (kk, 0)),
            pl.BlockSpec((1, block_d), lambda kk, i: (kk, i)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda kk, i: (kk, i)),
        out_shape=jax.ShapeDtypeStruct(
            (k, dst_p.shape[1]), jnp.int32
        ),
        interpret=interpret,
    )(src_p, dst_p)
    return out[:, :d]


@functools.partial(
    jax.jit, static_argnames=("side", "block_d", "block_s", "interpret")
)
def hazard_frontier(
    src_addr: jax.Array,
    dst_addr: jax.Array,
    *,
    side: str = "right",
    block_d: int = 256,
    block_s: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Minimal safe src commit count per dst request — the K=1 row of
    ``hazard_frontier_batch`` (one kernel, two shapes).

    src_addr: (S,) int32, monotonically non-decreasing (asserted by the
              compiler's §3 analysis or a §3.3 user annotation).
    dst_addr: (D,) int32, any distribution (consumer monotonicity is NOT
              required — only the source's, exactly as in the paper).
    side:     "right" merges hazards (RAW/WAR/WAW all wait for the
              equal-address producer); "left" is the strict-precedence
              variant (module docstring — not a WAR merge).
    """
    return hazard_frontier_batch(
        src_addr[None, :], dst_addr[None, :], side=side,
        block_d=block_d, block_s=block_s, interpret=interpret,
    )[0]
