"""Pallas TPU kernel: DU hazard frontier merge (paper §5 → DESIGN.md §2).

Computes, for every consumer (dst) request, the number of producer (src)
requests that must commit first. For a *monotonically non-decreasing*
source address stream — the paper's §3.1 requirement — this is

    frontier[j] = |{ i : src_addr[i] <= dst_addr[j] }|

which is exactly the Hazard Safety Check's address disjunct
(``req.addr_dst < ack.addr_src``) solved for the minimal safe frontier,
evaluated for the whole stream at once instead of stalling per request.

TPU mapping: the dst stream is tiled over the grid; each program
iterates the src stream in VMEM-sized blocks, accumulating block-local
counts with a broadcast compare + row reduction (VPU work, 8x128-lane
friendly). No address *history* is materialized — only (block_d, block_s)
tiles, mirroring how the paper's DU needs only frontier registers, not
history CAMs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hazard_kernel(src_ref, dst_ref, out_ref, *, src_len: int, block_s: int):
    """One dst block vs the whole src stream, block by block."""
    dst = dst_ref[...]  # (block_d,)
    n_sblocks = src_len // block_s

    def body(s, acc):
        blk = jax.lax.dynamic_slice(src_ref[...], (s * block_s,), (block_s,))
        # count src entries <= each dst element in this src block
        le = (blk[None, :] <= dst[:, None]).astype(jnp.int32)
        return acc + jnp.sum(le, axis=1)

    acc = jax.lax.fori_loop(
        0, n_sblocks, body, jnp.zeros(dst.shape, dtype=jnp.int32)
    )
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_d", "block_s", "interpret"))
def hazard_frontier(
    src_addr: jax.Array,
    dst_addr: jax.Array,
    *,
    block_d: int = 256,
    block_s: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Minimal safe src commit count per dst request.

    src_addr: (S,) int32, monotonically non-decreasing (asserted by the
              compiler's §3 analysis or a §3.3 user annotation).
    dst_addr: (D,) int32, any distribution (consumer monotonicity is NOT
              required — only the source's, exactly as in the paper).
    """
    s, d = src_addr.shape[0], dst_addr.shape[0]
    s_pad = -s % block_s
    d_pad = -d % block_d
    # pad src with +inf (never counted), dst with -inf (count 0)
    big = jnp.iinfo(jnp.int32).max
    src_p = jnp.pad(src_addr.astype(jnp.int32), (0, s_pad), constant_values=big)
    dst_p = jnp.pad(
        dst_addr.astype(jnp.int32), (0, d_pad), constant_values=-big
    )
    grid = (dst_p.shape[0] // block_d,)
    out = pl.pallas_call(
        functools.partial(
            _hazard_kernel, src_len=src_p.shape[0], block_s=block_s
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((src_p.shape[0],), lambda i: (0,)),  # full src in VMEM
            pl.BlockSpec((block_d,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dst_p.shape[0],), jnp.int32),
        interpret=interpret,
    )(src_p, dst_p)
    return out[:d]
