"""Pure-jnp oracle for the du_hazard kernel."""

import jax.numpy as jnp


def hazard_frontier_ref(src_addr, dst_addr):
    """Number of src requests with address <= each dst address.

    Requires src_addr monotonically non-decreasing — then this equals
    searchsorted(src, dst, 'right'), i.e. the minimal safe frontier of
    the paper's address disjunct.
    """
    return jnp.searchsorted(
        src_addr.astype(jnp.int32), dst_addr.astype(jnp.int32), side="right"
    ).astype(jnp.int32)
