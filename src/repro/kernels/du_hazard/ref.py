"""Pure-jnp oracle for the du_hazard kernel."""

import jax.numpy as jnp


def hazard_frontier_ref(src_addr, dst_addr, side: str = "right"):
    """Number of src requests with address <= (``side="right"``) or <
    (``side="left"``) each dst address.

    Requires src_addr monotonically non-decreasing — then this equals
    searchsorted(src, dst, side), i.e. the minimal safe frontier of
    the paper's address disjunct: "right" is the hazard-merge
    direction (RAW/WAR/WAW all wait for the equal-address producer),
    "left" the strict-precedence variant (kernel module docstring).
    """
    return jnp.searchsorted(
        src_addr.astype(jnp.int32), dst_addr.astype(jnp.int32), side=side
    ).astype(jnp.int32)


def hazard_frontier_batch_ref(src_addr, dst_addr, side: str = "right"):
    """Row-wise oracle for ``hazard_frontier_batch`` ((K, S) × (K, D))."""
    return jnp.stack([
        hazard_frontier_ref(src_addr[k], dst_addr[k], side=side)
        for k in range(src_addr.shape[0])
    ])
