"""Load-dependent-trip kernel oracles (`ref.py`).

Pure-numpy references for the loss-of-decoupling kernels in
``repro.core.programs`` (``spmv_ldtrip``, ``bfs_front``,
``chase_sum``) — an independent second oracle next to
``loopir.interpret`` for the speculative-AGU workloads (DESIGN.md §10).
"""
