"""Pure-numpy oracles for the load-dependent-trip and streaming kernels.

These recompute the final protected-array state of the speculative
kernels (``repro.core.programs``: ``spmv_ldtrip``, ``bfs_front``,
``chase_sum``) and the cross-PE FIFO streaming kernels (``stream_dot``,
``filter_pipe``, ``stream_join`` — DESIGN.md §11) directly from their
inputs — independently of LoopIR — so tests can pin
``loopir.interpret`` (and therefore every engine, which is
differential-tested against the interpreter) to a second, hand-written
semantics.
"""

from __future__ import annotations

import numpy as np


def spmv_ldtrip_ref(deg, rp, cidx, val, x):
    """y[i] = sum_k val[rp[i]+k] * x[cidx[rp[i]+k]] over deg[i] entries;
    also returns the published rowlen array (= deg)."""
    rows = len(deg)
    y = np.zeros(rows, dtype=np.float64)
    for i in range(rows):
        for k in range(int(deg[i])):
            e = int(rp[i]) + k
            y[i] += val[e] * x[int(cidx[e])]
    return np.asarray(deg, dtype=np.float64).copy(), y


def bfs_front_ref(off0, front, nodeval, nodes):
    """visit[pos] = nodeval[front[pos]] + 1 for every frontier position;
    also returns the published foff array (= off0)."""
    visit = np.zeros(nodes, dtype=np.float64)
    levels = len(off0) - 1
    for t in range(levels):
        lo, hi = int(off0[t]), int(off0[t + 1])
        for pos in range(lo, hi):
            visit[pos] = nodeval[int(front[pos])] + 1.0
    return np.asarray(off0, dtype=np.float64).copy(), visit


def chase_sum_ref(nxt, w, steps):
    """out[i] = w[p] + p where p walks the ``nxt`` chain from node 0 for
    ``steps`` steps (``laps`` full traversals of the n-node cycle)."""
    out = np.zeros(steps, dtype=np.float64)
    cur = 0
    for i in range(steps):
        p = int(nxt[cur])
        out[i] = w[p] + p
        cur = p
    return out


def strided_scan_ref(ptr, w, n):
    """out[i] = w[i] + p where p walks ``ptr`` from 0 (p = ptr[p_prev],
    an arithmetic sequence stored in memory)."""
    out = np.zeros(n, dtype=np.float64)
    cur = 0
    for i in range(n):
        p = int(ptr[cur])
        out[i] = w[i] + p
        cur = p
    return out


def stream_dot_ref(a, bv, out0, nb, k):
    """out[b] = out0[b] + sum_j a[b*k+j] * bv[b*k+j] (streamed partial
    sum folded into the writer leaf's read-modify-write)."""
    out = np.array(out0, dtype=np.float64, copy=True)
    for b in range(nb):
        ps = 0.0
        for j in range(k):
            ps = ps + a[b * k + j] * bv[b * k + j]
        out[b] = out[b] + ps
    return out


def filter_pipe_ref(x, y0):
    """y[e] = tanh(x[e]) * 0.5 + 1.0 where tanh(x[e]) > 0, else y0[e]
    (the streamed token decides the guarded store's valid bit)."""
    y = np.array(y0, dtype=np.float64, copy=True)
    for e in range(len(x)):
        v = float(np.tanh(x[e]))
        if v > 0.0:
            y[e] = v * 0.5 + 1.0
    return y


def stream_join_ref(u, w, z0):
    """z[t] = z0[t] + (u[t]*2 + (w[t]+1)) — two producer streams joined
    by a memory-less PE, result streamed to the writer."""
    z = np.array(z0, dtype=np.float64, copy=True)
    for t in range(len(u)):
        z[t] = z[t] + (u[t] * 2.0 + (w[t] + 1.0))
    return z
