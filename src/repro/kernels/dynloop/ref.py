"""Pure-numpy oracles for the load-dependent-trip kernels.

These recompute the final protected-array state of the speculative
kernels (``repro.core.programs``: ``spmv_ldtrip``, ``bfs_front``,
``chase_sum``) directly from their inputs — independently of LoopIR —
so tests can pin ``loopir.interpret`` (and therefore every engine,
which is differential-tested against the interpreter) to a second,
hand-written semantics.
"""

from __future__ import annotations

import numpy as np


def spmv_ldtrip_ref(deg, rp, cidx, val, x):
    """y[i] = sum_k val[rp[i]+k] * x[cidx[rp[i]+k]] over deg[i] entries;
    also returns the published rowlen array (= deg)."""
    rows = len(deg)
    y = np.zeros(rows, dtype=np.float64)
    for i in range(rows):
        for k in range(int(deg[i])):
            e = int(rp[i]) + k
            y[i] += val[e] * x[int(cidx[e])]
    return np.asarray(deg, dtype=np.float64).copy(), y


def bfs_front_ref(off0, front, nodeval, nodes):
    """visit[pos] = nodeval[front[pos]] + 1 for every frontier position;
    also returns the published foff array (= off0)."""
    visit = np.zeros(nodes, dtype=np.float64)
    levels = len(off0) - 1
    for t in range(levels):
        lo, hi = int(off0[t]), int(off0[t + 1])
        for pos in range(lo, hi):
            visit[pos] = nodeval[int(front[pos])] + 1.0
    return np.asarray(off0, dtype=np.float64).copy(), visit


def chase_sum_ref(nxt, w, n):
    """out[i] = w[p] + p where p walks the ``nxt`` chain from node 0."""
    out = np.zeros(n, dtype=np.float64)
    cur = 0
    for i in range(n):
        p = int(nxt[cur])
        out[i] = w[p] + p
        cur = p
    return out
