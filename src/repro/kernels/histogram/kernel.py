"""Pallas TPU kernel: fused histogram (hist+add benchmark substrate).

A histogram's store stream (hist[d[i]] += 1) is data-dependent and
non-monotonic — the paper's hardest case, where the DU falls back to
sentinels. The TPU adaptation sidesteps the hazard entirely by
re-associating the reduction: each data block produces a *private*
bincount tile in VMEM (broadcast-compare + row sum), accumulated across
the sequential grid — no read-modify-write hazard ever reaches memory.
This is the "re-associate instead of disambiguate" escape hatch noted in
DESIGN.md §8 for non-monotonic reductions.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(data_ref, out_ref, *, n_bins):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    d = data_ref[...]  # (block,)
    bins = jax.lax.iota(jnp.int32, n_bins)
    counts = jnp.sum(
        (d[None, :] == bins[:, None]).astype(jnp.float32), axis=1
    )
    out_ref[...] += counts.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_bins", "block", "interpret"))
def histogram(
    data: jax.Array,  # (N,) int32 bin indices
    *,
    n_bins: int,
    block: int = 512,
    interpret: bool = False,
) -> jax.Array:
    n = data.shape[0]
    pad = -n % block
    d = jnp.pad(data.astype(jnp.int32), (0, pad), constant_values=-1)
    grid = (d.shape[0] // block,)
    return pl.pallas_call(
        functools.partial(_hist_kernel, n_bins=n_bins),
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((n_bins,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n_bins,), jnp.float32),
        interpret=interpret,
    )(d)
