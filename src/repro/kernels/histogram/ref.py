"""Pure-jnp oracle for the histogram kernel."""

import jax.numpy as jnp


def histogram_ref(data, *, n_bins):
    return jnp.zeros((n_bins,), jnp.float32).at[data].add(
        jnp.where(data >= 0, 1.0, 0.0)
    )
