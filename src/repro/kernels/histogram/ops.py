"""Public histogram wrappers (hist+add benchmark: two fused histograms
plus the addition loop, all waves in one pass)."""

import jax.numpy as jnp

from repro.kernels.histogram.kernel import histogram
from repro.kernels.histogram.ref import histogram_ref

__all__ = ["histogram", "histogram_ref", "hist_add"]


def hist_add(d1, d2, *, n_bins, interpret=False, use_kernel=True):
    """The full hist+add benchmark, dynamically fused: both histograms
    and the addition execute as one fused program (the FUS2 pipeline of
    paper Table 1)."""
    f = histogram if use_kernel else histogram_ref
    kw = dict(n_bins=n_bins)
    if use_kernel:
        kw["interpret"] = interpret
    h1 = f(d1, **kw)
    h2 = f(d2, **kw)
    return h1 + h2
