"""Public csr_spmv wrappers."""

import numpy as np

from repro.kernels.csr_spmv.kernel import csr_spmv
from repro.kernels.csr_spmv.ref import csr_spmv_ref, csr_to_ell

__all__ = ["csr_spmv", "csr_spmv_ref", "csr_to_ell", "spmv_from_csr"]


def spmv_from_csr(row_ptr, col_idx, values, x, *, block_r=128, interpret=False,
                  use_kernel=True):
    """End-to-end y = A @ x from CSR inputs."""
    n_rows = len(row_ptr) - 1
    cols, vals = csr_to_ell(
        np.asarray(row_ptr), np.asarray(col_idx), np.asarray(values),
        n_rows, block_r,
    )
    if use_kernel:
        y = csr_spmv(cols, vals, x, block_r=block_r, interpret=interpret)
    else:
        y = csr_spmv_ref(cols, vals, x)
    return y[:n_rows]
