"""Pallas TPU kernel: CSR SpMV over a block-padded (ELL) layout.

Substrate for the paper's matpower / tanh+spmv benchmarks and the
sparse half of the bnn code. The CSR column stream is monotone within
each row (§3.3) — ops.py converts CSR to a dense-padded ELL block
layout on the host (the static analogue of the DU's burst coalescing:
every gather touches a dense, aligned tile instead of issuing per-element
requests).

Grid: one program per row block; x is resident in VMEM (sizes here are
benchmark-scale; a production kernel would tile x with a second grid
dimension and accumulate).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmv_kernel(cols_ref, vals_ref, x_ref, y_ref):
    cols = cols_ref[...]  # (block_r, width)
    vals = vals_ref[...].astype(jnp.float32)
    x = x_ref[...]
    gathered = jnp.take(x, cols, mode="clip").astype(jnp.float32)
    y_ref[...] = jnp.sum(vals * gathered, axis=1).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def csr_spmv(
    cols: jax.Array,  # (N_pad, W) int32, padded col indices (pad -> 0 val)
    vals: jax.Array,  # (N_pad, W) f32, zeros at padding
    x: jax.Array,     # (M,) f32
    *,
    block_r: int = 128,
    interpret: bool = False,
) -> jax.Array:
    n_pad, w = cols.shape
    assert n_pad % block_r == 0
    grid = (n_pad // block_r,)
    return pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, w), lambda i: (i, 0)),
            pl.BlockSpec((block_r, w), lambda i: (i, 0)),
            pl.BlockSpec((x.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_r,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), x.dtype),
        interpret=interpret,
    )(cols.astype(jnp.int32), vals, x)
