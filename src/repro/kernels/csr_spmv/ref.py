"""Pure-jnp oracle for csr_spmv."""

import jax.numpy as jnp
import numpy as np


def csr_spmv_ref(cols, vals, x):
    g = jnp.take(x, cols.astype(jnp.int32), mode="clip").astype(jnp.float32)
    return jnp.sum(vals.astype(jnp.float32) * g, axis=1).astype(x.dtype)


def csr_to_ell(row_ptr: np.ndarray, col_idx: np.ndarray, values: np.ndarray,
               n_rows: int, block_r: int = 128):
    """Host-side CSR -> padded ELL conversion (ops.py layout pass)."""
    width = max(1, int(np.max(row_ptr[1:] - row_ptr[:-1])))
    n_pad = -(-n_rows // block_r) * block_r
    cols = np.zeros((n_pad, width), dtype=np.int32)
    vals = np.zeros((n_pad, width), dtype=np.float32)
    for r in range(n_rows):
        lo, hi = row_ptr[r], row_ptr[r + 1]
        cols[r, : hi - lo] = col_idx[lo:hi]
        vals[r, : hi - lo] = values[lo:hi]
    return cols, vals
