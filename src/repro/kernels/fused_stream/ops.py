"""Public wrappers for the fused_stream kernel: end-to-end fused
producer/consumer execution (the RAWloop pattern of paper Fig. 1, fully
vectorized)."""

import jax

from repro.kernels.du_hazard.ops import hazard_frontier, hazard_frontier_ref
from repro.kernels.fused_stream.kernel import fused_stream
from repro.kernels.fused_stream.ref import fused_stream_ref

__all__ = ["fused_stream", "fused_stream_ref", "fused_raw_loops"]


def fused_raw_loops(
    src_addr, src_val, dst_addr, memory, *, interpret: bool = False
):
    """The complete Fig. 1 pipeline: producer loop storing A[f(i)],
    consumer loop loading A[g(j)], fused. Frontier merge (du_hazard) +
    forwarding (fused_stream) = consumer values with zero stalls and no
    sequentialization — assuming monotonic f(i), exactly the paper's
    requirement. Consumers see the producer's final effect on overlapping
    addresses; untouched addresses come from memory."""
    frontier = hazard_frontier(src_addr, dst_addr, interpret=interpret)
    vals, hits = fused_stream(
        src_addr, src_val, frontier, dst_addr, memory, interpret=interpret
    )
    return vals, hits
