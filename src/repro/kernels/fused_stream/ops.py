"""Public wrappers for the fused_stream kernel: end-to-end fused
producer/consumer execution (the RAWloop pattern of paper Fig. 1, fully
vectorized), generalized to §6 guarded producer streams via per-request
valid bits and a bounded same-address lookback."""

import numpy as np

from repro.kernels.du_hazard.ops import hazard_frontier, hazard_frontier_ref
from repro.kernels.fused_stream.kernel import fused_stream
from repro.kernels.fused_stream.ref import fused_stream_ref

__all__ = [
    "fused_stream", "fused_stream_ref", "fused_raw_loops", "min_lookback",
]


def min_lookback(src_addr) -> int:
    """Smallest exact ``lookback`` for a monotonic producer stream: the
    longest run of equal addresses (a §6-invalid entry can hide at most
    run-length - 1 younger siblings; the scan must reach past them)."""
    a = np.asarray(src_addr)
    if len(a) == 0:
        return 1
    starts = np.flatnonzero(np.diff(a) != 0)
    bounds = np.concatenate([[-1], starts, [len(a) - 1]])
    return int(np.diff(bounds).max())


def fused_raw_loops(
    src_addr, src_val, dst_addr, memory, src_valid=None, *,
    lookback=None, interpret: bool = False,
):
    """The complete Fig. 1 pipeline: producer loop storing A[f(i)],
    consumer loop loading A[g(j)], fused. Frontier merge (du_hazard) +
    forwarding (fused_stream) = consumer values with zero stalls and no
    sequentialization — assuming monotonic f(i), exactly the paper's
    requirement. Consumers see the producer's final *landed* effect on
    overlapping addresses (guard-failed producers forward nothing —
    pass their §6 valid bits as ``src_valid``); untouched addresses
    come from memory.

    ``lookback=None`` picks the exact depth: 1 for all-valid producers
    (the youngest entry below the frontier is the run's youngest), the
    longest same-address run otherwise — a valid producer hidden
    behind younger invalid siblings must stay reachable."""
    if lookback is None:
        lookback = 1 if src_valid is None else min_lookback(src_addr)
    frontier = hazard_frontier(src_addr, dst_addr, interpret=interpret)
    vals, hits = fused_stream(
        src_addr, src_val, frontier, dst_addr, memory, src_valid,
        lookback=lookback, interpret=interpret,
    )
    return vals, hits
