"""Pure-jnp oracle for fused_stream (store-to-load forwarding)."""

import jax.numpy as jnp


def fused_stream_ref(src_addr, src_val, frontier, dst_addr, memory,
                     src_valid=None, lookback: int = 1):
    """Youngest *valid* producer before the frontier with matching
    address forwards; otherwise read memory. Requires monotonic
    src_addr (same-address producers are adjacent, so the candidates
    are the ``lookback`` entries just below the frontier)."""
    f = frontier.astype(jnp.int32)
    a = dst_addr.astype(jnp.int32)
    src_addr = src_addr.astype(jnp.int32)
    if src_valid is None:
        src_valid = jnp.ones(src_addr.shape, dtype=jnp.int32)
    found = jnp.zeros(a.shape, dtype=jnp.bool_)
    val = jnp.zeros(a.shape, dtype=src_val.dtype)
    for lb in range(lookback):
        idx = f - 1 - lb
        ok = idx >= 0
        cand_addr = jnp.take(src_addr, idx, mode="clip")
        cand_val = jnp.take(src_val, idx, mode="clip")
        cand_ok = jnp.take(src_valid.astype(jnp.int32), idx,
                           mode="clip") == 1
        match = ok & (cand_addr == a) & cand_ok
        val = jnp.where(match & ~found, cand_val, val)
        found = found | match
    return jnp.where(found, val, jnp.take(memory, a, mode="clip")), found
