"""Pure-jnp oracle for fused_stream (store-to-load forwarding)."""

import jax.numpy as jnp


def fused_stream_ref(src_addr, src_val, frontier, dst_addr, memory):
    """Youngest producer before the frontier with matching address
    forwards; otherwise read memory. Requires monotonic src_addr (the
    youngest same-address producer below the frontier is at index
    frontier-1)."""
    f = frontier.astype(jnp.int32)
    a = dst_addr.astype(jnp.int32)
    last = jnp.maximum(f - 1, 0)
    cand_addr = jnp.take(src_addr.astype(jnp.int32), last, mode="clip")
    cand_val = jnp.take(src_val, last, mode="clip")
    hit = (f > 0) & (cand_addr == a)
    return jnp.where(hit, cand_val, jnp.take(memory, a, mode="clip")), hit
