"""Pallas TPU kernel: fused producer/consumer stream with store-to-load
forwarding (paper §5.5 → DESIGN.md §2).

The FPGA DU forwards a dependent value out of the store pending buffer
via an associative search. On TPU the analogue is *in-tile reuse*: the
producer's (address, value) stream block is resident in VMEM while the
consumer block executes, so a consumer whose address matches a producer
entry takes the value directly — no HBM round trip — and only consumers
with no match read memory.

Semantics (matching the DU): for consumer j with address a_j and
program-order frontier f_j (from du_hazard — the number of producer
requests preceding it), the value is

    youngest producer i < f_j with addr_i == a_j   -> forwarded value
    no such producer                               -> memory[a_j]

Monotonic producer addresses make "youngest before the frontier" a
bounded lookback: it is producer index f_j - 1 iff addr[f_j - 1] == a_j
(all older same-address entries are immediately adjacent — the youngest
is the last one below the frontier). This is why the paper's pending
buffers can stay small; here it collapses the associative search to one
gather + compare.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_kernel(src_addr_ref, src_val_ref, frontier_ref, dst_addr_ref,
                  mem_ref, out_ref, hits_ref):
    f = frontier_ref[...]  # (block_d,) producer commit counts
    a = dst_addr_ref[...]  # (block_d,)
    last = jnp.maximum(f - 1, 0)
    cand_addr = jnp.take(src_addr_ref[...], last, mode="clip")
    cand_val = jnp.take(src_val_ref[...], last, mode="clip")
    hit = (f > 0) & (cand_addr == a)
    mem_val = jnp.take(mem_ref[...], a, mode="clip")
    out_ref[...] = jnp.where(hit, cand_val, mem_val)
    hits_ref[...] = hit.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def fused_stream(
    src_addr: jax.Array,   # (S,) int32 monotonic producer addresses
    src_val: jax.Array,    # (S,) f32 producer values
    frontier: jax.Array,   # (D,) int32 per-consumer producer frontier
    dst_addr: jax.Array,   # (D,) int32 consumer addresses
    memory: jax.Array,     # (M,) f32 backing array (pre-producer state)
    *,
    block_d: int = 256,
    interpret: bool = False,
):
    """Returns (values, forwarded_mask) for every consumer request."""
    d = dst_addr.shape[0]
    d_pad = -d % block_d
    f_p = jnp.pad(frontier.astype(jnp.int32), (0, d_pad))
    a_p = jnp.pad(dst_addr.astype(jnp.int32), (0, d_pad))
    grid = (a_p.shape[0] // block_d,)
    out, hits = pl.pallas_call(
        _fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((src_addr.shape[0],), lambda i: (0,)),
            pl.BlockSpec((src_val.shape[0],), lambda i: (0,)),
            pl.BlockSpec((block_d,), lambda i: (i,)),
            pl.BlockSpec((block_d,), lambda i: (i,)),
            pl.BlockSpec((memory.shape[0],), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_d,), lambda i: (i,)),
            pl.BlockSpec((block_d,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((a_p.shape[0],), src_val.dtype),
            jax.ShapeDtypeStruct((a_p.shape[0],), jnp.int32),
        ],
        interpret=interpret,
    )(src_addr.astype(jnp.int32), src_val, f_p, a_p, memory)
    return out[:d], hits[:d].astype(bool)
