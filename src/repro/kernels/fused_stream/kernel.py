"""Pallas TPU kernel: fused producer/consumer stream with store-to-load
forwarding (paper §5.5 → DESIGN.md §2).

The FPGA DU forwards a dependent value out of the store pending buffer
via an associative search. On TPU the analogue is *in-tile reuse*: the
producer's (address, value) stream block is resident in VMEM while the
consumer block executes, so a consumer whose address matches a producer
entry takes the value directly — no HBM round trip — and only consumers
with no match read memory.

Semantics (matching the DU): for consumer j with address a_j and
program-order frontier f_j (from du_hazard — the number of producer
requests preceding it), the value is

    youngest *valid* producer i < f_j with addr_i == a_j -> forwarded
    no such producer                                     -> memory[a_j]

Monotonic producer addresses make "youngest before the frontier" a
bounded lookback: all same-address entries are immediately adjacent, so
the candidates are producer indices f_j - 1, f_j - 2, ... — a static
``lookback``-deep scan (one gather + compare per step), not an
associative search. This is why the paper's pending buffers can stay
small. ``lookback=1`` with all-valid producers is the original RAW
microbenchmark shape; guarded producers (§6: a store whose guard failed
leaves a *request* but no effect) are skipped by their valid bit, which
is why the scan must be able to look deeper than one entry — any
``lookback >= max same-address run length`` is exact
(``ops.min_lookback`` computes the tight bound for a stream).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_kernel(src_addr_ref, src_val_ref, src_valid_ref, frontier_ref,
                  dst_addr_ref, mem_ref, out_ref, hits_ref, *,
                  lookback: int):
    f = frontier_ref[...]  # (block_d,) producer commit counts
    a = dst_addr_ref[...]  # (block_d,)
    src_addr = src_addr_ref[...]
    src_val = src_val_ref[...]
    src_valid = src_valid_ref[...]
    found = jnp.zeros(a.shape, dtype=jnp.bool_)
    val = jnp.zeros(a.shape, dtype=src_val.dtype)
    for lb in range(lookback):
        idx = f - 1 - lb
        ok = idx >= 0
        cand_addr = jnp.take(src_addr, idx, mode="clip")
        cand_val = jnp.take(src_val, idx, mode="clip")
        cand_ok = jnp.take(src_valid, idx, mode="clip") == 1
        match = ok & (cand_addr == a) & cand_ok
        val = jnp.where(match & ~found, cand_val, val)
        found = found | match
    mem_val = jnp.take(mem_ref[...], a, mode="clip")
    out_ref[...] = jnp.where(found, val, mem_val)
    hits_ref[...] = found.astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("lookback", "block_d", "interpret")
)
def fused_stream(
    src_addr: jax.Array,   # (S,) int32 monotonic producer addresses
    src_val: jax.Array,    # (S,) f32 producer values
    frontier: jax.Array,   # (D,) int32 per-consumer producer frontier
    dst_addr: jax.Array,   # (D,) int32 consumer addresses
    memory: jax.Array,     # (M,) f32 backing array (pre-producer state)
    src_valid: jax.Array = None,  # (S,) optional §6 valid bits (1 = landed)
    *,
    lookback: int = 1,
    block_d: int = 256,
    interpret: bool = False,
):
    """Returns (values, forwarded_mask) for every consumer request.

    ``src_valid=None`` means every producer request landed (the
    unguarded case); then ``lookback=1`` is exact for distinct-address
    producers and equal-address runs alike (the youngest entry below
    the frontier is the run's youngest). With guarded producers pass
    the valid bits and a ``lookback`` covering the longest
    same-address run (``ops.min_lookback``).
    """
    d = dst_addr.shape[0]
    d_pad = -d % block_d
    f_p = jnp.pad(frontier.astype(jnp.int32), (0, d_pad))
    a_p = jnp.pad(dst_addr.astype(jnp.int32), (0, d_pad))
    if src_valid is None:
        src_valid = jnp.ones(src_addr.shape, dtype=jnp.int32)
    grid = (a_p.shape[0] // block_d,)
    out, hits = pl.pallas_call(
        functools.partial(_fused_kernel, lookback=lookback),
        grid=grid,
        in_specs=[
            pl.BlockSpec((src_addr.shape[0],), lambda i: (0,)),
            pl.BlockSpec((src_val.shape[0],), lambda i: (0,)),
            pl.BlockSpec((src_valid.shape[0],), lambda i: (0,)),
            pl.BlockSpec((block_d,), lambda i: (i,)),
            pl.BlockSpec((block_d,), lambda i: (i,)),
            pl.BlockSpec((memory.shape[0],), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_d,), lambda i: (i,)),
            pl.BlockSpec((block_d,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((a_p.shape[0],), src_val.dtype),
            jax.ShapeDtypeStruct((a_p.shape[0],), jnp.int32),
        ],
        interpret=interpret,
    )(src_addr.astype(jnp.int32), src_val, src_valid.astype(jnp.int32),
      f_p, a_p, memory)
    return out[:d], hits[:d].astype(bool)
