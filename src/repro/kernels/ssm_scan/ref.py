"""Pure-jnp oracle for the ssm_scan kernel."""

import jax
import jax.numpy as jnp


def ssm_scan_ref(xi, dt, bmat, cmat, a_neg):
    """Sequential recurrence, identical math to models/ssm._mamba1_step."""
    def step(h, inputs):
        xi_t, dt_t, b_t, c_t = inputs
        a_t = jnp.exp(a_neg * dt_t[:, None])
        bx_t = (dt_t * xi_t)[:, None] * b_t[None, :]
        h_new = a_t * h + bx_t
        y_t = jnp.sum(h_new * c_t[None, :], axis=1)
        return h_new, y_t

    di, n = a_neg.shape
    h0 = jnp.zeros((di, n), jnp.float32)
    _, y = jax.lax.scan(
        step, h0,
        (xi.astype(jnp.float32), dt.astype(jnp.float32),
         bmat.astype(jnp.float32), cmat.astype(jnp.float32)),
    )
    return y.astype(xi.dtype)
