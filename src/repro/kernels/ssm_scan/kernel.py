"""Pallas TPU kernel: fused Mamba-1 selective-scan chunk.

The §Perf Cell-A analysis (EXPERIMENTS.md) shows the SSM memory term is
dominated by per-position (B, di, n) intermediates hitting HBM in the
pure-JAX chunked scan. This kernel is the production fix: one grid step
processes a whole (chunk, di-block) tile with the recurrence state, the
projections, and every intermediate resident in VMEM — HBM traffic
collapses to the xi/dt/B/C inputs and the y output, once each.

Grid: (di_blocks, n_chunks). The chunk axis is the paper's monotonic RAW
frontier (DESIGN.md §3.3): chunk c+1 *loads* the state chunk c *stored*
— realized here by accumulating the carried state in a VMEM scratch
that lives across the (sequential) grid steps of one di-block row.

Layout notes for the MXU/VPU: di is tiled in multiples of 128 (lane
dim); the state expansion n (16 for falcon-mamba) rides the sublane dim.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(xi_ref, dt_ref, b_ref, c_ref, a_neg_ref, y_ref, h_scratch,
                 *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    xi = xi_ref[...].astype(jnp.float32)      # (C, bd)
    dt = dt_ref[...].astype(jnp.float32)      # (C, bd)
    bmat = b_ref[...].astype(jnp.float32)     # (C, n)
    cmat = c_ref[...].astype(jnp.float32)     # (C, n)
    a_neg = a_neg_ref[...].astype(jnp.float32)  # (bd, n)

    def pos_step(t, carry):
        h = carry  # (bd, n)
        a_t = jnp.exp(a_neg * dt[t][:, None])           # (bd, n)
        bx_t = (dt[t] * xi[t])[:, None] * bmat[t][None, :]
        h_new = a_t * h + bx_t
        y_t = jnp.sum(h_new * cmat[t][None, :], axis=1)  # (bd,)
        y_ref[t, :] = y_t.astype(y_ref.dtype)
        return h_new

    h = jax.lax.fori_loop(0, chunk, pos_step, h_scratch[...])
    h_scratch[...] = h  # the chunk-final state: the §3.3 RAW frontier


@functools.partial(
    jax.jit, static_argnames=("chunk", "block_d", "interpret")
)
def ssm_scan(
    xi: jax.Array,     # (S, di) post-conv/silu activations (one sample)
    dt: jax.Array,     # (S, di) softplus'd step sizes
    bmat: jax.Array,   # (S, n) input projections
    cmat: jax.Array,   # (S, n) output projections
    a_neg: jax.Array,  # (di, n) negative decay rates (-exp(a_log))
    *,
    chunk: int = 128,
    block_d: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """y[t, d] = sum_n C[t,n] * h[t, d, n] with
    h[t] = exp(a_neg * dt[t]) * h[t-1] + dt[t] * x[t] * B[t].

    Returns y (S, di). Batch is handled by vmap in ops.py.
    """
    s, di = xi.shape
    n = bmat.shape[1]
    assert s % chunk == 0 and di % block_d == 0, (s, chunk, di, block_d)
    grid = (di // block_d, s // chunk)
    return pl.pallas_call(
        functools.partial(_scan_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((chunk, block_d), lambda d, c: (c, d)),  # xi
            pl.BlockSpec((chunk, block_d), lambda d, c: (c, d)),  # dt
            pl.BlockSpec((chunk, n), lambda d, c: (c, 0)),        # B
            pl.BlockSpec((chunk, n), lambda d, c: (c, 0)),        # C
            pl.BlockSpec((block_d, n), lambda d, c: (d, 0)),      # a_neg
        ],
        out_specs=pl.BlockSpec((chunk, block_d), lambda d, c: (c, d)),
        out_shape=jax.ShapeDtypeStruct((s, di), xi.dtype),
        # carried recurrence state, resident in VMEM across the chunk axis
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(xi, dt, bmat, cmat, a_neg)
