"""Public wrappers for the fused Mamba selective-scan kernel.

``ssm_scan_batched`` vmaps the per-sample kernel over the batch; the
model's jnp path (models/ssm._mamba1_chunked) stays the SPMD-lowering
path for the dry-run, and this kernel is the TPU execution answer to
the SSM memory-term caveat in EXPERIMENTS.md §Perf Cell A.
"""

import jax

from repro.kernels.ssm_scan.kernel import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref

__all__ = ["ssm_scan", "ssm_scan_ref", "ssm_scan_batched"]


def ssm_scan_batched(xi, dt, bmat, cmat, a_neg, *, chunk=128, block_d=512,
                     interpret=False):
    """xi/dt: (B, S, di); bmat/cmat: (B, S, n); a_neg: (di, n)."""
    return jax.vmap(
        lambda x_, d_, b_, c_: ssm_scan(
            x_, d_, b_, c_, a_neg, chunk=chunk, block_d=block_d,
            interpret=interpret,
        )
    )(xi, dt, bmat, cmat)
