"""Pallas TPU kernel: grouped expert matmul over a monotonic dispatch
stream (DESIGN.md §3.1 — the LM-framework integration of the paper).

After a stable sort of token -> expert assignments the expert-id stream
is monotonically non-decreasing: the *same* property the paper's §3.3
asserts for CSR index streams. Dispatch(store) -> expert-FFN(compute) ->
combine(load) is a cross-loop RAW chain; its hazard frontier is the
per-expert offset table (one searchsorted — see du_hazard), after which
the fused execution is a block-diagonal grouped matmul.

TPU mapping (MegaBlocks-style): tokens are sorted and padded so every
row block belongs to exactly one expert; the expert id per block is a
*scalar-prefetch* operand, so each grid step streams exactly one
expert's weight tile HBM->VMEM (the analogue of the DU coalescing one
burst per dependent group). Block sizes keep the MXU shape-aligned
(multiples of 128 on the contracting/output dims in production; tests
use smaller tiles).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(block_expert_ref, x_ref, w_ref, o_ref):
    # x_ref: (block_t, d_in); w_ref: (1, d_in, d_out) for this block's expert
    x = x_ref[...]
    w = w_ref[0]
    o_ref[...] = jnp.dot(x, w, preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


@functools.partial(
    jax.jit, static_argnames=("block_t", "interpret")
)
def group_matmul(
    x_sorted: jax.Array,      # (T_pad, d_in) tokens sorted by expert, padded
    w: jax.Array,             # (E, d_in, d_out) expert weights
    block_expert: jax.Array,  # (T_pad // block_t,) int32 expert id per block
    *,
    block_t: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Block-diagonal grouped matmul: out[t] = x[t] @ w[expert_of(t)].

    ``x_sorted`` must be padded so each ``block_t`` row block maps to a
    single expert (ops.py builds this from the monotonic dispatch
    stream). Padding rows multiply into garbage that ops.py drops.
    """
    t_pad, d_in = x_sorted.shape
    d_out = w.shape[2]
    assert t_pad % block_t == 0
    grid = (t_pad // block_t,)
    return pl.pallas_call(
        _gmm_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_t, d_in), lambda i, be: (i, 0)),
                # stream exactly this block's expert weight tile
                pl.BlockSpec((1, d_in, d_out), lambda i, be: (be[i], 0, 0)),
            ],
            out_specs=pl.BlockSpec((block_t, d_out), lambda i, be: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((t_pad, d_out), x_sorted.dtype),
        interpret=interpret,
    )(block_expert, x_sorted, w)
