"""Pure-jnp oracle for the grouped matmul kernel."""

import jax.numpy as jnp


def group_matmul_ref(x_sorted, w, block_expert, *, block_t: int = 128):
    """out[t] = x[t] @ w[expert_of_block(t // block_t)], computed with a
    plain gather of per-block weights — identical semantics to the Pallas
    kernel, used as the CPU path and the allclose oracle."""
    t_pad, d_in = x_sorted.shape
    n_blocks = t_pad // block_t
    xb = x_sorted.reshape(n_blocks, block_t, d_in)
    wb = jnp.take(w, block_expert[:n_blocks], axis=0)  # (n_blocks, d_in, d_out)
    out = jnp.einsum(
        "bti,bio->bto", xb.astype(jnp.float32), wb.astype(jnp.float32)
    )
    return out.reshape(t_pad, -1).astype(x_sorted.dtype)
