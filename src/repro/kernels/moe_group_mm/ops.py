"""Monotonic MoE dispatch/combine built on the grouped matmul kernel.

``monotonic_dispatch`` turns (tokens, router top-k assignments) into the
sorted/padded layout the kernel needs — the compiler-side counterpart of
the paper's §3.3 assertion: after the stable sort the expert stream is
monotone, so per-expert offsets come from one frontier merge
(searchsorted == du_hazard), not a history search.

``moe_ffn`` is the full dropless expert-FFN layer used by the MoE
architectures (phi3.5-moe, moonshot). It is pure JAX except the
block-diagonal matmuls, which route through the Pallas kernel on TPU
(``use_kernel=True``) or an identical-semantics jnp path on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.moe_group_mm.kernel import group_matmul
from repro.kernels.moe_group_mm.ref import group_matmul_ref

__all__ = ["monotonic_dispatch", "group_matmul", "group_matmul_ref", "moe_ffn"]


@functools.partial(jax.jit, static_argnames=("n_experts", "block_t"))
def monotonic_dispatch(expert_ids: jax.Array, n_experts: int, block_t: int):
    """Sort the (flattened) token->expert stream into monotonic order and
    pad each expert group to a multiple of block_t.

    Returns (perm, inv_positions, block_expert, group_sizes, slot_of_assignment)
    where ``slot_of_assignment[a]`` is the padded row of assignment a.
    """
    n = expert_ids.shape[0]
    order = jnp.argsort(expert_ids, stable=True)  # monotonic expert stream
    sorted_e = jnp.take(expert_ids, order)
    # per-expert sizes via the frontier merge (searchsorted on the
    # monotonic stream — same primitive as kernels/du_hazard)
    bounds = jnp.searchsorted(
        sorted_e, jnp.arange(n_experts + 1, dtype=expert_ids.dtype), side="left"
    )
    sizes = bounds[1:] - bounds[:-1]
    padded_sizes = ((sizes + block_t - 1) // block_t) * block_t
    padded_offsets = jnp.concatenate(
        [jnp.zeros((1,), sizes.dtype), jnp.cumsum(padded_sizes)]
    )
    # slot of the i-th sorted assignment inside the padded layout
    rank_within = jnp.arange(n) - jnp.take(bounds, sorted_e)
    slot_sorted = jnp.take(padded_offsets, sorted_e) + rank_within
    slot_of_assignment = jnp.zeros((n,), dtype=jnp.int32).at[order].set(
        slot_sorted.astype(jnp.int32)
    )
    t_pad = int(padded_offsets[-1]) if False else None  # dynamic; see ops
    n_blocks_per_e = padded_sizes // block_t
    # block -> expert map (static length: worst case n//block_t + n_experts)
    max_blocks = n // block_t + n_experts
    block_starts = jnp.concatenate(
        [jnp.zeros((1,), sizes.dtype), jnp.cumsum(n_blocks_per_e)]
    )
    block_ids = jnp.arange(max_blocks)
    block_expert = (
        jnp.searchsorted(block_starts, block_ids, side="right") - 1
    ).astype(jnp.int32)
    block_expert = jnp.clip(block_expert, 0, n_experts - 1)
    return order, slot_of_assignment, block_expert, sizes, padded_offsets


def moe_ffn(
    x: jax.Array,          # (T, d_model) flattened tokens
    router_logits: jax.Array,  # (T, E)
    w_in: jax.Array,       # (E, d_model, d_ff)
    w_gate: jax.Array,     # (E, d_model, d_ff) or None (non-gated)
    w_out: jax.Array,      # (E, d_ff, d_model)
    *,
    top_k: int,
    use_kernel: bool = False,
    block_t: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Dropless top-k MoE FFN with monotonic dispatch.

    The dispatch->compute->combine chain is the paper's cross-loop RAW
    pattern; monotonicity (post-sort) lets every stage run fused without
    capacity drops or history searches.
    """
    t, d_model = x.shape
    n_experts = router_logits.shape[-1]
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    flat_e = top_e.reshape(-1).astype(jnp.int32)  # (T*k,)
    n = flat_e.shape[0]
    order, slot, block_expert, sizes, padded_offsets = monotonic_dispatch(
        flat_e, n_experts, block_t
    )
    t_pad = (n // block_t + n_experts) * block_t  # static upper bound

    token_of_assignment = jnp.arange(n) // top_k
    x_sorted = jnp.zeros((t_pad, d_model), x.dtype).at[slot].set(
        x[token_of_assignment]
    )

    def mm(a, w):
        if use_kernel:
            return group_matmul(
                a, w, block_expert, block_t=block_t, interpret=interpret
            )
        return group_matmul_ref(a, w, block_expert, block_t=block_t)

    h = mm(x_sorted, w_in)
    if w_gate is not None:
        h = jax.nn.silu(mm(x_sorted, w_gate)) * h
    else:
        h = jax.nn.gelu(h)
    y_sorted = mm(h.astype(x.dtype), w_out)

    # combine (the RAW "load" side): gather each assignment's row and
    # weight by router prob
    y_assign = jnp.take(y_sorted, slot, axis=0)
    w_assign = top_p.reshape(-1)[:, None].astype(y_assign.dtype)
    out = jnp.zeros((t, d_model), y_assign.dtype)
    out = out.at[token_of_assignment].add(y_assign * w_assign)
    return out.astype(x.dtype)
