"""Pallas TPU kernel: one fused wave as a gather→scatter step
(DESIGN.md §2 → the backend half of the WavePlan contract).

A wave is a conflict-free batch of memory requests (no two touch the
same address unless both are loads), so the whole batch executes
data-parallel against a flat protected-memory image:

    load_vals[i] = mem[addr[i]]                        (gather)
    mem[addr[i]] = sval[i]   where is_store & valid    (scatter)

Bit-exactness is by construction: the kernel only *moves* data. The
f64 memory image travels as ``(M, 2)`` uint32 bit-pattern rows — TPUs
have no f64 ALU, but a DU does not compute either; it disambiguates
and moves. Store values arrive precomputed by the op tables
(``core/optable``) from the gathers of *strictly earlier* waves
(WavePlan contract 1), which is what makes the single-kernel
gather+scatter sound: nothing computed in this wave feeds a store of
this wave.

The scatter writes back the gathered row for non-store lanes
(semantic no-op — contract 2 guarantees no store shares their
address), so the whole update is one vectorized masked scatter rather
than a serialized in-kernel loop.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wave_kernel(mem_ref, addr_ref, write_ref, sval_ref, out_mem_ref,
                 vals_ref):
    mem = mem_ref[...]  # (M, 2) uint32 f64 bit patterns
    addr = addr_ref[...]  # (W,) int32 in [0, M); see wave_step contract
    rows = jnp.take(mem, addr, axis=0, mode="clip")  # gather (pre-wave)
    vals_ref[...] = rows
    write = write_ref[...][:, None] == 1  # (W, 1) store & valid & !pad
    upd = jnp.where(write, sval_ref[...], rows)
    # conflict-freedom (WavePlan contract 2) makes duplicate indices
    # benign: duplicates are load lanes writing back identical rows
    out_mem_ref[...] = mem.at[addr].set(upd)


@functools.partial(jax.jit, static_argnames=("interpret",))
def wave_step(
    mem: jax.Array,   # (M, 2) uint32 — f64 memory image bit patterns
    addr: jax.Array,  # (W,) int32 flat addresses in [0, M)
    write: jax.Array,  # (W,) int32 1 = valid store lane, 0 = load/pad
    sval: jax.Array,  # (W, 2) uint32 — precomputed store value patterns
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Execute one wave; returns (new mem image, gathered rows).

    Caller contract: every lane's address must be in [0, M) and no two
    lanes may share an address unless all of them are load lanes —
    *including pad lanes*, because every non-write lane scatters its
    gathered row back. ``ops._run`` satisfies this by appending one
    scratch row past the image and pointing all pad lanes at it; a pad
    address that aliased a real store's address would race it through
    the duplicate-index scatter. Gathered rows are returned for every
    lane; the caller keeps only the load lanes.
    """
    m = mem.shape[0]
    w = addr.shape[0]
    out_mem, vals = pl.pallas_call(
        _wave_kernel,
        in_specs=[
            pl.BlockSpec((m, 2), lambda: (0, 0)),
            pl.BlockSpec((w,), lambda: (0,)),
            pl.BlockSpec((w,), lambda: (0,)),
            pl.BlockSpec((w, 2), lambda: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((m, 2), lambda: (0, 0)),
            pl.BlockSpec((w, 2), lambda: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, 2), jnp.uint32),
            jax.ShapeDtypeStruct((w, 2), jnp.uint32),
        ],
        interpret=interpret,
    )(mem, addr.astype(jnp.int32), write.astype(jnp.int32), sval)
    return out_mem, vals
