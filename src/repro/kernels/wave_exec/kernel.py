"""Pallas TPU kernels: batched wave steps as gather→scatter
(DESIGN.md §2 → the backend half of the WavePlan contract).

A step is a batch of conflict-free waves (WavePlan contract 5): no two
requests touch the same address except loads with loads and the WAR
pair whose load's wave strictly precedes the store's. The whole batch
therefore executes data-parallel against a flat protected-memory image
with gather strictly before scatter:

    load_vals[i] = mem[addr[i]]                        (gather, pre-step)
    mem[addr[i]] = sval[i]   where is_store & valid    (scatter)

Bit-exactness is by construction: the kernel only *moves* data. The
f64 memory image travels as ``(M, 2)`` uint32 bit-pattern rows — TPUs
have no f64 ALU, but a DU does not compute either; it disambiguates
and moves. Store values arrive precomputed by the op tables
(``core/optable``) from the gathers of *strictly earlier* steps
(contract 5), which is what makes the single-kernel gather+scatter
sound: nothing gathered in this step feeds a store of this step.

The scatter touches **only write lanes**: every non-write lane (loads,
§6-invalid stores, padding) is redirected to the scratch row ``M - 1``
past the real image, so a load may share a real address with a store
in the same step (the batch-internal WAR) without racing it through a
duplicate-index scatter. Scratch-row content is never observed — pad
lanes gather it and the caller discards those lanes.

``wave_loop`` drives a whole *segment* of equal-width steps through one
``jax.lax.fori_loop`` over the stacked per-step tables, so the host
dispatches one call per segment instead of one per step — step count
stops dominating wall-clock (ROADMAP item 1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wave_kernel(mem_ref, addr_ref, write_ref, sval_ref, out_mem_ref,
                 vals_ref):
    mem = mem_ref[...]  # (M, 2) uint32 f64 bit patterns
    addr = addr_ref[...]  # (W,) int32 in [0, M); see wave_step contract
    rows = jnp.take(mem, addr, axis=0, mode="clip")  # gather (pre-step)
    vals_ref[...] = rows
    write = write_ref[...] == 1  # (W,) store & valid & !pad
    # scatter only write lanes; everything else lands on the scratch
    # row M-1, whose content is never observed (module doc)
    scat = jnp.where(write, addr, mem.shape[0] - 1)
    out_mem_ref[...] = mem.at[scat].set(
        jnp.where(write[:, None], sval_ref[...], mem[-1])
    )


def _step_call(mem, addr, write, sval, interpret):
    m = mem.shape[0]
    w = addr.shape[0]
    return pl.pallas_call(
        _wave_kernel,
        in_specs=[
            pl.BlockSpec((m, 2), lambda: (0, 0)),
            pl.BlockSpec((w,), lambda: (0,)),
            pl.BlockSpec((w,), lambda: (0,)),
            pl.BlockSpec((w, 2), lambda: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((m, 2), lambda: (0, 0)),
            pl.BlockSpec((w, 2), lambda: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, 2), jnp.uint32),
            jax.ShapeDtypeStruct((w, 2), jnp.uint32),
        ],
        interpret=interpret,
    )(mem, addr.astype(jnp.int32), write.astype(jnp.int32), sval)


@functools.partial(jax.jit, static_argnames=("interpret",))
def wave_step(
    mem: jax.Array,   # (M, 2) uint32 — f64 memory image bit patterns
    addr: jax.Array,  # (W,) int32 flat addresses in [0, M)
    write: jax.Array,  # (W,) int32 1 = valid store lane, 0 = load/pad
    sval: jax.Array,  # (W, 2) uint32 — precomputed store value patterns
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Execute one batched step; returns (new mem image, gathered rows).

    Caller contract: every lane's address must be in [0, M) and no two
    *write* lanes may share an address (WavePlan contract 5 — one
    valid store per address per step). Non-write lanes never scatter
    (they are redirected to the scratch row M-1), so load and pad
    lanes may freely alias any address. Gathered rows are returned for
    every lane against the pre-step image; the caller keeps only the
    load lanes.
    """
    return _step_call(mem, addr, write, sval, interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def wave_loop(
    mem: jax.Array,    # (M, 2) uint32 — f64 memory image bit patterns
    addrs: jax.Array,  # (S, W) int32 per-step flat addresses
    writes: jax.Array,  # (S, W) int32 per-step write masks
    svals: jax.Array,  # (S, W, 2) uint32 per-step store value patterns
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Execute S equal-width steps as one ``jax.lax.fori_loop``.

    The per-step tables are precomputed on the host from the WavePlan's
    step offsets (``kernels/wave_exec/ops.py`` stacks them per
    segment); the loop body indexes them by step and chains the memory
    image through the carry — no host round-trip between steps. Pad
    steps (all lanes scratch, no writes) are no-ops, so the caller may
    pad S to a bucket size to bound compile count. Returns (final mem
    image, (S, W, 2) gathered rows per step).
    """

    def body(s, carry):
        cur, vals = carry
        nxt, v = _step_call(
            cur,
            jax.lax.dynamic_index_in_dim(addrs, s, 0, keepdims=False),
            jax.lax.dynamic_index_in_dim(writes, s, 0, keepdims=False),
            jax.lax.dynamic_index_in_dim(svals, s, 0, keepdims=False),
            interpret,
        )
        return nxt, jax.lax.dynamic_update_index_in_dim(vals, v, s, 0)

    vals0 = jnp.zeros(svals.shape, jnp.uint32)
    return jax.lax.fori_loop(0, addrs.shape[0], body, (mem, vals0))
