"""Wave-execution backend: drive a ``WavePlan`` through Pallas.

``run_plan`` is the hardware half of the DESIGN.md §2 split: the plan
(from ``core/executor.build_wave_plan``) carries the wave partition,
flat addresses, op tables and captured CU operand streams; execution
runs through the shared ``executor.drive_plan`` driver — identical
compute/bookkeeping/checks to the numpy reference backend — with the
memory step delegated to the ``wave_step`` Pallas kernel:

    compute  — op-table closures produce this wave's store values and
               §6 valid bits from the *gathers of earlier waves*
               (host numpy by default: bit-exact vs the oracle; the
               same closures run under jnp with ``compute="jnp"``),
    gather + — one ``wave_step`` Pallas call moves the wave's memory
    scatter    traffic against the flat uint32-pair image.

That ordering is sound because a store's feeding loads are in strictly
earlier waves (WavePlan contract 1) — the compute for wave *w* never
needs wave *w*'s gathers. Request batches are padded to power-of-two
buckets so the jitted kernel compiles O(log max-wave) times, not once
per wave, and pad lanes target a scratch row past the image so they can
never collide with a real store's address in-wave.

``run_sequential`` executes the same plan one request per step — the
paper's non-fused baseline on identical hardware — and is what
``benchmarks/bench_pallas.py`` compares wave execution against.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.core import executor as execlib

__all__ = ["run_plan", "run_sequential", "WaveExecResult"]

_MIN_BUCKET = 8


@dataclasses.dataclass
class WaveExecResult:
    """Final arrays + execution profile of one backend run."""

    arrays: dict[str, np.ndarray]
    stats: execlib.WaveStats
    n_steps: int  # pallas wave_step invocations
    elapsed: float  # seconds inside the wave loop
    complete: bool  # False when max_steps truncated the run


def _bucket(n: int) -> int:
    b = _MIN_BUCKET
    while b < n:
        b *= 2
    return b


def _to_u32(f64: np.ndarray) -> np.ndarray:
    return np.ascontiguousarray(f64, dtype=np.float64).view(
        np.uint32
    ).reshape(-1, 2)


def _from_u32(u32: np.ndarray) -> np.ndarray:
    return np.ascontiguousarray(u32, dtype=np.uint32).view(
        np.float64
    ).reshape(-1)


def _run(
    plan: execlib.WavePlan,
    arrays: dict[str, np.ndarray],
    wave_of: Optional[np.ndarray],
    n_waves: Optional[int],
    *,
    interpret: bool,
    compute: str,
    check: bool,
    max_steps: Optional[int],
) -> WaveExecResult:
    import jax.numpy as jnp

    from repro.kernels.wave_exec.kernel import wave_step

    assert plan.mem_size < 2**31 - 1, "flat image exceeds int32 addressing"
    # flat f64 image as uint32 bit-pattern rows (module doc), plus the
    # scratch row pad lanes gather from / write back to
    scratch = plan.mem_size
    mem_f64 = np.zeros(plan.mem_size + 1, dtype=np.float64)
    mem_f64[:plan.mem_size] = execlib.flat_image(plan, arrays)[
        :plan.mem_size
    ]
    mem_dev = jnp.asarray(_to_u32(mem_f64))

    def mem_step(flat_addr, write, sval):
        nonlocal mem_dev
        nb = len(flat_addr)
        nb_pad = _bucket(nb)
        addr = np.full(nb_pad, scratch, dtype=np.int32)
        addr[:nb] = flat_addr
        write_p = np.zeros(nb_pad, dtype=np.int32)
        write_p[:nb] = write
        sval_p = np.zeros(nb_pad, dtype=np.float64)
        sval_p[:nb] = sval
        mem_dev, vals = wave_step(
            mem_dev, jnp.asarray(addr), jnp.asarray(write_p),
            jnp.asarray(_to_u32(sval_p)), interpret=interpret,
        )
        return _from_u32(np.asarray(vals))[:nb]

    t0 = time.perf_counter()
    steps, complete = execlib.drive_plan(
        plan, mem_step, frozen=arrays, wave_of=wave_of, n_waves=n_waves,
        lib="np" if compute == "host" else "jnp", check=check,
        max_steps=max_steps,
    )
    elapsed = time.perf_counter() - t0

    mem_out = _from_u32(np.asarray(mem_dev))
    out = execlib.unpack_image(plan, mem_out, arrays)
    return WaveExecResult(
        arrays=out, stats=plan.stats, n_steps=steps, elapsed=elapsed,
        complete=complete,
    )


def run_plan(
    plan: execlib.WavePlan,
    arrays: dict[str, np.ndarray],
    *,
    interpret: bool = True,
    compute: str = "host",
    check: bool = True,
    max_steps: Optional[int] = None,
) -> WaveExecResult:
    """Execute a WavePlan wave-parallel through the Pallas backend.

    ``compute="host"`` (default) evaluates the op-table closures in
    numpy — elementwise identical to the oracle, so final arrays are
    bit-exact. ``compute="jnp"`` runs the same closures under
    jax.numpy (accelerator dtype semantics; tolerance-checked in
    tests, pair with ``check=False``).
    ``check`` pins every gather, store value and §6 valid bit
    request-exact against the plan's oracle reference streams — leave
    on except when timing.
    ``interpret`` runs the Pallas kernel in interpreter mode (the CPU
    CI path); pass False on real TPU hardware.
    """
    assert compute in ("host", "jnp"), f"unknown compute {compute!r}"
    return _run(
        plan, arrays, None, None,
        interpret=interpret, compute=compute, check=check,
        max_steps=max_steps,
    )


def run_sequential(
    plan: execlib.WavePlan,
    arrays: dict[str, np.ndarray],
    *,
    interpret: bool = True,
    compute: str = "host",
    check: bool = False,
    max_steps: Optional[int] = None,
) -> WaveExecResult:
    """Execute the plan one request per Pallas step, in program order —
    the sequential (non-fused) baseline on the same hardware path.
    ``max_steps`` truncates for timing extrapolation (the result's
    ``complete`` flag records it; truncated arrays are partial)."""
    n = plan.n_requests
    return _run(
        plan, arrays, np.arange(n, dtype=np.int64), n,
        interpret=interpret, compute=compute, check=check,
        max_steps=max_steps,
    )
