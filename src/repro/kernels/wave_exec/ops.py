"""Wave-execution backend: drive a ``WavePlan`` through Pallas.

``run_plan`` is the hardware half of the DESIGN.md §2 split: the plan
(from ``core/executor.build_wave_plan``) carries the batched-step
partition, flat addresses, op tables and captured CU operand streams.
Execution is two-phase:

    resolve — the shared ``executor.drive_plan`` driver runs over a
              host-side image: op-table closures produce each step's
              store values and §6 valid bits from the gathers of
              *strictly earlier* steps (WavePlan contract 5), every
              gather/guard/value is pinned request-exact against the
              oracle reference streams, and the per-step
              (addr, write, sval) tables are recorded,
    device  — the recorded tables are padded to power-of-two lane
              buckets, stacked into segments of equal width, and each
              segment runs as **one** jitted ``wave_loop`` call — a
              ``jax.lax.fori_loop`` over the step tables chaining the
              flat uint32-pair memory image through the carry. Final
              arrays are unpacked from the device image (and the
              per-step device gathers are checked bit-exact against
              the resolve phase under ``check=True``).

The split mirrors what the DU is: the resolve phase *disambiguates*
(and owns every divergence check); the device phase only *moves* —
which is why the whole memory schedule compiles to O(segments) kernel
launches instead of one per step, and why step count no longer
dominates wall-clock (ROADMAP item 1). Pad lanes target a scratch row
past the image; pad steps are no-ops (see ``kernel.py``).

``run_sequential`` executes the same plan one request per step — the
paper's non-fused baseline on identical hardware (a single bucket-8
segment of ``n_requests`` steps) — and is what
``benchmarks/bench_pallas.py`` compares wave execution against.

Cross-PE FIFO edges (DESIGN.md §11) need no support here: the plan
encodes each edge as circular pseudo-memory slots inside ``mem_size``
(zero-init in ``flat_image``, absent from ``array_order``), so pushes
and pops flow through the ordinary scatter/gather path — a popped
token is literally a gather from the slot its push scattered to, and
the resolve phase's request-exact checks pin the whole queue protocol
against the oracle.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.core import executor as execlib

__all__ = ["run_plan", "run_sequential", "WaveExecResult"]

_MIN_BUCKET = 8


@dataclasses.dataclass
class WaveExecResult:
    """Final arrays + execution profile of one backend run."""

    arrays: dict[str, np.ndarray]
    stats: execlib.WaveStats
    n_steps: int  # executed gather→scatter steps (pad steps excluded)
    elapsed: float  # seconds: resolve + device phases
    complete: bool  # False when max_steps truncated the run
    resolve_s: float = 0.0  # host resolution (op tables + checks)
    device_s: float = 0.0  # segmented wave_loop execution
    n_segments: int = 0  # wave_loop launches (fori_loop calls)


def _bucket(n: int) -> int:
    b = _MIN_BUCKET
    while b < n:
        b *= 2
    return b


def _to_u32(f64: np.ndarray) -> np.ndarray:
    return np.ascontiguousarray(f64, dtype=np.float64).view(
        np.uint32
    ).reshape(-1, 2)


def _from_u32(u32: np.ndarray) -> np.ndarray:
    return np.ascontiguousarray(u32, dtype=np.uint32).view(
        np.float64
    ).reshape(-1)


def _run(
    plan: execlib.WavePlan,
    arrays: dict[str, np.ndarray],
    step_of: Optional[np.ndarray],
    n_steps: Optional[int],
    *,
    interpret: bool,
    compute: str,
    check: bool,
    max_steps: Optional[int],
) -> WaveExecResult:
    import jax.numpy as jnp

    from repro.kernels.wave_exec.kernel import wave_loop

    assert plan.mem_size < 2**31 - 1, "flat image exceeds int32 addressing"
    # flat f64 image plus the scratch row pad/non-write lanes target
    scratch = plan.mem_size
    mem_f64 = np.zeros(plan.mem_size + 1, dtype=np.float64)
    mem_f64[:plan.mem_size] = execlib.flat_image(plan, arrays)[
        :plan.mem_size
    ]

    # --- resolve phase: op-table compute + checks over a host image ------
    # records the per-step memory traffic the device phase will replay
    host_mem = mem_f64.copy()
    rec: list[tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]] = []

    def mem_step(flat_addr, write, sval):
        got = host_mem[flat_addr]  # fancy indexing copies: pre-step state
        host_mem[flat_addr[write]] = sval[write]
        rec.append((flat_addr, write, sval, got))
        return got

    t0 = time.perf_counter()
    steps, complete = execlib.drive_plan(
        plan, mem_step, frozen=arrays, step_of=step_of, n_steps=n_steps,
        lib="np" if compute == "host" else "jnp", check=check,
        max_steps=max_steps,
    )
    t_resolve = time.perf_counter() - t0

    # --- device phase: segments of equal-width steps, one wave_loop each -
    t0 = time.perf_counter()
    mem_dev = jnp.asarray(_to_u32(mem_f64))
    widths = [_bucket(len(a)) for a, _, _, _ in rec]
    segments: list[tuple[int, int]] = []  # (start step, end step)
    for s, wd in enumerate(widths):
        if segments and widths[segments[-1][0]] == wd:
            segments[-1] = (segments[-1][0], s + 1)
        else:
            segments.append((s, s + 1))
    for s0, s1 in segments:
        wd = widths[s0]
        ns = s1 - s0
        # pad the segment's step count to a power of two as well (pad
        # steps are no-ops) so compile count is O(log steps · log width)
        ns_pad = 1
        while ns_pad < ns:
            ns_pad *= 2
        addrs = np.full((ns_pad, wd), scratch, dtype=np.int32)
        writes = np.zeros((ns_pad, wd), dtype=np.int32)
        svals = np.zeros((ns_pad, wd), dtype=np.float64)
        for j in range(ns):
            a, w, v, _ = rec[s0 + j]
            addrs[j, :len(a)] = a
            writes[j, :len(a)] = w
            svals[j, :len(a)] = v
        mem_dev, vals = wave_loop(
            mem_dev, jnp.asarray(addrs), jnp.asarray(writes),
            jnp.asarray(_to_u32(svals).reshape(ns_pad, wd, 2)),
            interpret=interpret,
        )
        if check:
            vals_h = np.asarray(vals)
            for j in range(ns):
                a, _, _, got = rec[s0 + j]
                np.testing.assert_array_equal(
                    _from_u32(vals_h[j])[:len(a)], got,
                    err_msg="device gather diverged from resolve phase",
                )
    t_device = time.perf_counter() - t0

    mem_out = _from_u32(np.asarray(mem_dev))
    if check:
        np.testing.assert_array_equal(
            mem_out[:plan.mem_size], host_mem[:plan.mem_size],
            err_msg="device image diverged from resolve phase",
        )
    out = execlib.unpack_image(plan, mem_out, arrays)
    return WaveExecResult(
        arrays=out, stats=plan.stats, n_steps=steps,
        elapsed=t_resolve + t_device, complete=complete,
        resolve_s=t_resolve, device_s=t_device, n_segments=len(segments),
    )


def run_plan(
    plan: execlib.WavePlan,
    arrays: dict[str, np.ndarray],
    *,
    interpret: bool = True,
    compute: str = "host",
    check: bool = True,
    max_steps: Optional[int] = None,
) -> WaveExecResult:
    """Execute a WavePlan step-parallel through the Pallas backend.

    ``compute="host"`` (default) evaluates the op-table closures in
    numpy — elementwise identical to the oracle, so final arrays are
    bit-exact. ``compute="jnp"`` runs the same closures under
    jax.numpy (accelerator dtype semantics; tolerance-checked in
    tests, pair with ``check=False``).
    ``check`` pins every gather, store value and §6 valid bit
    request-exact against the plan's oracle reference streams during
    the resolve phase, then the device gathers and final image
    bit-exact against the resolve phase — leave on except when timing.
    ``interpret`` runs the Pallas kernels in interpreter mode (the CPU
    CI path); pass False on real TPU hardware.
    """
    assert compute in ("host", "jnp"), f"unknown compute {compute!r}"
    return _run(
        plan, arrays, None, None,
        interpret=interpret, compute=compute, check=check,
        max_steps=max_steps,
    )


def run_sequential(
    plan: execlib.WavePlan,
    arrays: dict[str, np.ndarray],
    *,
    interpret: bool = True,
    compute: str = "host",
    check: bool = False,
    max_steps: Optional[int] = None,
) -> WaveExecResult:
    """Execute the plan one request per step, in program order — the
    sequential (non-fused) baseline on the same hardware path (one
    bucket-width-8 segment of ``n_requests`` steps through the same
    ``wave_loop`` driver). ``max_steps`` truncates for timing
    measurement (the result's ``complete`` flag records it; truncated
    arrays are partial)."""
    n = plan.n_requests
    return _run(
        plan, arrays, np.arange(n, dtype=np.int64), n,
        interpret=interpret, compute=compute, check=check,
        max_steps=max_steps,
    )
