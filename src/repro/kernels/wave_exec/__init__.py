"""Pallas wave-execution backend for the fused executor (DESIGN.md §2):
consumes ``core/executor`` WavePlans, executes each wave as a
gather→compute→scatter step. Public surface: ``run_plan``,
``run_sequential``, ``WaveExecResult``."""

from repro.kernels.wave_exec.ops import (  # noqa: F401
    WaveExecResult,
    run_plan,
    run_sequential,
)
