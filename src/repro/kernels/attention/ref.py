"""Pure-jnp oracles for the attention kernels."""

import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, sm_scale=1.0):
    s = jnp.einsum(
        "bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * sm_scale
    if causal:
        ql, kl = q.shape[1], k.shape[1]
        mask = jnp.arange(ql)[:, None] >= jnp.arange(kl)[None, :]
        s = jnp.where(mask[None], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, lengths, *, sm_scale=1.0):
    s = jnp.einsum(
        "bqd,bkd->bqk", q.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * sm_scale
    pos = jnp.arange(k_cache.shape[1])
    mask = pos[None, None, :] < lengths[:, None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, v_cache.astype(jnp.float32)).astype(
        q.dtype
    )
