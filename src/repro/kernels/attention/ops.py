"""Public attention wrappers used by the model stack.

On TPU the Pallas kernels are the production path; on CPU (this
container) the models call the jnp references, and tests validate the
kernels in interpret mode at reduced sizes.
"""

from repro.kernels.attention.kernel import decode_attention, flash_attention
from repro.kernels.attention.ref import (
    decode_attention_ref,
    flash_attention_ref,
)

__all__ = [
    "flash_attention",
    "flash_attention_ref",
    "decode_attention",
    "decode_attention_ref",
]
