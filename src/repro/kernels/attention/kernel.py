"""Pallas TPU kernels: blocked causal flash attention (prefill) and
single-token decode attention over a KV cache.

The decode path is the degenerate-but-ubiquitous instance of the paper's
pattern in serving (DESIGN.md §3.2): append(store at t) / attend(load <=
t) is a RAW pair whose store stream is trivially monotonic, so the
frontier check collapses to causal masking — the kernel only ever looks
at KV blocks below the frontier, never a history structure.

Prefill: grid (batch*heads, q_blocks); each program streams KV blocks
through VMEM with online softmax (running max/denominator), skipping
fully-masked blocks. Block shapes keep the MXU aligned: q/kv blocks are
multiples of 128 in production configs (tests use smaller tiles).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, causal, sm_scale):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * sm_scale  # (block_q, d)
    kv_len = k_ref.shape[1]
    n_kb = kv_len // block_k

    def body(kb, carry):
        acc, m, l = carry
        k = jax.lax.dynamic_slice(
            k_ref[0], (kb * block_k, 0), (block_k, k_ref.shape[2])
        ).astype(jnp.float32)
        v = jax.lax.dynamic_slice(
            v_ref[0], (kb * block_k, 0), (block_k, v_ref.shape[2])
        ).astype(jnp.float32)
        s = q @ k.T  # (block_q, block_k)
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0
            )
            k_pos = kb * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1
            )
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + p @ v
        return acc_new, m_new, l_new

    if causal:
        # only blocks at or below the diagonal contribute
        n_kb_eff = jnp.minimum(n_kb, (qi + 1) * block_q // block_k + 1)
    else:
        n_kb_eff = n_kb
    d = v_ref.shape[2]
    acc = jnp.zeros((block_q, d), jnp.float32)
    m = jnp.full((block_q,), NEG_INF, jnp.float32)
    l = jnp.zeros((block_q,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, n_kb_eff, body, (acc, m, l))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret", "sm_scale")
)
def flash_attention(
    q: jax.Array,  # (BH, S, d)
    k: jax.Array,  # (BH, S_kv, d)
    v: jax.Array,  # (BH, S_kv, d)
    *,
    causal: bool = True,
    sm_scale: float = 1.0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    bh, s, d = q.shape
    s_kv = k.shape[1]
    assert s % block_q == 0 and s_kv % block_k == 0
    grid = (bh, s // block_q)
    return pl.pallas_call(
        functools.partial(
            _flash_kernel,
            block_q=block_q,
            block_k=block_k,
            causal=causal,
            sm_scale=sm_scale,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, s_kv, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, s_kv, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        interpret=interpret,
    )(q, k, v)


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, block_k, sm_scale):
    q = q_ref[0].astype(jnp.float32) * sm_scale  # (1, d)
    kv_len = len_ref[0]  # frontier: number of committed KV entries
    s_kv = k_ref.shape[1]
    n_kb = s_kv // block_k

    def body(kb, carry):
        acc, m, l = carry
        k = jax.lax.dynamic_slice(
            k_ref[0], (kb * block_k, 0), (block_k, k_ref.shape[2])
        ).astype(jnp.float32)
        v = jax.lax.dynamic_slice(
            v_ref[0], (kb * block_k, 0), (block_k, v_ref.shape[2])
        ).astype(jnp.float32)
        s = (q @ k.T)[0]  # (block_k,)
        pos = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        s = jnp.where(pos < kv_len, s, NEG_INF)  # RAW frontier mask
        m_new = jnp.maximum(m, jnp.max(s))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p)
        acc_new = acc * alpha + p @ v
        return acc_new, m_new, l_new

    d = v_ref.shape[2]
    acc = jnp.zeros((d,), jnp.float32)
    carry = (acc, jnp.float32(NEG_INF), jnp.float32(0.0))
    acc, m, l = jax.lax.fori_loop(0, n_kb, body, carry)
    o_ref[0] = (acc / jnp.maximum(l, 1e-30))[None, :].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret", "sm_scale"))
def decode_attention(
    q: jax.Array,       # (BH, 1, d) one new token per head
    k_cache: jax.Array,  # (BH, S_max, d)
    v_cache: jax.Array,  # (BH, S_max, d)
    lengths: jax.Array,  # (BH,) committed KV frontier per head
    *,
    sm_scale: float = 1.0,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    bh, _, d = q.shape
    s_max = k_cache.shape[1]
    assert s_max % block_k == 0
    return pl.pallas_call(
        functools.partial(_decode_kernel, block_k=block_k, sm_scale=sm_scale),
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, s_max, d), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, s_max, d), lambda b: (b, 0, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, 1, d), q.dtype),
        interpret=interpret,
    )(q, k_cache, v_cache, lengths.astype(jnp.int32))
