"""Pallas kernel adaptations of the paper's disambiguation primitives
(DESIGN.md §2-3, §8): frontier merge (``du_hazard``), fused
producer/consumer streams (``fused_stream``), plus the workload kernels
(``csr_spmv``, ``histogram``, ``attention``, ``moe_group_mm``,
``ssm_scan``). Each has kernel.py / ops.py / ref.py."""
