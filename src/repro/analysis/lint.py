"""LoopIR program linter: static diagnostics with stable RPL0xx codes.

Runs the symbolic dependence certifier (``analysis/deps.py``), the §3
monotonicity pass and the FIFO/decoupling front-ends over a program and
reports everything they can *prove* about it before a single cycle is
simulated:

  ========  ========  ====================================================
  code      severity  meaning
  ========  ========  ====================================================
  RPL001    error     contradictory ``MonotonicHint``: the CR analysis
                      (which never trusts hints) proves the asserted
                      monotonicity false, or the hint names an impossible
                      reset depth — ``validate_hints=True`` would raise
                      ``HintViolation`` at runtime
  RPL002    warning   redundant ``MonotonicHint``: the address is fully
                      CR-analyzable and the analysis already derives at
                      least what the hint asserts — drop the hint
  RPL003    info      provably-dead hazard pair: the certifier proves the
                      kept pair can never observe a conflict (forced-pass
                      pairs additionally vanish under ``static_prune``)
  RPL004    error     statically-doomed FIFO topology: the cross-PE edge
                      set deadlocks or falls outside the token protocol
                      for every depth (``fifo.analyze_program`` reject)
  RPL005    info      loss-of-decoupling pre-diagnosis: ``speculation=
                      "off"`` would raise ``LossOfDecoupling``; ``"auto"``
                      recovers by marking the PE speculative (escalated to
                      an error when even ``"auto"`` rejects the program)
  ========  ========  ====================================================

Codes are stable across releases (tests pin them); severities order
``error > warning > info`` and the CLI exits non-zero iff any error or
warning was emitted — info diagnostics are advisory.

CLI (``python -m repro.analysis.lint``):

    python -m repro.analysis.lint --all            # every registered kernel
    python -m repro.analysis.lint bnn "tanh+spmv"  # selected kernels
    python -m repro.analysis.lint path/to/prog.py  # a file defining
                                                   # `program` or `make()`
"""

from __future__ import annotations

import argparse
import dataclasses
import sys
from typing import Optional

from repro.analysis import deps as depslib
from repro.core import cr as crlib
from repro.core import dae as daelib
from repro.core import fifo as fifolib
from repro.core import hazards as hz
from repro.core import loopir as ir
from repro.core import monotonic as mono
from repro.core import programs

SEVERITIES = ("error", "warning", "info")

# stable code registry: codes are never renumbered or reused (pinned by
# tests/test_deps.py); new checks append RPL006, RPL007, ...
CODES = {
    "RPL001": "contradictory MonotonicHint",
    "RPL002": "redundant MonotonicHint",
    "RPL003": "provably-dead hazard pair",
    "RPL004": "statically-doomed FIFO topology",
    "RPL005": "loss-of-decoupling pre-diagnosis",
}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One linter finding (stable ``code``, sortable, printable)."""

    code: str  # RPL001..RPL005
    severity: str  # error | warning | info
    kernel: str  # program label the finding belongs to
    where: str  # op id, "dst<-src" pair, or FIFO edge description
    message: str

    def format(self) -> str:
        return f"{self.kernel}: {self.code} {self.severity} [{self.where}]: {self.message}"


def _sort_key(d: Diagnostic) -> tuple:
    return (d.kernel, d.code, d.where, d.message)


# ---------------------------------------------------------------------------
# RPL001 / RPL002 — MonotonicHint checks
# ---------------------------------------------------------------------------


def _boundary_change_hi(
    cre: crlib.CRExpr, trips: dict[int, crlib.CRExpr], d: int, n: int
) -> Optional[int]:
    """Upper bound on ``addr(after) - addr(before)`` across an advance of
    loop depth ``d`` — the ``hi`` mirror of ``cr.min_adjacent_increase``,
    except the inner loops provably completed ``trip - 1`` iterations
    before resetting, so the elapsed interval is ``[trip_lo-1,
    trip_hi-1]``, not ``[0, trip_hi-1]``. None when the stream is opaque,
    holds a multiplicative recurrence, or an inner loop may run zero
    iterations (the adjacent request then spans several advances and the
    single-step bound is unsound)."""
    if crlib.has_opaque(cre) or any(c.op == "*" for c in cre.crs()):
        return None
    sd = crlib.step_at_depth(cre, d)
    if sd is None:
        return None
    hi = sd.range().hi
    for j in range(d + 1, n + 1):
        sj = crlib.step_at_depth(cre, j)
        if sj is None:
            return None
        t = trips[j].range()
        if t.lo < 1:
            return None
        back = crlib.Interval(crlib.clamp(-max(t.hi - 1, 0)), -(t.lo - 1))
        hi = crlib.clamp(hi + (sj.range() * back).hi)
    return hi


def _lint_hints(
    program: ir.Program, kernel: str, facts: dict[str, depslib.OpFacts]
) -> list[Diagnostic]:
    out: list[Diagnostic] = []
    for op, path in program.mem_ops():
        if op.hint is None:
            continue
        n = len(path)
        f = facts[op.id]

        # structural: asserted reset depths must name an *outer* loop
        if op.hint.non_monotonic_outer is not None:
            bad = sorted(
                d for d in op.hint.non_monotonic_outer if d < 1 or d >= n
            )
            if bad:
                out.append(Diagnostic(
                    "RPL001", "error", kernel, op.id,
                    f"hint asserts non-monotonic depth(s) {bad} outside the "
                    f"op's outer depths 1..{n - 1}",
                ))

        if not f.analyzable:
            continue  # opaque address: the hint is load-bearing

        cre, trips = f.cr, f.trips
        hint_nm = (
            frozenset(range(1, n))
            if op.hint.non_monotonic_outer is None
            else frozenset(op.hint.non_monotonic_outer)
        )

        # contradictions: CR (hints untrusted) proves a decrease the
        # hint declares impossible — the exact decreases validate_hints
        # would catch dynamically
        contradicted = False
        if op.hint.innermost_monotonic:
            ub = _boundary_change_hi(cre, trips, n, n)
            if (
                ub is not None and ub <= -1
                and trips[n].range().hi >= 2
            ):
                out.append(Diagnostic(
                    "RPL001", "error", kernel, op.id,
                    f"hint asserts innermost monotonicity but the address "
                    f"provably decreases by ≥ {-ub} every innermost "
                    f"iteration",
                ))
                contradicted = True
            for d in range(1, n):
                if d in hint_nm:
                    continue
                ub = _boundary_change_hi(cre, trips, d, n)
                if (
                    ub is not None and ub <= -1
                    and trips[d].range().hi >= 2
                ):
                    out.append(Diagnostic(
                        "RPL001", "error", kernel, op.id,
                        f"hint omits depth {d} from non_monotonic_outer but "
                        f"the address provably decreases by ≥ {-ub} across "
                        f"every depth-{d} advance",
                    ))
                    contradicted = True
        if contradicted:
            continue

        # redundancy: the CR analysis already derives at least this much
        info = mono.analyze_op(
            dataclasses.replace(op, hint=None), tuple(path)
        )
        implies_innermost = (
            info.innermost_monotonic or not op.hint.innermost_monotonic
        )
        if implies_innermost and info.non_monotonic <= hint_nm:
            out.append(Diagnostic(
                "RPL002", "warning", kernel, op.id,
                f"hint is redundant: the address is CR-analyzable and the "
                f"analysis derives {info.describe()!s} without it",
            ))
    return out


# ---------------------------------------------------------------------------
# RPL003 — provably-dead hazard pairs
# ---------------------------------------------------------------------------


def _lint_pairs(
    program: ir.Program,
    kernel: str,
    dres: daelib.DAEResult,
    facts: dict[str, depslib.OpFacts],
) -> list[Diagnostic]:
    infos = mono.analyze_program(program)
    plan = hz.build_plan(program, dres, infos, forwarding=False)
    out: list[Diagnostic] = []
    for pair, verdict in certify_plan(program, plan, facts).items():
        if verdict.kind != depslib.NEVER:
            continue
        where = f"{pair[0]}<-{pair[1]}"
        if verdict.forced_pass:
            out.append(Diagnostic(
                "RPL003", "info", kernel, where,
                f"hazard pair is provably dead ({verdict.evidence}); "
                f"static_prune=True drops it with bit-identical timing",
            ))
        else:
            out.append(Diagnostic(
                "RPL003", "info", kernel, where,
                f"hazard pair can never observe a conflict "
                f"({verdict.evidence}); kept because its program-order "
                f"disjunct may still pace issue",
            ))
    return out


def certify_plan(
    program: ir.Program, plan: hz.HazardPlan, facts=None
) -> dict[tuple[str, str], depslib.Verdict]:
    """Certifier verdicts for a plan's *kept* pairs (linter view)."""
    return depslib.certify_pairs(program, plan.pairs, facts=facts)


# ---------------------------------------------------------------------------
# RPL004 / RPL005 — front-end pre-diagnosis
# ---------------------------------------------------------------------------


def _lint_frontend(
    program: ir.Program, kernel: str
) -> tuple[list[Diagnostic], Optional[daelib.DAEResult]]:
    out: list[Diagnostic] = []
    try:
        dres = daelib.decouple(program, speculation="off")
    except daelib.LossOfDecoupling as exc:
        out.append(Diagnostic(
            "RPL005", "info", kernel, "decouple",
            f"speculation='off' loses decoupling ({exc}); "
            f"speculation='auto' recovers by marking the PE speculative",
        ))
        try:
            dres = daelib.decouple(program, speculation="auto")
        except daelib.LossOfDecoupling as exc2:
            out.append(Diagnostic(
                "RPL005", "error", kernel, "decouple",
                f"speculation='auto' also rejects the program: {exc2}",
            ))
            return out, None
    if dres.fifo_edges:
        try:
            fifolib.analyze_program(program, dres)
        except fifolib.FifoRejected as exc:
            out.append(Diagnostic(
                "RPL004", "error", kernel, "fifo",
                f"FIFO topology statically doomed "
                f"({type(exc).__name__}): {exc}",
            ))
    return out, dres


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def lint_program(program: ir.Program, kernel: str = "<program>") -> list[Diagnostic]:
    """All diagnostics for one program, deterministically sorted."""
    facts = depslib.stream_facts(program)
    out = _lint_hints(program, kernel, facts)
    frontend, dres = _lint_frontend(program, kernel)
    out += frontend
    if dres is not None:
        out += _lint_pairs(program, kernel, dres, facts)
    return sorted(out, key=_sort_key)


def lint_kernel(name: str, scale: Optional[int] = None) -> list[Diagnostic]:
    """Lint one registered kernel at ``scale`` (default: registered)."""
    bench = programs.get(name)
    prog, _arrays, _params = bench.make(scale or bench.default_scale)
    return lint_program(prog, kernel=name)


def _load_program_file(path: str) -> ir.Program:
    """A lintable file defines ``program`` (an ``ir.Program``) or
    ``make()`` returning one (optionally a (program, arrays, params)
    tuple, the registry convention)."""
    ns: dict = {"__name__": "__lint__", "__file__": path}
    with open(path, "r", encoding="utf-8") as f:
        exec(compile(f.read(), path, "exec"), ns)
    obj = ns.get("program")
    if obj is None and callable(ns.get("make")):
        obj = ns["make"]()
    if isinstance(obj, tuple):
        obj = obj[0]
    if not isinstance(obj, ir.Program):
        raise SystemExit(
            f"{path}: expected a `program` variable or `make()` callable "
            f"yielding an ir.Program"
        )
    return obj


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Static linter for LoopIR programs (stable RPL0xx codes).",
    )
    ap.add_argument(
        "targets", nargs="*",
        help="registered kernel names, or a path to a Python file "
        "defining `program` / `make()`",
    )
    ap.add_argument(
        "--all", action="store_true", help="lint every registered kernel"
    )
    ap.add_argument(
        "--scale", type=int, default=None,
        help="problem scale for registered kernels (default: registered)",
    )
    args = ap.parse_args(argv)
    if not args.all and not args.targets:
        ap.error("nothing to lint: pass kernel names, a file, or --all")

    jobs: list[tuple[str, ir.Program]] = []
    names = sorted(programs.REGISTRY) if args.all else []
    for t in args.targets:
        if t in programs.REGISTRY:
            names.append(t)
        else:
            jobs.append((t, _load_program_file(t)))
    for name in names:
        bench = programs.get(name)
        prog, _a, _p = bench.make(args.scale or bench.default_scale)
        jobs.append((name, prog))

    diags: list[Diagnostic] = []
    for label, prog in sorted(jobs, key=lambda j: j[0]):
        diags += lint_program(prog, kernel=label)
    for d in diags:
        print(d.format())
    counts = {s: sum(1 for d in diags if d.severity == s) for s in SEVERITIES}
    print(
        f"linted {len(jobs)} program(s): {counts['error']} error(s), "
        f"{counts['warning']} warning(s), {counts['info']} info"
    )
    return 1 if counts["error"] or counts["warning"] else 0


if __name__ == "__main__":
    sys.exit(main())
