"""Static analyses over LoopIR programs (DESIGN.md §12).

Two layers on top of the §3 CR algebra:

  * ``analysis.deps``  — the symbolic dependence certifier: per-hazard-
    pair verdicts (``never_conflict`` / ``min_distance`` / ``unknown``),
    the forced-pass certificate that lets ``hazards.build_plan(...,
    static_prune=True)`` drop pairs with bit-identical timing, per-op
    conflict-freedom certificates for the wave coarsener's symbolic
    admission fast path, and the runtime ``MonotonicHint`` sanitizer
    (``validate_hints=``),
  * ``analysis.lint``  — RPL0xx diagnostics over registered kernels or
    program files (``python -m repro.analysis.lint``).
"""
