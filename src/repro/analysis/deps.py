"""Symbolic dependence certifier over the CR algebra (DESIGN.md §12).

The §3 monotonicity pass classifies *one* address stream at a time; this
module reasons about *pairs* of streams (and whole protected arrays) to
produce dependence verdicts that are stronger than the §5.6 runtime
NoDependence bits because they hold for **all in-range parameter
values**, not one observed trace:

  * ``never_conflict`` — the two streams are provably address-disjoint
    (trip-aware value ranges, residue/stride classes: ``a[2i]`` vs
    ``a[2i+1]``) or the pair's runtime check is provably a tautology,
  * ``min_distance(d)`` — any two conflicting instances are at least
    ``d`` iterations apart at the pair's shared depth,
  * ``unknown`` — no proof found (always sound).

Only the **forced-pass** subclass of ``never_conflict`` may be dropped
from a hazard plan with bit-identical timing (``hazards.build_plan(...,
static_prune=True)``): a pair whose §5.6 NoDependence disjunct is
statically true at *every* evaluation, with no lastIter/address-reset
terms, passes its check unconditionally — removing it cannot change any
issue decision. A merely address-disjoint pair can still *block* on its
program-order disjunct (the source frontier starts at a sentinel), so
dropping it would be correct but not cycle-identical; such pairs keep
their ``never_conflict`` verdict for the linter and the DSE axis
without being dropped.

The module also supplies the per-op conflict-freedom certificates behind
``coarsen.batch_conflict_free_waves``'s symbolic admission fast path,
and the dynamic half of the hint story: ``check_hint_stream`` /
``check_hinted_traces`` raise ``HintViolation`` (op id + first violating
(instance, addr)) when a user ``MonotonicHint`` lies about an observed
address stream (``validate_hints=`` in both engines and
``executor.drive_plan``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro.core import cr as crlib
from repro.core import loopir as ir
from repro.core import monotonic as mono

NEVER = "never_conflict"
DISTANCE = "min_distance"
UNKNOWN = "unknown"


@dataclasses.dataclass(frozen=True)
class Verdict:
    """Certifier output for one hazard pair (dst, src)."""

    kind: str  # NEVER | DISTANCE | UNKNOWN
    distance: Optional[int] = None  # kind == DISTANCE: |i_k - j_k| >= distance
    forced_pass: bool = False  # droppable: runtime check statically a tautology
    evidence: str = ""

    def __str__(self):
        d = f"({self.distance})" if self.kind == DISTANCE else ""
        f = " [forced-pass]" if self.forced_pass else ""
        return f"{self.kind}{d}{f}: {self.evidence}"


@dataclasses.dataclass(frozen=True)
class OpFacts:
    """Hint-independent stream facts for one memory op.

    ``cr`` is recomputed from the address expression *ignoring* any
    ``MonotonicHint`` — the certifier never trusts user assertions, so
    its verdicts stay sound even when a hint lies (the linter reports
    the lie separately)."""

    op_id: str
    array: str
    is_store: bool
    depth: int
    path_key: tuple[int, ...]  # identity of the loop nest (same-nest test)
    cr: Optional[crlib.CRExpr]
    analyzable: bool  # cr exists and is opaque-free
    trips: dict[int, crlib.CRExpr]
    vrange: crlib.Interval  # trip-aware value range (opaque ranges honoured)
    residue: Optional[tuple[int, int]]  # (modulus, residue) or None
    min_adjacent: Optional[int]  # lower bound on addr(next) - addr(cur)


def stream_facts(program: ir.Program) -> dict[str, OpFacts]:
    """``op id -> OpFacts`` for every memory op, hints ignored."""
    out: dict[str, OpFacts] = {}
    for op, path in program.mem_ops():
        n = len(path)
        cre = mono.to_cr_or_none(op.addr, path)
        trips: dict[int, crlib.CRExpr] = {}
        for i, lp in enumerate(path):
            t = mono.to_cr_or_none(lp.trip, path)
            trips[i + 1] = (
                t if t is not None else crlib.CSym(f"__trip_{lp.var}", 0, crlib.INF)
            )
        analyzable = cre is not None and not crlib.has_opaque(cre)
        vrange = (
            crlib.value_range(cre, trips)
            if cre is not None
            else crlib.Interval(-crlib.INF, crlib.INF)
        )
        out[op.id] = OpFacts(
            op_id=op.id,
            array=op.array,
            is_store=op.is_store,
            depth=n,
            path_key=tuple(id(lp) for lp in path),
            cr=cre,
            analyzable=analyzable,
            trips=trips,
            vrange=vrange,
            residue=crlib.residue_class(cre) if analyzable else None,
            min_adjacent=(
                crlib.min_adjacent_increase(cre, trips, n) if analyzable else None
            ),
        )
    return out


def streams_disjoint(a: OpFacts, b: OpFacts) -> Optional[str]:
    """Evidence string when the two value sets provably never intersect,
    else None. Works through annotated opaque ranges (``vrange``)."""
    ra, rb = a.vrange, b.vrange
    if ra.hi < rb.lo or rb.hi < ra.lo:
        return (
            f"value ranges disjoint: {a.op_id}∈[{ra.lo},{ra.hi}] vs "
            f"{b.op_id}∈[{rb.lo},{rb.hi}]"
        )
    if crlib.residues_disjoint(a.residue, b.residue):
        (ga, rra), (gb, rrb) = a.residue, b.residue
        m = math.gcd(ga, gb)
        if m == 0:
            return f"distinct constant addresses: {rra} vs {rrb}"
        return (
            f"residue classes disjoint: {a.op_id}≡{rra % m} vs "
            f"{b.op_id}≡{rrb % m} (mod {m})"
        )
    return None


def _forced_pass(pair, fa: OpFacts, fb: OpFacts) -> Optional[Verdict]:
    """The droppable certificate: the pair's §5.6 NoDependence disjunct
    is statically true at every evaluation.

    Requirements (see DESIGN.md §12 for the proof):

      * the pair synthesized NoDependence (intra-PE same-nest RAW with a
        monotonic source) and has no reset terms (``l_depth is None``,
        no ``lastiter_depths``) — the accompanying NoAddressReset check
        is then the constant True,
      * both streams CR-analyzable (hints are not trusted) in the same
        nest,
      * the youngest program-order-preceding src request provably has a
        strictly smaller address than every dst request: for forward
        pairs that is the same-instance src (``lo(dst - src) >= 1``);
        for wraparound pairs it is the previous-instance src
        (``lo(dst - src) + min_adjacent_increase(src) >= 1``).

    The §5.6 bit then evaluates to True for every dst instance (the
    very first instance of a wrap pair sees the -2^62 sentinel, also
    True), so the whole HazardSafetyCheck is a tautology and dropping
    the pair is timing-invisible.
    """
    if not (pair.nodependence and pair.l_depth is None and not pair.lastiter_depths):
        return None
    if not (fa.analyzable and fb.analyzable and fa.path_key == fb.path_key):
        return None
    diff = crlib.cr_diff(fa.cr, fb.cr)
    dlo = crlib.value_range(diff, fa.trips).lo
    if not pair.wraparound:
        if dlo >= 1:
            return Verdict(
                NEVER,
                forced_pass=True,
                evidence=(
                    f"NoDependence statically true: dst-src same-instance "
                    f"difference ≥ {dlo}, no reset terms"
                ),
            )
        return None
    madj = fb.min_adjacent
    if madj is not None and crlib.clamp(dlo + madj) >= 1:
        return Verdict(
            NEVER,
            forced_pass=True,
            evidence=(
                f"NoDependence statically true: dst-src ≥ {dlo} same-instance, "
                f"src strictly increasing (min adjacent step {madj}), "
                f"no reset terms"
            ),
        )
    return None


def _min_distance(pair, fa: OpFacts, fb: OpFacts) -> Optional[Verdict]:
    """Distance reasoning for same-nest streams with a constant offset.

    When ``dst - src`` folds to a constant ``c != 0``, any conflicting
    instance pair (i, j) satisfies ``Σ_d s_d (i_d - j_d) = -c``. With
    ``s_k`` the (constant, positive) shared-depth step and the other
    depths bounded by their trips, ``|i_k - j_k| >= ceil((|c| - slack) /
    s_k)``; with zero slack and ``s_k ∤ c`` the streams never meet at
    all."""
    if pair.shared_depth < 1:
        return None
    if not (fa.analyzable and fb.analyzable and fa.path_key == fb.path_key):
        return None
    diff = crlib.cr_diff(fa.cr, fb.cr)
    if not isinstance(diff, crlib.CConst) or diff.v == 0:
        return None
    c = abs(diff.v)
    k = pair.shared_depth
    sk = crlib.step_at_depth(fb.cr, k)
    if not isinstance(sk, crlib.CConst) or sk.v < 1:
        return None
    slack = 0
    for d in range(1, fb.depth + 1):
        if d == k:
            continue
        sd = crlib.step_at_depth(fb.cr, d)
        if not isinstance(sd, crlib.CConst):
            return None
        if sd.v == 0:
            continue
        t_hi = fb.trips[d].range().hi
        if t_hi >= crlib.INF:
            return None
        slack += abs(sd.v) * max(t_hi - 1, 0)
    if slack == 0 and c % sk.v != 0:
        return Verdict(
            NEVER,
            evidence=(
                f"stride {sk.v} at depth {k} never covers constant offset "
                f"{diff.v}"
            ),
        )
    dist = -(-(c - slack) // sk.v)  # ceil
    if dist >= 1:
        return Verdict(
            DISTANCE,
            distance=int(dist),
            evidence=(
                f"constant offset {diff.v}, shared-depth step {sk.v}, "
                f"cross-depth slack {slack}: conflicts ≥ {dist} iterations "
                f"apart at depth {k}"
            ),
        )
    return None


def certify_pair(pair, fa: OpFacts, fb: OpFacts) -> Verdict:
    """Verdict for one hazard pair (``fa`` = dst stream, ``fb`` = src)."""
    forced = _forced_pass(pair, fa, fb)
    if forced is not None:
        return forced
    ev = streams_disjoint(fa, fb)
    if ev is not None:
        return Verdict(NEVER, evidence=ev)
    dist = _min_distance(pair, fa, fb)
    if dist is not None:
        return dist
    return Verdict(UNKNOWN, evidence="no disjointness or distance proof")


def certify_pairs(
    program: ir.Program,
    pairs,
    facts: Optional[dict[str, OpFacts]] = None,
) -> dict[tuple[str, str], Verdict]:
    """``(dst, src) -> Verdict`` for an iterable of hazard pairs."""
    if facts is None:
        facts = stream_facts(program)
    return {
        (p.dst, p.src): certify_pair(p, facts[p.dst], facts[p.src]) for p in pairs
    }


# ---------------------------------------------------------------------------
# Per-op conflict-freedom certificates (coarsener symbolic admission)
# ---------------------------------------------------------------------------


def symbolically_free_ops(
    program: ir.Program, facts: Optional[dict[str, OpFacts]] = None
) -> dict[str, bool]:
    """Ops whose requests the wave coarsener may admit without address
    enumeration (``coarsen.batch_conflict_free_waves(symbolic_free=)``).

    An op is *symbolically free* iff the certifier proves no request of
    it can ever collide with a batched store:

      * a load must be address-disjoint from every same-array store,
      * a store must be address-disjoint from every *other* same-array
        op **and** strictly increasing (hence injective — no same-batch
        self-WAW).

    Under these proofs the coarsener's per-address membership tests are
    statically False and its ``stored``-set insertions unobservable, so
    skipping them is outcome-identical (tested in tests/test_deps.py).
    """
    if facts is None:
        facts = stream_facts(program)
    by_array: dict[str, list[OpFacts]] = {}
    for f in facts.values():
        by_array.setdefault(f.array, []).append(f)
    out: dict[str, bool] = {}
    for f in facts.values():
        peers = by_array[f.array]
        free = True
        if f.is_store:
            free = f.min_adjacent is not None and f.min_adjacent >= 1
        for g in peers:
            if not free:
                break
            if g.op_id == f.op_id:
                continue
            if not (f.is_store or g.is_store):
                continue  # load/load never conflicts
            free = streams_disjoint(f, g) is not None
        out[f.op_id] = free
    return out


# ---------------------------------------------------------------------------
# Dynamic MonotonicHint sanitizer (validate_hints=)
# ---------------------------------------------------------------------------


class HintViolation(ValueError):
    """A user ``MonotonicHint`` contradicted by the observed address
    stream: op id plus the first violating (instance, addr) pair."""

    def __init__(self, op_id: str, instance, addr: int, prev_addr: int):
        self.op_id = op_id
        self.instance = instance
        self.addr = int(addr)
        self.prev_addr = int(prev_addr)
        super().__init__(
            f"MonotonicHint violated by op {op_id!r}: at instance {instance} "
            f"addr {int(addr)} < previous addr {int(prev_addr)} outside any "
            f"asserted non-monotonic depth"
        )


def _max_allowed_reset_depth(hint: ir.MonotonicHint, depth: int) -> int:
    """Deepest 1-indexed depth whose advance may legally reset the
    address under ``hint`` (0 = no resets allowed at all)."""
    if hint.non_monotonic_outer is None:
        return depth - 1  # all outer depths may reset
    return max(hint.non_monotonic_outer, default=0)


def check_hint_stream(
    op_id: str, addr: np.ndarray, sched: np.ndarray, hint: ir.MonotonicHint
) -> None:
    """Validate one op's full address stream against its hint.

    ``addr`` is the (n,) request addresses in program order, ``sched``
    the (n, depth) iteration vectors. A decrease between consecutive
    requests is legal iff the outermost schedule coordinate that
    advanced is one of the hint's asserted non-monotonic depths (or
    shallower); otherwise raises ``HintViolation`` at the first
    offending request. Vectorized — O(n·depth) numpy, no python loop."""
    if not hint.innermost_monotonic:
        return  # the hint asserts nothing checkable (any decrease legal)
    n = len(addr)
    if n < 2:
        return
    depth = sched.shape[1]
    dec = addr[1:] < addr[:-1]
    if not dec.any():
        return
    max_nm = _max_allowed_reset_depth(hint, depth)
    changed = sched[1:] != sched[:-1]
    any_changed = changed.any(axis=1)
    # 1-indexed outermost coordinate that advanced; unchanged rows can
    # never legally decrease (same instance re-request)
    dstar = np.where(any_changed, changed.argmax(axis=1) + 1, depth + 1)
    bad = dec & (dstar > max_nm)
    if bad.any():
        i = int(np.flatnonzero(bad)[0]) + 1
        raise HintViolation(op_id, tuple(int(v) for v in sched[i]), addr[i], addr[i - 1])


def check_hinted_traces(program: ir.Program, traces: dict) -> None:
    """Run ``check_hint_stream`` over every hinted op's schedule trace
    (the engines' ``validate_hints=True`` entry point)."""
    for op, _path in program.mem_ops():
        if op.hint is None:
            continue
        tr = traces[op.id]
        check_hint_stream(op.id, np.asarray(tr.addr), np.asarray(tr.sched), op.hint)


def check_hint_positions(
    op_id: str, addr: np.ndarray, resets: np.ndarray, innermost_monotonic: bool
) -> None:
    """Positional variant for the wave executor: ``resets`` lists the
    request ordinals at which an asserted non-monotonic loop was
    (re-)entered — the only places the stream may legally decrease.
    Equivalent to ``check_hint_stream`` (the executor records an enter
    of the deepest allowed reset loop exactly when the outermost
    advanced coordinate is at most that depth)."""
    if not innermost_monotonic:
        return
    n = len(addr)
    if n < 2:
        return
    dec = np.flatnonzero(addr[1:] < addr[:-1]) + 1
    if len(dec) == 0:
        return
    allowed = np.zeros(n, dtype=bool)
    rs = np.asarray(resets, dtype=np.int64)
    allowed[rs[(rs >= 0) & (rs < n)]] = True
    bad = dec[~allowed[dec]]
    if len(bad) > 0:
        i = int(bad[0])
        raise HintViolation(op_id, i, addr[i], addr[i - 1])
