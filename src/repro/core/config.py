"""Unified run configuration: one frozen record for every knob.

PRs 2-9 accreted knobs one kwarg at a time (``trace_mode``,
``speculation``, ``predictor``, ``static_prune``, ...). ``RunConfig``
consolidates them into a single frozen dataclass accepted as
``config=`` by the four public entry points:

  * ``simulator.simulate(config=...)``
  * ``executor.execute(config=...)``
  * ``executor.build_wave_plan(config=...)``
  * ``dse.SweepSpec(config=...)`` (seeds the sweep axes)

The legacy kwargs remain as deprecated pass-throughs. Mixing them with
an explicit ``config=`` is allowed only when they agree — a conflicting
explicit kwarg raises ``ConfigConflict`` rather than silently picking a
winner. Each entry point consumes the fields that apply to it and
ignores the rest (``backend`` means nothing to ``simulate()``;
``engine`` means nothing to the wave executor) — the ignored fields are
exactly the ones the DSE result identity proves inert for that layer
(``dse.spec.RESULT_INERT_FIELDS``).

Three fields (``spec_runahead``, ``fifo_depth``, ``fifo_latency``)
overlap ``SimParams``. They default to ``None`` = "take the SimParams
value"; a non-``None`` value overrides it, and a conflict with an
explicitly different ``sim=SimParams(...)`` raises.

This module is dependency-free by design (no core imports), so every
layer can import it. The value vocabularies are re-asserted against
their canonical homes (``dae.PREDICTORS``, ``schedule.TRACE_MODES``)
by ``tests/test_config.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

MODES = ("STA", "LSQ", "FUS1", "FUS2")
ENGINES = ("cycle", "event")
TRACE_MODES = ("auto", "compiled", "interp")
SPECULATIONS = ("off", "auto")
PREDICTORS = ("last", "stride", "context", "auto")
BACKENDS = ("numpy", "pallas")

# the SimParams fields RunConfig can override (None = inherit)
SIM_FIELDS = ("spec_runahead", "fifo_depth", "fifo_latency")


class ConfigConflict(ValueError):
    """An explicit legacy kwarg (or ``sim=``/axis value) disagrees with
    an explicit ``config=RunConfig(...)``."""


class _Unset:
    """Sentinel distinguishing "kwarg not passed" from any real value."""

    _instance = None

    def __new__(cls):
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self):
        return "<unset>"


UNSET = _Unset()


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """One fully specified run configuration.

    Fields and the layers that consume them (README "The knobs" has the
    full table; ``tools/check_docs.py`` cross-checks it against this
    class):

      * ``mode`` — evaluated system (simulate/engines, DSE).
      * ``engine`` — timing engine for the dynamic modes
        (simulate/engines, DSE; STA provably ignores it).
      * ``trace_mode`` — AGU/CU front-end (simulate, executor, DSE;
        proven bit-identical across values, so excluded from the DSE
        result identity).
      * ``speculation`` — loss-of-decoupling policy (simulate,
        executor, DSE).
      * ``predictor`` — speculative-AGU value predictor (simulate,
        executor, DSE; dead unless the point speculates).
      * ``spec_runahead`` / ``fifo_depth`` / ``fifo_latency`` —
        ``SimParams`` overrides (``None`` = inherit from ``sim=``);
        ``fifo_depth`` also sizes the wave plan's circular slot
        encoding in the executor.
      * ``static_prune`` — certifier-pruned hazard plan (simulate,
        DSE).
      * ``validate_hints`` — dynamic ``MonotonicHint`` checking
        (simulate, executor; a checker, never changes results).
      * ``backend`` / ``batch_waves`` / ``symbolic_admission`` — wave
        executor only (``execute()``; proven result-inert everywhere
        else).
    """

    mode: str = "FUS2"
    engine: str = "event"
    trace_mode: str = "auto"
    speculation: str = "off"
    predictor: str = "auto"
    spec_runahead: Optional[int] = None
    fifo_depth: Optional[int] = None
    fifo_latency: Optional[int] = None
    static_prune: bool = False
    validate_hints: bool = False
    backend: str = "numpy"
    batch_waves: bool = True
    symbolic_admission: bool = True

    def __post_init__(self):
        _check("mode", self.mode, MODES)
        _check("engine", self.engine, ENGINES)
        _check("trace_mode", self.trace_mode, TRACE_MODES)
        _check("speculation", self.speculation, SPECULATIONS)
        _check("predictor", self.predictor, PREDICTORS)
        _check("backend", self.backend, BACKENDS)
        for f in SIM_FIELDS:
            v = getattr(self, f)
            if v is not None:
                v = int(v)
                object.__setattr__(self, f, v)
                if v < (0 if f == "fifo_latency" else 1):
                    raise ValueError(f"RunConfig.{f} must be >= 1, got {v}")
        for f in ("static_prune", "validate_hints", "batch_waves",
                  "symbolic_admission"):
            object.__setattr__(self, f, bool(getattr(self, f)))

    # -- SimParams reconciliation -------------------------------------------

    def sim_overrides(self) -> dict:
        """The non-``None`` SimParams-field overrides this config
        carries (``{field: value}``)."""
        return {
            f: getattr(self, f)
            for f in SIM_FIELDS
            if getattr(self, f) is not None
        }

    def apply_sim(self, sim, default):
        """Merge this config's SimParams overrides into ``sim``.

        ``sim`` is the (possibly ``None``) explicit ``sim=`` argument;
        ``default`` a default-constructed instance of the same
        dataclass. A field ``sim`` left at its default takes the
        config's value; a field set to something *different* from both
        the default and the config raises ``ConfigConflict`` — the two
        explicit specifications disagree.
        """
        base = sim if sim is not None else default
        out = {}
        for f, v in self.sim_overrides().items():
            cur = getattr(base, f)
            if cur != getattr(default, f) and cur != v:
                raise ConfigConflict(
                    f"sim=SimParams({f}={cur}) conflicts with explicit "
                    f"config=RunConfig({f}={v})"
                )
            if cur != v:
                out[f] = v
        return dataclasses.replace(base, **out) if out else base


def _check(field: str, value, allowed) -> None:
    if value not in allowed:
        # "unknown <field> <value>" wording is load-bearing: pre-config
        # entry points raised it and callers match on it
        raise ValueError(
            f"unknown {field} {value!r}: RunConfig.{field} must be one of "
            f"{allowed}"
        )


def resolve(config: Optional[RunConfig], **legacy) -> RunConfig:
    """Resolve an entry point's ``config=`` + legacy kwargs to one
    ``RunConfig``.

    ``legacy`` maps RunConfig field names to either ``UNSET`` (the
    kwarg was not passed) or the explicitly passed value. Rules:

      * no ``config=``: the explicit kwargs fill a default
        ``RunConfig`` (full backward compatibility),
      * ``config=`` given and every explicit kwarg agrees with it:
        the config wins (redundant kwargs are harmless),
      * ``config=`` given and an explicit kwarg disagrees:
        ``ConfigConflict`` — never silently pick a winner.
    """
    explicit = {k: v for k, v in legacy.items() if v is not UNSET}
    if config is None:
        return RunConfig(**explicit) if explicit else RunConfig()
    if not isinstance(config, RunConfig):
        raise TypeError(f"config= must be a RunConfig, got {config!r}")
    conflicts = {
        k: (getattr(config, k), v)
        for k, v in explicit.items()
        if getattr(config, k) != v
    }
    if conflicts:
        detail = ", ".join(
            f"{k}: config={c!r} vs kwarg={v!r}"
            for k, (c, v) in sorted(conflicts.items())
        )
        raise ConfigConflict(
            f"explicit kwargs conflict with explicit config= ({detail}); "
            "drop the kwargs or pass a matching RunConfig"
        )
    return config
