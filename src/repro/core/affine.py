"""Affine analysis + vectorized iteration spaces for the AGU/CU front-end.

The per-iteration Python IR walks in ``schedule._trace_pe`` (AGU) and
``dae.CU`` (compute unit) were the last scalar bottlenecks after the
event engine made simulation scale with requests (DESIGN.md §7). For
affine loop nests — and, more generally, for any nest whose trips,
induction updates and address expressions are *vectorizable* — the whole
request stream has a closed form. This module provides the three pieces
the trace compiler (``schedule.compile_pe_trace``) and the vectorized
compute unit (``dae.VecCU``) share:

  * **classification** (`classify_pe`, `classify_cu`): decides per PE
    whether the compiled path is exact, and names the offending op/loop
    when it is not. The compiled subset:
      - trips at depth d reference only consts/params/`Read` gathers and
        vars/ivars of depths < d (params-dependent and outer-var ragged
        trips are fine; negative trips clamp to zero like ``range``);
      - `+` ivar steps may vary per iteration (closed form by segmented
        cumsum); `*` ivar steps must be loop-invariant (closed form by
        integer powers) — the FFT ``stride *= 2`` case;
      - addresses reference consts/params/vars/ivars/`Read` gathers
        (arbitrarily nested: CSR's ``idx[rp[i] + k]`` is a gather of a
        gather) — everything numpy can evaluate elementwise;
      - **no loop-carried locals** (`Local`/`SetLocal` chains are
        inherently sequential) and **no protected load values**
        (`LoadVal` — loss of decoupling, the AGU cannot run ahead).
    Anything outside the subset falls back per-PE to the interpreter
    (`trace_mode="auto"`) or raises `TraceCompileError` naming the
    offending op (`trace_mode="compiled"`).
  * **iteration spaces** (`build_iter_space`): the PE's ragged loop nest
    flattened level by level into numpy arrays — per depth: flat body
    invocation count, parent links, 0-based iteration index, lastIter
    flags, ancestor indices (= the §4 never-reset counters, minus one)
    and an environment of loop-var/ivar value vectors.
  * **vectorized evaluation** (`vec_eval`): LoopIR expression -> numpy
    array over a flat iteration space, mirroring the interpreter's
    Python semantics elementwise (same truncation, floor-div, mod).

Exactness contract: for every program in the subset the compiled
streams equal the interpreter's **bit for bit** (pinned by the random
differential fuzz suite in tests/test_trace_compile.py). The only
numerically delicate ops are the ivar *accumulations* (cumsum / powers):
they are restricted at build time to integer dtypes AND to magnitudes
provably inside int64 (the interpreter computes them with Python's
arbitrary-precision ints, so a wrapped value would silently diverge) —
float elementwise math is order-identical and stays allowed everywhere
else.

The CR algebra (monotonic.py / cr.py, paper §3) answers a different
question — *monotonicity* for the hazard checks; `classify_pe` reuses it
to tag each address as CR-affine for reporting, but compilability is the
broader vectorizability criterion above (FFT's multiplicative chain is
non-affine yet compiles; a loop-carried local is affine-valued yet does
not).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

import numpy as np

from repro.core import loopir as ir
from repro.core import monotonic as mono


class TraceCompileError(Exception):
    """The compiled trace path cannot (exactly) represent this PE."""


# ---------------------------------------------------------------------------
# expression scan: what does an expression reference?
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ExprScan:
    max_depth: int = 0  # deepest loop depth whose var/ivar appears
    locals: set = dataclasses.field(default_factory=set)
    loads: set = dataclasses.field(default_factory=set)
    ivars: set = dataclasses.field(default_factory=set)  # ivar names used
    unknown_vars: set = dataclasses.field(default_factory=set)
    unsupported: set = dataclasses.field(default_factory=set)  # node/op names


def scan_expr(
    e: ir.Expr,
    var_depth: dict[str, int],
    ivar_depth: dict[str, int],
) -> ExprScan:
    """Recursively collect the references of ``e``: deepest loop depth,
    loop-carried locals, protected loads, unsupported node kinds."""
    out = ExprScan()

    def walk(x: ir.Expr):
        if isinstance(x, (ir.Const, ir.Param)):
            return
        if isinstance(x, ir.Var):
            if x.name in var_depth:
                out.max_depth = max(out.max_depth, var_depth[x.name])
            elif x.name in ivar_depth:
                out.max_depth = max(out.max_depth, ivar_depth[x.name])
                out.ivars.add(x.name)
            else:
                out.unknown_vars.add(x.name)
            return
        if isinstance(x, ir.Local):
            out.locals.add(x.name)
            return
        if isinstance(x, ir.LoadVal):
            out.loads.add(x.load_id)
            return
        if isinstance(x, ir.Read):
            walk(x.index)
            return
        if isinstance(x, ir.Bin):
            if x.op not in _NP_BINOPS:
                out.unsupported.add(f"binop {x.op!r}")
                return
            walk(x.a)
            walk(x.b)
            return
        if isinstance(x, ir.Un):
            if x.op not in ir._UN_FNS:
                out.unsupported.add(f"unop {x.op!r}")
                return
            walk(x.a)
            return
        out.unsupported.add(type(x).__name__)

    walk(e)
    return out


# ---------------------------------------------------------------------------
# classification
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PEClass:
    """Compiled-path verdict for one PE (AGU or CU view)."""

    pe_id: int
    compilable: bool
    reasons: list[str]  # empty iff compilable; each names the offender
    # reporting: per-op CR classification of the address (paper §3 view)
    op_affine: dict[str, bool] = dataclasses.field(default_factory=dict)

    def describe(self) -> str:
        if self.compilable:
            return f"PE {self.pe_id}: compiled"
        return f"PE {self.pe_id}: interp ({'; '.join(self.reasons)})"


def _depth_maps(pe) -> tuple[dict[str, int], dict[str, int]]:
    var_depth = {lp.var: d for d, lp in enumerate(pe.path, 1)}
    ivar_depth = {}
    for d, lp in enumerate(pe.path, 1):
        for iv in lp.ivars:
            ivar_depth[iv.name] = d
    return var_depth, ivar_depth


def _check(
    scan: ExprScan, what: str, ctx_depth: int, reasons: list[str]
) -> None:
    """Append human-readable rejection reasons for one expression."""
    if scan.loads:
        reasons.append(
            f"{what} depends on protected load value(s) "
            f"{sorted(scan.loads)} (loss of decoupling)"
        )
    if scan.locals:
        reasons.append(
            f"{what} depends on loop-carried local(s) {sorted(scan.locals)}"
        )
    if scan.unknown_vars:
        reasons.append(f"{what} references unknown var(s) {sorted(scan.unknown_vars)}")
    if scan.unsupported:
        reasons.append(f"{what} uses unsupported {sorted(scan.unsupported)}")
    if scan.max_depth > ctx_depth:
        reasons.append(
            f"{what} references depth-{scan.max_depth} state but is "
            f"evaluated at depth {ctx_depth}"
        )


def classify_pe(pe) -> PEClass:
    """AGU view: can every trip, ivar update, and address be compiled?"""
    var_depth, ivar_depth = _depth_maps(pe)
    reasons: list[str] = []

    for d, lp in enumerate(pe.path, 1):
        s = scan_expr(lp.trip, var_depth, ivar_depth)
        _check(s, f"trip of loop {lp.var!r}", d - 1, reasons)
        for iv in lp.ivars:
            si = scan_expr(iv.init, var_depth, ivar_depth)
            _check(si, f"init of ivar {iv.name!r}", d - 1, reasons)
            ss = scan_expr(iv.step, var_depth, ivar_depth)
            same_loop = {
                n for n in ss.ivars if ivar_depth.get(n) == d
            }
            if same_loop:
                reasons.append(
                    f"step of ivar {iv.name!r} references same-loop "
                    f"ivar(s) {sorted(same_loop)} (sequential recurrence)"
                )
            if iv.op == "*":
                # closed form is init * step**j: step must be invariant
                # within the loop it steps
                _check(
                    ss, f"step of multiplicative ivar {iv.name!r}", d - 1,
                    reasons,
                )
            else:
                _check(ss, f"step of ivar {iv.name!r}", d, reasons)

    op_affine: dict[str, bool] = {}
    for s, d in pe.stmts:
        if not isinstance(s, (ir.Load, ir.Store)):
            continue
        sc = scan_expr(s.addr, var_depth, ivar_depth)
        _check(sc, f"address of op {s.id!r}", d, reasons)
        # §3 CR view, for reporting only (hint-free): affine in the
        # polyhedral sense is strictly narrower than compilable
        cre = mono.to_cr_or_none(s.addr, pe.path)
        op_affine[s.id] = cre is not None and mono.crlib.is_affine_expr(cre)

    return PEClass(
        pe_id=pe.id,
        compilable=not reasons,
        reasons=reasons,
        op_affine=op_affine,
    )


def classify_cu(pe) -> PEClass:
    """CU view: can the value stream be computed without the generator?

    Requires a *load-free* value chain — the generator exists to block on
    protected load values; without loads every store value/guard (and
    the iteration space) is computable up front.
    """
    base = classify_pe(pe)
    reasons = list(base.reasons)
    var_depth, ivar_depth = _depth_maps(pe)
    for s, d in pe.stmts:
        if isinstance(s, ir.Load):
            reasons.append(
                f"op {s.id!r} is a protected load (CU must block on its value)"
            )
        elif isinstance(s, ir.Store):
            sv = scan_expr(s.value, var_depth, ivar_depth)
            _check(sv, f"value of store {s.id!r}", d, reasons)
            if s.guard is not None:
                sg = scan_expr(s.guard, var_depth, ivar_depth)
                _check(sg, f"guard of store {s.id!r}", d, reasons)
    return PEClass(
        pe_id=pe.id,
        compilable=not reasons,
        reasons=reasons,
        op_affine=base.op_affine,
    )


# ---------------------------------------------------------------------------
# vectorized expression evaluation
# ---------------------------------------------------------------------------


_NP_BINOPS = ir.NP_BINOPS


def vec_eval(
    e: ir.Expr,
    env: dict[str, np.ndarray],
    arrays: dict[str, np.ndarray],
    params: dict[str, int],
    n: int,
) -> np.ndarray:
    """Evaluate ``e`` over a flat iteration space of ``n`` points.

    ``env`` maps loop vars / ivars to length-``n`` vectors. Matches the
    scalar interpreter elementwise: numpy's ``//``/``%`` agree with
    Python's on ints and floats, gathers truncate indices toward zero
    like ``int()``, and mixed int/float promotion mirrors Python
    arithmetic on the same values.
    """
    if isinstance(e, ir.Const):
        v = e.v
        dtype = np.int64 if isinstance(v, int) and not isinstance(v, bool) else np.float64
        return np.full(n, v, dtype=dtype)
    if isinstance(e, ir.Param):
        v = params[e.name]
        dtype = np.int64 if isinstance(v, (int, np.integer)) else np.float64
        return np.full(n, v, dtype=dtype)
    if isinstance(e, (ir.Var, ir.Local)):
        return env[e.name]
    if isinstance(e, ir.Read):
        idx = vec_eval(e.index, env, arrays, params, n)
        return np.asarray(arrays[e.array])[_as_index(idx)]
    if isinstance(e, ir.Bin):
        return _NP_BINOPS[e.op](
            vec_eval(e.a, env, arrays, params, n),
            vec_eval(e.b, env, arrays, params, n),
        )
    if isinstance(e, ir.Un):
        return ir._UN_FNS[e.op](vec_eval(e.a, env, arrays, params, n))
    raise TraceCompileError(f"cannot vectorize {type(e).__name__}")


def _as_index(v: np.ndarray) -> np.ndarray:
    """``int()``-style truncation toward zero, as the interpreter casts
    addresses and read indices."""
    if np.issubdtype(v.dtype, np.integer):
        return v
    return np.trunc(v).astype(np.int64)


# ---------------------------------------------------------------------------
# iteration spaces
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class IterSpace:
    """A PE's ragged loop nest, flattened per depth (1-indexed lists with
    a dummy depth-0 root of one point).

    At depth d, the flat enumeration order is exactly the interpreter's
    execution order of the depth-d body invocations, so flat index ==
    §4 counter value - 1 (counters increment per invocation and never
    reset).
    """

    depth: int
    counts: list[int]  # counts[d]: number of depth-d body invocations
    parent: list[Optional[np.ndarray]]  # parent[d]: index into depth d-1
    index: list[Optional[np.ndarray]]  # index[d]: 0-based iteration number
    is_last: list[Optional[np.ndarray]]  # lastIter flag (§4.2(3))
    anc: list[list[np.ndarray]]  # anc[d][k-1]: depth-k ancestor flat index
    env: list[dict[str, np.ndarray]]  # visible loop vars + ivars per depth


# accumulated ivar values must stay comfortably inside int64: the
# interpreter computes them with arbitrary-precision Python ints, so a
# wrapped cumsum/power would silently break the bit-for-bit contract.
# (The global cumsum may wrap internally — two's-complement differences
# are still exact — but the *values* themselves must fit.)
_ACC_BOUND_BITS = 60.0


def build_iter_space(pe, arrays, params) -> IterSpace:
    """Flatten the PE's loop nest into closed-form numpy arrays.

    Raises TraceCompileError for the residual dynamically-detected
    cases (non-integer or int64-overflowing ivar accumulation).
    Structural ineligibility is `classify_pe`'s job — callers should
    classify first.
    """
    D = pe.depth
    counts: list[int] = [1]
    parent: list[Optional[np.ndarray]] = [None]
    index: list[Optional[np.ndarray]] = [None]
    is_last: list[Optional[np.ndarray]] = [None]
    anc: list[list[np.ndarray]] = [[]]
    env: list[dict[str, np.ndarray]] = [{}]

    for d in range(1, D + 1):
        loop = pe.path[d - 1]
        n_par = counts[d - 1]
        trips = vec_eval(loop.trip, env[d - 1], arrays, params, n_par)
        trips = _as_index(np.asarray(trips))  # int() truncation
        reps = np.maximum(trips, 0)  # range(trip): negative == empty
        total = int(reps.sum())
        par = np.repeat(np.arange(n_par, dtype=np.int64), reps)
        offs = np.zeros(n_par, dtype=np.int64)
        if n_par:
            np.cumsum(reps[:-1], out=offs[1:])
        j = np.arange(total, dtype=np.int64) - offs[par]
        if loop.predictable:
            last = j == (reps[par] - 1)
        else:
            # §4.2(3): unpredictable exit — the lastIter hint is 0
            last = np.zeros(total, dtype=bool)

        new_env = {k: v[par] for k, v in env[d - 1].items()}
        new_env[loop.var] = j
        for iv in loop.ivars:
            init = vec_eval(iv.init, env[d - 1], arrays, params, n_par)
            init = np.asarray(init)
            if iv.op == "+":
                step = np.asarray(
                    vec_eval(iv.step, new_env, arrays, params, total)
                )
                if not (
                    np.issubdtype(init.dtype, np.integer)
                    and np.issubdtype(step.dtype, np.integer)
                ):
                    raise TraceCompileError(
                        f"ivar {iv.name!r}: non-integer '+' accumulation "
                        "(cumsum would not be bit-exact)"
                    )
                # conservative magnitude bound (float is fine: wide margin)
                mag = float(
                    np.abs(init.astype(np.float64)).max(initial=0.0)
                ) + float(np.abs(step.astype(np.float64)).sum())
                if mag > 2.0 ** _ACC_BOUND_BITS:
                    raise TraceCompileError(
                        f"ivar {iv.name!r}: '+' accumulation may exceed "
                        "int64 (the interpreter uses arbitrary precision)"
                    )
                # v_j = init + sum_{t<j} step_t, segmented per parent
                excl = np.cumsum(step) - step
                base = (
                    excl[np.minimum(offs, max(total - 1, 0))]
                    if total
                    else np.zeros(n_par, dtype=np.int64)
                )
                new_env[iv.name] = init[par] + (excl - base[par])
            else:  # '*': loop-invariant step (classify_pe enforced)
                stepc = np.asarray(
                    vec_eval(iv.step, env[d - 1], arrays, params, n_par)
                )
                if not (
                    np.issubdtype(init.dtype, np.integer)
                    and np.issubdtype(stepc.dtype, np.integer)
                ):
                    raise TraceCompileError(
                        f"ivar {iv.name!r}: non-integer '*' accumulation "
                        "(powers would not be bit-exact)"
                    )
                maxj = max(int(reps.max(initial=0)) - 1, 0)
                a = float(np.abs(init.astype(np.float64)).max(initial=0.0))
                s = float(np.abs(stepc.astype(np.float64)).max(initial=0.0))
                bits = (np.log2(a) if a > 1.0 else 0.0) + (
                    maxj * np.log2(s) if s > 1.0 else 0.0
                )
                if bits > _ACC_BOUND_BITS:
                    raise TraceCompileError(
                        f"ivar {iv.name!r}: '*' accumulation may exceed "
                        "int64 (the interpreter uses arbitrary precision)"
                    )
                new_env[iv.name] = init[par] * stepc[par] ** j

        counts.append(total)
        parent.append(par)
        index.append(j)
        is_last.append(last)
        anc.append([a[par] for a in anc[d - 1]] + [np.arange(total, dtype=np.int64)])
        env.append(new_env)

    return IterSpace(
        depth=D,
        counts=counts,
        parent=parent,
        index=index,
        is_last=is_last,
        anc=anc,
        env=env,
    )


# ---------------------------------------------------------------------------
# AGU/CU interleave order (the per-PE ``seq`` stream)
# ---------------------------------------------------------------------------

_PAST_OPS = np.int64(2**62)  # "descended past this depth's statements"


def interleave_order(
    space: IterSpace, op_ids: list[tuple[str, int, int]]
) -> dict[str, np.ndarray]:
    """Per-op generation-order sequence numbers for the given ops.

    ``op_ids`` is a list of (op_id, depth, rank-at-depth) where rank is
    the op's position among the listed ops of the same depth in
    statement order. Execution order is the interpreter's DFS: at each
    body invocation, this depth's statements run in order, then the
    inner loop runs. The order is therefore lexicographic on the padded
    key [c_1, r_1, c_2, r_2, ...] where a request at depth d carries its
    ancestors' counters, r_k = +inf for the depths it descends past, and
    r_d = its statement rank.
    """
    if not op_ids:
        return {}
    D = space.depth
    width = 2 * D
    mats = []
    for op_id, d, rank in op_ids:
        n = space.counts[d]
        key = np.full((n, width), -1, dtype=np.int64)
        for k in range(1, d + 1):
            key[:, 2 * (k - 1)] = space.anc[d][k - 1] + 1  # §4 counter
            key[:, 2 * (k - 1) + 1] = _PAST_OPS if k < d else rank
        mats.append(key)
    stacked = np.concatenate(mats, axis=0)
    order = np.lexsort(stacked.T[::-1])
    seq_all = np.empty(len(stacked), dtype=np.int64)
    seq_all[order] = np.arange(len(stacked), dtype=np.int64)
    out: dict[str, np.ndarray] = {}
    off = 0
    for op_id, d, _rank in op_ids:
        n = space.counts[d]
        out[op_id] = seq_all[off : off + n]
        off += n
    return out
