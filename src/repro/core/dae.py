"""DAE decoupling pass (paper §2.1.2, Fig. 3).

Decouples a loop forest into Processing Elements:

  * each *leaf* loop becomes its own PE, replicating the loop control of
    all its ancestors,
  * parent-body statements are assigned to the PE of the next leaf loop
    in topological order (Fig. 3: "Parent loop body instructions are
    included only if they come before the leaf loop"),
  * scalar values flowing between PEs become FIFO edges, written in the
    producer loop's exit block and read in the consumer's pre-header,
  * each PE is further split AGU/CU by def-use closure: the AGU keeps
    the address/trip computation (plus §4.2 schedule instrumentation,
    added later), the CU keeps value computation; dead code on each side
    is eliminated (we record instruction counts so the DCE effect is
    observable in tests/benchmarks).

Loss-of-decoupling (LoD): if an address or trip count depends on a
*protected* load value (``LoadVal``), the AGU cannot run ahead. The
paper resolves this with speculation from prior work [62]. Under
``decouple(speculation="off")`` (the default) such programs are
rejected with a diagnostic naming the offending op/loop/local; under
``speculation="auto"`` the PE is instead marked speculative
(``DAEResult.spec``) and the AGU runs ahead with a value predictor
(``predictor=`` selects from the zoo in ``PREDICTORS``), squashing
mis-speculated epochs through the §6 valid-bit machinery
(``core/speculate.py``, DESIGN.md §10).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

import numpy as np

from repro.core import loopir as ir


class LossOfDecoupling(Exception):
    """Raised when an AGU would depend on a protected load value."""


class CUContractError(RuntimeError):
    """Internal-contract violation between an engine and a CU: a call
    the CU's protocol forbids (e.g. ``feed`` on a load-free ``VecCU``,
    or script-recording a FIFO-coupled PE whose consumption order is
    timing-dependent). A mis-wired CU factory fails loudly here instead
    of corrupting the value stream."""


SPECULATION_MODES = ("off", "auto")

# The speculative-AGU predictor zoo (core/speculate.py, DESIGN.md §10):
# value predictors a speculative AGU port can run ahead on. Defined here
# (not in speculate.py) so every layer that threads the knob —
# ``decouple``, ``simulator.Compiled``, ``executor.build_wave_plan``,
# ``dse.spec`` — validates against one tuple without import cycles.
# ``"auto"`` runs a per-port tournament and follows the best-scoring
# component predictor.
PREDICTORS = ("last", "stride", "context", "auto")


@dataclasses.dataclass(frozen=True)
class SpecInfo:
    """Why one PE's AGU cannot run ahead without speculation.

    Produced by ``decouple(speculation="auto")`` instead of raising
    ``LossOfDecoupling``: ``loads`` are the protected load ops whose
    values the AGU's address/trip closure consumes (each becomes a
    value-predicted port of the speculative AGU — predictor zoo,
    DESIGN.md §10); ``reasons`` are the exact diagnostics
    ``speculation="off"`` raises.
    """

    pe_id: int
    loads: tuple  # load op ids the AGU depends on, sorted
    reasons: tuple  # one message per offending expression/local


# ---------------------------------------------------------------------------
# def-use helpers
# ---------------------------------------------------------------------------


def expr_deps(e: ir.Expr) -> tuple[set[str], set[str]]:
    """Returns (local names, protected load ids) referenced by ``e``."""
    locals_, loads = set(), set()

    def walk(x: ir.Expr):
        if isinstance(x, ir.Local):
            locals_.add(x.name)
        elif isinstance(x, ir.LoadVal):
            loads.add(x.load_id)
        elif isinstance(x, ir.Bin):
            walk(x.a)
            walk(x.b)
        elif isinstance(x, ir.Un):
            walk(x.a)
        elif isinstance(x, ir.Read):
            walk(x.index)

    walk(e)
    return locals_, loads


def _stmt_exprs(s: ir.Stmt) -> list[ir.Expr]:
    if isinstance(s, ir.Load):
        return [s.addr]
    if isinstance(s, ir.Store):
        out = [s.addr, s.value]
        if s.guard is not None:
            out.append(s.guard)
        return out
    if isinstance(s, ir.SetLocal):
        return [s.value]
    if isinstance(s, ir.Loop):
        out = [s.trip]
        for iv in s.ivars:
            out.extend([iv.init, iv.step])
        for b in s.body:
            out.extend(_stmt_exprs(b))
        return out
    raise TypeError(s)


# ---------------------------------------------------------------------------
# PE structure
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PE:
    id: int
    # full loop path of the leaf, outermost first (replicated control)
    path: tuple[ir.Loop, ...]
    # statements executed by this PE *inside the leaf body* plus any
    # parent-body statements assigned to it: list of (stmt, depth) where
    # depth is the 1-indexed loop depth the stmt executes at
    stmts: list[tuple[ir.Stmt, int]] = dataclasses.field(default_factory=list)
    mem_ops: list[str] = dataclasses.field(default_factory=list)
    # locals this PE defines that other PEs consume -> FIFO writes
    fifo_out: set[str] = dataclasses.field(default_factory=set)
    # locals this PE consumes that other PEs define -> FIFO reads
    fifo_in: set[str] = dataclasses.field(default_factory=set)
    # AGU/CU instruction counts after the def-use split + DCE
    agu_stmt_count: int = 0
    cu_stmt_count: int = 0

    @property
    def leaf(self) -> ir.Loop:
        return self.path[-1]

    @property
    def depth(self) -> int:
        return len(self.path)


@dataclasses.dataclass
class DAEResult:
    pes: list[PE]
    op_to_pe: dict[str, int]
    # FIFO edges: (producer PE id, consumer PE id, local name, shared depth)
    fifo_edges: list[tuple[int, int, str, int]]
    # PE id -> SpecInfo for PEs that need the speculative AGU (only
    # populated under decouple(speculation="auto"); empty otherwise)
    spec: dict[int, SpecInfo] = dataclasses.field(default_factory=dict)
    # the predictor knob the speculative AGU traces under (PREDICTORS);
    # carried for diagnostics — prediction itself is trace-time-only
    # (core/speculate.py), so decoupling is predictor-independent
    predictor: str = "auto"

    def shared_depth(self, op_a: str, op_b: str, program: ir.Program) -> int:
        """Number of common loops of the two ops' original nests."""
        _, pa = program.find_op(op_a)
        _, pb = program.find_op(op_b)
        k = 0
        for la, lb in zip(pa, pb):
            if la is lb:
                k += 1
            else:
                break
        return k


def decouple(
    program: ir.Program, speculation: str = "off", predictor: str = "auto"
) -> DAEResult:
    """Run the decoupling pass over the program's loop forest.

    ``speculation`` selects the loss-of-decoupling policy: ``"off"``
    raises ``LossOfDecoupling`` when an AGU's address/trip closure
    touches a protected load value, ``"auto"`` marks the PE speculative
    instead (``DAEResult.spec``) so the trace front-end can build the
    speculative AGU (``core/speculate.py``). ``predictor`` names the
    value predictor that AGU runs ahead on (``PREDICTORS``); it cannot
    change *which* PEs are marked — only how their trace predicts — and
    is validated and carried here so every backend shares one knob.
    """
    assert speculation in SPECULATION_MODES, (
        f"unknown speculation mode {speculation!r}"
    )
    assert predictor in PREDICTORS, (
        f"unknown predictor {predictor!r} (choose from {PREDICTORS})"
    )
    pes: list[PE] = []
    op_to_pe: dict[str, int] = {}
    # local name -> PE id that defines it (for FIFO edge construction)
    local_def_pe: dict[str, int] = {}
    local_use_pes: dict[str, set[int]] = {}

    # ---- step 1: assign leaf loops and statements to PEs -----------------

    def is_leaf(lp: ir.Loop) -> bool:
        return not any(isinstance(s, ir.Loop) for s in lp.body)

    def walk(stmts, path: tuple[ir.Loop, ...], pending: list[tuple[ir.Stmt, int]]):
        """``pending`` collects parent-body stmts awaiting the next leaf."""
        for s in stmts:
            if isinstance(s, ir.Loop):
                sub_path = path + (s,)
                if is_leaf(s):
                    pe = PE(id=len(pes), path=sub_path)
                    pe.stmts = list(pending)
                    pending.clear()
                    for b in s.body:
                        pe.stmts.append((b, len(sub_path)))
                        if isinstance(b, (ir.Load, ir.Store)):
                            pe.mem_ops.append(b.id)
                            op_to_pe[b.id] = pe.id
                    pes.append(pe)
                else:
                    walk(s.body, sub_path, pending)
            else:
                pending.append((s, len(path)))
                if isinstance(s, (ir.Load, ir.Store)):
                    # memory op directly in a parent body: belongs to the
                    # next leaf PE (recorded when that PE is created)
                    pass

    for top in program.loops:
        pending: list[tuple[ir.Stmt, int]] = []
        if is_leaf(top):
            pe = PE(id=len(pes), path=(top,))
            for b in top.body:
                pe.stmts.append((b, 1))
                if isinstance(b, (ir.Load, ir.Store)):
                    pe.mem_ops.append(b.id)
                    op_to_pe[b.id] = pe.id
            pes.append(pe)
        else:
            walk(top.body, (top,), pending)
            if pending and pes:
                # trailing parent-body stmts: assign to the last PE
                pes[-1].stmts.extend(pending)

    # register mem ops that came in via ``pending`` parent stmts
    for pe in pes:
        for s, _d in pe.stmts:
            if isinstance(s, (ir.Load, ir.Store)) and s.id not in op_to_pe:
                pe.mem_ops.append(s.id)
                op_to_pe[s.id] = pe.id

    # ---- step 2: FIFO edges for cross-PE scalar locals --------------------

    for pe in pes:
        for s, _d in pe.stmts:
            if isinstance(s, ir.SetLocal):
                local_def_pe.setdefault(s.name, pe.id)
            for e in _stmt_exprs(s) if not isinstance(s, ir.Loop) else []:
                for name in expr_deps(e)[0]:
                    local_use_pes.setdefault(name, set()).add(pe.id)
        # ivar init/steps may also use locals
        for lp in pe.path:
            for iv in lp.ivars:
                for e in (iv.init, iv.step):
                    for name in expr_deps(e)[0]:
                        local_use_pes.setdefault(name, set()).add(pe.id)

    fifo_edges: list[tuple[int, int, str, int]] = []
    for name, users in sorted(local_use_pes.items()):
        if name not in local_def_pe:
            continue
        prod = local_def_pe[name]
        for u in sorted(users):
            if u != prod:
                shared = _shared_depth_pe(pes[prod], pes[u])
                fifo_edges.append((prod, u, name, shared))
                pes[prod].fifo_out.add(name)
                pes[u].fifo_in.add(name)

    # ---- step 3: AGU/CU def-use split + DCE accounting + LoD check --------

    spec: dict[int, SpecInfo] = {}
    for pe in pes:
        agu, cu, si = _split_agu_cu(pe, speculation)
        pe.agu_stmt_count = agu
        pe.cu_stmt_count = cu
        if si is not None:
            spec[pe.id] = si

    return DAEResult(
        pes=pes, op_to_pe=op_to_pe, fifo_edges=fifo_edges, spec=spec,
        predictor=predictor,
    )


class CU:
    """Compute-unit thread of one PE (the value half of the AGU/CU
    split): executes leaf iterations in order, consuming load values
    (in-order FIFO per load op) and producing store values with §6 valid
    bits. Shared by both simulator engines. A CU with protected loads
    (or loop-carried locals) is inherently sequential, so it stays a
    generator; *load-free value chains* take the vectorized ``VecCU``
    path instead (``make_cu`` decides)."""

    def __init__(self, pe: PE, arrays, params, fifo_edges=()):
        self.pe = pe
        self.arrays = arrays
        self.params = params
        self.time = 0
        self.done = False
        # load op id, or ("fifo_pop", edge idx) / ("fifo_push", edge idx)
        self.waiting_on: Optional[Union[str, tuple]] = None
        # value pending for the engine while waiting on a fifo_push
        self.push_value: float = 0.0
        # this PE's slice of DAEResult.fifo_edges, in edge-index order
        self.fifo_in_edges = [
            (i, name)
            for i, (_p, c, name, _d) in enumerate(fifo_edges)
            if c == pe.id
        ]
        self.fifo_out_edges = [
            (i, name)
            for i, (p, _c, name, _d) in enumerate(fifo_edges)
            if p == pe.id
        ]
        self.outbox: list[tuple[str, float, bool]] = []
        self.gen = self._generator()
        self._advance(prime=True)

    def _generator(self):
        pe = self.pe
        by_depth: dict[int, list[ir.Stmt]] = {}
        for s, d in pe.stmts:
            by_depth.setdefault(d, []).append(s)

        def ev(e, scope, loadvals):
            return ir._eval(e, scope, self.arrays, self.params, loadvals)

        def run_depth(d, scope, outer_loadvals):
            # load values of enclosing iterations stay visible to inner
            # trips/ivars/values (mirrors loopir.interpret's chaining —
            # load-dependent trip counts need them, DESIGN.md §10)
            loop = pe.path[d - 1]
            loop_scope = ir._Env(scope)
            if d == pe.depth:
                # one pop per consumer leaf instance, at entry — before
                # the trip/ivars so the engines stall the whole instance
                # until its token arrives (core/fifo.py token protocol)
                for eidx, name in self.fifo_in_edges:
                    v = yield ("fifo_pop", eidx)
                    loop_scope.define(name, v)
            for iv in loop.ivars:
                loop_scope.define(iv.name, ev(iv.init, scope, outer_loadvals))
            trip = int(ev(loop.trip, scope, outer_loadvals))
            for i in range(trip):
                body = ir._Env(loop_scope)
                body.define(loop.var, i)
                loadvals: dict[str, float] = dict(outer_loadvals)
                for s in by_depth.get(d, ()):
                    if isinstance(s, ir.Load):
                        v = yield ("need", s.id)
                        loadvals[s.id] = v
                    elif isinstance(s, ir.Store):
                        valid = True
                        if s.guard is not None:
                            valid = bool(ev(s.guard, body, loadvals))
                        val = ev(s.value, body, loadvals) if valid else 0.0
                        self.outbox.append((s.id, val, valid))
                    elif isinstance(s, ir.SetLocal):
                        v = ev(s.value, body, loadvals)
                        if not body.set_existing(s.name, v):
                            body.define(s.name, v)
                if d < pe.depth:
                    yield from run_depth(d + 1, body, loadvals)
                for iv in loop.ivars:
                    cur = loop_scope.get(iv.name)
                    step = ev(iv.step, body, outer_loadvals)
                    loop_scope.vals[iv.name] = (
                        cur + step if iv.op == "+" else cur * step
                    )
            if d == pe.depth:
                # one push per producer leaf instance, at exit; a
                # zero-trip instance pushes the shared-depth init value
                # (core/fifo.py guarantees that init exists)
                for eidx, name in self.fifo_out_edges:
                    yield ("fifo_push", eidx, loop_scope.get(name))

        if pe.depth >= 1:
            yield from run_depth(1, ir._Env(), {})

    def _advance(self, value: float = 0.0, prime: bool = False):
        try:
            item = next(self.gen) if prime else self.gen.send(value)
            while True:
                if item[0] == "need":
                    self.waiting_on = item[1]
                    return
                if item[0] == "fifo_pop":
                    self.waiting_on = ("fifo_pop", item[1])
                    return
                if item[0] == "fifo_push":
                    self.waiting_on = ("fifo_push", item[1])
                    self.push_value = float(item[2])
                    return
                item = next(self.gen)  # pragma: no cover (stores don't yield)
        except StopIteration:
            self.done = True
            self.waiting_on = None

    def feed(self, value: float, at_time: int):
        assert self.waiting_on is not None
        self.time = max(self.time, at_time)
        self.waiting_on = None
        self._advance(value)


class VecCU:
    """Vectorized compute unit for load-free value chains.

    When a PE has no protected loads and every store value/guard is
    vectorizable (``affine.classify_cu``), the whole outbox — store
    values with §6 valid bits, in AGU/CU generation order — is one
    closed-form numpy evaluation over the PE's iteration space instead
    of a per-iteration generator walk. The interface matches ``CU``
    exactly as the engines use it: the full ``outbox`` is ready
    immediately (a load-free generator CU also runs to completion when
    primed, so event timing is identical), ``done`` is True, and
    ``feed`` can never legally be called.
    """

    def __init__(self, pe: PE, arrays, params):
        from repro.core import affine

        self.pe = pe
        self.time = 0
        self.done = True
        self.waiting_on = None
        space = affine.build_iter_space(pe, arrays, params)
        stores: list[tuple] = []  # (stmt, depth, rank-at-depth)
        rank_at: dict[int, int] = {}
        for s, d in pe.stmts:
            if isinstance(s, (ir.Load, ir.Store)):
                r = rank_at.get(d, 0)
                rank_at[d] = r + 1
                if isinstance(s, ir.Store):
                    stores.append((s, d, r))
        seqs = affine.interleave_order(
            space, [(s.id, d, r) for s, d, r in stores]
        )
        flat: list[tuple[int, str, float, bool]] = []
        for s, d, _r in stores:
            n = space.counts[d]
            if not n:
                continue
            env = space.env[d]
            val = np.asarray(affine.vec_eval(s.value, env, arrays, params, n))
            if s.guard is not None:
                valid = np.asarray(
                    affine.vec_eval(s.guard, env, arrays, params, n)
                ).astype(bool)
                val = np.where(valid, val, np.zeros_like(val))
            else:
                valid = np.ones(n, dtype=bool)
            seq = seqs[s.id]
            for i in range(n):
                flat.append((int(seq[i]), s.id, val[i].item(), bool(valid[i])))
        flat.sort()
        self.outbox: list[tuple[str, float, bool]] = [
            (op_id, v, ok) for _s, op_id, v, ok in flat
        ]

    def feed(self, value: float, at_time: int):
        raise CUContractError(
            f"PE {self.pe.id}: feed({value!r}) on a load-free VecCU — "
            "the engine delivered a value no load requested"
        )


def make_cu(pe: PE, arrays, params, trace_mode: str = "auto", fifo_edges=()):
    """CU factory: vectorized value stream for load-free PEs, the
    generator otherwise (or always, under ``trace_mode="interp"``).
    FIFO-coupled PEs always take the generator: their pop/push yields
    interleave with the engine's queue service (DESIGN.md §11)."""
    if pe.fifo_in or pe.fifo_out:
        return CU(pe, arrays, params, fifo_edges)
    if trace_mode != "interp":
        from repro.core import affine

        if affine.classify_cu(pe).compilable:
            try:
                return VecCU(pe, arrays, params)
            except (affine.TraceCompileError, IndexError):
                # residual dynamic ineligibility (non-integer ivar
                # accumulation; a guard-protected Read evaluated
                # speculatively out of bounds): the generator is exact
                pass
    return CU(pe, arrays, params)


# ---------------------------------------------------------------------------
# CU script recording / replay (the DSE batch runner's shared dataflow)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CUScript:
    """The complete, timing-independent behaviour of one PE's CU.

    A CU is pure dataflow: it consumes protected load values in a fixed
    order (``feeds``) and emits outbox items — ``(store op id, value,
    §6 valid bit)`` — at fixed points of that consumption sequence.
    *When* each feed arrives is timing; *what* happens is not. A script
    records the what once (per program/arrays/params), so a design-space
    sweep can replay the CU in O(1) Python per feed for every timing
    configuration instead of re-walking the IR per iteration
    (``ReplayCU``; see DESIGN.md §9).

    ``offsets[k]`` is the number of outbox items emitted after ``k``
    feeds (``offsets[0]`` = items emitted when the CU is primed, before
    any load value arrives; load-free PEs emit everything there).
    """

    pe_id: int
    items: list  # [(op_id, value, valid)] in emission order
    feeds: list  # load op ids, in consumption order
    offsets: list  # len(feeds)+1 cumulative item counts


def record_cu_script(
    pe: PE, arrays, params, oracle_loads: dict, trace_mode: str = "auto"
) -> CUScript:
    """Run one PE's CU to completion against the oracle's load-value
    streams and record its script.

    ``oracle_loads`` maps load op id -> the op's in-order value stream
    (``loopir.interpret``'s trace hook produces exactly this). Sound
    because the engines' validated delivery contract guarantees every
    load receives its oracle value regardless of timing parameters, so
    the recorded emission sequence is what any simulation of this
    (program, arrays, params) would produce.
    """
    if pe.fifo_in or pe.fifo_out:
        raise CUContractError(
            f"PE {pe.id}: cannot record a CU script for a FIFO-coupled "
            "PE — its pop/push interleaving is engine-serviced, not an "
            "oracle load stream (the DSE planner must not share CU "
            "scripts for streaming programs)"
        )
    cu = make_cu(pe, arrays, params, trace_mode)
    feeds: list[str] = []
    offsets: list[int] = [len(cu.outbox)]
    cursor: dict[str, int] = {}
    while cu.waiting_on is not None:
        op_id = cu.waiting_on
        i = cursor.get(op_id, 0)
        cursor[op_id] = i + 1
        feeds.append(op_id)
        cu.feed(float(oracle_loads[op_id][i]), 0)
        offsets.append(len(cu.outbox))
    assert cu.done, f"PE {pe.id}: CU neither waiting nor done"
    return CUScript(
        pe_id=pe.id, items=list(cu.outbox), feeds=feeds, offsets=offsets
    )


class ReplayCU:
    """Replay a recorded ``CUScript`` with the exact engine-facing
    behaviour of the CU it was recorded from: same ``outbox`` items in
    the same feed-relative positions, same ``waiting_on`` sequence, same
    ``done`` transitions — at O(1) Python cost per feed. Engines drain
    ``outbox`` after priming and after every ``feed``, so emission
    timing (and therefore simulated cycles) is bit-identical to running
    the generator/vectorized CU in place."""

    __slots__ = ("script", "k", "outbox", "done", "waiting_on", "time")

    def __init__(self, script: CUScript):
        self.script = script
        self.k = 0
        self.outbox = list(script.items[: script.offsets[0]])
        n = len(script.feeds)
        self.done = n == 0
        self.waiting_on = script.feeds[0] if n else None
        self.time = 0

    def feed(self, value: float, at_time: int):
        assert self.waiting_on is not None
        self.time = max(self.time, at_time)
        s = self.script
        k = self.k = self.k + 1
        self.outbox.extend(s.items[s.offsets[k - 1] : s.offsets[k]])
        if k < len(s.feeds):
            self.waiting_on = s.feeds[k]
        else:
            self.waiting_on = None
            self.done = True


def _shared_depth_pe(a: PE, b: PE) -> int:
    k = 0
    for la, lb in zip(a.path, b.path):
        if la is lb:
            k += 1
        else:
            break
    return k


def _split_agu_cu(
    pe: PE, speculation: str = "off"
) -> tuple[int, int, Optional[SpecInfo]]:
    """Compute AGU/CU statement counts after the def-use split.

    AGU closure: everything feeding addresses, trip counts and ivar
    updates. If that closure touches a protected LoadVal, the AGU can no
    longer run ahead (loss of decoupling): under ``speculation="off"``
    raise a diagnostic naming the consuming statement (op id, loop trip,
    or ivar — mirroring ``TraceCompileError``'s offender-naming); under
    ``"auto"`` collect the offending loads into a ``SpecInfo`` for the
    speculative AGU. Returns ``(agu_count, cu_count, SpecInfo | None)``.
    """
    # AGU-side expressions, each with the statement that owns it (the
    # diagnostics below must name the consumer, not just the load)
    agu_exprs: list[tuple[ir.Expr, str]] = []
    for lp in pe.path:
        agu_exprs.append((lp.trip, f"trip of loop {lp.var!r}"))
        for iv in lp.ivars:
            agu_exprs.append((iv.init, f"init of ivar {iv.name!r}"))
            agu_exprs.append((iv.step, f"step of ivar {iv.name!r}"))
    for s, _d in pe.stmts:
        if isinstance(s, (ir.Load, ir.Store)):
            agu_exprs.append((s.addr, f"address of op {s.id!r}"))

    spec_loads: set[str] = set()
    spec_reasons: list[str] = []

    def offend(what: str, lds: set) -> None:
        # collect even under "off": whether the auto hint is honest
        # depends on the *whole* closure (cross-PE loads re-reject)
        spec_loads.update(lds)
        spec_reasons.append(
            f"PE {pe.id}: {what} depends on protected load(s) "
            f"{sorted(lds)} — loss of decoupling "
            f'(speculation="auto" runs this AGU speculatively)'
        )

    needed_locals: set[str] = set()
    frontier: list[tuple[str, str]] = []  # (local name, consuming stmt)
    for e, what in agu_exprs:
        ls, lds = expr_deps(e)
        if lds:
            offend(what, lds)
        frontier.extend((name, what) for name in sorted(ls))
    # transitive closure over SetLocal defs within the PE
    setlocals = {
        s.name: s for s, _d in pe.stmts if isinstance(s, ir.SetLocal)
    }
    while frontier:
        name, what = frontier.pop()
        if name in needed_locals:
            continue
        needed_locals.add(name)
        if name in setlocals:
            ls, lds = expr_deps(setlocals[name].value)
            if lds:
                offend(f"AGU local {name!r} (SetLocal feeding {what})", lds)
            frontier.extend(
                (n, what) for n in sorted(ls - needed_locals)
            )

    streamed = sorted(needed_locals & pe.fifo_in)
    if streamed:
        # a FIFO token arrives through the CU's pop path — an AGU
        # address/trip reading it could never run ahead. Raised in both
        # speculation modes: the speculative AGU predicts load ports,
        # not cross-PE streams
        raise LossOfDecoupling(
            f"PE {pe.id}: AGU depends on cross-PE streamed local(s) "
            f"{streamed} — FIFO values cannot feed addresses or trips"
        )

    agu_count = 0
    cu_count = 0
    for s, _d in pe.stmts:
        if isinstance(s, (ir.Load, ir.Store)):
            agu_count += 1  # send_address
            cu_count += 1  # consume_value / produce_value
        elif isinstance(s, ir.SetLocal):
            if s.name in needed_locals:
                agu_count += 1
            # value-side locals always stay in the CU (DCE removes them
            # from the AGU unless address-feeding)
            cu_count += 1

    spec: Optional[SpecInfo] = None
    if spec_loads:
        foreign = sorted(spec_loads - set(pe.mem_ops))
        if foreign:
            # the predicted port must live in this PE: its delivery
            # stream is what resolves mis-speculated epochs — raised in
            # BOTH modes, so "off" never promises an auto that would
            # just re-reject
            raise LossOfDecoupling(
                f"PE {pe.id}: AGU depends on load(s) {foreign} of another "
                f"PE — cross-PE speculation is not supported"
            )
        if speculation == "off":
            # every reason, not just the first: a program can lose
            # decoupling through several expressions at once and the
            # user should see the full repair surface in one round
            raise LossOfDecoupling("; ".join(spec_reasons))
        spec = SpecInfo(
            pe_id=pe.id,
            loads=tuple(sorted(spec_loads)),
            reasons=tuple(spec_reasons),
        )
    return agu_count, cu_count, spec
