"""The paper's core: LoopIR, the DAE/monotonicity/hazard compiler
front-end, AGU trace compilation, and the cycle-level simulation of the
four evaluated systems (STA/LSQ/FUS1/FUS2). Start at
``repro.core.simulator.simulate`` and DESIGN.md §1."""
