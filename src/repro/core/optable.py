"""Reusable per-op compute tables: store bodies factored out of the oracle.

The sequential oracle (``loopir.interpret``) evaluates every store's
value/guard expression scalar-by-scalar while walking the program. A
hardware backend (``kernels/wave_exec``) cannot call back into the
oracle — it must *compute* store values itself from the load values its
own gathers produced. This module compiles each store into exactly that
shape, mirroring the paper's decoupled access/execute split:

  * everything the CU/AGU side can produce without touching protected
    memory — loop variables, ivars, locals, reads of index arrays —
    is **partially evaluated away**: every maximal ``LoadVal``-free
    subtree of the value/guard expression becomes an *environment
    slot*, a per-request operand stream captured once during the trace
    walk (``loopir.interpret``'s ``aux_exprs`` hook),
  * everything downstream of a protected ``LoadVal`` stays symbolic: a
    small closed closure over (dep load streams, env slots, frozen
    read-only arrays) that the backend evaluates *vectorized per wave*,
    with numpy (bit-exact vs the oracle — same elementwise ops in the
    same order) or jax.numpy (``lib="jnp"``; accelerator dtype rules,
    checked to tolerance).

The closure node set is tiny (Const / DepRef / EnvRef / Gather / Bin /
Un) because the IR's expression language is; ``compile_store_tables``
rejects the one genuinely unsupported case — a ``Read`` whose index
depends on a ``LoadVal`` *and* whose array is also a store target (the
closure would need a coherent snapshot mid-execution; Table-1 and the
speculative kernels only gather frozen index/weight arrays this way).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

import numpy as np

from repro.core import loopir as ir


class OpTableError(Exception):
    """A store body the op-table compiler cannot factor (module doc)."""


# ---------------------------------------------------------------------------
# Closure IR (the residue after partial evaluation)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CConst:
    v: float


@dataclasses.dataclass(frozen=True)
class CDep:
    """Value stream of a protected load (aligned via WavePlan dep maps)."""

    load_id: str


@dataclasses.dataclass(frozen=True)
class CEnv:
    """Captured environment slot (LoadVal-free subtree), by slot index."""

    slot: int


@dataclasses.dataclass(frozen=True)
class CGather:
    """Gather from a *frozen* read-only array at a load-dependent index."""

    array: str
    index: "CNode"


@dataclasses.dataclass(frozen=True)
class CBin:
    op: str
    a: "CNode"
    b: "CNode"


@dataclasses.dataclass(frozen=True)
class CUn:
    op: str
    a: "CNode"


CNode = Union[CConst, CDep, CEnv, CGather, CBin, CUn]

# The numpy path reuses the oracle's own op tables (ir.NP_BINOPS /
# ir.NP_UN_FNS) — one source, bit-exactness by construction. The jnp
# counterparts below are the only duplicates; built lazily so core/
# stays importable without jax, and key-checked against the oracle
# tables so a new IR op that was only added in loopir fails loudly
# here instead of surfacing as a KeyError mid-kernel.
_JNP_TABLES: Optional[tuple[dict, dict]] = None


def _jnp_tables():
    global _JNP_TABLES
    if _JNP_TABLES is None:
        import jax.numpy as jnp

        binops = {
            "+": jnp.add, "-": jnp.subtract, "*": jnp.multiply,
            "//": jnp.floor_divide, "%": jnp.mod,
            "min": jnp.minimum, "max": jnp.maximum,
            "<": jnp.less, "<=": jnp.less_equal,
            ">": jnp.greater, ">=": jnp.greater_equal,
            "==": jnp.equal, "!=": jnp.not_equal,
        }
        unfns = {
            "tanh": jnp.tanh, "relu": lambda x: jnp.maximum(x, 0),
            "neg": lambda x: -x, "abs": jnp.abs, "sign": jnp.sign,
            "exp": jnp.exp,
        }
        assert set(binops) == set(ir.NP_BINOPS), (
            "jnp binop table out of sync with loopir.NP_BINOPS: "
            f"{set(binops) ^ set(ir.NP_BINOPS)}"
        )
        assert set(unfns) == set(ir.NP_UN_FNS), (
            "jnp unary table out of sync with loopir.NP_UN_FNS: "
            f"{set(unfns) ^ set(ir.NP_UN_FNS)}"
        )
        _JNP_TABLES = (binops, unfns)
    return _JNP_TABLES


@dataclasses.dataclass
class StoreTable:
    """Compute body of one store op, in backend-executable form.

    ``deps`` are the load ops whose values feed the body; the backend
    supplies one aligned stream per dep (see ``WavePlan.dep_maps``).
    ``env_exprs`` are the captured slots in slot order — the plan
    builder evaluates them through the ``aux_exprs`` interpreter hook
    into ``WavePlan.env`` streams. ``value``/``guard`` are closure
    trees over those two input kinds plus ``frozen_reads`` gathers.
    """

    op_id: str
    array: str
    deps: tuple[str, ...]
    env_exprs: tuple[ir.Expr, ...]
    value: CNode
    guard: Optional[CNode]
    frozen_reads: tuple[str, ...]

    def eval_value(self, deps, env, arrays, n, lib="np"):
        """Vectorized store values for ``n`` requests; ``deps``/``env``
        map to per-request operand arrays already sliced and aligned to
        the same request subset. ``lib="np"`` is the bit-exact path."""
        v = _eval_closure(self.value, deps, env, arrays, lib)
        return _bcast(v, n, lib)

    def eval_guard(self, deps, env, arrays, n, lib="np"):
        """Vectorized §6 valid mask (all-True when unguarded)."""
        if self.guard is None:
            return np.ones(n, dtype=bool)
        m = _eval_closure(self.guard, deps, env, arrays, lib)
        return np.asarray(_bcast(m, n, lib)).astype(bool)


def _bcast(v, n: int, lib: str):
    """Constant-valued bodies evaluate to scalars; stretch to n rows."""
    if np.ndim(v) == 0:
        if lib == "np":
            return np.full(n, v, dtype=np.float64)
        import jax.numpy as jnp

        return jnp.full(n, v)
    return v


def _eval_closure(node: CNode, deps, env, arrays, lib):
    if lib == "np":
        binops, unfns, asarr = ir.NP_BINOPS, ir.NP_UN_FNS, np.asarray
    else:
        binops, unfns = _jnp_tables()
        import jax.numpy as jnp

        asarr = jnp.asarray

    def ev(n):
        if isinstance(n, CConst):
            return n.v
        if isinstance(n, CDep):
            return deps[n.load_id]
        if isinstance(n, CEnv):
            return env[n.slot]
        if isinstance(n, CGather):
            idx = ev(n.index)
            arr = asarr(arrays[n.array])
            # clip: mis-speculated (§6 guard-false) rows may hold garbage
            # indices; their results are masked out by the valid bit
            if lib == "np":
                i = np.clip(np.asarray(idx).astype(np.int64), 0, len(arr) - 1)
                return arr[i]
            import jax.numpy as jnp

            return jnp.take(arr, asarr(idx).astype(int), mode="clip")
        if isinstance(n, CBin):
            return binops[n.op](ev(n.a), ev(n.b))
        if isinstance(n, CUn):
            return unfns[n.op](ev(n.a))
        raise TypeError(f"cannot eval closure node {n!r}")

    with np.errstate(all="ignore"):
        return ev(node)


# ---------------------------------------------------------------------------
# Compilation: partial evaluation of store bodies
# ---------------------------------------------------------------------------


def _has_loadval(e: ir.Expr) -> bool:
    if isinstance(e, ir.LoadVal):
        return True
    if isinstance(e, ir.Bin):
        return _has_loadval(e.a) or _has_loadval(e.b)
    if isinstance(e, ir.Un):
        return _has_loadval(e.a)
    if isinstance(e, ir.Read):
        return _has_loadval(e.index)
    return False


def _has_streamed(e: ir.Expr, streamed: dict) -> bool:
    """Does ``e`` reference a cross-PE streamed local (FIFO pop value)?"""
    if isinstance(e, ir.Local):
        return e.name in streamed
    if isinstance(e, ir.Bin):
        return _has_streamed(e.a, streamed) or _has_streamed(e.b, streamed)
    if isinstance(e, ir.Un):
        return _has_streamed(e.a, streamed)
    if isinstance(e, ir.Read):
        return _has_streamed(e.index, streamed)
    return False


def compile_store_tables(
    program: ir.Program,
    stream_deps: Optional[dict[str, dict[str, str]]] = None,
) -> dict[str, StoreTable]:
    """One ``StoreTable`` per store op of ``program`` (keyed by op id).

    Partial evaluation rule: a maximal ``LoadVal``-free subtree becomes
    an env slot (deduplicated structurally); ``Const`` leaves inline;
    everything containing a ``LoadVal`` compiles to closure nodes.
    Raises ``OpTableError`` for a load-dependent ``Read`` of an array
    the program also stores to (no frozen snapshot exists).

    ``stream_deps`` maps a store op id to ``{local name: pop op id}``
    for cross-PE streamed locals (DESIGN.md §11): a ``Local`` in that
    map is *dynamic* — it compiles to a ``CDep`` on the pseudo pop op
    instead of an env slot, so the store's value flows through the
    FIFO slot in memory and the wave plan orders the store after the
    pop (the producer-before-consumer dep edge ``validate_plan``
    asserts per edge).
    """
    stream_deps = stream_deps or {}
    stored_arrays = {
        op.array for op, _ in program.mem_ops() if op.is_store
    }
    tables: dict[str, StoreTable] = {}
    for op, _path in program.mem_ops():
        if not op.is_store:
            continue
        env_exprs: list[ir.Expr] = []
        env_index: dict[ir.Expr, int] = {}
        deps: list[str] = []
        frozen: list[str] = []
        streamed = stream_deps.get(op.id, {})

        def slot(e: ir.Expr) -> CNode:
            if isinstance(e, ir.Const):
                return CConst(e.v)
            k = env_index.get(e)
            if k is None:
                k = len(env_exprs)
                env_index[e] = k
                env_exprs.append(e)
            return CEnv(k)

        def comp(e: ir.Expr) -> CNode:
            if not (_has_loadval(e) or _has_streamed(e, streamed)):
                return slot(e)
            if isinstance(e, ir.LoadVal):
                if e.load_id not in deps:
                    deps.append(e.load_id)
                return CDep(e.load_id)
            if isinstance(e, ir.Local):
                pop_op = streamed[e.name]
                if pop_op not in deps:
                    deps.append(pop_op)
                return CDep(pop_op)
            if isinstance(e, ir.Bin):
                return CBin(e.op, comp(e.a), comp(e.b))
            if isinstance(e, ir.Un):
                return CUn(e.op, comp(e.a))
            if isinstance(e, ir.Read):
                # index depends on a load value: the gather must run in
                # the backend, against a frozen array
                if e.array in stored_arrays:
                    raise OpTableError(
                        f"store '{op.id}': Read('{e.array}') has a "
                        f"load-dependent index but '{e.array}' is also a "
                        f"store target — no frozen snapshot to gather from"
                    )
                if e.array not in frozen:
                    frozen.append(e.array)
                return CGather(e.array, comp(e.index))
            raise TypeError(f"cannot compile {e!r}")  # pragma: no cover

        value = comp(op.value)
        guard = comp(op.guard) if op.guard is not None else None
        tables[op.id] = StoreTable(
            op_id=op.id,
            array=op.array,
            deps=tuple(deps),
            env_exprs=tuple(env_exprs),
            value=value,
            guard=guard,
            frozen_reads=tuple(frozen),
        )
    return tables
