"""Hazard pair enumeration, check synthesis, and pruning (paper §5).

For every protected base pointer (array with at least one store and a
second access that may conflict), the compiler enumerates *hazard
pairs* (dst checks src):

  * RAW: load  gated by store frontier,
  * WAR: store gated by load frontier,
  * WAW: store gated by store frontier,
  * loads never check loads (§5.4.1),
  * pairs exist in the forward direction (src topologically before dst)
    and — when the two ops share a loop — the wrap-around direction
    (dst before src, conflicting across the loop backedge).

Each pair carries the *statically configured* check (§4 item 3, §5.2-5.4):

    HazardSafetyCheck =
        ProgramOrderSafetyCheck
        || (req.addr_dst < frontier.addr_src && NoAddressResetCheck)
        || (NoDependence && NoAddressResetCheck)          # §5.6, intra-PE RAW

    ProgramOrderSafetyCheck =                              # only if k > 0
        req.sched_dst[k] (<=|<) ack.sched_src[k]
        || (req.sched_dst[k] (<=|<) req.sched_src[k] && noPendingAck_src)

    NoAddressResetCheck =                                  # §5.3
        AND-reduce(lastIter_src[j] for j in nonmono, j > k)
        && (req.sched_dst[l] == ack.sched_src[l] + delta   # deepest nonmono l <= k
            if such l exists else true)

The address-frontier disjunct is only synthesized when the *source*'s
innermost loop is monotonic (§3.1 — the paper's core requirement); for
unanalyzable sources the pair degrades to program order + completion
sentinels, which is always sound.

Pruning (§5.4.1):
  * WAR pairs where the written value depends on the read value [39],
  * transitive pruning: pair (a ⇐ c) is covered by kept pairs (a ⇐ b)
    and (b ⇐ c) for some b strictly between c and a in topological
    order, provided both links constrain at least the shared depth of
    (a, c). With store-to-load forwarding enabled, a RAW link (b=load ⇐
    c=store) no longer implies the store's ACK frontier advanced (§5.5),
    so such links are excluded from chains that cover WAW pairs.

Pairs are processed in increasing topological distance so chain links
are always final (never themselves pruned later).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import dae as daelib
from repro.core import loopir as ir
from repro.core import monotonic as mono


@dataclasses.dataclass(frozen=True)
class HazardPair:
    dst: str  # the op whose next request is gated
    src: str  # the dependency source whose frontier is consulted
    kind: str  # 'RAW' | 'WAR' | 'WAW'
    array: str
    shared_depth: int  # k; 0 = no shared loops
    dst_before_src: bool  # topological order; True -> comparator <=, delta=1
    wraparound: bool  # pair exists only via a loop backedge
    same_pe: bool
    # --- synthesized check configuration ---
    use_frontier: bool  # src innermost-monotonic -> addr compare allowed
    l_depth: Optional[int]  # deepest non-monotonic src depth <= k
    lastiter_depths: tuple[int, ...]  # non-monotonic src depths > k
    nodependence: bool  # §5.6 term synthesized (intra-PE RAW)

    @property
    def comparator(self) -> str:
        return "<=" if self.dst_before_src else "<"

    @property
    def delta(self) -> int:
        """δ in the No-Address-Reset equality (§5.3).

        δ=1 ("frontier may be one l-epoch behind") is only sound when the
        l-loop IS the innermost shared loop (l == k): then all src
        requests of the *new* epoch come after the dst request in program
        order, so the (ack, req) range stays inside the old epoch. When
        l < k, src requests from the new epoch can precede the dst
        request (the k-loop advances many times per l-epoch), so the
        frontier must already be in the *same* epoch: δ=0.
        """
        return 1 if (self.dst_before_src and self.l_depth == self.shared_depth) else 0


@dataclasses.dataclass
class HazardPlan:
    pairs: list[HazardPair]
    pruned: list[tuple[HazardPair, str]]  # (pair, reason)
    protected_arrays: list[str]

    def pairs_for_dst(self, op_id: str) -> list[HazardPair]:
        return self.by_dst().get(op_id, [])

    def by_dst(self) -> dict[str, list[HazardPair]]:
        """Kept pairs grouped by gated op, preserving plan order (the
        order both engines consult frontiers and resolve forward ties)."""
        out: dict[str, list[HazardPair]] = {}
        for p in self.pairs:
            out.setdefault(p.dst, []).append(p)
        return out

    def summary(self) -> str:
        total = len(self.pairs) + len(self.pruned)
        lines = [
            f"hazard pairs: {total} enumerated, {len(self.pruned)} pruned, "
            f"{len(self.pairs)} kept"
        ]
        for p in self.pairs:
            lines.append(
                f"  {p.dst} checks {p.src} [{p.kind}{'/wrap' if p.wraparound else ''}] "
                f"k={p.shared_depth} cmp={p.comparator} frontier={p.use_frontier} "
                f"l={p.l_depth} lastiter={list(p.lastiter_depths)} "
                f"nodep={p.nodependence}"
            )
        return "\n".join(lines)


def _value_depends_on_load(store: ir.Store, load_id: str) -> bool:
    _, loads = daelib.expr_deps(store.value)
    if store.guard is not None:
        loads |= daelib.expr_deps(store.guard)[1]
    return load_id in loads


def build_plan(
    program: ir.Program,
    dae: daelib.DAEResult,
    infos: dict[str, mono.AddressInfo],
    forwarding: bool = False,
    static_prune: bool = False,
) -> HazardPlan:
    """Enumerate, synthesize and prune the hazard plan (module doc).

    ``static_prune=True`` additionally drops pairs the symbolic
    dependence certifier (``analysis/deps.py``) proves *forced-pass*:
    their runtime HazardSafetyCheck is statically a tautology (the §5.6
    NoDependence disjunct is true at every evaluation and no reset
    terms exist), so removal is provably timing-invisible — cycles and
    arrays stay bit-identical (tested across every registered kernel in
    tests/test_deps.py). Dropped pairs land in ``plan.pruned`` with a
    ``"static: ..."`` reason, so ``Compiled.all_pairs`` (and hence STA)
    is unchanged. Forced-pass pairs are never used as transitive chain
    links (NoDependence links are excluded), so the kept set equals the
    baseline kept set minus exactly the dropped pairs."""
    ops = program.mem_ops()
    topo = program.op_index()
    by_array: dict[str, list] = {}
    for op, path in ops:
        by_array.setdefault(op.array, []).append((op, path))

    protected = [
        arr
        for arr, lst in by_array.items()
        if any(o.is_store for o, _ in lst) and len(lst) >= 2
    ]

    enumerated: list[HazardPair] = []
    for arr in protected:
        lst = by_array[arr]
        for op_a, path_a in lst:  # dst
            for op_b, path_b in lst:  # src
                if op_a.id == op_b.id:
                    continue
                if not (op_a.is_store or op_b.is_store):
                    continue  # loads never check loads
                k = dae.shared_depth(op_a.id, op_b.id, program)
                a_before_b = topo[op_a.id] < topo[op_b.id]
                wrap = a_before_b  # src comes later: only backedge conflicts
                if wrap and k == 0:
                    continue  # no shared loop -> src can never precede dst
                kind = (
                    "RAW"
                    if not op_a.is_store
                    else ("WAW" if op_b.is_store else "WAR")
                )
                info_b = infos[op_b.id]
                nonmono = info_b.non_monotonic
                l_candidates = [d for d in nonmono if d <= k]
                l_depth = max(l_candidates) if l_candidates else None
                lastiter_depths = tuple(sorted(d for d in nonmono if d > k))
                same_pe = dae.op_to_pe[op_a.id] == dae.op_to_pe[op_b.id]
                # §5.6: synthesized only for intra-loop RAW where the
                # source (store) stream is innermost-monotonic — the
                # NoDependence argument relies on monotonicity.
                nodep = (
                    kind == "RAW"
                    and same_pe
                    and len(path_a) == len(path_b) == k
                    and info_b.innermost_monotonic
                )
                enumerated.append(
                    HazardPair(
                        dst=op_a.id,
                        src=op_b.id,
                        kind=kind,
                        array=arr,
                        shared_depth=k,
                        dst_before_src=a_before_b,
                        wraparound=wrap,
                        same_pe=same_pe,
                        use_frontier=info_b.innermost_monotonic,
                        l_depth=l_depth,
                        lastiter_depths=lastiter_depths,
                        nodependence=nodep,
                    )
                )

    # ---- pruning ----------------------------------------------------------
    pruned: list[tuple[HazardPair, str]] = []
    kept: list[HazardPair] = []

    # rule 0 (opt-in): certifier-proven forced-pass pairs (DESIGN.md §12)
    if static_prune and enumerated:
        from repro.analysis import deps as depslib

        verdicts = depslib.certify_pairs(program, enumerated)
        remaining: list[HazardPair] = []
        for p in enumerated:
            v = verdicts[(p.dst, p.src)]
            if v.forced_pass:
                pruned.append((p, f"static: {v.evidence}"))
            else:
                remaining.append(p)
        enumerated = remaining

    # rule 1: WAR where the written value depends on the read value [39]
    stage1: list[HazardPair] = []
    for p in enumerated:
        if p.kind == "WAR" and not p.wraparound:
            store, _ = program.find_op(p.dst)
            if _value_depends_on_load(store, p.src):
                pruned.append((p, "WAR write-depends-on-read"))
                continue
        stage1.append(p)

    # rule 2: transitive pruning, shortest topological distance first so
    # chain links are final when consulted
    def dist(p: HazardPair) -> int:
        return abs(topo[p.dst] - topo[p.src])

    stage1.sort(key=lambda p: (dist(p), topo[p.dst], topo[p.src]))
    kept_set: set[tuple[str, str]] = set()
    kept_by_edge: dict[tuple[str, str], HazardPair] = {}
    for p in stage1:
        middle = _find_chain(p, kept_by_edge, topo, forwarding)
        if middle is not None:
            pruned.append((p, f"transitive via {middle}"))
            continue
        kept.append(p)
        kept_set.add((p.dst, p.src))
        kept_by_edge[(p.dst, p.src)] = p

    kept.sort(key=lambda p: (topo[p.dst], topo[p.src]))
    return HazardPlan(pairs=kept, pruned=pruned, protected_arrays=protected)


def _find_chain(
    p: HazardPair,
    kept: dict[tuple[str, str], HazardPair],
    topo: dict[str, int],
    forwarding: bool,
) -> Optional[str]:
    """A middle op b such that kept pairs (dst ⇐ b) and (b ⇐ src) cover p.

    Covering conditions:
      * **backedge conservation**: the number of loop backedges the chain
        traverses must equal the pair's — wrap(link1) + wrap(link2) ==
        wrap(p). (A wrap pair relates dst@t+1 to src@t; two wrap links
        would relate dst@t+1 to src@t-1 — a different, weaker property.
        This also pins b's topological position: for forward pairs b lies
        strictly between src and dst, for wrap pairs strictly outside.)
      * both links constrain at least p.shared_depth,
      * neither link synthesizes the §5.6 NoDependence shortcut — a
        NoDependence admission does not certify any source progress, so
        such links cannot anchor transitivity,
      * under forwarding, a (load ⇐ store) link does not imply the store
        ACK advanced, so it cannot support covering a WAW pair (§5.5).
    """
    for (d1, b), link1 in kept.items():
        if d1 != p.dst or b == p.src or link1.array != p.array:
            continue
        link2 = kept.get((b, p.src))
        if link2 is None or link2.array != p.array:
            continue
        if link1.wraparound + link2.wraparound != p.wraparound:
            continue
        if link1.shared_depth < p.shared_depth or link2.shared_depth < p.shared_depth:
            continue
        if link1.nodependence or link2.nodependence:
            continue
        if forwarding and p.kind == "WAW" and link2.kind == "RAW":
            continue  # §5.5: forwarded load ACKs don't imply store ACKs
        return b
    return None
