"""LoopIR: a small loop-forest IR for irregular streaming programs.

This is the input language of the dynamic-loop-fusion compiler (the
paper's benchmarks in §7.2 are all expressible in it). Design mirrors
what the paper's passes see in LLVM IR:

  * a *forest* of loop nests executed in program (topological) order,
  * explicit induction variables (``IVar``) whose add/mul updates are
    exactly what SCEV turns into chains of recurrences,
  * memory operations (``Load``/``Store``) against named arrays; arrays
    read through ``Read`` expressions are *unprotected* read-only data
    (index arrays such as CSR ``row_ptr`` — the paper protects one base
    pointer per DU, read-only inputs need no protection),
  * optional ``guard`` predicates on stores (the §6 control-flow /
    speculation case),
  * user monotonicity assertions for data-dependent addresses (§3.3).

The module also provides the **sequential oracle**: a reference
interpreter whose final memory state defines correctness for every
executor (cycle simulator, fused JAX executor, Pallas kernels).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Union

import numpy as np

from repro.core import cr as crlib

# ---------------------------------------------------------------------------
# Expressions
# ---------------------------------------------------------------------------


class Expr:
    def __add__(self, o):
        return Bin("+", self, wrap(o))

    def __radd__(self, o):
        return Bin("+", wrap(o), self)

    def __sub__(self, o):
        return Bin("-", self, wrap(o))

    def __rsub__(self, o):
        return Bin("-", wrap(o), self)

    def __mul__(self, o):
        return Bin("*", self, wrap(o))

    def __rmul__(self, o):
        return Bin("*", wrap(o), self)

    def __floordiv__(self, o):
        return Bin("//", self, wrap(o))

    def __mod__(self, o):
        return Bin("%", self, wrap(o))

    def __lt__(self, o):
        return Bin("<", self, wrap(o))

    def __le__(self, o):
        return Bin("<=", self, wrap(o))

    def __gt__(self, o):
        return Bin(">", self, wrap(o))

    def __ge__(self, o):
        return Bin(">=", self, wrap(o))

    def eq(self, o):
        return Bin("==", self, wrap(o))

    def ne(self, o):
        return Bin("!=", self, wrap(o))


@dataclasses.dataclass(frozen=True)
class Const(Expr):
    v: float


@dataclasses.dataclass(frozen=True)
class Param(Expr):
    """Runtime scalar parameter, with a conservative range for analysis."""

    name: str
    lo: int = 0
    hi: int = crlib.INF


@dataclasses.dataclass(frozen=True)
class Var(Expr):
    """Induction variable of an enclosing loop (the canonical 0,1,2,...
    counter) or a declared auxiliary IVar."""

    name: str


@dataclasses.dataclass(frozen=True)
class Local(Expr):
    """A loop-carried scalar local (defined by SetLocal)."""

    name: str


@dataclasses.dataclass(frozen=True)
class Read(Expr):
    """Read-only (unprotected) array read, e.g. CSR row_ptr/col_idx."""

    array: str
    index: Expr
    # optional user range assertion for the values read (helps analysis)
    lo: int = -crlib.INF
    hi: int = crlib.INF


@dataclasses.dataclass(frozen=True)
class LoadVal(Expr):
    """Value of the protected Load statement with the given id, in the
    current iteration."""

    load_id: str


@dataclasses.dataclass(frozen=True)
class Bin(Expr):
    op: str
    a: Expr
    b: Expr


@dataclasses.dataclass(frozen=True)
class Un(Expr):
    op: str  # tanh | relu | neg | abs | sign | exp
    a: Expr


def wrap(v: Union[int, float, Expr]) -> Expr:
    return v if isinstance(v, Expr) else Const(v)


_UN_FNS: dict[str, Callable] = {
    "tanh": np.tanh,
    "relu": lambda x: np.maximum(x, 0),
    "neg": lambda x: -x,
    "abs": np.abs,
    "sign": np.sign,
    "exp": np.exp,
}

# public alias: the numpy ufuncs above are already elementwise, so the
# oracle's scalar table IS the vectorized table (core/optable's
# closures use it directly — one source, nothing to keep in sync)
NP_UN_FNS: dict[str, Callable] = _UN_FNS


# vectorized counterparts of _binop, used by the affine trace compiler
# (core/affine.py); numpy's //, % match Python's semantics on ints and
# floats, min/max become elementwise minimum/maximum. Keep the two
# tables in sync: every op here must behave elementwise exactly like
# _binop does on scalars.
NP_BINOPS: dict[str, Callable] = {
    "+": np.add,
    "-": np.subtract,
    "*": np.multiply,
    "//": np.floor_divide,
    "%": np.mod,
    "min": np.minimum,
    "max": np.maximum,
    "<": np.less,
    "<=": np.less_equal,
    ">": np.greater,
    ">=": np.greater_equal,
    "==": np.equal,
    "!=": np.not_equal,
}


def _binop(op: str, a, b):
    if op == "+":
        return a + b
    if op == "-":
        return a - b
    if op == "*":
        return a * b
    if op == "//":
        return a // b
    if op == "%":
        return a % b
    if op == "min":
        return min(a, b)
    if op == "max":
        return max(a, b)
    if op == "<":
        return a < b
    if op == "<=":
        return a <= b
    if op == ">":
        return a > b
    if op == ">=":
        return a >= b
    if op == "==":
        return a == b
    if op == "!=":
        return a != b
    raise ValueError(f"unknown binop {op}")


# ---------------------------------------------------------------------------
# Statements
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MonotonicHint:
    """User assertion (§3.3): the address is monotonically non-decreasing
    in the innermost loop. ``non_monotonic_outer`` lists 1-indexed outer
    depths that reset the address (None = assume *all* outer depths are
    non-monotonic — maximally conservative)."""

    innermost_monotonic: bool = True
    non_monotonic_outer: Optional[frozenset[int]] = None


@dataclasses.dataclass(frozen=True)
class Load:
    id: str
    array: str
    addr: Expr
    hint: Optional[MonotonicHint] = None

    @property
    def is_store(self) -> bool:
        return False


@dataclasses.dataclass(frozen=True)
class Store:
    id: str
    array: str
    addr: Expr
    value: Expr
    guard: Optional[Expr] = None  # §6: store under an if-condition
    hint: Optional[MonotonicHint] = None

    @property
    def is_store(self) -> bool:
        return True


@dataclasses.dataclass(frozen=True)
class IVar:
    """Auxiliary induction variable of a loop: ``name = init`` before the
    loop, ``name = name (op) step`` at the end of each iteration. This is
    the source-level origin of non-affine CRs, e.g. FFT's stride *= 2
    gives the paper's {2, ×, 2} recurrence."""

    name: str
    init: Expr
    op: str  # '+' or '*'
    step: Expr


@dataclasses.dataclass(frozen=True)
class SetLocal:
    """Assign a loop-carried scalar local (reduction accumulators etc.)."""

    name: str
    value: Expr


@dataclasses.dataclass(frozen=True)
class Loop:
    var: str
    trip: Expr
    body: tuple  # of Load | Store | SetLocal | Loop
    ivars: tuple[IVar, ...] = ()
    # False models loops whose exit predicate cannot be computed one
    # iteration in advance (paper §4.2(3): lastIter hint degrades to 0).
    predictable: bool = True

    def __post_init__(self):
        object.__setattr__(self, "body", tuple(self.body))
        object.__setattr__(self, "ivars", tuple(self.ivars))


Stmt = Union[Load, Store, SetLocal, Loop]


@dataclasses.dataclass(frozen=True)
class Program:
    name: str
    loops: tuple[Loop, ...]  # the forest, in program order
    # arrays written/read via protected Load/Store and Read
    params: tuple[str, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "loops", tuple(self.loops))
        object.__setattr__(self, "params", tuple(self.params))

    # -- structural helpers -------------------------------------------------

    def mem_ops(self) -> list[tuple[Union[Load, Store], tuple[Loop, ...]]]:
        """All memory ops in topological (program) order, each with its
        enclosing loop path (outermost first)."""
        out = []

        def walk(stmts, path):
            for s in stmts:
                if isinstance(s, Loop):
                    walk(s.body, path + (s,))
                elif isinstance(s, (Load, Store)):
                    out.append((s, path))

        walk(self.loops, ())
        return out

    def op_index(self) -> dict[str, int]:
        """Topological order index for each memory op id."""
        return {op.id: i for i, (op, _) in enumerate(self.mem_ops())}

    def find_op(self, op_id: str) -> tuple[Union[Load, Store], tuple[Loop, ...]]:
        for op, path in self.mem_ops():
            if op.id == op_id:
                return op, path
        raise KeyError(op_id)

    def fingerprint(self) -> str:
        """Stable structural hash of the program (hex sha256).

        Canonical recursive encoding of the IR forest — statement kinds,
        op ids, expression trees, trips, ivars, guards, hints — so two
        structurally identical programs hash equal across processes and
        sessions (``repr``/``hash`` of nested dataclasses are not stable
        enough to key an on-disk cache). Array *contents* and parameter
        *values* are deliberately excluded: the DSE result cache
        (``repro.dse.cache``) hashes those separately.
        """
        import hashlib

        h = hashlib.sha256()

        def put(x):
            h.update(repr(x).encode())
            h.update(b"\x00")

        def enc(node):
            if node is None or isinstance(node, (str, int, float, bool)):
                put(node)
            elif isinstance(node, frozenset):
                put("{")
                for x in sorted(node):
                    enc(x)
                put("}")
            elif isinstance(node, (tuple, list)):
                put("(")
                for x in node:
                    enc(x)
                put(")")
            elif dataclasses.is_dataclass(node):
                put(type(node).__name__)
                for f in dataclasses.fields(node):
                    enc(getattr(node, f.name))
            else:  # pragma: no cover
                raise TypeError(f"cannot fingerprint {node!r}")

        enc(self)
        return h.hexdigest()

    def static_positions(self) -> tuple[dict[int, int], dict[str, int]]:
        """(loop object id -> index in parent body, op id -> index in its
        body). Together with per-depth counters these give a global
        lexicographic program order — the polyhedral 2d+1 schedule."""
        loop_pos: dict[int, int] = {}
        op_pos: dict[str, int] = {}

        def walk(stmts):
            for idx, s in enumerate(stmts):
                if isinstance(s, Loop):
                    loop_pos[id(s)] = idx
                    walk(s.body)
                elif isinstance(s, (Load, Store)):
                    op_pos[s.id] = idx

        walk(self.loops)
        return loop_pos, op_pos


# ---------------------------------------------------------------------------
# Sequential oracle interpreter
# ---------------------------------------------------------------------------


class UnavailableLoadValue(KeyError):
    """A ``LoadVal`` consumed before its ``Load`` produced a value —
    e.g. a trip reading a load of the loop it bounds. Distinguished
    from other ``KeyError``s (typo'd arrays/params) so the speculative
    AGU (``core/speculate.py``) converts only genuine
    use-before-availability into its auto-reject diagnostic."""


class _Env:
    """Chained mutable scopes for loop vars / ivars / locals."""

    def __init__(self, parent: Optional["_Env"] = None):
        self.parent = parent
        self.vals: dict[str, float] = {}

    def get(self, name: str):
        e = self
        while e is not None:
            if name in e.vals:
                return e.vals[name]
            e = e.parent
        raise KeyError(name)

    def set_existing(self, name: str, v) -> bool:
        e = self
        while e is not None:
            if name in e.vals:
                e.vals[name] = v
                return True
            e = e.parent
        return False

    def define(self, name: str, v):
        self.vals[name] = v


def _eval(e: Expr, env: _Env, arrays, params, loadvals) -> float:
    if isinstance(e, Const):
        return e.v
    if isinstance(e, Param):
        return params[e.name]
    if isinstance(e, (Var, Local)):
        return env.get(e.name)
    if isinstance(e, Read):
        idx = int(_eval(e.index, env, arrays, params, loadvals))
        return arrays[e.array][idx]
    if isinstance(e, LoadVal):
        try:
            return loadvals[e.load_id]
        except KeyError:
            raise UnavailableLoadValue(e.load_id) from None
    if isinstance(e, Bin):
        return _binop(
            e.op,
            _eval(e.a, env, arrays, params, loadvals),
            _eval(e.b, env, arrays, params, loadvals),
        )
    if isinstance(e, Un):
        return _UN_FNS[e.op](_eval(e.a, env, arrays, params, loadvals))
    raise TypeError(f"cannot eval {e!r}")


def interpret(
    program: Program,
    arrays: dict[str, np.ndarray],
    params: Optional[dict[str, int]] = None,
    trace_hook: Optional[Callable] = None,
    aux_exprs: Optional[dict[str, tuple]] = None,
    aux_hook: Optional[Callable] = None,
    loop_hook: Optional[Callable] = None,
) -> dict[str, np.ndarray]:
    """Run the program sequentially; returns the final array state.

    This is THE semantics. Every executor must reproduce it bit-for-bit
    (modulo float associativity, which we avoid by executing in the same
    per-element order).

    ``trace_hook(op_id, addr, is_store, valid, value)`` is called for
    every memory operation *in program order*, including mis-speculated
    stores (guard false -> valid=False, value=None) — the request exists
    in the decoupled machine even when the effect doesn't (§6).

    ``aux_exprs`` maps an op id to a tuple of extra expressions; when
    that op fires, each is evaluated in the op's environment and the
    results are passed to ``aux_hook(op_id, values_tuple)`` *before* the
    trace hook — for guarded stores the aux values are produced even
    when the guard fails (the CU-side operand stream exists regardless
    of the §6 valid bit). This is how ``core/optable`` captures the
    environment slots of its partially-evaluated compute bodies without
    leaking memory (LoadVal) values out of the oracle.

    ``loop_hook(loop, phase, reader)`` is called at every loop
    *instance* boundary — ``phase="enter"`` before the instance's
    ivars/trip are evaluated, ``phase="exit"`` after its last iteration
    (a zero-trip instance fires both) — with ``reader(name)`` exposing
    the enclosing environment's locals. This is how the FIFO token
    protocol (``core/fifo.py``, DESIGN.md §11) observes the
    one-token-per-leaf-instance push/pop stream without re-deriving
    loop structure.

    Load values are visible downstream of their ``Load`` within the
    enclosing body *and* inside nested loops of that body — including
    loop trip counts and ivar updates. Load-dependent trips (the §6
    speculation workloads, ``core/speculate.py``) are therefore plain
    programs to the oracle; only the decoupled machine needs the
    speculative AGU to run them.
    """
    params = params or {}
    arrays = {k: np.array(v, copy=True) for k, v in arrays.items()}

    def run_aux(op_id, env, loadvals, guard_ok=True):
        # guard-false rows (§6) still need an aux row for per-op
        # ordinal alignment, but the guard may be the very bounds check
        # that makes the value operands evaluable — evaluate those
        # defensively and emit NaN placeholders (the backend masks the
        # whole row by its recomputed valid bit)
        if aux_exprs is not None and op_id in aux_exprs:
            vals = []
            for e in aux_exprs[op_id]:
                if guard_ok:
                    vals.append(_eval(e, env, arrays, params, loadvals))
                else:
                    try:
                        vals.append(_eval(e, env, arrays, params, loadvals))
                    except Exception:
                        vals.append(np.nan)
            aux_hook(op_id, tuple(vals))

    def run_body(stmts: Sequence[Stmt], env: _Env, outer_loadvals):
        # chained visibility: loads of enclosing iterations stay readable
        loadvals: dict[str, float] = dict(outer_loadvals)
        for s in stmts:
            if isinstance(s, Load):
                a = int(_eval(s.addr, env, arrays, params, loadvals))
                v = arrays[s.array][a]
                run_aux(s.id, env, loadvals)
                if trace_hook is not None:
                    trace_hook(s.id, a, False, True, float(v))
                loadvals[s.id] = v
            elif isinstance(s, Store):
                a = int(_eval(s.addr, env, arrays, params, loadvals))
                guard_ok = s.guard is None or _eval(
                    s.guard, env, arrays, params, loadvals
                )
                run_aux(s.id, env, loadvals, guard_ok=guard_ok)
                if not guard_ok:
                    if trace_hook is not None:
                        trace_hook(s.id, a, True, False, None)
                    continue
                v = _eval(s.value, env, arrays, params, loadvals)
                if trace_hook is not None:
                    trace_hook(s.id, a, True, True, float(v))
                arrays[s.array][a] = v
            elif isinstance(s, SetLocal):
                v = _eval(s.value, env, arrays, params, loadvals)
                if not env.set_existing(s.name, v):
                    env.define(s.name, v)
            elif isinstance(s, Loop):
                run_loop(s, env, loadvals)
            else:
                raise TypeError(f"unknown stmt {s!r}")

    def run_loop(loop: Loop, env: _Env, loadvals):
        if loop_hook is not None:
            loop_hook(loop, "enter", env.get)
        outer = _Env(env)
        for iv in loop.ivars:
            outer.define(iv.name, _eval(iv.init, env, arrays, params, loadvals))
        trip = int(_eval(loop.trip, env, arrays, params, loadvals))
        for i in range(trip):
            inner = _Env(outer)
            inner.define(loop.var, i)
            run_body(loop.body, inner, loadvals)
            for iv in loop.ivars:
                cur = outer.get(iv.name)
                step = _eval(iv.step, inner, arrays, params, loadvals)
                outer.vals[iv.name] = cur + step if iv.op == "+" else cur * step
        if loop_hook is not None:
            loop_hook(loop, "exit", env.get)
        return

    top = _Env()
    for lp in program.loops:
        run_loop(lp, top, {})
    return arrays
