"""The paper's evaluation benchmarks (§7.2) expressed in LoopIR.

Table 1 lists nine kernels (the text says "ten benchmarks"; the table
has nine rows — we implement the nine of Table 1):

  RAWloop / WARloop / WAWloop — two sibling loops, one access each,
      forming the named cross-loop dependency (theoretical-speedup
      microbenchmarks),
  bnn        — sparse binarized NN layer: two loops with data-dependent
      CSR accesses, user-asserted monotonic (§3.3),
  pagerank   — CSR graph iteration; two regular loops separated by the
      irregular loop; wrap-around dependencies across outer iterations,
  fft        — stage loop with multiplicative-IVar (non-affine,
      monotonic) strides; middle loop unrolled by 2 into sibling nests,
  matpower   — sparse matrix power, outer loop unrolled by 2 into two
      chained SpMV nests,
  hist+add   — two histogram loops (data-dependent, *non*-monotonic
      stores) + an addition loop; STA can fuse the two histograms,
  tanh+spmv  — tanh with a store under an if-condition (§6 speculation)
      feeding a sorted-COO SpMV.

Each entry provides ``make(scale)`` returning (Program, arrays, params).
Sizes scale linearly so tests run tiny and benchmarks run larger.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.loopir import (
    Bin,
    Const,
    IVar,
    Load,
    LoadVal,
    Local,
    Loop,
    MonotonicHint,
    Param,
    Program,
    Read,
    SetLocal,
    Store,
    Un,
    Var,
)

V = Var
R = Read


@dataclasses.dataclass
class Bench:
    name: str
    make: Callable[[int], tuple[Program, dict[str, np.ndarray], dict[str, int]]]
    complexity: str
    default_scale: int
    # True for kernels whose AGU depends on protected load values: they
    # run only under simulate(speculation="auto") (DESIGN.md §10); the
    # DSE result identity folds the speculation axis for the rest
    speculative: bool = False
    # True for kernels that communicate scalars between PEs over bounded
    # cross-PE FIFO edges (core/fifo, DESIGN.md §11) — the streaming
    # benchmark set (benchmarks/bench_stream.py, fifo_depth DSE axis)
    streaming: bool = False


REGISTRY: dict[str, Bench] = {}


def _register(name, complexity, default_scale, speculative=False,
              streaming=False):
    def deco(fn):
        REGISTRY[name] = Bench(
            name, fn, complexity, default_scale, speculative, streaming
        )
        return fn

    return deco


# ---------------------------------------------------------------------------
# RAW / WAR / WAW microbenchmarks
# ---------------------------------------------------------------------------


@_register("RAWloop", "O(n)", 4000)
def raw_loop(scale: int):
    n = scale
    prog = Program(
        name="RAWloop",
        loops=(
            Loop("i", Param("n", 0, n), (
                Store("st_a", "A", V("i"), R("d0", V("i")) * 2.0),
            )),
            Loop("j", Param("n", 0, n), (
                Load("ld_a", "A", V("j")),
                Store("st_b", "B", V("j"), LoadVal("ld_a") + 1.0),
            )),
        ),
        params=("n",),
    )
    rng = np.random.default_rng(0)
    arrays = {
        "A": np.zeros(n, dtype=np.float64),
        "B": np.zeros(n, dtype=np.float64),
        "d0": rng.standard_normal(n),
    }
    return prog, arrays, {"n": n}


@_register("WARloop", "O(n)", 4000)
def war_loop(scale: int):
    n = scale
    prog = Program(
        name="WARloop",
        loops=(
            Loop("i", Param("n", 0, n), (
                Load("ld_a", "A", V("i")),
                Store("st_b", "B", V("i"), LoadVal("ld_a") * 2.0),
            )),
            Loop("j", Param("n", 0, n), (
                Store("st_a", "A", V("j"), R("d0", V("j"))),
            )),
        ),
        params=("n",),
    )
    rng = np.random.default_rng(1)
    arrays = {
        "A": rng.standard_normal(n),
        "B": np.zeros(n, dtype=np.float64),
        "d0": rng.standard_normal(n),
    }
    return prog, arrays, {"n": n}


@_register("WAWloop", "O(n)", 4000)
def waw_loop(scale: int):
    n = scale
    prog = Program(
        name="WAWloop",
        loops=(
            Loop("i", Param("n", 0, n), (
                Store("st_0", "A", V("i"), R("d0", V("i"))),
            )),
            Loop("j", Param("n", 0, n), (
                Store("st_1", "A", V("j"), R("d1", V("j")) + 0.5),
            )),
        ),
        params=("n",),
    )
    rng = np.random.default_rng(2)
    arrays = {
        "A": np.zeros(n, dtype=np.float64),
        "d0": rng.standard_normal(n),
        "d1": rng.standard_normal(n),
    }
    return prog, arrays, {"n": n}


# ---------------------------------------------------------------------------
# bnn: sparse binarized NN layer — data-dependent monotonic accesses
# ---------------------------------------------------------------------------


@_register("bnn", "O(n^2)", 64)
def bnn(scale: int):
    # layer 1 scatters activations through a sorted sparse index set;
    # layer 2 gathers them through another sorted index set. Both
    # data-dependent — static fusion is impossible; the programmer
    # asserts per-row monotonicity (§3.3).
    rows, width = scale, scale
    rng = np.random.default_rng(3)
    nnz_per_row = max(2, width // 4)

    def sorted_rows(nrows):
        rp = [0]
        idx = []
        for _ in range(nrows):
            cols = np.sort(
                rng.choice(width, size=nnz_per_row, replace=False)
            )
            idx.extend(cols.tolist())
            rp.append(len(idx))
        return np.array(rp, dtype=np.int64), np.array(idx, dtype=np.int64)

    rp1, idx1 = sorted_rows(rows)
    rp2, idx2 = sorted_rows(rows)
    hint = MonotonicHint(innermost_monotonic=True, non_monotonic_outer=None)

    prog = Program(
        name="bnn",
        loops=(
            Loop("i", Param("rows", 0, rows), (
                Loop("k", R("rp1", V("i") + 1) - R("rp1", V("i")), (
                    Store(
                        "st_act", "act",
                        R("idx1", R("rp1", V("i")) + V("k")),
                        Un("sign", R("w1", R("rp1", V("i")) + V("k"))),
                        hint=hint,
                    ),
                )),
            )),
            Loop("i2", Param("rows", 0, rows), (
                Loop("k2", R("rp2", V("i2") + 1) - R("rp2", V("i2")), (
                    Load(
                        "ld_act", "act",
                        R("idx2", R("rp2", V("i2")) + V("k2")),
                        hint=hint,
                    ),
                    Store(
                        "st_out", "out",
                        R("rp2", V("i2")) + V("k2"),
                        Un("relu", LoadVal("ld_act") + 0.25),
                    ),
                )),
            )),
        ),
        params=("rows",),
    )
    arrays = {
        "act": np.zeros(width, dtype=np.float64),
        "out": np.zeros(len(idx2), dtype=np.float64),
        "rp1": rp1, "idx1": idx1, "w1": rng.standard_normal(len(idx1)),
        "rp2": rp2, "idx2": idx2,
    }
    return prog, arrays, {"rows": rows}


# ---------------------------------------------------------------------------
# pagerank: two regular loops around an irregular CSR loop, repeated
# ---------------------------------------------------------------------------


@_register("pagerank", "O(iters*(nodes+edges))", 256)
def pagerank(scale: int):
    nodes = scale
    iters = 4
    rng = np.random.default_rng(4)
    deg = rng.integers(1, 6, size=nodes)
    rp = np.concatenate([[0], np.cumsum(deg)]).astype(np.int64)
    cidx = np.concatenate(
        [np.sort(rng.choice(nodes, size=d, replace=False)) for d in deg]
    ).astype(np.int64)
    hint_inner = MonotonicHint(True, None)  # sorted within each row

    prog = Program(
        name="pagerank",
        loops=(
            Loop("t", Param("iters", 0, iters), (
                # regular loop 1: contributions
                Loop("i", Param("nodes", 0, nodes), (
                    Load("ld_rank", "rank", V("i")),
                    Store(
                        "st_c", "contrib", V("i"),
                        LoadVal("ld_rank") * R("invdeg", V("i")),
                    ),
                    Store("st_z", "acc", V("i"), Const(0.0)),
                )),
                # irregular CSR loop: gather + accumulate in memory
                Loop("i2", Param("nodes", 0, nodes), (
                    Loop("e", R("rp", V("i2") + 1) - R("rp", V("i2")), (
                        Load(
                            "ld_c", "contrib",
                            R("cidx", R("rp", V("i2")) + V("e")),
                            hint=hint_inner,
                        ),
                        Load("ld_acc", "acc", V("i2")),
                        Store(
                            "st_acc", "acc", V("i2"),
                            LoadVal("ld_acc") + LoadVal("ld_c"),
                        ),
                    )),
                )),
                # regular loop 2: damping + rank update (wrap-around RAW
                # into the next outer iteration's ld_rank)
                Loop("i3", Param("nodes", 0, nodes), (
                    Load("ld_acc2", "acc", V("i3")),
                    Store(
                        "st_rank", "rank", V("i3"),
                        LoadVal("ld_acc2") * 0.85 + 0.15,
                    ),
                )),
            )),
        ),
        params=("iters", "nodes"),
    )
    arrays = {
        "rank": np.full(nodes, 1.0 / nodes),
        "contrib": np.zeros(nodes, dtype=np.float64),
        "acc": np.zeros(nodes, dtype=np.float64),
        "rp": rp, "cidx": cidx,
        "invdeg": (1.0 / np.maximum(deg, 1)).astype(np.float64),
    }
    return prog, arrays, {"iters": iters, "nodes": nodes}


# ---------------------------------------------------------------------------
# fft: multiplicative-stride stages, middle loop unrolled by two
# ---------------------------------------------------------------------------


@_register("fft", "O(n log n)", 1024)
def fft(scale: int):
    n = scale
    assert n & (n - 1) == 0, "fft size must be a power of two"
    stages = int(np.log2(n))
    rng = np.random.default_rng(5)

    def nest(tag: str, odd: int):
        """One unrolled half: nest 0 processes even global groups (2g),
        nest 1 odd groups (2g+1). Butterfly on x[base], x[base+half].
        The group stride 2*half comes from the multiplicative IVar — the
        paper's non-affine, monotonic {., ×, 2} chain of recurrences.
        """
        g, t = f"g{tag}", f"t{tag}"
        base = (Var(g) * 2 + odd) * (Var("half") * 2) + Var(t)
        partner = base + Var("half")
        ngroups = Param("n", 0, n) // (Var("half") * 2)
        trip = (ngroups + (1 - odd)) // 2  # ceil for even nest, floor for odd
        return Loop(
            g,
            trip,
            (
                Loop(t, Var("half"), (
                    Load(f"ld_top{tag}", "x", base),
                    Load(f"ld_bot{tag}", "x", partner),
                    Store(
                        f"st_top{tag}", "x", base,
                        LoadVal(f"ld_top{tag}")
                        + R("tw", Var(t)) * LoadVal(f"ld_bot{tag}"),
                    ),
                    Store(
                        f"st_bot{tag}", "x", partner,
                        LoadVal(f"ld_top{tag}")
                        - R("tw", Var(t)) * LoadVal(f"ld_bot{tag}"),
                    ),
                )),
            ),
        )

    stage = Loop(
        "s",
        Param("stages", 0, stages),
        (
            nest("0", 0),
            nest("1", 1),
        ),
        ivars=(IVar("half", Const(1), "*", Const(2)),),
    )
    prog = Program(name="fft", loops=(stage,), params=("n", "stages"))
    arrays = {
        "x": rng.standard_normal(n),
        "tw": rng.standard_normal(n),
    }
    return prog, arrays, {"n": n, "stages": stages}


# ---------------------------------------------------------------------------
# matpower: CSR sparse matrix power, outer loop unrolled by two
# ---------------------------------------------------------------------------


@_register("matpower", "O(p * nnz)", 128)
def matpower(scale: int):
    nodes = scale
    powers = 2  # unroll factor 2 -> two chained SpMV nests per power
    rng = np.random.default_rng(6)
    deg = rng.integers(1, 5, size=nodes)
    rp = np.concatenate([[0], np.cumsum(deg)]).astype(np.int64)
    cidx = np.concatenate(
        [np.sort(rng.choice(nodes, size=d, replace=False)) for d in deg]
    ).astype(np.int64)
    hint = MonotonicHint(True, None)

    def spmv(tag: str, src: str, dst: str):
        i, e = f"i{tag}", f"e{tag}"
        return Loop(i, Param("nodes", 0, nodes), (
            Store(f"st_z{tag}", dst, V(i), Const(0.0)),
            Loop(e, R("rp", V(i) + 1) - R("rp", V(i)), (
                Load(
                    f"ld_x{tag}", src,
                    R("cidx", R("rp", V(i)) + V(e)),
                    hint=hint,
                ),
                Load(f"ld_y{tag}", dst, V(i)),
                Store(
                    f"st_y{tag}", dst, V(i),
                    LoadVal(f"ld_y{tag}")
                    + R("val", R("rp", V(i)) + V(e)) * LoadVal(f"ld_x{tag}"),
                ),
            )),
        ))

    prog = Program(
        name="matpower",
        loops=(
            Loop("p", Param("powers", 0, powers), (
                spmv("a", "x", "y"),
                spmv("b", "y", "x"),  # wrap-around into next power
            )),
        ),
        params=("powers", "nodes"),
    )
    arrays = {
        "x": rng.standard_normal(nodes),
        "y": np.zeros(nodes, dtype=np.float64),
        "rp": rp, "cidx": cidx, "val": rng.standard_normal(len(cidx)),
    }
    return prog, arrays, {"powers": powers, "nodes": nodes}


# ---------------------------------------------------------------------------
# hist+add: two (non-monotonic!) histogram loops + an addition loop
# ---------------------------------------------------------------------------


@_register("hist+add", "O(n)", 2048)
def hist_add(scale: int):
    n = scale
    # few-bin histograms (the common case): store-to-load forwarding hits
    # the pending buffer most iterations, as in the paper's evaluation
    bins = 32
    rng = np.random.default_rng(7)
    prog = Program(
        name="hist+add",
        loops=(
            Loop("i", Param("n", 0, n), (
                Load("ld_h1", "h1", R("d1", V("i"), 0, bins - 1)),
                Store(
                    "st_h1", "h1", R("d1", V("i"), 0, bins - 1),
                    LoadVal("ld_h1") + 1.0,
                ),
            )),
            Loop("j", Param("n", 0, n), (
                Load("ld_h2", "h2", R("d2", V("j"), 0, bins - 1)),
                Store(
                    "st_h2", "h2", R("d2", V("j"), 0, bins - 1),
                    LoadVal("ld_h2") + 1.0,
                ),
            )),
            Loop("k", Param("bins", 0, bins), (
                Load("ld_a1", "h1", V("k")),
                Load("ld_a2", "h2", V("k")),
                Store(
                    "st_sum", "hsum", V("k"),
                    LoadVal("ld_a1") + LoadVal("ld_a2"),
                ),
            )),
        ),
        params=("n", "bins"),
    )
    arrays = {
        "h1": np.zeros(bins, dtype=np.float64),
        "h2": np.zeros(bins, dtype=np.float64),
        "hsum": np.zeros(bins, dtype=np.float64),
        "d1": rng.integers(0, bins, size=n),
        "d2": rng.integers(0, bins, size=n),
    }
    return prog, arrays, {"n": n, "bins": bins}


# ---------------------------------------------------------------------------
# tanh+spmv: speculated store under an if-condition + sorted-COO SpMV
# ---------------------------------------------------------------------------


@_register("tanh+spmv", "O(n + nnz)", 512)
def tanh_spmv(scale: int):
    n = scale
    nnz = scale * 2
    rng = np.random.default_rng(8)
    # sorted COO: rows non-decreasing (asserted monotonic)
    rows = np.sort(rng.integers(0, n, size=nnz)).astype(np.int64)
    cols = rng.integers(0, n, size=nnz).astype(np.int64)
    hint_rows = MonotonicHint(True, None)

    prog = Program(
        name="tanh+spmv",
        loops=(
            Loop("i", Param("n", 0, n), (
                Load("ld_v", "v", V("i")),
                # §6: the store executes only when the guard holds — the
                # request is speculated in the AGU, the CU tags validity
                Store(
                    "st_v", "v", V("i"),
                    Un("tanh", LoadVal("ld_v")),
                    guard=Bin(">", LoadVal("ld_v"), Const(0.0)),
                ),
            )),
            Loop("e", Param("nnz", 0, nnz), (
                Load("ld_vv", "v", R("cols", V("e"), 0, n - 1)),
                Load("ld_y", "y", R("rows", V("e"), 0, n - 1), hint=hint_rows),
                Store(
                    "st_y", "y", R("rows", V("e"), 0, n - 1),
                    LoadVal("ld_y") + R("val", V("e")) * LoadVal("ld_vv"),
                    hint=hint_rows,
                ),
            )),
        ),
        params=("n", "nnz"),
    )
    arrays = {
        "v": rng.standard_normal(n),
        "y": np.zeros(n, dtype=np.float64),
        "rows": rows, "cols": cols, "val": rng.standard_normal(nnz),
    }
    return prog, arrays, {"n": n, "nnz": nnz}


# ---------------------------------------------------------------------------
# loss-of-decoupling kernels (speculation="auto" only, DESIGN.md §10)
# ---------------------------------------------------------------------------


@_register("spmv_ldtrip", "O(nnz)", 128, speculative=True)
def spmv_ldtrip(scale: int):
    """SpMV whose row lengths are *computed* by a sibling loop and read
    back through a protected load — the inner trip count depends on
    ``LoadVal``, so ``dae.decouple`` loses decoupling and only the
    speculative AGU can fuse the two loops. Row lengths are mostly
    uniform, so the last-value predictor runs ahead across rows."""
    rows = scale
    rng = np.random.default_rng(9)
    base_len = 4
    deg = np.full(rows, base_len, dtype=np.int64)
    # ~1/8 of rows deviate: real mispredictions + squash traffic, but
    # enough regularity that run-ahead wins
    odd = rng.random(rows) < 0.125
    deg[odd] = rng.integers(0, 2 * base_len + 1, size=int(odd.sum()))
    rp = np.concatenate([[0], np.cumsum(deg)]).astype(np.int64)
    nnz = int(rp[-1])
    cidx = rng.integers(0, rows, size=nnz).astype(np.int64)

    prog = Program(
        name="spmv_ldtrip",
        loops=(
            # producer: publish the (runtime-computed) row lengths
            Loop("i", Param("rows", 0, rows), (
                Store("st_len", "rowlen", V("i"), R("deg", V("i"))),
            )),
            # consumer: SpMV whose trip loads what the producer stored
            Loop("i2", Param("rows", 0, rows), (
                Load("ld_len", "rowlen", V("i2")),
                Store("st_z", "y", V("i2"), Const(0.0)),
                Loop("k", LoadVal("ld_len"), (
                    Load("ld_x", "x", R("cidx", R("rp", V("i2")) + V("k"))),
                    Load("ld_y", "y", V("i2")),
                    Store(
                        "st_y", "y", V("i2"),
                        LoadVal("ld_y")
                        + R("val", R("rp", V("i2")) + V("k")) * LoadVal("ld_x"),
                    ),
                )),
            )),
        ),
        params=("rows",),
    )
    arrays = {
        "rowlen": np.zeros(rows, dtype=np.float64),
        "deg": deg.astype(np.float64),
        "x": rng.standard_normal(rows),
        "y": np.zeros(rows, dtype=np.float64),
        "rp": rp, "cidx": cidx, "val": rng.standard_normal(max(nnz, 1)),
    }
    return prog, arrays, {"rows": rows}


@_register("bfs_front", "O(nodes)", 256, speculative=True)
def bfs_front(scale: int):
    """Front-driven BFS-style frontier gather: per-level frontier
    offsets are published by a producer loop and loaded back — both the
    level trip count (``hi - lo``) and the frontier addresses
    (``lo + k``) depend on protected load values. Trip/address
    speculation squashes once per level and streams within it."""
    nodes = scale
    levels = 8
    rng = np.random.default_rng(10)
    # random partition of the nodes into level frontiers
    cuts = np.sort(rng.choice(nodes, size=levels - 1, replace=False))
    off0 = np.concatenate([[0], cuts, [nodes]]).astype(np.int64)
    front = rng.permutation(nodes).astype(np.int64)

    prog = Program(
        name="bfs_front",
        loops=(
            Loop("t", Param("levels1", 0, levels + 1), (
                Store("st_off", "foff", V("t"), R("off0", V("t"))),
            )),
            Loop("t2", Param("levels", 0, levels), (
                Load("ld_lo", "foff", V("t2")),
                Load("ld_hi", "foff", V("t2") + 1),
                Loop("k", LoadVal("ld_hi") - LoadVal("ld_lo"), (
                    Load("ld_n", "front", LoadVal("ld_lo") + V("k")),
                    Store(
                        "st_v", "visit",
                        LoadVal("ld_lo") + V("k"),
                        R("nodeval", LoadVal("ld_n")) + 1.0,
                    ),
                )),
            )),
        ),
        params=("levels", "levels1"),
    )
    arrays = {
        "foff": np.zeros(levels + 1, dtype=np.float64),
        "off0": off0,
        "front": front.astype(np.float64),
        "visit": np.zeros(nodes, dtype=np.float64),
        "nodeval": rng.standard_normal(nodes),
    }
    return prog, arrays, {"levels": levels, "levels1": levels + 1}


@_register("chase_sum", "O(laps * n)", 256, speculative=True)
def chase_sum(scale: int):
    """Repeated linked-list pointer chase (the lmbench latency idiom):
    ``nxt`` is one n-node cycle, walked ``laps`` times from node 0. The
    next address round-trips through an AGU local fed by the loaded
    value — the worst case for the last-value predictor (every
    occurrence mispredicts, delivery-gated sequential issue), but the
    context-table predictor learns node -> successor on the first lap
    and runs ahead on the rest; confidence gating keeps lap 1 cheap
    (wait gates instead of squash storms). The kernel the predictor
    zoo turns from a documented non-win into a speedup (DESIGN.md
    §10, BENCH_SPEC.json)."""
    n = scale
    laps = 3
    steps = laps * n
    rng = np.random.default_rng(11)
    # a single n-cycle: following nxt from any node visits every node
    order = rng.permutation(n).astype(np.int64)
    nxt = np.empty(n, dtype=np.int64)
    nxt[order] = np.roll(order, -1)

    prog = Program(
        name="chase_sum",
        loops=(
            Loop("o", Const(1), (
                SetLocal("cur", Const(0)),
                Loop("i", Param("steps", 0, steps), (
                    Load("ld_nxt", "nxt", Local("cur")),
                    SetLocal("cur", LoadVal("ld_nxt")),
                    Store(
                        "st_o", "out", V("i"),
                        R("w", LoadVal("ld_nxt")) + LoadVal("ld_nxt"),
                    ),
                )),
            )),
        ),
        params=("steps",),
    )
    arrays = {
        "nxt": nxt.astype(np.float64),
        "out": np.zeros(steps, dtype=np.float64),
        "w": rng.standard_normal(n),
    }
    return prog, arrays, {"steps": steps}


@_register("strided_scan", "O(n)", 256, speculative=True)
def strided_scan(scale: int):
    """AGU-local induction through memory: the next pointer is loaded
    from ``ptr[cur]`` where the stored values form an arithmetic
    sequence (``cur + stride``) — a software-pipelined sparse scan
    whose index increment lives in memory. Loss of decoupling like
    ``chase_sum``, but the value stream is affine: the stride predictor
    locks on after two occurrences and runs the whole scan ahead, while
    last-value mispredicts every occurrence (DESIGN.md §10)."""
    n = scale
    stride = 3
    rng = np.random.default_rng(13)
    # ptr[k] = k + stride: following ptr from 0 yields stride, 2*stride,
    # ... — an arithmetic value sequence only visible through memory
    ptr = (np.arange(n * stride, dtype=np.int64) + stride)

    prog = Program(
        name="strided_scan",
        loops=(
            Loop("o", Const(1), (
                SetLocal("cur", Const(0)),
                Loop("i", Param("n", 0, n), (
                    Load("ld_p", "ptr", Local("cur")),
                    SetLocal("cur", LoadVal("ld_p")),
                    Store(
                        "st_o", "out", V("i"),
                        R("w", V("i")) + LoadVal("ld_p"),
                    ),
                )),
            )),
        ),
        params=("n",),
    )
    arrays = {
        "ptr": ptr.astype(np.float64),
        "out": np.zeros(n, dtype=np.float64),
        "w": rng.standard_normal(n),
    }
    return prog, arrays, {"n": n}


# ---------------------------------------------------------------------------
# streaming kernels: cross-PE scalar FIFO edges (core/fifo, DESIGN.md §11)
# ---------------------------------------------------------------------------


@_register("stream_dot", "O(nb * k)", 256, streaming=True)
def stream_dot(scale: int):
    """Streaming blocked dot-reduction: a reducer leaf accumulates a
    per-block partial sum in a CU local and streams it over a FIFO edge
    to a writer leaf that folds it into ``out[b]``. The two leaves share
    no memory — the producer-before-consumer ordering is carried purely
    by the bounded FIFO token per block instance."""
    nb = scale
    k = 8
    rng = np.random.default_rng(12)
    prog = Program(
        name="stream_dot",
        loops=(
            Loop("b", Param("nb", 0, nb), (
                SetLocal("ps", Const(0.0)),
                Loop("k", Param("k", 0, k), (
                    Load("ld_a", "a", V("b") * k + V("k")),
                    Load("ld_b", "bv", V("b") * k + V("k")),
                    SetLocal(
                        "ps",
                        Local("ps") + LoadVal("ld_a") * LoadVal("ld_b"),
                    ),
                )),
                Loop("w", Const(1), (
                    Load("ld_o", "out", V("b")),
                    Store(
                        "st_o", "out", V("b"),
                        LoadVal("ld_o") + Local("ps"),
                    ),
                )),
            )),
        ),
        params=("nb", "k"),
    )
    arrays = {
        "a": rng.standard_normal(nb * k),
        "bv": rng.standard_normal(nb * k),
        "out": rng.standard_normal(nb),
    }
    return prog, arrays, {"nb": nb, "k": k}


@_register("filter_pipe", "O(n)", 1024, streaming=True)
def filter_pipe(scale: int):
    """Two-stage filter pipeline: stage 1 loads and transforms each
    element into a CU local, stage 2 consumes the streamed value in both
    the store *value* and its §6 *guard* — a guarded store fed entirely
    through a FIFO edge (the valid bit is decided by the popped token)."""
    n = scale
    rng = np.random.default_rng(13)
    prog = Program(
        name="filter_pipe",
        loops=(
            Loop("e", Param("n", 0, n), (
                SetLocal("v", Const(0.0)),
                Loop("p", Const(1), (
                    Load("ld_x", "x", V("e")),
                    SetLocal("v", Un("tanh", LoadVal("ld_x"))),
                )),
                Loop("c", Const(1), (
                    Store(
                        "st_y", "y", V("e"),
                        Local("v") * 0.5 + 1.0,
                        guard=Bin(">", Local("v"), Const(0.0)),
                    ),
                )),
            )),
        ),
        params=("n",),
    )
    arrays = {
        "x": rng.standard_normal(n),
        "y": np.zeros(n, dtype=np.float64),
    }
    return prog, arrays, {"n": n}


@_register("stream_join", "O(n)", 512, streaming=True)
def stream_join(scale: int):
    """Two producers feed a memory-less join PE (no loads, no stores —
    pure FIFO-in/FIFO-out compute) whose result streams to a writer:
    a 4-PE dataflow diamond exercising multi-edge fan-in and a
    chained producer→join→consumer FIFO path."""
    n = scale
    rng = np.random.default_rng(14)
    prog = Program(
        name="stream_join",
        loops=(
            Loop("t", Param("n", 0, n), (
                SetLocal("a", Const(0.0)),
                Loop("p1", Const(1), (
                    Load("ld_u", "u", V("t")),
                    SetLocal("a", LoadVal("ld_u") * 2.0),
                )),
                SetLocal("b", Const(0.0)),
                Loop("p2", Const(1), (
                    Load("ld_w", "w", V("t")),
                    SetLocal("b", LoadVal("ld_w") + 1.0),
                )),
                SetLocal("j", Const(0.0)),
                Loop("m", Const(1), (
                    SetLocal("j", Local("a") + Local("b")),
                )),
                Loop("c", Const(1), (
                    Load("ld_z", "z", V("t")),
                    Store(
                        "st_z", "z", V("t"),
                        LoadVal("ld_z") + Local("j"),
                    ),
                )),
            )),
        ),
        params=("n",),
    )
    arrays = {
        "u": rng.standard_normal(n),
        "w": rng.standard_normal(n),
        "z": rng.standard_normal(n),
    }
    return prog, arrays, {"n": n}


def get(name: str) -> Bench:
    return REGISTRY[name]


def all_names() -> list[str]:
    return list(REGISTRY)


# The nine Table-1 kernels, in the paper's order. Frozen as an explicit
# list (NOT tuple(REGISTRY)): registering new kernels — e.g. the
# speculative ones above — must never silently grow the paper's
# evaluation set (benchmarks/paper_table1.py, test_engine_diff, nightly
# benchmarks). tests/test_speculation.py guards REGISTRY ⊇ TABLE1.
TABLE1: tuple[str, ...] = (
    "RAWloop",
    "WARloop",
    "WAWloop",
    "bnn",
    "pagerank",
    "fft",
    "matpower",
    "hist+add",
    "tanh+spmv",
)

# the loss-of-decoupling kernels, in registration order (the
# speculation benchmark set: benchmarks/bench_speculation.py)
SPEC_KERNELS: tuple[str, ...] = tuple(
    name for name, b in REGISTRY.items() if b.speculative
)

# the cross-PE FIFO streaming kernels, in registration order (the
# streaming benchmark set: benchmarks/bench_stream.py, DESIGN.md §11)
STREAM_KERNELS: tuple[str, ...] = tuple(
    name for name, b in REGISTRY.items() if b.streaming
)
