"""Cross-PE scalar FIFO edges: static analysis + bounded-queue model.

``dae.decouple`` discovers scalar locals that flow between PEs
(``DAEResult.fifo_edges``). This module makes those edges executable
(DESIGN.md §11): each edge is a bounded in-order queue carrying **one
token per leaf-loop instance** of its producer PE —

  * the producer pushes the local's value once per producer leaf-loop
    *instance*, at instance exit (a zero-trip instance still pushes: the
    token is the local's init value at the shared depth),
  * the consumer pops once per consumer leaf-loop *instance*, at
    instance entry (before its trip count is evaluated),

so a full queue backpressures the producer and an empty queue stalls
the consumer — the latency-insensitive semantics of R-HLS state edges /
DAE4HLS explicit decoupling (PAPERS.md).

``analyze_program`` is the static gate: it rejects cyclic edge graphs
(guaranteed deadlock under zero initial tokens) with a diagnostic
naming every edge on the cycle, and rejects shapes the token protocol
cannot express (backward edges, producer/consumer rate mismatches,
missing shared-depth init, multiple definers, stores reading locals
*derived* from streamed values). ``check_depth`` rejects undersized
buffers by name. Programs that pass run under both simulator engines
(``FifoQueue`` below), the wave executor, and the Pallas backend —
see ``executor.build_wave_plan`` for the slot encoding.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Optional

from repro.core import dae as daelib
from repro.core import loopir as ir


class FifoRejected(Exception):
    """A program's FIFO edge set cannot run under the token protocol."""


class FifoDeadlockError(FifoRejected):
    """The edge graph is cyclic: with zero initial tokens every PE on
    the cycle waits on its predecessor forever, for any finite depth."""


class FifoUnsupportedError(FifoRejected):
    """The edge set is acyclic but outside the token protocol."""


@dataclasses.dataclass(frozen=True)
class FifoEdge:
    """One cross-PE scalar stream (index into ``DAEResult.fifo_edges``)."""

    idx: int
    prod_pe: int
    cons_pe: int
    local: str
    shared_depth: int

    def describe(self) -> str:
        return (
            f"(pe{self.prod_pe} -> pe{self.cons_pe}, "
            f"{self.local!r}, shared={self.shared_depth})"
        )


def format_edges(edges) -> str:
    return ", ".join(e.describe() for e in edges)


@dataclasses.dataclass(frozen=True)
class FifoSpec:
    """The analyzed, executable edge set of one program."""

    edges: tuple[FifoEdge, ...]
    # pe id -> ((edge idx, local name), ...) in edge-index order
    in_edges: dict[int, tuple]
    out_edges: dict[int, tuple]

    def __bool__(self) -> bool:
        return bool(self.edges)


def _pe_locals_in(expr: ir.Expr) -> set[str]:
    return daelib.expr_deps(expr)[0]


def _tainted_locals(pe: daelib.PE) -> set[str]:
    """Locals of ``pe`` transitively derived from its fifo-in locals
    (fixpoint over the PE's SetLocal statements)."""
    tainted = set(pe.fifo_in)
    changed = True
    while changed:
        changed = False
        for s, _d in pe.stmts:
            if isinstance(s, ir.SetLocal) and s.name not in tainted:
                if _pe_locals_in(s.value) & tainted:
                    tainted.add(s.name)
                    changed = True
    return tainted


def _find_cycle(edges: tuple[FifoEdge, ...]) -> Optional[list[FifoEdge]]:
    """First producer->consumer cycle in the edge graph, as the list of
    edges along it (None if the graph is a DAG)."""
    adj: dict[int, list[FifoEdge]] = {}
    for e in edges:
        adj.setdefault(e.prod_pe, []).append(e)
    WHITE, GREY, BLACK = 0, 1, 2
    color: dict[int, int] = {}
    stack: list[FifoEdge] = []

    def dfs(u: int) -> Optional[list[FifoEdge]]:
        color[u] = GREY
        for e in adj.get(u, ()):
            v = e.cons_pe
            if color.get(v, WHITE) == GREY:
                # unwind the stack to the first edge leaving v
                cyc = [e]
                for back in reversed(stack):
                    cyc.append(back)
                    if back.prod_pe == v:
                        break
                cyc.reverse()
                return cyc
            if color.get(v, WHITE) == WHITE:
                stack.append(e)
                found = dfs(v)
                stack.pop()
                if found is not None:
                    return found
        color[u] = BLACK
        return None

    for e in edges:
        if color.get(e.prod_pe, WHITE) == WHITE:
            found = dfs(e.prod_pe)
            if found is not None:
                return found
    return None


def analyze_program(program: ir.Program, dres: daelib.DAEResult) -> FifoSpec:
    """Static gate for the token protocol. Raises ``FifoDeadlockError``
    / ``FifoUnsupportedError`` (both ``FifoRejected``) with named-edge
    diagnostics; returns the executable ``FifoSpec`` otherwise."""
    edges = tuple(
        FifoEdge(idx=i, prod_pe=p, cons_pe=c, local=name, shared_depth=d)
        for i, (p, c, name, d) in enumerate(dres.fifo_edges)
    )

    # 1. cycles deadlock for ANY finite depth (zero initial tokens):
    #    checked first so cyclic programs get the deadlock diagnostic,
    #    not an incidental shape complaint about one of their edges
    cyc = _find_cycle(edges)
    if cyc is not None:
        raise FifoDeadlockError(
            "FIFO edge cycle would deadlock (every PE on the cycle "
            "waits on its predecessor; no initial tokens): "
            + format_edges(cyc)
        )

    pes = dres.pes
    for e in edges:
        prod, cons = pes[e.prod_pe], pes[e.cons_pe]
        # 2. backward edge: the consumer's leaf precedes the producer's
        #    in program order -> a loop-carried cross-PE scalar, outside
        #    the one-token-per-instance protocol
        if e.cons_pe <= e.prod_pe:
            raise FifoUnsupportedError(
                f"backward (loop-carried) FIFO edge {e.describe()}: the "
                "consumer leaf runs before the producer in program order"
            )
        # 3. rate match: one push per producer instance must meet one
        #    pop per consumer instance, so both leaves must sit directly
        #    under the shared scope
        if prod.depth != e.shared_depth + 1 or cons.depth != e.shared_depth + 1:
            raise FifoUnsupportedError(
                f"FIFO edge {e.describe()}: producer depth {prod.depth} / "
                f"consumer depth {cons.depth} != shared depth + 1 — "
                "push/pop rates would diverge"
            )
        # 4. the producer must init the local at (or above) the shared
        #    depth: a zero-trip producer instance still owes a token
        has_init = any(
            isinstance(s, ir.SetLocal) and s.name == e.local
            and d <= e.shared_depth
            for s, d in prod.stmts
        )
        if not has_init:
            raise FifoUnsupportedError(
                f"FIFO edge {e.describe()}: streamed local {e.local!r} "
                f"has no SetLocal init at depth <= {e.shared_depth} — a "
                "zero-trip producer instance would have no token value"
            )
        # 5. exactly one defining PE per streamed local
        definers = sorted(
            pe.id
            for pe in pes
            if any(
                isinstance(s, ir.SetLocal) and s.name == e.local
                for s, _d in pe.stmts
            )
        )
        if definers != [e.prod_pe]:
            raise FifoUnsupportedError(
                f"FIFO edge {e.describe()}: local {e.local!r} is defined "
                f"by PEs {definers} — the token protocol needs exactly "
                "one producer"
            )

    # 6. consumer stores must read streamed locals *directly*: a store
    #    reading a local derived from one would need the derivation to
    #    replay inside the op tables, which only see env slots + deps
    by_cons: dict[int, list[FifoEdge]] = {}
    for e in edges:
        by_cons.setdefault(e.cons_pe, []).append(e)
    for pe_id, pe_edges in by_cons.items():
        pe = pes[pe_id]
        tainted = _tainted_locals(pe)
        derived = tainted - pe.fifo_in
        if not derived:
            continue
        for s, _d in pe.stmts:
            if not isinstance(s, ir.Store):
                continue
            exprs = [s.value] + ([s.guard] if s.guard is not None else [])
            for ex in exprs:
                bad = sorted(_pe_locals_in(ex) & derived)
                if bad:
                    raise FifoUnsupportedError(
                        f"store {s.id!r} reads local(s) {bad} derived "
                        f"from streamed value(s) (edges "
                        f"{format_edges(pe_edges)}) — reference the "
                        "streamed local directly"
                    )

    in_edges: dict[int, list] = {}
    out_edges: dict[int, list] = {}
    for e in edges:
        out_edges.setdefault(e.prod_pe, []).append((e.idx, e.local))
        in_edges.setdefault(e.cons_pe, []).append((e.idx, e.local))
    return FifoSpec(
        edges=edges,
        in_edges={k: tuple(v) for k, v in in_edges.items()},
        out_edges={k: tuple(v) for k, v in out_edges.items()},
    )


def check_depth(spec: FifoSpec, depth: int) -> None:
    """Buffer sizing gate: every analyzed edge needs >= 1 slot."""
    if spec.edges and depth < 1:
        raise FifoUnsupportedError(
            f"undersized FIFO depth {depth} (< 1 slot) for edges: "
            + format_edges(spec.edges)
        )


class FifoQueue:
    """Bounded in-order queue of one edge, with occupancy accounting.

    Tokens become visible ``latency`` cycles after the push (the
    producer's exit-block write to the consumer's pre-header read).
    Both engines service these in their settle loops: a push against a
    full queue and a pop against an empty one simply leave the CU's
    ``waiting_on`` set — backpressure is the *absence* of service.
    """

    __slots__ = (
        "edge", "depth", "latency", "q",
        "pushed", "popped", "max_occupancy", "push_stalls", "pop_stalls",
    )

    def __init__(self, edge: FifoEdge, depth: int, latency: int):
        self.edge = edge
        self.depth = int(depth)
        self.latency = int(latency)
        self.q: collections.deque = collections.deque()  # (ready_time, value)
        self.pushed = 0
        self.popped = 0
        self.max_occupancy = 0
        self.push_stalls = 0
        self.pop_stalls = 0

    @property
    def occupancy(self) -> int:
        return len(self.q)

    def can_push(self) -> bool:
        return len(self.q) < self.depth

    def push(self, value: float, now: int) -> None:
        assert self.can_push(), f"push into full FIFO {self.edge.describe()}"
        self.q.append((now + self.latency, float(value)))
        self.pushed += 1
        if len(self.q) > self.max_occupancy:
            self.max_occupancy = len(self.q)

    def head_ready(self, now: int) -> bool:
        return bool(self.q) and self.q[0][0] <= now

    def next_ready_time(self) -> Optional[int]:
        return self.q[0][0] if self.q else None

    def pop(self, now: int) -> float:
        assert self.head_ready(now), f"pop from {self.edge.describe()}"
        _t, v = self.q.popleft()
        self.popped += 1
        return v

    def stats(self) -> dict:
        return {
            "edge": self.edge.describe(),
            "pushed": self.pushed,
            "popped": self.popped,
            "max_occupancy": self.max_occupancy,
            "push_stalls": self.push_stalls,
            "pop_stalls": self.pop_stalls,
        }
