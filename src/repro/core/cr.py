"""Chain of Recurrences (CR) algebra with interval ranges.

Implements the compiler theory from paper §3 (Address Monotonicity):

  * a CR is ``{base, op, step}`` attached to a loop; ``base``/``step`` may
    themselves be expressions containing CRs of *outer* loops,
  * *affine*    iff it is an add-recurrence whose step is a constant
    expression containing no CRs (paper §3.2),
  * *monotonic* (short for monotonically non-decreasing) iff every CR in
    the expression has a non-negative step (paper §3.2, [71]),
  * non-monotonic *outer* loop detection per §3.4.1:
    depth ``k`` is non-monotonic iff there is a deeper depth ``j > k``
    with ``CR_k.step < CR_j.step * tripCount_j`` — evaluated with symbols
    substituted by their *maximum* values, making the check conservative
    (false positives possible, never false negatives).

Symbolic values carry integer intervals (value-range analysis); interval
arithmetic is used wherever the paper substitutes maxima.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Union

INF = 10**18  # effectively unbounded


# ---------------------------------------------------------------------------
# Interval (value-range) arithmetic
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Interval:
    lo: int
    hi: int

    def __post_init__(self):
        assert self.lo <= self.hi, f"bad interval [{self.lo}, {self.hi}]"

    def __add__(self, o: "Interval") -> "Interval":
        return Interval(clamp(self.lo + o.lo), clamp(self.hi + o.hi))

    def __sub__(self, o: "Interval") -> "Interval":
        return Interval(clamp(self.lo - o.hi), clamp(self.hi - o.lo))

    def __mul__(self, o: "Interval") -> "Interval":
        cs = [self.lo * o.lo, self.lo * o.hi, self.hi * o.lo, self.hi * o.hi]
        return Interval(clamp(min(cs)), clamp(max(cs)))

    def union(self, o: "Interval") -> "Interval":
        return Interval(min(self.lo, o.lo), max(self.hi, o.hi))

    @property
    def nonneg(self) -> bool:
        return self.lo >= 0

    @property
    def is_const(self) -> bool:
        return self.lo == self.hi


def clamp(v: int) -> int:
    return max(-INF, min(INF, v))


# ---------------------------------------------------------------------------
# Expression nodes usable inside CRs (constants, symbols, arithmetic)
# ---------------------------------------------------------------------------

class CRExpr:
    """Base class for expressions appearing in CR bases/steps."""

    def range(self) -> Interval:  # pragma: no cover - abstract
        raise NotImplementedError

    def contains_cr(self) -> bool:
        return False

    def crs(self) -> list["CR"]:
        return []

    # small-constructor conveniences -------------------------------------
    def __add__(self, o):
        return cr_add(self, lift(o))

    def __radd__(self, o):
        return cr_add(lift(o), self)

    def __mul__(self, o):
        return cr_mul(self, lift(o))

    def __rmul__(self, o):
        return cr_mul(lift(o), self)


@dataclasses.dataclass(frozen=True)
class CConst(CRExpr):
    v: int

    def range(self) -> Interval:
        return Interval(self.v, self.v)

    def __repr__(self):
        return str(self.v)


@dataclasses.dataclass(frozen=True)
class CSym(CRExpr):
    """A symbolic runtime parameter with a known (conservative) range."""

    name: str
    lo: int = 0
    hi: int = INF

    def range(self) -> Interval:
        return Interval(self.lo, self.hi)

    def __repr__(self):
        return self.name


@dataclasses.dataclass(frozen=True)
class CAdd(CRExpr):
    a: CRExpr
    b: CRExpr

    def range(self) -> Interval:
        return self.a.range() + self.b.range()

    def contains_cr(self) -> bool:
        return self.a.contains_cr() or self.b.contains_cr()

    def crs(self):
        return self.a.crs() + self.b.crs()

    def __repr__(self):
        return f"({self.a} + {self.b})"


@dataclasses.dataclass(frozen=True)
class CMul(CRExpr):
    a: CRExpr
    b: CRExpr

    def range(self) -> Interval:
        return self.a.range() * self.b.range()

    def contains_cr(self) -> bool:
        return self.a.contains_cr() or self.b.contains_cr()

    def crs(self):
        return self.a.crs() + self.b.crs()

    def __repr__(self):
        return f"({self.a} * {self.b})"


@dataclasses.dataclass(frozen=True)
class COpaque(CRExpr):
    """A value the analysis cannot see through (e.g. a data-dependent read).

    Carries an optional user-asserted range, mirroring the paper's
    programmer annotations for sparse formats (§3.3).
    """

    name: str
    lo: int = -INF
    hi: int = INF

    def range(self) -> Interval:
        return Interval(self.lo, self.hi)

    def __repr__(self):
        return f"opaque({self.name})"


@dataclasses.dataclass(frozen=True)
class CR(CRExpr):
    """{base, op, step} recurrence attached to loop ``depth`` (1-indexed,
    1 = outermost of the op's nest, matching paper notation)."""

    base: CRExpr
    op: str  # '+' or '*'
    step: CRExpr
    depth: int

    def __post_init__(self):
        assert self.op in ("+", "*")

    def contains_cr(self) -> bool:
        return True

    def crs(self):
        return [self] + self.base.crs() + self.step.crs()

    def range(self) -> Interval:
        # Conservative: base range unioned with base evolved by
        # step*trip — without trip info we use [lo(base), INF) for
        # non-negative steps, full range otherwise.
        b = self.base.range()
        s = self.step.range()
        if self.op == "+":
            if s.nonneg:
                return Interval(b.lo, INF)
            if s.hi <= 0:
                return Interval(-INF, b.hi)
            return Interval(-INF, INF)
        # multiplicative recurrence
        if s.lo >= 1 and b.lo >= 0:
            return Interval(b.lo, INF)
        return Interval(-INF, INF)

    # --- paper §3.2 predicates ------------------------------------------

    @property
    def is_affine(self) -> bool:
        """Add recurrence whose step is a constant expression w/o CRs."""
        return (
            self.op == "+"
            and not self.step.contains_cr()
            and (not self.base.contains_cr() or all(c.is_affine for c in self.base.crs()))
        )

    @property
    def is_monotonic(self) -> bool:
        """Monotonically non-decreasing: non-negative step (×: step>=1,
        non-negative base)."""
        s = self.step.range()
        if self.op == "+":
            ok = s.nonneg
        else:
            ok = s.lo >= 1 and self.base.range().lo >= 0
        return ok and all(c.is_monotonic for c in self.base.crs()) and all(
            c.is_monotonic for c in self.step.crs()
        )

    def __repr__(self):
        return f"{{{self.base}, {self.op}, {self.step}}}@{self.depth}"


def lift(v: Union[int, CRExpr]) -> CRExpr:
    if isinstance(v, CRExpr):
        return v
    return CConst(int(v))


# ---------------------------------------------------------------------------
# CR construction algebra (simplifying constructors)
# ---------------------------------------------------------------------------

def cr_add(a: CRExpr, b: CRExpr) -> CRExpr:
    a, b = lift(a), lift(b)
    if isinstance(a, CConst) and isinstance(b, CConst):
        return CConst(a.v + b.v)
    if isinstance(a, CConst) and a.v == 0:
        return b
    if isinstance(b, CConst) and b.v == 0:
        return a
    # {b1,+,s1}@d + {b2,+,s2}@d = {b1+b2,+,s1+s2}@d
    if isinstance(a, CR) and isinstance(b, CR) and a.depth == b.depth and a.op == b.op == "+":
        return CR(cr_add(a.base, b.base), "+", cr_add(a.step, b.step), a.depth)
    # {b,+,s}@d + c = {b+c,+,s}@d  (fold into deeper CR's base)
    if isinstance(a, CR) and a.op == "+" and not _mentions_depth(b, a.depth):
        return CR(cr_add(a.base, b), "+", a.step, a.depth)
    if isinstance(b, CR) and b.op == "+" and not _mentions_depth(a, b.depth):
        return CR(cr_add(b.base, a), "+", b.step, b.depth)
    return CAdd(a, b)


def cr_mul(a: CRExpr, b: CRExpr) -> CRExpr:
    a, b = lift(a), lift(b)
    if isinstance(a, CConst) and isinstance(b, CConst):
        return CConst(a.v * b.v)
    if isinstance(a, CConst):
        if a.v == 0:
            return CConst(0)
        if a.v == 1:
            return b
    if isinstance(b, CConst):
        if b.v == 0:
            return CConst(0)
        if b.v == 1:
            return a
    # c * {b,+,s}@d = {c*b,+,c*s}@d when c is invariant w.r.t. loop d
    # (contains no CR at depth >= d — e.g. FFT's stride {1,×,2}@outer
    # multiplying the inner counter)
    if isinstance(a, CR) and a.op == "+" and _invariant_at(b, a.depth):
        return CR(cr_mul(a.base, b), "+", cr_mul(a.step, b), a.depth)
    if isinstance(b, CR) and b.op == "+" and _invariant_at(a, b.depth):
        return CR(cr_mul(b.base, a), "+", cr_mul(b.step, a), b.depth)
    # c * {b,×,s}@d = {c*b,×,s}@d for constant c
    if isinstance(a, CR) and a.op == "*" and isinstance(b, CConst):
        return CR(cr_mul(a.base, b), "*", a.step, a.depth)
    if isinstance(b, CR) and b.op == "*" and isinstance(a, CConst):
        return CR(cr_mul(b.base, a), "*", b.step, b.depth)
    return CMul(a, b)


def _invariant_at(e: CRExpr, depth: int) -> bool:
    return all(c.depth < depth for c in e.crs()) and not has_opaque(e)


def _mentions_depth(e: CRExpr, depth: int) -> bool:
    return any(c.depth == depth for c in e.crs())


# ---------------------------------------------------------------------------
# Whole-expression predicates (paper §3.2 / §3.4.1)
# ---------------------------------------------------------------------------

def is_affine_expr(e: CRExpr) -> bool:
    crs = e.crs()
    return bool(crs) and all(c.is_affine for c in crs) and not has_opaque(e)


def is_monotonic_expr(e: CRExpr) -> bool:
    """Paper: an address expression is monotonic w.r.t. a loop depth iff
    the CR expression consists of only monotonic CRs."""
    if has_opaque(e):
        return False
    crs = e.crs()
    return all(c.is_monotonic for c in crs)


def has_opaque(e: CRExpr) -> bool:
    """True iff ``e`` contains a ``COpaque`` term anywhere — i.e. the
    analysis cannot see the whole value evolution (a data-dependent read
    or an untranslatable sub-expression hides part of it)."""
    if isinstance(e, COpaque):
        return True
    if isinstance(e, (CAdd, CMul)):
        return has_opaque(e.a) or has_opaque(e.b)
    if isinstance(e, CR):
        return has_opaque(e.base) or has_opaque(e.step)
    return False


def step_at_depth(e: CRExpr, depth: int) -> Optional[CRExpr]:
    """The (summed) step contribution of loop ``depth`` to expression
    ``e``.

    If no CR at ``depth`` appears and the expression is opaque-free, the
    address is invariant in that loop — the step is literally 0. (The
    paper's "CR_k might not exist -> trivially non-monotonic" covers the
    *unanalyzable* case, which the opaque path handles before we get
    here.) Returns None only when an opaque term hides the dependence.
    """
    steps = [c.step for c in e.crs() if c.depth == depth]
    if not steps:
        return None if has_opaque(e) else CConst(0)
    out = steps[0]
    for s in steps[1:]:
        out = cr_add(out, s)
    return out


def _factors(e: CRExpr) -> tuple[int, tuple]:
    """Flatten a product into (constant coefficient, sorted symbolic
    factors) for light symbolic comparison."""
    if isinstance(e, CConst):
        return e.v, ()
    if isinstance(e, CMul):
        ca, fa = _factors(e.a)
        cb, fb = _factors(e.b)
        return ca * cb, tuple(sorted(fa + fb, key=repr))
    return 1, (e,)


def symbolic_ge(a: CRExpr, b: CRExpr) -> bool:
    """Best-effort proof that ``a >= b`` for all symbol values.

    1. structural equality,
    2. equal symbolic factor multisets with coefficient comparison
       (proves 2*half >= 1*half, M >= M, ...),
    3. conservative interval fallback: min(a) >= max(b).
    Returns False when no proof is found (callers treat that as "may be
    smaller" — conservative for the §3.4.1 check).
    """
    if a == b:
        return True
    ca, fa = _factors(a)
    cb, fb = _factors(b)
    if fa == fb and ca >= cb >= 0:
        return True
    # pointwise CR comparison: same loop & operator, step_a >= step_b and
    # base_a >= base_b (>=0 for multiplicative) implies a >= b everywhere
    if (
        isinstance(a, CR)
        and isinstance(b, CR)
        and a.depth == b.depth
        and a.op == b.op
        and b.base.range().lo >= 0
        and b.step.range().lo >= (1 if a.op == "*" else 0)
        and symbolic_ge(a.base, b.base)
        and symbolic_ge(a.step, b.step)
    ):
        return True
    return a.range().lo >= b.range().hi


def non_monotonic_depths(
    e: CRExpr, trip_counts: dict[int, CRExpr], n_depths: int
) -> set[int]:
    """§3.4.1 detection: depth k (1..n_depths) is non-monotonic if some
    deeper depth j contributes more per full execution than one k-step:
    ``CR_k.step < CR_j.step * tripCount_j``.

    ``trip_counts[j]`` is the (symbolic) trip count of depth j. The
    comparison is attempted symbolically first (structural equality of
    the simplified expressions handles the paper's row-major ``M`` vs
    ``M`` case); otherwise symbols fall back to conservative interval
    comparison (min step vs max contribution) — false positives
    possible, never false negatives. The innermost depth is
    non-monotonic iff its step can be negative (the paper *requires*
    innermost monotonicity; callers reject such ops or demand
    annotations).
    """
    out: set[int] = set()
    steps: dict[int, Optional[CRExpr]] = {
        k: step_at_depth(e, k) for k in range(1, n_depths + 1)
    }
    for k in range(1, n_depths + 1):
        sk = steps[k]
        if sk is None:
            out.add(k)
            continue
        rk = sk.range()
        if rk.lo < 0:
            out.add(k)
            continue
        for j in range(k + 1, n_depths + 1):
            sj = steps[j]
            if sj is None:
                # deeper depth contributes an unknown amount
                out.add(k)
                break
            contrib = cr_mul(sj, trip_counts.get(j, CSym(f"__trip{j}", 0, INF)))
            # monotonic w.r.t. this j iff step_k >= step_j * trip_j, proven
            # symbolically where possible (row-major M vs M; FFT 2*half
            # vs half) else by conservative intervals
            if not symbolic_ge(sk, contrib):
                out.add(k)
                break
    return out


# ---------------------------------------------------------------------------
# Dependence-certificate primitives (analysis/deps.py, DESIGN.md §12)
#
# Everything below reasons about the *value set* and *evolution* of an
# address stream over a full loop nest, rather than per-depth
# monotonicity: trip-aware value ranges (interval disjointness), residue
# classes (stride disjointness: a[2i] vs a[2i+1]), exact stream
# differences, and a lower bound on the increase between consecutive
# nest instances. All are conservative — a ``None``/trivial answer is
# always allowed, a definite answer must hold for every in-range
# assignment of symbols.
# ---------------------------------------------------------------------------


def cr_diff(a: CRExpr, b: CRExpr) -> CRExpr:
    """``a - b`` with zero-step add-recurrences collapsed to their base.

    The collapse makes identical (or offset-identical) streams fold to a
    constant: ``{0,+,1}@1 - {0,+,1}@1`` becomes ``0``, not
    ``{0,+,0}@1`` — which is what lets the certifier prove exact
    per-instance differences."""
    return _collapse(cr_add(a, cr_mul(CConst(-1), b)))


def _collapse(e: CRExpr) -> CRExpr:
    if isinstance(e, CR):
        base = _collapse(e.base)
        step = _collapse(e.step)
        if e.op == "+" and step == CConst(0):
            return base
        return CR(base, e.op, step, e.depth)
    if isinstance(e, CAdd):
        return cr_add(_collapse(e.a), _collapse(e.b))
    if isinstance(e, CMul):
        return cr_mul(_collapse(e.a), _collapse(e.b))
    return e


def value_range(e: CRExpr, trips: dict[int, CRExpr]) -> Interval:
    """Trip-aware range of ``e`` over one full execution of its nest.

    Unlike ``CR.range`` (which has no trip information and answers
    ``[lo, INF)`` for any non-negative step), an add-recurrence at depth
    ``d`` contributes ``step * [0, trip_d - 1]``, so two streams with
    disjoint footprints (``a[i]`` vs ``a[T + i]`` with ``i < T``) get
    provably disjoint intervals. ``trips[d]`` is the symbolic trip count
    of depth ``d``; missing depths fall back to unbounded. Opaque terms
    contribute their asserted range (§3.3 annotations), so hinted
    data-dependent streams still participate in range disjointness."""
    if isinstance(e, CR):
        if e.op == "+":
            b = value_range(e.base, trips)
            s = value_range(e.step, trips)
            t = trips.get(e.depth)
            t_hi = t.range().hi if t is not None else INF
            iters = Interval(0, clamp(max(t_hi - 1, 0)))
            return b + s * iters
        return e.range()
    if isinstance(e, CAdd):
        return value_range(e.a, trips) + value_range(e.b, trips)
    if isinstance(e, CMul):
        return value_range(e.a, trips) * value_range(e.b, trips)
    return e.range()


def base_value(e: CRExpr) -> Optional[int]:
    """The concrete value of ``e`` at the all-zero iteration vector, or
    None when it is not a known integer (symbols, opaque terms,
    multiplicative recurrences)."""
    if isinstance(e, CConst):
        return e.v
    if isinstance(e, CR):
        return base_value(e.base) if e.op == "+" else None
    if isinstance(e, CAdd):
        a, b = base_value(e.a), base_value(e.b)
        return None if a is None or b is None else a + b
    if isinstance(e, CMul):
        a, b = base_value(e.a), base_value(e.b)
        return None if a is None or b is None else a * b
    return None


def residue_class(e: CRExpr) -> Optional[tuple[int, int]]:
    """``(g, r)`` such that every value of ``e`` is ``≡ r (mod g)``.

    Requires every recurrence to be additive with a *constant* step and
    a constant base value: the stream is then ``r + Σ_d s_d·i_d`` with
    ``g = gcd(s_d)``. ``g == 0`` means the stream is the single constant
    ``r``. Returns None when no such proof exists. This is the stride
    lens of the certifier: ``a[2i]`` → ``(2, 0)`` vs ``a[2i+1]`` →
    ``(2, 1)`` proves disjointness regardless of trip counts."""
    if has_opaque(e):
        return None
    crs = e.crs()
    if any(c.op != "+" for c in crs):
        return None
    b0 = base_value(e)
    if b0 is None:
        return None
    g = 0
    for d in {c.depth for c in crs}:
        s = step_at_depth(e, d)
        if not isinstance(s, CConst):
            return None
        g = math.gcd(g, abs(s.v))
    return (g, b0 % g if g else b0)


def residues_disjoint(
    a: Optional[tuple[int, int]], b: Optional[tuple[int, int]]
) -> bool:
    """True iff the two residue classes can never produce equal values:
    distinct constants, or residues that differ mod ``gcd(g_a, g_b) ≥ 2``."""
    if a is None or b is None:
        return False
    (ga, ra), (gb, rb) = a, b
    m = math.gcd(ga, gb)
    if m == 0:
        return ra != rb
    return m >= 2 and (ra - rb) % m != 0


def min_adjacent_increase(
    e: CRExpr, trips: dict[int, CRExpr], n_depths: int
) -> Optional[int]:
    """Conservative lower bound on ``e(next) - e(cur)`` over *adjacent*
    instances of an ``n_depths``-deep nest (lexicographic order).

    When the outermost coordinate that advances is depth ``m``, the
    difference is ``step_m - Σ_{j>m} step_j · (executed iterations of
    j)``, so the bound at ``m`` is ``lo(step_m) + Σ_{j>m}
    lo(step_j · [-(trip_j - 1), 0])`` and the result is the min over
    ``m``. ``≥ 1`` proves the stream strictly increasing (hence
    injective). None when opaque or multiplicative recurrences make the
    per-iteration delta unknown."""
    if has_opaque(e) or any(c.op == "*" for c in e.crs()):
        return None
    lo = None
    for m in range(1, n_depths + 1):
        sm = step_at_depth(e, m)
        if sm is None:
            return None
        bound = sm.range().lo
        for j in range(m + 1, n_depths + 1):
            sj = step_at_depth(e, j)
            if sj is None:
                return None
            t = trips.get(j)
            t_hi = t.range().hi if t is not None else INF
            back = Interval(clamp(-max(t_hi - 1, 0)), 0)
            bound = clamp(bound + (sj.range() * back).lo)
        lo = bound if lo is None else min(lo, bound)
    return lo
