"""Program-order schedule generation (paper §4).

The schedule representation, per memory operation of loop depth n:

  * an n-tuple of counters, one per loop depth, each incremented by 1 at
    every invocation of that loop's body — *never reset* when inner
    loops re-enter (§4 item 2),
  * comparisons between two operations use ONLY the element at their
    innermost shared depth k, with comparator direction configured from
    topological order (§4 item 3, synthesized in hazards.py),
  * one ``lastIter`` bit per non-monotonic loop depth, computed one
    iteration in advance when the loop is ``predictable`` (§4.1/§4.2(3)),
  * at stream end the AGU emits a sentinel (schedule = +inf, addr = +inf)
    signalling no further requests (§4.2(4)).

This module runs the AGU semantics (decoupled address threads, which by
the LoD check never depend on protected load values) ahead of time and
materializes each op's full request stream — the software analogue of
the AGU "running ahead" of the compute pipeline (§2.1.1).

Two implementations produce bit-identical streams (DESIGN.md §7):

  * ``_trace_pe`` — the reference interpreter: a per-iteration Python
    walk of the PE's replicated loop control; wall-clock scales with
    leaf iterations.
  * ``compile_pe_trace`` — the affine trace compiler: when
    ``affine.classify_pe`` accepts the PE, every array (sched counters,
    addresses, lastIter hints, seq numbers) is built closed-form with
    numpy over the flattened iteration space.

``trace_program(mode=...)`` selects per PE: ``"auto"`` (default)
compiles where possible and falls back to the interpreter, ``"interp"``
forces the reference, ``"compiled"`` raises ``TraceCompileError`` naming
the offending op when a PE is outside the compiled subset.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import affine
from repro.core import dae as daelib
from repro.core import loopir as ir

TraceCompileError = affine.TraceCompileError

TRACE_MODES = ("auto", "compiled", "interp")

SENTINEL = np.int64(2**62)


@dataclasses.dataclass
class OpTrace:
    """Full AGU request stream for one memory operation."""

    op_id: str
    pe_id: int
    depth: int
    is_store: bool
    sched: np.ndarray  # (n_req, depth) int64, counters start at 1
    addr: np.ndarray  # (n_req,) int64
    lastiter: np.ndarray  # (n_req, depth) bool
    seq: np.ndarray = None  # (n_req,) int64: per-PE AGU generation order

    @property
    def n_req(self) -> int:
        return len(self.addr)


@dataclasses.dataclass
class PETrace:
    pe_id: int
    ops: dict[str, OpTrace]
    n_leaf_iters: int  # total leaf-body invocations (for timing models)


def instance_rank_table(
    traces: dict[str, OpTrace],
    dae: "daelib.DAEResult",
    loop_pos: dict[int, int],
    op_pos: dict[str, int],
    fuse_group: dict[int, int],
    op_path: dict[str, tuple],
    key_len: Optional[int] = None,
) -> tuple[dict[str, np.ndarray], np.ndarray]:
    """Vectorized leaf-loop *instance* ranking of every request.

    Builds the polyhedral 2d+1 key of each request (static positions and
    per-depth counters interleaved, trailing leaf counter dropped so all
    iterations of one leaf instance share a key; fused siblings share the
    group leader's leaf position) as one int64 matrix per op, then ranks
    all requests globally with a single lexicographic ``np.unique``.

    Returns (per-op rank array aligned with the op's request stream,
    per-rank total request count). Replaces a per-request Python loop —
    this is what lets the sequential (LSQ) window logic run at paper
    scales.
    """
    if key_len is None and traces:
        # widest key any op can need: positions+counters interleaved for
        # every depth plus a trailing position slot
        key_len = max(2 * tr.depth + 1 for tr in traces.values())
    mats = []
    ops = sorted(traces)
    for op_id in ops:
        tr = traces[op_id]
        pe = dae.pes[tr.pe_id]
        path = op_path[op_id]
        key = np.full((tr.n_req, key_len), -1, dtype=np.int64)
        if tr.depth == pe.depth:
            for j in range(tr.depth - 1):
                key[:, 2 * j] = loop_pos[id(path[j])]
                key[:, 2 * j + 1] = tr.sched[:, j]
            leader = dae.pes[fuse_group[tr.pe_id]]
            key[:, 2 * (tr.depth - 1)] = loop_pos[id(leader.leaf)]
        else:  # parent-body op: its own micro-instance per iteration
            for j in range(tr.depth):
                key[:, 2 * j] = loop_pos[id(path[j])]
                key[:, 2 * j + 1] = tr.sched[:, j]
            key[:, 2 * tr.depth] = op_pos[op_id]
        mats.append(key)
    if not mats:
        return {}, np.zeros(0, dtype=np.int64)
    stacked = np.concatenate(mats, axis=0)
    _, inverse, counts = np.unique(
        stacked, axis=0, return_inverse=True, return_counts=True
    )
    inverse = inverse.reshape(-1)
    ranks: dict[str, np.ndarray] = {}
    off = 0
    for op_id in ops:
        n = traces[op_id].n_req
        ranks[op_id] = inverse[off : off + n]
        off += n
    return ranks, counts


def trace_program(
    program: ir.Program,
    dae: daelib.DAEResult,
    arrays: dict[str, np.ndarray],
    params: Optional[dict[str, int]] = None,
    mode: str = "auto",
    report: Optional[dict] = None,
    spec_out: Optional[list] = None,
    oracle_loads: Optional[dict] = None,
    predictor: str = "auto",
    spec_runahead: Optional[int] = None,
) -> dict[str, OpTrace]:
    """Generate the AGU request streams of every memory op in every PE.

    ``mode`` selects the per-PE trace path (module docstring); pass a
    dict as ``report`` to receive, per PE id, ``{"path": "compiled" |
    "interp" | "speculative", "reason": None | str, "op_affine": {...}}``.

    PEs marked speculative by ``dae.decouple(speculation="auto")`` are
    routed to the speculative AGU (``speculate.trace_spec_pe``) under
    ``"auto"``/``"interp"`` — its run-ahead is inherently interpretive,
    so ``"compiled"`` raises ``TraceCompileError`` for them. Pass a list
    as ``spec_out`` to receive the accumulated ``speculate.SpecPlan``
    (appended once; ``None`` when no PE speculates) — the engines
    consume it for epoch gating and squash traffic (DESIGN.md §10).
    ``oracle_loads`` optionally supplies the per-op oracle load streams
    the speculative AGU predicts against (callers that already ran a
    hooked ``loopir.interpret`` — validation, the DSE planner, the wave
    executor — pass theirs to avoid a second sequential walk); when
    absent and a PE speculates, one hooked run happens here.
    ``predictor`` (``dae.PREDICTORS``) and ``spec_runahead``
    (``SimParams.spec_runahead``; ``None`` = the speculate default)
    parameterize the built ``SpecPlan`` — they move gates and phantom
    traffic only, never the request streams.
    """
    assert mode in TRACE_MODES, f"unknown trace mode {mode!r}"
    params = params or {}
    out: dict[str, OpTrace] = {}
    spec_plan = None
    for pe in dae.pes:
        if pe.id in dae.spec:
            if mode == "compiled":
                raise TraceCompileError(
                    f"PE {pe.id} needs the speculative AGU (loss of "
                    f"decoupling: {'; '.join(dae.spec[pe.id].reasons)}) — "
                    f"speculative streams are interpreter-built; use "
                    f"trace_mode='auto'"
                )
            from repro.core import speculate

            if spec_plan is None:
                assert predictor in daelib.PREDICTORS, (
                    f"unknown predictor {predictor!r} "
                    f"(choose from {daelib.PREDICTORS})"
                )
                spec_plan = speculate.SpecPlan(
                    predictor=predictor,
                    runahead=(
                        speculate.DEFAULT_RUNAHEAD
                        if spec_runahead is None
                        else int(spec_runahead)
                    ),
                )
                if oracle_loads is None:
                    oracle_loads = speculate.oracle_load_streams(
                        program, arrays, params
                    )
            t = speculate.trace_spec_pe(
                pe, dae.spec[pe.id], arrays, params, oracle_loads, spec_plan
            )
            if report is not None:
                report[pe.id] = {
                    "path": "speculative",
                    "reason": "; ".join(dae.spec[pe.id].reasons),
                    "op_affine": {},
                }
            out.update(t.ops)
            continue
        path, reason, cls = "interp", None, None
        if mode != "interp" and pe.fifo_in:
            # cross-PE FIFO consumers (DESIGN.md §11): streamed locals are
            # CU-side values the affine compiler has no stream for; the
            # interpreter walk skips them statically (taint set below)
            reason = (
                f"PE {pe.id} consumes cross-PE FIFO local(s) "
                f"{sorted(pe.fifo_in)} — streamed values are CU-side only"
            )
            if mode == "compiled":
                raise TraceCompileError(reason)
        elif mode != "interp":
            cls = affine.classify_pe(pe)
            if cls.compilable:
                try:
                    t = compile_pe_trace(pe, arrays, params)
                    path = "compiled"
                except TraceCompileError as e:
                    if mode == "compiled":
                        raise
                    reason = str(e)
            elif mode == "compiled":
                raise TraceCompileError(
                    f"PE {pe.id} (leaf loop {pe.leaf.var!r}) is outside "
                    f"the compiled subset: {'; '.join(cls.reasons)}"
                )
            else:
                reason = "; ".join(cls.reasons)
        if path == "interp":
            t = _trace_pe(pe, arrays, params)
        if report is not None:
            report[pe.id] = {
                "path": path,
                "reason": reason,
                "op_affine": dict(cls.op_affine) if cls is not None else {},
            }
        out.update(t.ops)
    if spec_out is not None:
        spec_out.append(spec_plan)
    return out


def _static_op_meta(
    pe: daelib.PE,
) -> tuple[list[tuple], dict[str, int], dict[str, bool]]:
    """(mem stmts with depth+rank, op depth, op is_store) — statically,
    so zero-request ops (a loop that never executes) still declare the
    depth/kind the hazard plan derived from the same static paths."""
    mem: list[tuple] = []  # (stmt, depth, rank-at-depth)
    rank_at: dict[int, int] = {}
    op_depth: dict[str, int] = {}
    op_store: dict[str, bool] = {}
    for s, d in pe.stmts:
        if isinstance(s, (ir.Load, ir.Store)):
            r = rank_at.get(d, 0)
            rank_at[d] = r + 1
            mem.append((s, d, r))
            op_depth[s.id] = d
            op_store[s.id] = isinstance(s, ir.Store)
    return mem, op_depth, op_store


def compile_pe_trace(
    pe: daelib.PE, arrays: dict[str, np.ndarray], params: dict[str, int]
) -> PETrace:
    """Closed-form construction of the PE's request streams.

    Exactly equivalent to ``_trace_pe`` for PEs inside the compiled
    subset (``affine.classify_pe``): counters are flat invocation
    indices + 1, lastIter flags come from the per-depth iteration
    spaces, addresses are one vectorized evaluation per op, and the
    per-PE ``seq`` interleave is a single lexsort of padded
    (counter, statement-rank) keys.
    """
    space = affine.build_iter_space(pe, arrays, params)
    mem, op_depth, op_store = _static_op_meta(pe)
    seqs = affine.interleave_order(space, [(s.id, d, r) for s, d, r in mem])
    ops: dict[str, OpTrace] = {}
    # emit in pe.mem_ops order, matching _trace_pe: the trace dict's key
    # order is the engines' deterministic port-scan order, so the paths
    # must agree on it or same-cycle ties resolve differently (observed
    # as a 2-cycle drift on matpower at 8x scale before this ordering)
    mem.sort(key=lambda t: pe.mem_ops.index(t[0].id))
    for s, d, _r in mem:
        n = space.counts[d]
        if n:
            addr = affine._as_index(
                np.asarray(
                    affine.vec_eval(s.addr, space.env[d], arrays, params, n)
                )
            ).astype(np.int64, copy=False)
            sched = np.stack(
                [space.anc[d][k - 1] + 1 for k in range(1, d + 1)], axis=1
            )
            lastiter = np.stack(
                [
                    space.is_last[k][space.anc[d][k - 1]]
                    for k in range(1, d + 1)
                ],
                axis=1,
            )
        else:
            addr = np.zeros(0, dtype=np.int64)
            sched = np.zeros((0, d), dtype=np.int64)
            lastiter = np.zeros((0, d), dtype=bool)
        ops[s.id] = OpTrace(
            op_id=s.id,
            pe_id=pe.id,
            depth=d,
            is_store=op_store[s.id],
            sched=sched,
            addr=addr,
            lastiter=lastiter,
            seq=seqs[s.id],
        )
    return PETrace(
        pe_id=pe.id, ops=ops, n_leaf_iters=space.counts[pe.depth]
    )


def _trace_pe(
    pe: daelib.PE, arrays: dict[str, np.ndarray], params: dict[str, int]
) -> PETrace:
    # recorded streams per op
    rec: dict[str, dict[str, list]] = {
        op_id: {"sched": [], "addr": [], "lastiter": [], "seq": []}
        for op_id in pe.mem_ops
    }
    seq_counter = [0]
    # static metadata: a zero-trip loop's ops emit no requests but must
    # still declare the depth/kind the hazard plan sees (compiled-path
    # parity; previously these silently defaulted to pe.depth / False)
    _, op_depth, op_store = _static_op_meta(pe)

    # cross-PE streamed locals (DESIGN.md §11) and anything derived from
    # them are CU-side values — the LoD check already rejects address or
    # trip uses, so the AGU walk must skip those SetLocals entirely
    tainted = set(pe.fifo_in)
    changed = True
    while changed:
        changed = False
        for s, _d in pe.stmts:
            if isinstance(s, ir.SetLocal) and s.name not in tainted:
                locs, _ = daelib.expr_deps(s.value)
                if locs & tainted:
                    tainted.add(s.name)
                    changed = True

    # group the PE's statements by depth
    by_depth: dict[int, list[ir.Stmt]] = {}
    for s, d in pe.stmts:
        by_depth.setdefault(d, []).append(s)

    counters = [0] * (pe.depth + 1)  # 1-indexed
    n_leaf = 0

    env = ir._Env()

    def eval_expr(e: ir.Expr, scope: ir._Env):
        # AGU-side evaluation: LoadVal is impossible here (LoD check)
        return ir._eval(e, scope, arrays, params, {})

    # per-depth "is current iteration the last one" flags
    last_flags = [False] * (pe.depth + 1)

    def run_depth(d: int, scope: ir._Env):
        nonlocal n_leaf
        loop = pe.path[d - 1]
        loop_scope = ir._Env(scope)
        for iv in loop.ivars:
            loop_scope.define(iv.name, eval_expr(iv.init, scope))
        trip = int(eval_expr(loop.trip, scope))
        for i in range(trip):
            counters[d] += 1
            body = ir._Env(loop_scope)
            body.define(loop.var, i)
            # §4.2(3): lastIter computed one iteration in advance when the
            # loop predicate is predictable; otherwise the hint is 0.
            last_flags[d] = (i == trip - 1) if loop.predictable else False
            if d == pe.depth:
                n_leaf += 1
            for s in by_depth.get(d, ()):  # this depth's statements
                exec_stmt(s, body, d)
            if d < pe.depth:
                run_depth(d + 1, body)
            for iv in loop.ivars:
                cur = loop_scope.get(iv.name)
                step = eval_expr(iv.step, body)
                loop_scope.vals[iv.name] = (
                    cur + step if iv.op == "+" else cur * step
                )

    def exec_stmt(s: ir.Stmt, scope: ir._Env, d: int):
        if isinstance(s, (ir.Load, ir.Store)):
            # speculation (§6): requests are generated unconditionally —
            # guarded stores get a valid bit from the CU at sim time
            a = int(eval_expr(s.addr, scope))
            r = rec[s.id]
            r["sched"].append(tuple(counters[1 : d + 1]))
            r["addr"].append(a)
            r["lastiter"].append(tuple(last_flags[1 : d + 1]))
            r["seq"].append(seq_counter[0])
            seq_counter[0] += 1
        elif isinstance(s, ir.SetLocal):
            if s.name in tainted:
                return  # FIFO-streamed (or derived): CU-side only
            # AGU keeps only address-feeding locals; evaluating all
            # load-free locals is a superset and harmless
            _, lds = daelib.expr_deps(s.value)
            if not lds:
                v = eval_expr(s.value, scope)
                if not scope.set_existing(s.name, v):
                    scope.define(s.name, v)
        # nested Loop stmts cannot appear: PE stmts are flattened

    if pe.depth >= 1:
        run_depth(1, env)

    ops = {}
    for op_id in pe.mem_ops:
        r = rec[op_id]
        d = op_depth[op_id]
        n = len(r["addr"])
        ops[op_id] = OpTrace(
            op_id=op_id,
            pe_id=pe.id,
            depth=d,
            is_store=op_store[op_id],
            sched=np.array(r["sched"], dtype=np.int64).reshape(n, d),
            addr=np.array(r["addr"], dtype=np.int64).reshape(n),
            lastiter=np.array(r["lastiter"], dtype=bool).reshape(n, d),
            seq=np.array(r["seq"], dtype=np.int64).reshape(n),
        )
    return PETrace(pe_id=pe.id, ops=ops, n_leaf_iters=n_leaf)
