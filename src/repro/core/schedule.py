"""Program-order schedule generation (paper §4).

The schedule representation, per memory operation of loop depth n:

  * an n-tuple of counters, one per loop depth, each incremented by 1 at
    every invocation of that loop's body — *never reset* when inner
    loops re-enter (§4 item 2),
  * comparisons between two operations use ONLY the element at their
    innermost shared depth k, with comparator direction configured from
    topological order (§4 item 3, synthesized in hazards.py),
  * one ``lastIter`` bit per non-monotonic loop depth, computed one
    iteration in advance when the loop is ``predictable`` (§4.1/§4.2(3)),
  * at stream end the AGU emits a sentinel (schedule = +inf, addr = +inf)
    signalling no further requests (§4.2(4)).

This module runs the AGU semantics (decoupled address threads, which by
the LoD check never depend on protected load values) ahead of time and
materializes each op's full request stream — the software analogue of
the AGU "running ahead" of the compute pipeline (§2.1.1).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import dae as daelib
from repro.core import loopir as ir

SENTINEL = np.int64(2**62)


@dataclasses.dataclass
class OpTrace:
    """Full AGU request stream for one memory operation."""

    op_id: str
    pe_id: int
    depth: int
    is_store: bool
    sched: np.ndarray  # (n_req, depth) int64, counters start at 1
    addr: np.ndarray  # (n_req,) int64
    lastiter: np.ndarray  # (n_req, depth) bool
    seq: np.ndarray = None  # (n_req,) int64: per-PE AGU generation order

    @property
    def n_req(self) -> int:
        return len(self.addr)


@dataclasses.dataclass
class PETrace:
    pe_id: int
    ops: dict[str, OpTrace]
    n_leaf_iters: int  # total leaf-body invocations (for timing models)


def instance_rank_table(
    traces: dict[str, OpTrace],
    dae: "daelib.DAEResult",
    loop_pos: dict[int, int],
    op_pos: dict[str, int],
    fuse_group: dict[int, int],
    op_path: dict[str, tuple],
    key_len: Optional[int] = None,
) -> tuple[dict[str, np.ndarray], np.ndarray]:
    """Vectorized leaf-loop *instance* ranking of every request.

    Builds the polyhedral 2d+1 key of each request (static positions and
    per-depth counters interleaved, trailing leaf counter dropped so all
    iterations of one leaf instance share a key; fused siblings share the
    group leader's leaf position) as one int64 matrix per op, then ranks
    all requests globally with a single lexicographic ``np.unique``.

    Returns (per-op rank array aligned with the op's request stream,
    per-rank total request count). Replaces a per-request Python loop —
    this is what lets the sequential (LSQ) window logic run at paper
    scales.
    """
    if key_len is None and traces:
        # widest key any op can need: positions+counters interleaved for
        # every depth plus a trailing position slot
        key_len = max(2 * tr.depth + 1 for tr in traces.values())
    mats = []
    ops = sorted(traces)
    for op_id in ops:
        tr = traces[op_id]
        pe = dae.pes[tr.pe_id]
        path = op_path[op_id]
        key = np.full((tr.n_req, key_len), -1, dtype=np.int64)
        if tr.depth == pe.depth:
            for j in range(tr.depth - 1):
                key[:, 2 * j] = loop_pos[id(path[j])]
                key[:, 2 * j + 1] = tr.sched[:, j]
            leader = dae.pes[fuse_group[tr.pe_id]]
            key[:, 2 * (tr.depth - 1)] = loop_pos[id(leader.leaf)]
        else:  # parent-body op: its own micro-instance per iteration
            for j in range(tr.depth):
                key[:, 2 * j] = loop_pos[id(path[j])]
                key[:, 2 * j + 1] = tr.sched[:, j]
            key[:, 2 * tr.depth] = op_pos[op_id]
        mats.append(key)
    if not mats:
        return {}, np.zeros(0, dtype=np.int64)
    stacked = np.concatenate(mats, axis=0)
    _, inverse, counts = np.unique(
        stacked, axis=0, return_inverse=True, return_counts=True
    )
    inverse = inverse.reshape(-1)
    ranks: dict[str, np.ndarray] = {}
    off = 0
    for op_id in ops:
        n = traces[op_id].n_req
        ranks[op_id] = inverse[off : off + n]
        off += n
    return ranks, counts


def trace_program(
    program: ir.Program,
    dae: daelib.DAEResult,
    arrays: dict[str, np.ndarray],
    params: Optional[dict[str, int]] = None,
) -> dict[str, OpTrace]:
    """Generate the AGU request streams of every memory op in every PE."""
    params = params or {}
    out: dict[str, OpTrace] = {}
    for pe in dae.pes:
        t = _trace_pe(pe, arrays, params)
        out.update(t.ops)
    return out


def _trace_pe(
    pe: daelib.PE, arrays: dict[str, np.ndarray], params: dict[str, int]
) -> PETrace:
    # recorded streams per op
    rec: dict[str, dict[str, list]] = {
        op_id: {"sched": [], "addr": [], "lastiter": [], "seq": []}
        for op_id in pe.mem_ops
    }
    seq_counter = [0]
    op_depth: dict[str, int] = {}
    op_store: dict[str, bool] = {}

    # group the PE's statements by depth
    by_depth: dict[int, list[ir.Stmt]] = {}
    for s, d in pe.stmts:
        by_depth.setdefault(d, []).append(s)

    counters = [0] * (pe.depth + 1)  # 1-indexed
    n_leaf = 0

    env = ir._Env()

    def eval_expr(e: ir.Expr, scope: ir._Env):
        # AGU-side evaluation: LoadVal is impossible here (LoD check)
        return ir._eval(e, scope, arrays, params, {})

    # per-depth "is current iteration the last one" flags
    last_flags = [False] * (pe.depth + 1)

    def run_depth(d: int, scope: ir._Env):
        nonlocal n_leaf
        loop = pe.path[d - 1]
        loop_scope = ir._Env(scope)
        for iv in loop.ivars:
            loop_scope.define(iv.name, eval_expr(iv.init, scope))
        trip = int(eval_expr(loop.trip, scope))
        for i in range(trip):
            counters[d] += 1
            body = ir._Env(loop_scope)
            body.define(loop.var, i)
            # §4.2(3): lastIter computed one iteration in advance when the
            # loop predicate is predictable; otherwise the hint is 0.
            last_flags[d] = (i == trip - 1) if loop.predictable else False
            if d == pe.depth:
                n_leaf += 1
            for s in by_depth.get(d, ()):  # this depth's statements
                exec_stmt(s, body, d)
            if d < pe.depth:
                run_depth(d + 1, body)
            for iv in loop.ivars:
                cur = loop_scope.get(iv.name)
                step = eval_expr(iv.step, body)
                loop_scope.vals[iv.name] = (
                    cur + step if iv.op == "+" else cur * step
                )

    def exec_stmt(s: ir.Stmt, scope: ir._Env, d: int):
        if isinstance(s, (ir.Load, ir.Store)):
            # speculation (§6): requests are generated unconditionally —
            # guarded stores get a valid bit from the CU at sim time
            a = int(eval_expr(s.addr, scope))
            r = rec[s.id]
            r["sched"].append(tuple(counters[1 : d + 1]))
            r["addr"].append(a)
            r["lastiter"].append(tuple(last_flags[1 : d + 1]))
            r["seq"].append(seq_counter[0])
            seq_counter[0] += 1
            op_depth[s.id] = d
            op_store[s.id] = isinstance(s, ir.Store)
        elif isinstance(s, ir.SetLocal):
            # AGU keeps only address-feeding locals; evaluating all
            # load-free locals is a superset and harmless
            _, lds = daelib.expr_deps(s.value)
            if not lds:
                v = eval_expr(s.value, scope)
                if not scope.set_existing(s.name, v):
                    scope.define(s.name, v)
        # nested Loop stmts cannot appear: PE stmts are flattened

    if pe.depth >= 1:
        run_depth(1, env)

    ops = {}
    for op_id in pe.mem_ops:
        r = rec[op_id]
        d = op_depth.get(op_id, pe.depth)
        n = len(r["addr"])
        ops[op_id] = OpTrace(
            op_id=op_id,
            pe_id=pe.id,
            depth=d,
            is_store=op_store.get(op_id, False),
            sched=np.array(r["sched"], dtype=np.int64).reshape(n, d),
            addr=np.array(r["addr"], dtype=np.int64).reshape(n),
            lastiter=np.array(r["lastiter"], dtype=bool).reshape(n, d),
            seq=np.array(r["seq"], dtype=np.int64).reshape(n),
        )
    return PETrace(pe_id=pe.id, ops=ops, n_leaf_iters=n_leaf)
