"""Vectorized event-driven simulator engine (``simulate(engine="event")``).

The reference engine (core/simulator.Engine) steps Python once per
cycle: every port re-evaluates its scalar Hazard Safety Check every
cycle it is blocked, so wall-clock scales with *cycles*, not with
*requests*. This engine makes wall-clock scale with requests:

  * **Waves.** Each port's full request stream is already materialized
    as numpy arrays (schedule.trace_program). When a port is evaluated,
    the checks for a whole *slice* of its upcoming requests are computed
    at once against the current (frozen) src frontiers
    (du.check_pair_batch); the passing prefix issues as one wave at
    II=1, occupying consecutive cycles.
  * **Event queue.** Time advances only to event timestamps (DRAM burst
    close/complete, CU value arrival, forwarding latency, invalid-store
    ACK wakeups) — idle cycles are skipped entirely. Blocked ports are
    re-evaluated only when an event may have changed a frontier, not
    every cycle.
  * **Array-backed DU state.** The pending buffer of a port is the
    contiguous index window [head, next) of its trace plus per-request
    flag arrays; the ACK frontier registers are just row ``head - 1``.

Why a frozen frontier is sound: a Hazard Safety Check pass certifies a
*permanent* fact — every src request that precedes the dst request in
program order and could alias it has completed (or, in the §5.5
forwarding variant, has at least issued with its value). ACKs and issues
are irreversible and the remaining src stream only moves forward in
program order, so a request that passes against a frontier observed at
cycle t may issue at any cycle >= t with identical memory semantics.
Final arrays therefore match the cycle engine (and the oracle) exactly;
only *timing* can drift, because a wave freezes frontiers for up to one
inter-event gap. Waves are capped at the next event timestamp to bound
that drift; the observed envelope across the Table-1 matrix is
documented in DESIGN.md and asserted by tests/test_engine_diff.py.
"""

from __future__ import annotations

import heapq
from collections import deque
from typing import Optional

import numpy as np

from repro.core import coarsen as coarsenlib
from repro.core import dae as daelib
from repro.core import du as dulib
from repro.core import fifo as fifolib
from repro.core import schedule as schedlib

SENTINEL = int(schedlib.SENTINEL)


class EvPort:
    """One DU port with its whole request stream resident as arrays.

    ``next`` is the first request not yet issued; ``head`` the first not
    yet ACK-popped. The pending buffer is the window [head, next); the
    most-recent-ACK registers are row ``head - 1`` (§4.2 sentinel rules
    applied when the stream is complete and drained).
    """

    __slots__ = (
        "trace", "op_id", "pe_id", "is_store", "depth", "n",
        "sched", "addr", "lastiter",
        "head", "next", "acked", "valid", "value", "forwarded",
        "issue_cycle", "free_at",
        "val_time", "val_data", "val_valid",
        "wake_posted", "retry_posted",
        "_fa_key", "_fa_val", "_fn_key", "_fn_val",
    )

    def __init__(self, trace: schedlib.OpTrace):
        self.trace = trace
        self.op_id = trace.op_id
        self.pe_id = trace.pe_id
        self.is_store = trace.is_store
        self.depth = trace.depth
        self.n = trace.n_req
        self.sched = np.ascontiguousarray(trace.sched)
        self.addr = trace.addr
        self.lastiter = trace.lastiter
        self.head = 0
        self.next = 0
        self.acked = np.zeros(self.n, dtype=bool)
        self.valid = np.ones(self.n, dtype=bool)
        self.value = np.zeros(self.n, dtype=np.float64)
        self.forwarded = np.zeros(self.n, dtype=bool)
        self.issue_cycle = np.full(self.n, -1, dtype=np.int64)
        self.free_at = 0  # II=1 pacing: earliest cycle of the next issue
        # store-value queue from the CU, index-aligned with requests
        self.val_time: list[int] = []
        self.val_data: list[float] = []
        self.val_valid: list[bool] = []
        self.wake_posted = -1
        self.retry_posted = -1
        self._fa_key = self._fn_key = -1
        self._fa_val = self._fn_val = None

    # ---- next-request registers (same contract as du.Port) --------------

    @property
    def exhausted(self) -> bool:
        return self.next >= self.n

    def req_sched(self) -> tuple[int, ...]:
        if self.exhausted:
            return (SENTINEL,) * self.depth
        return tuple(int(x) for x in self.sched[self.next])

    def req_addr(self) -> int:
        if self.exhausted:
            return SENTINEL
        return int(self.addr[self.next])

    def req_lastiter(self) -> tuple[bool, ...]:
        if self.exhausted:
            return (True,) * self.depth
        return tuple(bool(x) for x in self.lastiter[self.next])

    @property
    def no_pending_ack(self) -> bool:
        return self.head == self.next

    def frontier(self, use_next_request: bool):
        # registers change only when head/next move: memoize on them
        if use_next_request:
            if self._fn_key != self.next:
                self._fn_key = self.next
                self._fn_val = (
                    self.req_sched(), self.req_addr(), self.req_lastiter()
                )
            return self._fn_val
        if self._fa_key == self.head:
            return self._fa_val
        self._fa_key = self.head
        if self.head >= self.n:
            # sentinel ACK: stream complete and fully drained
            val = ((SENTINEL,) * self.depth, SENTINEL, (True,) * self.depth)
        elif self.head == 0:
            val = ((0,) * self.depth, -(2**62), (False,) * self.depth)
        else:
            i = self.head - 1
            val = (
                tuple(int(x) for x in self.sched[i]),
                int(self.addr[i]),
                tuple(bool(x) for x in self.lastiter[i]),
            )
        self._fa_val = val
        return val


class _OpenBurst:
    __slots__ = ("idxs", "open_cycle", "tick_posted")

    def __init__(self, open_cycle: int):
        self.idxs: list[int] = []
        self.open_cycle = open_cycle
        self.tick_posted = False


class EventEngine:
    """LSQ / FUS1 / FUS2 execution with vectorized waves (module doc)."""

    def __init__(self, comp, traces, arrays, params, mode, p,
                 oracle_loads: Optional[dict] = None, shared=None, spec=None,
                 validate_hints: bool = False):
        self.comp = comp
        self.traces = traces
        self.mode = mode
        self.p = p
        if validate_hints:
            # MonotonicHint sanitizer (DESIGN.md §12): raises
            # analysis.deps.HintViolation before any timing runs
            from repro.analysis import deps as depslib

            depslib.check_hinted_traces(comp.program, traces)
        self.forwarding = mode == "FUS2"
        self.sequential = mode == "LSQ"
        self.burst_size = 1 if mode == "LSQ" else p.burst_size

        self.mem = {k: np.array(v, copy=True) for k, v in arrays.items()}
        self.params = params
        self.ports = {op: EvPort(tr) for op, tr in traces.items()}
        self.pairs_by_dst = comp.plan.by_dst()
        if shared is not None and shared.nodep_bits is not None:
            self.nodep_bits = shared.nodep_bits
        else:
            self.nodep_bits = dulib.nodependence_bits(comp.plan.pairs, traces)
        # reverse dependency map: when src's frontier moves (issue/pop),
        # these dst ports must be re-evaluated
        self.dsts_of: dict[str, list[str]] = {}
        for pr in comp.plan.pairs:
            self.dsts_of.setdefault(pr.src, []).append(pr.dst)
        # dirty-set scheduling: wave attempts / ACK scans / CU delivery
        # happen only for ports an event or a state change actually touched
        self.port_order = list(traces)
        self.dirty: set[str] = set(traces)
        # temporal wave coarsening (core/coarsen.BlockMemo): a
        # check-blocked attempt whose observable inputs are unchanged is
        # skipped on a key comparison instead of re-running the batch
        # checks — this is what tames the pagerank re-evaluation storm
        # without touching issue cycles (timing is bit-identical: only
        # attempts that would return False without side effects are
        # skipped; see _issue_wave for the record conditions)
        self.block_memo = coarsenlib.BlockMemo()
        self.ack_dirty: set[str] = set()
        self.deliver_dirty: set[int] = set()
        self.capped: set[str] = set()
        if shared is not None and shared.cu_factory is not None:
            self.cus = {pe.id: shared.cu_factory(pe) for pe in comp.dae.pes}
        else:
            self.cus = {
                pe.id: daelib.make_cu(
                    pe, self.mem, params, getattr(comp, "trace_mode", "auto"),
                    fifo_edges=comp.dae.fifo_edges,
                )
                for pe in comp.dae.pes
            }
        # loads popped from pending, queued for in-order CU delivery
        self.ready_loads: dict[str, deque] = {op: deque() for op in traces}
        # bounded cross-PE FIFO queues (core/fifo, DESIGN.md §11); serviced
        # from _deliver when a CU's waiting_on is a ("fifo_pop"|"fifo_push",
        # edge) tuple instead of a load op id
        self.fifos: dict[int, fifolib.FifoQueue] = {}
        if getattr(comp, "fifo", None):
            fifolib.check_depth(comp.fifo, p.fifo_depth)
            self.fifos = {
                e.idx: fifolib.FifoQueue(e, p.fifo_depth, p.fifo_latency)
                for e in comp.fifo.edges
            }
            # CUs can be fifo-blocked at t=0 with no load event ever due
            # (e.g. a load-free producer): give every PE one initial visit
            self.deliver_dirty.update(pe.id for pe in comp.dae.pes)

        if self.sequential:
            if shared is not None and shared.rank_table is not None:
                ranks, counts = shared.rank_table
            else:
                fuse = {pe.id: pe.id for pe in comp.dae.pes}  # LSQ: no fusion
                ranks, counts = schedlib.instance_rank_table(
                    traces, comp.dae, comp.loop_pos, comp.op_pos, fuse,
                    comp.op_path,
                )
            self.inst_rank = ranks
            self.inst_outstanding = counts.copy()
            self.inst_window = 0

        # speculative AGU plan (speculate.SpecPlan, DESIGN.md §10):
        # per-request epoch gates + squash traffic
        self.spec = spec
        if spec is not None:
            self.gate_time = np.full(
                max(spec.n_gates, 1), SENTINEL, dtype=np.int64
            )
            # gid -> ports with requests gated on it (wave wakeups)
            self.gate_ports: dict[int, set] = {}
            for op_id, g in spec.gates.items():
                for gid in np.unique(g[g >= 0]):
                    self.gate_ports.setdefault(int(gid), set()).add(op_id)

        self.open_bursts: dict[str, _OpenBurst] = {}
        self.channel_free_at = 0
        self.events: list[tuple[int, int, str, object]] = []
        self._n = 0
        self.now = 0
        self.oracle_loads = (
            {k: np.asarray(v) for k, v in oracle_loads.items()}
            if oracle_loads is not None
            else None
        )
        from repro.core.simulator import SimResult

        self.result = SimResult(cycles=0, arrays={}, mode=mode)

    # -- events -----------------------------------------------------------

    def _post(self, t: int, kind: str, payload=None):
        self._n += 1
        heapq.heappush(self.events, (int(t), self._n, kind, payload))

    # -- main loop --------------------------------------------------------

    def run(self):
        for cu in self.cus.values():
            self._drain_outbox(cu)
        self._settle()
        while not self._all_done():
            if not self.events:
                self._deadlock()
            t = self.events[0][0]
            self.now = t
            if self.now > self.p.max_cycles:
                raise RuntimeError("max_cycles exceeded")
            while self.events and self.events[0][0] == t:
                _, _, kind, payload = heapq.heappop(self.events)
                self._event(kind, payload)
            self._settle()
        self.result.cycles = self.now
        self.result.arrays = self.mem
        self.result.fifo_stats = [q.stats() for q in self.fifos.values()]
        if self.spec is not None:
            self.result.spec_stats = self.spec.stats()
        return self.result

    def _all_done(self):
        return (
            all(p.head >= p.n for p in self.ports.values())
            and all(cu.done for cu in self.cus.values())
            and not self.open_bursts
            and not self.events
        )

    def _deadlock(self):
        lines = [f"DEADLOCK at cycle {self.now} mode={self.mode} (event engine)"]
        for op_id, p in self.ports.items():
            lines.append(
                f"  {op_id}: next={p.next}/{p.n} head={p.head}"
                f" frontier={p.frontier(False)}"
            )
        for pe_id, cu in self.cus.items():
            lines.append(f"  cu{pe_id}: done={cu.done} waiting={cu.waiting_on}")
        for q in self.fifos.values():
            lines.append(
                f"  fifo {q.edge.describe()}: occ={q.occupancy}/{q.depth}"
                f" pushed={q.pushed} popped={q.popped}"
            )
        raise RuntimeError("\n".join(lines))

    # -- settle: fixpoint of combinational progress at self.now -----------

    def _touch_dependents(self, op_id: str):
        for d in self.dsts_of.get(op_id, ()):
            self.dirty.add(d)

    def _settle(self):
        # ports capped by the previous horizon get another shot now
        self.dirty |= self.capped
        self.capped.clear()
        while self.ack_dirty or self.deliver_dirty or self.dirty:
            if self.ack_dirty:
                batch = [o for o in self.port_order if o in self.ack_dirty]
                self.ack_dirty.clear()
                for op_id in batch:
                    if self._ack_scan(self.ports[op_id]):
                        self._touch_dependents(op_id)
            if self.deliver_dirty:
                self._deliver()
            if self.sequential and self._advance_window():
                self.dirty.update(
                    op for op, p in self.ports.items() if not p.exhausted
                )
            if self.dirty:
                # deterministic trace order, like the cycle engine's scan
                batch = [o for o in self.port_order if o in self.dirty]
                self.dirty.clear()
                for op_id in batch:
                    port = self.ports[op_id]
                    if not port.exhausted and self._issue_wave(op_id, port):
                        self._touch_dependents(op_id)

    # -- wave issue -------------------------------------------------------

    def _issue_wave(self, op_id: str, port: EvPort) -> bool:
        start = max(self.now, port.free_at)
        horizon = self.events[0][0] if self.events else None
        if horizon is not None and start >= horizon:
            self.capped.add(op_id)
            return False
        n0 = port.next
        # temporal coarsening: when a prior attempt was check-blocked on
        # its first request with every consulted src *current* (no
        # future-stamped issue cycles — checks a pure function of the
        # src (head, next) windows), an attempt with an identical
        # fingerprint must fail identically, with no side effects to
        # replay — skip it (coarsen.BlockMemo doc)
        memo_key = coarsenlib.BlockMemo.key(
            n0, len(port.val_time),
            tuple(
                (self.ports[pr.src].head, self.ports[pr.src].next)
                for pr in self.pairs_by_dst.get(op_id, ())
            ),
        )
        if self.block_memo.probe(op_id, memo_key):
            return False
        m = port.n - n0
        capped = False
        if horizon is not None and horizon - start < m:
            m = horizon - start
            capped = True

        if self.sequential:
            # sequential window: ranks are non-decreasing per stream
            r = self.inst_rank[op_id][n0 : n0 + m]
            m2 = int(np.searchsorted(r, self.inst_window, side="right"))
            if m2 < m:
                m, capped = m2, False  # window-gated: woken on advance
            if m <= 0:
                return False

        # speculative AGU: cut the wave at the first unresolved epoch
        # gate (ids are non-decreasing along every stream). Fired gates
        # need no cycle lower bound: a gate's fire time is the event
        # timestamp it was processed at, so any later wave has
        # start >= now >= gate_time already.
        if self.spec is not None:
            g = self.spec.gates.get(op_id)
            if g is not None:
                gs = g[n0 : n0 + m]
                if len(gs) and gs[-1] >= 0:
                    unfired = (gs >= 0) & (
                        self.gate_time[np.maximum(gs, 0)] >= SENTINEL
                    )
                    if unfired.any():
                        m2 = int(np.argmax(unfired))
                        m, capped = m2, False  # woken by spec_fire
                        if m <= 0:
                            return False

        if port.is_store:
            # §5.5: a store issues only together with its value
            avail = len(port.val_time) - n0
            if avail < m:
                m, capped = avail, False  # value-starved: woken on cu_value
            if m <= 0:
                return False
            vt = np.asarray(port.val_time[n0 : n0 + m], dtype=np.int64)
            cyc = np.maximum(vt, start + np.arange(m, dtype=np.int64))
            # enforce II=1 spacing: cyc strictly increasing by >= 1
            cyc = np.maximum.accumulate(cyc - np.arange(m)) + np.arange(m)
            if horizon is not None:
                m2 = int(np.searchsorted(cyc, horizon, side="left"))
                if m2 < m:
                    m, capped = m2, True
                if m <= 0:
                    self.capped.add(op_id)
                    return False
                cyc = cyc[:m]
        else:
            cyc = start + np.arange(m, dtype=np.int64)

        sl_sched = port.sched[n0 : n0 + m]
        sl_addr = port.addr[n0 : n0 + m]
        ok = np.ones(m, dtype=bool)
        all_current = True  # every consulted src current so far
        for pair in self.pairs_by_dst.get(op_id, ()):
            if self.sequential and not pair.same_pe:
                continue  # LSQ: cross-loop order enforced by instances
            src = self.ports[pair.src]
            use_next = (
                self.forwarding and pair.kind == "RAW" and src.is_store
            )
            bits = None
            if pair.nodependence:
                full = self.nodep_bits.get((pair.dst, pair.src))
                bits = full[n0 : n0 + m] if full is not None else None
                if bits is None:
                    bits = np.zeros(m, dtype=bool)
            # Terms that read the src *next-request* registers would leak
            # future wave issues into earlier cycles; reconstruct them
            # per-request from the src's stamped issue cycles. Fast path:
            # when the src has no issues stamped beyond `now` (the common
            # case outside same-settle interactions), the registers are
            # constant over the wave and the frozen scalars are exact.
            src_current = (
                src.next == 0 or src.issue_cycle[src.next - 1] <= self.now
            )
            all_current &= src_current
            frontier = None
            next_state = None
            if not src_current:
                if use_next:
                    frontier = self._frontier_at(src, cyc)
                elif pair.shared_depth > 0:
                    next_state = self._next_state_at(
                        src, cyc, pair.shared_depth
                    )
            ok &= dulib.check_pair_batch(
                pair, sl_sched, sl_addr, src, use_next, bits,
                frontier=frontier, next_state=next_state,
            )
            if not ok[0]:
                # check-blocked on the first request. Record the
                # fingerprint only when every consulted src is current
                # (outcome independent of time) and outside LSQ mode
                # (the sequential window is not in the key); a current
                # prefix also guarantees _schedule_usenext_retry posts
                # nothing (all stamped issues <= now <= cyc[0]), so a
                # skipped replay loses no event.
                if all_current and not self.sequential:
                    self.block_memo.record(op_id, memo_key)
                self._schedule_usenext_retry(op_id, port, int(cyc[0]))
                return False
        L = m if ok.all() else int(np.argmin(ok))
        if L < m:
            # Prefix-blocked. Checks against ACK frontiers resolve via
            # events (touch_dependents), but the §5.5 next-request
            # frontier also advances with *time* through src issue
            # cycles stamped by earlier waves — schedule a retry at the
            # next such advance or the blocked request starves until the
            # next unrelated event.
            self._schedule_usenext_retry(op_id, port, int(cyc[L]))
        if L <= 0:
            return False
        if L == m and capped:
            self.capped.add(op_id)  # ran to the horizon: more may go then
        cyc = cyc[:L]
        end = n0 + L

        port.issue_cycle[n0:end] = cyc
        port.next = end
        port.free_at = int(cyc[-1]) + 1

        if port.is_store:
            port.value[n0:end] = port.val_data[n0:end]
            port.valid[n0:end] = port.val_valid[n0:end]
            any_invalid = False
            for j in range(L):
                i = n0 + j
                if port.valid[i]:
                    self._enqueue_burst(port, i, int(cyc[j]))
                else:
                    # Fig. 7: invalid stores skip DRAM; they ACK when
                    # they reach the pending-buffer head (_ack_scan) —
                    # flag the port or nothing ever scans it
                    any_invalid = True
            if any_invalid:
                self.ack_dirty.add(op_id)
        elif self.forwarding:
            for j in range(L):
                i = n0 + j
                if not self._try_forward(op_id, port, i, int(cyc[j])):
                    self._enqueue_burst(port, i, int(cyc[j]))
        else:
            for j in range(L):
                self._enqueue_burst(port, n0 + j, int(cyc[j]))
        return True

    def _schedule_usenext_retry(self, op_id: str, port: EvPort, fail_cyc: int):
        if not self.forwarding:
            return
        t_min = None
        for pair in self.pairs_by_dst.get(op_id, ()):
            src = self.ports[pair.src]
            if not (pair.kind == "RAW" and src.is_store):
                continue
            issued = src.issue_cycle[: src.next]
            pos = int(np.searchsorted(issued, fail_cyc, side="right"))
            if pos < src.next:
                t = int(issued[pos])
                if t_min is None or t < t_min:
                    t_min = t
        if t_min is not None and port.retry_posted < t_min:
            port.retry_posted = t_min
            self._post(t_min, "retry", op_id)

    # -- per-cycle src state reconstruction -------------------------------

    def _next_index_at(self, src: EvPort, cyc: np.ndarray) -> np.ndarray:
        """The src port's next-request *index* as of each cycle in
        ``cyc``: the count of src requests already issued by then. Issue
        cycles are strictly increasing per port, so this is exact."""
        return np.searchsorted(
            src.issue_cycle[: src.next], cyc, side="right"
        )

    def _frontier_at(self, src: EvPort, cyc: np.ndarray):
        """Per-request next-request registers (§5.5 forwarding variant)
        of ``src`` as of each dst issue cycle — sched row, addr, and
        lastIter bits, with the §4.2(4) sentinel once the stream ends."""
        nxt = self._next_index_at(src, cyc)
        done = nxt >= src.n
        idx = np.minimum(nxt, max(src.n - 1, 0))
        if src.n == 0:
            m = len(cyc)
            return (
                np.full((m, src.depth), SENTINEL, dtype=np.int64),
                np.full(m, SENTINEL, dtype=np.int64),
                np.ones((m, src.depth), dtype=bool),
            )
        f_sched = np.where(done[:, None], SENTINEL, src.sched[idx])
        f_addr = np.where(done, SENTINEL, src.addr[idx])
        f_last = np.where(done[:, None], True, src.lastiter[idx])
        return f_sched, f_addr, f_last

    def _next_state_at(self, src: EvPort, cyc: np.ndarray, k: int):
        """Per-request (next-request sched at depth k, noPendingAck) of
        ``src`` as of each dst issue cycle — the §5.2 second line."""
        nxt = self._next_index_at(src, cyc)
        if src.n == 0:
            m = len(cyc)
            return np.full(m, SENTINEL, dtype=np.int64), np.ones(m, bool)
        done = nxt >= src.n
        idx = np.minimum(nxt, src.n - 1)
        next_sched_k = np.where(done, SENTINEL, src.sched[idx, k - 1])
        no_pend = nxt == src.head
        return next_sched_k, no_pend

    # -- §5.5 forwarding --------------------------------------------------

    def _try_forward(self, op_id: str, port: EvPort, i: int, cycle: int) -> bool:
        """Associative pending-buffer search, youngest match wins; only
        program-order-earlier entries *already issued by this load's
        cycle* qualify (the buffer as the DU would see it then). Mirrors
        the cycle engine's _try_forward incl. its >= tie-breaking."""
        addr_i = int(port.addr[i])
        best = None  # (key, src op, global entry index)
        for pair in self.pairs_by_dst.get(op_id, ()):
            if pair.kind != "RAW":
                continue
            sport = self.ports[pair.src]
            h, nx = sport.head, sport.next
            if h >= nx:
                continue
            mask = (
                (sport.addr[h:nx] == addr_i)
                & sport.valid[h:nx]
                & (sport.issue_cycle[h:nx] <= cycle)
            )
            k = pair.shared_depth
            if k > 0:
                es = sport.sched[h:nx, k - 1]
                rs = int(port.sched[i, k - 1])
                before = (es < rs) | ((es == rs) & (not pair.dst_before_src))
                mask &= before
            else:
                if pair.dst_before_src:
                    continue  # dst precedes src topologically: never before
            hits = np.nonzero(mask)[0]
            if len(hits) == 0:
                continue
            j = int(hits[-1]) + h  # youngest: sched non-decreasing in stream
            key = (
                int(sport.sched[j, k - 1]) if k > 0 else 0,
                not pair.dst_before_src,
            )
            if best is None or key >= best[0]:
                best = (key, pair.src, j)
        if best is None:
            return False
        _, src_op, j = best
        port.value[i] = self.ports[src_op].value[j]
        port.forwarded[i] = True
        self.result.forwards += 1
        self._post(
            int(port.issue_cycle[i]) + self.p.forward_latency,
            "fwd_ready",
            (op_id, i),
        )
        return True

    # -- bursts -----------------------------------------------------------

    def _enqueue_burst(self, port: EvPort, i: int, cycle: int):
        op_id = port.op_id
        b = self.open_bursts.get(op_id)
        if b is not None and cycle - b.open_cycle >= self.p.burst_timeout:
            # the wave ran past the open burst's timeout: close it there
            self._close_burst(op_id, b.open_cycle + self.p.burst_timeout)
            b = None
        if b is None:
            b = _OpenBurst(cycle)
            self.open_bursts[op_id] = b
        b.idxs.append(i)
        if len(b.idxs) >= self.burst_size:
            self._close_burst(op_id, cycle)
        elif not b.tick_posted:
            # a lingering burst closes burst_timeout after opening (§2.1.1)
            b.tick_posted = True
            self._post(
                b.open_cycle + self.p.burst_timeout, "burst_tick",
                (op_id, b.open_cycle),
            )

    def _close_burst(self, op_id: str, close_cycle: int):
        b = self.open_bursts.pop(op_id)
        self._post(close_cycle, "burst_close", (op_id, np.asarray(b.idxs)))

    # -- event handlers ---------------------------------------------------

    def _event(self, kind: str, payload):
        if kind == "burst_close":
            # the DRAM channel serves bursts in close order (heap order)
            op_id, idxs = payload
            issue = max(self.now, self.channel_free_at)
            self.channel_free_at = issue + self.p.channel_occupancy
            complete = issue + self.p.channel_occupancy + self.p.dram_latency
            self.result.dram_bursts += 1
            self.result.dram_requests += len(idxs)
            self._post(complete, "burst_done", (op_id, idxs))
        elif kind == "burst_done":
            op_id, idxs = payload
            port = self.ports[op_id]
            arr = self.mem[self.comp.op_array[op_id]]
            addrs = port.addr[idxs]
            if port.is_store:
                vals = port.value[idxs]
                if len(np.unique(addrs)) == len(addrs):
                    arr[addrs] = vals
                else:  # duplicate addresses in one burst: last write wins
                    u, last = np.unique(addrs[::-1], return_index=True)
                    arr[u] = vals[::-1][last]
            else:
                port.value[idxs] = arr[addrs]
            port.acked[idxs] = True
            self.ack_dirty.add(op_id)
        elif kind == "burst_tick":
            op_id, open_cycle = payload
            b = self.open_bursts.get(op_id)
            if b is not None and b.open_cycle == open_cycle:
                self._close_burst(op_id, self.now)
        elif kind == "fwd_ready":
            op_id, i = payload
            self.ports[op_id].acked[i] = True
            self.ack_dirty.add(op_id)
        elif kind == "cu_value":
            op_id, value, valid = payload
            port = self.ports[op_id]
            port.val_time.append(self.now)
            port.val_data.append(value)
            port.val_valid.append(valid)
            self.dirty.add(op_id)
        elif kind == "wake":
            self.ack_dirty.add(payload)
        elif kind == "retry":
            self.dirty.add(payload)
        elif kind == "spec_fire":
            self._fire_gate(payload)
        elif kind == "fifo_tick":
            # a queued FIFO token matured (or a push landed): revisit the
            # PE named in the payload so _deliver can unblock it
            self.deliver_dirty.add(payload)
        else:  # pragma: no cover
            raise ValueError(kind)

    def _fire_gate(self, gid: int):
        """Squash of epoch ``gid`` completes: open the gate, wake the
        gated ports, and release the phantom traffic through the shared
        accounting (``speculate.fire_phantoms`` — one body for both
        engines keeps their counters bit-identical; phantoms never
        touch hazard-visible port state, DESIGN.md §10)."""
        if self.gate_time[gid] <= self.now:
            return
        self.gate_time[gid] = self.now
        self.dirty.update(self.gate_ports.get(gid, ()))
        from repro.core import speculate as speclib

        self.channel_free_at = speclib.fire_phantoms(
            self.spec, gid, self.now, self.channel_free_at,
            self.burst_size, self.p.channel_occupancy, self.result,
        )

    # -- ACK frontier -----------------------------------------------------

    def _ack_scan(self, port: EvPort) -> bool:
        """Pop the ACKed prefix of the pending window, advancing the ACK
        registers (row head-1). Mis-speculated stores ACK one cycle after
        issue once they reach the buffer head (Fig. 7), without DRAM."""
        h0 = port.head
        h, nx = h0, port.next
        while h < nx:
            if port.acked[h]:
                h += 1
                continue
            if port.is_store and not port.valid[h]:
                t = int(port.issue_cycle[h]) + 1
                if t <= self.now:
                    port.acked[h] = True
                    h += 1
                    continue
                if port.wake_posted < t:
                    port.wake_posted = t
                    self._post(t, "wake", port.op_id)
            break
        if h == h0:
            return False
        popped = np.arange(h0, h)
        port.head = h
        if not port.is_store:
            if self.oracle_loads is not None:
                self._validate_loads(port, popped)
            self.ready_loads[port.op_id].extend(popped.tolist())
            self.deliver_dirty.add(port.pe_id)
            if self.spec is not None:
                # gated value delivered: squash gates fire
                # squash_latency later, wait gates at delivery
                # (SpecPlan.fire_delay)
                rv = self.spec.resolve_of.get(port.op_id)
                if rv is not None:
                    sel = popped[popped < len(rv)]
                    for gid in rv[sel]:
                        if gid >= 0:
                            gid = int(gid)
                            self._post(
                                self.now
                                + self.spec.fire_delay(
                                    gid, self.p.squash_latency
                                ),
                                "spec_fire", gid,
                            )
        if self.sequential:
            r = self.inst_rank[port.op_id][popped]
            np.subtract.at(self.inst_outstanding, r, 1)
        return True

    def _validate_loads(self, port: EvPort, popped: np.ndarray):
        exp = self.oracle_loads[port.op_id][popped]
        got = port.value[popped]
        bad = ~np.isclose(got, exp, atol=1e-9)
        if bad.any():
            i = int(popped[np.argmax(bad)])
            raise AssertionError(
                f"HAZARD VIOLATION: {port.op_id}[{i}] addr={port.addr[i]} "
                f"got {port.value[i]} expected {self.oracle_loads[port.op_id][i]} "
                f"at cycle {self.now} sched={tuple(port.sched[i])} "
                f"(forwarded={bool(port.forwarded[i])}) — re-run with "
                f"engine='cycle', validate=True for per-request issue logs"
            )

    # -- CU delivery ------------------------------------------------------

    def _deliver(self) -> bool:
        progressed = False
        pes = self.deliver_dirty
        self.deliver_dirty = set()
        for pe_id in pes:
            cu = self.cus[pe_id]
            while cu.waiting_on is not None:
                if isinstance(cu.waiting_on, tuple):
                    # FIFO wait (DESIGN.md §11): ("fifo_pop"|"fifo_push", e)
                    if not self._service_fifo_wait(pe_id, cu):
                        break
                    progressed = True
                    continue
                q = self.ready_loads.get(cu.waiting_on)
                if not q:
                    break
                i = q.popleft()
                cu.feed(float(self.ports[cu.waiting_on].value[i]), self.now)
                self._drain_outbox(cu)
                progressed = True
        return progressed

    def _service_fifo_wait(self, pe_id: int, cu) -> bool:
        """Try to satisfy one FIFO pop/push wait; False → still blocked."""
        kind, eidx = cu.waiting_on
        q = self.fifos[eidx]
        if kind == "fifo_pop":
            if not q.head_ready(self.now):
                if q.q:
                    # token in flight: wake this consumer when it matures
                    self._post(q.next_ready_time(), "fifo_tick", pe_id)
                q.pop_stalls += 1
                return False
            cu.feed(q.pop(self.now), self.now)
            # a slot freed: a producer backpressured on this edge can go
            self.deliver_dirty.add(q.edge.prod_pe)
        else:  # fifo_push
            if not q.can_push():
                q.push_stalls += 1
                return False
            q.push(cu.push_value, self.now)
            self._post(self.now + q.latency, "fifo_tick", q.edge.cons_pe)
            cu.feed(0.0, self.now)  # push ack; value is ignored
        self._drain_outbox(cu)
        return True

    def _drain_outbox(self, cu):  # daelib.CU or daelib.VecCU
        for op_id, v, valid in cu.outbox:
            self._post(self.now + self.p.cu_latency, "cu_value", (op_id, v, valid))
        cu.outbox.clear()

    def _advance_window(self) -> bool:
        progressed = False
        while (
            self.inst_window < len(self.inst_outstanding)
            and self.inst_outstanding[self.inst_window] == 0
        ):
            self.inst_window += 1
            progressed = True
        return progressed
