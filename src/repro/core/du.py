"""Data Unit hardware model (paper §5, Fig. 4).

Per-memory-op port state and the synthesized Hazard Safety Check
evaluation. The port tracks, exactly as the paper's DU does:

  * the (address, schedule, lastIter) of the most recent ACK,
  * the (address, schedule, lastIter) of the next request to be sent,
  * a pending buffer (FIFO) of requests sent but not yet ACKed — for
    stores it also holds values (+ §6 valid bits) enabling the
    associative store-to-load forwarding search (§5.5),
  * the ``noPendingAck`` single-bit term (§5.2),
  * sentinel propagation: when the AGU stream ends, the next-request
    registers go to +inf; once the pending buffer drains the ACK
    registers follow (§4.2(4)).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import hazards as hz
from repro.core import schedule as sched

SENTINEL = int(sched.SENTINEL)


@dataclasses.dataclass
class PendingEntry:
    req_idx: int
    addr: int
    sched: tuple[int, ...]
    lastiter: tuple[bool, ...]
    # store-side value state
    value: Optional[float] = None
    valid: Optional[bool] = None  # None = value not yet arrived from CU
    issued: bool = False  # sent to DRAM
    acked: bool = False
    # load-side
    forwarded: bool = False


class Port:
    """One DU port (one load or store operation)."""

    def __init__(self, trace: sched.OpTrace):
        self.trace = trace
        self.op_id = trace.op_id
        self.is_store = trace.is_store
        self.depth = trace.depth
        self.next = 0  # index of next request not yet moved to pending
        self.pending: list[PendingEntry] = []
        # ACK frontier registers
        self.ack_sched: tuple[int, ...] = tuple([0] * trace.depth)
        self.ack_addr: int = -(2**62)
        self.ack_lastiter: tuple[bool, ...] = tuple([False] * trace.depth)
        self.acked_count = 0
        # loads: values delivered to the CU, in order
        self.delivered = 0

    # ---- next-request registers ------------------------------------------

    @property
    def exhausted(self) -> bool:
        return self.next >= self.trace.n_req

    def req_sched(self) -> tuple[int, ...]:
        if self.exhausted:
            return tuple([SENTINEL] * self.depth)
        return tuple(int(x) for x in self.trace.sched[self.next])

    def req_addr(self) -> int:
        if self.exhausted:
            return SENTINEL
        return int(self.trace.addr[self.next])

    def req_lastiter(self) -> tuple[bool, ...]:
        if self.exhausted:
            return tuple([True] * self.depth)
        return tuple(bool(x) for x in self.trace.lastiter[self.next])

    @property
    def no_pending_ack(self) -> bool:
        return not any(not e.acked for e in self.pending)

    # ---- frontier views used by the checks ---------------------------------

    def frontier(self, use_next_request: bool):
        """(sched, addr, lastiter, drained) of the consulted frontier.

        ``use_next_request=True`` is the §5.5 forwarding variant: consult
        the *next request* registers instead of the most recent ACK.
        """
        if use_next_request:
            return self.req_sched(), self.req_addr(), self.req_lastiter()
        if self.exhausted and not self.pending:
            # sentinel ACK: stream complete and fully drained
            return (
                tuple([SENTINEL] * self.depth),
                SENTINEL,
                tuple([True] * self.depth),
            )
        return self.ack_sched, self.ack_addr, self.ack_lastiter

    def update_ack(self, e: PendingEntry):
        self.ack_sched = e.sched
        self.ack_addr = e.addr
        self.ack_lastiter = e.lastiter
        self.acked_count += 1


def nodependence_bits(
    pairs: list[hz.HazardPair], traces: dict[str, sched.OpTrace]
) -> dict[tuple[str, str], np.ndarray]:
    """Precompute the §5.6 NoDependence bit stream of every pair that
    synthesizes the term: bit[i] is True when dst request i's address is
    strictly above the youngest preceding src request's address (both
    streams innermost-monotonic), i.e. no intra-loop dependence exists."""
    out: dict[tuple[str, str], np.ndarray] = {}
    for pr in pairs:
        if not pr.nodependence:
            continue
        lt, st = traces[pr.dst], traces[pr.src]
        idx = np.searchsorted(st.seq, lt.seq, side="left") - 1
        prev = np.where(idx >= 0, st.addr[np.maximum(idx, 0)], -(2**62))
        out[(pr.dst, pr.src)] = lt.addr > prev
    return out


def _cmp(a: int, b: int, op: str) -> bool:
    return a <= b if op == "<=" else a < b


def check_pair(
    pair: hz.HazardPair,
    req_sched_a: tuple[int, ...],
    req_addr_a: int,
    src: Port,
    use_next_request: bool = False,
    nodep_bit: bool = False,
    explain: Optional[list] = None,
) -> bool:
    """Evaluate the synthesized Hazard Safety Check (§5.4) for the next
    dst request against the src frontier. Mirrors the paper equations
    term for term."""
    k = pair.shared_depth
    f_sched, f_addr, f_lastiter = src.frontier(use_next_request)

    # --- Program Order Safety Check (§5.2) ---
    if k == 0:
        # no shared loops: relative order == topological order. dst after
        # src topologically -> never "before" in program order.
        program_order_ok = pair.dst_before_src
    else:
        c = pair.comparator
        program_order_ok = _cmp(req_sched_a[k - 1], f_sched[k - 1], c)
        if not program_order_ok and not use_next_request:
            # second line: no further src requests in the considered range
            program_order_ok = (
                _cmp(req_sched_a[k - 1], src.req_sched()[k - 1], c)
                and src.no_pending_ack
            )
    if program_order_ok:
        if explain is not None:
            explain.append(
                f"{pair.dst}<={pair.src}: PO ok (req={req_sched_a} "
                f"f_sched={f_sched} next={src.req_sched()} "
                f"nopend={src.no_pending_ack})"
            )
        return True

    # --- No Address Reset Check (§5.3) ---
    reset_ok = all(f_lastiter[j - 1] for j in pair.lastiter_depths)
    if reset_ok and pair.l_depth is not None:
        l = pair.l_depth
        reset_ok = req_sched_a[l - 1] == f_sched[l - 1] + pair.delta
        # sentinel frontier: the source is fully complete, no reset possible
        if f_sched[l - 1] >= SENTINEL:
            reset_ok = True

    # --- §5.6 NoDependence term (intra-loop RAW) ---
    if pair.nodependence and nodep_bit and reset_ok:
        if explain is not None:
            explain.append(f"{pair.dst}<={pair.src}: NoDependence ok")
        return True

    # --- address frontier comparison (needs innermost monotonicity, §3.1) ---
    if pair.use_frontier or f_addr >= SENTINEL:
        ok = req_addr_a < f_addr and reset_ok
        if ok and explain is not None:
            explain.append(
                f"{pair.dst}<={pair.src}: ADDR ok (addr={req_addr_a} "
                f"f_addr={f_addr} reset_ok={reset_ok} f_sched={f_sched} "
                f"req_sched={req_sched_a} lastiter={f_lastiter})"
            )
        return ok

    return False


def check_pair_batch(
    pair: hz.HazardPair,
    req_sched: np.ndarray,  # (m, dst_depth) int64
    req_addr: np.ndarray,  # (m,) int64
    src,  # any object with frontier()/req_sched()/no_pending_ack
    use_next_request: bool = False,
    nodep_bits: Optional[np.ndarray] = None,  # (m,) bool, §5.6 slice
    frontier: Optional[tuple] = None,  # per-request frontier override
    next_state: Optional[tuple] = None,  # per-request (next_sched_k, no_pend)
) -> np.ndarray:
    """Vectorized ``check_pair``: evaluate the synthesized Hazard Safety
    Check for ``m`` consecutive dst requests. Returns an (m,) bool array.

    By default the src frontier is frozen at its current state for the
    whole batch — sound, because a pass certifies the permanent
    program-order/completion fact the paper's check establishes (ACKs
    are irreversible and the remaining src stream only moves forward in
    program order), so a request that passes against a frontier observed
    at cycle t may issue at any cycle >= t with identical memory
    semantics. The event engine passes per-request overrides for the
    terms that would otherwise leak *future* src state into earlier
    cycles of a wave:

      * ``frontier`` = (f_sched (m, d_src), f_addr (m,), f_last (m, d_src))
        — used for the §5.5 forwarding variant, reconstructed from the
        src port's stamped issue cycles;
      * ``next_state`` = (next_sched_k (m,), no_pending_ack (m,)) — the
        second Program-Order line, likewise time-reconstructed.

    **Config batching.** All stateful inputs additionally accept a
    leading *config* axis: ``frontier`` arrays of shape ``(C, m, d)`` /
    ``(C, m)``, ``next_state`` of ``(C, m)``, ``nodep_bits`` of
    ``(C, m)`` — one row per sweep configuration evaluating the same
    ``m`` dst requests against per-config DU states. The result then has
    shape ``(C, m)``. This is how the DSE sweep runner
    (``repro.dse.runner``) evaluates one pair across a whole group of
    design points in a single call instead of C scalar-slice calls.

    Term-for-term mirror of ``check_pair``; tests assert elementwise
    equivalence against the scalar version (and config-stacked calls
    against per-config calls).
    """
    m = len(req_addr)
    k = pair.shared_depth
    le = pair.comparator == "<="

    if frontier is not None:
        f_sched_rows, f_addr, f_last_rows = frontier
    else:
        f_sched, f_addr, f_lastiter = src.frontier(use_next_request)

    def f_sched_at(depth: int):
        if frontier is not None:
            return f_sched_rows[..., depth - 1]
        return f_sched[depth - 1]

    # --- Program Order Safety Check (§5.2) ---
    # terms are Python bools or (m,) arrays; | and & broadcast either way
    if k == 0:
        po = pair.dst_before_src
    else:
        col = req_sched[:, k - 1]
        fk = f_sched_at(k)
        po = (col <= fk) if le else (col < fk)
        if not use_next_request:
            if next_state is not None:
                next_sched_k, no_pend = next_state
            else:
                next_sched_k = src.req_sched()[k - 1]
                no_pend = src.no_pending_ack
            second = (col <= next_sched_k) if le else (col < next_sched_k)
            po = po | (second & no_pend)

    # --- No Address Reset Check (§5.3) ---
    if frontier is not None:
        reset = True
        for j in pair.lastiter_depths:
            reset = reset & f_last_rows[..., j - 1]
    else:
        reset = all(f_lastiter[j - 1] for j in pair.lastiter_depths)
    if pair.l_depth is not None:
        l = pair.l_depth
        fl = f_sched_at(l)
        # sentinel frontier: source fully complete, no reset possible
        reset = reset & ((req_sched[:, l - 1] == fl + pair.delta) | (fl >= SENTINEL))

    ok = po

    # --- §5.6 NoDependence term (intra-loop RAW) ---
    if pair.nodependence and nodep_bits is not None:
        ok = ok | (nodep_bits & reset)

    # --- address frontier comparison (§3.1 monotonicity) ---
    if pair.use_frontier:
        ok = ok | ((req_addr < f_addr) & reset)
    else:
        # the addr disjunct is not synthesized; it still admits when the
        # source frontier is the completion sentinel
        ok = ok | ((req_addr < f_addr) & reset & (f_addr >= SENTINEL))

    if np.ndim(ok) == 0:
        return np.full(m, bool(ok))
    return ok
