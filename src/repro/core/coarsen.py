"""Wave coarsening: two faces of "grow the unit of progress".

Both execution paths in this repo advance in *units* whose fixed
per-unit overhead can dominate wall-clock when the units are small:

  * the TPU wave executor (``core/executor.py`` / ``kernels/wave_exec``)
    pays one gather→scatter step per wave — a kernel with thousands of
    short dependence chains produces thousands of near-empty waves,
  * the event engine (``core/engine_event.py``) pays one vectorized
    Hazard Safety Check evaluation per wave *attempt* — a port that is
    check-blocked gets re-evaluated on every event that dirties it,
    even when nothing its checks read has moved (the pagerank
    re-evaluation storm: ~100k attempts for ~43k requests).

This module holds the shared coarsening abstraction for both
(ROADMAP item 1):

  * ``batch_conflict_free_waves`` — **spatial** coarsening: merge runs
    of consecutive waves into one *step* whenever the merged batch
    stays executable as a single gather-before-scatter unit (see the
    function doc for the exact admission rule),
  * ``BlockMemo`` — **temporal** coarsening: collapse repeated blocked
    wave attempts whose entire observable input state is unchanged
    into a single key comparison, so a port is re-checked only when a
    frontier it actually reads has moved.

Both are pure bookkeeping over integer state — no numerics, no timing
model — which is what lets one module serve an executor backend and a
cycle-conformant simulator engine without coupling them.
"""

from __future__ import annotations

import numpy as np


def batch_conflict_free_waves(
    req_wave: np.ndarray,
    req_flat: np.ndarray,
    req_store: np.ndarray,
    feed_max_wave: np.ndarray,
    symbolic_free: np.ndarray = None,
) -> tuple[np.ndarray, int]:
    """Greedily merge consecutive waves into batched steps.

    Consecutive waves always have at least one cross edge (that is what
    makes them consecutive), so "merge iff no edges" would never merge
    anything. The usable slack is that a gather-before-scatter step
    tolerates WAR edges *inside* the batch: every load gathers the
    pre-step image, so a store overwriting an address a same-batch
    (earlier-wave) load reads cannot be observed by it. A wave ``w``
    joins the batch that started at wave ``b`` iff:

      * every store in ``w`` has all feeding loads in waves strictly
        before ``b`` (its value/guard are computed *before* the step's
        memory traffic moves — same-batch load values do not exist yet),
      * no store in ``w`` targets an address already **stored** in the
        batch (WAW — the step's scatter admits no duplicate write
        lanes),
      * no load in ``w`` reads an address already stored in the batch
        (RAW — it would need the post-store value, but gathers see the
        pre-step image).

    ``feed_max_wave[i]`` is the max wave over request *i*'s feeding
    loads (−1 for loads and dep-free stores) — ``executor`` computes it
    from the plan's dep maps. Returns ``(step_of_wave, n_steps)`` with
    ``step_of_wave`` non-decreasing, so waves stay contiguous inside
    their step and the wave order is preserved batch-internally.

    ``symbolic_free`` is the certifier's admission fast path
    (``analysis.deps.symbolically_free_ops``, DESIGN.md §12): a (n,)
    bool marking requests of ops *proven* address-disjoint from every
    batched store (stores additionally proven self-injective). Such
    requests skip the ``stored``-set membership test and — for stores —
    the insertion: both are statically known no-ops, so the produced
    batching is bit-identical (tested in tests/test_deps.py) while whole
    dep-edges are admitted without enumerating a single address. The
    dataflow feed check is *not* skipped — it is about value
    availability, not address conflicts.
    """
    n = len(req_wave)
    n_waves = int(req_wave.max()) + 1 if n else 0
    step_of_wave = np.zeros(n_waves, dtype=np.int64)
    if n_waves == 0:
        return step_of_wave, 0
    if symbolic_free is None:
        symbolic_free = np.zeros(n, dtype=bool)
    order = np.argsort(req_wave, kind="stable")
    bounds = np.searchsorted(req_wave[order], np.arange(n_waves + 1))
    step = 0
    batch_start = 0
    stored: set[int] = set()  # flat addresses stored by the open batch
    for w in range(n_waves):
        rows = order[bounds[w]:bounds[w + 1]]
        if w != batch_start:
            ok = True
            for i in rows:
                if req_store[i]:
                    if feed_max_wave[i] >= batch_start or (
                        not symbolic_free[i] and int(req_flat[i]) in stored
                    ):
                        ok = False
                        break
                elif not symbolic_free[i] and int(req_flat[i]) in stored:
                    ok = False
                    break
            if not ok:
                step += 1
                batch_start = w
                stored.clear()
        for i in rows:
            if req_store[i] and not symbolic_free[i]:
                stored.add(int(req_flat[i]))
        step_of_wave[w] = step
    return step_of_wave, step + 1


class BlockMemo:
    """Skip re-evaluating a blocked wave attempt whose inputs are frozen.

    The event engine calls ``key(...)`` with everything a port's Hazard
    Safety Checks can observe when every consulted src port is
    *current* (no issue cycles stamped beyond ``now`` — the fast path
    of ``engine_event._issue_wave``): the port's own ``next`` index,
    its CU value-queue length, and each src's ``(head, next)`` window.
    When a check-blocked attempt records its key and a later attempt
    probes with an identical key, the outcome is necessarily identical
    — frontiers are functions of ``(head, next)`` alone in the current
    case — so the attempt is skipped without touching the checks.

    The key is fully self-invalidating: any state change that could
    change the outcome (an src ACK pop, an src issue, this port's own
    issue, a CU value arrival) moves one of the key's components, so
    there is no explicit clear. Attempts whose blocking depends on
    *time* (horizon caps, §5.5 frontiers reconstructed from
    future-stamped issue cycles, the LSQ sequential window) must not be
    recorded — the engine only records on the check-blocked failure
    path with all srcs current and outside sequential mode.
    """

    __slots__ = ("_blocked", "hits", "misses")

    def __init__(self):
        self._blocked: dict[str, tuple] = {}
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key(next_idx: int, n_vals: int, src_windows: tuple) -> tuple:
        """The observable-state fingerprint of one wave attempt."""
        return (next_idx, n_vals, src_windows)

    def probe(self, op_id: str, key: tuple) -> bool:
        """True iff this attempt is known-blocked under ``key``."""
        if self._blocked.get(op_id) == key:
            self.hits += 1
            return True
        self.misses += 1
        return False

    def record(self, op_id: str, key: tuple) -> None:
        """Remember a check-blocked attempt (see class doc for when)."""
        self._blocked[op_id] = key
