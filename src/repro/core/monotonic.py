"""Address monotonicity analysis (paper §3).

Translates LoopIR address expressions into the CR algebra (the moral
equivalent of running LLVM's SCEV on the address def-use chain), then
classifies every memory operation:

  * ``affine``               — polyhedral tools could handle it,
  * ``innermost_monotonic``  — the paper's *requirement* for using the
                               frontier (``addr_a < ack.addr_b``) check,
  * ``non_monotonic``        — set of 1-indexed loop depths (within the
                               op's own nest) that may *reset* the
                               address (§3.4.1), driving `lastIter`
                               instrumentation and the No-Address-Reset
                               check.

Data-dependent addresses (``Read`` of an index array) cannot be analyzed
by the CR formalism; they are handled through user assertions
(``MonotonicHint``, §3.3) or conservatively marked non-monotonic at
every depth — such ops never use the frontier comparison and are
disambiguated purely by program order + completion sentinels.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

from repro.core import cr as crlib
from repro.core import loopir as ir


@dataclasses.dataclass(frozen=True)
class AddressInfo:
    op_id: str
    depth: int  # loop-nest depth of the op (n >= 1)
    cr: Optional[crlib.CRExpr]  # None if not analyzable (data-dependent)
    affine: bool
    innermost_monotonic: bool
    non_monotonic: frozenset[int]  # 1-indexed depths that may reset the address
    from_hint: bool = False

    def describe(self) -> str:
        kind = (
            "affine"
            if self.affine
            else ("monotonic" if self.innermost_monotonic else "unanalyzable")
        )
        src = " (user-asserted)" if self.from_hint else ""
        return (
            f"{self.op_id}: {kind}{src}, depth={self.depth}, "
            f"non-monotonic depths={sorted(self.non_monotonic)}"
        )


class _Untranslatable(Exception):
    pass


def to_cr_or_none(
    e: ir.Expr, path: tuple[ir.Loop, ...]
) -> Optional[crlib.CRExpr]:
    """Translate an expression evaluated inside loop nest ``path`` to the
    CR algebra, or None when no translation exists. Public wrapper used
    by the affine trace compiler (core/affine.py) to tag compiled
    addresses with their §3 classification without re-deriving the
    depth/ivar maps."""
    depth_of = {lp.var: i + 1 for i, lp in enumerate(path)}
    ivars: dict[str, tuple[ir.IVar, int]] = {}
    for i, lp in enumerate(path):
        for iv in lp.ivars:
            ivars[iv.name] = (iv, i + 1)
    try:
        return _to_cr(e, depth_of, ivars)
    except _Untranslatable:
        return None


def _to_cr(
    e: ir.Expr,
    depth_of: dict[str, int],
    ivars: dict[str, tuple[ir.IVar, int]],
) -> crlib.CRExpr:
    """Translate a LoopIR expression to a CR expression.

    ``depth_of`` maps canonical loop vars to 1-indexed depth;
    ``ivars`` maps auxiliary induction variables to (IVar, depth).
    """
    if isinstance(e, ir.Const):
        if float(e.v) != int(e.v):
            raise _Untranslatable("non-integer constant in address")
        return crlib.CConst(int(e.v))
    if isinstance(e, ir.Param):
        return crlib.CSym(e.name, e.lo, e.hi)
    if isinstance(e, ir.Var):
        if e.name in depth_of:
            # canonical induction variable: {0, +, 1}@depth
            return crlib.CR(crlib.CConst(0), "+", crlib.CConst(1), depth_of[e.name])
        if e.name in ivars:
            iv, d = ivars[e.name]
            base = _to_cr(iv.init, depth_of, ivars)
            step = _to_cr(iv.step, depth_of, ivars)
            return crlib.CR(base, iv.op, step, d)
        raise _Untranslatable(f"unknown var {e.name}")
    if isinstance(e, ir.Read):
        return crlib.COpaque(e.array, e.lo, e.hi)
    if isinstance(e, ir.LoadVal):
        return crlib.COpaque(f"loadval:{e.load_id}")
    if isinstance(e, ir.Bin):
        if e.op == "+":
            return crlib.cr_add(
                _to_cr(e.a, depth_of, ivars), _to_cr(e.b, depth_of, ivars)
            )
        if e.op == "-":
            return crlib.cr_add(
                _to_cr(e.a, depth_of, ivars),
                crlib.cr_mul(crlib.CConst(-1), _to_cr(e.b, depth_of, ivars)),
            )
        if e.op == "*":
            return crlib.cr_mul(
                _to_cr(e.a, depth_of, ivars), _to_cr(e.b, depth_of, ivars)
            )
        raise _Untranslatable(f"op {e.op} not CR-translatable")
    if isinstance(e, ir.Local):
        raise _Untranslatable(f"loop-carried local {e.name} in address")
    raise _Untranslatable(f"cannot translate {type(e).__name__}")


def analyze_op(
    op: Union[ir.Load, ir.Store], path: tuple[ir.Loop, ...]
) -> AddressInfo:
    """Classify one memory op. ``path`` is its loop nest, outermost first."""
    n = len(path)
    assert n >= 1, "memory ops must be inside at least one loop"
    depth_of = {lp.var: i + 1 for i, lp in enumerate(path)}
    ivars: dict[str, tuple[ir.IVar, int]] = {}
    for i, lp in enumerate(path):
        for iv in lp.ivars:
            ivars[iv.name] = (iv, i + 1)

    # --- user assertion path (§3.3) -------------------------------------
    if op.hint is not None:
        if op.hint.non_monotonic_outer is None:
            nm = frozenset(range(1, n))  # all outer depths reset
        else:
            nm = frozenset(op.hint.non_monotonic_outer)
        if not op.hint.innermost_monotonic:
            nm = nm | {n}
        return AddressInfo(
            op_id=op.id,
            depth=n,
            cr=None,
            affine=False,
            innermost_monotonic=op.hint.innermost_monotonic,
            non_monotonic=nm,
            from_hint=True,
        )

    # --- CR path ----------------------------------------------------------
    try:
        cre = _to_cr(op.addr, depth_of, ivars)
    except _Untranslatable:
        cre = None
    if cre is None or crlib.has_opaque(cre):
        # unanalyzable without an annotation: conservatively non-monotonic
        # at every depth. The op is still *supported* (paper hist-style
        # codes): consumers fall back to program order and sentinels.
        return AddressInfo(
            op_id=op.id,
            depth=n,
            cr=cre,
            affine=False,
            innermost_monotonic=False,
            non_monotonic=frozenset(range(1, n + 1)),
        )

    affine = crlib.is_affine_expr(cre)
    monotonic = crlib.is_monotonic_expr(cre)

    # trip counts per depth for the §3.4.1 comparison (symbolic)
    trips: dict[int, crlib.CRExpr] = {}
    for i, lp in enumerate(path):
        try:
            trips[i + 1] = _to_cr(lp.trip, depth_of, ivars)
        except _Untranslatable:
            trips[i + 1] = crlib.CSym(f"__trip_{lp.var}", 0, crlib.INF)

    nm = crlib.non_monotonic_depths(cre, trips, n)
    innermost_monotonic = monotonic and (n not in nm)
    return AddressInfo(
        op_id=op.id,
        depth=n,
        cr=cre,
        affine=affine,
        innermost_monotonic=innermost_monotonic,
        non_monotonic=frozenset(nm),
    )


def analyze_program(program: ir.Program) -> dict[str, AddressInfo]:
    """Address monotonicity analysis of every memory op (paper §3).

    Maps op id -> ``AddressInfo``: whether the address is affine /
    innermost-monotonic (the requirement for the DU's frontier
    comparison) and which outer loop depths may reset it (driving the
    lastIter instrumentation and No-Address-Reset check). Data-dependent
    addresses honour user ``MonotonicHint`` assertions (§3.3), else are
    conservatively non-monotonic at every depth. This is the first
    stage of ``simulator.Compiled``; the hazard plan
    (``hazards.build_plan``) consumes the result."""
    return {op.id: analyze_op(op, path) for op, path in program.mem_ops()}
