"""Speculative AGU with rollback-free squash (DESIGN.md §10).

``dae.decouple(speculation="off")`` rejects programs whose AGU
address/trip closure consumes a protected load value (loss of
decoupling): the AGU cannot run ahead of the load round trip. The
paper's lineage (speculation in dynamically scheduled HLS, [62])
resolves this by letting the AGU *predict* the value, run ahead, and
squash on mis-speculation — requests are never retracted, they stay in
flight tagged invalid, exactly the §6 valid-bit machinery the decoupled
machine already has for guarded stores.

This module builds that behaviour as a trace-level plan:

  * **Predictor.** Each AGU-feeding load port gets a last-value
    predictor: the predicted value of occurrence ``k`` is the true
    value of occurrence ``k-1`` (0.0 before the first). Load-dependent
    trip counts with repetitive structure (CSR row lengths, frontier
    sizes) predict well; pointer chases predict poorly and degrade to
    delivery-gated issue — correct either way.
  * **Epochs.** Requests the AGU emits are tagged with the current
    *epoch* — the id of the most recent misprediction preceding them in
    AGU generation order (-1 before any). A misprediction at occurrence
    ``(L, k)`` opens a new epoch whose *gate* fires
    ``SimParams.squash_latency`` cycles after L's k-th value is
    delivered: requests of that epoch may not issue earlier (the AGU
    regenerated them from the true value).
  * **Squash.** Requests the AGU issued *under* the mispredicted value
    (wrong trip tail, wrong address) are squashed, not rolled back:
    they are accounted as phantom traffic released at the gate's fire
    time — squashed loads occupy DU issue slots and DRAM bandwidth,
    squashed stores occupy issue slots and ACK at the pending-buffer
    head without DRAM (Fig. 7). Phantoms never enter the
    hazard-visible port state: frontiers advance only on true
    program-order requests, which is conservative in timing and keeps
    the §5 hazard argument (and final-array exactness) untouched.

The *true* request streams themselves are computed against the
sequential oracle's load values — sound for the same reason
``dae.record_cu_script`` is: the engines' validated delivery contract
guarantees every load receives its oracle value regardless of timing,
so the speculative AGU's post-squash stream is exactly the oracle-fed
stream. ``schedule.trace_program`` routes speculative PEs here and
returns the accumulated ``SpecPlan`` to the engines.

When speculation cannot even run ahead — a trip depending on a load
*inside* the loop it bounds, or an AGU value that is simply unavailable
at its use point — ``trace_spec_pe`` falls back to rejecting with
``LossOfDecoupling`` (the documented ``auto``-mode reject rule).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import dae as daelib
from repro.core import loopir as ir


# How far the run-ahead AGU gets before a mispredicted value's truth
# arrives and squashes it, per (epoch, op): one DRAM burst's worth of
# requests (§2.1.1, N=16). Squash traffic per misprediction is capped
# here — the run-ahead window of real speculative dataflow hardware is
# a queue depth, not the whole dependent region.
RUNAHEAD_CAP = 16


@dataclasses.dataclass
class SpecPlan:
    """Engine-facing speculation schedule of one compiled program.

    ``gates[op]`` tags every request of ``op`` with its epoch id (-1 =
    epoch 0, never gated); ids are non-decreasing along each stream.
    ``triggers[g]`` is the ``(load op id, delivery index)`` whose value
    delivery resolves epoch ``g``; ``resolve_of[load op]`` maps each
    delivery index to the epoch it resolves (-1 = none).
    ``phantoms[g]`` lists ``(op id, count, is_store)`` squashed requests
    released when gate ``g`` fires.
    """

    gates: dict = dataclasses.field(default_factory=dict)
    triggers: list = dataclasses.field(default_factory=list)
    resolve_of: dict = dataclasses.field(default_factory=dict)
    phantoms: list = dataclasses.field(default_factory=list)
    pe_ids: list = dataclasses.field(default_factory=list)
    predictions: int = 0
    mispredictions: int = 0
    phantom_requests: int = 0

    @property
    def n_gates(self) -> int:
        return len(self.triggers)

    def summary(self) -> dict:
        """Counters for benchmarks/reports (JSON-friendly)."""
        return {
            "speculative_pes": list(self.pe_ids),
            "predictions": self.predictions,
            "mispredictions": self.mispredictions,
            "phantom_requests": self.phantom_requests,
            "gates": self.n_gates,
        }


def fire_phantoms(
    plan: SpecPlan,
    gid: int,
    now: int,
    channel_free_at: int,
    burst_size: int,
    channel_occupancy: int,
    result,
) -> int:
    """Shared squash-release accounting of both engines' ``_fire_gate``:
    count gate ``gid``'s phantoms into ``result.squashed``, charge the
    squashed *loads* to the DRAM channel (squashed stores ACK without
    DRAM, Fig. 7), and return the updated ``channel_free_at``. Keeping
    this in one place is what keeps the engines' ``squashed``/DRAM
    counters bit-identical (tests/test_speculation.py)."""
    n_load = 0
    total = 0
    for _op, count, is_store in plan.phantoms[gid]:
        total += count
        if not is_store:
            n_load += count
    result.squashed += total
    if n_load:
        nb = -(-n_load // burst_size)
        issue = max(now, channel_free_at)
        channel_free_at = issue + nb * channel_occupancy
        result.dram_bursts += nb
        result.dram_requests += n_load
    return channel_free_at


def interpret_hooked(
    program: ir.Program,
    arrays: dict[str, np.ndarray],
    params: Optional[dict],
    trace_hook,
    aux_exprs=None,
    aux_hook=None,
) -> dict[str, np.ndarray]:
    """``loopir.interpret`` with the speculative auto-reject applied:
    a load value consumed before it exists even sequentially (e.g. a
    trip reading a load of the loop it bounds) becomes the documented
    ``LossOfDecoupling`` — speculation cannot repair an ill-defined
    program. Other KeyErrors (typo'd array/param names) propagate
    untouched. The single conversion site shared by ``simulate()``
    (via ``oracle_load_streams``) and ``executor.execute``.
    ``aux_exprs``/``aux_hook`` pass through to ``loopir.interpret``."""
    try:
        return ir.interpret(
            program, arrays, params or {}, trace_hook=trace_hook,
            aux_exprs=aux_exprs, aux_hook=aux_hook,
        )
    except ir.UnavailableLoadValue as exc:
        raise daelib.LossOfDecoupling(
            f"value {exc} is unavailable at its use point even in the "
            f"sequential oracle — speculation cannot run ahead"
        ) from None


def oracle_load_streams(
    program: ir.Program,
    arrays: dict[str, np.ndarray],
    params: Optional[dict] = None,
) -> dict[str, list]:
    """Per-op in-order load value streams from the sequential oracle —
    the ground truth the speculative AGU's predictor is scored against
    (and what the engines are contracted to deliver)."""
    loads: dict[str, list] = {}

    def hook(op_id, addr, is_store, valid, value):
        if not is_store:
            loads.setdefault(op_id, []).append(value)

    interpret_hooked(program, arrays, params, hook)
    return loads


def trace_spec_pe(
    pe: daelib.PE,
    info: daelib.SpecInfo,
    arrays: dict[str, np.ndarray],
    params: dict,
    oracle_loads: dict[str, list],
    plan: SpecPlan,
):
    """Run the speculative AGU of one PE and record its true request
    streams plus epoch/squash bookkeeping into ``plan``.

    Returns a ``schedule.PETrace`` (imported lazily to avoid the
    schedule <-> speculate cycle) whose streams are identical to what
    ``schedule._trace_pe`` would produce if it could read protected
    load values — the hazard machinery sees ordinary program-order
    streams; speculation only adds the per-request epoch tags and the
    phantom traffic in ``plan``.
    """
    from repro.core import schedule as schedlib

    plan.pe_ids.append(pe.id)
    spec_loads = set(info.loads)

    rec: dict[str, dict[str, list]] = {
        op_id: {"sched": [], "addr": [], "lastiter": [], "seq": [], "gate": []}
        for op_id in pe.mem_ops
    }
    seq_counter = [0]
    _, op_depth, op_store = schedlib._static_op_meta(pe)

    by_depth: dict[int, list[ir.Stmt]] = {}
    for s, d in pe.stmts:
        by_depth.setdefault(d, []).append(s)

    counters = [0] * (pe.depth + 1)
    last_flags = [False] * (pe.depth + 1)
    n_leaf = 0

    # ---- speculation state ------------------------------------------------
    occ: dict[str, int] = {}  # delivery index per load op
    last_val: dict[str, float] = {}  # last-value predictor state
    pred_val: dict[str, float] = {}  # prediction made for latest occurrence
    mispred: dict[str, bool] = {}  # latest occurrence mispredicted?
    gate_of: dict[str, int] = {}  # gate of latest (mispredicted) occurrence
    tainted: dict[str, int] = {}  # AGU local -> gate of the bad value
    cur_gate = [-1]  # epoch tag of requests emitted from here on

    def eval_expr(e: ir.Expr, scope: ir._Env, loadvals: dict):
        try:
            return ir._eval(e, scope, arrays, params, loadvals)
        except ir.UnavailableLoadValue as exc:
            raise daelib.LossOfDecoupling(
                f"PE {pe.id}: AGU value {exc} is unavailable at its use "
                f"point (e.g. a trip depending on a load inside the loop "
                f"it bounds) — speculation cannot run ahead"
            ) from None

    def bad_epoch(e: ir.Expr) -> Optional[int]:
        """Gate id of the most recent misprediction feeding ``e``'s
        current value, or None when every input was predicted right."""
        locals_, loads = daelib.expr_deps(e)
        gids = [gate_of[l] for l in loads if mispred.get(l)]
        gids += [tainted[n] for n in locals_ if n in tainted]
        return max(gids) if gids else None

    phantom_counts: dict[tuple[int, str], int] = {}

    def phantom(gid: int, op_id: str, count: int, is_store: bool):
        # cap the squash window per (epoch, op) at RUNAHEAD_CAP: the
        # run-ahead AGU only gets one burst ahead before the truth
        # arrives and squashes it
        seen = phantom_counts.get((gid, op_id), 0)
        count = min(int(count), RUNAHEAD_CAP - seen)
        if count <= 0:
            return
        phantom_counts[(gid, op_id)] = seen + count
        plan.phantoms[gid].append((op_id, count, is_store))
        plan.phantom_requests += count

    def eval_trip(loop: ir.Loop, scope: ir._Env, loadvals: dict, d: int) -> int:
        trip = int(eval_expr(loop.trip, scope, loadvals))
        gid = bad_epoch(loop.trip)
        if gid is not None:
            # the AGU entered this loop with a mispredicted bound: the
            # over-predicted tail iterations were issued and squashed.
            # First-order estimate: re-evaluate the trip under the
            # predicted values (taint through locals has no closed
            # predicted value — counted as gated, not phantom).
            _, loads = daelib.expr_deps(loop.trip)
            if any(mispred.get(l) for l in loads):
                lv = dict(loadvals)
                for l in loads:
                    if mispred.get(l):
                        lv[l] = pred_val[l]
                trip_pred = max(0, int(eval_expr(loop.trip, scope, lv)))
                extra = max(0, trip_pred - max(0, trip))
                for s in by_depth.get(d, ()):
                    if isinstance(s, (ir.Load, ir.Store)):
                        phantom(gid, s.id, extra, isinstance(s, ir.Store))
        return trip

    def run_depth(d: int, scope: ir._Env, outer_loadvals: dict):
        nonlocal n_leaf
        loop = pe.path[d - 1]
        loop_scope = ir._Env(scope)
        for iv in loop.ivars:
            loop_scope.define(iv.name, eval_expr(iv.init, scope, outer_loadvals))
        trip = eval_trip(loop, scope, outer_loadvals, d)
        for i in range(trip):
            counters[d] += 1
            body = ir._Env(loop_scope)
            body.define(loop.var, i)
            last_flags[d] = (i == trip - 1) if loop.predictable else False
            if d == pe.depth:
                n_leaf += 1
            loadvals = dict(outer_loadvals)
            for s in by_depth.get(d, ()):
                exec_stmt(s, body, d, loadvals)
            if d < pe.depth:
                run_depth(d + 1, body, loadvals)
            for iv in loop.ivars:
                cur = loop_scope.get(iv.name)
                step = eval_expr(iv.step, body, outer_loadvals)
                loop_scope.vals[iv.name] = (
                    cur + step if iv.op == "+" else cur * step
                )

    def exec_stmt(s: ir.Stmt, scope: ir._Env, d: int, loadvals: dict):
        if isinstance(s, (ir.Load, ir.Store)):
            gid = bad_epoch(s.addr)
            if gid is not None:
                # the run-ahead AGU issued this request with a wrong
                # address; the corrected re-issue below is epoch-gated
                phantom(gid, s.id, 1, isinstance(s, ir.Store))
            a = int(eval_expr(s.addr, scope, loadvals))
            r = rec[s.id]
            r["sched"].append(tuple(counters[1 : d + 1]))
            r["addr"].append(a)
            r["lastiter"].append(tuple(last_flags[1 : d + 1]))
            r["seq"].append(seq_counter[0])
            r["gate"].append(cur_gate[0])
            seq_counter[0] += 1
            if isinstance(s, ir.Load):
                k = occ.get(s.id, 0)
                occ[s.id] = k + 1
                truth = float(oracle_loads.get(s.id, [])[k])
                loadvals[s.id] = truth
                if s.id in spec_loads:
                    pred = last_val.get(s.id, 0.0)
                    plan.predictions += 1
                    pred_val[s.id] = pred
                    if pred != truth:
                        gid = len(plan.triggers)
                        plan.triggers.append((s.id, k))
                        plan.phantoms.append([])
                        plan.mispredictions += 1
                        mispred[s.id] = True
                        gate_of[s.id] = gid
                        cur_gate[0] = gid
                    else:
                        mispred[s.id] = False
                    last_val[s.id] = truth
        elif isinstance(s, ir.SetLocal):
            gid = bad_epoch(s.value)
            v = eval_expr(s.value, scope, loadvals)
            if not scope.set_existing(s.name, v):
                scope.define(s.name, v)
            if gid is not None:
                tainted[s.name] = gid
            else:
                tainted.pop(s.name, None)

    if pe.depth >= 1:
        run_depth(1, ir._Env(), {})

    ops = {}
    for op_id in pe.mem_ops:
        r = rec[op_id]
        d = op_depth[op_id]
        n = len(r["addr"])
        ops[op_id] = schedlib.OpTrace(
            op_id=op_id,
            pe_id=pe.id,
            depth=d,
            is_store=op_store[op_id],
            sched=np.array(r["sched"], dtype=np.int64).reshape(n, d),
            addr=np.array(r["addr"], dtype=np.int64).reshape(n),
            lastiter=np.array(r["lastiter"], dtype=bool).reshape(n, d),
            seq=np.array(r["seq"], dtype=np.int64).reshape(n),
        )
        plan.gates[op_id] = np.array(r["gate"], dtype=np.int64).reshape(n)
    _finalize_resolve(plan)
    return schedlib.PETrace(pe_id=pe.id, ops=ops, n_leaf_iters=n_leaf)


def _finalize_resolve(plan: SpecPlan) -> None:
    """(Re)build ``resolve_of`` from ``triggers`` — delivery index ->
    gate id per spec load port. Idempotent across multiple PEs."""
    per_op: dict[str, dict[int, int]] = {}
    for gid, (op_id, k) in enumerate(plan.triggers):
        per_op.setdefault(op_id, {})[k] = gid
    plan.resolve_of = {
        op_id: _to_resolve_array(m) for op_id, m in per_op.items()
    }


def _to_resolve_array(m: dict[int, int]) -> np.ndarray:
    n = max(m) + 1
    out = np.full(n, -1, dtype=np.int64)
    for k, gid in m.items():
        out[k] = gid
    return out
