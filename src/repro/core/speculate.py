"""Speculative AGU with a predictor zoo and rollback-free squash
(DESIGN.md §10).

``dae.decouple(speculation="off")`` rejects programs whose AGU
address/trip closure consumes a protected load value (loss of
decoupling): the AGU cannot run ahead of the load round trip. The
paper's lineage (speculation in dynamically scheduled HLS, [62])
resolves this by letting the AGU *predict* the value, run ahead, and
squash on mis-speculation — requests are never retracted, they stay in
flight tagged invalid, exactly the §6 valid-bit machinery the decoupled
machine already has for guarded stores.

This module builds that behaviour as a trace-level plan:

  * **Predictor zoo.** Each AGU-feeding load port gets a value
    predictor (``dae.PREDICTORS``):

      - ``"last"`` — last-value: occurrence ``k`` predicts the true
        value of ``k-1`` (0.0 cold). Repetitive trip counts (CSR row
        lengths, frontier sizes) predict well.
      - ``"stride"`` — last value plus the last observed first
        difference: locks onto arithmetic value sequences (AGU-local
        induction through memory, e.g. ``strided_scan``) after two
        occurrences.
      - ``"context"`` — a context table mapping the previous value to
        the value that followed it last time (last-value fallback on a
        cold key): learns pointer chains, so a linked list traversed
        more than once (``chase_sum``) predicts perfectly from the
        second lap on.
      - ``"auto"`` — per-port tournament: all three components run in
        parallel on the true value stream; each keeps a saturating
        accuracy score and the best-scoring one (ties to the simplest)
        makes the port's prediction.

  * **Confidence gating.** Each port carries a saturating confidence
    counter updated from the selected predictor's outcomes (+1 hit,
    -2 miss). While confidence is below threshold the port does not
    speculate: the occurrence opens a *wait* gate — downstream requests
    are delivery-gated exactly as a non-speculative AGU would be, but
    nothing was issued under a wrong value, so there is no phantom
    traffic and no squash latency. Low-confidence ports therefore fall
    back to waiting instead of squash-storming; predictors keep
    learning during the wait, so a port whose pattern becomes
    predictable (lap 2 of a pointer chase) re-enables itself.

  * **Epochs.** Requests the AGU emits are tagged with the current
    *epoch* — the id of the most recent gate preceding them in AGU
    generation order (-1 before any). A mispredicted (or suppressed)
    occurrence at ``(L, k)`` opens a new epoch whose *gate* fires when
    L's k-th value is delivered — plus ``SimParams.squash_latency`` for
    a mispredicted (squash) gate, immediately for a wait gate
    (``SpecPlan.fire_delay``): requests of that epoch may not issue
    earlier (the AGU regenerated them from the true value).

  * **Squash.** Requests the AGU issued *under* a mispredicted value
    (wrong trip tail, wrong address) are squashed, not rolled back:
    they are accounted as phantom traffic released at the gate's fire
    time — squashed loads occupy DU issue slots and DRAM bandwidth,
    squashed stores occupy issue slots and ACK at the pending-buffer
    head without DRAM (Fig. 7). Phantom traffic per (epoch, op) is
    capped at the run-ahead window ``SimParams.spec_runahead`` (a DSE
    axis; cap hits are surfaced in ``SpecPlan.stats()``). Phantoms
    never enter the hazard-visible port state: frontiers advance only
    on true program-order requests, which is conservative in timing and
    keeps the §5 hazard argument (and final-array exactness) untouched.

The *true* request streams themselves are computed against the
sequential oracle's load values — sound for the same reason
``dae.record_cu_script`` is: the engines' validated delivery contract
guarantees every load receives its oracle value regardless of timing,
so the speculative AGU's post-squash stream is exactly the oracle-fed
stream *under every predictor* — the knob only moves gates and phantom
traffic, never addresses. ``schedule.trace_program`` routes speculative
PEs here and returns the accumulated ``SpecPlan`` to the engines, which
stay predictor-agnostic: they consume gates/triggers/phantoms
generically and surface ``SpecPlan.stats()`` as
``SimResult.spec_stats``.

When speculation cannot even run ahead — a trip depending on a load
*inside* the loop it bounds, or an AGU value that is simply unavailable
at its use point — ``trace_spec_pe`` falls back to rejecting with
``LossOfDecoupling`` (the documented ``auto``-mode reject rule).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import dae as daelib
from repro.core import loopir as ir

# re-export: the valid predictor knob values (defined next to
# SPECULATION_MODES so every layer validates against one tuple)
PREDICTORS = daelib.PREDICTORS

# Default run-ahead window: how far the speculative AGU gets before a
# mispredicted value's truth arrives and squashes it, per (epoch, op) —
# one DRAM burst's worth of requests (§2.1.1, N=16). The live value is
# ``SimParams.spec_runahead`` (threaded into ``SpecPlan.runahead``); a
# run-ahead window of real speculative dataflow hardware is a queue
# depth, not the whole dependent region.
DEFAULT_RUNAHEAD = 16

# Per-port confidence counter (saturating 0..CONF_MAX): speculate while
# >= CONF_THRESHOLD; +1 on a hit, -2 on a miss. Starts weakly confident
# so ports speculate until the pattern proves unpredictable; misses
# shut a port off after two, four consecutive would-be hits of the
# selected predictor re-enable it.
CONF_MAX = 7
CONF_INIT = 4
CONF_THRESHOLD = 4
CONF_HIT = 1
CONF_MISS = 2  # subtracted


class _LastValue:
    """Predict the previous true value (0.0 cold)."""

    name = "last"

    def __init__(self):
        self.last: Optional[float] = None

    def predict(self) -> float:
        return 0.0 if self.last is None else self.last

    def update(self, truth: float) -> None:
        self.last = truth


class _Stride:
    """Predict last + (last - previous): arithmetic value sequences."""

    name = "stride"

    def __init__(self):
        self.last: Optional[float] = None
        self.stride = 0.0

    def predict(self) -> float:
        return 0.0 if self.last is None else self.last + self.stride

    def update(self, truth: float) -> None:
        if self.last is not None:
            self.stride = truth - self.last
        self.last = truth


class _Context:
    """Predict table[previous value] — the value that followed it last
    time — with a last-value fallback on a cold key: repeated pointer
    chains predict perfectly from their second traversal on."""

    name = "context"

    def __init__(self):
        self.table: dict[float, float] = {}
        self.last: Optional[float] = None

    def predict(self) -> float:
        if self.last is None:
            return 0.0
        return self.table.get(self.last, self.last)

    def update(self, truth: float) -> None:
        if self.last is not None:
            self.table[self.last] = truth
        self.last = truth


_COMPONENTS = {"last": _LastValue, "stride": _Stride, "context": _Context}


class PortPredictor:
    """One speculative load port's predictor state: the component zoo
    (a single component for a fixed knob, all three under ``"auto"``),
    the tournament scores, and the confidence counter that gates
    whether the port speculates at all."""

    def __init__(self, knob: str):
        assert knob in PREDICTORS, f"unknown predictor {knob!r}"
        self.knob = knob
        if knob == "auto":
            # tie order = simplest first: ties go to the earliest entry
            self.components = [_LastValue(), _Stride(), _Context()]
        else:
            self.components = [_COMPONENTS[knob]()]
        self.scores = [CONF_INIT] * len(self.components)
        self.confidence = CONF_INIT
        # stats
        self.predictions = 0
        self.mispredictions = 0
        self.waits = 0

    @property
    def speculating(self) -> bool:
        return self.confidence >= CONF_THRESHOLD

    def peek(self) -> tuple[str, float]:
        """(selected component name, its prediction) — selection is the
        best tournament score, ties to the simplest component."""
        i = max(range(len(self.scores)), key=lambda j: (self.scores[j], -j))
        return self.components[i].name, self.components[i].predict()

    def observe(self, truth: float) -> None:
        """Score every component's would-be prediction against the
        delivered truth, update the confidence counter from the
        *selected* component's outcome, then advance all component
        states. Runs every occurrence — including suppressed ones — so
        predictors keep learning while the port waits."""
        sel, sel_pred = self.peek()
        for j, c in enumerate(self.components):
            ok = c.predict() == truth
            self.scores[j] = (
                min(CONF_MAX, self.scores[j] + CONF_HIT)
                if ok
                else max(0, self.scores[j] - CONF_MISS)
            )
        if sel_pred == truth:
            self.confidence = min(CONF_MAX, self.confidence + CONF_HIT)
        else:
            self.confidence = max(0, self.confidence - CONF_MISS)
        for c in self.components:
            c.update(truth)

    def port_stats(self) -> dict:
        sel, _ = self.peek()
        return {
            "predictor": sel,
            "predictions": self.predictions,
            "mispredictions": self.mispredictions,
            "waits": self.waits,
        }


@dataclasses.dataclass
class SpecPlan:
    """Engine-facing speculation schedule of one compiled program.

    ``gates[op]`` tags every request of ``op`` with its epoch id (-1 =
    epoch 0, never gated); ids are non-decreasing along each stream.
    ``triggers[g]`` is the ``(load op id, delivery index)`` whose value
    delivery resolves epoch ``g``; ``resolve_of[load op]`` maps each
    delivery index to the epoch it resolves (-1 = none).
    ``gate_kind[g]`` is ``"squash"`` (a misprediction: fires
    ``squash_latency`` after delivery, releases phantoms) or ``"wait"``
    (a confidence-suppressed occurrence: fires at delivery, no
    phantoms); ``gate_pred[g]`` names the component predictor the gate
    is attributed to. ``phantoms[g]`` lists ``(op id, count, is_store)``
    squashed requests released when gate ``g`` fires, capped per
    (epoch, op) at ``runahead`` (``SimParams.spec_runahead``).
    """

    predictor: str = "auto"  # the knob (dae.PREDICTORS)
    runahead: int = DEFAULT_RUNAHEAD
    gates: dict = dataclasses.field(default_factory=dict)
    triggers: list = dataclasses.field(default_factory=list)
    resolve_of: dict = dataclasses.field(default_factory=dict)
    phantoms: list = dataclasses.field(default_factory=list)
    gate_kind: list = dataclasses.field(default_factory=list)
    gate_pred: list = dataclasses.field(default_factory=list)
    pe_ids: list = dataclasses.field(default_factory=list)
    predictions: int = 0
    mispredictions: int = 0
    wait_gates: int = 0
    phantom_requests: int = 0
    # run-ahead cap visibility: clamp events and requests clamped away
    cap_hits: int = 0
    phantom_capped: int = 0
    # op id -> PortPredictor.port_stats() of every speculative load port
    port_stats: dict = dataclasses.field(default_factory=dict)

    @property
    def n_gates(self) -> int:
        return len(self.triggers)

    def fire_delay(self, gid: int, squash_latency: int) -> int:
        """Cycles from the trigger value's delivery to gate ``gid``
        opening: a squash gate pays ``squash_latency`` (the corrected
        epoch re-issues after the squash completes), a wait gate opens
        at delivery (nothing was issued under a wrong value). The one
        timing rule both engines share."""
        return squash_latency if self.gate_kind[gid] == "squash" else 0

    def by_predictor(self) -> dict:
        """Per-component attribution of squash activity: gates opened,
        phantom requests squashed, and run-ahead cap hits, keyed by the
        component predictor that made (or would have made) the
        prediction. The per-predictor visibility ISSUE'd for
        ``SimResult.spec_stats``."""
        out: dict[str, dict] = {}
        for g, pname in enumerate(self.gate_pred):
            d = out.setdefault(
                pname,
                {"mispredictions": 0, "wait_gates": 0, "squashed": 0,
                 "cap_hits": 0},
            )
            if self.gate_kind[g] == "squash":
                d["mispredictions"] += 1
                d["squashed"] += sum(c for _op, c, _s in self.phantoms[g])
            else:
                d["wait_gates"] += 1
        for pname, hits in getattr(self, "_cap_by", {}).items():
            out.setdefault(
                pname,
                {"mispredictions": 0, "wait_gates": 0, "squashed": 0,
                 "cap_hits": 0},
            )["cap_hits"] += hits
        return out

    def stats(self) -> dict:
        """The ``SimResult.spec_stats`` payload (JSON-friendly): global
        counters, the run-ahead cap visibility, per-port predictor
        outcomes, and per-predictor squash attribution. Shape pinned by
        tests/test_speculation.py."""
        return {
            "predictor": self.predictor,
            "runahead": int(self.runahead),
            "predictions": int(self.predictions),
            "mispredictions": int(self.mispredictions),
            "wait_gates": int(self.wait_gates),
            "squash_gates": int(self.mispredictions),
            "gates": int(self.n_gates),
            "phantom_requests": int(self.phantom_requests),
            "phantom_capped": int(self.phantom_capped),
            "cap_hits": int(self.cap_hits),
            "per_port": {k: dict(v) for k, v in self.port_stats.items()},
            "by_predictor": self.by_predictor(),
        }

    def summary(self) -> dict:
        """Counters for benchmarks/reports (JSON-friendly)."""
        return {
            "speculative_pes": list(self.pe_ids),
            "predictor": self.predictor,
            "runahead": int(self.runahead),
            "predictions": self.predictions,
            "mispredictions": self.mispredictions,
            "wait_gates": self.wait_gates,
            "phantom_requests": self.phantom_requests,
            "phantom_capped": self.phantom_capped,
            "gates": self.n_gates,
        }


def fire_phantoms(
    plan: SpecPlan,
    gid: int,
    now: int,
    channel_free_at: int,
    burst_size: int,
    channel_occupancy: int,
    result,
) -> int:
    """Shared squash-release accounting of both engines' ``_fire_gate``:
    count gate ``gid``'s phantoms into ``result.squashed``, charge the
    squashed *loads* to the DRAM channel (squashed stores ACK without
    DRAM, Fig. 7), and return the updated ``channel_free_at``. Wait
    gates carry no phantoms, so firing them is accounting-free. Keeping
    this in one place is what keeps the engines' ``squashed``/DRAM
    counters bit-identical (tests/test_speculation.py)."""
    n_load = 0
    total = 0
    for _op, count, is_store in plan.phantoms[gid]:
        total += count
        if not is_store:
            n_load += count
    result.squashed += total
    if n_load:
        nb = -(-n_load // burst_size)
        issue = max(now, channel_free_at)
        channel_free_at = issue + nb * channel_occupancy
        result.dram_bursts += nb
        result.dram_requests += n_load
    return channel_free_at


def interpret_hooked(
    program: ir.Program,
    arrays: dict[str, np.ndarray],
    params: Optional[dict],
    trace_hook,
    aux_exprs=None,
    aux_hook=None,
) -> dict[str, np.ndarray]:
    """``loopir.interpret`` with the speculative auto-reject applied:
    a load value consumed before it exists even sequentially (e.g. a
    trip reading a load of the loop it bounds) becomes the documented
    ``LossOfDecoupling`` — speculation cannot repair an ill-defined
    program. Other KeyErrors (typo'd array/param names) propagate
    untouched. The single conversion site shared by ``simulate()``
    (via ``oracle_load_streams``) and ``executor.execute``.
    ``aux_exprs``/``aux_hook`` pass through to ``loopir.interpret``."""
    try:
        return ir.interpret(
            program, arrays, params or {}, trace_hook=trace_hook,
            aux_exprs=aux_exprs, aux_hook=aux_hook,
        )
    except ir.UnavailableLoadValue as exc:
        raise daelib.LossOfDecoupling(
            f"value {exc} is unavailable at its use point even in the "
            f"sequential oracle — speculation cannot run ahead"
        ) from None


def oracle_load_streams(
    program: ir.Program,
    arrays: dict[str, np.ndarray],
    params: Optional[dict] = None,
) -> dict[str, list]:
    """Per-op in-order load value streams from the sequential oracle —
    the ground truth the speculative AGU's predictors are scored
    against (and what the engines are contracted to deliver)."""
    loads: dict[str, list] = {}

    def hook(op_id, addr, is_store, valid, value):
        if not is_store:
            loads.setdefault(op_id, []).append(value)

    interpret_hooked(program, arrays, params, hook)
    return loads


def trace_spec_pe(
    pe: daelib.PE,
    info: daelib.SpecInfo,
    arrays: dict[str, np.ndarray],
    params: dict,
    oracle_loads: dict[str, list],
    plan: SpecPlan,
):
    """Run the speculative AGU of one PE and record its true request
    streams plus epoch/squash bookkeeping into ``plan``.

    The predictor knob and run-ahead window are read from
    ``plan.predictor``/``plan.runahead`` (set by
    ``schedule.trace_program`` from the caller's ``predictor=`` /
    ``SimParams.spec_runahead``). Returns a ``schedule.PETrace``
    (imported lazily to avoid the schedule <-> speculate cycle) whose
    streams are identical to what ``schedule._trace_pe`` would produce
    if it could read protected load values — the hazard machinery sees
    ordinary program-order streams; speculation only adds the
    per-request epoch tags and the phantom traffic in ``plan``, and the
    streams are identical under every predictor (only gates/phantoms
    move).
    """
    from repro.core import schedule as schedlib

    plan.pe_ids.append(pe.id)
    spec_loads = set(info.loads)

    rec: dict[str, dict[str, list]] = {
        op_id: {"sched": [], "addr": [], "lastiter": [], "seq": [], "gate": []}
        for op_id in pe.mem_ops
    }
    seq_counter = [0]
    _, op_depth, op_store = schedlib._static_op_meta(pe)

    by_depth: dict[int, list[ir.Stmt]] = {}
    for s, d in pe.stmts:
        by_depth.setdefault(d, []).append(s)

    counters = [0] * (pe.depth + 1)
    last_flags = [False] * (pe.depth + 1)
    n_leaf = 0

    # ---- speculation state ------------------------------------------------
    occ: dict[str, int] = {}  # delivery index per load op
    predictors: dict[str, PortPredictor] = {
        op_id: PortPredictor(plan.predictor) for op_id in spec_loads
    }
    pred_val: dict[str, float] = {}  # prediction of latest mispredicted occ
    mispred: dict[str, bool] = {}  # latest occurrence opened a gate?
    gate_of: dict[str, int] = {}  # gate of latest gated occurrence
    tainted: dict[str, int] = {}  # AGU local -> gate of the bad value
    cur_gate = [-1]  # epoch tag of requests emitted from here on

    def eval_expr(e: ir.Expr, scope: ir._Env, loadvals: dict):
        try:
            return ir._eval(e, scope, arrays, params, loadvals)
        except ir.UnavailableLoadValue as exc:
            raise daelib.LossOfDecoupling(
                f"PE {pe.id}: AGU value {exc} is unavailable at its use "
                f"point (e.g. a trip depending on a load inside the loop "
                f"it bounds) — speculation cannot run ahead"
            ) from None

    def bad_epoch(e: ir.Expr) -> Optional[int]:
        """Gate id of the most recent gated occurrence feeding ``e``'s
        current value, or None when every input was predicted right."""
        locals_, loads = daelib.expr_deps(e)
        gids = [gate_of[l] for l in loads if mispred.get(l)]
        gids += [tainted[n] for n in locals_ if n in tainted]
        return max(gids) if gids else None

    def open_gate(op_id: str, k: int, kind: str, pname: str) -> int:
        gid = len(plan.triggers)
        plan.triggers.append((op_id, k))
        plan.phantoms.append([])
        plan.gate_kind.append(kind)
        plan.gate_pred.append(pname)
        return gid

    phantom_counts: dict[tuple[int, str], int] = {}

    def phantom(gid: int, op_id: str, count: int, is_store: bool):
        # wait gates: the AGU stalled instead of running ahead under a
        # wrong value — nothing was issued, nothing squashes
        if plan.gate_kind[gid] != "squash":
            return
        # cap the squash window per (epoch, op) at plan.runahead
        # (SimParams.spec_runahead): the run-ahead AGU only gets a
        # bounded queue depth ahead before the truth arrives
        count = int(count)
        if count <= 0:
            return
        seen = phantom_counts.get((gid, op_id), 0)
        granted = min(count, plan.runahead - seen)
        if granted < count:
            plan.cap_hits += 1
            plan.phantom_capped += count - max(granted, 0)
            cap_by = getattr(plan, "_cap_by", None)
            if cap_by is None:
                cap_by = {}
                plan._cap_by = cap_by
            pname = plan.gate_pred[gid]
            cap_by[pname] = cap_by.get(pname, 0) + 1
        if granted <= 0:
            return
        phantom_counts[(gid, op_id)] = seen + granted
        plan.phantoms[gid].append((op_id, granted, is_store))
        plan.phantom_requests += granted

    def eval_trip(loop: ir.Loop, scope: ir._Env, loadvals: dict, d: int) -> int:
        trip = int(eval_expr(loop.trip, scope, loadvals))
        gid = bad_epoch(loop.trip)
        if gid is not None and plan.gate_kind[gid] == "squash":
            # the AGU entered this loop with a mispredicted bound: the
            # over-predicted tail iterations were issued and squashed.
            # First-order estimate: re-evaluate the trip under the
            # predicted values (taint through locals — and suppressed
            # occurrences — has no closed predicted value: counted as
            # gated, not phantom).
            _, loads = daelib.expr_deps(loop.trip)
            specced = [l for l in loads if mispred.get(l) and l in pred_val]
            if specced:
                lv = dict(loadvals)
                for l in specced:
                    lv[l] = pred_val[l]
                trip_pred = max(0, int(eval_expr(loop.trip, scope, lv)))
                extra = max(0, trip_pred - max(0, trip))
                for s in by_depth.get(d, ()):
                    if isinstance(s, (ir.Load, ir.Store)):
                        phantom(gid, s.id, extra, isinstance(s, ir.Store))
        return trip

    def run_depth(d: int, scope: ir._Env, outer_loadvals: dict):
        nonlocal n_leaf
        loop = pe.path[d - 1]
        loop_scope = ir._Env(scope)
        for iv in loop.ivars:
            loop_scope.define(iv.name, eval_expr(iv.init, scope, outer_loadvals))
        trip = eval_trip(loop, scope, outer_loadvals, d)
        for i in range(trip):
            counters[d] += 1
            body = ir._Env(loop_scope)
            body.define(loop.var, i)
            last_flags[d] = (i == trip - 1) if loop.predictable else False
            if d == pe.depth:
                n_leaf += 1
            loadvals = dict(outer_loadvals)
            for s in by_depth.get(d, ()):
                exec_stmt(s, body, d, loadvals)
            if d < pe.depth:
                run_depth(d + 1, body, loadvals)
            for iv in loop.ivars:
                cur = loop_scope.get(iv.name)
                step = eval_expr(iv.step, body, outer_loadvals)
                loop_scope.vals[iv.name] = (
                    cur + step if iv.op == "+" else cur * step
                )

    def exec_stmt(s: ir.Stmt, scope: ir._Env, d: int, loadvals: dict):
        if isinstance(s, (ir.Load, ir.Store)):
            gid = bad_epoch(s.addr)
            if gid is not None:
                # the run-ahead AGU issued this request with a wrong
                # address; the corrected re-issue below is epoch-gated
                # (phantom() is a no-op for wait gates)
                phantom(gid, s.id, 1, isinstance(s, ir.Store))
            a = int(eval_expr(s.addr, scope, loadvals))
            r = rec[s.id]
            r["sched"].append(tuple(counters[1 : d + 1]))
            r["addr"].append(a)
            r["lastiter"].append(tuple(last_flags[1 : d + 1]))
            r["seq"].append(seq_counter[0])
            r["gate"].append(cur_gate[0])
            seq_counter[0] += 1
            if isinstance(s, ir.Load):
                k = occ.get(s.id, 0)
                occ[s.id] = k + 1
                truth = float(oracle_loads.get(s.id, [])[k])
                loadvals[s.id] = truth
                if s.id in spec_loads:
                    pp = predictors[s.id]
                    pname, pred = pp.peek()
                    if pp.speculating:
                        plan.predictions += 1
                        pp.predictions += 1
                        if pred != truth:
                            gid = open_gate(s.id, k, "squash", pname)
                            plan.mispredictions += 1
                            pp.mispredictions += 1
                            pred_val[s.id] = pred
                            mispred[s.id] = True
                            gate_of[s.id] = gid
                            cur_gate[0] = gid
                        else:
                            mispred[s.id] = False
                            pred_val.pop(s.id, None)
                    else:
                        # confidence-suppressed: the port waits for
                        # delivery — a gate with no phantoms and no
                        # squash latency
                        gid = open_gate(s.id, k, "wait", pname)
                        plan.wait_gates += 1
                        pp.waits += 1
                        pred_val.pop(s.id, None)
                        mispred[s.id] = True
                        gate_of[s.id] = gid
                        cur_gate[0] = gid
                    pp.observe(truth)
        elif isinstance(s, ir.SetLocal):
            gid = bad_epoch(s.value)
            v = eval_expr(s.value, scope, loadvals)
            if not scope.set_existing(s.name, v):
                scope.define(s.name, v)
            if gid is not None:
                tainted[s.name] = gid
            else:
                tainted.pop(s.name, None)

    if pe.depth >= 1:
        run_depth(1, ir._Env(), {})

    ops = {}
    for op_id in pe.mem_ops:
        r = rec[op_id]
        d = op_depth[op_id]
        n = len(r["addr"])
        ops[op_id] = schedlib.OpTrace(
            op_id=op_id,
            pe_id=pe.id,
            depth=d,
            is_store=op_store[op_id],
            sched=np.array(r["sched"], dtype=np.int64).reshape(n, d),
            addr=np.array(r["addr"], dtype=np.int64).reshape(n),
            lastiter=np.array(r["lastiter"], dtype=bool).reshape(n, d),
            seq=np.array(r["seq"], dtype=np.int64).reshape(n),
        )
        plan.gates[op_id] = np.array(r["gate"], dtype=np.int64).reshape(n)
    for op_id, pp in sorted(predictors.items()):
        plan.port_stats[op_id] = pp.port_stats()
    _finalize_resolve(plan)
    return schedlib.PETrace(pe_id=pe.id, ops=ops, n_leaf_iters=n_leaf)


def _finalize_resolve(plan: SpecPlan) -> None:
    """(Re)build ``resolve_of`` from ``triggers`` — delivery index ->
    gate id per spec load port. Idempotent across multiple PEs."""
    per_op: dict[str, dict[int, int]] = {}
    for gid, (op_id, k) in enumerate(plan.triggers):
        per_op.setdefault(op_id, {})[k] = gid
    plan.resolve_of = {
        op_id: _to_resolve_array(m) for op_id, m in per_op.items()
    }


def _to_resolve_array(m: dict[int, int]) -> np.ndarray:
    n = max(m) + 1
    out = np.full(n, -1, dtype=np.int64)
    for k, gid in m.items():
        out[k] = gid
    return out
