"""TPU-native fused executor: dynamic loop fusion as *wave partitioning*.

This is the hardware adaptation described in DESIGN.md §2. On an FPGA
the DU stalls each request until its Hazard Safety Check passes; on a
TPU (bulk-synchronous SPMD) we instead *partition* the fused request
stream into **waves**: wave(r) = 1 + max(wave of every request that must
commit before r). All requests in one wave are conflict-free and execute
data-parallel; the wave count is the critical path of the fused program
— the fine-grained cross-loop parallelism of the paper's Fig. 1(c).

Dependencies are exact (addresses are known after the AGU pass — the
same property the paper's monotonicity exploits to avoid history
searches):

  * memory edges: for each address, a load depends on the nearest
    preceding store; a store depends on the nearest preceding store and
    every load since it (computed in one program-order sweep — the
    vectorized analogue is the monotonic frontier merge in
    ``kernels/du_hazard``),
  * dataflow edges: a store depends on the loads of its own iteration
    (DAE value chain), approximated PE-locally by "store depends on the
    most recent loads of its PE".

``execute`` returns the final memory state (bit-identical to the
sequential oracle) plus wave statistics; ``frontier_merge`` is the
vectorized monotonic-streams primitive shared with the Pallas kernels
and the MoE dispatch path.

``trace_mode`` (default ``"auto"``) selects where the program-order
request stream's op ids / addresses / kinds come from: the AGU trace
compiler (``schedule.trace_program``) plus one lexsort of polyhedral
2d+1 keys, with the oracle walk supplying the value/valid stream;
``"interp"`` keeps the original pure-hook path. The oracle walk runs in
full either way (store values ARE execution), so the trace-driven path
is not a speedup — it is the conformance-bearing route that exercises
the compiled front-end's global request ordering end to end, validated
against the oracle by pass-3's replay assertion.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import loopir as ir


@dataclasses.dataclass
class WaveStats:
    n_requests: int
    n_waves: int
    sequential_depth: int  # = n_requests (one request per step, fused b/w)

    @property
    def parallelism(self) -> float:
        return self.n_requests / max(self.n_waves, 1)


@dataclasses.dataclass
class ExecResult:
    arrays: dict[str, np.ndarray]
    stats: WaveStats
    waves: np.ndarray  # per-request wave index, in program order


def frontier_merge(src_addr: np.ndarray, dst_addr: np.ndarray) -> np.ndarray:
    """For each dst request (monotonic source stream!): the number of src
    requests that must commit before it = |{i : src_addr[i] <= dst}|
    under monotonic non-decreasing src_addr. This is the §3.1 insight
    vectorized: one searchsorted instead of an address-history search.

    Returns the required src commit count per dst element.
    """
    return np.searchsorted(src_addr, dst_addr, side="right")


def _trace_stream(
    program: ir.Program,
    dae,
    arrays: dict[str, np.ndarray],
    params: dict[str, int],
    trace_mode: str,
    oracle_loads=None,
) -> tuple[list[str], list[int], list[bool]]:
    """Program-order (op id, address, is_store) stream from AGU traces.

    Global program order is lexicographic on the polyhedral 2d+1 key —
    static body positions and the §4 never-reset counters interleaved,
    with the op's own body position last. Supplies everything except
    values/valid bits, which only the oracle walk can produce
    (``oracle_loads`` feeds the speculative AGU of loss-of-decoupling
    PEs from that same walk).
    """
    from repro.core import schedule as schedlib

    traces = schedlib.trace_program(
        program, dae, arrays, params, mode=trace_mode,
        oracle_loads=oracle_loads,
    )
    loop_pos, op_pos = program.static_positions()
    op_path = {op.id: path for op, path in program.mem_ops()}
    ops = sorted(traces)
    if not ops:
        return [], [], []
    width = 2 * max(tr.depth for tr in traces.values()) + 1
    mats = []
    for op_id in ops:
        tr = traces[op_id]
        path = op_path[op_id]
        key = np.full((tr.n_req, width), -1, dtype=np.int64)
        for j in range(tr.depth):
            key[:, 2 * j] = loop_pos[id(path[j])]
            key[:, 2 * j + 1] = tr.sched[:, j]
        key[:, 2 * tr.depth] = op_pos[op_id]
        mats.append(key)
    stacked = np.concatenate(mats, axis=0)
    order = np.lexsort(stacked.T[::-1])
    flat_op: list[str] = []
    flat_addr = np.concatenate([traces[o].addr for o in ops])
    flat_store: list[bool] = []
    for op_id in ops:
        tr = traces[op_id]
        flat_op.extend([op_id] * tr.n_req)
        flat_store.extend([tr.is_store] * tr.n_req)
    return (
        [flat_op[i] for i in order],
        flat_addr[order].tolist(),
        [flat_store[i] for i in order],
    )


def execute(
    program: ir.Program,
    arrays: dict[str, np.ndarray],
    params: Optional[dict[str, int]] = None,
    trace_mode: str = "auto",
    speculation: str = "off",
) -> ExecResult:
    """Wave-partitioned fused execution, validated against the oracle by
    construction: effects are applied in oracle order inside each wave,
    and conflicting requests never share a wave.

    ``speculation="auto"`` admits loss-of-decoupling programs
    (load-dependent trips/addresses, DESIGN.md §10): the wave partition
    works off the *true* post-squash request stream — phantom squash
    traffic is a DU-timing artifact and has no wave-executor analogue.
    """
    params = params or {}

    from repro.core import dae as daelib

    dae = daelib.decouple(program, speculation=speculation)
    op_pe = dae.op_to_pe

    def interpret_hooked(hook):
        if dae.spec:
            # speculative programs get the documented auto-reject
            # (DESIGN.md §10) through the shared conversion site
            from repro.core import speculate

            speculate.interpret_hooked(program, arrays, params, hook)
        else:
            ir.interpret(program, arrays, params, trace_hook=hook)

    # --- pass 1: program-order request stream ----------------------------
    # op/addr/kind from the trace compiler (trace_mode != "interp");
    # value/valid always from the oracle walk — values are execution.
    if trace_mode != "interp":
        per_op_vv: dict[str, list[tuple[bool, Optional[float]]]] = {}
        load_streams: dict[str, list[float]] = {}

        def hook(op_id, addr, is_store, valid, value):
            per_op_vv.setdefault(op_id, []).append((valid, value))
            if not is_store and dae.spec:
                # only the speculative AGU consumes the load streams
                load_streams.setdefault(op_id, []).append(value)

        interpret_hooked(hook)
        req_op, req_addr, req_store = _trace_stream(
            program, dae, arrays, params, trace_mode,
            oracle_loads=load_streams if dae.spec else None,
        )
        n_oracle = sum(len(v) for v in per_op_vv.values())
        assert n_oracle == len(req_op), (
            f"trace stream has {len(req_op)} requests, oracle walk "
            f"{n_oracle} — trace compiler divergence"
        )
        taken: dict[str, int] = {}
        req_valid: list[bool] = []
        req_value: list[Optional[float]] = []
        for op_id in req_op:
            i = taken.get(op_id, 0)
            taken[op_id] = i + 1
            valid, value = per_op_vv[op_id][i]
            req_valid.append(valid)
            req_value.append(value)
    else:
        req_op, req_addr, req_store = [], [], []
        req_valid, req_value = [], []

        def hook(op_id, addr, is_store, valid, value):
            req_op.append(op_id)
            req_addr.append(addr)
            req_store.append(is_store)
            req_valid.append(valid)
            req_value.append(value)

        interpret_hooked(hook)

    n = len(req_op)

    # --- pass 2: wave assignment (one program-order sweep) ---------------
    waves = np.zeros(n, dtype=np.int64)
    # per (array, addr): wave of last store; max wave of loads since it
    last_store_wave: dict[tuple[str, int], int] = {}
    loads_since_store: dict[tuple[str, int], int] = {}
    # per PE: max wave of recent loads (dataflow into store values)
    pe_load_wave: dict[int, int] = {}
    op_array = {op.id: op.array for op, _ in program.mem_ops()}

    for i in range(n):
        key = (op_array[req_op[i]], req_addr[i])
        w = 0
        if req_store[i]:
            # WAW: after last store; WAR: after every load since it;
            # dataflow: after this PE's recent loads (value availability)
            w = max(
                last_store_wave.get(key, -1) + 1,
                loads_since_store.get(key, -1) + 1,
                pe_load_wave.get(op_pe[req_op[i]], -1) + 1,
            )
            if req_valid[i]:
                last_store_wave[key] = w
                loads_since_store[key] = -1
            else:
                # §6: invalid stores occupy a wave slot (they update the
                # frontier in hardware) but have no memory effect
                last_store_wave[key] = max(last_store_wave.get(key, -1), w)
        else:
            # RAW: after the last store to this address
            w = last_store_wave.get(key, -1) + 1
            loads_since_store[key] = max(loads_since_store.get(key, -1), w)
            pe = op_pe[req_op[i]]
            pe_load_wave[pe] = max(pe_load_wave.get(pe, -1), w)
        waves[i] = w

    n_waves = int(waves.max()) + 1 if n else 0

    # --- pass 3: wave-ordered replay (validation by construction) --------
    # Within a wave: all loads first (conflict-freedom guarantees no
    # same-address store in the same wave), then all stores.
    out = {k: np.array(v, copy=True) for k, v in arrays.items()}
    order = np.argsort(waves, kind="stable")
    got_loads: dict[int, float] = {}
    pos = 0
    for w in range(n_waves):
        # gather this wave's request indices (order is wave-major, stable)
        batch = []
        while pos < len(order) and waves[order[pos]] == w:
            batch.append(int(order[pos]))
            pos += 1
        for i in batch:
            if not req_store[i]:
                got_loads[i] = float(out[op_array[req_op[i]]][req_addr[i]])
        for i in batch:
            if req_store[i] and req_valid[i]:
                out[op_array[req_op[i]]][req_addr[i]] = req_value[i]

    # loads must have observed oracle values
    for i in range(n):
        if not req_store[i]:
            assert np.isclose(got_loads[i], req_value[i], atol=1e-9), (
                f"wave executor divergence at request {i} ({req_op[i]}, "
                f"addr {req_addr[i]}): got {got_loads[i]}, oracle {req_value[i]}"
            )

    stats = WaveStats(n_requests=n, n_waves=n_waves, sequential_depth=n)
    return ExecResult(arrays=out, stats=stats, waves=waves)
