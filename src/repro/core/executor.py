"""TPU-native fused executor: dynamic loop fusion as *wave partitioning*.

This is the hardware adaptation described in DESIGN.md §2. On an FPGA
the DU stalls each request until its Hazard Safety Check passes; on a
TPU (bulk-synchronous SPMD) we instead *partition* the fused request
stream into **waves**: wave(r) = 1 + max(wave of every request that must
commit before r). All requests in one wave are conflict-free and execute
data-parallel; the wave count is the critical path of the fused program
— the fine-grained cross-loop parallelism of the paper's Fig. 1(c).

Dependencies are exact (addresses are known after the AGU pass — the
same property the paper's monotonicity exploits to avoid history
searches):

  * memory edges: for each address, a load depends on the nearest
    preceding store; a store depends on the nearest preceding store and
    every load since it (computed in one program-order sweep — the
    vectorized analogue is the monotonic frontier merge in
    ``kernels/du_hazard``),
  * dataflow edges: a store depends on exactly the load requests that
    feed its compute body — per (PE, dep-edge), resolved through the
    op-table dep maps, **not** a per-PE barrier. Independent per-address
    chains (CSR row accumulations, chained SpMVs) therefore overlap
    instead of serializing behind every other chain of their PE.

Waves are then coarsened into **steps**
(``core/coarsen.batch_conflict_free_waves``): consecutive waves merge
into one gather-before-scatter step whenever the merged batch has no
internal RAW/WAW or dataflow edge (internal WAR is safe — gathers see
the pre-step image), so a backend's step count tracks the *memory*
critical path rather than the wave count.

The module is split along the backend seam (DESIGN.md §2):

  * ``build_wave_plan`` runs the AGU/CU front-end once and emits a
    **WavePlan** — the complete backend-consumable partition: per
    request the op id, array-local and flat address, kind, §6 valid
    bit, wave id and per-op ordinal, plus the ``core/optable`` compute
    bodies with their captured environment streams and dep alignment
    maps. A backend needs nothing else: no oracle callbacks, no IR
    walking.
  * ``execute`` drives a plan through a backend: ``backend="numpy"``
    (default) is the in-process reference replay below;
    ``backend="pallas"`` hands the same plan to
    ``repro.kernels.wave_exec`` which executes every wave as a Pallas
    gather→compute→scatter step. Both must produce arrays bit-identical
    to the sequential oracle.

``frontier_merge`` is the vectorized monotonic-streams primitive shared
with the Pallas kernels and the MoE dispatch path.

``trace_mode`` (default ``"auto"``) selects where the program-order
request stream's op ids / addresses / kinds come from: the AGU trace
compiler (``schedule.trace_program``) plus one lexsort of polyhedral
2d+1 keys, with the oracle walk supplying the reference value/valid
stream; ``"interp"`` keeps the original pure-hook path. The oracle walk
runs in full either way — backends *compute* store values through the
op tables, and the walk's values are the per-request reference that
pins any divergence to the first offending request.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import config as cfglib
from repro.core import loopir as ir
from repro.core import optable as optablelib


@dataclasses.dataclass
class WaveStats:
    n_requests: int
    n_waves: int
    sequential_depth: int  # = n_requests (one request per step, fused b/w)
    n_steps: int = 0  # batched gather→scatter steps (<= n_waves)
    # symbolic admission fast path (analysis/deps.py, DESIGN.md §12):
    # requests of certifier-proven conflict-free ops skip the
    # coarsener's address enumeration entirely
    n_sym_requests: int = 0
    sym_ops: tuple = ()

    @property
    def parallelism(self) -> float:
        return self.n_requests / max(self.n_waves, 1)

    @property
    def step_parallelism(self) -> float:
        return self.n_requests / max(self.n_steps, 1)


@dataclasses.dataclass
class WavePlan:
    """Backend contract for fused wave execution (DESIGN.md §2).

    Request streams are in program order. Guarantees a backend may rely
    on (checked by ``validate_plan`` / tests/test_pallas_parity.py):

      1. waves topologically order the exact dependences — same-address
         RAW/WAR/WAW (invalid §6 stores occupy wave slots too) and the
         per-(PE, dep-edge) dataflow edge (a store is in a strictly
         later wave than every load request feeding its compute body,
         resolved through ``dep_maps`` — not a per-PE barrier),
      2. intra-wave conflict-freedom — within one wave no two requests
         touch the same flat address unless both are loads, so a
         backend may gather all of a wave's loads and scatter all of
         its valid stores in any intra-wave order,
      3. ``dep_maps[s][l][k]`` is the ordinal of the ``l`` request whose
         value the ``k``-th ``s`` request consumes (-1 iff that request
         is guard-invalid and the load never fired before it — the row
         is masked by the valid bit),
      4. ``req_valid``/``req_value`` are *reference* streams from the
         oracle walk: a backend recomputes valid bits from the op-table
         guards and load/store values from its own gathers; the
         reference exists to pin the first divergence, not to execute,
      5. ``req_step`` coarsens waves into batched gather-before-scatter
         steps (``core/coarsen.py``): steps are contiguous wave runs
         (``req_step`` is a non-decreasing function of ``req_wave``);
         within one step no two requests touch the same flat address
         except loads with loads and the WAR pair (the load's wave
         strictly precedes the store's), and every store's feeding
         loads sit in strictly earlier *steps* — so one step may gather
         all its loads against the pre-step image and then scatter all
         its valid stores. ``batch_waves=False`` degenerates steps to
         waves (``req_step == req_wave``).
    """

    program: ir.Program
    params: dict[str, int]
    # per-op metadata (op order = program.mem_ops order)
    op_ids: list[str]
    op_array: dict[str, str]
    op_is_store: dict[str, bool]
    op_nreq: dict[str, int]
    # per-request streams (program order)
    req_op: np.ndarray  # (n,) int32 index into op_ids
    req_addr: np.ndarray  # (n,) int64 array-local address
    req_flat: np.ndarray  # (n,) int64 flat-memory address
    req_store: np.ndarray  # (n,) bool
    req_valid: np.ndarray  # (n,) bool   (reference, see contract 4)
    req_value: np.ndarray  # (n,) float64 (reference; NaN for invalid)
    req_wave: np.ndarray  # (n,) int64
    req_step: np.ndarray  # (n,) int64 batched step (contract 5)
    req_ordinal: np.ndarray  # (n,) int64 k-th request of its own op
    # compute bodies (core/optable) + captured operand streams
    tables: dict[str, optablelib.StoreTable]
    env: dict[str, list[np.ndarray]]  # store op -> per-slot streams
    dep_maps: dict[str, dict[str, np.ndarray]]  # store op -> load op -> map
    # flat protected-memory layout
    array_order: list[str]
    base: dict[str, int]
    mem_size: int
    stats: WaveStats = None
    # cross-PE FIFO edge metadata (DESIGN.md §11): one dict per edge
    # with idx/prod_pe/cons_pe/local/depth/base/n_tokens/push_op/pop_op;
    # the edge's circular slots live at [base, base+depth) inside
    # mem_size (zero-init, not in array_order)
    fifo_edges: list = dataclasses.field(default_factory=list)
    # MonotonicHint sanitizer data (DESIGN.md §12): one dict per hinted
    # op — ``op``, ``resets`` (request ordinals where an asserted
    # non-monotonic loop was re-entered, the only legal decrease
    # points), ``innermost`` (the hint's innermost_monotonic bit).
    # None when hints exist but capture was impossible (speculative
    # programs run the unhooked walk); ``drive_plan(validate_hints=
    # True)`` then refuses rather than silently skipping.
    hint_checks: Optional[list] = dataclasses.field(default_factory=list)

    @property
    def n_requests(self) -> int:
        return len(self.req_op)


@dataclasses.dataclass
class ExecResult:
    arrays: dict[str, np.ndarray]
    stats: WaveStats
    waves: np.ndarray  # per-request wave index, in program order
    plan: Optional[WavePlan] = None


def frontier_merge(src_addr: np.ndarray, dst_addr: np.ndarray) -> np.ndarray:
    """For each dst request (monotonic source stream!): the number of src
    requests that must commit before it = |{i : src_addr[i] <= dst}|
    under monotonic non-decreasing src_addr. This is the §3.1 insight
    vectorized: one searchsorted instead of an address-history search.

    Returns the required src commit count per dst element.
    """
    return np.searchsorted(src_addr, dst_addr, side="right")


def _trace_stream(
    program: ir.Program,
    dae,
    arrays: dict[str, np.ndarray],
    params: dict[str, int],
    trace_mode: str,
    oracle_loads=None,
    predictor: str = "auto",
) -> tuple[list[str], list[int], list[bool]]:
    """Program-order (op id, address, is_store) stream from AGU traces.

    Global program order is lexicographic on the polyhedral 2d+1 key —
    static body positions and the §4 never-reset counters interleaved,
    with the op's own body position last. Supplies everything except
    values/valid bits, which only the oracle walk can produce
    (``oracle_loads`` feeds the speculative AGU of loss-of-decoupling
    PEs from that same walk).
    """
    from repro.core import schedule as schedlib

    traces = schedlib.trace_program(
        program, dae, arrays, params, mode=trace_mode,
        oracle_loads=oracle_loads, predictor=predictor,
    )
    loop_pos, op_pos = program.static_positions()
    op_path = {op.id: path for op, path in program.mem_ops()}
    ops = sorted(traces)
    if not ops:
        return [], [], []
    width = 2 * max(tr.depth for tr in traces.values()) + 1
    mats = []
    for op_id in ops:
        tr = traces[op_id]
        path = op_path[op_id]
        key = np.full((tr.n_req, width), -1, dtype=np.int64)
        for j in range(tr.depth):
            key[:, 2 * j] = loop_pos[id(path[j])]
            key[:, 2 * j + 1] = tr.sched[:, j]
        key[:, 2 * tr.depth] = op_pos[op_id]
        mats.append(key)
    stacked = np.concatenate(mats, axis=0)
    order = np.lexsort(stacked.T[::-1])
    flat_op: list[str] = []
    flat_addr = np.concatenate([traces[o].addr for o in ops])
    flat_store: list[bool] = []
    for op_id in ops:
        tr = traces[op_id]
        flat_op.extend([op_id] * tr.n_req)
        flat_store.extend([tr.is_store] * tr.n_req)
    return (
        [flat_op[i] for i in order],
        flat_addr[order].tolist(),
        [flat_store[i] for i in order],
    )


def build_wave_plan(
    program: ir.Program,
    arrays: dict[str, np.ndarray],
    params: Optional[dict[str, int]] = None,
    trace_mode=cfglib.UNSET,
    speculation=cfglib.UNSET,
    predictor=cfglib.UNSET,
    batch_waves=cfglib.UNSET,
    fifo_depth=cfglib.UNSET,
    symbolic_admission=cfglib.UNSET,
    config: Optional[cfglib.RunConfig] = None,
) -> WavePlan:
    """Run the AGU/CU front-end and emit the backend-consumable plan.

    One hooked oracle walk supplies (a) the reference value/valid
    streams, (b) the op-table environment slots via the ``aux_exprs``
    interpreter hook, (c) the dep alignment maps (most recent request
    of each feeding load at every store request), and — for speculative
    programs — (d) the load streams the run-ahead AGU predicts against.
    ``trace_mode != "interp"`` additionally builds op/addr/kind streams
    through the trace compiler and asserts they agree with the walk.

    ``speculation="auto"`` admits loss-of-decoupling programs
    (load-dependent trips/addresses, DESIGN.md §10): the wave partition
    works off the *true* post-squash request stream — phantom squash
    traffic is a DU-timing artifact and has no wave-executor analogue.
    ``predictor`` (``dae.PREDICTORS``) is accepted for API uniformity
    with ``simulate()``: the post-squash streams are identical under
    every predictor, so the emitted plan does not depend on it.

    ``batch_waves`` (default on) coarsens the wave partition into
    batched steps (WavePlan contract 5); ``False`` keeps one step per
    wave — the partition itself is identical either way.
    ``symbolic_admission`` (default on) feeds the certifier's per-op
    conflict-freedom proofs (``analysis.deps.symbolically_free_ops``)
    to the coarsener so proven-disjoint dep-edges batch without address
    enumeration — the resulting steps are bit-identical, the flag only
    controls whether the fast path (and its ``WaveStats`` accounting)
    is used.

    Cross-PE FIFO edges (DESIGN.md §11) become ``fifo_depth`` circular
    pseudo-memory slots per edge, appended after the real arrays in the
    flat image: each push is a store-like pseudo-request (``~push:K``)
    and each pop a load-like one (``~pop:K``) at slot ``token %
    fifo_depth``, so the ordinary same-address sweep yields the
    producer-before-consumer dep edge (slot RAW) *and* bounded
    backpressure (slot WAW/WAR: push ``k+depth`` lands strictly after
    pop ``k``) — ``validate_plan`` asserts both per edge.

    ``config=`` accepts a ``repro.core.config.RunConfig``; the
    executor consumes its ``trace_mode``/``speculation``/``predictor``/
    ``batch_waves``/``fifo_depth``/``symbolic_admission`` fields and
    ignores the simulator-only ones (``mode``, ``engine``, ...). A
    conflicting explicit kwarg raises ``config.ConfigConflict``.
    """
    cfg = cfglib.resolve(
        config, trace_mode=trace_mode, speculation=speculation,
        predictor=predictor, batch_waves=batch_waves,
        symbolic_admission=symbolic_admission,
    )
    trace_mode, speculation, predictor = (
        cfg.trace_mode, cfg.speculation, cfg.predictor
    )
    batch_waves, symbolic_admission = cfg.batch_waves, cfg.symbolic_admission
    # fifo_depth=None in a config means "default" (4 here, matching
    # SimParams.fifo_depth) — only a real config value can conflict
    if fifo_depth is cfglib.UNSET:
        fifo_depth = cfg.fifo_depth if cfg.fifo_depth is not None else 4
    elif cfg.fifo_depth is not None and cfg.fifo_depth != fifo_depth:
        raise cfglib.ConfigConflict(
            f"explicit fifo_depth={fifo_depth} conflicts with explicit "
            f"config=RunConfig(fifo_depth={cfg.fifo_depth})"
        )
    fifo_depth = int(fifo_depth)
    params = params or {}

    from repro.core import coarsen as coarsenlib
    from repro.core import dae as daelib
    from repro.core import fifo as fifolib

    dae = daelib.decouple(program, speculation=speculation, predictor=predictor)
    fifo_spec = None
    if dae.fifo_edges:
        if dae.spec:
            raise NotImplementedError(
                "cross-PE FIFO streaming cannot combine with speculative "
                "AGUs (loss-of-decoupling PEs) in the wave executor"
            )
        fifo_spec = fifolib.analyze_program(program, dae)
        fifolib.check_depth(fifo_spec, fifo_depth)
    # the flat image and the op-table closures compute in f64; a
    # narrower protected array would make the oracle round every store
    # to the array dtype and the backends diverge in the last ulp —
    # reject it up front instead of tripping a divergence assert deep
    # in the wave loop (unprotected Read arrays may be any dtype)
    for arr in sorted({op.array for op, _ in program.mem_ops()}):
        if arrays[arr].dtype != np.float64:
            raise ValueError(
                f"wave executor requires float64 protected arrays: "
                f"'{arr}' is {arrays[arr].dtype}"
            )
    # consumer stores reading streamed locals compile those to CDeps on
    # the pseudo pop ops (optable stream_deps, DESIGN.md §11)
    stream_deps: dict[str, dict[str, str]] = {}
    if fifo_spec:
        for op, _path in program.mem_ops():
            if not op.is_store:
                continue
            ins = fifo_spec.in_edges.get(dae.op_to_pe[op.id], ())
            if ins:
                stream_deps[op.id] = {
                    name: f"~pop:{eidx}" for eidx, name in ins
                }
    tables = optablelib.compile_store_tables(program, stream_deps or None)
    aux_exprs = {
        op_id: t.env_exprs for op_id, t in tables.items() if t.env_exprs
    }

    # --- pass 1: hooked oracle walk (reference + CU operand capture) -----
    per_op_vv: dict[str, list[tuple[bool, Optional[float]]]] = {}
    load_streams: dict[str, list[float]] = {}
    env_rows: dict[str, list[tuple]] = {op_id: [] for op_id in aux_exprs}
    dep_rows: dict[str, dict[str, list[int]]] = {
        op_id: {ld: [] for ld in t.deps} for op_id, t in tables.items()
    }
    counts: dict[str, int] = {}
    interp_stream: list[tuple[str, int, bool]] = []
    # FIFO token capture: (pos in the real request stream, kind, edge
    # idx, token value) — pops fire at consumer leaf-instance entry
    # (before the instance's own requests), pushes at producer instance
    # exit (after them); same-pos events keep chronological order
    fifo_events: list[tuple[int, str, int, float]] = []
    n_real = [0]

    # MonotonicHint sanitizer capture (DESIGN.md §12): for every hinted
    # op, record the request ordinals at which its deepest *asserted*
    # non-monotonic loop is (re-)entered — exactly the positions where
    # the address stream may legally decrease. ``drive_plan(
    # validate_hints=True)`` replays the positional check.
    hinted = [(op, path) for op, path in program.mem_ops() if op.hint is not None]
    hint_count: dict[str, int] = {}
    hint_resets: dict[str, list[int]] = {}
    hint_marker: dict[int, list[str]] = {}
    if hinted and not dae.spec:
        from repro.analysis import deps as depslib

        for op, path in hinted:
            hint_count[op.id] = 0
            hint_resets[op.id] = []
            if op.hint.innermost_monotonic:
                max_nm = depslib._max_allowed_reset_depth(op.hint, len(path))
                if max_nm >= 1:
                    hint_marker.setdefault(id(path[max_nm]), []).append(op.id)

    def aux_hook(op_id, values):
        env_rows[op_id].append(values)

    def hook(op_id, addr, is_store, valid, value):
        n_real[0] += 1
        per_op_vv.setdefault(op_id, []).append((valid, value))
        if op_id in hint_count:
            hint_count[op_id] += 1
        if is_store:
            for ld, rows in dep_rows[op_id].items():
                rows.append(counts.get(ld, 0) - 1)
        else:
            counts[op_id] = counts.get(op_id, 0) + 1
            if dae.spec:
                # only the speculative AGU consumes the load streams
                load_streams.setdefault(op_id, []).append(value)
        if trace_mode == "interp":
            interp_stream.append((op_id, addr, is_store))

    fifo_loop_hook = None
    if fifo_spec:
        push_leaves: dict[int, list] = {}
        pop_leaves: dict[int, list] = {}
        for e in fifo_spec.edges:
            push_leaves.setdefault(id(dae.pes[e.prod_pe].leaf), []).append(e)
            pop_leaves.setdefault(id(dae.pes[e.cons_pe].leaf), []).append(e)

        def fifo_loop_hook(loop, phase, reader):
            if phase == "enter":
                for e in pop_leaves.get(id(loop), ()):
                    # the enclosing scope holds the producer's token
                    # value (sequential semantics); counts updates live
                    # so a consumer store's dep row sees its own pop
                    o = f"~pop:{e.idx}"
                    counts[o] = counts.get(o, 0) + 1
                    fifo_events.append(
                        (n_real[0], "pop", e.idx, float(reader(e.local)))
                    )
            else:
                for e in push_leaves.get(id(loop), ()):
                    # zero-trip instances still push: the init value
                    fifo_events.append(
                        (n_real[0], "push", e.idx, float(reader(e.local)))
                    )

    loop_hook = fifo_loop_hook
    if hint_marker:

        def loop_hook(loop, phase, reader):
            if phase == "enter":
                for o in hint_marker.get(id(loop), ()):
                    hint_resets[o].append(hint_count[o])
            if fifo_loop_hook is not None:
                fifo_loop_hook(loop, phase, reader)

    if dae.spec:
        # speculative programs get the documented auto-reject
        # (DESIGN.md §10) through the shared conversion site
        from repro.core import speculate

        speculate.interpret_hooked(
            program, arrays, params, hook,
            aux_exprs=aux_exprs, aux_hook=aux_hook,
        )
    else:
        ir.interpret(
            program, arrays, params, trace_hook=hook,
            aux_exprs=aux_exprs, aux_hook=aux_hook, loop_hook=loop_hook,
        )

    if trace_mode != "interp":
        req_op_l, req_addr_l, req_store_l = _trace_stream(
            program, dae, arrays, params, trace_mode,
            oracle_loads=load_streams if dae.spec else None,
            predictor=predictor,
        )
        n_oracle = sum(len(v) for v in per_op_vv.values())
        assert n_oracle == len(req_op_l), (
            f"trace stream has {len(req_op_l)} requests, oracle walk "
            f"{n_oracle} — trace compiler divergence"
        )
    else:
        req_op_l = [r[0] for r in interp_stream]
        req_addr_l = [r[1] for r in interp_stream]
        req_store_l = [r[2] for r in interp_stream]

    op_ids = [op.id for op, _ in program.mem_ops()]
    op_array = {op.id: op.array for op, _ in program.mem_ops()}
    op_is_store = {op.id: op.is_store for op, _ in program.mem_ops()}

    # merge the FIFO token events into the request stream as pseudo
    # requests on the edge's circular slots (module docstring) — after
    # the trace-count assert, which covers real requests only
    push_k: dict[int, int] = {}
    if fifo_events:
        pop_k: dict[int, int] = {}
        m_op: list[str] = []
        m_addr: list[int] = []
        m_store: list[bool] = []
        ev = 0
        for pos in range(len(req_op_l) + 1):
            while ev < len(fifo_events) and fifo_events[ev][0] == pos:
                _p, kind, eidx, value = fifo_events[ev]
                ev += 1
                if kind == "push":
                    o = f"~push:{eidx}"
                    k = push_k.get(eidx, 0)
                    push_k[eidx] = k + 1
                    m_store.append(True)
                else:
                    o = f"~pop:{eidx}"
                    k = pop_k.get(eidx, 0)
                    pop_k[eidx] = k + 1
                    m_store.append(False)
                m_op.append(o)
                m_addr.append(k % fifo_depth)
                per_op_vv.setdefault(o, []).append((True, value))
            if pos < len(req_op_l):
                m_op.append(req_op_l[pos])
                m_addr.append(req_addr_l[pos])
                m_store.append(req_store_l[pos])
        req_op_l, req_addr_l, req_store_l = m_op, m_addr, m_store
    if fifo_spec:
        for e in fifo_spec.edges:
            for o, st in ((f"~push:{e.idx}", True), (f"~pop:{e.idx}", False)):
                op_ids.append(o)
                op_array[o] = f"~fifo:{e.idx}"
                op_is_store[o] = st
            po = f"~push:{e.idx}"
            tables[po] = optablelib.StoreTable(
                op_id=po, array=f"~fifo:{e.idx}", deps=(),
                env_exprs=(ir.Local(e.local),),  # descriptive; slot 0 is
                value=optablelib.CEnv(0),        # the captured token
                guard=None, frozen_reads=(),
            )
            dep_rows[po] = {}

    n = len(req_op_l)
    op_index = {o: i for i, o in enumerate(op_ids)}

    req_op = np.fromiter(
        (op_index[o] for o in req_op_l), dtype=np.int32, count=n
    )
    req_addr = np.asarray(req_addr_l, dtype=np.int64) if n else np.zeros(
        0, dtype=np.int64
    )
    req_store = np.asarray(req_store_l, dtype=bool) if n else np.zeros(
        0, dtype=bool
    )

    # per-op ordinal + the (valid, value) reference streams, by ordinal
    req_ordinal = np.zeros(n, dtype=np.int64)
    req_valid = np.zeros(n, dtype=bool)
    req_value = np.full(n, np.nan, dtype=np.float64)
    taken: dict[str, int] = {}
    for i in range(n):
        o = req_op_l[i]
        k = taken.get(o, 0)
        taken[o] = k + 1
        req_ordinal[i] = k
        valid, value = per_op_vv[o][k]
        req_valid[i] = valid
        if value is not None:
            req_value[i] = value

    # --- pass 2: wave assignment (one program-order sweep) ---------------
    waves = np.zeros(n, dtype=np.int64)
    # per (array, addr): wave of last store; max wave of loads since it
    last_store_wave: dict[tuple[str, int], int] = {}
    loads_since_store: dict[tuple[str, int], int] = {}
    # per load op: wave of its k-th request (appended in program order,
    # so list position == ordinal) — the exact per-(PE, dep-edge)
    # dataflow inputs a store's wave is computed from
    wave_of_load: dict[str, list[int]] = {}
    # per request: max wave over its feeding loads (-1 for loads and
    # dep-free stores) — feeds the wave-batching admission rule
    feed_max = np.full(n, -1, dtype=np.int64)

    # FIFO pushes carry a CU local: they must land strictly after every
    # load (and pop) of the producer PE seen so far — tracked as a
    # running per-PE wave frontier over the load-like requests
    pe_frontier: dict[int, int] = {}
    push_pe: dict[str, int] = {}
    pop_pe: dict[str, int] = {}
    if fifo_spec:
        for e in fifo_spec.edges:
            push_pe[f"~push:{e.idx}"] = e.prod_pe
            pop_pe[f"~pop:{e.idx}"] = e.cons_pe

    for i in range(n):
        o = req_op_l[i]
        key = (op_array[o], req_addr_l[i])
        if req_store[i]:
            # WAW: after last store; WAR: after every load since it;
            # dataflow: after exactly the load requests feeding this
            # store's value/guard (dep maps, contract 3) — invalid §6
            # stores included, their *guard* still reads those loads
            fm = -1
            k = req_ordinal[i]
            for ld in tables[o].deps:
                m = dep_rows[o][ld][k]
                if m >= 0:
                    lw = wave_of_load[ld][m]
                    if lw > fm:
                        fm = lw
            ppe = push_pe.get(o)
            if ppe is not None:
                fm = max(fm, pe_frontier.get(ppe, -1))
            feed_max[i] = fm
            w = max(
                last_store_wave.get(key, -1) + 1,
                loads_since_store.get(key, -1) + 1,
                fm + 1,
            )
            if req_valid[i]:
                last_store_wave[key] = w
                loads_since_store[key] = -1
            else:
                # §6: invalid stores occupy a wave slot (they update the
                # frontier in hardware) but have no memory effect
                last_store_wave[key] = max(last_store_wave.get(key, -1), w)
        else:
            # RAW: after the last store to this address
            w = last_store_wave.get(key, -1) + 1
            loads_since_store[key] = max(loads_since_store.get(key, -1), w)
            wave_of_load.setdefault(o, []).append(w)
            if fifo_spec:
                pe_of = pop_pe.get(o, dae.op_to_pe.get(o))
                if pe_of is not None and w > pe_frontier.get(pe_of, -1):
                    pe_frontier[pe_of] = w
        waves[i] = w

    n_waves = int(waves.max()) + 1 if n else 0

    # --- wave coarsening: batch conflict-free waves into steps -----------
    # (needs flat addresses — computed below — so steps are assigned
    # after the layout pass)

    # --- flat protected-memory layout ------------------------------------
    # real arrays first; each FIFO edge then gets ``fifo_depth`` circular
    # slots inside ``mem_size`` (zero-init in the flat image, never
    # unpacked — ``array_order`` stays real-only), so backends execute
    # FIFO traffic as ordinary gathers/scatters without special cases
    protected = sorted({op.array for op, _ in program.mem_ops()})
    base: dict[str, int] = {}
    off = 0
    for a in protected:
        base[a] = off
        off += len(arrays[a])
    fifo_meta: list[dict] = []
    if fifo_spec:
        for e in fifo_spec.edges:
            base[f"~fifo:{e.idx}"] = off
            fifo_meta.append({
                "idx": e.idx, "prod_pe": e.prod_pe, "cons_pe": e.cons_pe,
                "local": e.local, "depth": int(fifo_depth),
                "base": off, "n_tokens": push_k.get(e.idx, 0),
                "push_op": f"~push:{e.idx}", "pop_op": f"~pop:{e.idx}",
            })
            off += fifo_depth
    op_base = np.asarray(
        [base[op_array[o]] for o in op_ids], dtype=np.int64
    ) if op_ids else np.zeros(0, dtype=np.int64)
    req_flat = (op_base[req_op] + req_addr) if n else req_addr.copy()

    env = {
        op_id: [
            np.asarray([row[k] for row in rows])
            for k in range(len(aux_exprs[op_id]))
        ]
        for op_id, rows in env_rows.items()
    }
    if fifo_spec:
        # push "stores" compute through a one-slot env stream: the
        # captured token values, in push order
        for e in fifo_spec.edges:
            env[f"~push:{e.idx}"] = [np.asarray(
                [v for _p, kind, ei, v in fifo_events
                 if kind == "push" and ei == e.idx],
                dtype=np.float64,
            )]
    dep_maps = {
        op_id: {ld: np.asarray(rows, dtype=np.int64)
                for ld, rows in per_ld.items()}
        for op_id, per_ld in dep_rows.items()
    }
    op_nreq = {o: len(per_op_vv.get(o, ())) for o in op_ids}

    # symbolic admission certificates (analysis/deps.py, DESIGN.md §12):
    # requests of certifier-proven conflict-free ops skip the
    # coarsener's address enumeration. FIFO pseudo-ops are never
    # certified (their slot streams are circular by construction).
    sym_free = None
    sym_ops: tuple = ()
    n_sym = 0
    if symbolic_admission:
        from repro.analysis import deps as depslib

        free = depslib.symbolically_free_ops(program)
        sym_ops = tuple(sorted(o for o, ok in free.items() if ok))
        free_arr = np.asarray(
            [free.get(o, False) for o in op_ids], dtype=bool
        ) if op_ids else np.zeros(0, dtype=bool)
        sym_free = free_arr[req_op] if n else np.zeros(0, dtype=bool)
        n_sym = int(sym_free.sum())

    if batch_waves:
        step_of_wave, n_steps = coarsenlib.batch_conflict_free_waves(
            waves, req_flat, req_store, feed_max, symbolic_free=sym_free,
        )
        req_step = step_of_wave[waves] if n else waves.copy()
    else:
        req_step, n_steps = waves.copy(), n_waves

    # hint sanitizer data (None = hints present but capture impossible:
    # the speculative walk has no loop hook)
    hint_checks: Optional[list] = None
    if not (dae.spec and hinted):
        hint_checks = [
            {
                "op": op.id,
                "resets": np.asarray(
                    sorted(set(hint_resets.get(op.id, ()))), dtype=np.int64
                ),
                "innermost": bool(op.hint.innermost_monotonic),
            }
            for op, _path in hinted
        ]

    stats = WaveStats(
        n_requests=n, n_waves=n_waves, sequential_depth=n, n_steps=n_steps,
        n_sym_requests=n_sym, sym_ops=sym_ops,
    )
    return WavePlan(
        program=program, params=dict(params),
        op_ids=op_ids, op_array=op_array, op_is_store=op_is_store,
        op_nreq=op_nreq,
        req_op=req_op, req_addr=req_addr, req_flat=req_flat,
        req_store=req_store, req_valid=req_valid, req_value=req_value,
        req_wave=waves, req_step=req_step, req_ordinal=req_ordinal,
        tables=tables, env=env, dep_maps=dep_maps,
        array_order=protected, base=base, mem_size=off,
        stats=stats, fifo_edges=fifo_meta, hint_checks=hint_checks,
    )


def wave_store_inputs(
    plan: WavePlan, op_id: str, rows: np.ndarray,
    lv_streams: dict[str, np.ndarray],
) -> tuple[dict[str, np.ndarray], list[np.ndarray], int]:
    """Gather the op-table operands for the given requests of one store.

    ``rows`` are global request indices (all of op ``op_id``);
    ``lv_streams`` are the per-load-op value streams the backend has
    produced so far (waves strictly before the current one — WavePlan
    contract 1 guarantees they are filled). Returns (dep value arrays,
    env slot arrays, n) ready for ``StoreTable.eval_value/eval_guard``.
    """
    table = plan.tables[op_id]
    k = plan.req_ordinal[rows]
    deps: dict[str, np.ndarray] = {}
    for ld in table.deps:
        m = plan.dep_maps[op_id][ld][k]
        # -1 = guard-invalid row whose feeding load never fired; clip —
        # the garbage value is masked by the valid bit (contract 3)
        deps[ld] = lv_streams[ld][np.clip(m, 0, None)]
    env = [plan.env[op_id][s][k] for s in range(len(table.env_exprs))]
    return deps, env, len(rows)


def validate_plan(plan: WavePlan) -> None:
    """Assert the WavePlan contract (docstring items 1–3 and 5)
    vectorized.

    Cheap enough to run in tests on every kernel; backends may call it
    defensively before executing an externally produced plan.
    """
    waves, n = plan.req_wave, plan.n_requests
    # 2. intra-wave conflict-freedom: (wave, flat addr) pairs involving
    # a store are unique
    key = waves * max(plan.mem_size, 1) + plan.req_flat
    touched = key[plan.req_store]
    assert len(np.unique(touched)) == len(touched), (
        "two stores share (wave, address)"
    )
    load_keys = set(np.unique(key[~plan.req_store]).tolist())
    for kk in touched.tolist():
        assert kk not in load_keys, "load and store share (wave, address)"
    # 1+3. every store is strictly after the loads feeding it
    lv_wave: dict[str, np.ndarray] = {}
    for op_id, is_store in plan.op_is_store.items():
        if not is_store:
            rows = np.nonzero(plan.req_op == plan.op_ids.index(op_id))[0]
            w = np.zeros(plan.op_nreq[op_id], dtype=np.int64)
            w[plan.req_ordinal[rows]] = waves[rows]
            lv_wave[op_id] = w
    lv_step: dict[str, np.ndarray] = {}
    steps = plan.req_step
    for op_id, is_store in plan.op_is_store.items():
        if not is_store:
            rows = np.nonzero(plan.req_op == plan.op_ids.index(op_id))[0]
            s = np.zeros(plan.op_nreq[op_id], dtype=np.int64)
            s[plan.req_ordinal[rows]] = steps[rows]
            lv_step[op_id] = s
    for op_id, per_ld in plan.dep_maps.items():
        rows = np.nonzero(plan.req_op == plan.op_ids.index(op_id))[0]
        k = plan.req_ordinal[rows]
        for ld, m in per_ld.items():
            mm = m[k]
            ok = mm >= 0
            assert np.all(
                waves[rows][ok] > lv_wave[ld][mm[ok]]
            ), f"store {op_id} not strictly after its {ld} inputs"
            # 5. feeding loads in strictly earlier *steps* too (the
            # batching admission rule — same-step loads do not exist
            # yet when the step's store values are computed)
            assert np.all(
                steps[rows][ok] > lv_step[ld][mm[ok]]
            ), f"store {op_id} shares a step with its {ld} inputs"
            # -1 rows must be guard-invalid (contract 3)
            assert np.all(plan.req_valid[rows][~ok] == False)  # noqa: E712
    # 5. steps coarsen waves order-preservingly: the step index is a
    # non-decreasing function of the wave index
    if n:
        order = np.argsort(waves, kind="stable")
        assert np.all(np.diff(steps[order]) >= 0), (
            "steps do not coarsen waves monotonically"
        )
    # 5. step-level conflict-freedom: stores never share (step, addr)
    # with another store, and only with loads from strictly earlier
    # waves (the batch-internal WAR a gather-before-scatter step allows)
    skey = steps * max(plan.mem_size, 1) + plan.req_flat
    stouched = skey[plan.req_store]
    assert len(np.unique(stouched)) == len(stouched), (
        "two stores share (step, address)"
    )
    store_wave_of = dict(zip(stouched.tolist(),
                             waves[plan.req_store].tolist()))
    lrows = np.nonzero(~plan.req_store)[0]
    for i, kk in zip(lrows.tolist(), skey[lrows].tolist()):
        sw = store_wave_of.get(kk)
        assert sw is None or waves[i] < sw, (
            "load shares (step, address) with a non-later store"
        )
    assert n == 0 or int(waves.max()) + 1 == plan.stats.n_waves
    assert n == 0 or int(steps.max()) + 1 == plan.stats.n_steps
    assert plan.stats.n_steps <= plan.stats.n_waves or n == 0
    # FIFO edges (DESIGN.md §11): per edge, producer-before-consumer
    # ordering and bounded backpressure over the token sequence
    for fe in plan.fifo_edges:
        prow = np.nonzero(plan.req_op == plan.op_ids.index(fe["push_op"]))[0]
        crow = np.nonzero(plan.req_op == plan.op_ids.index(fe["pop_op"]))[0]
        assert len(prow) == len(crow) == fe["n_tokens"], (
            f"fifo edge {fe['idx']}: push/pop token counts diverge"
        )
        pw = waves[prow][np.argsort(plan.req_ordinal[prow])]
        cw = waves[crow][np.argsort(plan.req_ordinal[crow])]
        assert np.all(cw > pw), (
            f"fifo edge {fe['idx']}: pop not strictly after its push"
        )
        d = fe["depth"]
        if len(pw) > d:
            assert np.all(pw[d:] > cw[:-d]), (
                f"fifo edge {fe['idx']}: push overruns the {d}-slot "
                f"buffer (backpressure violated)"
            )
        ps = steps[prow][np.argsort(plan.req_ordinal[prow])]
        cs = steps[crow][np.argsort(plan.req_ordinal[crow])]
        assert np.all(cs > ps), (
            f"fifo edge {fe['idx']}: pop shares a step with its push"
        )


def validate_plan_hints(plan: WavePlan) -> None:
    """Check every hinted op's request stream against its asserted
    monotonicity (``analysis.deps.check_hint_positions``): raises
    ``HintViolation`` with op id + first violating (instance, addr)."""
    from repro.analysis import deps as depslib

    if plan.hint_checks is None:
        raise NotImplementedError(
            "validate_hints: hint capture is unavailable for speculative "
            "programs (the run-ahead walk has no loop hook)"
        )
    for hc in plan.hint_checks:
        i = plan.op_ids.index(hc["op"])
        rows = np.flatnonzero(plan.req_op == i)  # program order
        depslib.check_hint_positions(
            hc["op"], plan.req_addr[rows], hc["resets"], hc["innermost"]
        )


def drive_plan(
    plan: WavePlan,
    mem_step,
    *,
    frozen: dict[str, np.ndarray],
    step_of: Optional[np.ndarray] = None,
    n_steps: Optional[int] = None,
    lib: str = "np",
    check: bool = True,
    max_steps: Optional[int] = None,
    validate_hints: bool = False,
) -> tuple[int, bool]:
    """Shared step-loop driver for every backend.

    Owns everything that must stay identical across backends — the
    batched-step iteration, op-table compute (store values + §6 valid
    bits from *earlier* steps' gathers, contract 5), dep/load-stream
    bookkeeping, and the request-exact divergence checks — and
    delegates only the memory move: ``mem_step(flat_addr, write_mask,
    store_vals) -> gathered f64 values per lane`` over whatever image
    the backend keeps (a numpy array here, a Pallas-resident uint32
    image in ``kernels/wave_exec``). The gather must read the
    *pre-step* image (contract 5 admits WAR inside a step).
    ``step_of``/``n_steps`` default to the plan's batched partition;
    pass ``req_wave`` for one step per wave, or ``arange(n)`` for the
    sequential baseline. Returns (steps taken, ran to completion).

    ``validate_hints=True`` runs the MonotonicHint sanitizer
    (``validate_plan_hints``) before stepping: a user hint contradicted
    by the actual address stream raises ``analysis.deps.HintViolation``
    instead of silently executing with an unsound hazard plan.
    """
    if validate_hints:
        validate_plan_hints(plan)
    if step_of is None:
        step_of = plan.req_step
        n_steps = plan.stats.n_steps
    lv_streams = {
        op_id: np.zeros(plan.op_nreq[op_id], dtype=np.float64)
        for op_id, s in plan.op_is_store.items() if not s
    }
    order = np.argsort(step_of, kind="stable")
    bounds = np.searchsorted(step_of[order], np.arange(n_steps + 1))
    steps = 0
    for w in range(n_steps):
        if max_steps is not None and steps >= max_steps:
            return steps, False
        batch = order[bounds[w]:bounds[w + 1]]
        store_sel = np.nonzero(plan.req_store[batch])[0]
        stores = batch[store_sel]
        # compute: store values/valid from op tables (deps are filled —
        # contract 5). Grouped per op for vectorized closure eval.
        sval = np.zeros(len(batch), dtype=np.float64)
        write = np.zeros(len(batch), dtype=bool)
        for op_i in np.unique(plan.req_op[stores]):
            sel = store_sel[plan.req_op[stores] == op_i]
            rows = batch[sel]
            op_id = plan.op_ids[op_i]
            deps, env, nn = wave_store_inputs(plan, op_id, rows, lv_streams)
            v = plan.tables[op_id].eval_value(deps, env, frozen, nn, lib=lib)
            g = plan.tables[op_id].eval_guard(deps, env, frozen, nn, lib=lib)
            v, g = np.asarray(v, dtype=np.float64), np.asarray(g)
            if check:
                np.testing.assert_array_equal(
                    g, plan.req_valid[rows],
                    err_msg=f"op-table guard diverged from oracle valid "
                    f"bits on {op_id}",
                )
                np.testing.assert_array_equal(
                    v[g], plan.req_value[rows][g],
                    err_msg=f"op-table store values diverged from oracle "
                    f"on {op_id}",
                )
            sval[sel] = np.where(g, v, 0.0)
            write[sel] = g
        got = mem_step(plan.req_flat[batch], write, sval)
        steps += 1
        # collect this wave's load values into the per-op streams
        load_sel = ~plan.req_store[batch]
        loads = batch[load_sel]
        if len(loads):
            got_loads = np.asarray(got, dtype=np.float64)[load_sel]
            if check:
                np.testing.assert_array_equal(
                    got_loads, plan.req_value[loads],
                    err_msg="backend gather diverged from oracle loads",
                )
            for op_i in np.unique(plan.req_op[loads]):
                m = plan.req_op[loads] == op_i
                lv_streams[plan.op_ids[op_i]][
                    plan.req_ordinal[loads[m]]
                ] = got_loads[m]
    return steps, True


def flat_image(plan: WavePlan, arrays: dict[str, np.ndarray]) -> np.ndarray:
    """The flat f64 protected-memory image a backend executes against."""
    mem = np.zeros(max(plan.mem_size, 1), dtype=np.float64)
    for a in plan.array_order:
        mem[plan.base[a]:plan.base[a] + len(arrays[a])] = arrays[a]
    return mem


def unpack_image(
    plan: WavePlan, mem: np.ndarray, arrays: dict[str, np.ndarray]
) -> dict[str, np.ndarray]:
    """Final array dict from a flat image (unprotected arrays copied)."""
    out = {k: np.array(v, copy=True) for k, v in arrays.items()}
    for a in plan.array_order:
        out[a] = mem[plan.base[a]:plan.base[a] + len(arrays[a])].copy()
    return out


def _replay_numpy(plan: WavePlan, arrays: dict[str, np.ndarray]):
    """Reference wave backend: the shared driver over a numpy image.

    Identical to the Pallas backend minus the kernel — same driver,
    same op-table compute, same flat image; the memory step is a numpy
    gather + masked scatter. Every §6 valid bit, store value and
    gathered load is pinned request-exact against the oracle reference
    streams — "validated by construction": effects apply in step order,
    conflicting requests never share a step (except the WAR pair the
    gather-before-scatter ordering resolves), so agreement proves the
    partition, the batching, the dep maps and the compute bodies
    together reproduce sequential semantics.
    """
    mem = flat_image(plan, arrays)

    def mem_step(addr, write, sval):
        got = mem[addr]  # fancy indexing copies: pre-wave state
        mem[addr[write]] = sval[write]
        return got

    drive_plan(plan, mem_step, frozen=arrays, check=True)
    return unpack_image(plan, mem, arrays)


def execute(
    program: ir.Program,
    arrays: dict[str, np.ndarray],
    params: Optional[dict[str, int]] = None,
    trace_mode=cfglib.UNSET,
    speculation=cfglib.UNSET,
    predictor=cfglib.UNSET,
    backend=cfglib.UNSET,
    batch_waves=cfglib.UNSET,
    fifo_depth=cfglib.UNSET,
    symbolic_admission=cfglib.UNSET,
    validate_hints=cfglib.UNSET,
    config: Optional[cfglib.RunConfig] = None,
) -> ExecResult:
    """Wave-partitioned fused execution of ``program``.

    Builds the ``WavePlan`` (AGU/CU front-end, wave partition, op
    tables) and drives it through a backend:

      * ``backend="numpy"`` — the reference replay in this module,
      * ``backend="pallas"`` — ``repro.kernels.wave_exec``: each wave
        runs as a data-parallel Pallas gather→compute→scatter step over
        a flat bit-exact memory image (interpret mode on CPU).

    Both compute store values through the op tables and are asserted
    request-exact against the oracle reference stream; final arrays are
    bit-identical to ``loopir.interpret`` for every Table-1 kernel in
    both trace modes (tests/test_pallas_parity.py).

    ``speculation="auto"`` admits loss-of-decoupling programs
    (load-dependent trips/addresses, DESIGN.md §10): the wave partition
    works off the *true* post-squash request stream — phantom squash
    traffic is a DU-timing artifact and has no wave-executor analogue.
    ``predictor`` (``dae.PREDICTORS``) is accepted for API uniformity:
    final arrays and the wave partition are identical under every
    predictor (tests/test_speculation.py pins this).

    ``batch_waves`` (default on) lets both backends execute batched
    conflict-free wave runs as single steps (WavePlan contract 5);
    ``False`` forces one step per wave. Final arrays are identical.

    ``fifo_depth`` sizes every cross-PE FIFO edge's circular slot
    buffer (DESIGN.md §11). Final arrays are identical for any depth
    >= 1 — a shallower buffer only tightens backpressure, i.e. grows
    the wave/step count.

    ``symbolic_admission`` toggles the certifier's wave-batching fast
    path (bit-identical steps either way, DESIGN.md §12);
    ``validate_hints=True`` checks every ``MonotonicHint`` against the
    plan's actual request streams and raises
    ``analysis.deps.HintViolation`` on a lie.

    ``config=`` accepts a ``repro.core.config.RunConfig``; the
    executor consumes every field except the simulator-only ``mode``/
    ``engine``/``spec_runahead``/``fifo_latency``/``static_prune``. A
    conflicting explicit kwarg raises ``config.ConfigConflict``. Final
    arrays are bit-identical between the two spellings.
    """
    cfg = cfglib.resolve(
        config, trace_mode=trace_mode, speculation=speculation,
        predictor=predictor, backend=backend, batch_waves=batch_waves,
        symbolic_admission=symbolic_admission, validate_hints=validate_hints,
    )
    backend, validate_hints = cfg.backend, cfg.validate_hints
    plan = build_wave_plan(
        program, arrays, params, fifo_depth=fifo_depth, config=cfg,
    )
    if validate_hints:
        validate_plan_hints(plan)
    if backend == "numpy":
        out = _replay_numpy(plan, arrays)
    elif backend == "pallas":
        from repro.kernels import wave_exec

        out = wave_exec.run_plan(plan, arrays).arrays
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return ExecResult(
        arrays=out, stats=plan.stats, waves=plan.req_wave, plan=plan
    )
