"""Cycle-level simulator of the four evaluated systems (paper §7.1).

Modes:
  * ``STA``  — static HLS baseline: leaf-loop *instances* execute in
    program order (with automatic static fusion of hazard-free sibling
    loops, as Intel HLS does); loops with potential intra-loop memory
    dependencies run at a conservative static II; bursting LSUs. STA is
    evaluated analytically (static schedules are closed-form by
    definition); its result arrays come from the sequential oracle.
  * ``LSQ``  — dynamic HLS with a load-store queue [60]: loop instances
    still sequential, intra-loop hazards resolved dynamically by the
    same check machinery, but a *non-bursting* LSU (burst size 1).
  * ``FUS1`` — this paper: all PEs run concurrently, every memory
    request gated only by the synthesized Hazard Safety Checks.
  * ``FUS2`` — FUS1 + store-to-load forwarding (§5.5).

LSQ/FUS modes execute real memory semantics: loads read the backing
array when their DRAM burst completes (or take a forwarded value),
stores commit at burst completion, mis-speculated stores (§6) enter the
pending buffer with their valid bit and ACK at the buffer head without a
DRAM request (Fig. 7). The final state is compared against the
sequential oracle — that comparison is what validates the hazard logic.

Timing model (``SimParams``): a single DRAM channel serves bursts in
issue order; a burst occupies the channel for ``channel_occupancy``
cycles and completes ``dram_latency`` cycles after issue; per-port
dynamic coalescing closes a burst at ``burst_size`` requests or after
``burst_timeout`` idle cycles (§2.1.1, N=16). Each port moves at most
one request per cycle (the paper's II=1 pipelines).

Two engines implement the LSQ/FUS modes (``simulate(engine=...)``):
this module's per-cycle reference ``Engine`` (scalar checks, one
request per port per cycle — the conformance oracle and debugging aid)
and the vectorized event-driven ``engine_event.EventEngine`` (the
default: batched check waves, event-queue time skipping). See
DESIGN.md §1.1-1.2 for the engine contract and drift tolerance.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

import numpy as np

from repro.core import config as cfglib
from repro.core import dae as daelib
from repro.core import du as dulib
from repro.core import fifo as fifolib
from repro.core import hazards as hz
from repro.core import loopir as ir
from repro.core import monotonic as mono
from repro.core import schedule as schedlib


@dataclasses.dataclass
class SimParams:
    dram_latency: int = 200
    burst_size: int = 16
    burst_timeout: int = 16
    channel_occupancy: int = 2  # cycles a burst holds the channel
    cu_latency: int = 8  # load value -> dependent store value
    forward_latency: int = 1
    # speculative AGU (§6 / DESIGN.md §10): cycles from a mispredicted
    # load's value delivery to the squash completing and the corrected
    # epoch becoming issuable
    squash_latency: int = 4
    # speculative run-ahead window: phantom requests per (epoch, op) a
    # mispredicting AGU gets in flight before the truth squashes it — a
    # DSE axis (dse.SweepSpec); cap hits surface in SimResult.spec_stats
    spec_runahead: int = 16
    # static II for loops with potential memory dependencies: a static
    # pipeline cannot disambiguate, so the loop is scheduled at the DRAM
    # round-trip dependence distance (load -> compute -> store visible).
    # Fitted by dse/calibrate.py against the paper Table-1 per-iteration
    # cycle targets (hist+add STA ~110, tanh+spmv ~225, pagerank ~200
    # cycles/iter at 286 MHz; see BENCH_CALIB.json — the earlier hand
    # calibration of 160 undershot the static targets by ~30%).
    sta_mem_dep_ii: int = 224
    pipeline_fill: int = 20  # static pipeline fill/drain per loop instance
    # cross-PE scalar FIFO edges (core/fifo.py, DESIGN.md §11): slots
    # per queue (a full queue backpressures its producer) and cycles
    # from a push to the token becoming poppable
    fifo_depth: int = 4
    fifo_latency: int = 1
    max_cycles: int = 50_000_000


@dataclasses.dataclass
class SimResult:
    """Outcome of one simulated (program, mode, timing) point.

    ``cycles`` is the simulated completion time under the DU timing
    model; ``arrays`` the final protected-memory state (always equal to
    the sequential oracle — that equality is what validates the hazard
    logic); ``dram_bursts``/``dram_requests`` the DRAM traffic,
    ``forwards`` the §5.5 store-to-load forwarding hit count (FUS2),
    and ``squashed`` the speculative AGU's squashed phantom request
    count (0 unless the program runs with ``speculation="auto"``,
    DESIGN.md §10; phantom loads are included in the DRAM counters).
    ``spec_stats`` is ``speculate.SpecPlan.stats()`` — predictor,
    run-ahead window, per-port and per-predictor outcomes, wait/squash
    gate counts, and run-ahead cap visibility; empty for
    non-speculative runs.
    """

    cycles: int
    arrays: dict[str, np.ndarray]
    mode: str
    dram_bursts: int = 0
    dram_requests: int = 0
    forwards: int = 0
    squashed: int = 0
    # per-edge FIFO accounting (core/fifo.py stats dicts) for streaming
    # programs; empty for everything else
    fifo_stats: list = dataclasses.field(default_factory=list)
    # speculate.SpecPlan.stats() for speculative runs; {} otherwise
    spec_stats: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class SharedArtifacts:
    """Precomputed per-(program, arrays, params) state shared across many
    simulation points by the DSE batch runner (``repro.dse``, DESIGN.md
    §9). Every field is a pure function of the program/data — never of
    timing parameters — so injecting it cannot change any result; each
    field falls back to the engine's own computation when ``None``.

      * ``nodep_bits`` — §5.6 NoDependence bit streams keyed
        ``(dst, src)``; may be a superset of the pairs any one plan
        keeps (engines look up by pair id).
      * ``rank_table`` — ``(ranks, counts)`` from
        ``schedule.instance_rank_table`` for the LSQ instance window
        (engines copy ``counts`` before mutating).
      * ``cu_factory`` — ``pe -> CU-like``; the DSE runner passes
        recorded-script replay CUs (``dae.ReplayCU``).
      * ``sta_instances`` — ``(order, info)`` from ``_instances`` for
        the STA analytical model.
      * ``final_arrays`` — the sequential oracle's final state; STA
        results copy it instead of re-interpreting.
    """

    nodep_bits: Optional[dict] = None
    rank_table: Optional[tuple] = None
    cu_factory: Optional[object] = None
    sta_instances: Optional[tuple] = None
    final_arrays: Optional[dict] = None


# ---------------------------------------------------------------------------
# shared compile front-end
# ---------------------------------------------------------------------------


class Compiled:
    """Everything the paper's compiler derives statically for a program.

    ``trace_mode`` selects the AGU/CU front-end path (DESIGN.md §7):
    ``"auto"`` compiles affine PEs and falls back per PE, ``"compiled"``
    demands the vectorized path (raising ``schedule.TraceCompileError``
    otherwise), ``"interp"`` forces the reference interpreter. The
    engines consult it when constructing CUs (``dae.make_cu``).

    ``speculation`` selects the loss-of-decoupling policy (DESIGN.md
    §10): ``"off"`` rejects AGUs that depend on protected loads,
    ``"auto"`` marks them speculative so the trace front-end builds a
    run-ahead AGU with epoch squash. ``predictor`` picks the value
    predictor of that AGU (``dae.PREDICTORS``; dead code when nothing
    speculates).
    """

    def __init__(
        self,
        program: ir.Program,
        forwarding: bool,
        trace_mode: str = "auto",
        speculation: str = "off",
        predictor: str = "auto",
        static_prune: bool = False,
    ):
        self.program = program
        self.trace_mode = trace_mode
        self.speculation = speculation
        self.predictor = predictor
        self.static_prune = static_prune
        self.dae = daelib.decouple(
            program, speculation=speculation, predictor=predictor
        )
        # cross-PE scalar FIFO edges: the static token-protocol gate
        # (core/fifo.py, DESIGN.md §11). Programs it admits run with
        # bounded backpressured queues in both engines; programs it
        # rejects fall back to the historical NotImplementedError —
        # now naming every edge (prod PE, cons PE, local, depth)
        self.fifo = fifolib.FifoSpec(edges=(), in_edges={}, out_edges={})
        if self.dae.fifo_edges:
            edge_list = ", ".join(
                f"(pe{p} -> pe{c}, {name!r}, shared={d})"
                for p, c, name, d in self.dae.fifo_edges
            )
            try:
                self.fifo = fifolib.analyze_program(program, self.dae)
            except fifolib.FifoRejected as exc:
                raise NotImplementedError(
                    "cross-PE scalar FIFO edge(s) outside the "
                    f"bounded-queue token protocol: {edge_list} — {exc}; "
                    "communicate such scalars through a protected array"
                ) from exc
            if self.dae.spec:
                raise NotImplementedError(
                    "speculative AGUs cannot drive cross-PE FIFO "
                    f"streams (edges {edge_list}): squashed epochs have "
                    "no token-protocol semantics"
                )
        self.infos = mono.analyze_program(program)
        self.plan = hz.build_plan(
            program, self.dae, self.infos, forwarding, static_prune=static_prune
        )
        self.op_array = {op.id: op.array for op, _ in program.mem_ops()}
        self.op_path = {op.id: path for op, path in program.mem_ops()}
        self.loop_pos, self.op_pos = program.static_positions()
        # unpruned view for the *static* analysis (STA cannot prune
        # dynamically; any potential pair forces a conservative schedule)
        self.all_pairs = self.plan.pairs + [p for p, _ in self.plan.pruned]

    def pe_has_mem_dep(self, pe_id: int) -> bool:
        # a speculative PE's AGU consumes load values (loss of
        # decoupling): to a static scheduler that IS a loop-carried
        # memory dependence — the recurrence must run at the
        # load-round-trip II even without an aliasing pair
        if pe_id in self.dae.spec:
            return True
        return any(
            p.same_pe and self.dae.op_to_pe[p.dst] == pe_id
            for p in self.all_pairs
        )

    def cross_pe_pairs(self, a: int, b: int) -> list[hz.HazardPair]:
        return [
            p
            for p in self.all_pairs
            if {self.dae.op_to_pe[p.dst], self.dae.op_to_pe[p.src]} == {a, b}
        ]


# ---------------------------------------------------------------------------
# instance bookkeeping (sequential baselines + STA analytical model)
# ---------------------------------------------------------------------------


_KEY_LEN = 18


def _request_key(comp: Compiled, tr, i: int, fuse_group: dict[int, int]):
    """Program-order instance key of one request: positions and counters
    interleaved (the polyhedral 2d+1 schedule), with the trailing leaf
    counter dropped so all iterations of one leaf-loop instance share a
    key. Fused sibling leaves share the group leader's position."""
    pe = comp.dae.pes[tr.pe_id]
    path = comp.op_path[tr.op_id]
    parts: list[int] = []
    if tr.depth == pe.depth:
        for j in range(tr.depth - 1):
            parts += [comp.loop_pos[id(path[j])], int(tr.sched[i][j])]
        leader = comp.dae.pes[fuse_group[tr.pe_id]]
        parts.append(comp.loop_pos[id(leader.leaf)])
    else:  # parent-body op: its own micro-instance per iteration
        for j in range(tr.depth):
            parts += [comp.loop_pos[id(path[j])], int(tr.sched[i][j])]
        parts.append(comp.op_pos[tr.op_id])
    return tuple(parts) + (-1,) * (_KEY_LEN - len(parts))


def _instances(
    comp: Compiled,
    traces: dict[str, schedlib.OpTrace],
    fuse_group: dict[int, int],
):
    """Group requests into program-ordered leaf-loop instances."""
    keys: dict[tuple, dict] = {}
    for op_id, tr in traces.items():
        pe = comp.dae.pes[tr.pe_id]
        for i in range(tr.n_req):
            key = _request_key(comp, tr, i, fuse_group)
            d = keys.setdefault(
                key, {"requests": 0, "loads": 0, "pes": set(), "iters": {}}
            )
            d["requests"] += 1
            if not tr.is_store:
                d["loads"] += 1
            d["pes"].add(tr.pe_id)
            if tr.depth == pe.depth:
                s = d["iters"].setdefault(tr.pe_id, set())
                s.add(int(tr.sched[i][-1]))
    ordered = sorted(keys)
    return ordered, keys


# ---------------------------------------------------------------------------
# STA: analytical static-schedule model
# ---------------------------------------------------------------------------


def _fusion_groups_sta(comp: Compiled) -> dict[int, int]:
    """Static loop fusion (Intel-HLS-like): merge consecutive sibling PEs
    with identical parents, structurally equal trip counts, and no
    possible cross-PE hazard pair."""
    fuse = {pe.id: pe.id for pe in comp.dae.pes}
    # a FIFO edge is a scalar dependence between the PEs: a static
    # scheduler cannot overlap them any more than a hazard pair lets it
    fifo_pairs = {
        frozenset((p, c)) for p, c, _name, _d in comp.dae.fifo_edges
    }
    for a, b in zip(comp.dae.pes, comp.dae.pes[1:]):
        if (
            len(a.path) == len(b.path)
            and a.path[:-1] == b.path[:-1]
            and a.leaf.trip == b.leaf.trip
            and not comp.cross_pe_pairs(a.id, b.id)
            and frozenset((a.id, b.id)) not in fifo_pairs
        ):
            fuse[b.id] = fuse[a.id]
    return fuse


def _simulate_sta(
    comp: Compiled,
    traces: dict[str, schedlib.OpTrace],
    arrays: dict[str, np.ndarray],
    params: dict[str, int],
    p: SimParams,
    shared: Optional[SharedArtifacts] = None,
) -> SimResult:
    if shared is not None and shared.sta_instances is not None:
        order, info = shared.sta_instances
    else:
        fuse = _fusion_groups_sta(comp)
        order, info = _instances(comp, traces, fuse)

    total = 0
    bursts = 0
    requests = 0
    for key in order:
        d = info[key]
        # concurrent fused PEs: instance latency = max over members
        lat = 0
        for pe_id in d["pes"]:
            ii = p.sta_mem_dep_ii if comp.pe_has_mem_dep(pe_id) else 1
            lat = max(lat, len(d["iters"].get(pe_id, (1,))) * ii)
        fill = p.pipeline_fill + (p.dram_latency if d["loads"] else 0)
        # DRAM bandwidth bound for this instance (bursting LSUs)
        n_bursts = -(-d["requests"] // p.burst_size)
        bw = n_bursts * p.channel_occupancy
        total += fill + max(lat, bw)
        bursts += n_bursts
        requests += d["requests"]

    if shared is not None and shared.final_arrays is not None:
        final = {
            k: np.array(v, copy=True) for k, v in shared.final_arrays.items()
        }
    else:
        final = ir.interpret(comp.program, arrays, params)
    return SimResult(
        cycles=total,
        arrays=final,
        mode="STA",
        dram_bursts=bursts,
        dram_requests=requests,
    )


# ---------------------------------------------------------------------------
# event-driven engine (LSQ / FUS1 / FUS2)
# ---------------------------------------------------------------------------


class _Burst:
    __slots__ = ("port", "entries", "opened_at", "closed", "complete_at")

    def __init__(self, port, now):
        self.port = port
        self.entries: list[dulib.PendingEntry] = []
        self.opened_at = now
        self.closed = False
        self.complete_at = -1


# Compute-unit thread: lives in dae.py (the CU half of the AGU/CU
# split), shared by both engines. Kept under the old name for callers.
_CU = daelib.CU


class Engine:
    def __init__(
        self,
        comp: Compiled,
        traces: dict[str, schedlib.OpTrace],
        arrays: dict[str, np.ndarray],
        params: dict[str, int],
        mode: str,
        p: SimParams,
        shared: Optional[SharedArtifacts] = None,
        spec=None,
        validate_hints: bool = False,
    ):
        self.comp = comp
        self.traces = traces
        self.mode = mode
        self.p = p
        if validate_hints:
            # MonotonicHint sanitizer (DESIGN.md §12): raises
            # analysis.deps.HintViolation before any timing runs
            from repro.analysis import deps as depslib

            depslib.check_hinted_traces(comp.program, traces)
        # speculative AGU plan (speculate.SpecPlan): per-request epoch
        # gates + squash traffic; None for non-speculative programs
        self.spec = spec
        if spec is not None:
            self.gate_time = np.full(
                max(spec.n_gates, 1), 2**62, dtype=np.int64
            )
            self.pending_fires = 0
        self.forwarding = mode == "FUS2"
        self.sequential = mode == "LSQ"
        self.burst_size = 1 if mode == "LSQ" else p.burst_size

        self.mem = {k: np.array(v, copy=True) for k, v in arrays.items()}
        self.params = params
        self.ports = {op_id: dulib.Port(tr) for op_id, tr in traces.items()}
        self.pairs_by_dst = comp.plan.by_dst()

        # §5.6 NoDependence bits
        if shared is not None and shared.nodep_bits is not None:
            self.nodep_bits = shared.nodep_bits
        else:
            self.nodep_bits = dulib.nodependence_bits(comp.plan.pairs, traces)

        if shared is not None and shared.cu_factory is not None:
            self.cus = {pe.id: shared.cu_factory(pe) for pe in comp.dae.pes}
        else:
            self.cus = {
                pe.id: daelib.make_cu(
                    pe, self.mem, params, getattr(comp, "trace_mode", "auto"),
                    fifo_edges=comp.dae.fifo_edges,
                )
                for pe in comp.dae.pes
            }
        # bounded backpressured FIFO queues, one per analyzed edge
        # (core/fifo.py); empty dict for non-streaming programs
        self.fifos: dict[int, fifolib.FifoQueue] = {}
        if comp.fifo:
            fifolib.check_depth(comp.fifo, p.fifo_depth)
            self.fifos = {
                e.idx: fifolib.FifoQueue(e, p.fifo_depth, p.fifo_latency)
                for e in comp.fifo.edges
            }
        self.store_values: dict[str, list[tuple[int, float, bool]]] = {}
        self.ready_loads: dict[str, list[dulib.PendingEntry]] = {}

        if self.sequential:
            if shared is not None and shared.rank_table is not None:
                ranks, counts = shared.rank_table
            else:
                fuse = {pe.id: pe.id for pe in comp.dae.pes}  # LSQ: no fusion
                ranks, counts = schedlib.instance_rank_table(
                    traces, comp.dae, comp.loop_pos, comp.op_pos, fuse,
                    comp.op_path,
                )
            self.inst_outstanding = counts.tolist()
            self.req_inst: dict[tuple[str, int], int] = {}
            for op_id, r in ranks.items():
                for i, rank in enumerate(r.tolist()):
                    self.req_inst[(op_id, i)] = rank
            self.inst_window = 0

        self.open_bursts: dict[str, _Burst] = {}
        self.channel_free_at = 0
        self.events: list[tuple[int, int, str, object]] = []
        self._n = 0
        self.now = 0
        self.port_issued_at: dict[str, int] = {k: -1 for k in self.ports}
        self.result = SimResult(cycles=0, arrays={}, mode=mode)
        # debug: per-op oracle load values for first-divergence detection
        self.oracle_loads: Optional[dict[str, list[float]]] = None
        self.issue_log: dict[tuple[str, int], list[str]] = {}

    # -- events ---------------------------------------------------------

    def _post(self, t, kind, payload=None):
        self._n += 1
        heapq.heappush(self.events, (t, self._n, kind, payload))

    # -- main loop --------------------------------------------------------

    def run(self) -> SimResult:
        for cu in self.cus.values():
            self._drain_outbox(cu)
        while True:
            cycle_progress = False
            # 1. process all events due now
            while self.events and self.events[0][0] <= self.now:
                _, _, kind, payload = heapq.heappop(self.events)
                self._event(kind, payload)
                cycle_progress = True
            # 2. settle combinational progress at this cycle
            while self._settle():
                cycle_progress = True
            if self._all_done():
                break
            # 3. advance time. If this cycle made progress, the next cycle
            # may too (per-port issue pacing resets). Otherwise nothing
            # can change until the next event — jump straight to it.
            if cycle_progress:
                self.now += 1
            elif self.events:
                self.now = max(self.now + 1, self.events[0][0])
            else:
                self._deadlock()
            if self.now > self.p.max_cycles:
                raise RuntimeError("max_cycles exceeded")
        self.result.cycles = self.now
        self.result.arrays = self.mem
        self.result.fifo_stats = [q.stats() for q in self.fifos.values()]
        if self.spec is not None:
            self.result.spec_stats = self.spec.stats()
        return self.result

    def _all_done(self):
        return (
            all(p.exhausted and not p.pending for p in self.ports.values())
            and all(cu.done for cu in self.cus.values())
            and not self.open_bursts
            # pending squash events still carry phantom DRAM accounting
            and not (self.spec is not None and self.pending_fires)
        )

    def _deadlock(self):
        lines = [f"DEADLOCK at cycle {self.now} mode={self.mode}"]
        for op_id, p in self.ports.items():
            lines.append(
                f"  {op_id}: next={p.next}/{p.trace.n_req} pending={len(p.pending)}"
                f" ack_addr={p.ack_addr} ack_sched={p.ack_sched}"
            )
        for pe_id, cu in self.cus.items():
            lines.append(f"  cu{pe_id}: done={cu.done} waiting={cu.waiting_on}")
        for q in self.fifos.values():
            lines.append(
                f"  fifo {q.edge.describe()}: occ={q.occupancy}/{q.depth}"
                f" pushed={q.pushed} popped={q.popped}"
            )
        raise RuntimeError("\n".join(lines))

    # -- cycle work ---------------------------------------------------------

    def _settle(self) -> bool:
        progressed = False
        for op_id, port in self.ports.items():
            if self.port_issued_at[op_id] == self.now:
                continue  # one request per port per cycle
            if not port.exhausted and self._try_issue(op_id, port):
                self.port_issued_at[op_id] = self.now
                progressed = True
        for op_id in list(self.open_bursts):
            b = self.open_bursts[op_id]
            if (
                not b.closed
                and b.entries
                and self.now - b.opened_at >= self.p.burst_timeout
            ):
                self._close_burst(op_id, b)
                progressed = True
        for port in self.ports.values():
            if not port.is_store and self._deliver(port):
                progressed = True
        if self.fifos and self._service_fifos():
            progressed = True
        if self.sequential and self._advance_window():
            progressed = True
        return progressed

    def _service_fifos(self) -> bool:
        """Serve CUs blocked on FIFO pops/pushes (DESIGN.md §11).

        Backpressure is the absence of service: a pop against an empty
        (or not-yet-ready) queue and a push against a full one leave
        ``waiting_on`` set, and the settle fixpoint retries once a
        matching push/pop frees the queue. Not-ready heads post a
        ``fifo_tick`` so the time-jump lands on the ready cycle.
        """
        progressed = False
        for cu in self.cus.values():
            while isinstance(cu.waiting_on, tuple):
                kind, eidx = cu.waiting_on
                q = self.fifos[eidx]
                if kind == "fifo_pop":
                    if not q.head_ready(self.now):
                        if q.q:
                            self._post(q.next_ready_time(), "fifo_tick", eidx)
                        q.pop_stalls += 1
                        break
                    cu.feed(q.pop(self.now), self.now)
                else:  # fifo_push
                    if not q.can_push():
                        q.push_stalls += 1
                        break
                    q.push(cu.push_value, self.now)
                    self._post(self.now + q.latency, "fifo_tick", eidx)
                    cu.feed(0.0, self.now)  # push ack; value is ignored
                self._drain_outbox(cu)
                progressed = True
        return progressed

    def _try_issue(self, op_id: str, port: dulib.Port) -> bool:
        idx = port.next
        if self.sequential and self.req_inst[(op_id, idx)] > self.inst_window:
            return False
        if self.spec is not None:
            # epoch gate: a request of a squashed epoch re-issues only
            # once its trigger value delivered + squash completed
            g = self.spec.gates.get(op_id)
            if g is not None and idx < len(g):
                gid = int(g[idx])
                if gid >= 0 and self.gate_time[gid] > self.now:
                    return False
        # stores: the request is sent together with its value (§5.5: a
        # store moves to the pending buffer only with its value)
        value = valid = None
        if port.is_store:
            vq = self.store_values.get(op_id)
            if not vq or vq[0][0] > self.now:
                return False
            value, valid = vq[0][1], vq[0][2]

        req_sched = port.req_sched()
        req_addr = port.req_addr()
        for pair in self.pairs_by_dst.get(op_id, ()):
            if self.sequential and not pair.same_pe:
                continue  # LSQ: cross-loop order enforced by instances
            src_port = self.ports[pair.src]
            use_next = (
                self.forwarding and pair.kind == "RAW" and src_port.is_store
            )
            nodep = False
            if pair.nodependence:
                bits = self.nodep_bits.get((pair.dst, pair.src))
                nodep = bool(bits[idx]) if bits is not None else False
            explain = [] if self.oracle_loads is not None else None
            if not dulib.check_pair(
                pair, req_sched, req_addr, src_port, use_next, nodep, explain
            ):
                return False
            if explain is not None:
                self.issue_log[(op_id, idx)] = (
                    self.issue_log.get((op_id, idx), [])
                ) + explain

        entry = dulib.PendingEntry(
            req_idx=idx,
            addr=req_addr,
            sched=req_sched,
            lastiter=port.req_lastiter(),
        )
        port.next += 1
        port.pending.append(entry)
        if self.sequential:
            pass  # outstanding decremented at ACK
        if port.is_store:
            self.store_values[op_id].pop(0)
            entry.value, entry.valid = value, valid
            if valid:
                self._enqueue_burst(port, entry)
            else:
                # Fig. 7: invalid stores skip DRAM; ACK at buffer head
                self._post(self.now + 1, "invalid_ack", op_id)
        else:
            if not (self.forwarding and self._try_forward(op_id, entry)):
                self._enqueue_burst(port, entry)
        return True

    def _try_forward(self, op_id: str, entry: dulib.PendingEntry) -> bool:
        """§5.5 associative pending-buffer search, youngest match wins.
        Only reached after the modified RAW check passed, so a miss means
        the value is already committed to memory.

        Qualification: only entries that precede the load in *program
        order* may forward — a wrap-around source (e.g. next epoch's
        store) legitimately running ahead must not satisfy this load.
        """
        best = None  # (sort key, entry, src op)
        for pair in self.pairs_by_dst.get(op_id, ()):
            if pair.kind != "RAW":
                continue
            sport = self.ports[pair.src]
            k = pair.shared_depth
            for e in sport.pending:
                if e.addr != entry.addr or not e.valid:
                    continue  # invalid entries never produce a value
                # program-order qualification at the shared depth
                if k > 0:
                    es, rs = e.sched[k - 1], entry.sched[k - 1]
                    before = es < rs or (es == rs and not pair.dst_before_src)
                elif k == 0:
                    before = not pair.dst_before_src
                if not before:
                    continue
                key = (e.sched[k - 1] if k > 0 else 0, not pair.dst_before_src)
                if best is None or key >= best[0]:
                    best = (key, e, pair.src)
        if best is not None:
            _, e, src_op = best
            entry.value = e.value
            entry.forwarded = True
            entry.fwd_src = (src_op, e.req_idx, tuple(e.sched))  # type: ignore
            self.result.forwards += 1
            self._post(
                self.now + self.p.forward_latency, "fwd_ready", (op_id, entry)
            )
            return True
        return False

    # -- bursts -----------------------------------------------------------

    def _enqueue_burst(self, port: dulib.Port, entry):
        b = self.open_bursts.get(port.op_id)
        if b is None or b.closed:
            b = _Burst(port, self.now)
            self.open_bursts[port.op_id] = b
            self._post(self.now + self.p.burst_timeout, "burst_tick", port.op_id)
        b.entries.append(entry)
        if len(b.entries) >= self.burst_size:
            self._close_burst(port.op_id, b)

    def _close_burst(self, op_id: str, b: _Burst):
        b.closed = True
        issue = max(self.now, self.channel_free_at)
        self.channel_free_at = issue + self.p.channel_occupancy
        b.complete_at = issue + self.p.channel_occupancy + self.p.dram_latency
        self.result.dram_bursts += 1
        self.result.dram_requests += len(b.entries)
        self._post(b.complete_at, "burst_done", (op_id, b))
        if self.open_bursts.get(op_id) is b:
            del self.open_bursts[op_id]

    # -- events -----------------------------------------------------------

    def _event(self, kind, payload):
        if kind == "burst_done":
            op_id, b = payload
            port = b.port
            arr = self.mem[self.comp.op_array[op_id]]
            for e in b.entries:
                if port.is_store:
                    arr[e.addr] = e.value
                else:
                    e.value = float(arr[e.addr])
                e.acked = True
            self._ack_prefix(port)
        elif kind == "burst_tick":
            op_id = payload
            b = self.open_bursts.get(op_id)
            if (
                b is not None
                and not b.closed
                and b.entries
                and self.now - b.opened_at >= self.p.burst_timeout
            ):
                self._close_burst(op_id, b)
        elif kind == "fwd_ready":
            op_id, entry = payload
            entry.acked = True
            self._ack_prefix(self.ports[op_id])
        elif kind == "invalid_ack":
            self._ack_prefix(self.ports[payload])
        elif kind == "cu_value":
            op_id, value, valid = payload
            self.store_values.setdefault(op_id, []).append(
                (self.now, value, valid)
            )
        elif kind == "spec_fire":
            self.pending_fires -= 1
            self._fire_gate(payload)
        elif kind == "fifo_tick":
            # pure wake-up: a token matured (or a slot freed) at this
            # cycle; the settle fixpoint does the actual service
            pass
        else:  # pragma: no cover
            raise ValueError(kind)

    def _fire_gate(self, gid: int):
        """Squash of epoch ``gid`` completes: open the gate and release
        the phantom traffic (``speculate.fire_phantoms``; phantoms never
        touch the hazard-visible port state, DESIGN.md §10)."""
        if self.gate_time[gid] <= self.now:
            return
        self.gate_time[gid] = self.now
        from repro.core import speculate as speclib

        self.channel_free_at = speclib.fire_phantoms(
            self.spec, gid, self.now, self.channel_free_at,
            self.burst_size, self.p.channel_occupancy, self.result,
        )

    def _ack_prefix(self, port: dulib.Port):
        if (
            self.oracle_loads is not None
            and not port.is_store
        ):
            for e in port.pending:
                if e.acked and not getattr(e, "checked", False):
                    e.checked = True  # type: ignore[attr-defined]
                    exp = self.oracle_loads[port.op_id][e.req_idx]
                    if not np.isclose(e.value, exp, atol=1e-9):
                        log = "\n  ".join(
                            self.issue_log.get((port.op_id, e.req_idx), [])
                        )
                        fwd = getattr(e, "fwd_src", None)
                        fwd_log = ""
                        if fwd is not None:
                            src_lines = self.issue_log.get((fwd[0], fwd[1]), [])
                            fwd_log = (
                                f"\n  forwarded from {fwd[0]}[{fwd[1]}] "
                                f"sched={fwd[2]}:\n    " + "\n    ".join(src_lines)
                            )
                        raise AssertionError(
                            f"HAZARD VIOLATION: {port.op_id}[{e.req_idx}] "
                            f"addr={e.addr} got {e.value} expected {exp} "
                            f"at cycle {self.now} sched={e.sched} "
                            f"(forwarded={e.forwarded})\n  {log}{fwd_log}"
                        )
        while port.pending:
            e = port.pending[0]
            if not e.acked and e.valid is False:
                # Fig. 7: a mis-speculated store reaching the head of the
                # pending buffer ACKs without waiting for DRAM
                e.acked = True
            if not e.acked:
                break
            port.pending.pop(0)
            port.update_ack(e)
            if self.sequential:
                r = self.req_inst[(port.op_id, e.req_idx)]
                self.inst_outstanding[r] -= 1
            if not port.is_store:
                self.ready_loads.setdefault(port.op_id, []).append(e)
                if self.spec is not None:
                    # delivery of a gated value: a squash gate fires
                    # squash_latency later, a wait gate at delivery
                    # (SpecPlan.fire_delay)
                    rv = self.spec.resolve_of.get(port.op_id)
                    if (
                        rv is not None
                        and e.req_idx < len(rv)
                        and rv[e.req_idx] >= 0
                    ):
                        gid = int(rv[e.req_idx])
                        self.pending_fires += 1
                        self._post(
                            self.now
                            + self.spec.fire_delay(gid, self.p.squash_latency),
                            "spec_fire",
                            gid,
                        )

    def _deliver(self, port: dulib.Port) -> bool:
        ready = self.ready_loads.get(port.op_id)
        if not ready:
            return False
        cu = self.cus[self.traces[port.op_id].pe_id]
        progressed = False
        while ready and cu.waiting_on == port.op_id:
            e = ready.pop(0)
            cu.feed(e.value, self.now)
            self._drain_outbox(cu)
            progressed = True
        return progressed

    def _drain_outbox(self, cu: _CU):
        for op_id, v, valid in cu.outbox:
            self.store_values.setdefault(op_id, [])
            self._post(self.now + self.p.cu_latency, "cu_value", (op_id, v, valid))
        cu.outbox.clear()

    def _advance_window(self) -> bool:
        progressed = False
        while (
            self.inst_window < len(self.inst_outstanding)
            and self.inst_outstanding[self.inst_window] == 0
        ):
            self.inst_window += 1
            progressed = True
        return progressed


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def simulate(
    program: ir.Program,
    arrays: dict[str, np.ndarray],
    params: Optional[dict[str, int]] = None,
    mode=cfglib.UNSET,
    sim: Optional[SimParams] = None,
    validate: bool = False,
    engine=cfglib.UNSET,
    trace_mode=cfglib.UNSET,
    speculation=cfglib.UNSET,
    predictor=cfglib.UNSET,
    static_prune=cfglib.UNSET,
    validate_hints=cfglib.UNSET,
    config: Optional[cfglib.RunConfig] = None,
) -> SimResult:
    """Simulate ``program`` under one of the four evaluated systems.

    ``engine`` selects the timing engine for LSQ/FUS modes:

      * ``"event"`` (default) — vectorized event-driven engine
        (core/engine_event.py): batched numpy hazard-check waves, time
        advanced only at DRAM/CU/forwarding events. Identical final
        arrays; cycle counts match the cycle engine within the tolerance
        documented in DESIGN.md.
      * ``"cycle"`` — the reference per-cycle engine: one request per
        port per cycle, scalar checks, per-request issue logging when
        validating. Slow; use for conformance and first-divergence
        debugging.

    STA is evaluated analytically and ignores ``engine``.

    ``trace_mode`` selects the AGU/CU front-end (``"auto"`` |
    ``"compiled"`` | ``"interp"``, see ``schedule.trace_program``); both
    engines consume the same streams, so results are identical across
    trace modes — ``"compiled"`` just builds them closed-form.

    ``speculation`` selects the loss-of-decoupling policy (DESIGN.md
    §10): ``"off"`` (default) raises ``dae.LossOfDecoupling`` when an
    AGU depends on a protected load value; ``"auto"`` builds a
    speculative run-ahead AGU instead — value prediction, epoch
    tagging, rollback-free squash through the §6 valid-bit path — and
    opens load-dependent-trip/address kernels. ``predictor``
    (``dae.PREDICTORS``: ``"last"`` | ``"stride"`` | ``"context"`` |
    ``"auto"``) picks the speculative AGU's value predictor; the
    run-ahead window is ``SimParams.spec_runahead``. Final arrays stay
    bit-identical to the sequential oracle under every setting — the
    predictor only moves epoch gates and phantom traffic.

    ``static_prune`` lets the symbolic dependence certifier
    (``analysis/deps.py``, DESIGN.md §12) drop hazard pairs whose
    runtime check is provably a tautology — cycles and arrays stay
    bit-identical, the plan just carries fewer pairs. ``validate_hints``
    is the dynamic complement: every user ``MonotonicHint`` is checked
    against the op's actual address stream and a lying hint raises
    ``analysis.deps.HintViolation`` with the op id and first violating
    (instance, addr) pair.

    ``config=`` accepts a ``repro.core.config.RunConfig`` carrying all
    of the above knobs at once (the individual kwargs remain as
    deprecated pass-throughs; an explicit kwarg that conflicts with an
    explicit config raises ``config.ConfigConflict``). A config's
    non-``None`` ``spec_runahead``/``fifo_depth``/``fifo_latency``
    override the matching ``sim=`` fields; ``backend``/``batch_waves``/
    ``symbolic_admission`` belong to the wave executor and are ignored
    here. Results are bit-identical between the two spellings.
    """
    cfg = cfglib.resolve(
        config, mode=mode, engine=engine, trace_mode=trace_mode,
        speculation=speculation, predictor=predictor,
        static_prune=static_prune, validate_hints=validate_hints,
    )
    mode, engine, trace_mode = cfg.mode, cfg.engine, cfg.trace_mode
    speculation, predictor = cfg.speculation, cfg.predictor
    static_prune, validate_hints = cfg.static_prune, cfg.validate_hints
    assert trace_mode in schedlib.TRACE_MODES, f"unknown trace mode {trace_mode!r}"
    params = params or {}
    p = cfg.apply_sim(sim, SimParams())
    comp = Compiled(
        program, forwarding=(mode == "FUS2"), trace_mode=trace_mode,
        speculation=speculation, predictor=predictor,
        static_prune=static_prune,
    )
    spec_out: list = []
    oracle_loads: Optional[dict[str, list[float]]] = None
    if comp.dae.spec:
        # the speculative AGU predicts against the oracle's load
        # streams; compute them once and share with validation below
        from repro.core import speculate

        oracle_loads = speculate.oracle_load_streams(program, arrays, params)
    traces = schedlib.trace_program(
        program, comp.dae, arrays, params, mode=trace_mode,
        spec_out=spec_out, oracle_loads=oracle_loads,
        predictor=predictor, spec_runahead=p.spec_runahead,
    )

    if validate and mode != "STA" and oracle_loads is None:
        oracle_loads = {}

        def hook(op_id, addr, is_store, valid, value):
            if not is_store:
                oracle_loads.setdefault(op_id, []).append(value)

        ir.interpret(program, arrays, params, trace_hook=hook)

    return simulate_traced(
        comp, traces, arrays, params, mode=mode, sim=p, engine=engine,
        oracle_loads=oracle_loads if (validate and mode != "STA") else None,
        spec_plan=spec_out[0] if spec_out else None,
        validate_hints=validate_hints,
    )


def simulate_traced(
    comp: Compiled,
    traces: dict[str, schedlib.OpTrace],
    arrays: dict[str, np.ndarray],
    params: dict[str, int],
    mode: str = "FUS2",
    sim: Optional[SimParams] = None,
    engine: str = "event",
    oracle_loads: Optional[dict] = None,
    shared: Optional[SharedArtifacts] = None,
    spec_plan=None,
    validate_hints: bool = False,
) -> SimResult:
    """Simulate from an already-compiled front-end.

    The lower half of ``simulate()``: takes the ``Compiled`` analysis
    and the materialized AGU request streams instead of rebuilding them,
    plus an optional ``SharedArtifacts`` bundle. This is the entry point
    the DSE batch runner (``repro.dse``) uses to run many timing/mode
    points against one compiled program — results are bit-identical to
    ``simulate()`` with the same settings, because every shared artifact
    is timing-independent (DESIGN.md §9).

    ``oracle_loads`` (op id -> in-order load value list/array) enables
    per-request validation against the sequential oracle, as
    ``simulate(validate=True)`` does. ``spec_plan`` is the
    ``speculate.SpecPlan`` the trace front-end produced for speculative
    programs (``trace_program(spec_out=...)``) — required whenever the
    compiled DAE has speculative PEs, ignored otherwise.
    """
    p = sim or SimParams()
    if mode == "STA":
        if validate_hints:
            from repro.analysis import deps as depslib

            depslib.check_hinted_traces(comp.program, traces)
        return _simulate_sta(comp, traces, arrays, params, p, shared=shared)
    assert not (comp.dae.spec and spec_plan is None), (
        "speculative program simulated without its SpecPlan — pass "
        "trace_program(spec_out=...)'s plan through spec_plan"
    )

    if engine == "event":
        from repro.core import engine_event

        ev = engine_event.EventEngine(
            comp, traces, arrays, params, mode, p,
            oracle_loads=oracle_loads, shared=shared, spec=spec_plan,
            validate_hints=validate_hints,
        )
        return ev.run()
    eng = Engine(
        comp, traces, arrays, params, mode, p, shared=shared, spec=spec_plan,
        validate_hints=validate_hints,
    )
    if oracle_loads is not None:
        eng.oracle_loads = {k: list(v) for k, v in oracle_loads.items()}
    return eng.run()
