"""falcon-mamba-7b [ssm]: pure Mamba-1, attention-free.
[arXiv:2410.05355; unverified]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=65024,
    attn_type="none", ssm="mamba1", ssm_state=16, d_conv=4, expand=2,
    gated=False,
))
