"""zamba2-7b [hybrid]: Mamba-2 backbone with a shared attention block
applied periodically. [arXiv:2411.15242; unverified]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000, head_dim=112,
    attn_type="gqa", ssm="mamba2", ssm_state=64, d_conv=4, expand=2,
    shared_attn_every=6,
    gated=True, act="silu",
))
