"""moonshot-v1-16b-a3b [moe]: Moonlight-style 64 experts top-6 with
shared experts. [hf:moonshotai/Moonlight-16B-A3B; hf]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=163840, head_dim=128,
    attn_type="gqa", rope_theta=5e4,
    n_experts=64, top_k=6, moe_d_ff=1408, n_shared_experts=2,
    gated=True, act="silu",
))
