"""internvl2-76b [vlm]: InternViT frontend (stub) + InternLM2 backbone.
[arXiv:2404.16821; unverified]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, head_dim=128,
    attn_type="gqa", rope_theta=1e6, gated=True, act="silu",
    frontend="vision", frontend_len=256,
    # §Perf D1: at d_model=8192 the boundary<->attention reshard costs
    # 5x more collective than attention replication saves — measured
    # 92s (off) vs 494s (on) on train_4k/16x16
    attn_shard_constraint=False,
))
