"""Model-architecture configs (one module per assigned family) and the
registry in ``repro.configs.base``."""
