"""whisper-tiny [audio]: enc-dec transformer; conv audio frontend is a
STUB (input_specs provides precomputed frame embeddings).
[arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865, head_dim=64,
    attn_type="gqa", rope_theta=1e4, gated=False, act="gelu",
    enc_dec=True, n_enc_layers=4,
    frontend="audio", frontend_len=1500,
    tie_embeddings=True,
))
