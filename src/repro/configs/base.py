"""Architecture configuration system.

One frozen dataclass describes every assigned architecture; the model
factory (models/transformer.py) builds the right block stack from it.
``reduced()`` produces the CPU smoke-test variant of the same family.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None  # default d_model // n_heads
    # --- attention variants ---
    attn_type: str = "gqa"  # gqa | mla | none
    qk_norm: bool = False
    rope_theta: float = 1e4
    sliding_window: int = 0  # >0: local attention window
    local_global_ratio: int = 0  # N local layers per 1 global (gemma3: 5)
    # --- MLA (minicpm3) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- FFN ---
    gated: bool = True  # SwiGLU vs plain MLP
    act: str = "silu"
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden (d_ff used for dense layers)
    n_shared_experts: int = 0
    # --- SSM ---
    ssm: Optional[str] = None  # mamba1 | mamba2
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    ssm_chunk: int = 128  # chunked-scan length (DESIGN.md §3.3)
    # --- hybrid (zamba2): one shared attention block every N ssm layers ---
    shared_attn_every: int = 0
    # --- encoder/decoder (whisper) ---
    enc_dec: bool = False
    n_enc_layers: int = 0
    # --- modality frontend stub ---
    frontend: Optional[str] = None  # vision | audio
    frontend_len: int = 0  # prompt positions fed by the frontend stub
    # --- misc ---
    # apply the model-internal attention sharding constraint (§Perf B1).
    # Empirically tuned OFF where the per-layer boundary<->attention
    # reshard costs more than the replication it removes (MoE archs,
    # internvl2's d=8192): see EXPERIMENTS.md §Perf C2/D1.
    attn_shard_constraint: bool = True
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    max_seq: int = 131072

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.attn_type == "none" and self.shared_attn_every == 0

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic long context: SSM/hybrid or mostly-sliding-window
        attention. Pure full-attention archs skip long_500k (DESIGN.md
        §Arch-applicability)."""
        return (
            self.ssm is not None
            or (self.sliding_window > 0 and self.local_global_ratio > 0)
        )

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, L = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.attn_type == "gqa":
            per_layer += d * hd * self.n_heads  # q
            per_layer += 2 * d * hd * self.n_kv_heads  # k, v
            per_layer += hd * self.n_heads * d  # o
        elif self.attn_type == "mla":
            per_layer += d * self.q_lora_rank + self.q_lora_rank * self.n_heads * (
                self.qk_nope_dim + self.qk_rope_dim
            )
            per_layer += d * (self.kv_lora_rank + self.qk_rope_dim)
            per_layer += self.kv_lora_rank * self.n_heads * (
                self.qk_nope_dim + self.v_head_dim
            )
            per_layer += self.n_heads * self.v_head_dim * d
        if self.ssm is not None:
            di = self.expand * d
            per_layer += 2 * d * di  # in_proj (x, z)
            per_layer += di * self.d_conv
            per_layer += di * (2 * self.ssm_state + 1) if self.ssm == "mamba1" else 0
            per_layer += di * d  # out_proj
        if self.is_moe:
            ff = self.moe_d_ff or self.d_ff
            n_mats = 3 if self.gated else 2
            per_layer += self.n_experts * n_mats * d * ff
            per_layer += d * self.n_experts  # router
            if self.n_shared_experts:
                per_layer += self.n_shared_experts * n_mats * d * ff
        elif self.d_ff:
            n_mats = 3 if self.gated else 2
            per_layer += n_mats * d * self.d_ff
        total = emb + L * per_layer
        if self.enc_dec:
            total += self.n_enc_layers * per_layer  # rough: same block cost
        if self.shared_attn_every:
            total += d * hd * self.n_heads * 2 + 2 * d * self.d_ff  # one shared block
        return total

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.n_params()
        full = self.n_params()
        ff = self.moe_d_ff or self.d_ff
        n_mats = 3 if self.gated else 2
        expert_params = self.n_layers * self.n_experts * n_mats * self.d_model * ff
        active_experts = self.n_layers * (
            (self.top_k + self.n_shared_experts) * n_mats * self.d_model * ff
        )
        return full - expert_params + active_experts

    def reduced(self) -> "ArchConfig":
        """CPU smoke-test variant: same family/block structure, tiny dims."""
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 2),
            n_enc_layers=min(self.n_enc_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            moe_d_ff=64 if self.is_moe else 0,
            vocab=256,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            n_shared_experts=min(self.n_shared_experts, 1),
            q_lora_rank=32 if self.q_lora_rank else 0,
            kv_lora_rank=16 if self.kv_lora_rank else 0,
            qk_nope_dim=8 if self.qk_nope_dim else 0,
            qk_rope_dim=8 if self.qk_rope_dim else 0,
            v_head_dim=16 if self.v_head_dim else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            expand=2,
            ssm_chunk=16,
            sliding_window=32 if self.sliding_window else 0,
            shared_attn_every=2 if self.shared_attn_every else 0,
            frontend_len=8 if self.frontend else 0,
            max_seq=512,
        )


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def all_names() -> list[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    # import side effect registers each architecture
    from repro.configs import (  # noqa: F401
        falcon_mamba_7b,
        gemma3_4b,
        internvl2_76b,
        minicpm3_4b,
        moonshot_v1_16b,
        phi35_moe,
        qwen3_14b,
        starcoder2_7b,
        whisper_tiny,
        zamba2_7b,
    )
