"""gemma3-4b [dense]: 5:1 local(sliding-window):global attention, 128k
context, qk-norm, huge vocab. [hf:google/gemma-3-*-pt; unverified]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
    d_ff=10240, vocab=262144, head_dim=256,
    attn_type="gqa", qk_norm=True, rope_theta=1e6,
    sliding_window=1024, local_global_ratio=5,
    gated=True, act="gelu", tie_embeddings=True,
))
