"""qwen3-14b [dense]: GQA with qk_norm. [hf:Qwen/Qwen3-14B; hf]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=17408, vocab=151936, head_dim=128,
    attn_type="gqa", qk_norm=True, rope_theta=1e6,
    gated=True, act="silu",
))
