"""Sharded, atomic, topology-independent checkpointing.

Layout:  <dir>/step_<N>/manifest.json + one .npy per pytree leaf
(path-encoded file names). Writes go to ``step_<N>.tmp`` and are
committed with an atomic rename — a crash mid-save never corrupts the
latest checkpoint (fault-tolerance requirement #1).

Topology independence: leaves are saved as *full* (unsharded) host
arrays keyed by tree path, so a restore may target any mesh/device
count — the train driver re-device_puts with its own NamedShardings
(elastic scaling requirement). For 1000+-node deployments the same
manifest format extends to per-shard files (`shard_spec` field is
already recorded); this implementation gathers because the CPU test
environment is single-host.

``AsyncCheckpointer`` snapshots to host memory synchronously (cheap)
and serializes on a background thread, overlapping I/O with the next
training steps.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def _tree_structure(tree):
    return jax.tree_util.tree_structure(tree)


def save(tree, directory: str, step: int) -> str:
    """Atomic synchronous save. Returns the committed path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    manifest = {"step": step, "leaves": {}}
    for key, arr in flat.items():
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "shard_spec": None,  # per-shard layout hook for multi-host
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(like_tree, directory: str, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``like_tree``. ``shardings`` (same
    pytree shape, of jax.sharding.Sharding) re-shards onto the *current*
    mesh — elastic across device counts."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    flat_like = _flatten(like_tree)
    loaded = {}
    for key in flat_like:
        meta = manifest["leaves"][key]
        loaded[key] = np.load(os.path.join(path, meta["file"]))

    leaves_like, treedef = jax.tree_util.tree_flatten(like_tree)
    keys = list(_flatten(like_tree).keys())
    new_leaves = [loaded[k] for k in keys]
    tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings
        )
    return tree, step


class AsyncCheckpointer:
    """Snapshot synchronously, serialize in the background."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, tree, step: int):
        self.wait()  # one outstanding save at a time
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot

        def work():
            try:
                save(host_tree, self.directory, step)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(
            d for d in os.listdir(self.directory) if d.startswith("step_")
            and not d.endswith(".tmp")
        )
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, d))
