"""Deterministic synthetic data pipeline with document packing.

Design goals for 1000+-node operation:

  * **Stateless determinism**: batch ``step`` is a pure function of
    (seed, step, shard) via counted PRNG keys — resuming from a
    checkpoint needs only the step counter, and elastic re-sharding
    (different host count after a failure) re-partitions the *same*
    global stream (fault tolerance without data-state checkpoints).
  * **Monotonic packing**: documents are packed into fixed (B, S)
    windows; the pack offsets are a monotonically non-decreasing stream
    — the same property the paper's DU exploits — so the pack step is a
    frontier merge (searchsorted), not a scan over documents.
  * **Host sharding**: each host materializes only its
    ``process_index`` slice of the global batch.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512
    bos: int = 1
    eos: int = 2


def _rng(cfg: DataConfig, step: int, shard: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, shard])
    )


def global_batch_at(cfg: DataConfig, step: int) -> dict[str, np.ndarray]:
    """The full (global_batch, seq_len) batch for one step."""
    return shard_batch_at(cfg, step, shard=0, n_shards=1)


def shard_batch_at(
    cfg: DataConfig, step: int, shard: int, n_shards: int
) -> dict[str, np.ndarray]:
    """This host's slice of the step's batch. Re-sharding with a
    different n_shards yields the identical global stream (elasticity)."""
    assert cfg.global_batch % n_shards == 0
    local = cfg.global_batch // n_shards
    rows = []
    for r in range(local):
        global_row = shard * local + r
        rng = _rng(cfg, step, global_row)
        rows.append(_pack_row(cfg, rng))
    tokens = np.stack(rows)
    # next-token prediction targets
    targets = np.concatenate(
        [tokens[:, 1:], np.full((local, 1), cfg.eos, tokens.dtype)], axis=1
    )
    return {"tokens": tokens, "targets": targets}


def _pack_row(cfg: DataConfig, rng: np.random.Generator) -> np.ndarray:
    """Pack documents into one sequence window.

    Document lengths are drawn first; their cumulative offsets form the
    monotonic pack stream; boundary positions come from one searchsorted
    (frontier merge) instead of per-document append loops.
    """
    # draw docs until they cover the window (geometric lengths can
    # undershoot any fixed count)
    lens_list: list[int] = []
    total = 0
    while total < cfg.seq_len + 1:
        drawn = int(rng.geometric(1.0 / cfg.mean_doc_len))
        drawn = max(drawn, 4)
        lens_list.append(drawn)
        total += drawn + 1  # +1 for eos
    lens = np.array(lens_list)
    offsets = np.concatenate([[0], np.cumsum(lens + 1)])
    # zipfian token stream (skewed like natural text)
    body = rng.zipf(1.3, size=int(offsets[-1])).clip(3, cfg.vocab - 1)
    # frontier merge: which document owns each window position
    pos = np.arange(cfg.seq_len)
    doc_of = np.searchsorted(offsets, pos, side="right") - 1
    boundary = pos == offsets[doc_of]  # document starts -> BOS
    row = body[:cfg.seq_len].astype(np.int32)
    row[boundary[: len(row)]] = cfg.bos
    eos_pos = offsets[1:][offsets[1:] < cfg.seq_len] - 1
    row[eos_pos.astype(int)] = cfg.eos
    return row


class ShardedLoader:
    """Iterator facade used by the train driver."""

    def __init__(self, cfg: DataConfig, shard: int = 0, n_shards: int = 1,
                 start_step: int = 0):
        self.cfg = cfg
        self.shard = shard
        self.n_shards = n_shards
        self.step = start_step

    def __next__(self):
        b = shard_batch_at(self.cfg, self.step, self.shard, self.n_shards)
        self.step += 1
        return b

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict):
        self.step = int(state["step"])
