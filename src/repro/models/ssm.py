"""Mamba-1 and Mamba-2 blocks with chunked selective scan.

The chunked scan is the SSM instance of the paper's pattern
(DESIGN.md §3.3): chunk-final states are *stored* by chunk c and
*loaded* by chunk c+1 — a RAW chain over a trivially monotonic chunk
index, executed as an outer ``lax.scan`` (sequential frontier) with a
fully parallel intra-chunk computation.

Memory discipline (§Perf iteration zamba2/falcon-mamba): all
(chunk, d_inner, d_state)-sized tensors are materialized *inside* the
chunk scan body — never for the full sequence. Mamba-2 uses the SSD
quadratic-in-chunk form (per-head (C, C) decay matrices) so the
(hd, d_state) outer product only appears in the O(1)-per-chunk state
update, not per position.

Decode is the O(1) recurrent step on the carried (conv window, h state).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import Dtypes, _init, rms_norm

MAMBA2_HEAD = 64


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------


def mamba_init(key, cfg: ArchConfig, dt: Dtypes):
    d = cfg.d_model
    di = cfg.expand * d
    n = cfg.ssm_state
    ks = jax.random.split(key, 8)
    p = {
        "w_in": _init(ks[0], (d, 2 * di), d ** -0.5, dt.param),  # x and z
        "conv_w": _init(ks[1], (cfg.d_conv, di), 0.5, dt.param),
        "conv_b": jnp.zeros((di,), dt.param),
        "w_out": _init(ks[2], (di, d), di ** -0.5, dt.param),
    }
    if cfg.ssm == "mamba1":
        p.update({
            # S4D-real init: A negative diagonals
            "a_log": jnp.log(
                jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))
            ).astype(jnp.float32),
            "w_bc": _init(ks[3], (di, 2 * n), di ** -0.5, dt.param),
            "w_dt": _init(ks[4], (di, 1), di ** -0.5, dt.param),
            "dt_bias": jnp.zeros((di,), jnp.float32),
            "d_skip": jnp.ones((di,), jnp.float32),
        })
    else:  # mamba2 (SSD): scalar decay per head
        nh = di // MAMBA2_HEAD
        p.update({
            "a_log": jnp.zeros((nh,), jnp.float32),
            "w_bc": _init(ks[3], (d, 2 * n), d ** -0.5, dt.param),
            "w_dt": _init(ks[4], (d, nh), d ** -0.5, dt.param),
            "dt_bias": jnp.zeros((nh,), jnp.float32),
            "d_skip": jnp.ones((nh,), jnp.float32),
            "norm_scale": jnp.zeros((di,), dt.param),
        })
    return p


# ---------------------------------------------------------------------------
# causal depthwise conv
# ---------------------------------------------------------------------------


def _causal_conv(x, w, b, state=None):
    """x: (B, S, di); w: (K, di). state: (B, K-1, di) carried for decode."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(k)
    )
    new_state = xp[:, -(k - 1):, :]
    return out + b.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# mamba1: chunked scan with chunk-internal (C, di, n) working set
# ---------------------------------------------------------------------------


def _mamba1_chunked(p, xi, cfg: ArchConfig, h0, chunk: int):
    """xi: (B, S, di) post-conv/silu. Returns (y (B,S,di) f32, h_final).

    Structure: outer scan over chunks (the §3.3 RAW frontier chain) with
    a remat'd inner position scan — the exact shape of a fused TPU mamba
    kernel (sequential in time, vectorized over (di, n)); working set is
    one (B, C, di) projection block plus a (B, di, n) state, and the
    backward pass recomputes inside each chunk instead of saving
    (B, S, di, n) residuals. Numerically exact (no cum-product
    divisions), NaN-free by construction.
    """
    b, s, di = xi.shape
    n = cfg.ssm_state
    c = min(chunk, s)
    nc = s // c
    xi_c = jnp.moveaxis(xi.reshape(b, nc, c, di), 1, 0)  # (nc, B, C, di)
    a_neg = -jnp.exp(p["a_log"])  # (di, n)

    def chunk_step(h, xi_i):
        bc = xi_i @ p["w_bc"].astype(xi_i.dtype)
        bmat = bc[..., :n].astype(jnp.float32)  # (B, C, n)
        cmat = bc[..., n:].astype(jnp.float32)
        dt_ = jax.nn.softplus(
            (xi_i @ p["w_dt"].astype(xi_i.dtype)).astype(jnp.float32)
            + p["dt_bias"][None, None, :]
        )  # (B, C, di)
        xf = xi_i.astype(jnp.float32)

        def pos_step(hc, t):
            a_t = jnp.exp(a_neg[None] * dt_[:, t, :, None])  # (B, di, n)
            bx_t = (
                dt_[:, t, :, None] * bmat[:, t, None, :]
            ) * xf[:, t, :, None]
            h_new = a_t * hc + bx_t
            y_t = jnp.einsum("bdn,bn->bd", h_new, cmat[:, t])
            return h_new, y_t

        h_fin, y_i = jax.lax.scan(pos_step, h, jnp.arange(c))
        return h_fin, jnp.moveaxis(y_i, 0, 1)  # (B, C, di)

    chunk_step = jax.checkpoint(
        chunk_step, policy=jax.checkpoint_policies.nothing_saveable
    )
    h_final, y_chunks = jax.lax.scan(chunk_step, h0, xi_c)
    y = jnp.moveaxis(y_chunks, 0, 1).reshape(b, s, di)
    return y, h_final


def _mamba1_step(p, xi_t, h):
    """One recurrent step: xi_t (B, di), h (B, di, n)."""
    n = h.shape[-1]
    bc = xi_t @ p["w_bc"].astype(xi_t.dtype)
    bmat, cmat = bc[..., :n].astype(jnp.float32), bc[..., n:].astype(jnp.float32)
    dt_ = jax.nn.softplus(
        (xi_t @ p["w_dt"].astype(xi_t.dtype)).astype(jnp.float32)
        + p["dt_bias"][None, :]
    )  # (B, di)
    a = jnp.exp(-jnp.exp(p["a_log"])[None] * dt_[..., None])  # (B, di, n)
    bx = (dt_[..., None] * bmat[:, None, :]) * xi_t.astype(jnp.float32)[..., None]
    h_new = a * h + bx
    y = jnp.einsum("bdn,bn->bd", h_new, cmat)
    return y, h_new


# ---------------------------------------------------------------------------
# mamba2 (SSD): quadratic-in-chunk with per-head (C, C) decay matrices
# ---------------------------------------------------------------------------


def _mamba2_chunked(p, x_resid, xi, cfg: ArchConfig, h0, chunk: int):
    """x_resid: (B, S, d) block input (B/C/dt projections read it);
    xi: (B, S, di) post-conv/silu. Returns (y (B,S,di) f32, h_final)."""
    b, s, di = xi.shape
    n = cfg.ssm_state
    nh = di // MAMBA2_HEAD
    hd = MAMBA2_HEAD
    c = min(chunk, s)
    nc = s // c

    xh_c = jnp.moveaxis(xi.reshape(b, nc, c, nh, hd), 1, 0)
    xr_c = jnp.moveaxis(x_resid.reshape(b, nc, c, x_resid.shape[-1]), 1, 0)
    a_neg = -jnp.exp(p["a_log"])  # (nh,)

    def step(h, inputs):
        xr_i, xh_i = inputs  # (B, C, d), (B, C, nh, hd)
        bc = xr_i @ p["w_bc"].astype(xr_i.dtype)
        bmat = bc[..., :n].astype(jnp.float32)  # (B, C, n)
        cmat = bc[..., n:].astype(jnp.float32)
        dt_ = jax.nn.softplus(
            (xr_i @ p["w_dt"].astype(xr_i.dtype)).astype(jnp.float32)
            + p["dt_bias"][None, None, :]
        )  # (B, C, nh)
        loga = a_neg[None, None] * dt_  # (B, C, nh) <= 0
        logcum = jnp.cumsum(loga, axis=1)  # (B, C, nh)
        xf = xh_i.astype(jnp.float32)

        # intra-chunk: Y[t] = sum_{j<=t} exp(lc_t - lc_j) (C_t.B_j) dt_j x_j
        ldiff = jnp.maximum(
            logcum[:, :, None, :] - logcum[:, None, :, :], -30.0
        )  # (B, C, C, nh): t rows, j cols
        tri = jnp.tril(jnp.ones((c, c), bool))
        w = jnp.where(tri[None, :, :, None], jnp.exp(ldiff), 0.0)
        scores = jnp.einsum("btn,bjn->btj", cmat, bmat)  # (B, C, C)
        wmat = w * scores[..., None] * dt_[:, None, :, :]  # (B,C,C,nh)
        y_intra = jnp.einsum("btjh,bjhp->bthp", wmat, xf)

        # inter-chunk: carry-in state contribution
        decay_t = jnp.exp(jnp.maximum(logcum, -30.0))  # (B, C, nh)
        y_inter = jnp.einsum(
            "btn,bhpn,bth->bthp", cmat, h, decay_t
        )

        # state update: h' = decay_C * h + sum_j exp(lc_C - lc_j) dt_j x_j B_j
        decay_last = jnp.exp(
            jnp.maximum(logcum[:, -1:, :] - logcum, -30.0)
        ) * dt_  # (B, C, nh) weights
        h_new = (
            jnp.exp(jnp.maximum(logcum[:, -1], -30.0))[:, :, None, None] * h
            + jnp.einsum("bjh,bjhp,bjn->bhpn", decay_last, xf, bmat)
        )
        y = (y_intra + y_inter).reshape(b, c, di)
        return h_new, y

    step = jax.checkpoint(
        step, policy=jax.checkpoint_policies.nothing_saveable
    )
    h_final, y_chunks = jax.lax.scan(step, h0, (xr_c, xh_c))
    y = jnp.moveaxis(y_chunks, 0, 1).reshape(b, s, di)
    return y, h_final


def _mamba2_step(p, xr_t, xh_t, h, n):
    """xr_t: (B, d); xh_t: (B, nh, hd); h: (B, nh, hd, n)."""
    bc = xr_t @ p["w_bc"].astype(xr_t.dtype)
    bmat, cmat = bc[..., :n].astype(jnp.float32), bc[..., n:].astype(jnp.float32)
    dt_ = jax.nn.softplus(
        (xr_t @ p["w_dt"].astype(xr_t.dtype)).astype(jnp.float32)
        + p["dt_bias"][None, :]
    )  # (B, nh)
    a = jnp.exp(-jnp.exp(p["a_log"])[None] * dt_)  # (B, nh)
    bx = jnp.einsum(
        "bh,bhp,bn->bhpn", dt_, xh_t.astype(jnp.float32), bmat
    )
    h_new = a[..., None, None] * h + bx
    y = jnp.einsum("bhpn,bn->bhp", h_new, cmat)
    return y, h_new


# ---------------------------------------------------------------------------
# public block API
# ---------------------------------------------------------------------------


def mamba_apply(p, x, cfg: ArchConfig, *, state=None):
    """x: (B, S, d). state: None for training, else dict with
    ``conv`` (B, K-1, di) and ``h``. Returns (y, new_state)."""
    b, s, d = x.shape
    di = cfg.expand * d
    n = cfg.ssm_state

    xz = x @ p["w_in"].astype(x.dtype)
    xi, z = xz[..., :di], xz[..., di:]
    conv_state = None if state is None else state["conv"]
    xi, new_conv = _causal_conv(xi, p["conv_w"], p["conv_b"], conv_state)
    xi = jax.nn.silu(xi)

    if cfg.ssm == "mamba1":
        h0 = (
            jnp.zeros((b, di, n), jnp.float32) if state is None else state["h"]
        )
        if s == 1:
            y, new_h = _mamba1_step(p, xi[:, 0], h0)
            y = y[:, None, :]
        else:
            y, new_h = _mamba1_chunked(p, xi, cfg, h0, cfg.ssm_chunk)
        y = y + xi.astype(jnp.float32) * p["d_skip"][None, None, :]
    else:
        nh = di // MAMBA2_HEAD
        h0 = (
            jnp.zeros((b, nh, MAMBA2_HEAD, n), jnp.float32)
            if state is None
            else state["h"]
        )
        if s == 1:
            y, new_h = _mamba2_step(
                p, x[:, 0], xi[:, 0].reshape(b, nh, MAMBA2_HEAD), h0, n
            )
            y = y.reshape(b, 1, di)
        else:
            y, new_h = _mamba2_chunked(p, x, xi, cfg, h0, cfg.ssm_chunk)
        y = y + jnp.repeat(
            p["d_skip"][None, None, :], MAMBA2_HEAD, axis=-1
        ) * xi.astype(jnp.float32)
        y = rms_norm(y.astype(x.dtype), p["norm_scale"], cfg.norm_eps).astype(
            jnp.float32
        )

    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["w_out"].astype(x.dtype)
    new_state = {"conv": new_conv, "h": new_h}
    return y, new_state


def mamba_init_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    di = cfg.expand * d
    n = cfg.ssm_state
    conv = jnp.zeros((batch, cfg.d_conv - 1, di), dtype)
    if cfg.ssm == "mamba1":
        h = jnp.zeros((batch, di, n), jnp.float32)
    else:
        h = jnp.zeros((batch, di // MAMBA2_HEAD, MAMBA2_HEAD, n), jnp.float32)
    return {"conv": conv, "h": h}
