"""Model assembly for all assigned architectures.

One functional model API driven entirely by ArchConfig:

  init_params(key, cfg, dt)                  -> pytree (layer-stacked)
  loss_fn(params, batch, cfg, dt)            -> scalar LM loss (chunked CE)
  prefill(params, tokens, cfg, dt, ...)      -> (last-token logits, cache)
  decode_step(params, tokens, cache, lengths, cfg, dt) -> (logits, cache)
  init_cache(cfg, batch, max_seq, dt)        -> cache pytree

Layer weights are stacked over the layer axis and executed with
``lax.scan`` (+ remat), keeping HLO size and compile time independent of
depth — required for the 80-layer dry-runs. Heterogeneous stacks
(gemma3 local/global, zamba2 mamba/shared-attn) run as segment loops
over uniform sub-stacks.

Modality frontends are STUBS per the assignment: ``batch['frontend']``
carries precomputed patch/frame embeddings which replace (vlm) or feed
the encoder (audio).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import shardctx
from repro.models import ssm as S
from repro.models.flash import flash_mha

Dtypes = L.Dtypes

# Optional NamedSharding applied to layer-boundary activations (the scan
# carry). Set by the launchers (launch/dryrun.py, launch/train.py):
# batch-over-data + sequence-over-model (Megatron sequence parallelism)
# keeps the per-layer saved residuals 16x smaller on the production mesh.
ACTIVATION_SHARDING = None


def set_activation_sharding(sharding):
    global ACTIVATION_SHARDING
    ACTIVATION_SHARDING = sharding


def _constrain(x):
    if ACTIVATION_SHARDING is not None:
        return jax.lax.with_sharding_constraint(x, ACTIVATION_SHARDING)
    return x


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _layer_init(key, cfg: ArchConfig, dt: Dtypes, kind: str):
    ks = jax.random.split(key, 4)
    p = {"attn_norm": jnp.zeros((cfg.d_model,), dt.param)}
    if kind in ("attn", "cross"):
        if cfg.attn_type == "mla":
            p["attn"] = L.mla_init(ks[0], cfg, dt)
        else:
            p["attn"] = L.gqa_init(ks[0], cfg, dt)
        if kind == "cross":
            p["cross_norm"] = jnp.zeros((cfg.d_model,), dt.param)
            p["cross"] = L.gqa_init(ks[2], cfg, dt)
        p["mlp_norm"] = jnp.zeros((cfg.d_model,), dt.param)
        if cfg.is_moe:
            p["moe"] = L.moe_init(ks[1], cfg, dt)
        else:
            p["mlp"] = L.mlp_init(ks[1], cfg, dt)
    elif kind == "ssm":
        p["ssm"] = S.mamba_init(ks[0], cfg, dt)
    return p


def init_params(key, cfg: ArchConfig, dt: Dtypes = L.FP32):
    ks = jax.random.split(key, 8)
    params = {
        "embed": L._init(ks[0], (cfg.vocab, cfg.d_model), 0.02, dt.param),
        "final_norm": jnp.zeros((cfg.d_model,), dt.param),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L._init(
            ks[1], (cfg.d_model, cfg.vocab), cfg.d_model ** -0.5, dt.param
        )

    kind = "ssm" if cfg.ssm is not None and cfg.shared_attn_every == 0 else (
        "ssm" if cfg.ssm is not None else "attn"
    )
    if cfg.enc_dec:
        enc_keys = jax.random.split(ks[2], cfg.n_enc_layers)
        params["enc_layers"] = jax.vmap(
            lambda k: _layer_init(k, cfg, dt, "attn")
        )(enc_keys)
        dec_keys = jax.random.split(ks[3], cfg.n_layers)
        params["layers"] = jax.vmap(
            lambda k: _layer_init(k, cfg, dt, "cross")
        )(dec_keys)
        params["enc_norm"] = jnp.zeros((cfg.d_model,), dt.param)
    else:
        layer_keys = jax.random.split(ks[2], cfg.n_layers)
        params["layers"] = jax.vmap(
            lambda k: _layer_init(k, cfg, dt, kind)
        )(layer_keys)
    if cfg.shared_attn_every:
        # zamba2: ONE shared attention+mlp block reused across segments
        params["shared_attn"] = _layer_init(ks[4], cfg, dt, "attn")
    return params


# ---------------------------------------------------------------------------
# layer bodies
# ---------------------------------------------------------------------------


def _attn_mlp_block(p, x, cfg: ArchConfig, *, positions, window, enc_out=None,
                    inference=False):
    """Pre-norm attention (+ optional cross) + MLP/MoE. ``window`` is a
    traced scalar (0 = full attention) so gemma3's local/global pattern
    stays inside one scanned stack. ``inference=True`` enables the
    causal block-skip in flash attention (not reverse-differentiable)."""
    h = L.rms_norm(x, p["attn_norm"], cfg.norm_eps)
    if cfg.attn_type == "mla":
        a = L.mla_apply(p["attn"], h, cfg, positions=positions, eps=cfg.norm_eps)
    else:
        a = _gqa_train(p["attn"], h, cfg, positions, window, inference)
    x = x + a
    if enc_out is not None:
        h = L.rms_norm(x, p["cross_norm"], cfg.norm_eps)
        c = L.gqa_apply(
            p["cross"], h, cfg, positions=positions, kv_source=enc_out,
            use_rope=False, eps=cfg.norm_eps,
        )
        x = x + c
    h = L.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    if cfg.is_moe:
        f = L.moe_apply(p["moe"], h, cfg)
    else:
        f = L.mlp_apply(p["mlp"], h, cfg)
    return x + f


def _gqa_train(p, h, cfg: ArchConfig, positions, window, inference=False):
    """Full-sequence GQA through blocked flash attention."""
    b, s, d = h.shape
    hd = cfg.resolved_head_dim
    q = (h @ p["wq"].astype(h.dtype)).reshape(b, s, cfg.n_heads, hd)
    k = (h @ p["wk"].astype(h.dtype)).reshape(b, s, cfg.n_kv_heads, hd)
    v = (h @ p["wv"].astype(h.dtype)).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = L.rope(q, positions[:, :, None], cfg.rope_theta)
    k = L.rope(k, positions[:, :, None], cfg.rope_theta)
    # attention sharding (§Perf iteration): heads over the model axis (or
    # model folded into batch) keeps the flash loops collective-free —
    # XLA's default choice replicates attention across the model axis.
    # REFUTED for MoE archs (phi3.5: 80s -> 238s collective) and for
    # internvl2 (d=8192): their per-layer boundary<->attention reshard
    # costs more than the replication it removes — cfg carries the
    # empirically-tuned opt-out.
    use_c = cfg.attn_shard_constraint and not cfg.is_moe
    spec = shardctx.attn_spec(cfg.n_heads, b) if use_c else None
    if spec is not None:
        q = shardctx.constrain(q, *spec)
        kspec = shardctx.attn_spec(cfg.n_kv_heads, b)
        if kspec is not None:
            k = shardctx.constrain(k, *kspec)
            v = shardctx.constrain(v, *kspec)
    out = flash_mha(
        q, k, v, causal=True, window=window, skip_masked_blocks=inference
    )
    if spec is not None:
        out = shardctx.constrain(out, *spec)
    return out.reshape(b, s, cfg.n_heads * hd) @ p["wo"].astype(h.dtype)


def _mla_train(p, h, cfg, positions):
    return L.mla_apply(p["attn"], h, cfg, positions=positions, eps=cfg.norm_eps)


def _window_schedule(cfg: ArchConfig):
    """(L,) per-layer window (0 = global), as a host numpy array (cfg is
    static). gemma3: every (ratio+1)-th layer is global."""
    import numpy as np

    if cfg.sliding_window and cfg.local_global_ratio:
        idx = np.arange(cfg.n_layers)
        is_global = (idx + 1) % (cfg.local_global_ratio + 1) == 0
        return np.where(is_global, 0, cfg.sliding_window).astype(np.int32)
    return np.zeros((cfg.n_layers,), np.int32)


# ---------------------------------------------------------------------------
# forward (training / prefill trunk)
# ---------------------------------------------------------------------------


def _embed(params, tokens, cfg: ArchConfig, dt: Dtypes, frontend=None):
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt.compute)
    if cfg.frontend == "vision" and frontend is not None:
        # VLM stub: precomputed patch embeddings occupy the first
        # frontend_len positions of the sequence
        f = frontend.astype(dt.compute)
        n = f.shape[1]
        x = jnp.concatenate([f, x[:, n:, :]], axis=1)
    return x


def forward_hidden(params, tokens, cfg: ArchConfig, dt: Dtypes, *,
                   frontend=None, inference=False):
    """Token ids -> final-normed hidden states (B, S, d)."""
    b, s = tokens.shape
    x = _embed(params, tokens, cfg, dt, frontend)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    enc_out = None
    if cfg.enc_dec:
        enc_out = _encode(params, frontend, cfg, dt)

    if cfg.ssm is not None and cfg.shared_attn_every == 0:
        x = _scan_ssm(params["layers"], x, cfg)
    elif cfg.shared_attn_every:
        x = _hybrid_forward(params, x, cfg, positions, inference)
    else:
        x = _scan_attn(params["layers"], x, cfg, positions, enc_out, inference)
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps)


def _scan_attn(stacked, x, cfg: ArchConfig, positions, enc_out=None,
               inference=False):
    windows = _window_schedule(cfg)

    def body(carry, inp):
        lp, w = inp
        y = _attn_mlp_block(
            lp, carry, cfg, positions=positions, window=w, enc_out=enc_out,
            inference=inference,
        )
        return _constrain(y), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, (stacked, windows))
    return x


def _scan_ssm(stacked, x, cfg: ArchConfig):
    def body(carry, lp):
        h = L.rms_norm(carry, lp["attn_norm"], cfg.norm_eps)
        y, _ = S.mamba_apply(lp["ssm"], h, cfg)
        return _constrain(carry + y), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, stacked)
    return x


def _hybrid_forward(params, x, cfg: ArchConfig, positions, inference=False):
    """zamba2: segments of ``shared_attn_every`` mamba layers, each
    followed by the single shared attention block."""
    every = cfg.shared_attn_every
    n_seg = cfg.n_layers // every
    stacked = params["layers"]

    def seg_slice(tree, lo, hi):
        return jax.tree.map(lambda a: a[lo:hi], tree)

    # NOTE(§Perf A3, REFUTED): remat'ing the shared attention block was
    # predicted to drop ~13 x 2.3 GiB of saved internals; measured HBM
    # went UP 41.3 -> 49.7 GiB — the dominant saves are the per-segment
    # python-loop boundary tensors, and the extra recompute inputs cost
    # more than the internals saved. Kept un-remat'd.
    for seg in range(n_seg):
        x = _scan_ssm(seg_slice(stacked, seg * every, (seg + 1) * every), x, cfg)
        x = _attn_mlp_block(
            params["shared_attn"], x, cfg, positions=positions,
            window=jnp.int32(0), inference=inference,
        )
    rem = cfg.n_layers - n_seg * every
    if rem:
        x = _scan_ssm(seg_slice(stacked, n_seg * every, cfg.n_layers), x, cfg)
    return x


def _encode(params, frames, cfg: ArchConfig, dt: Dtypes):
    """whisper encoder over stub frame embeddings (B, F, d)."""
    x = frames.astype(dt.compute)
    b, f, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(f)[None, :], (b, f))

    def body(carry, lp):
        h = L.rms_norm(carry, lp["attn_norm"], cfg.norm_eps)
        a = L.gqa_apply(
            lp["attn"], h, cfg, positions=positions, causal=False,
            use_rope=False, eps=cfg.norm_eps,
        )
        y = carry + a
        h = L.rms_norm(y, lp["mlp_norm"], cfg.norm_eps)
        return y + L.mlp_apply(lp["mlp"], h, cfg), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# loss: chunked cross-entropy (logits never materialized at (B, S, V))
# ---------------------------------------------------------------------------


def chunked_ce(hidden, targets, w_out, *, chunk: int = 512):
    b, s, d = hidden.shape
    c = min(chunk, s)
    nc = s // c
    h = hidden.reshape(b, nc, c, d)
    t = targets.reshape(b, nc, c)

    def body(acc, i):
        logits = (
            h[:, i].astype(jnp.float32) @ w_out.astype(jnp.float32)
        )  # (B, c, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, t[:, i][..., None], axis=-1
        )[..., 0]
        return acc + jnp.sum(lse - gold), None

    # remat: recompute each chunk's logits in the backward pass instead of
    # saving (B, c, V) tiles per chunk
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    total, _ = jax.lax.scan(body, jnp.float32(0.0), jnp.arange(nc))
    return total / (b * s)


def loss_fn(params, batch, cfg: ArchConfig, dt: Dtypes = L.FP32):
    hidden = forward_hidden(
        params, batch["tokens"], cfg, dt, frontend=batch.get("frontend")
    )
    w_out = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    )
    return chunked_ce(hidden, batch["targets"], w_out)


# ---------------------------------------------------------------------------
# serving: cache init, prefill, decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dt: Dtypes = L.FP32):
    hd = cfg.resolved_head_dim
    cache = {}
    if cfg.ssm is not None:
        st = S.mamba_init_state(cfg, batch)
        cache["ssm"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), st
        )
    if cfg.shared_attn_every:
        n_app = cfg.n_layers // cfg.shared_attn_every
        cache["shared_kv"] = (
            jnp.zeros((n_app, batch, max_seq, cfg.n_kv_heads, hd), dt.compute),
            jnp.zeros((n_app, batch, max_seq, cfg.n_kv_heads, hd), dt.compute),
        )
    elif cfg.attn_type == "mla":
        cache["mla"] = (
            jnp.zeros((cfg.n_layers, batch, max_seq, cfg.kv_lora_rank), dt.compute),
            jnp.zeros((cfg.n_layers, batch, max_seq, cfg.qk_rope_dim), dt.compute),
        )
    elif cfg.attn_type == "gqa" and cfg.ssm is None:
        windows = _window_schedule(cfg)
        if cfg.sliding_window and cfg.local_global_ratio:
            n_local = int((windows > 0).sum())
            n_global = cfg.n_layers - n_local
            w = cfg.sliding_window
            cache["local_kv"] = (
                jnp.zeros((n_local, batch, min(w, max_seq), cfg.n_kv_heads, hd), dt.compute),
                jnp.zeros((n_local, batch, min(w, max_seq), cfg.n_kv_heads, hd), dt.compute),
            )
            cache["global_kv"] = (
                jnp.zeros((n_global, batch, max_seq, cfg.n_kv_heads, hd), dt.compute),
                jnp.zeros((n_global, batch, max_seq, cfg.n_kv_heads, hd), dt.compute),
            )
        else:
            cache["kv"] = (
                jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads, hd), dt.compute),
                jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads, hd), dt.compute),
            )
    if cfg.enc_dec:
        cache["cross_kv"] = (
            jnp.zeros(
                (cfg.n_layers, batch, cfg.frontend_len, cfg.n_kv_heads, hd),
                dt.compute,
            ),
            jnp.zeros(
                (cfg.n_layers, batch, cfg.frontend_len, cfg.n_kv_heads, hd),
                dt.compute,
            ),
        )
    return cache


def _decode_gqa(p, x, cfg, cache_kv, lengths, *, window, positions_t):
    """One-token GQA against a (possibly ring-buffer) KV cache.

    cache_kv: (k, v) with shape (B, C, nk, hd); C = full max_seq or the
    sliding window (ring). ``lengths`` (B,) is the number of committed
    positions (the monotonic RAW frontier of DESIGN.md §3.2)."""
    b, _, d = x.shape
    hd = cfg.resolved_head_dim
    nh, nk = cfg.n_heads, cfg.n_kv_heads
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, 1, nh, hd)
    k = (x @ p["wk"].astype(x.dtype)).reshape(b, 1, nk, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(b, 1, nk, hd)
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = L.rope(q, positions_t[:, :, None], cfg.rope_theta)
    k = L.rope(k, positions_t[:, :, None], cfg.rope_theta)

    ck, cv = cache_kv
    cap = ck.shape[1]
    slot = lengths % cap  # ring position (== lengths when cap == max_seq)
    ck = jax.vmap(
        lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0, 0))
    )(ck, k, slot)
    cv = jax.vmap(
        lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0, 0))
    )(cv, v, slot)

    idx = jnp.arange(cap)[None, :]  # (1, C)
    committed = idx <= slot[:, None] if False else None
    # entry validity: for a ring of capacity `cap`, entries written so far
    age_ok = idx < jnp.minimum(lengths + 1, cap)[:, None]
    mask = age_ok
    rep = nh // nk
    qr = q.reshape(b, 1, nk, rep, hd).astype(jnp.float32) * (hd ** -0.5)
    sc = jnp.einsum("bqhrd,bchd->bhrqc", qr, ck.astype(jnp.float32))
    sc = jnp.where(mask[:, None, None, None, :], sc, -1e30)
    w = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhrqc,bchd->bqhrd", w, cv.astype(jnp.float32))
    y = out.reshape(b, 1, nh * hd).astype(x.dtype) @ p["wo"].astype(x.dtype)
    return y, (ck, cv)


def decode_step(params, tokens, cache, lengths, cfg: ArchConfig,
                dt: Dtypes = L.FP32, *, enc_out=None):
    """One decoding step for the whole batch: tokens (B, 1), lengths (B,).
    Returns (logits (B, V), new cache)."""
    b = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt.compute)
    positions_t = lengths[:, None]

    new_cache = dict(cache)
    if cfg.ssm is not None and cfg.shared_attn_every == 0:
        def body(carry, inp):
            lp, st = inp
            h = L.rms_norm(carry, lp["attn_norm"], cfg.norm_eps)
            y, new_st = S.mamba_apply(lp["ssm"], h, cfg, state=st)
            return carry + y, new_st

        x, new_ssm = jax.lax.scan(body, x, (params["layers"], cache["ssm"]))
        new_cache["ssm"] = new_ssm
    elif cfg.shared_attn_every:
        x, new_cache = _hybrid_decode(params, x, cache, lengths, cfg, positions_t)
    elif cfg.attn_type == "mla":
        def body(carry, inp):
            lp, (c_lat, c_kr) = inp
            h = L.rms_norm(carry, lp["attn_norm"], cfg.norm_eps)
            a, nc = L.mla_apply(
                lp["attn"], h, cfg, positions=positions_t,
                kv_cache=(c_lat, c_kr), cache_len=lengths, eps=cfg.norm_eps,
            )
            y = carry + a
            h = L.rms_norm(y, lp["mlp_norm"], cfg.norm_eps)
            f = L.moe_apply(lp["moe"], h, cfg) if cfg.is_moe else L.mlp_apply(
                lp["mlp"], h, cfg
            )
            return y + f, nc

        x, new_mla = jax.lax.scan(body, x, (params["layers"], cache["mla"]))
        new_cache["mla"] = new_mla
    else:
        x, new_cache = _dense_decode(
            params, x, cache, lengths, cfg, positions_t, enc_out
        )

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    w_out = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x[:, 0].astype(jnp.float32) @ w_out.astype(jnp.float32)
    return logits, new_cache


def _dense_decode(params, x, cache, lengths, cfg, positions_t, enc_out):
    new_cache = dict(cache)
    windows = _window_schedule(cfg)
    if "kv" in cache:  # uniform stack
        def body(carry, inp):
            lp, (ck, cv), w = inp
            h = L.rms_norm(carry, lp["attn_norm"], cfg.norm_eps)
            a, nkv = _decode_gqa(
                lp["attn"], h, cfg, (ck, cv), lengths,
                window=w, positions_t=positions_t,
            )
            y = carry + a
            if cfg.enc_dec:
                h = L.rms_norm(y, lp["cross_norm"], cfg.norm_eps)
                c = L.gqa_apply(
                    lp["cross"], h, cfg, positions=positions_t,
                    kv_source=None, use_rope=False, eps=cfg.norm_eps,
                ) if enc_out is None else _cross_decode(lp, h, cfg, enc_out)
                y = y + c
            h = L.rms_norm(y, lp["mlp_norm"], cfg.norm_eps)
            f = L.moe_apply(lp["moe"], h, cfg) if cfg.is_moe else L.mlp_apply(
                lp["mlp"], h, cfg
            )
            return y + f, nkv

        x, new_kv = jax.lax.scan(
            body, x, (params["layers"], cache["kv"], windows)
        )
        new_cache["kv"] = new_kv
        return x, new_cache

    # gemma3: interleaved local(ring)/global(full) stacks
    lk, lv = cache["local_kv"]
    gk, gv = cache["global_kv"]
    # python loop over layers (34) — decode graphs are small
    li_np = list((windows == 0).tolist())
    l_ptr = g_ptr = 0
    stacked = params["layers"]
    for i, is_g in enumerate(li_np):
        lp = jax.tree.map(lambda a: a[i], stacked)
        h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        if is_g:
            a, (nk_, nv_) = _decode_gqa(
                lp["attn"], h, cfg, (gk[g_ptr], gv[g_ptr]), lengths,
                window=0, positions_t=positions_t,
            )
            gk = gk.at[g_ptr].set(nk_)
            gv = gv.at[g_ptr].set(nv_)
            g_ptr += 1
        else:
            a, (nk_, nv_) = _decode_gqa(
                lp["attn"], h, cfg, (lk[l_ptr], lv[l_ptr]), lengths,
                window=cfg.sliding_window, positions_t=positions_t,
            )
            lk = lk.at[l_ptr].set(nk_)
            lv = lv.at[l_ptr].set(nv_)
            l_ptr += 1
        x = x + a
        h = L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
        x = x + L.mlp_apply(lp["mlp"], h, cfg)
    new_cache["local_kv"] = (lk, lv)
    new_cache["global_kv"] = (gk, gv)
    return x, new_cache


def _cross_decode(lp, h, cfg, enc_out):
    return L.gqa_apply(
        lp["cross"], h, cfg,
        positions=jnp.zeros((h.shape[0], 1), jnp.int32),
        kv_source=enc_out, use_rope=False, eps=cfg.norm_eps,
    )


def _hybrid_decode(params, x, cache, lengths, cfg, positions_t):
    every = cfg.shared_attn_every
    n_seg = cfg.n_layers // every
    stacked = params["layers"]
    ssm_states = cache["ssm"]
    sk, sv = cache["shared_kv"]
    new_states = []

    def seg_slice(tree, lo, hi):
        return jax.tree.map(lambda a: a[lo:hi], tree)

    for seg in range(n_seg):
        sub = seg_slice(stacked, seg * every, (seg + 1) * every)
        sub_state = seg_slice(ssm_states, seg * every, (seg + 1) * every)

        def body(carry, inp):
            lp, st = inp
            h = L.rms_norm(carry, lp["attn_norm"], cfg.norm_eps)
            y, nst = S.mamba_apply(lp["ssm"], h, cfg, state=st)
            return carry + y, nst

        x, nst = jax.lax.scan(body, x, (sub, sub_state))
        new_states.append(nst)
        h = L.rms_norm(x, params["shared_attn"]["attn_norm"], cfg.norm_eps)
        a, (nk_, nv_) = _decode_gqa(
            params["shared_attn"]["attn"], h, cfg, (sk[seg], sv[seg]),
            lengths, window=0, positions_t=positions_t,
        )
        sk = sk.at[seg].set(nk_)
        sv = sv.at[seg].set(nv_)
        x = x + a
        h = L.rms_norm(x, params["shared_attn"]["mlp_norm"], cfg.norm_eps)
        x = x + L.mlp_apply(params["shared_attn"]["mlp"], h, cfg)
    rem = cfg.n_layers - n_seg * every
    if rem:
        sub = seg_slice(stacked, n_seg * every, cfg.n_layers)
        sub_state = seg_slice(ssm_states, n_seg * every, cfg.n_layers)

        def body(carry, inp):
            lp, st = inp
            h = L.rms_norm(carry, lp["attn_norm"], cfg.norm_eps)
            y, nst = S.mamba_apply(lp["ssm"], h, cfg, state=st)
            return carry + y, nst

        x, nst = jax.lax.scan(body, x, (sub, sub_state))
        new_states.append(nst)

    new_ssm = jax.tree.map(
        lambda *xs: jnp.concatenate(xs, axis=0), *new_states
    )
    new_cache = dict(cache)
    new_cache["ssm"] = new_ssm
    new_cache["shared_kv"] = (sk, sv)
    return x, new_cache


def prefill(params, tokens, cfg: ArchConfig, dt: Dtypes = L.FP32, *,
            frontend=None, max_seq: Optional[int] = None):
    """Full-sequence forward that also fills the decode cache. For the
    dry-run's prefill shapes we lower this function; the returned cache
    is what decode_step consumes."""
    b, s = tokens.shape
    hidden = forward_hidden(
        params, tokens, cfg, dt, frontend=frontend, inference=True
    )
    w_out = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = hidden[:, -1].astype(jnp.float32) @ w_out.astype(jnp.float32)
    # cache construction: replay through decode-shaped storage. For
    # dry-run purposes we account the cache tensors; a production
    # prefill writes K/V during the forward pass itself.
    cache = init_cache(cfg, b, max_seq or s, dt)
    return logits, cache
