"""Mesh context for model-internal sharding constraints.

The model code stays mesh-agnostic; launchers call ``set_mesh_ctx`` and
layers apply ``constrain`` hints. Dims that don't divide their mesh axes
are auto-dropped, so reduced smoke configs and the production configs
share one code path.
"""

from __future__ import annotations

import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

_MESH = None
_DP: tuple = ("data",)


def set_mesh_ctx(mesh, dp_axes=("data",)):
    global _MESH, _DP
    _MESH = mesh
    _DP = tuple(dp_axes)


def clear_mesh_ctx():
    set_mesh_ctx(None)


def dp_axes() -> tuple:
    return _DP


def axis_size(name) -> int:
    if _MESH is None:
        return 1
    sizes = dict(zip(_MESH.axis_names, _MESH.devices.shape))
    names = name if isinstance(name, tuple) else (name,)
    return int(np.prod([sizes.get(n, 1) for n in names]))


def constrain(x, *spec):
    """with_sharding_constraint with per-dim divisibility auto-drop."""
    import jax

    if _MESH is None:
        return x
    fixed = []
    for dim, ax in enumerate(spec):
        if ax is None:
            fixed.append(None)
            continue
        need = axis_size(ax)
        fixed.append(ax if need > 1 and x.shape[dim] % need == 0 else None)
    if all(a is None for a in fixed):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, P(*fixed))
    )


def attn_spec(n_heads: int, batch: int):
    """Best sharding for (B, S, H, D) attention activations: heads over
    model when divisible, else fold model into the batch dim, else give
    up (XLA decides)."""
    if _MESH is None:
        return None
    m = axis_size("model")
    dp = axis_size(_DP)
    if n_heads % m == 0:
        return (_DP, None, "model", None)
    if batch % (dp * m) == 0:
        return (tuple(_DP) + ("model",), None, None, None)
    # fallback: batch-only sharding. Attention compute replicates across
    # the model axis (a known 16x waste, visible in the compute term) but
    # the flash loops stay collective-free — measured far cheaper than
    # XLA's default of sharding the sequence and gathering every block.
    return (_DP, None, None, None)
