"""Model definitions (transformer/SSM families) and sharding context
used by the launch drivers."""
