"""Dense layer library: norms, RoPE, GQA/MLA attention, MLPs, MoE.

Pure-functional JAX: every layer is (init(key, cfg) -> params dict,
apply(params, x, ...) -> y). Parameters are plain pytrees so the
distributed layer can attach PartitionSpecs by path (distributed/
partition.py) and the checkpoint layer can serialize by name.

Attention supports three execution modes used by the launchers:
  * train/prefill: full-sequence causal (optionally sliding-window),
  * decode: one token against a KV cache (the monotonic append/attend
    RAW pair of DESIGN.md §3.2),
  * cross: encoder-decoder (whisper).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class Dtypes:
    param: jnp.dtype = jnp.bfloat16
    compute: jnp.dtype = jnp.bfloat16
    accum: jnp.dtype = jnp.float32


FP32 = Dtypes(jnp.float32, jnp.float32, jnp.float32)
BF16 = Dtypes()


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(
        x.dtype
    )


def rope(x, positions, theta, dims: Optional[int] = None):
    """Rotary embedding over the last ``dims`` features (default all)."""
    d = dims or x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:d]
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    if d < x.shape[-1]:
        rotated = jnp.concatenate([rotated, x[..., d:]], axis=-1)
    return rotated.astype(x.dtype)


def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def gqa_init(key, cfg: ArchConfig, dt: Dtypes):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nh, nk = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": _init(ks[0], (d, nh * hd), s, dt.param),
        "wk": _init(ks[1], (d, nk * hd), s, dt.param),
        "wv": _init(ks[2], (d, nk * hd), s, dt.param),
        "wo": _init(ks[3], (nh * hd, d), (nh * hd) ** -0.5, dt.param),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dt.param)
        p["k_norm"] = jnp.zeros((hd,), dt.param)
    return p


def _sdpa(q, k, v, mask):
    """(B, S, H, D) attention with f32 softmax accumulation."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


def gqa_apply(
    p,
    x,
    cfg: ArchConfig,
    *,
    positions,
    causal: bool = True,
    window: int = 0,
    kv_cache=None,  # (k, v) of shape (B, S_max, nk, hd); decode mode
    cache_len=None,  # (B,) committed KV frontier (decode)
    kv_source=None,  # cross attention: encoder output (B, S_enc, d)
    use_rope: bool = True,
    eps: float = 1e-6,
):
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    nh, nk = cfg.n_heads, cfg.n_kv_heads
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, nh, hd)
    src = kv_source if kv_source is not None else x
    k = (src @ p["wk"].astype(x.dtype)).reshape(b, src.shape[1], nk, hd)
    v = (src @ p["wv"].astype(x.dtype)).reshape(b, src.shape[1], nk, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], eps)
        k = rms_norm(k, p["k_norm"], eps)
    if use_rope and kv_source is None:
        q = rope(q, positions[:, :, None], cfg.rope_theta)
        kpos = positions if kv_cache is None else positions
        k = rope(k, kpos[:, :, None], cfg.rope_theta)

    new_cache = None
    if kv_cache is not None:
        ck, cv = kv_cache
        idx = cache_len  # (B,) write position of the new token
        ck = jax.vmap(lambda c, kk, i: jax.lax.dynamic_update_slice(
            c, kk, (i, 0, 0)))(ck, k, idx)
        cv = jax.vmap(lambda c, vv, i: jax.lax.dynamic_update_slice(
            c, vv, (i, 0, 0)))(cv, v, idx)
        k, v = ck, cv
        new_cache = (ck, cv)

    # expand kv heads to query heads
    if nk != nh:
        rep = nh // nk
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)

    s_kv = k.shape[1]
    q_pos = positions  # (B, S)
    if kv_cache is not None:
        k_pos = jnp.arange(s_kv)[None, :]
        valid = k_pos <= q_pos[:, :1]  # monotonic frontier (append<=attend)
        mask = valid[:, None, :, :] if False else valid[:, None, None, :]
        mask = jnp.broadcast_to(mask, (b, 1, s, s_kv))
        if window:
            mask = mask & (k_pos[:, None, None, :] > q_pos[:, None, :, None] - window)
    elif kv_source is not None:
        mask = jnp.ones((b, 1, s, s_kv), bool)
    else:
        k_pos = positions
        mask = q_pos[:, None, :, None] >= k_pos[:, None, None, :]
        if window:
            mask = mask & (k_pos[:, None, None, :] > q_pos[:, None, :, None] - window)
        if not causal:
            mask = jnp.ones((b, 1, s, s_kv), bool)
    out = _sdpa(q, k, v, mask)
    y = out.reshape(b, s, nh * hd) @ p["wo"].astype(x.dtype)
    return (y, new_cache) if kv_cache is not None else y


# ---------------------------------------------------------------------------
# MLA attention (minicpm3 / deepseek-style)
# ---------------------------------------------------------------------------


def mla_init(key, cfg: ArchConfig, dt: Dtypes):
    d = cfg.d_model
    r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    nh = cfg.n_heads
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "wq_a": _init(ks[0], (d, r_q), s, dt.param),
        "wq_b": _init(ks[1], (r_q, nh * (dn + dr)), r_q ** -0.5, dt.param),
        "wkv_a": _init(ks[2], (d, r_kv + dr), s, dt.param),
        "wkv_b": _init(ks[3], (r_kv, nh * (dn + dv)), r_kv ** -0.5, dt.param),
        "wo": _init(ks[4], (nh * dv, d), (nh * dv) ** -0.5, dt.param),
        "q_a_norm": jnp.zeros((r_q,), dt.param),
        "kv_a_norm": jnp.zeros((r_kv,), dt.param),
    }


def mla_apply(
    p,
    x,
    cfg: ArchConfig,
    *,
    positions,
    kv_cache=None,  # (latent (B,S_max,r_kv), k_rope (B,S_max,dr))
    cache_len=None,
    eps: float = 1e-6,
):
    b, s, d = x.shape
    nh = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r_kv = cfg.kv_lora_rank

    q_lat = rms_norm(x @ p["wq_a"].astype(x.dtype), p["q_a_norm"], eps)
    q = (q_lat @ p["wq_b"].astype(x.dtype)).reshape(b, s, nh, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions[:, :, None], cfg.rope_theta)

    kv_a = x @ p["wkv_a"].astype(x.dtype)
    latent = rms_norm(kv_a[..., :r_kv], p["kv_a_norm"], eps)
    k_rope = rope(
        kv_a[..., r_kv:][:, :, None, :], positions[:, :, None], cfg.rope_theta
    )[:, :, 0, :]

    new_cache = None
    if kv_cache is not None:
        c_lat, c_kr = kv_cache
        c_lat = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
            c, u, (i, 0)))(c_lat, latent, cache_len)
        c_kr = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
            c, u, (i, 0)))(c_kr, k_rope, cache_len)
        latent, k_rope = c_lat, c_kr
        new_cache = (c_lat, c_kr)

    s_kv = latent.shape[1]
    wkv_b = p["wkv_b"].astype(x.dtype).reshape(r_kv, nh, dn + dv)
    w_uk, w_uv = wkv_b[..., :dn], wkv_b[..., dn:]  # (r, nh, dn), (r, nh, dv)

    if kv_cache is None:
        # train/prefill: expand per-head K/V from the latent and run
        # blocked flash attention (never materializes (S, S) scores)
        from repro.models import shardctx
        from repro.models.flash import flash_mha

        k_nope = jnp.einsum("bkr,rhd->bkhd", latent, w_uk)
        v_full = jnp.einsum("bkr,rhd->bkhd", latent, w_uv)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s_kv, nh, dr))],
            axis=-1,
        )
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        spec = shardctx.attn_spec(nh, b)
        if spec is not None:
            q_full = shardctx.constrain(q_full, *spec)
            k_full = shardctx.constrain(k_full, *spec)
            v_full = shardctx.constrain(v_full, *spec)
        out = flash_mha(q_full, k_full, v_full, causal=True)
        if spec is not None:
            out = shardctx.constrain(out, *spec)
        return out.reshape(b, s, nh * dv) @ p["wo"].astype(x.dtype)

    # decode: absorbed attention in latent space (the MLA memory win —
    # the cache holds (latent, k_rope) only)
    q_abs = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)  # (b, s, nh, r)
    scores = jnp.einsum(
        "bshr,bkr->bhsk", q_abs, latent, preferred_element_type=jnp.float32
    )
    scores = scores + jnp.einsum(
        "bshd,bkd->bhsk", q_rope, k_rope, preferred_element_type=jnp.float32
    )
    scores = scores * ((dn + dr) ** -0.5)

    q_pos = positions
    k_pos = jnp.arange(s_kv)[None, :]
    mask = (k_pos <= q_pos[:, :1])[:, None, None, :]
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)

    ctx_lat = jnp.einsum("bhsk,bkr->bshr", w, latent)  # (b, s, nh, r)
    out = jnp.einsum("bshr,rhd->bshd", ctx_lat, w_uv)  # absorbed W_uv
    y = out.reshape(b, s, nh * dv) @ p["wo"].astype(x.dtype)
    return y, new_cache


# ---------------------------------------------------------------------------
# MLPs and MoE
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ArchConfig, dt: Dtypes, d_ff: Optional[int] = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w_in": _init(ks[0], (d, ff), d ** -0.5, dt.param),
        "w_out": _init(ks[1], (ff, d), ff ** -0.5, dt.param),
    }
    if cfg.gated:
        p["w_gate"] = _init(ks[2], (d, ff), d ** -0.5, dt.param)
    return p


def _act(name):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def mlp_apply(p, x, cfg: ArchConfig):
    h = x @ p["w_in"].astype(x.dtype)
    if cfg.gated:
        h = _act(cfg.act)(x @ p["w_gate"].astype(x.dtype)) * h
    else:
        h = _act(cfg.act)(h)
    return h @ p["w_out"].astype(x.dtype)


def moe_init(key, cfg: ArchConfig, dt: Dtypes):
    d, ff, e = cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": _init(ks[0], (d, e), d ** -0.5, jnp.float32),
        "w_in": _init(ks[1], (e, d, ff), d ** -0.5, dt.param),
        "w_out": _init(ks[2], (e, ff, d), ff ** -0.5, dt.param),
    }
    if cfg.gated:
        p["w_gate"] = _init(ks[3], (e, d, ff), d ** -0.5, dt.param)
    if cfg.n_shared_experts:
        shared_ff = ff * cfg.n_shared_experts
        p["shared"] = mlp_init(ks[4], cfg, dt, d_ff=shared_ff)
    return p


def moe_apply(
    p,
    x,
    cfg: ArchConfig,
    *,
    use_kernel: bool = False,
    capacity_factor: float = 1.25,
):
    """MoE FFN via *monotonic dispatch* (DESIGN.md §3.1).

    Default path: capacity-based gather/scatter. Tokens are placed into
    per-expert buffers at positions given by a cumulative count over the
    assignment stream — the vectorized frontier merge of the paper (the
    expert buffer is the DU "pending buffer", the capacity its depth).
    FLOPs stay proportional to *active* params (top_k of n_experts); the
    dispatch itself is pure data movement, so the compiled HLO FLOPs in
    the roofline reflect useful compute. Tokens above capacity drop
    (capacity_factor 1.25); the Pallas path (kernels/moe_group_mm) is
    the fully dropless variant used on real token streams.
    """
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    t = flat.shape[0]
    e, k = cfg.n_experts, cfg.top_k
    logits = (flat @ p["router"].astype(jnp.float32)).astype(jnp.float32)
    if use_kernel:
        from repro.kernels.moe_group_mm.ops import moe_ffn

        out = moe_ffn(
            flat, logits, p["w_in"], p.get("w_gate"), p["w_out"],
            top_k=cfg.top_k,
        )
    else:
        from repro.models import shardctx

        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)  # (T, k)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

        # Hierarchical dispatch (§Perf iteration: moonshot/phi3.5 train):
        # tokens are grouped by their data shard and dispatched into
        # per-group capacity buffers, so the scatter/gather stays
        # shard-local. A single global buffer gets replicated by the SPMD
        # partitioner (measured: 161 GiB temp on moonshot train_4k,
        # flat in layer count — one giant allocation).
        g_count = max(shardctx.axis_size(shardctx.dp_axes()), 1)
        if t % g_count != 0:
            g_count = 1
        tg = t // g_count  # tokens per group
        cap = max(1, int(capacity_factor * tg * k / e))

        flat_e = top_e.reshape(g_count, tg * k)  # per-group streams
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (G, Tg*k, E)
        # position inside the expert buffer: the monotonic frontier count
        # per group (cumsum == searchsorted post-sort)
        pos = jnp.sum((jnp.cumsum(onehot, axis=1) - 1) * onehot, axis=-1)
        keep = pos < cap
        slot = jnp.where(keep, flat_e * cap + pos, e * cap)  # overflow row

        tok = jnp.arange(tg * k) // k  # token-in-group per assignment
        xg = flat.reshape(g_count, tg, d)

        # per-group scatter/gather via vmap: the group axis is a clean
        # batch dim the SPMD partitioner can shard (2D fancy indexing
        # defeated it — measured 48 GiB x4 replicated (T*k, d) buffers)
        def dispatch_g(xg_g, slot_g):
            return jnp.zeros((e * cap + 1, d), flat.dtype).at[slot_g].set(
                xg_g[tok]
            )

        buf = jax.vmap(dispatch_g)(xg, slot)
        xe = buf[:, : e * cap].reshape(g_count, e, cap, d)
        xe = shardctx.constrain(xe, shardctx.dp_axes(), None, None, None)
        # NOTE: additionally pinning the expert dim to the model axis was
        # REFUTED (phi3.5: 80 -> 220s collective) — XLA materializes the
        # forced token->expert resharding through a replicated
        # intermediate. Group-local placement only.

        h = jnp.einsum("gecd,edf->gecf", xe, p["w_in"].astype(flat.dtype))
        if cfg.gated:
            gt = jnp.einsum(
                "gecd,edf->gecf", xe, p["w_gate"].astype(flat.dtype)
            )
            h = _act(cfg.act)(gt) * h
        else:
            h = _act(cfg.act)(h)
        ye = jnp.einsum("gecf,efd->gecd", h, p["w_out"].astype(flat.dtype))
        ye = shardctx.constrain(ye, shardctx.dp_axes(), None, None, None)

        gates = (
            top_p.reshape(g_count, tg * k) * keep
        ).astype(flat.dtype)

        def combine_g(ye_g, slot_g, gates_g):
            ya = ye_g.reshape(e * cap, d)[jnp.minimum(slot_g, e * cap - 1)]
            return jnp.zeros((tg, d), flat.dtype).at[tok].add(
                ya * gates_g[:, None]
            )

        out = jax.vmap(combine_g)(ye, slot, gates).reshape(t, d)
    if cfg.n_shared_experts:
        out = out + mlp_apply(p["shared"], flat, cfg)
    return out.reshape(b, s, d)
