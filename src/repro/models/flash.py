"""Blocked (flash-style) attention in pure JAX with a custom VJP.

Forward: static double loop (scan over q blocks, bounded fori over kv
blocks with causal block-skip) and online softmax — memory per step is
O(q_block * kv_block), never the (S, S) score matrix.

Backward: custom VJP with block recomputation (the real FlashAttention
recipe): residuals are only (q, k, v, out, row-logsumexp) = O(S·d); the
probability tiles are recomputed blockwise while accumulating dq/dk/dv.
Without this, differentiating the scan saves every (q,k) tile —
~400 GiB/device at 4k context (measured; see EXPERIMENTS.md §Perf).

Because AD never enters the loops, the causal block-skip (dynamic fori
bound) is usable in training too — the compiled FLOPs include only the
lower-triangle blocks.

The Pallas kernel in kernels/attention is the TPU twin of this loop
structure; tests validate both against attention_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30

# Causal block handling: "full" computes every (q, kv) block pair with
# masking (≈2x causal FLOPs, but statically counted in the HLO);
# "skip" bounds the kv loop at each q block's diagonal (dynamic trip —
# saves the compute but XLA can't report its FLOPs). EXPERIMENTS.md §Perf
# iterates this into the lower-triangle enumeration ("triangle"), which
# is both minimal and statically counted.
CAUSAL_BLOCKS = "full"  # full | skip


def _n_eff(causal, qi, qb, kb, nk):
    if not causal or CAUSAL_BLOCKS == "full":
        return nk
    return jnp.minimum(nk, ((qi + 1) * qb + kb - 1) // kb)


def _blockify(q, k, v, q_block, kv_block):
    b, s, h, d = q.shape
    s_kv, hk = k.shape[1], k.shape[2]
    qb = min(q_block, s)
    kb = min(kv_block, s_kv)
    assert s % qb == 0 and s_kv % kb == 0, (s, qb, s_kv, kb)
    return qb, kb, s // qb, s_kv // kb


def _mask(q_pos, k_pos, causal, window):
    mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        mask = q_pos[:, None] >= k_pos[None, :]
    w = jnp.asarray(window, jnp.int32)
    return mask & ((w == 0) | (k_pos[None, :] > q_pos[:, None] - w))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _flash(causal: bool, q_block: int, kv_block: int, q, k, v, window):
    out, _ = _flash_fwd_impl(causal, q_block, kv_block, q, k, v, window)
    return out


def _flash_fwd_impl(causal, q_block, kv_block, q, k, v, window):
    b, s, h, d = q.shape
    s_kv, hk = k.shape[1], k.shape[2]
    dv = v.shape[3]  # value head dim may differ from qk head dim (MLA)
    rep = h // hk
    scale = d ** -0.5
    qb, kb, nq, nk = _blockify(q, k, v, q_block, kv_block)

    qr = q.reshape(b, nq, qb, hk, rep, d).astype(jnp.float32) * scale
    kr = k.reshape(b, nk, kb, hk, d).astype(jnp.float32)
    vr = v.reshape(b, nk, kb, hk, dv).astype(jnp.float32)

    def q_step(_, qi):
        qblk = qr[:, qi]
        q_pos = qi * qb + jnp.arange(qb)

        def kv_step(ki, carry):
            acc, m, l = carry
            kblk, vblk = kr[:, ki], vr[:, ki]
            sc = jnp.einsum("bqhrd,bkhd->bhrqk", qblk, kblk)
            k_pos = ki * kb + jnp.arange(kb)
            msk = _mask(q_pos, k_pos, causal, window)
            sc = jnp.where(msk[None, None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhrqk,bkhd->bhrqd", p, vblk
            )
            return acc_new, m_new, l_new

        acc0 = jnp.zeros((b, hk, rep, qb, dv), jnp.float32)
        m0 = jnp.full((b, hk, rep, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hk, rep, qb), jnp.float32)
        acc, m, l = jax.lax.fori_loop(
            0, _n_eff(causal, qi, qb, kb, nk), kv_step, (acc0, m0, l0)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))  # (b, hk, rep, qb)
        return None, (jnp.moveaxis(out, 3, 1).reshape(b, qb, h, dv), lse)

    _, (blocks, lses) = jax.lax.scan(q_step, None, jnp.arange(nq))
    out = jnp.moveaxis(blocks, 0, 1).reshape(b, s, h, dv).astype(q.dtype)
    # lses: (nq, b, hk, rep, qb) -> (b, hk, rep, s)
    lse = jnp.moveaxis(lses, 0, 3).reshape(b, hk, rep, s)
    return out, lse


def _flash_fwd(causal, q_block, kv_block, q, k, v, window):
    out, lse = _flash_fwd_impl(causal, q_block, kv_block, q, k, v, window)
    return out, (q, k, v, window, out, lse)


def _flash_bwd(causal, q_block, kv_block, res, g):
    q, k, v, window, out, lse = res
    b, s, h, d = q.shape
    s_kv, hk = k.shape[1], k.shape[2]
    dv = v.shape[3]
    rep = h // hk
    scale = d ** -0.5
    qb, kb, nq, nk = _blockify(q, k, v, q_block, kv_block)

    qr = q.reshape(b, nq, qb, hk, rep, d).astype(jnp.float32) * scale
    kr = k.reshape(b, nk, kb, hk, d).astype(jnp.float32)
    vr = v.reshape(b, nk, kb, hk, dv).astype(jnp.float32)
    do = g.reshape(b, nq, qb, hk, rep, dv).astype(jnp.float32)
    o = out.reshape(b, nq, qb, hk, rep, dv).astype(jnp.float32)
    lse_r = lse.reshape(b, hk, rep, nq, qb)

    def q_step(carry, qi):
        dk, dvc = carry  # (b, nk, kb, hk, ·) f32 accumulators
        qblk = qr[:, qi]  # (b, qb, hk, rep, d)
        doblk = do[:, qi]
        oblk = o[:, qi]
        lblk = lse_r[:, :, :, qi]  # (b, hk, rep, qb)
        # D_i = rowsum(dO * O)
        dmat = jnp.einsum("bqhrd,bqhrd->bhrq", doblk, oblk)
        q_pos = qi * qb + jnp.arange(qb)

        def kv_step(ki, inner):
            dq_blk, dk, dvacc = inner
            kblk, vblk = kr[:, ki], vr[:, ki]
            sc = jnp.einsum("bqhrd,bkhd->bhrqk", qblk, kblk)
            k_pos = ki * kb + jnp.arange(kb)
            msk = _mask(q_pos, k_pos, causal, window)
            sc = jnp.where(msk[None, None, None], sc, NEG_INF)
            p = jnp.exp(sc - lblk[..., None])  # (b,hk,rep,qb,kb)
            dv_c = jnp.einsum("bhrqk,bqhrd->bkhd", p, doblk)
            dp = jnp.einsum("bqhrd,bkhd->bhrqk", doblk, vblk)
            ds = p * (dp - dmat[..., None])
            dq_blk = dq_blk + jnp.einsum("bhrqk,bkhd->bqhrd", ds, kblk)
            dk_c = jnp.einsum("bhrqk,bqhrd->bkhd", ds, qblk)
            dk = jax.lax.dynamic_update_slice(
                dk,
                jax.lax.dynamic_slice(
                    dk, (0, ki, 0, 0, 0), (b, 1, kb, hk, d)
                ) + dk_c[:, None],
                (0, ki, 0, 0, 0),
            )
            dvacc = jax.lax.dynamic_update_slice(
                dvacc,
                jax.lax.dynamic_slice(
                    dvacc, (0, ki, 0, 0, 0), (b, 1, kb, hk, dv)
                ) + dv_c[:, None],
                (0, ki, 0, 0, 0),
            )
            return dq_blk, dk, dvacc

        dq0 = jnp.zeros((b, qb, hk, rep, d), jnp.float32)
        dq_blk, dk, dvc = jax.lax.fori_loop(
            0, _n_eff(causal, qi, qb, kb, nk), kv_step, (dq0, dk, dvc)
        )
        return (dk, dvc), dq_blk * scale

    dk0 = jnp.zeros((b, nk, kb, hk, d), jnp.float32)
    dv0 = jnp.zeros((b, nk, kb, hk, dv), jnp.float32)
    (dk, dvc), dq_blocks = jax.lax.scan(q_step, (dk0, dv0), jnp.arange(nq))
    dq = jnp.moveaxis(dq_blocks, 0, 1).reshape(b, s, h, d).astype(q.dtype)
    dk_out = dk.reshape(b, s_kv, hk, d).astype(k.dtype)
    dv_out = dvc.reshape(b, s_kv, hk, dv).astype(v.dtype)
    return dq, dk_out, dv_out, None  # no cotangent for window


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_mha(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S_kv, Hk, D)
    v: jax.Array,  # (B, S_kv, Hk, D)
    *,
    causal: bool = True,
    window=0,  # static int or traced scalar; 0 = full attention
    q_block: int = 512,
    kv_block: int = 512,
    skip_masked_blocks: bool = True,  # kept for API compat; always safe now
) -> jax.Array:
    del skip_masked_blocks  # the custom VJP makes the skip AD-safe
    qb = min(q_block, q.shape[1])
    kb = min(kv_block, k.shape[1])
    return _flash(bool(causal), qb, kb, q, k, v, jnp.asarray(window, jnp.int32))


def attention_ref(q, k, v, *, causal=True, window=0):
    """Direct O(S^2)-memory oracle for flash_mha."""
    b, s, h, d = q.shape
    hk = k.shape[2]
    rep = h // hk
    qr = q.reshape(b, s, hk, rep, d).astype(jnp.float32) * (d ** -0.5)
    sc = jnp.einsum("bqhrd,bkhd->bhrqk", qr, k.astype(jnp.float32))
    q_pos, k_pos = jnp.arange(s), jnp.arange(k.shape[1])
    msk = _mask(q_pos, k_pos, causal, window)
    sc = jnp.where(msk[None, None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhrqk,bkhd->bhrqd", p, v.astype(jnp.float32))
    return jnp.moveaxis(o, 3, 1).reshape(b, s, h, d).astype(q.dtype)
