"""Partitioning rules: parameter/optimizer/batch/cache PartitionSpecs.

Strategy (single pod mesh ("data", "model") = (16, 16); multi-pod adds a
leading "pod" axis used for data parallelism only):

  * 2D weight sharding: every large matrix is sharded on BOTH axes —
    row-wise over "data" (FSDP/ZeRO: XLA inserts the all-gather before
    use and reduce-scatters the gradient) and column-wise over "model"
    (Megatron tensor parallelism over heads / FFN / vocab / experts).
  * Experts (MoE): expert dimension over "model" (EP), contracting dim
    over "data".
  * Optimizer moments: identical specs to their parameters (fp32,
    fully sharded — ZeRO-2/3 equivalent).
  * Activations: layer-boundary carries are sharded batch-over-data and
    sequence-over-model (Megatron sequence parallelism) via
    with_sharding_constraint in the train step.
  * Decode caches: batch over data, kv-heads over "model"; long-context
    (batch=1) caches shard the *sequence* over "data" (SP).
  * Params are replicated across pods; the pod axis only reduces
    gradients (optionally int8-compressed, optim/compression.py).
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# name -> spec for the TRAILING dims (leading stacked dims get None)
_RULES: dict[str, tuple] = {
    "embed": ("model", "data"),
    "lm_head": ("data", "model"),
    "final_norm": (None,),
    "enc_norm": (None,),
    # attention
    "wq": ("data", "model"),
    "wk": ("data", "model"),
    "wv": ("data", "model"),
    "wo": ("model", "data"),
    "q_norm": (None,),
    "k_norm": (None,),
    # MLA
    "wq_a": ("data", None),
    "wq_b": (None, "model"),
    "wkv_a": ("data", None),
    "wkv_b": (None, "model"),
    "q_a_norm": (None,),
    "kv_a_norm": (None,),
    # MLP
    "w_in": ("data", "model"),
    "w_gate": ("data", "model"),
    "w_out": ("model", "data"),
    # MoE (expert-stacked weights override by rank below)
    "router": ("data", None),
    # SSM
    "conv_w": (None, "model"),
    "conv_b": ("model",),
    "a_log": ("model",),
    "w_bc": ("data", None),
    "w_dt": ("data", "model"),
    "dt_bias": ("model",),
    "d_skip": ("model",),
    "norm_scale": ("model",),
    # norms
    "attn_norm": (None,),
    "mlp_norm": (None,),
    "cross_norm": (None,),
}

# MoE expert weights: (E, d, ff)-shaped -> EP over model, FSDP over data
_MOE_RULES = {
    "w_in": ("model", "data", None),
    "w_gate": ("model", "data", None),
    "w_out": ("model", None, "data"),
}


def param_spec(path, leaf) -> P:
    names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
    key = names[-1]
    moe = any(n in ("moe",) for n in names)
    if moe and key in _MOE_RULES:
        trailing = _MOE_RULES[key]
    elif key in _RULES:
        trailing = _RULES[key]
    else:
        trailing = tuple([None] * leaf.ndim)
    pad = leaf.ndim - len(trailing)
    spec = (None,) * pad + tuple(trailing)
    # degenerate dims: drop sharding on axes the array can't fill evenly
    return P(*spec[: leaf.ndim])


def param_specs(params):
    return jax.tree_util.tree_map_with_path(param_spec, params)


def opt_specs(params):
    """Optimizer moments share their parameter's spec; step is replicated."""
    ps = param_specs(params)
    return {"m": ps, "v": ps, "step": P()}


def _dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def batch_spec(mesh: Mesh, *, long_context: bool = False) -> dict:
    dp = _dp_axes(mesh)
    if long_context:  # batch=1: shard the sequence instead (SP)
        return {"tokens": P(None, "data"), "targets": P(None, "data")}
    return {"tokens": P(dp, None), "targets": P(dp, None)}


def cache_spec(path, leaf, mesh: Mesh, *, long_context: bool = False) -> P:
    """Decode-cache specs: (stack, B, S, heads, hd)-style trees.

    The model axis lands on the kv-head dim when divisible, else on the
    head_dim (always 128-aligned), else on the sequence — without this
    fallback, archs with few kv heads (e.g. 4 < 16) would carry
    model-replicated caches (measured: internvl2 decode_32k at 240 GiB
    per device before the fix)."""
    dp = _dp_axes(mesh)
    msize = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
    nd = leaf.ndim
    if "ssm" in names:
        if "conv" in names:
            # (L, B, K-1, di)
            return P(None, dp, None, "model") if nd == 4 else P(*((None,) * nd))
        # h: (L, B, di, n) or (L, B, nh, hd, n)
        if nd == 4:
            return P(None, dp, "model", None)
        if nd == 5:
            return P(None, dp, "model", None, None)
    if nd == 5:  # (L, B, S, kv, hd)
        batch_ax = None if long_context else dp
        seq_ax = "data" if long_context else None
        if leaf.shape[3] % msize == 0:
            return P(None, batch_ax, seq_ax, "model", None)
        if leaf.shape[4] % msize == 0:
            return P(None, batch_ax, seq_ax, None, "model")
        if long_context:
            return P(None, None, ("data", "model"), None, None)
        return P(None, dp, "model", None, None)
    if nd == 4:  # mla: (L, B, S, r)
        seq_ax = "data" if long_context else None
        batch_ax = None if long_context else dp
        if leaf.shape[3] % msize == 0:
            return P(None, batch_ax, seq_ax, "model")
        return P(None, batch_ax, seq_ax, None)
    return P(*((None,) * nd))


def cache_specs(cache, mesh: Mesh, *, long_context: bool = False):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: cache_spec(p, l, mesh, long_context=long_context), cache
    )


def shardings_of(specs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def validate_divisibility(specs, tree, mesh: Mesh):
    """Replace specs whose sharded dims don't divide the mesh axis —
    keeps small/reduced configs lowerable on the production mesh."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix(spec, leaf):
        out = []
        for dim, ax in enumerate(spec):
            if ax is None:
                out.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            need = int(np.prod([sizes[a] for a in axes]))
            out.append(ax if leaf.shape[dim] % need == 0 else None)
        return P(*out)

    return jax.tree.map(
        fix, specs, tree, is_leaf=lambda x: isinstance(x, P)
    )
