"""Elastic scaling: rebuild the mesh from surviving devices and re-shard.

Checkpoints are topology-independent (full host arrays keyed by tree
path), so elasticity is: (1) choose a new mesh shape from the available
device count, (2) re-derive PartitionSpecs (they are symbolic, not
device-count-bound), (3) device_put the restored state under the new
NamedShardings, (4) re-partition the data stream (pipeline sharding is a
pure function of (step, shard, n_shards)).

``choose_mesh_shape`` prefers keeping the model axis at the largest
divisor that still fits the architecture's head/expert counts — dropping
data-parallel width first, which changes only throughput, never
legality.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.distributed import partition


def choose_mesh_shape(n_devices: int, *, prefer_model: int = 16,
                      max_model_divisor: int = 16) -> tuple[int, int]:
    """(data, model) for an arbitrary surviving device count."""
    model = min(prefer_model, max_model_divisor)
    while model > 1 and n_devices % model != 0:
        model //= 2
    return n_devices // model, model


def rebuild_mesh(devices=None, *, prefer_model: int = 16) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    data, model = choose_mesh_shape(len(devices), prefer_model=prefer_model)
    dev = np.array(devices[: data * model]).reshape(data, model)
    return Mesh(dev, ("data", "model"))


def reshard_state(state, mesh: Mesh):
    """Re-shard a (restored, host-resident) state pytree onto ``mesh``
    using the standard partitioning rules, with divisibility fixes for
    the new axis sizes."""
    params = state["params"] if isinstance(state, dict) and "params" in state else state
    specs = partition.param_specs(params)
    specs = partition.validate_divisibility(specs, params, mesh)
    sh = partition.shardings_of(specs, mesh)
    new_params = jax.tree.map(lambda a, s: jax.device_put(a, s), params, sh)
    if isinstance(state, dict) and "params" in state:
        out = dict(state)
        out["params"] = new_params
        return out
    return new_params
