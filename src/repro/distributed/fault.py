"""Fault tolerance: checkpointed restart loop + straggler watchdog.

``FaultTolerantLoop`` wraps the train step:

  * periodic async checkpoints (checkpoint/manager.py) with atomic
    commit and retention,
  * on ANY step failure: restore the latest checkpoint, rebuild device
    state, and *resume the exact data stream* (the pipeline is a pure
    function of the step counter — no data state to lose),
  * bounded retries with exponential backoff; a persistent failure
    re-raises with the step context,
  * straggler watchdog: per-step wall times feed an EWMA; steps slower
    than ``straggler_factor`` x the EWMA are logged with the step index
    (on a real fleet this triggers the elastic re-shard path in
    elastic.py; in tests it records events).

At 1000+ nodes the same structure runs per-controller: JAX multi-host
SPMD fails collectively (any host error aborts the step on all hosts),
so restart-from-checkpoint is the recovery primitive, and elastic
re-sharding (elastic.py) handles permanent node loss by re-building the
mesh from survivors — checkpoints are topology-independent.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

from repro.checkpoint import manager as ckpt

log = logging.getLogger("repro.fault")


@dataclasses.dataclass
class FaultConfig:
    checkpoint_dir: str = "/tmp/repro_ckpt"
    checkpoint_every: int = 50
    max_retries: int = 3
    backoff_s: float = 0.1
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.1


class FaultTolerantLoop:
    def __init__(
        self,
        step_fn: Callable,  # (state, batch) -> (state, metrics)
        state,  # pytree: params/opt/etc.
        loader,  # data.pipeline.ShardedLoader
        cfg: FaultConfig,
        state_shardings=None,
    ):
        self.step_fn = step_fn
        self.state = state
        self.loader = loader
        self.cfg = cfg
        self.state_shardings = state_shardings
        self.saver = ckpt.AsyncCheckpointer(cfg.checkpoint_dir)
        self.step = 0
        self.ewma: Optional[float] = None
        self.straggler_events: list[tuple[int, float]] = []
        self.recoveries = 0

    # -- checkpoint/restore -------------------------------------------------

    def _save(self):
        self.saver.save({"state": self.state, "data": self.loader.state()},
                        self.step)

    def try_restore(self) -> bool:
        # a failure can race an in-flight async save: without draining it
        # we restore an older step and silently replay (and re-log) the
        # steps in between
        self.saver.wait()
        latest = ckpt.latest_step(self.cfg.checkpoint_dir)
        if latest is None:
            return False
        like = {"state": self.state, "data": self.loader.state()}
        shardings = None
        if self.state_shardings is not None:
            shardings = {"state": self.state_shardings,
                         "data": {"step": None}}
        restored, step = ckpt.restore(
            like, self.cfg.checkpoint_dir, shardings=shardings
        )
        self.state = restored["state"]
        if self.state_shardings is not None:
            import jax

            self.state = jax.tree.map(
                lambda a, s: jax.device_put(a, s),
                self.state,
                self.state_shardings,
            )
        self.loader.restore(restored["data"])
        self.step = step
        return True

    # -- main loop ----------------------------------------------------------

    def run(self, n_steps: int):
        metrics_log = []
        while self.step < n_steps:
            batch = next(self.loader)
            t0 = time.monotonic()
            for attempt in range(self.cfg.max_retries + 1):
                try:
                    self.state, metrics = self.step_fn(self.state, batch)
                    break
                except Exception as e:  # noqa: BLE001 — any step fault
                    log.warning("step %d failed (%s); recovering", self.step, e)
                    self.recoveries += 1
                    if attempt == self.cfg.max_retries:
                        raise RuntimeError(
                            f"step {self.step} failed after "
                            f"{self.cfg.max_retries} retries"
                        ) from e
                    time.sleep(self.cfg.backoff_s * 2 ** attempt)
                    if self.try_restore():
                        # loader rewound with the checkpoint: re-fetch so
                        # the retried step consumes the right batch and
                        # the stream stays aligned with the step counter
                        batch = next(self.loader)
                    else:
                        log.warning("no checkpoint yet; retrying in place")
            dt = time.monotonic() - t0
            self._watch_straggler(dt)
            metrics_log.append(metrics)
            self.step += 1
            if self.step % self.cfg.checkpoint_every == 0:
                self._save()
        self.saver.wait()
        return metrics_log

    def _watch_straggler(self, dt: float):
        if self.ewma is None:
            self.ewma = dt
            return
        if dt > self.cfg.straggler_factor * self.ewma:
            log.warning("straggler step %d: %.3fs vs EWMA %.3fs",
                        self.step, dt, self.ewma)
            self.straggler_events.append((self.step, dt))
        a = self.cfg.ewma_alpha
        self.ewma = (1 - a) * self.ewma + a * dt
