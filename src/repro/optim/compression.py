"""Gradient compression for cross-pod data parallelism.

int8 quantization with error feedback: the residual of each step's
quantization is carried and added to the next step's gradient, so the
compression is unbiased over time (standard EF-SGD/EF21 argument). On
the production mesh this halves-to-quarters the bytes crossing the
(slow) pod axis; the roofline collective term in EXPERIMENTS.md §Perf
quantifies it per architecture.

``compress_decompress`` is the pure pjit-compatible form: XLA sees the
quantize -> (all-reduce in int8 space is modelled by the caller's psum
over the pod axis) -> dequantize chain and schedules it on the pod
collectives. ``shard_map`` usage lives in distributed/dp.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array):
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_grads(grads, errors):
    """Error-feedback compression of a gradient pytree.

    Returns (quantized-dequantized grads, new error state). Callers
    all-reduce the returned grads (they are the int8-representable
    values, so the reduction is exactly what an int8 collective would
    produce up to the deferred residual)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), g32 - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        tdef.unflatten([o[0] for o in out]),
        tdef.unflatten([o[1] for o in out]),
    )


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
