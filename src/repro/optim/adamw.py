"""AdamW with FSDP-friendly state, global-norm clipping, LR schedules.

Implemented from scratch (no optax dependency): the optimizer state is a
pytree congruent with the parameters, so the same PartitionSpecs shard
both — the fp32 master moments live fully sharded over the data axis
(ZeRO-style) under the partitioner in distributed/partition.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1
    )
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t)
    )
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params) -> dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, new_state, metrics
