"""Sweep planner: group, deduplicate, and share compiled artifacts.

``plan()`` partitions sweep points into per-(kernel, scale) groups and
collapses each group's points onto *unique runs* by ``result_key`` —
the dedup exploits the two proven result-invariances (trace modes are
bit-identical; STA ignores the engine; see ``dse.spec``).

``GroupContext`` then materializes, lazily and at most once per group,
everything a run needs that does not depend on timing parameters:

  * the program + input arrays/params (``programs.REGISTRY``),
  * ``Compiled`` per forwarding class (FUS2 forwards; the rest do not),
  * one AGU trace set (``schedule.trace_program(mode="auto")``) shared
    by every point — the trace-sharing contract of DESIGN.md §9; a
    point that demands ``trace_mode="compiled"`` triggers the same
    strict check (and the same ``TraceCompileError``) standalone
    ``simulate()`` would raise,
  * the hooked sequential oracle (final arrays + per-op load values),
  * recorded CU scripts (``dae.record_cu_script``) replayed per run,
  * §5.6 NoDependence bits over the union of both plans' pairs, and
    the LSQ instance rank table,
  * STA instance decomposition.

All of these are pure functions of (program, arrays, params), so runs
seeded with them are bit-identical to standalone ``simulate()``.
"""

from __future__ import annotations

import dataclasses
from functools import cached_property
from typing import Optional

from repro.core import dae as daelib
from repro.core import du as dulib
from repro.core import loopir as ir
from repro.core import programs
from repro.core import schedule as schedlib
from repro.core import simulator
from repro.dse.spec import SweepPoint


@dataclasses.dataclass
class UniqueRun:
    """One actual simulation serving one or more sweep points."""

    key: tuple  # SweepPoint.result_key
    rep: SweepPoint  # representative point (defines mode/engine/sim)
    point_indices: list  # indices into the sweep's point list


@dataclasses.dataclass
class Group:
    kernel: str
    scale: int
    runs: list  # [UniqueRun]
    # the decoupling policy this group compiles under: "auto" only when
    # its points actually speculate (SweepPoint.spec_class); points
    # whose knob provably cannot change the result share the "off"
    # compile (the fourth result-invariance, dse.spec)
    speculation: str = "off"
    # the speculative-AGU predictor and run-ahead window the group's
    # shared SpecPlan is traced under (dse.spec fifth invariance):
    # distinct values produce distinct gate schedules, so they get
    # distinct groups; non-speculative (and STA-folded) groups keep the
    # defaults — their plan is unused
    predictor: str = "auto"
    spec_runahead: Optional[int] = None
    # the planner's group identity (kernel, scale, spec_class,
    # predictor_class, runahead_class): stable across shards —
    # ``shard.merge_results`` sorts by it to restore the canonical
    # single-host group order
    class_key: tuple = ()

    @property
    def n_points(self) -> int:
        return sum(len(r.point_indices) for r in self.runs)


def plan(points: list[SweepPoint]) -> list[Group]:
    """Group points by (kernel, scale, spec/predictor/run-ahead class),
    dedup by result key. The predictor and run-ahead classes fold to
    ``"-"`` for points that never consult a SpecPlan (dse.spec), so
    e.g. all STA points of a speculative kernel share one group — and
    one run — across every predictor value."""
    groups: dict[tuple, dict[tuple, UniqueRun]] = {}
    for i, p in enumerate(points):
        g = groups.setdefault(
            (p.kernel, p.scale, p.spec_class, p.predictor_class,
             p.runahead_class),
            {},
        )
        run = g.get(p.result_key)
        if run is None:
            g[p.result_key] = UniqueRun(key=p.result_key, rep=p, point_indices=[i])
        else:
            run.point_indices.append(i)
    return [
        Group(
            kernel=k, scale=s, runs=list(g.values()),
            speculation="auto" if sc == "auto" else "off",
            predictor=pc if pc != "-" else "auto",
            spec_runahead=rc if rc != "-" else None,
            class_key=(k, s, sc, pc, rc),
        )
        for (k, s, sc, pc, rc), g in sorted(
            groups.items(), key=lambda kv: tuple(map(str, kv[0]))
        )
    ]


class GroupContext:
    """Lazily-built shared artifacts for one (kernel, scale) group."""

    def __init__(self, group: Group):
        self.group = group
        prog, arrays, params = programs.get(group.kernel).make(group.scale)
        self.program = prog
        self.arrays = arrays
        self.params = params
        self._strict_checked = False
        # statically-pruned hazard-plan variants (DESIGN.md §12), keyed
        # by forwarding class; built only when a run asks for one
        self._comp_pruned: dict[bool, simulator.Compiled] = {}

    # -- compile front-end -------------------------------------------------

    @cached_property
    def comp_fwd(self) -> simulator.Compiled:
        return simulator.Compiled(
            self.program, forwarding=True, speculation=self.group.speculation,
            predictor=self.group.predictor,
        )

    @cached_property
    def comp_nofwd(self) -> simulator.Compiled:
        return simulator.Compiled(
            self.program, forwarding=False, speculation=self.group.speculation,
            predictor=self.group.predictor,
        )

    def comp(self, mode: str, static_prune: bool = False) -> simulator.Compiled:
        """Shared compile for ``mode``. ``static_prune`` selects the
        certifier-pruned hazard-plan variant (DESIGN.md §12); its kept
        pairs are a subset of the baseline's, so the group's
        ``nodep_bits`` (built over the baseline plans' union) cover
        every pair the pruned plan can look up."""
        if not static_prune:
            return self.comp_fwd if mode == "FUS2" else self.comp_nofwd
        fwd = mode == "FUS2"
        comp = self._comp_pruned.get(fwd)
        if comp is None:
            comp = simulator.Compiled(
                self.program, forwarding=fwd,
                speculation=self.group.speculation,
                predictor=self.group.predictor, static_prune=True,
            )
            self._comp_pruned[fwd] = comp
        return comp

    @cached_property
    def _traced(self) -> tuple:
        """(trace set, SpecPlan | None) — one shared build per group.
        Speculative groups reuse the group's hooked oracle run for the
        predictor's load streams (no second sequential walk)."""
        spec_out: list = []
        traces = schedlib.trace_program(
            self.program, self.comp_nofwd.dae, self.arrays, self.params,
            mode="auto", spec_out=spec_out,
            oracle_loads=(
                self.oracle_loads if self.comp_nofwd.dae.spec else None
            ),
            predictor=self.group.predictor,
            spec_runahead=self.group.spec_runahead,
        )
        return traces, (spec_out[0] if spec_out else None)

    @property
    def traces(self) -> dict[str, schedlib.OpTrace]:
        """The single shared AGU trace set (compiled where possible)."""
        return self._traced[0]

    @property
    def spec_plan(self):
        """Shared speculation plan (``speculate.SpecPlan``), or None."""
        return self._traced[1]

    def check_strict_compiled(self) -> None:
        """Raise ``TraceCompileError`` exactly as ``simulate()`` with
        ``trace_mode="compiled"`` would, if any PE is off the compiled
        path. (The streams themselves are shared either way.)"""
        if not self._strict_checked:
            report: dict = {}
            schedlib.trace_program(
                self.program, self.comp_nofwd.dae, self.arrays, self.params,
                mode="compiled", report=report,
            )
            self._strict_checked = True

    # -- oracle ------------------------------------------------------------

    @cached_property
    def _oracle(self) -> tuple:
        loads: dict[str, list] = {}

        def hook(op_id, addr, is_store, valid, value):
            if not is_store:
                loads.setdefault(op_id, []).append(value)

        final = ir.interpret(self.program, self.arrays, self.params, hook)
        return final, loads

    @property
    def final_arrays(self) -> dict:
        return self._oracle[0]

    @property
    def oracle_loads(self) -> dict:
        return self._oracle[1]

    # -- shared engine state -----------------------------------------------

    @cached_property
    def cu_scripts(self) -> dict[int, daelib.CUScript]:
        return {
            pe.id: daelib.record_cu_script(
                pe, self.arrays, self.params, self.oracle_loads
            )
            for pe in self.comp_nofwd.dae.pes
        }

    def cu_factory(self, pe: daelib.PE) -> daelib.ReplayCU:
        return daelib.ReplayCU(self.cu_scripts[pe.id])

    @cached_property
    def nodep_bits(self) -> dict:
        """§5.6 bit streams over the union of both forwarding classes'
        kept pairs (engines look entries up by (dst, src) id)."""
        pairs = {
            (p.dst, p.src): p
            for p in self.comp_nofwd.plan.pairs + self.comp_fwd.plan.pairs
        }
        return dulib.nodependence_bits(list(pairs.values()), self.traces)

    @cached_property
    def rank_table(self) -> tuple:
        comp = self.comp_nofwd
        fuse = {pe.id: pe.id for pe in comp.dae.pes}
        return schedlib.instance_rank_table(
            self.traces, comp.dae, comp.loop_pos, comp.op_pos, fuse,
            comp.op_path,
        )

    @cached_property
    def sta_instances(self) -> tuple:
        comp = self.comp_nofwd
        fuse = simulator._fusion_groups_sta(comp)
        return simulator._instances(comp, self.traces, fuse)

    # -- assembly ----------------------------------------------------------

    def shared_for(self, mode: str) -> simulator.SharedArtifacts:
        """The ``SharedArtifacts`` bundle for one run of this group."""
        if mode == "STA":
            return simulator.SharedArtifacts(
                sta_instances=self.sta_instances,
                final_arrays=self.final_arrays,
            )
        # FIFO streaming PEs (DESIGN.md §11) run live generator CUs —
        # their pop/push waits cannot be pre-recorded as a replay
        # script, so those groups skip the cu_factory fast path
        streaming = bool(self.comp_nofwd.dae.fifo_edges)
        return simulator.SharedArtifacts(
            nodep_bits=self.nodep_bits,
            rank_table=self.rank_table if mode == "LSQ" else None,
            cu_factory=None if streaming else self.cu_factory,
        )

    def oracle_loads_if(self, validate: bool) -> Optional[dict]:
        return self.oracle_loads if validate else None
