"""Sharded sweep execution: deterministic partition + exact merge.

A shard is a subset of the planner's (kernel, scale, spec_class,
predictor_class, runahead_class) groups — the same unit ``runner``
parallelizes over, so sharding composes with ``workers`` and the cache
and cannot split a group's shared artifacts across hosts.

The partition is a pure function of the point list and the shard
count: groups are sorted by descending run count (plan index breaking
ties) and greedily assigned to the least-loaded shard (LPT). Every
host running ``sweep_shard(spec, i, n)`` with the same spec therefore
computes the same assignment without any coordination, and
``merge_results()`` reassembles the ``SweepResult`` a single host
would have produced — bit-identically, because group execution is
independent and deterministic (DESIGN.md §13).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

from repro.dse.planner import Group, plan
from repro.dse.spec import SweepPoint, SweepSpec


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Deterministic assignment of planner groups to shards.

    ``assignment[i]`` is the shard index of the i-th group of
    ``planner.plan(points)`` (canonical plan order); ``loads`` the
    resulting per-shard unique-run counts — the balance the LPT
    heuristic achieved (max-min bounded by the largest group).
    """

    n_shards: int
    assignment: tuple  # group index (plan order) -> shard index
    loads: tuple  # per-shard unique-run counts

    def groups_for(self, shard: int) -> list[int]:
        """Plan-order indices of the groups shard ``shard`` owns."""
        if not (0 <= shard < self.n_shards):
            raise ValueError(
                f"shard index {shard} outside 0..{self.n_shards - 1}"
            )
        return [i for i, s in enumerate(self.assignment) if s == shard]


def shard_groups(groups: Sequence[Group], n_shards: int) -> ShardPlan:
    """LPT-partition already-planned ``groups`` across ``n_shards``."""
    n_shards = int(n_shards)
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    order = sorted(range(len(groups)), key=lambda i: (-len(groups[i].runs), i))
    loads = [0] * n_shards
    assignment = [0] * len(groups)
    for i in order:
        s = min(range(n_shards), key=lambda j: (loads[j], j))
        assignment[i] = s
        loads[s] += len(groups[i].runs)
    return ShardPlan(
        n_shards=n_shards, assignment=tuple(assignment), loads=tuple(loads)
    )


def shard_plan(
    spec: Union[SweepSpec, Sequence[SweepPoint]], n_shards: int
) -> ShardPlan:
    """Partition a spec's planner groups across ``n_shards`` —
    deterministic: same spec, same count, same plan on every host."""
    points = list(spec.points() if isinstance(spec, SweepSpec) else spec)
    return shard_groups(plan(points), n_shards)


def sweep_shard(
    spec: Union[SweepSpec, Sequence[SweepPoint]],
    shard: int,
    n_shards: int,
    **kwargs,
):
    """Run shard ``shard`` of ``n_shards`` of a sweep.

    Thin wrapper over ``runner.sweep(shard=(shard, n_shards))`` —
    accepts the same keyword arguments (``cache_dir``, ``workers``,
    ``resume``, ``on_point``, ...). The returned ``SweepResult`` keeps
    the full-length point list with ``None`` at indices other shards
    own, counts only its own points/runs, and marks
    ``stats.shard=(shard, n_shards)``; feed all shards to
    ``merge_results()``.
    """
    from repro.dse import runner

    return runner.sweep(spec, shard=(int(shard), int(n_shards)), **kwargs)


def merge_results(shards: Sequence):
    """Union per-shard ``SweepResult``s into the single-host result.

    Validates the shards form an exact partition (every point owned by
    exactly one shard, shard indices distinct, one shard count) and
    splices points back into canonical order; group stats and profile
    rows are re-sorted by the planner's ``class_key`` order, counters
    are summed, and ``wall_s`` is the max over shards (they run
    concurrently). The result is bit-identical to
    ``runner.sweep(spec)`` run unsharded — pinned by
    tests/test_sweep_service.py and ``benchmarks/sweep.py --smoke``.
    """
    from repro.dse.runner import SweepStats

    shards = list(shards)
    if not shards:
        raise ValueError("merge_results: no shard results")
    n_total = len(shards[0].points)
    counts = {
        s.stats.shard[1] if s.stats and s.stats.shard else None
        for s in shards
    }
    if len(counts) != 1 or None in counts:
        raise ValueError(
            "merge_results: inputs must all be sharded results from one "
            f"shard count, got shard markers {sorted(map(str, counts))}"
        )
    seen_idx = set()
    points: list = [None] * n_total
    for s in shards:
        if len(s.points) != n_total:
            raise ValueError(
                "merge_results: shard point lists disagree in length "
                f"({len(s.points)} vs {n_total}) — different specs?"
            )
        idx = s.stats.shard[0]
        if idx in seen_idx:
            raise ValueError(f"merge_results: duplicate shard index {idx}")
        seen_idx.add(idx)
        for i, pr in enumerate(s.points):
            if pr is None:
                continue
            if points[i] is not None:
                raise ValueError(
                    f"merge_results: point {i} owned by more than one shard"
                )
            points[i] = pr
    missing = [i for i, pr in enumerate(points) if pr is None]
    if missing:
        raise ValueError(
            f"merge_results: {len(missing)} point(s) owned by no shard "
            f"(first: {missing[0]}) — pass every shard of the partition"
        )

    tagged = []
    profile_rows = []
    for s in sorted(shards, key=lambda s: s.stats.shard[0]):
        tagged.extend(s.groups)
        profile_rows.extend(s.profile)
    group_stats = sorted(
        tagged, key=lambda g: tuple(map(str, g.get("class_key", ())))
    )
    profile_rows = sorted(
        profile_rows, key=lambda r: tuple(map(str, r.get("class_key", ())))
    )

    stats = SweepStats(
        n_groups=sum(s.stats.n_groups for s in shards),
        n_points=sum(s.stats.n_points for s in shards),
        n_unique_runs=sum(s.stats.n_unique_runs for s in shards),
        n_cache_hits=sum(s.stats.n_cache_hits for s in shards),
        n_executed=sum(s.stats.n_executed for s in shards),
        n_retries=sum(s.stats.n_retries for s in shards),
        retries=[r for s in shards for r in s.stats.retries],
        n_resumed_runs=sum(s.stats.n_resumed_runs for s in shards),
        journal_entries=sum(s.stats.journal_entries for s in shards),
        journal_corrupt=sum(s.stats.journal_corrupt for s in shards),
        shard=None,
        wall_s=max(s.stats.wall_s for s in shards),
    )
    return dataclasses.replace(
        shards[0],
        points=points,
        n_points=stats.n_points,
        n_unique_runs=stats.n_unique_runs,
        n_cache_hits=stats.n_cache_hits,
        wall_s=stats.wall_s,
        groups=group_stats,
        profile=profile_rows,
        stats=stats,
    )


def merge_caches(dst: str, *srcs: str) -> int:
    """Copy every cache entry (and journal line) absent from ``dst``
    out of the ``srcs`` cache directories; returns the number of npz
    entries copied. Content-addressed names make this a union — no
    entry can conflict."""
    import os
    import shutil

    from repro.dse import cache as cachelib

    os.makedirs(dst, exist_ok=True)
    copied = 0
    journal = cachelib.SweepJournal(dst)
    for src in srcs:
        if not os.path.isdir(src):
            continue
        for fn in sorted(os.listdir(src)):
            if fn.endswith(".npz"):
                target = os.path.join(dst, fn)
                if not os.path.exists(target):
                    shutil.copyfile(os.path.join(src, fn), target)
                    copied += 1
        src_journal = cachelib.SweepJournal(src)
        entries, _corrupt = src_journal.load()
        for e in entries:
            journal.append(e)
    return copied
