"""Sweep specification: the configuration grid of a design-space run.

A *sweep point* is one fully specified simulation:
``(kernel, scale, mode, engine, trace_mode, speculation, SimParams
sizing)``. A ``SweepSpec`` expands a grid (or several stacked grids)
into points.

Two distinct notions of identity matter downstream:

  * ``point_id`` — the user-facing identity; every requested point gets
    its own row in the sweep result.
  * ``result_key`` — the *result* identity used for dedup and caching:
    points that provably produce bit-identical ``SimResult``s share it.
    Three result-invariances fold points together (DESIGN.md §9.1):

      1. ``trace_mode`` is excluded entirely (compiled and interpreted
         AGU streams are bit-for-bit equal — the PR-2 contract asserted
         by tests/test_trace_compile.py and tests/test_engine_diff.py),
      2. ``engine`` is excluded for STA (the analytical model never
         runs an engine),
      3. the ``SimParams`` overrides are **projected onto the fields
         the mode actually reads** (``MODE_SIM_FIELDS``): STA never
         reads CU/forwarding latencies, the dynamic engines never read
         ``sta_mem_dep_ii``/``pipeline_fill``, LSQ forces burst size 1,
         and FUS1/LSQ never forward — so e.g. a calibration grid over
         ``sta_mem_dep_ii`` x all four systems re-runs only STA,
      4. the ``speculation`` knob folds to ``"-"`` for kernels the
         decoupling pass never marks speculative (``spec_class``) —
         ``"off"`` and ``"auto"`` provably share results there, and
         ``squash_latency`` overrides are projected out with it,
      5. the ``predictor`` knob (and ``spec_runahead`` overrides) fold
         the same way (``predictor_class``/``runahead_class``): they
         only reach a result through a live ``SpecPlan``, so they are
         dead code — and projected out — unless the point speculates.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

from repro.core import programs
from repro.core.dae import PREDICTORS
from repro.core.simulator import SimParams

MODES = ("STA", "LSQ", "FUS1", "FUS2")
ENGINES = ("cycle", "event")
TRACE_MODES = ("auto", "compiled", "interp")
SPECULATIONS = ("off", "auto")

_SIM_FIELDS = tuple(f.name for f in dataclasses.fields(SimParams))

# SimParams fields each mode actually reads (audited against
# simulator._simulate_sta and the two engines; the batch-vs-single
# differential in tests/test_dse.py would catch any drift). The result
# identity of a point projects its overrides onto this set.
# ``squash_latency`` and ``spec_runahead`` are additionally projected
# out unless the point actually speculates
# (``SweepPoint.spec_class == "auto"``) — the engines only read them
# through a live SpecPlan.
_DYN_COMMON = (
    "dram_latency", "burst_timeout", "channel_occupancy", "cu_latency",
    "max_cycles", "fifo_depth", "fifo_latency",
)
_SPEC_FIELDS = ("squash_latency", "spec_runahead")
MODE_SIM_FIELDS = {
    "STA": (
        "dram_latency", "burst_size", "channel_occupancy",
        "pipeline_fill", "sta_mem_dep_ii",
    ),
    "LSQ": _DYN_COMMON + _SPEC_FIELDS,  # burst 1; never forwards
    "FUS1": _DYN_COMMON + ("burst_size",) + _SPEC_FIELDS,
    "FUS2": _DYN_COMMON + ("burst_size", "forward_latency") + _SPEC_FIELDS,
}


def _canon_sim(sim: Union[None, dict, SimParams]) -> tuple:
    """Canonical sorted (field, value) tuple of non-default overrides."""
    if sim is None:
        return ()
    if isinstance(sim, SimParams):
        sim = dataclasses.asdict(sim)
    elif isinstance(sim, (tuple, list)):
        sim = dict(sim)
    default = SimParams()
    out = []
    for k in sorted(sim):
        if k not in _SIM_FIELDS:
            raise ValueError(f"unknown SimParams field {k!r}")
        v = int(sim[k])
        if v != getattr(default, k):
            out.append((k, v))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One simulation configuration of the design space."""

    kernel: str  # a programs.REGISTRY name
    scale: int
    mode: str = "FUS2"
    engine: str = "event"
    trace_mode: str = "auto"
    sim: tuple = ()  # canonical ((field, value), ...) SimParams overrides
    sizing: str = "base"  # display label for the sim overrides
    speculation: str = "off"  # loss-of-decoupling policy (DESIGN.md §10)
    predictor: str = "auto"  # speculative-AGU value predictor (dae.PREDICTORS)
    # hazard-plan variant (DESIGN.md §12): certifier-proven forced-pass
    # pairs dropped before pruning. Results are proven bit-identical to
    # the baseline plan (tests/test_deps.py); the axis exists to A/B
    # planner cost and pair counts at sweep scale
    static_prune: bool = False

    def __post_init__(self):
        assert self.kernel in programs.REGISTRY, f"unknown kernel {self.kernel!r}"
        assert self.mode in MODES, f"unknown mode {self.mode!r}"
        assert self.engine in ENGINES, f"unknown engine {self.engine!r}"
        assert self.trace_mode in TRACE_MODES, (
            f"unknown trace mode {self.trace_mode!r}"
        )
        assert self.speculation in SPECULATIONS, (
            f"unknown speculation mode {self.speculation!r}"
        )
        assert self.predictor in PREDICTORS, (
            f"unknown predictor {self.predictor!r}"
        )
        object.__setattr__(self, "sim", _canon_sim(self.sim))

    def sim_params(self) -> SimParams:
        return dataclasses.replace(SimParams(), **dict(self.sim))

    @property
    def point_id(self) -> tuple:
        return (
            self.kernel, self.scale, self.mode, self.engine,
            self.trace_mode, self.sim, self.speculation, self.predictor,
            self.static_prune,
        )

    @property
    def spec_class(self) -> str:
        """Speculation part of the result identity: ``"-"`` for kernels
        that never speculate (the knob provably cannot change their
        result — ``decouple`` marks no PE, so ``"off"`` and ``"auto"``
        fold together), else the knob value itself."""
        if not programs.REGISTRY[self.kernel].speculative:
            return "-"
        return self.speculation

    @property
    def predictor_class(self) -> str:
        """Predictor part of the result identity: ``"-"`` unless the
        point actually speculates (``spec_class == "auto"``) — on
        everything else the predictor knob is dead code and every value
        folds to one result. STA folds too: the analytical model never
        consults the SpecPlan."""
        if self.mode == "STA" or self.spec_class != "auto":
            return "-"
        return self.predictor

    @property
    def runahead_class(self) -> Union[str, int]:
        """Run-ahead-window part of the result identity: ``"-"`` unless
        the point speculates, else the resolved ``spec_runahead``
        (override or default) — it only reaches a result through a live
        ``SpecPlan`` (``"-"`` for STA, as ``predictor_class``)."""
        if self.mode == "STA" or self.spec_class != "auto":
            return "-"
        sim = dict(self.sim)
        return int(sim.get("spec_runahead", SimParams().spec_runahead))

    @property
    def relevant_sim(self) -> tuple:
        """``sim`` projected onto the fields this point's mode reads
        (``MODE_SIM_FIELDS``) — the SimParams part of the result
        identity. ``squash_latency``/``spec_runahead`` only count when
        the point actually speculates."""
        fields = MODE_SIM_FIELDS[self.mode]
        if self.spec_class != "auto":
            fields = tuple(f for f in fields if f not in _SPEC_FIELDS)
        return tuple((k, v) for k, v in self.sim if k in fields)

    @property
    def prune_class(self) -> str:
        """Hazard-plan-variant part of the result identity: ``"-"`` for
        the baseline plan, ``"prune"`` with ``static_prune``. The
        certifier's drops are *proven* timing-invisible, but unlike the
        registry-metadata folds (``spec_class``) that proof rests on
        the certifier itself — keying the variants separately means a
        certifier bug can never silently serve a baseline cache entry
        for a pruned point (or vice versa). The certifier's code is in
        the cache's ``code_version`` (repro.analysis is hashed), so
        verdict changes invalidate pruned entries wholesale. STA folds
        to ``"-"``: it consumes ``all_pairs``, which static pruning
        provably leaves unchanged (drops land in ``plan.pruned``)."""
        if self.mode == "STA" or not self.static_prune:
            return "-"
        return "prune"

    @property
    def result_key(self) -> tuple:
        """Dedup/cache identity: what the SimResult depends on.

        Excludes ``trace_mode`` entirely, ``engine`` for STA, any
        SimParams override the mode never reads, and folds the
        speculation and predictor knobs for non-speculative kernels
        (``spec_class``/``predictor_class``) — the result-invariances
        the planner exploits (DESIGN.md §9.1). The hazard-plan variant
        travels as ``prune_class``.
        """
        engine_class = "-" if self.mode == "STA" else self.engine
        return (
            self.kernel, self.scale, self.mode, engine_class,
            self.relevant_sim, self.spec_class, self.predictor_class,
            self.prune_class,
        )


@dataclasses.dataclass
class SweepSpec:
    """A grid of sweep points (cross product of the axes).

    ``sizings`` maps a label to ``SimParams`` overrides (a dict of
    field -> value, or a full ``SimParams``); ``{"base": {}}`` is the
    default timing model. ``scales`` maps kernel -> problem scale and
    defaults to each kernel's registered ``default_scale`` divided by
    ``scale_div`` (tests use large divisors to stay tiny). Several
    grids can be stacked via ``extra`` (e.g. an STA-only engine grid);
    duplicate points are dropped at expansion.
    """

    kernels: Sequence[str] = tuple(programs.TABLE1)
    scales: Optional[dict] = None
    scale_div: int = 1
    modes: Sequence[str] = ("STA", "LSQ", "FUS1", "FUS2")
    engines: Sequence[str] = ("event",)
    trace_modes: Sequence[str] = ("auto",)
    sizings: Optional[dict] = None
    # loss-of-decoupling axis: sweeps over speculative kernels need
    # ("auto",) — an "off" point on such a kernel raises exactly like
    # standalone simulate() would
    speculations: Sequence[str] = ("off",)
    # speculative-AGU predictor axis (dae.PREDICTORS); folds to one
    # result for points that never speculate (predictor_class)
    predictors: Sequence[str] = ("auto",)
    # hazard-plan-variant axis (DESIGN.md §12): certifier-dropped
    # forced-pass pairs on/off; results are proven bit-identical, the
    # axis A/Bs planner cost and pair counts
    static_prunes: Sequence[bool] = (False,)
    extra: Sequence["SweepSpec"] = ()

    def points(self) -> list[SweepPoint]:
        sizings = self.sizings if self.sizings is not None else {"base": {}}
        out: list[SweepPoint] = []
        seen: set[tuple] = set()
        for k in self.kernels:
            if self.scales is not None:
                scale = int(self.scales[k])
            else:
                scale = max(programs.REGISTRY[k].default_scale // self.scale_div, 8)
            for mode in self.modes:
                for engine in self.engines:
                    for tm in self.trace_modes:
                        for spec_mode in self.speculations:
                            for pred in self.predictors:
                                for sp in self.static_prunes:
                                    for label, sim in sizings.items():
                                        p = SweepPoint(
                                            kernel=k, scale=scale, mode=mode,
                                            engine=engine, trace_mode=tm,
                                            sim=_canon_sim(sim), sizing=label,
                                            speculation=spec_mode,
                                            predictor=pred,
                                            static_prune=bool(sp),
                                        )
                                        if p.point_id not in seen:
                                            seen.add(p.point_id)
                                            out.append(p)
        for sub in self.extra:
            for p in sub.points():
                if p.point_id not in seen:
                    seen.add(p.point_id)
                    out.append(p)
        return out
