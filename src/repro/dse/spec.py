"""Sweep specification: the configuration grid of a design-space run.

A *sweep point* is one fully specified simulation:
``(kernel, scale, mode, engine, trace_mode, speculation, SimParams
sizing)``. A ``SweepSpec`` expands a grid (or several stacked grids)
into points.

Two distinct notions of identity matter downstream:

  * ``point_id`` — the user-facing identity; every requested point gets
    its own row in the sweep result.
  * ``result_key`` — the *result* identity used for dedup and caching:
    points that provably produce bit-identical ``SimResult``s share it.
    Three result-invariances fold points together (DESIGN.md §9.1):

      1. ``trace_mode`` is excluded entirely (compiled and interpreted
         AGU streams are bit-for-bit equal — the PR-2 contract asserted
         by tests/test_trace_compile.py and tests/test_engine_diff.py),
      2. ``engine`` is excluded for STA (the analytical model never
         runs an engine),
      3. the ``SimParams`` overrides are **projected onto the fields
         the mode actually reads** (``MODE_SIM_FIELDS``): STA never
         reads CU/forwarding latencies, the dynamic engines never read
         ``sta_mem_dep_ii``/``pipeline_fill``, LSQ forces burst size 1,
         and FUS1/LSQ never forward — so e.g. a calibration grid over
         ``sta_mem_dep_ii`` x all four systems re-runs only STA,
      4. the ``speculation`` knob folds to ``"-"`` for kernels the
         decoupling pass never marks speculative (``spec_class``) —
         ``"off"`` and ``"auto"`` provably share results there, and
         ``squash_latency`` overrides are projected out with it,
      5. the ``predictor`` knob (and ``spec_runahead`` overrides) fold
         the same way (``predictor_class``/``runahead_class``): they
         only reach a result through a live ``SpecPlan``, so they are
         dead code — and projected out — unless the point speculates.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

from repro.core import programs
from repro.core.config import ConfigConflict, RunConfig
from repro.core.dae import PREDICTORS
from repro.core.simulator import SimParams

MODES = ("STA", "LSQ", "FUS1", "FUS2")
ENGINES = ("cycle", "event")
TRACE_MODES = ("auto", "compiled", "interp")
SPECULATIONS = ("off", "auto")

# RunConfig fields that never enter the result identity, with the proof
# obligation that keeps them honest (tests/test_config.py pins that
# every RunConfig field is either projected into result_projection()'s
# output or listed here):
#   trace_mode         — compiled/interp streams are bit-equal (PR 2)
#   backend            — numpy/pallas replay the same WavePlan
#                        (tests/test_pallas_parity.py)
#   batch_waves        — batching coarsens steps, never results
#   symbolic_admission — admission fast path emits bit-identical steps
#   validate_hints     — a checker: raises or changes nothing
RESULT_INERT_FIELDS = (
    "trace_mode", "backend", "batch_waves", "symbolic_admission",
    "validate_hints",
)

_SIM_FIELDS = tuple(f.name for f in dataclasses.fields(SimParams))

# SimParams fields each mode actually reads (audited against
# simulator._simulate_sta and the two engines; the batch-vs-single
# differential in tests/test_dse.py would catch any drift). The result
# identity of a point projects its overrides onto this set.
# ``squash_latency`` and ``spec_runahead`` are additionally projected
# out unless the point actually speculates
# (``SweepPoint.spec_class == "auto"``) — the engines only read them
# through a live SpecPlan.
_DYN_COMMON = (
    "dram_latency", "burst_timeout", "channel_occupancy", "cu_latency",
    "max_cycles", "fifo_depth", "fifo_latency",
)
_SPEC_FIELDS = ("squash_latency", "spec_runahead")
MODE_SIM_FIELDS = {
    "STA": (
        "dram_latency", "burst_size", "channel_occupancy",
        "pipeline_fill", "sta_mem_dep_ii",
    ),
    "LSQ": _DYN_COMMON + _SPEC_FIELDS,  # burst 1; never forwards
    "FUS1": _DYN_COMMON + ("burst_size",) + _SPEC_FIELDS,
    "FUS2": _DYN_COMMON + ("burst_size", "forward_latency") + _SPEC_FIELDS,
}


def _canon_sim(sim: Union[None, dict, SimParams]) -> tuple:
    """Canonical sorted (field, value) tuple of non-default overrides."""
    if sim is None:
        return ()
    if isinstance(sim, SimParams):
        sim = dataclasses.asdict(sim)
    elif isinstance(sim, (tuple, list)):
        sim = dict(sim)
    default = SimParams()
    out = []
    for k in sorted(sim):
        if k not in _SIM_FIELDS:
            raise ValueError(f"unknown SimParams field {k!r}")
        v = int(sim[k])
        if v != getattr(default, k):
            out.append((k, v))
    return tuple(out)


# -- the result-identity projection (DESIGN.md §9.1) -------------------------
# Module-level so SweepPoint's properties and result_projection() share
# one implementation: the PR-3 invariances live in exactly one place.


def _spec_class(kernel: str, speculation: str) -> str:
    if not programs.REGISTRY[kernel].speculative:
        return "-"
    return speculation


def _predictor_class(mode: str, spec_cls: str, predictor: str) -> str:
    if mode == "STA" or spec_cls != "auto":
        return "-"
    return predictor


def _runahead_class(mode: str, spec_cls: str, sim: tuple) -> Union[str, int]:
    if mode == "STA" or spec_cls != "auto":
        return "-"
    return int(dict(sim).get("spec_runahead", SimParams().spec_runahead))


def _relevant_sim(mode: str, spec_cls: str, sim: tuple) -> tuple:
    fields = MODE_SIM_FIELDS[mode]
    if spec_cls != "auto":
        fields = tuple(f for f in fields if f not in _SPEC_FIELDS)
    return tuple((k, v) for k, v in sim if k in fields)


def _prune_class(mode: str, static_prune: bool) -> str:
    if mode == "STA" or not static_prune:
        return "-"
    return "prune"


def _merge_config_sim(config: RunConfig, sim) -> tuple:
    """Fold a RunConfig's SimParams overrides into a sizing, canonical
    tuple out; a field explicitly present in both with different values
    raises ``ConfigConflict``."""
    merged = dict(_canon_sim(sim))
    for f, v in config.sim_overrides().items():
        if f in merged and merged[f] != v:
            raise ConfigConflict(
                f"sizing sets {f}={merged[f]} but config=RunConfig "
                f"carries {f}={v}"
            )
        merged[f] = v
    return _canon_sim(merged)


def result_projection(
    kernel: str, scale: int, config: RunConfig, sim=()
) -> tuple:
    """Project one run configuration onto its *result identity* — THE
    single place the DSE dedup key and the on-disk cache key derive
    from a ``RunConfig``.

    ``sim`` carries SimParams overrides (dict / canonical tuple /
    ``SimParams``); the config's non-``None`` sim fields fold in first.
    The output tuple is ``(kernel, scale, mode, engine_class,
    relevant_sim, spec_class, predictor_class, prune_class)`` with the
    PR-3 invariances applied (``SweepPoint.result_key`` delegates
    here; fields listed in ``RESULT_INERT_FIELDS`` are dropped by
    construction).
    """
    sim_t = _merge_config_sim(config, sim)
    spec_cls = _spec_class(kernel, config.speculation)
    return (
        kernel, int(scale), config.mode,
        "-" if config.mode == "STA" else config.engine,
        _relevant_sim(config.mode, spec_cls, sim_t),
        spec_cls,
        _predictor_class(config.mode, spec_cls, config.predictor),
        _prune_class(config.mode, config.static_prune),
    )


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One simulation configuration of the design space."""

    kernel: str  # a programs.REGISTRY name
    scale: int
    mode: str = "FUS2"
    engine: str = "event"
    trace_mode: str = "auto"
    sim: tuple = ()  # canonical ((field, value), ...) SimParams overrides
    sizing: str = "base"  # display label for the sim overrides
    speculation: str = "off"  # loss-of-decoupling policy (DESIGN.md §10)
    predictor: str = "auto"  # speculative-AGU value predictor (dae.PREDICTORS)
    # hazard-plan variant (DESIGN.md §12): certifier-proven forced-pass
    # pairs dropped before pruning. Results are proven bit-identical to
    # the baseline plan (tests/test_deps.py); the axis exists to A/B
    # planner cost and pair counts at sweep scale
    static_prune: bool = False

    def __post_init__(self):
        assert self.kernel in programs.REGISTRY, f"unknown kernel {self.kernel!r}"
        assert self.mode in MODES, f"unknown mode {self.mode!r}"
        assert self.engine in ENGINES, f"unknown engine {self.engine!r}"
        assert self.trace_mode in TRACE_MODES, (
            f"unknown trace mode {self.trace_mode!r}"
        )
        assert self.speculation in SPECULATIONS, (
            f"unknown speculation mode {self.speculation!r}"
        )
        assert self.predictor in PREDICTORS, (
            f"unknown predictor {self.predictor!r}"
        )
        object.__setattr__(self, "sim", _canon_sim(self.sim))

    def sim_params(self) -> SimParams:
        return dataclasses.replace(SimParams(), **dict(self.sim))

    @property
    def point_id(self) -> tuple:
        return (
            self.kernel, self.scale, self.mode, self.engine,
            self.trace_mode, self.sim, self.speculation, self.predictor,
            self.static_prune,
        )

    @property
    def config(self) -> RunConfig:
        """This point's knobs as a ``repro.core.config.RunConfig``.

        SimParams overrides stay in ``self.sim`` (the config's three
        sim-overlap fields remain ``None`` = inherit); the
        executor-only fields keep their defaults — both are result-
        inert here by construction.
        """
        return RunConfig(
            mode=self.mode, engine=self.engine, trace_mode=self.trace_mode,
            speculation=self.speculation, predictor=self.predictor,
            static_prune=self.static_prune,
        )

    @property
    def spec_class(self) -> str:
        """Speculation part of the result identity: ``"-"`` for kernels
        that never speculate (the knob provably cannot change their
        result — ``decouple`` marks no PE, so ``"off"`` and ``"auto"``
        fold together), else the knob value itself."""
        return _spec_class(self.kernel, self.speculation)

    @property
    def predictor_class(self) -> str:
        """Predictor part of the result identity: ``"-"`` unless the
        point actually speculates (``spec_class == "auto"``) — on
        everything else the predictor knob is dead code and every value
        folds to one result. STA folds too: the analytical model never
        consults the SpecPlan."""
        return _predictor_class(self.mode, self.spec_class, self.predictor)

    @property
    def runahead_class(self) -> Union[str, int]:
        """Run-ahead-window part of the result identity: ``"-"`` unless
        the point speculates, else the resolved ``spec_runahead``
        (override or default) — it only reaches a result through a live
        ``SpecPlan`` (``"-"`` for STA, as ``predictor_class``)."""
        return _runahead_class(self.mode, self.spec_class, self.sim)

    @property
    def relevant_sim(self) -> tuple:
        """``sim`` projected onto the fields this point's mode reads
        (``MODE_SIM_FIELDS``) — the SimParams part of the result
        identity. ``squash_latency``/``spec_runahead`` only count when
        the point actually speculates."""
        return _relevant_sim(self.mode, self.spec_class, self.sim)

    @property
    def prune_class(self) -> str:
        """Hazard-plan-variant part of the result identity: ``"-"`` for
        the baseline plan, ``"prune"`` with ``static_prune``. The
        certifier's drops are *proven* timing-invisible, but unlike the
        registry-metadata folds (``spec_class``) that proof rests on
        the certifier itself — keying the variants separately means a
        certifier bug can never silently serve a baseline cache entry
        for a pruned point (or vice versa). The certifier's code is in
        the cache's ``code_version`` (repro.analysis is hashed), so
        verdict changes invalidate pruned entries wholesale. STA folds
        to ``"-"``: it consumes ``all_pairs``, which static pruning
        provably leaves unchanged (drops land in ``plan.pruned``)."""
        return _prune_class(self.mode, self.static_prune)

    @property
    def result_key(self) -> tuple:
        """Dedup/cache identity: what the SimResult depends on.

        Excludes ``trace_mode`` entirely, ``engine`` for STA, any
        SimParams override the mode never reads, and folds the
        speculation and predictor knobs for non-speculative kernels
        (``spec_class``/``predictor_class``) — the result-invariances
        the planner exploits (DESIGN.md §9.1). The hazard-plan variant
        travels as ``prune_class``. Delegates to
        ``result_projection()`` — the one projection implementation.
        """
        return result_projection(self.kernel, self.scale, self.config, self.sim)


@dataclasses.dataclass
class SweepSpec:
    """A grid of sweep points (cross product of the axes).

    ``sizings`` maps a label to ``SimParams`` overrides (a dict of
    field -> value, or a full ``SimParams``); ``{"base": {}}`` is the
    default timing model. ``scales`` maps kernel -> problem scale and
    defaults to each kernel's registered ``default_scale`` divided by
    ``scale_div`` (tests use large divisors to stay tiny). Several
    grids can be stacked via ``extra`` (e.g. an STA-only engine grid);
    duplicate points are dropped at expansion.

    ``config=`` seeds the grid from a ``repro.core.config.RunConfig``:
    every axis left at its default collapses to the config's value
    (``SweepSpec(config=RunConfig(mode="STA"))`` sweeps only STA), an
    explicitly set axis wins unless the config field is *also*
    non-default and absent from the axis — that raises
    ``ConfigConflict``. The config's non-``None``
    ``spec_runahead``/``fifo_depth``/``fifo_latency`` fold into every
    sizing (conflicting sizing values raise).
    """

    kernels: Sequence[str] = tuple(programs.TABLE1)
    scales: Optional[dict] = None
    scale_div: int = 1
    modes: Sequence[str] = ("STA", "LSQ", "FUS1", "FUS2")
    engines: Sequence[str] = ("event",)
    trace_modes: Sequence[str] = ("auto",)
    sizings: Optional[dict] = None
    # loss-of-decoupling axis: sweeps over speculative kernels need
    # ("auto",) — an "off" point on such a kernel raises exactly like
    # standalone simulate() would
    speculations: Sequence[str] = ("off",)
    # speculative-AGU predictor axis (dae.PREDICTORS); folds to one
    # result for points that never speculate (predictor_class)
    predictors: Sequence[str] = ("auto",)
    # hazard-plan-variant axis (DESIGN.md §12): certifier-dropped
    # forced-pass pairs on/off; results are proven bit-identical, the
    # axis A/Bs planner cost and pair counts
    static_prunes: Sequence[bool] = (False,)
    extra: Sequence["SweepSpec"] = ()
    # a RunConfig seeding every defaulted axis (see class docstring)
    config: Optional[RunConfig] = None

    def _axis(self, axis_name: str, cfg_field: str) -> tuple:
        """Resolve one axis against ``self.config`` (see docstring)."""
        val = tuple(getattr(self, axis_name))
        if self.config is None:
            return val
        cfg_v = getattr(self.config, cfg_field)
        if val != tuple(SweepSpec.__dataclass_fields__[axis_name].default):
            cfg_default = RunConfig.__dataclass_fields__[cfg_field].default
            if cfg_v != cfg_default and cfg_v not in val:
                raise ConfigConflict(
                    f"SweepSpec.{axis_name}={val} does not contain the "
                    f"explicit config value {cfg_field}={cfg_v!r}"
                )
            return val
        return (cfg_v,)

    def points(self) -> list[SweepPoint]:
        sizings = self.sizings if self.sizings is not None else {"base": {}}
        if self.config is not None and self.config.sim_overrides():
            sizings = {
                label: dict(_merge_config_sim(self.config, sim))
                for label, sim in sizings.items()
            }
        out: list[SweepPoint] = []
        seen: set[tuple] = set()
        for k in self.kernels:
            if self.scales is not None:
                scale = int(self.scales[k])
            else:
                scale = max(programs.REGISTRY[k].default_scale // self.scale_div, 8)
            for mode in self._axis("modes", "mode"):
                for engine in self._axis("engines", "engine"):
                    for tm in self._axis("trace_modes", "trace_mode"):
                        for spec_mode in self._axis("speculations", "speculation"):
                            for pred in self._axis("predictors", "predictor"):
                                for sp in self._axis("static_prunes", "static_prune"):
                                    for label, sim in sizings.items():
                                        p = SweepPoint(
                                            kernel=k, scale=scale, mode=mode,
                                            engine=engine, trace_mode=tm,
                                            sim=_canon_sim(sim), sizing=label,
                                            speculation=spec_mode,
                                            predictor=pred,
                                            static_prune=bool(sp),
                                        )
                                        if p.point_id not in seen:
                                            seen.add(p.point_id)
                                            out.append(p)
        for sub in self.extra:
            for p in sub.points():
                if p.point_id not in seen:
                    seen.add(p.point_id)
                    out.append(p)
        return out
