"""Sweep-driven calibration of ``SimParams`` against the paper's
per-iteration cycle counts (DESIGN.md §13).

The paper's Table 1 reports per-iteration cycles (cycles / innermost
loop iterations at 286 MHz) for every benchmark under the static
baseline and the fused dynamic design. ``simulator.SimParams`` was
hand-calibrated against those numbers (the ``sta_mem_dep_ii`` comment
in ``simulator.py``); this module replaces the hand fit with a sweep:

  * ``iteration_count()`` measures a kernel's innermost-loop iteration
    total from the oracle walk (one ``trace_hook`` event per iteration
    of the first direct memory op of each innermost loop), so
    *measured* per-iteration cycles are ``SimResult.cycles / iters``;
  * ``calibrate()`` runs ``dse.sweep`` grids over the timing fields
    (``sta_mem_dep_ii`` for the STA targets; ``dram_latency`` x
    ``forward_latency`` for the FUS2 targets) and picks the values
    minimizing the mean relative error against ``STA_TARGETS_CPI`` /
    ``FUS2_TARGETS_CPI`` — the dedup/caching of the DSE engine make
    the grid cheap (STA grids re-run only the analytical model).

``benchmarks/bench_calibrate.py`` runs this at benchmark scale and
writes ``BENCH_CALIB.json`` (fitted fields + per-kernel relative
errors), the committed calibration evidence.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import loopir as ir
from repro.core import programs
from repro.core.simulator import SimParams

# Paper Table-1 per-iteration cycle targets (cycles/iter at 286 MHz)
# for the kernels whose structure this repro reproduces faithfully
# enough to calibrate against. STA targets pin the static memory-
# dependence II (hist+add's ~110 cycles/iter static pipeline is the
# number the original hand calibration in simulator.py cited); FUS2
# targets pin the dynamic path (DRAM round-trip + forwarding).
STA_TARGETS_CPI = {
    "hist+add": 110.0,
    "tanh+spmv": 225.0,
    "pagerank": 200.0,
}
FUS2_TARGETS_CPI = {
    "hist+add": 110.0,
    "tanh+spmv": 47.0,
    "pagerank": 40.0,
}

# default search grids: centred generously around the hand-calibrated
# values so the fit can contradict them (it does: see BENCH_CALIB.json)
STA_II_GRID = (96, 128, 160, 192, 224, 256, 288)
DRAM_GRID = (100, 150, 200, 300, 400)
FWD_GRID = (1, 2, 4)


@dataclasses.dataclass
class CalibResult:
    """Outcome of one ``calibrate()`` fit.

    ``fitted`` maps each swept SimParams field to its error-minimizing
    value; ``params`` is a full ``SimParams`` with the fit applied;
    ``per_field`` records each field's grid and the mean relative
    error at every grid value (the fit curve); ``per_kernel`` the
    per-kernel measured/target per-iteration cycles and relative error
    *at the fitted values*; ``mean_rel_err`` the overall objective at
    the optimum.
    """

    fitted: dict
    params: SimParams
    per_field: dict
    per_kernel: dict
    mean_rel_err: float
    scales: dict = dataclasses.field(default_factory=dict)
    iters: dict = dataclasses.field(default_factory=dict)


def iteration_count(
    program: ir.Program, arrays: dict, params: Optional[dict] = None
) -> int:
    """Total innermost-loop iterations of one program execution.

    Counted exactly from the oracle walk: for each innermost loop (no
    nested ``Loop`` in its body) the first direct memory op fires one
    ``trace_hook`` event per iteration — guard-false stores included —
    so its event count *is* the loop's dynamic iteration total.
    """
    probes: set[str] = set()
    seen_loops: set[int] = set()
    for op, path in program.mem_ops():
        loop = path[-1]
        if any(isinstance(s, ir.Loop) for s in loop.body):
            continue  # op sits directly in a non-innermost loop
        if id(loop) in seen_loops:
            continue
        seen_loops.add(id(loop))
        probes.add(op.id)
    counts = {op_id: 0 for op_id in probes}

    def hook(op_id, addr, is_store, valid, value):
        if op_id in counts:
            counts[op_id] += 1

    work = {k: v.copy() for k, v in arrays.items()}
    ir.interpret(program, work, params or {}, trace_hook=hook)
    return sum(counts.values())


def _cpi_by_kernel(result, iters: dict) -> dict:
    """kernel -> cycles/iteration for one sweep's rows (one row per
    kernel expected)."""
    out = {}
    for row in result.rows():
        out[row["kernel"]] = row["cycles"] / iters[row["kernel"]]
    return out


# a grid value must beat the SimParams default by more than this mean-
# relative-error margin to displace it — a flat fit curve (the field is
# not identified by the targets) keeps the default instead of chasing
# noise (forward_latency is the live example: its curve is flat to
# ~0.3%, see BENCH_CALIB.json)
IDENTIFIABILITY_MARGIN = 0.005


def _fit_axis(
    mode: str,
    targets: dict,
    sizings: dict,
    scales: dict,
    iters: dict,
    cache_dir: Optional[str],
    workers: int,
    default_label: Optional[str] = None,
) -> tuple[str, dict]:
    """Sweep ``sizings`` over ``targets``' kernels in ``mode``; return
    (best sizing label, {label: {"err", "cpi"}}). ``default_label``
    names the sizing equal to the SimParams defaults; it wins unless
    some grid value beats it by ``IDENTIFIABILITY_MARGIN``."""
    from repro.dse import runner
    from repro.dse.spec import SweepSpec

    spec = SweepSpec(
        kernels=tuple(sorted(targets)),
        scales={k: scales[k] for k in targets},
        modes=(mode,),
        sizings=sizings,
    )
    res = runner.sweep(spec, cache_dir=cache_dir, workers=workers)
    by_label: dict = {label: {} for label in sizings}
    for row in res.rows():
        cpi = row["cycles"] / iters[row["kernel"]]
        by_label[row["sizing"]][row["kernel"]] = cpi
    curve = {}
    for label, cpis in by_label.items():
        errs = [
            abs(cpis[k] - targets[k]) / targets[k] for k in sorted(targets)
        ]
        curve[label] = {
            "err": sum(errs) / len(errs),
            "cpi": {k: round(cpis[k], 3) for k in sorted(targets)},
        }
    best = min(sorted(curve), key=lambda l: curve[l]["err"])
    if (
        default_label is not None
        and default_label in curve
        and curve[default_label]["err"]
        <= curve[best]["err"] + IDENTIFIABILITY_MARGIN
    ):
        best = default_label
    return best, curve


def calibrate(
    scales: Optional[dict] = None,
    scale_div: int = 4,
    sta_grid: tuple = STA_II_GRID,
    dram_grid: tuple = DRAM_GRID,
    fwd_grid: tuple = FWD_GRID,
    cache_dir: Optional[str] = None,
    workers: int = 1,
) -> CalibResult:
    """Fit ``sta_mem_dep_ii`` (STA stage) then ``dram_latency`` x
    ``forward_latency`` (FUS2 stage) against the Table-1 per-iteration
    cycle targets, minimizing mean relative error per stage.

    ``scales`` overrides the per-kernel problem scale (default: each
    kernel's ``default_scale // scale_div``); larger scales amortize
    pipeline fill and stabilize cycles/iter. Deterministic: same
    inputs, same fit.
    """
    kernels = sorted(set(STA_TARGETS_CPI) | set(FUS2_TARGETS_CPI))
    if scales is None:
        scales = {
            k: max(programs.REGISTRY[k].default_scale // scale_div, 16)
            for k in kernels
        }
    iters = {}
    for k in kernels:
        program, arrays, params = programs.get(k).make(scales[k])
        iters[k] = iteration_count(program, arrays, params)

    defaults = SimParams()

    # stage 1: STA memory-dependence II (default joins the grid so the
    # identifiability rule can compare against it)
    sta_values = sorted(set(sta_grid) | {defaults.sta_mem_dep_ii})
    sta_sizings = {f"sta_mem_dep_ii={v}": {"sta_mem_dep_ii": v} for v in sta_values}
    sta_best, sta_curve = _fit_axis(
        "STA", STA_TARGETS_CPI, sta_sizings, scales, iters, cache_dir,
        workers,
        default_label=f"sta_mem_dep_ii={defaults.sta_mem_dep_ii}",
    )
    fitted = {"sta_mem_dep_ii": dict(sta_sizings[sta_best])["sta_mem_dep_ii"]}

    # stage 2: dynamic-path latencies (joint grid), II fixed at stage-1
    dyn_sizings = {}
    for d in sorted(set(dram_grid) | {defaults.dram_latency}):
        for f in sorted(set(fwd_grid) | {defaults.forward_latency}):
            dyn_sizings[f"dram_latency={d},forward_latency={f}"] = {
                "dram_latency": d, "forward_latency": f,
            }
    dyn_best, dyn_curve = _fit_axis(
        "FUS2", FUS2_TARGETS_CPI, dyn_sizings, scales, iters, cache_dir,
        workers,
        default_label=(
            f"dram_latency={defaults.dram_latency},"
            f"forward_latency={defaults.forward_latency}"
        ),
    )
    fitted.update(dyn_sizings[dyn_best])

    params = dataclasses.replace(SimParams(), **fitted)
    per_kernel = {}
    errs = []
    for k in kernels:
        per_kernel[k] = {}
        if k in STA_TARGETS_CPI:
            cpi = sta_curve[sta_best]["cpi"][k]
            rel = abs(cpi - STA_TARGETS_CPI[k]) / STA_TARGETS_CPI[k]
            per_kernel[k]["STA"] = {
                "target_cpi": STA_TARGETS_CPI[k], "fitted_cpi": cpi,
                "rel_err": round(rel, 4),
            }
            errs.append(rel)
        if k in FUS2_TARGETS_CPI:
            cpi = dyn_curve[dyn_best]["cpi"][k]
            rel = abs(cpi - FUS2_TARGETS_CPI[k]) / FUS2_TARGETS_CPI[k]
            per_kernel[k]["FUS2"] = {
                "target_cpi": FUS2_TARGETS_CPI[k], "fitted_cpi": cpi,
                "rel_err": round(rel, 4),
            }
            errs.append(rel)
    return CalibResult(
        fitted=fitted,
        params=params,
        per_field={
            "sta_mem_dep_ii": {"best": sta_best, "curve": sta_curve},
            "dram_latency,forward_latency": {
                "best": dyn_best, "curve": dyn_curve,
            },
        },
        per_kernel=per_kernel,
        mean_rel_err=round(sum(errs) / len(errs), 4),
        scales=dict(scales),
        iters=dict(iters),
    )
