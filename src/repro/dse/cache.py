"""On-disk result cache for design-space sweeps (DESIGN.md §9).

One entry per *result key*: a ``SimResult`` stored as an ``.npz``
(final arrays + a JSON metadata member) under a content-addressed file
name. The key hashes everything the result depends on — and nothing it
does not:

  * **code version** — sha256 over the source bytes of ``repro.core``,
    ``repro.analysis`` and ``repro.dse``; any change to the
    simulator/compiler/certifier/DSE code invalidates every entry
    (conservative by design: results are cheap to recompute relative
    to debugging a stale cache),
  * **program** — ``Program.fingerprint()`` (structural IR hash),
  * **data** — array names, dtypes, shapes and bytes; parameter values,
  * **configuration** — mode, engine class (``"-"`` for STA, which has
    no engine), the canonical ``SimParams`` override tuple, and the
    speculation class (``"-"`` for kernels the knob cannot affect).

``trace_mode`` is deliberately absent: compiled and interpreted AGU
streams are bit-identical (the PR-2 contract), so all trace modes share
one entry. Writes are atomic (tmp file + ``os.replace``), so concurrent
sweeps at worst duplicate work, never corrupt entries.

The sweep *journal* (``SweepJournal``) rides alongside the cache: an
append-only ``journal.jsonl`` in the cache directory recording one line
per completed unique run. The npz store stays the source of truth for
resume — the journal exists for observability (what ran, where, how
long) and resume accounting, so a corrupt journal line is skipped and
counted, never fatal (DESIGN.md §13).
"""

from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import os
import tempfile
from typing import Optional

import numpy as np

from repro.core import loopir as ir
from repro.core.simulator import SimResult

_CODE_VERSION: Optional[str] = None

CACHE_FORMAT = 1


def code_version() -> str:
    """sha256 over the repro.core + repro.analysis + repro.dse source
    files (cached)."""
    global _CODE_VERSION
    if _CODE_VERSION is None:
        import repro.analysis
        import repro.core
        import repro.dse

        h = hashlib.sha256()
        for pkg in (repro.core, repro.analysis, repro.dse):
            root = os.path.dirname(pkg.__file__)
            for fn in sorted(os.listdir(root)):
                if fn.endswith(".py"):
                    with open(os.path.join(root, fn), "rb") as f:
                        h.update(fn.encode())
                        h.update(f.read())
        _CODE_VERSION = h.hexdigest()
    return _CODE_VERSION


def result_cache_key(
    program: ir.Program,
    arrays: dict[str, np.ndarray],
    params: dict[str, int],
    mode: str,
    engine_class: str,
    sim: tuple,
    version: Optional[str] = None,
    speculation: str = "-",
    predictor: str = "-",
    static_prune: str = "-",
) -> str:
    """Content hash naming one cache entry (hex sha256).

    ``speculation`` is the point's *spec class* (``SweepPoint.
    spec_class``): ``"-"`` for kernels the knob cannot affect — so
    ``off``/``auto`` share one entry there — else the knob value.
    ``predictor`` is likewise the *predictor class*
    (``SweepPoint.predictor_class``): ``"-"`` unless the point
    actually speculates, else the predictor knob — distinct predictors
    produce distinct gate schedules, hence distinct results. The
    resolved ``spec_runahead`` travels in ``sim`` (``relevant_sim``
    keeps it only for speculating points). ``static_prune`` is the
    *prune class* (``SweepPoint.prune_class``): ``"-"`` for the
    baseline hazard plan (and always for STA), ``"prune"`` when the
    certifier's forced-pass drops are applied — the variants are
    proven bit-identical but keyed separately so a certifier bug can
    never cross-contaminate entries (the certifier code itself is in
    the code version).
    """
    h = hashlib.sha256()
    h.update(f"format={CACHE_FORMAT}\x00".encode())
    h.update((version or code_version()).encode())
    h.update(program.fingerprint().encode())
    for name in sorted(arrays):
        a = np.ascontiguousarray(arrays[name])
        h.update(f"{name}:{a.dtype.str}:{a.shape}\x00".encode())
        h.update(a.tobytes())
    h.update(repr(sorted((params or {}).items())).encode())
    h.update(f"\x00{mode}\x00{engine_class}\x00{sim!r}\x00{speculation}".encode())
    h.update(f"\x00{predictor}".encode())
    h.update(f"\x00{static_prune}".encode())
    return h.hexdigest()


class ResultCache:
    """Directory of ``{key}.npz`` SimResult entries."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(path, exist_ok=True)
        self.hits = 0
        self.misses = 0

    def _file(self, key: str) -> str:
        return os.path.join(self.path, f"{key}.npz")

    def get(self, key: str) -> Optional[SimResult]:
        fn = self._file(key)
        if not os.path.exists(fn):
            self.misses += 1
            return None
        try:
            with np.load(fn, allow_pickle=False) as z:
                meta = json.loads(str(z["__meta__"]))
                arrays = {
                    k[len("A::"):]: z[k] for k in z.files if k.startswith("A::")
                }
        except Exception:
            self.misses += 1  # unreadable/truncated entry: treat as miss
            return None
        self.hits += 1
        return SimResult(
            cycles=meta["cycles"],
            arrays=arrays,
            mode=meta["mode"],
            dram_bursts=meta["dram_bursts"],
            dram_requests=meta["dram_requests"],
            forwards=meta["forwards"],
            squashed=meta.get("squashed", 0),
            fifo_stats=meta.get("fifo_stats", []),
            spec_stats=meta.get("spec_stats", {}),
        )

    def put(self, key: str, result: SimResult) -> None:
        meta = dataclasses.asdict(result)
        meta.pop("arrays")
        buf = io.BytesIO()
        np.savez(
            buf,
            __meta__=np.array(json.dumps(meta)),
            **{f"A::{k}": v for k, v in result.arrays.items()},
        )
        fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(buf.getvalue())
            os.replace(tmp, self._file(key))
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def keys(self) -> set[str]:
        """Keys of every readable-looking entry currently on disk."""
        return {
            fn[:-len(".npz")]
            for fn in os.listdir(self.path)
            if fn.endswith(".npz")
        }


class SweepJournal:
    """Append-only ``journal.jsonl`` next to a sweep's npz cache.

    One JSON object per line, written (with a flush) the moment a
    unique run lands: the cache key, the run's (kernel, scale, mode,
    engine, sizing) coordinates, whether it was a cache hit, and its
    wall time. Readers must tolerate torn tails and garbage — a sweep
    can be SIGKILLed mid-append — so ``load()`` skips-and-counts
    corrupt lines instead of raising (pinned by
    tests/test_sweep_service.py).
    """

    FILENAME = "journal.jsonl"

    def __init__(self, cache_dir: str):
        os.makedirs(cache_dir, exist_ok=True)
        self.path = os.path.join(cache_dir, self.FILENAME)

    def append(self, entry: dict) -> None:
        line = json.dumps(entry, sort_keys=True)
        with open(self.path, "a") as f:
            f.write(line + "\n")
            f.flush()
            os.fsync(f.fileno())

    def load(self) -> tuple[list[dict], int]:
        """(entries, n_corrupt): every parseable line, in order; corrupt
        lines are skipped with a warning and counted."""
        import warnings

        entries: list[dict] = []
        corrupt = 0
        if not os.path.exists(self.path):
            return entries, corrupt
        with open(self.path) as f:
            for i, line in enumerate(f, 1):
                line = line.strip()
                if not line:
                    continue
                try:
                    obj = json.loads(line)
                    if not isinstance(obj, dict):
                        raise ValueError("journal entry is not an object")
                except Exception:
                    corrupt += 1
                    warnings.warn(
                        f"{self.path}:{i}: skipping corrupt journal entry",
                        stacklevel=2,
                    )
                    continue
                entries.append(obj)
        return entries, corrupt
