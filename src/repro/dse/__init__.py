"""Batched design-space exploration over the DU simulator (DESIGN.md §9).

The paper's headline numbers come from sweeping configurations — DU
sizings, schedules, systems — across the nine Table-1 kernels, not from
single points. This package turns the three single-shot layers
(compile front-end, AGU trace compiler, simulator engines) into a
many-point service:

  * ``SweepSpec`` (``dse.spec``) — a grid/list of sweep points:
    kernel × scale × mode × engine × trace_mode × speculation ×
    ``SimParams`` sizing.
  * the planner (``dse.planner``) — groups points by (kernel, scale,
    speculation class), **deduplicates** points whose results are
    provably identical (trace modes produce bit-identical streams; STA
    ignores the engine; the speculation knob folds for kernels that
    never speculate), and builds per-group shared artifacts: one
    compiled trace set (plus its ``speculate.SpecPlan`` when the group
    speculates), one hazard analysis per forwarding class, one hooked
    oracle run, shared §5.6 bit streams / LSQ rank tables, and recorded
    CU scripts replayed per timing point (``dae.ReplayCU``).
  * the runner (``dse.runner``) — exact per-point engine runs on the
    shared artifacts (bit-identical to standalone ``simulate()``),
    optionally parallel across groups, with a config-batched
    forwarding-admissibility profile through ``du.check_pair_batch``.
  * the cache (``dse.cache``) — an on-disk result store keyed by
    (code version, program, arrays, params, mode, engine, sizing) so
    repeated sweeps are incremental, plus the append-only run journal.
  * the sweep service layer (DESIGN.md §13) — ``shard``/
    ``sweep_shard``/``merge_results`` for deterministic multi-host
    partitions, ``sweep(resume=True)`` to restart from the surviving
    cache, ``sweep(on_point=...)``/``iter_points()`` for streaming
    observability, and ``calibrate`` to fit ``SimParams`` against the
    paper's per-iteration cycle targets.

Entry point::

    from repro import dse
    res = dse.sweep(dse.SweepSpec(kernels=["bnn"], modes=["STA", "FUS2"]))
    for row in res.rows():
        print(row["kernel"], row["mode"], row["cycles"])

Evidence: ``benchmarks/sweep.py`` (committed as ``BENCH_DSE.json``)
measures sweep throughput against the looped-``simulate()`` baseline
and re-verifies per-point bit-identity at benchmark scale;
``benchmarks/bench_calibrate.py`` (committed as ``BENCH_CALIB.json``)
records the sweep-driven SimParams fit.
"""

from repro.dse.cache import ResultCache, SweepJournal, code_version
from repro.dse.calibrate import CalibResult, calibrate, iteration_count
from repro.dse.planner import plan
from repro.dse.runner import (
    SweepGroupError,
    SweepResult,
    SweepStats,
    iter_points,
    sweep,
)
from repro.dse.shard import (
    ShardPlan,
    merge_caches,
    merge_results,
    shard_plan,
    sweep_shard,
)
from repro.dse.spec import RESULT_INERT_FIELDS, SweepPoint, SweepSpec, result_projection

__all__ = [
    "SweepPoint",
    "SweepSpec",
    "SweepResult",
    "SweepStats",
    "SweepGroupError",
    "SweepJournal",
    "ShardPlan",
    "CalibResult",
    "RESULT_INERT_FIELDS",
    "ResultCache",
    "calibrate",
    "code_version",
    "iter_points",
    "iteration_count",
    "merge_caches",
    "merge_results",
    "plan",
    "result_projection",
    "shard_plan",
    "sweep",
    "sweep_shard",
]
