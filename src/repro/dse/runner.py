"""Batched sweep runner: exact per-point runs on shared artifacts.

``sweep()`` is the public entry point (re-exported as ``dse.sweep``).
Execution model:

  * the planner collapses the requested points onto unique runs and
    groups them per (kernel, scale);
  * each group builds its shared artifacts once (``GroupContext``) and
    executes its unique runs with ``simulator.simulate_traced`` /
    the engines directly — **bit-identical** to standalone
    ``simulate()`` because every shared artifact is timing-independent
    (DESIGN.md §9; asserted per point by tests/test_dse.py and at
    benchmark scale by benchmarks/sweep.py);
  * a result cache (``dse.cache``) short-circuits runs whose key was
    computed by any previous sweep under the same code version;
  * groups execute in parallel across processes when ``workers > 1``
    (results are deterministic, so the worker count cannot change any
    value);
  * with ``profile=True`` the runner also emits the §5.5
    forwarding-admissibility profile: for every forwarding pair it
    reconstructs each FUS2 config's next-request frontier at the
    consumer's recorded issue cycles and evaluates the forwarding-form
    hazard check for *all configs of the group in one call* through the
    config-batched ``du.check_pair_batch`` (leading config axis).
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Optional, Sequence, Union

import numpy as np

from repro.core import du as dulib
from repro.core import schedule as schedlib
from repro.core import simulator
from repro.dse import cache as cachelib
from repro.dse.planner import Group, GroupContext, UniqueRun, plan
from repro.dse.spec import SweepPoint, SweepSpec

SENTINEL = int(schedlib.SENTINEL)


@dataclasses.dataclass
class PointResult:
    """One sweep point's outcome. ``result.arrays`` may be shared with
    other points deduplicated onto the same unique run — treat results
    as read-only."""

    point: SweepPoint
    result: simulator.SimResult
    run_key: tuple
    cached: bool
    run_wall_s: float


@dataclasses.dataclass
class SweepResult:
    points: list  # [PointResult] aligned with the requested point list
    n_points: int
    n_unique_runs: int
    n_cache_hits: int
    wall_s: float
    groups: list  # per-group {"kernel", "scale", "points", "runs", "wall_s"}
    profile: list  # §5.5 admissibility rows (empty unless profile=True)

    def rows(self) -> list:
        """Flat per-point dict rows (for ``launch.analysis`` helpers)."""
        out = []
        for pr in self.points:
            p, r = pr.point, pr.result
            out.append({
                "kernel": p.kernel, "scale": p.scale, "mode": p.mode,
                "engine": p.engine, "trace_mode": p.trace_mode,
                "sizing": p.sizing, "sim": dict(p.sim),
                "speculation": p.speculation,
                "predictor": p.predictor,
                "static_prune": p.static_prune,
                "cycles": r.cycles, "dram_bursts": r.dram_bursts,
                "dram_requests": r.dram_requests, "forwards": r.forwards,
                "squashed": r.squashed,
                "spec_stats": r.spec_stats,
                "cached": pr.cached, "run_wall_s": pr.run_wall_s,
            })
        return out


# ---------------------------------------------------------------------------
# single-group execution (also the unit of worker parallelism)
# ---------------------------------------------------------------------------


def _frontier_rows(src_state: dict, cyc: np.ndarray):
    """Next-request registers of a source port as of each cycle in
    ``cyc``, reconstructed from its recorded issue cycles (the same
    derivation as ``EventEngine._frontier_at``, §4.2(4) sentinel
    included)."""
    n = len(src_state["addr"])
    depth = src_state["sched"].shape[1] if src_state["sched"].ndim == 2 else 0
    if n == 0:
        m = len(cyc)
        return (
            np.full((m, depth), SENTINEL, dtype=np.int64),
            np.full(m, SENTINEL, dtype=np.int64),
            np.ones((m, depth), dtype=bool),
        )
    nxt = np.searchsorted(src_state["issue_cycle"], cyc, side="right")
    done = nxt >= n
    idx = np.minimum(nxt, n - 1)
    f_sched = np.where(done[:, None], SENTINEL, src_state["sched"][idx])
    f_addr = np.where(done, SENTINEL, src_state["addr"][idx])
    f_last = np.where(done[:, None], True, src_state["lastiter"][idx])
    return f_sched, f_addr, f_last


def _forward_admissibility(ctx: GroupContext, fus2_states: dict) -> list:
    """§5.5 forwarding-slack profile, config-batched.

    ``fus2_states`` maps a config label -> per-op recorded port state of
    one FUS2 event-engine run. For every forwarding pair, each config's
    next-request frontier is reconstructed **one cycle before** each
    consumer request's recorded issue cycle, and the forwarding-form
    hazard check is evaluated for *all configs of the group in one*
    ``check_pair_batch`` call with a leading config axis.

    The returned ``slack_frac`` is the fraction of consumer requests
    that were already §5.5-admissible a cycle before they issued: high
    means the port was paced by II-1/bandwidth/waves (sizing-bound),
    low means issues were released by the hazard check itself
    (dependence-bound) — the attribution a DU-sizing sweep is after.
    """
    rows = []
    labels = sorted(fus2_states)
    if not labels:
        return rows
    for pair in ctx.comp_fwd.plan.pairs:
        if pair.kind != "RAW":
            continue
        dst_tr = ctx.traces[pair.dst]
        src_tr = ctx.traces[pair.src]
        if not src_tr.is_store or dst_tr.n_req == 0:
            continue
        stacked = [
            _frontier_rows(
                fus2_states[c][pair.src],
                fus2_states[c][pair.dst]["issue_cycle"] - 1,
            )
            for c in labels
        ]
        frontier = tuple(
            np.stack([s[j] for s in stacked]) for j in range(3)
        )
        bits = ctx.nodep_bits.get((pair.dst, pair.src))
        ok = dulib.check_pair_batch(
            pair, dst_tr.sched, dst_tr.addr, None, True,
            bits if pair.nodependence else None,
            frontier=frontier,
        )
        ok = np.broadcast_to(ok, (len(labels), dst_tr.n_req))
        rows.append({
            "kernel": ctx.group.kernel,
            "pair": (pair.dst, pair.src),
            "configs": labels,
            "slack_frac": [round(float(r.mean()), 4) for r in ok],
        })
    return rows


def _port_state(port) -> dict:
    return {
        "sched": port.sched, "addr": port.addr, "lastiter": port.lastiter,
        "issue_cycle": port.issue_cycle,
    }


def _execute_run(ctx: GroupContext, run: UniqueRun, validate: bool):
    """Run one unique point exactly; returns (SimResult, port states or
    None). The dispatch mirrors ``simulator.simulate_traced`` — the
    event engine is instantiated directly only to keep its ports for
    the profile."""
    rep = run.rep
    p = rep.sim_params()
    mode = rep.mode
    # prune_class folds STA (and static_prune=False) to the baseline
    # compile, so the pruned variant is built only when a dynamic-mode
    # run actually requests it
    prune = rep.prune_class == "prune"
    shared = ctx.shared_for(mode)
    oracle_loads = ctx.oracle_loads_if(validate and mode != "STA")
    if mode == "STA" or rep.engine == "cycle":
        res = simulator.simulate_traced(
            ctx.comp(mode, static_prune=prune), ctx.traces, ctx.arrays,
            ctx.params, mode=mode,
            sim=p, engine=rep.engine, oracle_loads=oracle_loads,
            shared=shared, spec_plan=ctx.spec_plan,
        )
        return res, None
    from repro.core import engine_event

    ev = engine_event.EventEngine(
        ctx.comp(mode, static_prune=prune), ctx.traces, ctx.arrays,
        ctx.params, mode, p,
        oracle_loads=oracle_loads, shared=shared, spec=ctx.spec_plan,
    )
    res = ev.run()
    states = {op: _port_state(port) for op, port in ev.ports.items()}
    return res, states


def _run_group_task(args):
    """Execute one group (worker-safe: rebuilds everything from names)."""
    (group, trace_modes, cache_dir, validate, profile) = args
    t0 = time.perf_counter()
    ctx = GroupContext(group)
    cache = cachelib.ResultCache(cache_dir) if cache_dir else None
    if "compiled" in trace_modes:
        ctx.check_strict_compiled()
    out: dict[tuple, tuple] = {}
    fus2_states: dict[str, dict] = {}
    profile_skipped: list[str] = []

    def _label(rep):
        # sizing is display-only and may collide across unique runs;
        # disambiguate with the projected sim overrides
        base = f"{rep.sizing}/{rep.engine}"
        if base in fus2_states or base in profile_skipped:
            base = f"{base}{dict(rep.relevant_sim)}"
        return base

    for run in group.runs:
        rep = run.rep
        key = None
        if cache is not None:
            key = cachelib.result_cache_key(
                ctx.program, ctx.arrays, ctx.params, rep.mode,
                "-" if rep.mode == "STA" else rep.engine, rep.relevant_sim,
                speculation=rep.spec_class, predictor=rep.predictor_class,
                static_prune=rep.prune_class,
            )
            # validate=True means "actually check this configuration":
            # cached results carry no validation, so only write-through
            hit = None if (validate and rep.mode != "STA") else cache.get(key)
            if hit is not None:
                out[run.key] = (hit, True, 0.0)
                if profile and rep.mode == "FUS2" and rep.engine == "event":
                    # port states are not cached: this config cannot
                    # appear in the slack profile — surface that
                    profile_skipped.append(_label(rep))
                continue
        t1 = time.perf_counter()
        res, states = _execute_run(ctx, run, validate)
        wall = time.perf_counter() - t1
        if cache is not None:
            cache.put(key, res)
        out[run.key] = (res, False, wall)
        if profile and states is not None and rep.mode == "FUS2":
            fus2_states[_label(rep)] = states
    prof = _forward_admissibility(ctx, fus2_states) if profile else []
    stats = {
        "kernel": group.kernel,
        "scale": group.scale,
        "points": group.n_points,
        "runs": len(group.runs),
        "cache_hits": sum(1 for r in out.values() if r[1]),
        "wall_s": round(time.perf_counter() - t0, 4),
    }
    if profile_skipped:
        stats["profile_skipped"] = profile_skipped
    return out, stats, prof


# ---------------------------------------------------------------------------
# public entry point
# ---------------------------------------------------------------------------


def sweep(
    spec: Union[SweepSpec, Sequence[SweepPoint]],
    *,
    cache_dir: Optional[str] = None,
    workers: int = 1,
    validate: bool = False,
    profile: bool = False,
) -> SweepResult:
    """Run a batched design-space sweep.

    ``spec`` is a ``SweepSpec`` grid or an explicit point list. Every
    requested point receives a ``SimResult`` **bit-identical to a
    standalone** ``simulate(...)`` **call with the same settings** —
    dedup, trace sharing, CU replay, caching and worker parallelism are
    all result-invariant (DESIGN.md §9 states the argument; the
    differential tests enforce it).

    ``cache_dir`` enables the on-disk result cache (repeated sweeps
    only pay for new points); ``workers > 1`` runs groups in parallel
    processes; ``validate`` turns on per-request oracle validation
    inside the engines — and therefore bypasses cache *reads* for the
    dynamic modes (a cached result carries no validation; results are
    still written through); ``profile`` adds the config-batched §5.5
    forwarding-slack rows to ``SweepResult.profile``. The profile is
    built from recorded port states, so it covers only configs that
    actually ran this sweep — FUS2 runs served from the cache are
    listed under ``profile_skipped`` in their group's stats instead.
    """
    t0 = time.perf_counter()
    points = list(spec.points() if isinstance(spec, SweepSpec) else spec)
    groups = plan(points)
    tasks = []
    for g in groups:
        tms = {
            points[i].trace_mode for r in g.runs for i in r.point_indices
        }
        tasks.append((g, tms, cache_dir, validate, profile))

    if workers > 1 and len(tasks) > 1:
        import concurrent.futures as cf
        import multiprocessing as mp

        n = min(workers, len(tasks), os.cpu_count() or 1)
        # spawn, not fork: parent processes may hold multithreaded
        # runtimes (JAX) that are not fork-safe
        with cf.ProcessPoolExecutor(
            max_workers=n, mp_context=mp.get_context("spawn")
        ) as ex:
            outcomes = list(ex.map(_run_group_task, tasks))
    else:
        outcomes = [_run_group_task(t) for t in tasks]

    by_key: dict[tuple, tuple] = {}
    group_stats = []
    profile_rows: list = []
    for g, (out, stats, prof) in zip(groups, outcomes):
        by_key.update(out)
        group_stats.append(stats)
        profile_rows.extend(prof)

    results: list[Optional[PointResult]] = [None] * len(points)
    for g in groups:
        for run in g.runs:
            res, cached, wall = by_key[run.key]
            for i in run.point_indices:
                results[i] = PointResult(
                    point=points[i], result=res, run_key=run.key,
                    cached=cached, run_wall_s=wall,
                )
    return SweepResult(
        points=results,
        n_points=len(points),
        n_unique_runs=sum(len(g.runs) for g in groups),
        n_cache_hits=sum(s["cache_hits"] for s in group_stats),
        wall_s=time.perf_counter() - t0,
        groups=group_stats,
        profile=profile_rows,
    )
