"""Batched sweep runner: exact per-point runs on shared artifacts.

``sweep()`` is the public entry point (re-exported as ``dse.sweep``).
Execution model:

  * the planner collapses the requested points onto unique runs and
    groups them per (kernel, scale);
  * each group builds its shared artifacts once (``GroupContext``) and
    executes its unique runs with ``simulator.simulate_traced`` /
    the engines directly — **bit-identical** to standalone
    ``simulate()`` because every shared artifact is timing-independent
    (DESIGN.md §9; asserted per point by tests/test_dse.py and at
    benchmark scale by benchmarks/sweep.py);
  * a result cache (``dse.cache``) short-circuits runs whose key was
    computed by any previous sweep under the same code version;
  * groups execute in parallel across processes when ``workers > 1``
    (results are deterministic, so the worker count cannot change any
    value);
  * with ``profile=True`` the runner also emits the §5.5
    forwarding-admissibility profile: for every forwarding pair it
    reconstructs each FUS2 config's next-request frontier at the
    consumer's recorded issue cycles and evaluates the forwarding-form
    hazard check for *all configs of the group in one call* through the
    config-batched ``du.check_pair_batch`` (leading config axis).

Service features (DESIGN.md §13):

  * **streaming** — ``sweep(on_point=...)`` / ``iter_points()`` deliver
    ``PointResult`` rows the moment their group completes (completion
    order; the final ``SweepResult`` stays in canonical order);
  * **resume** — ``sweep(resume=True, cache_dir=...)`` re-plans from
    the surviving npz cache: only cache-missing runs execute, and the
    journal (``cache.SweepJournal``) supplies interrupted-run
    accounting (``SweepStats``). The cache is the source of truth;
    corrupt journal lines are skipped-and-counted, never fatal;
  * **sharding** — ``sweep(shard=(i, n))`` executes only shard *i* of
    the deterministic ``dse.shard`` partition; ``merge_results()``
    reassembles the single-host result bit-identically;
  * **retry** — transient worker failures (``OSError``, a broken
    process pool) are retried with exponential backoff instead of
    aborting; a persistent or non-transient failure raises
    ``SweepGroupError`` naming the (kernel, scale, spec_class) group
    and the surviving-cache state.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Optional, Sequence, Union

import numpy as np

from repro.core import du as dulib
from repro.core import schedule as schedlib
from repro.core import simulator
from repro.dse import cache as cachelib
from repro.dse.planner import Group, GroupContext, UniqueRun, plan
from repro.dse.spec import SweepPoint, SweepSpec

SENTINEL = int(schedlib.SENTINEL)


@dataclasses.dataclass
class PointResult:
    """One sweep point's outcome. ``result.arrays`` may be shared with
    other points deduplicated onto the same unique run — treat results
    as read-only."""

    point: SweepPoint
    result: simulator.SimResult
    run_key: tuple
    cached: bool
    run_wall_s: float


@dataclasses.dataclass
class SweepStats:
    """Per-sweep progress/retry/timing counters (DESIGN.md §13).

    For a sharded run ``shard=(i, n)`` identifies the slice and every
    counter covers only the shard's own groups, so
    ``shard.merge_results`` can sum them back to the single-host
    numbers. ``n_resumed_runs`` counts cache hits under ``resume=True``
    (runs a previous, possibly killed, sweep already paid for);
    ``journal_entries``/``journal_corrupt`` report what the journal
    held at resume time. ``retries`` logs one dict per transient
    worker failure that was retried ({"group", "attempt", "error",
    "backoff_s"}).
    """

    n_groups: int = 0
    n_points: int = 0
    n_unique_runs: int = 0
    n_cache_hits: int = 0
    n_executed: int = 0
    n_retries: int = 0
    retries: list = dataclasses.field(default_factory=list)
    n_resumed_runs: int = 0
    journal_entries: int = 0
    journal_corrupt: int = 0
    shard: Optional[tuple] = None
    wall_s: float = 0.0


class SweepGroupError(RuntimeError):
    """A sweep group failed permanently.

    The message names the failing (kernel, scale, spec_class) planner
    group, the attempt count, and — when a cache directory is active —
    how many of the group's runs already survive in the cache (so the
    operator knows a ``resume=True`` rerun will skip them). The
    original worker exception is chained as ``__cause__``.
    """


@dataclasses.dataclass
class SweepResult:
    points: list  # [PointResult] aligned with the requested point list
    n_points: int
    n_unique_runs: int
    n_cache_hits: int
    wall_s: float
    groups: list  # per-group {"kernel", "scale", "points", "runs", "wall_s"}
    profile: list  # §5.5 admissibility rows (empty unless profile=True)
    # progress/retry/timing counters; a sharded run marks stats.shard
    # and leaves unowned entries of ``points`` as None
    stats: Optional[SweepStats] = None

    def rows(self) -> list:
        """Flat per-point dict rows (for ``launch.analysis`` helpers).
        Sharded results emit rows only for the shard's own points."""
        out = []
        for pr in self.points:
            if pr is None:
                continue
            p, r = pr.point, pr.result
            out.append({
                "kernel": p.kernel, "scale": p.scale, "mode": p.mode,
                "engine": p.engine, "trace_mode": p.trace_mode,
                "sizing": p.sizing, "sim": dict(p.sim),
                "speculation": p.speculation,
                "predictor": p.predictor,
                "static_prune": p.static_prune,
                "cycles": r.cycles, "dram_bursts": r.dram_bursts,
                "dram_requests": r.dram_requests, "forwards": r.forwards,
                "squashed": r.squashed,
                "spec_stats": r.spec_stats,
                "cached": pr.cached, "run_wall_s": pr.run_wall_s,
            })
        return out


# ---------------------------------------------------------------------------
# single-group execution (also the unit of worker parallelism)
# ---------------------------------------------------------------------------


def _frontier_rows(src_state: dict, cyc: np.ndarray):
    """Next-request registers of a source port as of each cycle in
    ``cyc``, reconstructed from its recorded issue cycles (the same
    derivation as ``EventEngine._frontier_at``, §4.2(4) sentinel
    included)."""
    n = len(src_state["addr"])
    depth = src_state["sched"].shape[1] if src_state["sched"].ndim == 2 else 0
    if n == 0:
        m = len(cyc)
        return (
            np.full((m, depth), SENTINEL, dtype=np.int64),
            np.full(m, SENTINEL, dtype=np.int64),
            np.ones((m, depth), dtype=bool),
        )
    nxt = np.searchsorted(src_state["issue_cycle"], cyc, side="right")
    done = nxt >= n
    idx = np.minimum(nxt, n - 1)
    f_sched = np.where(done[:, None], SENTINEL, src_state["sched"][idx])
    f_addr = np.where(done, SENTINEL, src_state["addr"][idx])
    f_last = np.where(done[:, None], True, src_state["lastiter"][idx])
    return f_sched, f_addr, f_last


def _forward_admissibility(ctx: GroupContext, fus2_states: dict) -> list:
    """§5.5 forwarding-slack profile, config-batched.

    ``fus2_states`` maps a config label -> per-op recorded port state of
    one FUS2 event-engine run. For every forwarding pair, each config's
    next-request frontier is reconstructed **one cycle before** each
    consumer request's recorded issue cycle, and the forwarding-form
    hazard check is evaluated for *all configs of the group in one*
    ``check_pair_batch`` call with a leading config axis.

    The returned ``slack_frac`` is the fraction of consumer requests
    that were already §5.5-admissible a cycle before they issued: high
    means the port was paced by II-1/bandwidth/waves (sizing-bound),
    low means issues were released by the hazard check itself
    (dependence-bound) — the attribution a DU-sizing sweep is after.
    """
    rows = []
    labels = sorted(fus2_states)
    if not labels:
        return rows
    for pair in ctx.comp_fwd.plan.pairs:
        if pair.kind != "RAW":
            continue
        dst_tr = ctx.traces[pair.dst]
        src_tr = ctx.traces[pair.src]
        if not src_tr.is_store or dst_tr.n_req == 0:
            continue
        stacked = [
            _frontier_rows(
                fus2_states[c][pair.src],
                fus2_states[c][pair.dst]["issue_cycle"] - 1,
            )
            for c in labels
        ]
        frontier = tuple(
            np.stack([s[j] for s in stacked]) for j in range(3)
        )
        bits = ctx.nodep_bits.get((pair.dst, pair.src))
        ok = dulib.check_pair_batch(
            pair, dst_tr.sched, dst_tr.addr, None, True,
            bits if pair.nodependence else None,
            frontier=frontier,
        )
        ok = np.broadcast_to(ok, (len(labels), dst_tr.n_req))
        rows.append({
            "kernel": ctx.group.kernel,
            "pair": (pair.dst, pair.src),
            "configs": labels,
            "slack_frac": [round(float(r.mean()), 4) for r in ok],
        })
    return rows


def _port_state(port) -> dict:
    return {
        "sched": port.sched, "addr": port.addr, "lastiter": port.lastiter,
        "issue_cycle": port.issue_cycle,
    }


def _execute_run(ctx: GroupContext, run: UniqueRun, validate: bool):
    """Run one unique point exactly; returns (SimResult, port states or
    None). The dispatch mirrors ``simulator.simulate_traced`` — the
    event engine is instantiated directly only to keep its ports for
    the profile."""
    rep = run.rep
    p = rep.sim_params()
    mode = rep.mode
    # prune_class folds STA (and static_prune=False) to the baseline
    # compile, so the pruned variant is built only when a dynamic-mode
    # run actually requests it
    prune = rep.prune_class == "prune"
    shared = ctx.shared_for(mode)
    oracle_loads = ctx.oracle_loads_if(validate and mode != "STA")
    if mode == "STA" or rep.engine == "cycle":
        res = simulator.simulate_traced(
            ctx.comp(mode, static_prune=prune), ctx.traces, ctx.arrays,
            ctx.params, mode=mode,
            sim=p, engine=rep.engine, oracle_loads=oracle_loads,
            shared=shared, spec_plan=ctx.spec_plan,
        )
        return res, None
    from repro.core import engine_event

    ev = engine_event.EventEngine(
        ctx.comp(mode, static_prune=prune), ctx.traces, ctx.arrays,
        ctx.params, mode, p,
        oracle_loads=oracle_loads, shared=shared, spec=ctx.spec_plan,
    )
    res = ev.run()
    states = {op: _port_state(port) for op, port in ev.ports.items()}
    return res, states


def _run_group_task(args):
    """Execute one group (worker-safe: rebuilds everything from names).

    ``differential`` is the batch-vs-single per-request oracle check
    (the knob ``sweep()`` exposes as ``differential=``)."""
    (group, trace_modes, cache_dir, differential, profile) = args
    validate = differential
    t0 = time.perf_counter()
    ctx = GroupContext(group)
    cache = cachelib.ResultCache(cache_dir) if cache_dir else None
    if "compiled" in trace_modes:
        ctx.check_strict_compiled()
    out: dict[tuple, tuple] = {}
    fus2_states: dict[str, dict] = {}
    profile_skipped: list[str] = []

    def _label(rep):
        # sizing is display-only and may collide across unique runs;
        # disambiguate with the projected sim overrides
        base = f"{rep.sizing}/{rep.engine}"
        if base in fus2_states or base in profile_skipped:
            base = f"{base}{dict(rep.relevant_sim)}"
        return base

    for run in group.runs:
        rep = run.rep
        key = None
        if cache is not None:
            key = cachelib.result_cache_key(
                ctx.program, ctx.arrays, ctx.params, rep.mode,
                "-" if rep.mode == "STA" else rep.engine, rep.relevant_sim,
                speculation=rep.spec_class, predictor=rep.predictor_class,
                static_prune=rep.prune_class,
            )
            # validate=True means "actually check this configuration":
            # cached results carry no validation, so only write-through
            hit = None if (validate and rep.mode != "STA") else cache.get(key)
            if hit is not None:
                out[run.key] = (hit, True, 0.0, key)
                if profile and rep.mode == "FUS2" and rep.engine == "event":
                    # port states are not cached: this config cannot
                    # appear in the slack profile — surface that
                    profile_skipped.append(_label(rep))
                continue
        t1 = time.perf_counter()
        res, states = _execute_run(ctx, run, validate)
        wall = time.perf_counter() - t1
        if cache is not None:
            cache.put(key, res)
        out[run.key] = (res, False, wall, key)
        if profile and states is not None and rep.mode == "FUS2":
            fus2_states[_label(rep)] = states
    prof = _forward_admissibility(ctx, fus2_states) if profile else []
    for row in prof:
        row["class_key"] = group.class_key
    stats = {
        "kernel": group.kernel,
        "scale": group.scale,
        # planner identity — shard.merge_results sorts merged group
        # stats by it to restore the canonical single-host order
        "class_key": group.class_key,
        "points": group.n_points,
        "runs": len(group.runs),
        "cache_hits": sum(1 for r in out.values() if r[1]),
        "wall_s": round(time.perf_counter() - t0, 4),
    }
    if profile_skipped:
        stats["profile_skipped"] = profile_skipped
    return out, stats, prof


# ---------------------------------------------------------------------------
# task execution with retry
# ---------------------------------------------------------------------------


def _surviving_cache_note(group: Group, cache_dir: Optional[str]) -> str:
    """How many of ``group``'s runs already survive in the cache —
    computed defensively: this runs inside failure handling and must
    never mask the original error."""
    if not cache_dir:
        return ""
    try:
        from repro.core import programs

        program, arrays, params = programs.get(group.kernel).make(group.scale)
        cache = cachelib.ResultCache(cache_dir)
        n = sum(
            1
            for run in group.runs
            if os.path.exists(cache._file(cachelib.result_cache_key(
                program, arrays, params, run.rep.mode,
                "-" if run.rep.mode == "STA" else run.rep.engine,
                run.rep.relevant_sim, speculation=run.rep.spec_class,
                predictor=run.rep.predictor_class,
                static_prune=run.rep.prune_class,
            )))
        )
        return (
            f"; surviving cache: {n}/{len(group.runs)} of the group's runs "
            f"already stored under {cache_dir!r} — a resume=True rerun "
            f"skips them"
        )
    except Exception:
        return f"; surviving-cache state unavailable (cache_dir={cache_dir!r})"


def _group_error(
    task, exc: BaseException, attempts: int, cache_dir: Optional[str]
) -> SweepGroupError:
    group = task[0]
    spec_cls = group.class_key[2] if group.class_key else group.speculation
    return SweepGroupError(
        f"sweep group (kernel={group.kernel!r}, scale={group.scale}, "
        f"spec_class={spec_cls!r}) failed after {attempts} attempt(s): "
        f"{type(exc).__name__}: {exc}"
        + _surviving_cache_note(group, cache_dir)
    )


def _transient_types() -> tuple:
    from concurrent.futures.process import BrokenProcessPool

    return (OSError, BrokenProcessPool)


def _execute_tasks(
    tasks: list, workers: int, stats: SweepStats, retries: int,
    backoff_s: float, cache_dir: Optional[str],
):
    """Yield ``(task_index, (out, gstats, prof))`` in completion order.

    Transient failures (``OSError``, a broken spawn pool) are retried
    up to ``retries`` times with exponential backoff — the pool is
    recreated each round, so a poisoned worker process cannot sink
    every remaining group. Anything else (or exhausted retries) raises
    ``SweepGroupError`` naming the group, chained from the original.
    """
    transient = _transient_types()
    pending = list(range(len(tasks)))
    attempt = 0
    while pending:
        failures: list[tuple[int, BaseException]] = []
        if workers > 1 and len(pending) > 1:
            import concurrent.futures as cf
            import multiprocessing as mp

            n = min(workers, len(pending), os.cpu_count() or 1)
            # spawn, not fork: parent processes may hold multithreaded
            # runtimes (JAX) that are not fork-safe
            with cf.ProcessPoolExecutor(
                max_workers=n, mp_context=mp.get_context("spawn")
            ) as ex:
                futs = {
                    ex.submit(_run_group_task, tasks[i]): i for i in pending
                }
                for fut in cf.as_completed(futs):
                    i = futs[fut]
                    try:
                        yield i, fut.result()
                    except transient as e:
                        failures.append((i, e))
                    except Exception as e:
                        raise _group_error(
                            tasks[i], e, attempt + 1, cache_dir
                        ) from e
        else:
            for i in pending:
                try:
                    yield i, _run_group_task(tasks[i])
                except transient as e:
                    failures.append((i, e))
                except Exception as e:
                    raise _group_error(
                        tasks[i], e, attempt + 1, cache_dir
                    ) from e
        if not failures:
            return
        attempt += 1
        if attempt > retries:
            i, e = failures[0]
            raise _group_error(tasks[i], e, attempt, cache_dir) from e
        delay = backoff_s * (2 ** (attempt - 1))
        for i, e in failures:
            g = tasks[i][0]
            stats.n_retries += 1
            stats.retries.append({
                "group": (g.kernel, g.scale, g.speculation),
                "attempt": attempt,
                "error": f"{type(e).__name__}: {e}",
                "backoff_s": delay,
            })
        if delay > 0:
            time.sleep(delay)
        pending = [i for i, _ in failures]


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def _sweep_events(
    spec, cache_dir, workers, differential, profile, resume, shard,
    retries, backoff_s,
):
    """Generator core shared by ``sweep()`` and ``iter_points()``:
    yields ``PointResult`` rows as groups complete, returns the final
    ``SweepResult`` (canonical order) as the generator's value."""
    t0 = time.perf_counter()
    points = list(spec.points() if isinstance(spec, SweepSpec) else spec)
    groups = plan(points)
    stats = SweepStats(
        n_points=len(points),
        n_unique_runs=sum(len(g.runs) for g in groups),
    )

    journal = None
    if resume and not cache_dir:
        raise ValueError("resume=True requires cache_dir=")
    if cache_dir:
        journal = cachelib.SweepJournal(cache_dir)
        if resume:
            entries, corrupt = journal.load()
            stats.journal_entries = len(entries)
            stats.journal_corrupt = corrupt

    sel = list(range(len(groups)))
    if shard is not None:
        from repro.dse import shard as shardlib

        idx, n_shards = int(shard[0]), int(shard[1])
        if not (0 <= idx < n_shards):
            raise ValueError(f"shard index {idx} outside 0..{n_shards - 1}")
        sel = shardlib.shard_groups(groups, n_shards).groups_for(idx)
        stats.shard = (idx, n_shards)
        stats.n_points = sum(groups[i].n_points for i in sel)
        stats.n_unique_runs = sum(len(groups[i].runs) for i in sel)
    stats.n_groups = len(sel)

    tasks = []
    for i in sel:
        g = groups[i]
        tms = {points[j].trace_mode for r in g.runs for j in r.point_indices}
        tasks.append((g, tms, cache_dir, differential, profile))

    results: list[Optional[PointResult]] = [None] * len(points)
    outcome_by_task: dict[int, tuple] = {}
    for ti, (out, gstats, prof) in _execute_tasks(
        tasks, workers, stats, retries, backoff_s, cache_dir
    ):
        outcome_by_task[ti] = (out, gstats, prof)
        group = tasks[ti][0]
        for run in group.runs:
            res, cached, wall, key = out[run.key]
            if journal is not None:
                rep = run.rep
                journal.append({
                    "key": key, "kernel": rep.kernel, "scale": rep.scale,
                    "mode": rep.mode, "engine": rep.engine,
                    "sizing": rep.sizing, "cached": bool(cached),
                    "wall_s": round(wall, 4),
                })
            if cached:
                stats.n_cache_hits += 1
                if resume:
                    stats.n_resumed_runs += 1
            else:
                stats.n_executed += 1
            for j in run.point_indices:
                pr = PointResult(
                    point=points[j], result=res, run_key=run.key,
                    cached=cached, run_wall_s=wall,
                )
                results[j] = pr
                yield pr

    # deterministic final assembly: stats/profile in task (= plan) order
    group_stats = [outcome_by_task[ti][1] for ti in range(len(tasks))]
    profile_rows: list = []
    for ti in range(len(tasks)):
        profile_rows.extend(outcome_by_task[ti][2])
    stats.wall_s = time.perf_counter() - t0
    return SweepResult(
        points=results,
        n_points=stats.n_points,
        n_unique_runs=stats.n_unique_runs,
        n_cache_hits=stats.n_cache_hits,
        wall_s=stats.wall_s,
        groups=group_stats,
        profile=profile_rows,
        stats=stats,
    )


def sweep(
    spec: Union[SweepSpec, Sequence[SweepPoint]],
    *,
    cache_dir: Optional[str] = None,
    workers: int = 1,
    differential: bool = False,
    profile: bool = False,
    resume: bool = False,
    on_point=None,
    shard: Optional[tuple] = None,
    retries: int = 2,
    backoff_s: float = 0.25,
    validate: Optional[bool] = None,
) -> SweepResult:
    """Run a batched design-space sweep.

    ``spec`` is a ``SweepSpec`` grid or an explicit point list. Every
    requested point receives a ``SimResult`` **bit-identical to a
    standalone** ``simulate(...)`` **call with the same settings** —
    dedup, trace sharing, CU replay, caching, worker parallelism,
    sharding and resume are all result-invariant (DESIGN.md §9 states
    the argument; the differential tests enforce it).

    ``cache_dir`` enables the on-disk result cache (repeated sweeps
    only pay for new points) and the append-only run journal;
    ``workers > 1`` runs groups in parallel spawn processes;
    ``differential`` turns on per-request oracle validation inside the
    engines — and therefore bypasses cache *reads* for the dynamic
    modes (a cached result carries no validation; results are still
    written through); ``profile`` adds the config-batched §5.5
    forwarding-slack rows to ``SweepResult.profile``. The profile is
    built from recorded port states, so it covers only configs that
    actually ran this sweep — FUS2 runs served from the cache are
    listed under ``profile_skipped`` in their group's stats instead.

    Service knobs (DESIGN.md §13): ``resume=True`` (requires
    ``cache_dir``) re-plans from the surviving cache — only missing
    runs execute, the journal is loaded for accounting and corrupt
    lines are skipped-and-counted; ``on_point`` is called with each
    ``PointResult`` the moment its group completes (completion order);
    ``shard=(i, n)`` executes only shard *i* of the deterministic
    n-way group partition (``dse.shard``); ``retries``/``backoff_s``
    control transient-worker-failure retry.

    ``validate=`` is the deprecated spelling of ``differential=`` (it
    collided with ``simulate(validate=)``, which means oracle *array*
    checking).
    """
    if validate is not None:
        import warnings

        warnings.warn(
            "dse.sweep(validate=) is deprecated: use differential= "
            "(simulate(validate=) means oracle array checking)",
            DeprecationWarning,
            stacklevel=2,
        )
        if differential and differential != validate:
            raise ValueError(
                "both differential= and deprecated validate= were "
                "passed with different values"
            )
        differential = bool(validate)
    gen = _sweep_events(
        spec, cache_dir, workers, differential, profile, resume, shard,
        retries, backoff_s,
    )
    while True:
        try:
            pr = next(gen)
        except StopIteration as stop:
            return stop.value
        if on_point is not None:
            on_point(pr)


def iter_points(
    spec: Union[SweepSpec, Sequence[SweepPoint]],
    *,
    cache_dir: Optional[str] = None,
    workers: int = 1,
    differential: bool = False,
    resume: bool = False,
    shard: Optional[tuple] = None,
    retries: int = 2,
    backoff_s: float = 0.25,
):
    """Generator twin of ``sweep()``: yields each ``PointResult`` as
    its group completes (completion order — deterministic for
    ``workers=1``, interleaved otherwise; the *set* of rows is always
    identical to ``sweep().points``). Use for live dashboards /
    partial Pareto fronts (``launch.analysis.ParetoTracker``) without
    waiting for the full sweep."""
    return (
        pr
        for pr in _sweep_events(
            spec, cache_dir, workers, differential, False, resume, shard,
            retries, backoff_s,
        )
    )
