"""Reproduction of "Dynamic Loop Fusion in High-Level Synthesis" grown
into a jax_pallas system.

Layers (DESIGN.md §1): the paper's compiler + cycle-level DU simulator
(``repro.core``), batched design-space sweeps over it (``repro.dse``),
Pallas kernel adaptations (``repro.kernels``), and the LM
training/serving system those kernels serve (``repro.models``,
``repro.launch``, ``repro.distributed``).
"""
