"""Hazard pair enumeration, check synthesis, and pruning (§5)."""

import pytest

from repro.core import dae as daelib
from repro.core import hazards as hz
from repro.core import monotonic as mono
from repro.core import programs


def _plan(name, scale=16, forwarding=False):
    prog, arrays, params = programs.get(name).make(scale)
    spec = "auto" if programs.get(name).speculative else "off"
    d = daelib.decouple(prog, speculation=spec)
    infos = mono.analyze_program(prog)
    return prog, hz.build_plan(prog, d, infos, forwarding=forwarding)


def test_raw_pair_direction_and_comparator():
    prog, plan = _plan("RAWloop")
    assert len(plan.pairs) == 1
    p = plan.pairs[0]
    assert p.kind == "RAW" and p.dst == "ld_a" and p.src == "st_a"
    # sibling loops: no shared depth, comparator irrelevant; frontier on
    assert p.shared_depth == 0
    assert p.use_frontier  # affine source


def test_war_pair_kept_when_value_independent():
    # WARloop: the A pair (st_a checks ld_a) is kept, because st_a's
    # value does NOT come from ld_a; B is unprotected (single access).
    prog, plan = _plan("WARloop")
    assert len(plan.pairs) == 1
    p = plan.pairs[0]
    assert (p.dst, p.src, p.kind) == ("st_a", "ld_a", "WAR")


def test_intra_loop_war_value_dep_pruned():
    # hist+add: st_h1 value = ld_h1 + 1 -> forward WAR pruned (§5.4.1)
    prog, plan = _plan("hist+add")
    pruned_reasons = {(p.dst, p.src): r for p, r in plan.pruned}
    assert any(
        "write-depends-on-read" in r
        for (d, s), r in pruned_reasons.items()
        if d == "st_h1" and s == "ld_h1"
    )


def test_fft_pair_counts_match_paper_magnitude():
    """Paper Fig. 5: 44 enumerated pairs on the FFT code; pruning removes
    the majority. Our enumeration yields exactly 44; the kept set must be
    well below half (paper reaches 10 with a sharper transitivity
    argument than our conservative backedge-conserving one)."""
    prog, plan = _plan("fft", scale=32)
    total = len(plan.pairs) + len(plan.pruned)
    assert total == 44
    assert len(plan.pruned) >= 10
    assert len(plan.pairs) <= 32


def test_forwarding_restricts_pruning():
    _, plan_nf = _plan("matpower", forwarding=False)
    _, plan_fw = _plan("matpower", forwarding=True)
    # §5.5: with forwarding some WAW prunes become illegal
    assert len(plan_fw.pairs) >= len(plan_nf.pairs)


def test_nodependence_only_intra_pe_monotonic():
    prog, plan = _plan("matpower")
    for p in plan.pairs:
        if p.nodependence:
            assert p.kind == "RAW" and p.same_pe


def test_delta_epoch_semantics():
    # delta=1 only when the deepest non-monotonic depth IS the shared
    # depth (soundness fix validated by the simulator suite)
    for name in programs.all_names():
        _, plan = _plan(name)
        for p in plan.pairs:
            if p.delta == 1:
                assert p.dst_before_src and p.l_depth == p.shared_depth


def test_loads_never_check_loads():
    for name in programs.all_names():
        prog, plan = _plan(name)
        ops = {op.id: op for op, _ in prog.mem_ops()}
        for p in plan.pairs:
            assert ops[p.dst].is_store or ops[p.src].is_store
