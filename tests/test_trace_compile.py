"""Differential conformance for the affine trace compiler (DESIGN.md §7).

The compiled AGU/CU front-end (core/affine.py + schedule.compile_pe_trace
+ dae.VecCU) must be **bit-for-bit** equal to the reference interpreter
(schedule._trace_pe + dae.CU) on every program inside the compiled
subset — sched counters, addresses, lastIter hints, seq numbers, and
declared metadata (depth, is_store). This file pins that contract with:

  * a random-program differential fuzz suite (hypothesis strategies in
    tests/loopir_strategies.py; the nightly CI job raises the example
    budget via HYPOTHESIS_PROFILE=nightly and randomizes the seed),
  * the Table-1 acceptance bar: all nine kernels fully on the compiled
    path under trace_mode="auto",
  * fallback coverage: loop-carried-local addresses (CSR-style row
    pointers, histogram-style bin accumulators), load-dependent
    trips/addresses, sequential ivar recurrences — detected, routed to
    the interpreter under "auto", and rejected with a diagnostic naming
    the offender under "compiled",
  * the zero-trip metadata regression: ops of never-executing loops
    declare the same depth/is_store on both paths.
"""

import numpy as np
import pytest

import loopir_strategies as strat
from repro.core import affine
from repro.core import dae as daelib
from repro.core import loopir as ir
from repro.core import programs
from repro.core import schedule as schedlib
from repro.core import simulator


def _assert_traces_equal(ti, tc, label=""):
    assert set(ti) == set(tc), label
    for op_id in ti:
        a, b = ti[op_id], tc[op_id]
        assert a.pe_id == b.pe_id, (label, op_id)
        assert a.depth == b.depth, (label, op_id, a.depth, b.depth)
        assert a.is_store == b.is_store, (label, op_id)
        np.testing.assert_array_equal(
            a.sched, b.sched, err_msg=f"{label}/{op_id}: sched"
        )
        np.testing.assert_array_equal(
            a.addr, b.addr, err_msg=f"{label}/{op_id}: addr"
        )
        np.testing.assert_array_equal(
            a.lastiter, b.lastiter, err_msg=f"{label}/{op_id}: lastiter"
        )
        np.testing.assert_array_equal(
            a.seq, b.seq, err_msg=f"{label}/{op_id}: seq"
        )
        assert b.sched.shape == (b.n_req, b.depth), (label, op_id)
        assert b.sched.dtype == np.int64 and b.addr.dtype == np.int64
        assert b.lastiter.dtype == np.bool_ and b.seq.dtype == np.int64


# ---------------------------------------------------------------------------
# the differential fuzz suite
# ---------------------------------------------------------------------------


def _check_agu_differential(pap):
    """One generated program: every PE classifies compiled and the
    compiled streams equal the interpreter's exactly."""
    prog, arrays, params = pap
    d = daelib.decouple(prog)
    ti = schedlib.trace_program(prog, d, arrays, params, mode="interp")
    report = {}
    tc = schedlib.trace_program(
        prog, d, arrays, params, mode="compiled", report=report
    )
    assert all(r["path"] == "compiled" for r in report.values())
    _assert_traces_equal(ti, tc)


def _check_cu_differential(pap):
    """Load-free value chains: VecCU's outbox (values, §6 valid bits,
    generation order) must equal the generator CU's, which for load-free
    PEs runs to completion when primed."""
    prog, arrays, params = pap
    d = daelib.decouple(prog)
    for pe in d.pes:
        cls = affine.classify_cu(pe)
        assert cls.compilable, cls.reasons
        gen = daelib.CU(pe, arrays, params)
        assert gen.done and gen.waiting_on is None
        vec = daelib.make_cu(pe, arrays, params)
        assert type(vec).__name__ == "VecCU"
        assert vec.done and vec.waiting_on is None
        assert len(vec.outbox) == len(gen.outbox)
        for i, ((ga, gv, gok), (va, vv, vok)) in enumerate(
            zip(gen.outbox, vec.outbox)
        ):
            assert ga == va, (pe.id, i, ga, va)
            assert gok == vok, (pe.id, i, ga)
            assert gv == vv, (pe.id, i, ga, gv, vv)


# deterministic seeded sweep: always runs (no hypothesis dependency),
# keeping the differential pinned in tier-1 even without the test extra
@pytest.mark.parametrize("seed", range(50))
def test_compiled_trace_equals_interpreter_seeded(seed):
    _check_agu_differential(
        strat.random_affine_program(np.random.default_rng(seed))
    )


@pytest.mark.parametrize("seed", range(30))
def test_vectorized_cu_equals_generator_seeded(seed):
    _check_cu_differential(
        strat.random_loadfree_cu_program(np.random.default_rng(1000 + seed))
    )


if strat.HAVE_HYPOTHESIS:
    from hypothesis import given

    # example budget comes from the active profile (tier1: 60 examples;
    # HYPOTHESIS_PROFILE=nightly: 250) — do not pin @settings here, it
    # would override the nightly budget

    @given(strat.affine_programs())
    def test_compiled_trace_equals_interpreter(pap):
        _check_agu_differential(pap)

    @given(strat.loadfree_cu_programs())
    def test_vectorized_cu_equals_generator(pap):
        _check_cu_differential(pap)


# ---------------------------------------------------------------------------
# Table-1 acceptance: all nine kernels fully compiled under "auto"
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", programs.TABLE1)
def test_table1_kernels_take_compiled_path(name):
    prog, arrays, params = programs.get(name).make(
        32 if name != "fft" else 64
    )
    d = daelib.decouple(prog)
    report = {}
    tc = schedlib.trace_program(
        prog, d, arrays, params, mode="auto", report=report
    )
    assert all(r["path"] == "compiled" for r in report.values()), report
    ti = schedlib.trace_program(prog, d, arrays, params, mode="interp")
    _assert_traces_equal(ti, tc, name)
    # key ORDER must match too: the trace dict's iteration order is the
    # engines' deterministic port-scan order, so a path-dependent order
    # resolves same-cycle ties differently (2-cycle drift on matpower at
    # 8x scale before compile_pe_trace emitted pe.mem_ops order)
    assert list(ti) == list(tc), (name, list(ti), list(tc))


def test_fft_compiles_despite_non_affine_address():
    """The classifier report separates compilability from §3 CR
    affinity: FFT's multiplicative stride is monotonic but *non-affine*
    in the CR sense, yet the trace compiles (vectorizability is the
    broader criterion)."""
    prog, arrays, params = programs.get("fft").make(64)
    d = daelib.decouple(prog)
    report = {}
    schedlib.trace_program(prog, d, arrays, params, mode="auto", report=report)
    affine_flags = [
        v for r in report.values() for v in r["op_affine"].values()
    ]
    assert all(r["path"] == "compiled" for r in report.values())
    assert not any(affine_flags), "fft addresses should be CR-non-affine"
    # while RAWloop's are plainly affine
    prog, arrays, params = programs.get("RAWloop").make(16)
    d = daelib.decouple(prog)
    report = {}
    schedlib.trace_program(prog, d, arrays, params, mode="auto", report=report)
    assert all(
        v for r in report.values() for v in r["op_affine"].values()
    )


# ---------------------------------------------------------------------------
# fallback coverage: detection, auto-routing, and forced-"compiled" errors
# ---------------------------------------------------------------------------


def _csr_local_rowptr():
    """CSR-style SpMV walking the row pointer in a loop-carried local —
    the address is sequential (non-affine) and must route to the
    interpreter."""
    prog = ir.Program(
        "csr_local",
        loops=(
            ir.Loop("i", ir.Param("rows", 0, 8), (
                ir.SetLocal("ptr", ir.Var("i") * 2),
                ir.Loop("k", ir.Const(2), (
                    ir.Load(
                        "ld_rowptr", "vals",
                        ir.Bin("+", ir.Local("ptr"), ir.Var("k")),
                    ),
                    ir.Store(
                        "st_y", "y", ir.Var("i"),
                        ir.LoadVal("ld_rowptr") * 2.0,
                    ),
                )),
            )),
        ),
        params=("rows",),
    )
    rng = np.random.default_rng(0)
    arrays = {"vals": rng.standard_normal(16), "y": np.zeros(8)}
    return prog, arrays, {"rows": 8}


def _hist_local_bin():
    """Histogram whose bin address round-trips through a loop-carried
    local — data-dependent via the local, not a direct gather."""
    prog = ir.Program(
        "hist_local",
        loops=(
            ir.Loop("i", ir.Param("n", 0, 32), (
                ir.SetLocal("bin", ir.Read("d", ir.Var("i"), 0, 7)),
                ir.Load("ld_h", "h", ir.Local("bin")),
                ir.Store(
                    "st_h", "h", ir.Local("bin"), ir.LoadVal("ld_h") + 1.0
                ),
            )),
        ),
        params=("n",),
    )
    rng = np.random.default_rng(1)
    arrays = {"h": np.zeros(8), "d": rng.integers(0, 8, size=32)}
    return prog, arrays, {"n": 32}


@pytest.mark.parametrize(
    "make,offender",
    [(_csr_local_rowptr, "ld_rowptr"), (_hist_local_bin, "ld_h")],
)
def test_local_carried_addresses_fall_back(make, offender):
    prog, arrays, params = make()
    d = daelib.decouple(prog)

    # detection: the classifier names the op and the local
    report = {}
    tc = schedlib.trace_program(
        prog, d, arrays, params, mode="auto", report=report
    )
    bad = [r for r in report.values() if r["path"] == "interp"]
    assert bad, "expected at least one PE on the interpreter path"
    assert any(offender in (r["reason"] or "") for r in bad)
    assert any("local" in (r["reason"] or "") for r in bad)

    # auto == interp exactly (it IS the interpreter for these PEs)
    ti = schedlib.trace_program(prog, d, arrays, params, mode="interp")
    _assert_traces_equal(ti, tc)

    # forcing "compiled" raises a diagnostic naming the offending op
    with pytest.raises(schedlib.TraceCompileError, match=offender):
        schedlib.trace_program(prog, d, arrays, params, mode="compiled")

    # and the full simulation still runs oracle-exact under auto
    oracle = ir.interpret(prog, arrays, params)
    res = simulator.simulate(
        prog, arrays, params, mode="FUS2", validate=True, trace_mode="auto"
    )
    for k in oracle:
        np.testing.assert_allclose(res.arrays[k], oracle[k], atol=1e-12)


def test_load_dependent_trip_is_detected():
    """A trip fed by a protected load value is loss of decoupling: the
    decoupling pass rejects the program outright under the default
    ``speculation="off"`` (any trace mode), names the consuming loop,
    and the affine classifier independently names the load when handed
    such a PE directly. Under ``speculation="auto"`` the same program
    *runs*, oracle-exact (DESIGN.md §10; the deeper coverage lives in
    tests/test_speculation.py)."""
    loops = (
        ir.Loop("i", ir.Param("n", 0, 4), (
            ir.Load("ld_n", "bounds", ir.Var("i")),
            ir.Loop("k", ir.LoadVal("ld_n"), (
                ir.Load("ld_x", "x", ir.Var("k")),
            )),
        )),
    )
    prog = ir.Program("lod", loops=loops, params=("n",))
    arrays = {"bounds": np.ones(4), "x": np.zeros(8)}
    for tm in ("auto", "compiled", "interp"):
        with pytest.raises(
            daelib.LossOfDecoupling, match=r"trip of loop 'k'"
        ):
            simulator.simulate(prog, arrays, {"n": 4}, trace_mode=tm)

    # regression: the previously-rejected program now runs speculatively
    oracle = ir.interpret(prog, arrays, {"n": 4})
    res = simulator.simulate(
        prog, arrays, {"n": 4}, speculation="auto", validate=True
    )
    for k in oracle:
        np.testing.assert_array_equal(res.arrays[k], oracle[k])

    # classifier view, bypassing the decoupling pass
    pe = daelib.PE(id=0, path=(loops[0], loops[0].body[1]))
    pe.stmts = [(loops[0].body[0], 1), (loops[0].body[1].body[0], 2)]
    cls = affine.classify_pe(pe)
    assert not cls.compilable
    assert any("ld_n" in r and "load" in r for r in cls.reasons)


def test_load_dependent_address_is_detected():
    loop = ir.Loop("i", ir.Const(4), (
        ir.Load("ld_a", "x", ir.Var("i")),
        ir.Load("ld_b", "x", ir.LoadVal("ld_a")),
    ))
    pe = daelib.PE(id=0, path=(loop,))
    pe.stmts = [(loop.body[0], 1), (loop.body[1], 1)]
    cls = affine.classify_pe(pe)
    assert not cls.compilable
    assert any("ld_b" in r and "ld_a" in r for r in cls.reasons)


def test_sequential_multiplicative_ivar_falls_back():
    """A '*' ivar whose step varies inside the loop has no closed form;
    auto must route the PE to the interpreter and agree exactly."""
    prog = ir.Program(
        "seqmul",
        loops=(
            ir.Loop(
                "i", ir.Const(5),
                (ir.Load("ld", "x", ir.Var("s")),),
                ivars=(
                    ir.IVar(
                        "s", ir.Const(1), "*",
                        ir.Bin("+", ir.Var("i"), ir.Const(1)),
                    ),
                ),
            ),
        ),
    )
    arrays = {"x": np.zeros(200)}
    d = daelib.decouple(prog)
    report = {}
    tc = schedlib.trace_program(prog, d, arrays, {}, mode="auto", report=report)
    assert report[0]["path"] == "interp"
    assert "s" in report[0]["reason"]
    ti = schedlib.trace_program(prog, d, arrays, {}, mode="interp")
    _assert_traces_equal(ti, tc)
    with pytest.raises(schedlib.TraceCompileError):
        schedlib.trace_program(prog, d, arrays, {}, mode="compiled")


def test_multiplicative_ivar_overflow_falls_back():
    """3**44 wraps int64; the interpreter computes it with Python's
    arbitrary-precision ints. The build-time magnitude bound must route
    such PEs to the interpreter instead of silently diverging."""
    prog = ir.Program(
        "ovf",
        loops=(
            ir.Loop(
                "i", ir.Const(45),
                (ir.Load("ld", "x", ir.Bin("%", ir.Var("s"), ir.Const(10))),),
                ivars=(ir.IVar("s", ir.Const(1), "*", ir.Const(3)),),
            ),
        ),
    )
    arrays = {"x": np.zeros(16)}
    d = daelib.decouple(prog)
    report = {}
    tc = schedlib.trace_program(prog, d, arrays, {}, mode="auto", report=report)
    assert report[0]["path"] == "interp"
    assert "int64" in report[0]["reason"]
    ti = schedlib.trace_program(prog, d, arrays, {}, mode="interp")
    _assert_traces_equal(ti, tc)
    with pytest.raises(schedlib.TraceCompileError, match="int64"):
        schedlib.trace_program(prog, d, arrays, {}, mode="compiled")


def test_additive_ivar_overflow_falls_back():
    prog = ir.Program(
        "ovfadd",
        loops=(
            ir.Loop(
                "i", ir.Const(8),
                (ir.Load("ld", "x", ir.Bin("%", ir.Var("a"), ir.Const(7))),),
                ivars=(
                    ir.IVar("a", ir.Const(0), "+", ir.Read("big", ir.Var("i"))),
                ),
            ),
        ),
    )
    arrays = {
        "x": np.zeros(8),
        # sum = 2^61 exceeds the 2^60 safety bound while each value (and
        # the true running sum) still fits int64 — guard must be
        # conservative, not just catch actual wraps
        "big": np.full(8, 2**58, dtype=np.int64),
    }
    d = daelib.decouple(prog)
    report = {}
    tc = schedlib.trace_program(prog, d, arrays, {}, mode="auto", report=report)
    assert report[0]["path"] == "interp"
    assert "int64" in report[0]["reason"]
    ti = schedlib.trace_program(prog, d, arrays, {}, mode="interp")
    _assert_traces_equal(ti, tc)
    with pytest.raises(schedlib.TraceCompileError, match="int64"):
        schedlib.trace_program(prog, d, arrays, {}, mode="compiled")


def test_float_ivar_accumulation_falls_back_at_build():
    """Classification is structural; non-integer accumulation is only
    visible at build time (array dtypes). auto falls back, compiled
    raises."""
    prog = ir.Program(
        "facc",
        loops=(
            ir.Loop(
                "i", ir.Const(4),
                (ir.Load("ld", "x", ir.Var("a")),),
                ivars=(
                    ir.IVar(
                        "a", ir.Const(0), "+",
                        ir.Read("w", ir.Var("i")),  # float-valued steps
                    ),
                ),
            ),
        ),
    )
    arrays = {"x": np.zeros(64), "w": np.array([1.5, 2.0, 0.5, 3.0])}
    d = daelib.decouple(prog)
    report = {}
    tc = schedlib.trace_program(prog, d, arrays, {}, mode="auto", report=report)
    assert report[0]["path"] == "interp"
    assert "bit-exact" in report[0]["reason"]
    ti = schedlib.trace_program(prog, d, arrays, {}, mode="interp")
    _assert_traces_equal(ti, tc)
    with pytest.raises(schedlib.TraceCompileError, match="bit-exact"):
        schedlib.trace_program(prog, d, arrays, {}, mode="compiled")


# ---------------------------------------------------------------------------
# zero-trip metadata regression (the negative-space fix)
# ---------------------------------------------------------------------------


def _parent_body_prog():
    return ir.Program(
        "zt",
        loops=(
            ir.Loop("i", ir.Param("n", 0, 8), (
                ir.Store("st_pre", "B", ir.Var("i"), ir.Const(1.0)),
                ir.Loop("k", ir.Param("m", 0, 4), (
                    ir.Load("ld_in", "A", ir.Var("k")),
                )),
            )),
        ),
        params=("n", "m"),
    )


@pytest.mark.parametrize("mode", ("interp", "compiled"))
@pytest.mark.parametrize("n,m", [(0, 2), (3, 0), (0, 0)])
def test_zero_trip_ops_declare_static_metadata(mode, n, m):
    """A mem op whose loop never executes must still declare its static
    depth and kind. Previously the interpreter path silently defaulted
    to pe.depth / is_store=False for such ops."""
    prog = _parent_body_prog()
    arrays = {"A": np.zeros(8), "B": np.zeros(8)}
    params = {"n": n, "m": m}
    d = daelib.decouple(prog)
    tr = schedlib.trace_program(prog, d, arrays, params, mode=mode)
    # st_pre is a parent-body op at depth 1 in a depth-2 PE
    assert tr["st_pre"].depth == 1
    assert tr["st_pre"].is_store is True
    assert tr["st_pre"].sched.shape == (n, 1)
    assert tr["ld_in"].depth == 2
    assert tr["ld_in"].is_store is False
    if n == 0:
        assert tr["st_pre"].n_req == 0
    if n == 0 or m == 0:
        assert tr["ld_in"].n_req == 0


@pytest.mark.parametrize("n,m", [(0, 2), (3, 0)])
def test_zero_trip_simulation_still_oracle_exact(n, m):
    prog = _parent_body_prog()
    arrays = {"A": np.zeros(8), "B": np.zeros(8)}
    params = {"n": n, "m": m}
    oracle = ir.interpret(prog, arrays, params)
    for tm in ("interp", "compiled"):
        res = simulator.simulate(
            prog, arrays, params, mode="FUS2", validate=True, trace_mode=tm
        )
        for k in oracle:
            np.testing.assert_allclose(res.arrays[k], oracle[k], atol=1e-12)


# ---------------------------------------------------------------------------
# executor plumbing: trace-driven request stream == oracle-hook stream
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ("bnn", "fft", "hist+add", "tanh+spmv"))
def test_executor_trace_modes_agree(name):
    from repro.core import executor

    prog, arrays, params = programs.get(name).make(
        24 if name != "fft" else 32
    )
    ra = executor.execute(prog, arrays, params, trace_mode="compiled")
    rb = executor.execute(prog, arrays, params, trace_mode="interp")
    assert ra.stats.n_requests == rb.stats.n_requests
    assert ra.stats.n_waves == rb.stats.n_waves
    np.testing.assert_array_equal(ra.waves, rb.waves)
    for k in ra.arrays:
        np.testing.assert_array_equal(ra.arrays[k], rb.arrays[k])
