"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp refs,
over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

KEY = jax.random.PRNGKey(7)


def keys(n):
    return jax.random.split(KEY, n)


# ---------------------------------------------------------------------------
# du_hazard
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "s,d",
    [
        (64, 33),
        pytest.param(1000, 777, marks=pytest.mark.slow),
        pytest.param(257, 512, marks=pytest.mark.slow),
    ],
)
@pytest.mark.parametrize("hi", [10, 500])
def test_du_hazard_sweep(s, d, hi):
    from repro.kernels.du_hazard.ops import hazard_frontier, hazard_frontier_ref

    k1, k2 = keys(2)
    src = jnp.sort(jax.random.randint(k1, (s,), 0, hi))
    dst = jax.random.randint(k2, (d,), 0, hi + 50)
    got = hazard_frontier(src, dst, block_d=64, block_s=128, interpret=True)
    np.testing.assert_array_equal(got, hazard_frontier_ref(src, dst))


@pytest.mark.parametrize("side", ["right", "left"])
def test_du_hazard_side_sweep(side):
    """Hazard merge ("right") includes the equal-address producer;
    strict precedence ("left") counts only strictly-smaller ones."""
    from repro.kernels.du_hazard.ops import hazard_frontier, hazard_frontier_ref

    k1, k2 = keys(2)
    src = jnp.sort(jax.random.randint(k1, (70,), 0, 25))
    dst = jax.random.randint(k2, (41,), 0, 30)
    got = hazard_frontier(src, dst, side=side, block_d=64, block_s=64,
                          interpret=True)
    np.testing.assert_array_equal(got, hazard_frontier_ref(src, dst, side))
    if side == "left":
        right = hazard_frontier_ref(src, dst, "right")
        assert bool(jnp.any(got < right))  # equal addresses exist


@pytest.mark.parametrize("k,s,d", [(3, 40, 30), (6, 129, 77)])
def test_du_hazard_batch_sweep(k, s, d):
    """K independent stream pairs in one launch == K single merges."""
    from repro.kernels.du_hazard.ops import (
        hazard_frontier_batch,
        hazard_frontier_batch_ref,
        hazard_frontier_ref,
    )

    k1, k2 = keys(2)
    src = jnp.sort(jax.random.randint(k1, (k, s), 0, 50), axis=1)
    dst = jax.random.randint(k2, (k, d), 0, 60)
    got = hazard_frontier_batch(src, dst, block_d=64, block_s=64,
                                interpret=True)
    np.testing.assert_array_equal(
        got, hazard_frontier_batch_ref(src, dst)
    )
    for kk in range(k):
        np.testing.assert_array_equal(
            got[kk], hazard_frontier_ref(src[kk], dst[kk])
        )


# ---------------------------------------------------------------------------
# fused_stream (store-to-load forwarding)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "s,d,mem",
    [
        (100, 77, 64),
        pytest.param(512, 333, 256, marks=pytest.mark.slow),
    ],
)
def test_fused_stream_sweep(s, d, mem):
    from repro.kernels.du_hazard.ops import hazard_frontier_ref
    from repro.kernels.fused_stream.ops import fused_raw_loops, fused_stream_ref

    k1, k2, k3, k4 = keys(4)
    src = jnp.sort(jax.random.randint(k1, (s,), 0, mem))
    val = jax.random.normal(k2, (s,))
    dst = jax.random.randint(k3, (d,), 0, mem)
    memory = jax.random.normal(k4, (mem,))
    got_v, got_h = fused_raw_loops(src, val, dst, memory, interpret=True)
    exp_v, exp_h = fused_stream_ref(
        src, val, hazard_frontier_ref(src, dst), dst, memory
    )
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(exp_v), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(got_h), np.asarray(exp_h))


def test_fused_stream_semantics_vs_loop():
    """End-to-end Fig. 1 semantics: fused == sequential loops."""
    from repro.kernels.fused_stream.ops import fused_raw_loops

    rng = np.random.default_rng(0)
    mem0 = rng.standard_normal(32)
    src = np.sort(rng.integers(0, 32, 40))
    val = rng.standard_normal(40)
    dst = rng.integers(0, 32, 25)
    seq_mem = mem0.copy()
    for a, v in zip(src, val):
        seq_mem[a] = v
    expected = seq_mem[dst]
    got, _ = fused_raw_loops(
        jnp.asarray(src), jnp.asarray(val), jnp.asarray(dst),
        jnp.asarray(mem0), interpret=True,
    )
    np.testing.assert_allclose(np.asarray(got), expected, atol=1e-6)


@pytest.mark.parametrize("valid_rate", [1.0, 0.5, 0.0])
def test_fused_stream_guarded_vs_loop(valid_rate):
    """§6 generalization: guard-failed producers forward nothing — the
    bounded lookback skips them. Oracle is an independent sequential
    loop applying only the landed stores."""
    from repro.kernels.fused_stream.ops import fused_raw_loops, min_lookback

    rng = np.random.default_rng(11)
    mem0 = rng.standard_normal(24).astype(np.float32)
    src = np.sort(rng.integers(0, 24, 50))
    val = rng.standard_normal(50).astype(np.float32)
    valid = (rng.random(50) < valid_rate).astype(np.int32)
    dst = rng.integers(0, 24, 37)
    seq = mem0.copy()
    for a, v, ok in zip(src, val, valid):
        if ok:
            seq[a] = v
    lb = min_lookback(src)
    got, hits = fused_raw_loops(
        jnp.asarray(src), jnp.asarray(val), jnp.asarray(dst),
        jnp.asarray(mem0), jnp.asarray(valid), lookback=lb, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(got), seq[dst], atol=1e-6)
    if valid_rate == 0.0:
        assert not np.asarray(hits).any()


def test_min_lookback_runs():
    from repro.kernels.fused_stream.ops import min_lookback

    assert min_lookback(np.array([], dtype=np.int64)) == 1
    assert min_lookback(np.array([1, 2, 3])) == 1
    assert min_lookback(np.array([1, 1, 2, 2, 2, 5])) == 3
    assert min_lookback(np.array([7, 7, 7, 7])) == 4


# ---------------------------------------------------------------------------
# moe_group_mm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "e,din,dout,bt,nb",
    [
        (4, 32, 48, 16, 8),
        pytest.param(8, 16, 16, 8, 16, marks=pytest.mark.slow),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_group_matmul_sweep(e, din, dout, bt, nb, dtype):
    from repro.kernels.moe_group_mm.kernel import group_matmul
    from repro.kernels.moe_group_mm.ref import group_matmul_ref

    k1, k2, k3 = keys(3)
    x = jax.random.normal(k1, (nb * bt, din), dtype)
    w = jax.random.normal(k2, (e, din, dout), dtype) * 0.1
    be = jax.random.randint(k3, (nb,), 0, e).astype(jnp.int32)
    got = group_matmul(x, w, be, block_t=bt, interpret=True)
    exp = group_matmul_ref(x, w, be, block_t=bt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=1e-5)


@pytest.mark.slow
def test_moe_ffn_dropless_vs_dense_oracle():
    from repro.kernels.moe_group_mm.ops import moe_ffn

    k1, k2, k3, k4, k5 = keys(5)
    T, dm, dff, E, K = 24, 16, 32, 4, 2
    x = jax.random.normal(k1, (T, dm))
    logits = jax.random.normal(k2, (T, E))
    wi = jax.random.normal(k3, (E, dm, dff)) * 0.1
    wg = jax.random.normal(k4, (E, dm, dff)) * 0.1
    wo = jax.random.normal(k5, (E, dff, dm)) * 0.1
    out_k = moe_ffn(x, logits, wi, wg, wo, top_k=K, use_kernel=True,
                    block_t=8, interpret=True)
    out_r = moe_ffn(x, logits, wi, wg, wo, top_k=K, use_kernel=False,
                    block_t=8)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=1e-4)

    probs = jax.nn.softmax(logits, -1)
    tp, te = jax.lax.top_k(probs, K)
    tp = tp / tp.sum(-1, keepdims=True)
    dense = np.zeros((T, dm), np.float32)
    for kk in range(K):
        for t in range(T):
            e = int(te[t, kk])
            h = jax.nn.silu(x[t] @ wg[e]) * (x[t] @ wi[e])
            dense[t] += float(tp[t, kk]) * np.asarray(h @ wo[e])
    np.testing.assert_allclose(np.asarray(out_r), dense, atol=1e-3)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "s,d,causal",
    [
        (64, 32, True),
        pytest.param(128, 16, False, marks=pytest.mark.slow),
    ],
)
def test_flash_attention_kernel_sweep(s, d, causal):
    from repro.kernels.attention.ops import flash_attention, flash_attention_ref

    k1, k2, k3 = keys(3)
    q = jax.random.normal(k1, (4, s, d), jnp.float32)
    k = jax.random.normal(k2, (4, s, d), jnp.float32)
    v = jax.random.normal(k3, (4, s, d), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, sm_scale=d ** -0.5,
                          block_q=16, block_k=16, interpret=True)
    exp = flash_attention_ref(q, k, v, causal=causal, sm_scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=1e-4)


def test_decode_attention_kernel():
    from repro.kernels.attention.ops import decode_attention, decode_attention_ref

    k1, k2, k3 = keys(3)
    q = jax.random.normal(k1, (4, 1, 32))
    kc = jax.random.normal(k2, (4, 64, 32))
    vc = jax.random.normal(k3, (4, 64, 32))
    lengths = jnp.array([1, 17, 33, 64])
    got = decode_attention(q, kc, vc, lengths, sm_scale=0.2, block_k=16,
                           interpret=True)
    exp = decode_attention_ref(q, kc, vc, lengths, sm_scale=0.2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=1e-4)


# ---------------------------------------------------------------------------
# csr_spmv + histogram
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,block_r", [(16, 8), (100, 32)])
def test_csr_spmv_sweep(n, block_r):
    from repro.kernels.csr_spmv.ops import spmv_from_csr

    rng = np.random.default_rng(3)
    deg = rng.integers(1, 6, n)
    rp = np.concatenate([[0], np.cumsum(deg)])
    ci = rng.integers(0, n, int(rp[-1]))
    vv = rng.standard_normal(int(rp[-1])).astype(np.float32)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    y = spmv_from_csr(rp, ci, vv, x, block_r=block_r, interpret=True)
    dense = np.zeros((n, n), np.float32)
    for r in range(n):
        for p in range(rp[r], rp[r + 1]):
            dense[r, ci[p]] += vv[p]
    np.testing.assert_allclose(np.asarray(y), dense @ np.asarray(x), atol=1e-4)


@pytest.mark.parametrize("n,bins,block", [(100, 16, 32), (1000, 64, 128)])
def test_histogram_sweep(n, bins, block):
    from repro.kernels.histogram.ops import histogram, histogram_ref

    d = jax.random.randint(keys(1)[0], (n,), 0, bins)
    got = histogram(d, n_bins=bins, block=block, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(histogram_ref(d, n_bins=bins))
    )


def test_hist_add_fused_matches_numpy():
    from repro.kernels.histogram.ops import hist_add

    rng = np.random.default_rng(5)
    d1 = rng.integers(0, 32, 500)
    d2 = rng.integers(0, 32, 500)
    got = hist_add(jnp.asarray(d1), jnp.asarray(d2), n_bins=32,
                   interpret=True)
    exp = np.bincount(d1, minlength=32) + np.bincount(d2, minlength=32)
    np.testing.assert_allclose(np.asarray(got), exp)


# ---------------------------------------------------------------------------
# ssm_scan (fused Mamba selective scan)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("s,di,n,chunk,bd", [(64, 64, 8, 16, 32),
                                             (128, 128, 16, 32, 128)])
def test_ssm_scan_kernel_sweep(s, di, n, chunk, bd):
    from repro.kernels.ssm_scan.ops import ssm_scan, ssm_scan_ref

    k1, k2, k3, k4 = keys(4)
    xi = jax.random.normal(k1, (s, di)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(k2, (s, di)))
    bm = jax.random.normal(k3, (s, n)) * 0.5
    cm = jax.random.normal(k4, (s, n)) * 0.5
    a_neg = -jnp.exp(jax.random.normal(keys(5)[4], (di, n)) * 0.3)
    got = ssm_scan(xi, dt, bm, cm, a_neg, chunk=chunk, block_d=bd,
                   interpret=True)
    exp = ssm_scan_ref(xi, dt, bm, cm, a_neg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.slow
def test_ssm_scan_matches_model_path():
    """The kernel agrees with the model's chunked jnp scan end to end."""
    import dataclasses

    from repro.configs import base as configs
    from repro.kernels.ssm_scan.ops import ssm_scan_batched
    from repro.models import ssm as S
    from repro.models.layers import FP32

    cfg = dataclasses.replace(
        configs.get("falcon-mamba-7b").reduced(), d_model=32, ssm_chunk=16
    )
    di, n = cfg.expand * 32, cfg.ssm_state
    key = jax.random.PRNGKey(9)
    p = S.mamba_init(key, cfg, FP32)
    b, s = 2, 64
    xi = jax.random.normal(key, (b, s, di)) * 0.5

    # model path
    h0 = jnp.zeros((b, di, n), jnp.float32)
    y_model, _ = S._mamba1_chunked(p, xi, cfg, h0, cfg.ssm_chunk)

    # kernel path: same projections
    bc = xi @ p["w_bc"]
    bm, cm = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus(xi @ p["w_dt"] + p["dt_bias"][None, None])
    a_neg = -jnp.exp(p["a_log"])
    y_kern = ssm_scan_batched(
        xi, dt, bm, cm, a_neg, chunk=16, block_d=di, interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(y_kern), np.asarray(y_model), rtol=1e-4, atol=1e-4
    )
