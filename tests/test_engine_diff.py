"""Differential conformance: the vectorized event engine against the
reference cycle engine, across the full Table-1 × mode matrix, plus the
edge cases the wave machinery has to get right (zero-request ops,
sentinel-only streams, §6 misspeculation, §5.5 forwarding hits/misses)
and elementwise scalar-vs-batch hazard-check equivalence.

Contract (see DESIGN.md "Engine conformance"):
  * final arrays: exactly equal (both engines are validated against the
    sequential oracle; the comparison here is engine-vs-engine),
  * cycle counts: equal within CYCLE_TOL relative drift — the event
    engine freezes ACK frontiers over one inter-event gap per wave, so
    port-order ties resolve slightly differently; everything else is
    reconstructed per-cycle and matches exactly.
"""

import numpy as np
import pytest

from repro.core import loopir as ir
from repro.core import programs, simulator

MODES = ("STA", "LSQ", "FUS1", "FUS2")
CYCLE_TOL = 0.02  # documented engine drift envelope (DESIGN.md)
SCALE = 32  # small keeps the cycle-engine half inside the tier-1 budget


def _scale(name):
    return 64 if name == "fft" else SCALE


def _both(prog, arrays, params, mode, sim=None):
    cy = simulator.simulate(
        prog, arrays, params, mode=mode, engine="cycle", sim=sim
    )
    ev = simulator.simulate(
        prog, arrays, params, mode=mode, engine="event", validate=True, sim=sim
    )
    return cy, ev


def _assert_conformant(cy, ev, label=""):
    for k in cy.arrays:
        np.testing.assert_array_equal(
            ev.arrays[k], cy.arrays[k],
            err_msg=f"{label}: engines diverged on array {k}",
        )
    drift = abs(ev.cycles - cy.cycles) / max(cy.cycles, 1)
    assert drift <= CYCLE_TOL, (
        f"{label}: cycle drift {drift:.3%} ({cy.cycles} vs {ev.cycles}) "
        f"exceeds the documented {CYCLE_TOL:.0%} tolerance"
    )


# ---------------------------------------------------------------------------
# the full Table-1 matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", programs.TABLE1)
@pytest.mark.parametrize("mode", MODES)
def test_engines_conform_on_table1(name, mode):
    prog, arrays, params = programs.get(name).make(_scale(name))
    oracle = ir.interpret(prog, arrays, params)
    cy, ev = _both(prog, arrays, params, mode)
    _assert_conformant(cy, ev, f"{name}/{mode}")
    for k in oracle:  # and both match the sequential oracle
        np.testing.assert_allclose(ev.arrays[k], oracle[k], atol=1e-12)
    # same DRAM traffic: the wave engine batches issue, not bursts
    assert ev.dram_requests == cy.dram_requests, (name, mode)
    if mode != "STA":
        assert ev.forwards == cy.forwards, (name, mode)


@pytest.mark.parametrize("name", programs.TABLE1)
@pytest.mark.parametrize("mode", MODES)
def test_trace_modes_agree_on_table1(name, mode):
    """The compiled AGU/CU front-end feeds the engines streams that are
    *exactly* the interpreter's, so simulation results are identical —
    not merely within tolerance: same cycles, same traffic, same final
    arrays (and oracle-exact)."""
    prog, arrays, params = programs.get(name).make(_scale(name))
    oracle = ir.interpret(prog, arrays, params)
    ri = simulator.simulate(
        prog, arrays, params, mode=mode, engine="event", trace_mode="interp"
    )
    rc = simulator.simulate(
        prog, arrays, params, mode=mode, engine="event",
        validate=(mode != "STA"), trace_mode="compiled",
    )
    assert rc.cycles == ri.cycles, (name, mode, ri.cycles, rc.cycles)
    assert rc.dram_requests == ri.dram_requests, (name, mode)
    if mode != "STA":
        assert rc.forwards == ri.forwards, (name, mode)
    for k in oracle:
        np.testing.assert_array_equal(
            rc.arrays[k], ri.arrays[k],
            err_msg=f"{name}/{mode}: trace modes diverged on array {k}",
        )
        np.testing.assert_allclose(rc.arrays[k], oracle[k], atol=1e-12)


# ---------------------------------------------------------------------------
# edge cases
# ---------------------------------------------------------------------------


def _two_loop_raw(n1, n2, mem=32):
    """Producer stores A[idx], consumer loads A[j] and stores B[j]."""
    prog = ir.Program(
        "edge",
        loops=(
            ir.Loop("i", ir.Param("n1", 0, n1), (
                ir.Store("st_a", "A", ir.Var("i"), ir.Read("d", ir.Var("i")) * 2.0),
            )),
            ir.Loop("j", ir.Param("n2", 0, n2), (
                ir.Load("ld_a", "A", ir.Var("j")),
                ir.Store("st_b", "B", ir.Var("j"), ir.LoadVal("ld_a") + 1.0),
            )),
        ),
        params=("n1", "n2"),
    )
    rng = np.random.default_rng(12)
    arrays = {
        "A": np.zeros(mem), "B": np.zeros(mem), "d": rng.standard_normal(mem),
    }
    return prog, arrays, {"n1": n1, "n2": n2}


@pytest.mark.parametrize("mode", ("LSQ", "FUS1", "FUS2"))
def test_zero_request_producer(mode):
    """A zero-trip loop's ports emit only the §4.2(4) sentinel; the
    consumer must drain against the sentinel frontier immediately."""
    prog, arrays, params = _two_loop_raw(0, 16)
    oracle = ir.interpret(prog, arrays, params)
    cy, ev = _both(prog, arrays, params, mode)
    _assert_conformant(cy, ev, f"zero-producer/{mode}")
    np.testing.assert_allclose(ev.arrays["B"], oracle["B"], atol=1e-12)


@pytest.mark.parametrize("mode", ("LSQ", "FUS1", "FUS2"))
def test_zero_request_consumer(mode):
    prog, arrays, params = _two_loop_raw(16, 0)
    cy, ev = _both(prog, arrays, params, mode)
    _assert_conformant(cy, ev, f"zero-consumer/{mode}")


def test_whole_program_sentinel_only():
    """Every loop zero-trip: nothing issues, memory untouched, 0-ish
    cycles on both engines."""
    prog, arrays, params = _two_loop_raw(0, 0)
    cy, ev = _both(prog, arrays, params, "FUS2")
    np.testing.assert_array_equal(ev.arrays["A"], arrays["A"])
    np.testing.assert_array_equal(ev.arrays["B"], arrays["B"])
    assert ev.dram_requests == cy.dram_requests == 0


@pytest.mark.parametrize("mode", ("LSQ", "FUS1", "FUS2"))
@pytest.mark.parametrize("frac", (0.0, 0.5, 1.0))
def test_misspeculated_stores(mode, frac):
    """§6: guarded stores speculate their requests; invalid ones must
    ACK at the pending-buffer head without touching DRAM — including the
    all-invalid case where the whole stream drains without a burst."""
    n = 40
    rng = np.random.default_rng(5)
    v = rng.standard_normal(n)
    # force the guard (v > 0) outcome for a controlled invalid fraction
    v = np.abs(v) if frac == 0.0 else (-np.abs(v) if frac == 1.0 else v)
    prog = ir.Program(
        "spec",
        loops=(
            ir.Loop("i", ir.Param("n", 0, n), (
                ir.Load("ld_v", "v", ir.Var("i")),
                ir.Store(
                    "st_v", "v", ir.Var("i"),
                    ir.Un("tanh", ir.LoadVal("ld_v")),
                    guard=ir.Bin(">", ir.LoadVal("ld_v"), ir.Const(0.0)),
                ),
            )),
            ir.Loop("j", ir.Param("n", 0, n), (
                ir.Load("ld_v2", "v", ir.Var("j")),
                ir.Store("st_o", "o", ir.Var("j"), ir.LoadVal("ld_v2") * 3.0),
            )),
        ),
        params=("n",),
    )
    arrays = {"v": v, "o": np.zeros(n)}
    oracle = ir.interpret(prog, arrays, {"n": n})
    cy, ev = _both(prog, arrays, {"n": n}, mode)
    _assert_conformant(cy, ev, f"misspec/{mode}/{frac}")
    np.testing.assert_allclose(ev.arrays["o"], oracle["o"], atol=1e-12)


def test_forwarding_hits_and_misses():
    """§5.5 hit/miss split: on bnn the producer's pending buffer drains
    while the consumer walks its own sorted stream, so some loads
    forward (hits) and the rest read committed memory (misses). Both
    engines must agree on values AND on the split; latency extremes
    shift the split identically on both."""
    from repro.core.simulator import SimParams

    prog, arrays, params = programs.get("bnn").make(48)
    cy, ev = _both(prog, arrays, params, "FUS2")
    _assert_conformant(cy, ev, "fwd/bnn")
    n_loads = int(np.sum(arrays["rp2"][-1]))
    assert 0 < ev.forwards, "expected at least one forwarding hit"
    assert ev.forwards < n_loads, "expected at least one forwarding miss"
    assert ev.forwards == cy.forwards

    # a much longer DRAM latency keeps entries pending longer: strictly
    # more hits, and the engines still agree
    slow = SimParams(dram_latency=2000)
    cy2, ev2 = _both(prog, arrays, params, "FUS2", sim=slow)
    _assert_conformant(cy2, ev2, "fwd/bnn-slow")
    assert ev2.forwards == cy2.forwards
    assert ev2.forwards > ev.forwards


def test_intra_loop_forwarding_hist():
    """hist-style same-loop RAW (§5.6 NoDependence + forwarding): the
    engines agree on forwards and final bins."""
    prog, arrays, params = programs.get("hist+add").make(96)
    cy, ev = _both(prog, arrays, params, "FUS2")
    _assert_conformant(cy, ev, "hist-intra")
    assert ev.forwards == cy.forwards


# ---------------------------------------------------------------------------
# scalar vs batch hazard-check equivalence (randomized, deterministic rng)
# ---------------------------------------------------------------------------


class _FakePort:
    """Minimal frontier-state stub for check equivalence tests."""

    def __init__(self, depth, f_sched, f_addr, f_last, nxt_sched, no_pend):
        self.depth = depth
        self._f = (tuple(f_sched), int(f_addr), tuple(f_last))
        self._next = tuple(nxt_sched)
        self.no_pending_ack = no_pend

    def frontier(self, use_next_request):
        if use_next_request:
            return self._next, self._f[1], self._f[2]
        return self._f

    def req_sched(self):
        return self._next


def test_check_pair_batch_matches_scalar():
    from repro.core import du as dulib
    from repro.core import hazards as hz

    rng = np.random.default_rng(0)
    SEN = dulib.SENTINEL
    for trial in range(300):
        depth = int(rng.integers(1, 4))
        k = int(rng.integers(0, depth + 1))
        dst_before = bool(rng.integers(2))
        nonmono = sorted(
            int(d) for d in rng.choice(
                range(1, depth + 1),
                size=int(rng.integers(0, depth + 1)), replace=False,
            )
        )
        l_cands = [d for d in nonmono if d <= k]
        pair = hz.HazardPair(
            dst="a", src="b", kind="RAW", array="A",
            shared_depth=k, dst_before_src=dst_before,
            wraparound=False, same_pe=bool(rng.integers(2)),
            use_frontier=bool(rng.integers(2)),
            l_depth=max(l_cands) if l_cands else None,
            lastiter_depths=tuple(d for d in nonmono if d > k),
            nodependence=bool(rng.integers(2)),
        )
        m = int(rng.integers(1, 9))
        req_sched = rng.integers(0, 6, size=(m, depth)).astype(np.int64)
        req_addr = rng.integers(0, 10, size=m).astype(np.int64)
        f_sched = rng.integers(0, 6, size=depth).astype(np.int64)
        if rng.integers(4) == 0:
            f_sched[:] = SEN  # drained-source sentinel
        f_addr = int(rng.integers(-2, 12))
        if rng.integers(4) == 0:
            f_addr = SEN
        f_last = rng.integers(0, 2, size=depth).astype(bool)
        nxt = np.maximum(f_sched, rng.integers(0, 8, size=depth)).astype(np.int64)
        use_next = bool(rng.integers(2))
        no_pend = bool(rng.integers(2))
        src = _FakePort(depth, f_sched, f_addr, f_last, nxt, no_pend)
        bits = rng.integers(0, 2, size=m).astype(bool)

        got = dulib.check_pair_batch(
            pair, req_sched, req_addr, src, use_next,
            bits if pair.nodependence else None,
        )
        for i in range(m):
            exp = dulib.check_pair(
                pair,
                tuple(int(x) for x in req_sched[i]),
                int(req_addr[i]),
                src,
                use_next,
                bool(bits[i]),
            )
            assert bool(got[i]) == exp, (
                f"trial {trial} row {i}: batch={bool(got[i])} scalar={exp} "
                f"pair={pair} req={req_sched[i]} addr={req_addr[i]} "
                f"f=({f_sched},{f_addr},{f_last}) next={nxt} "
                f"no_pend={no_pend} use_next={use_next}"
            )
