"""Cross-PE FIFO streaming suite (core/fifo.py, DESIGN.md §11).

Pins the whole streaming stack end to end:

  * the three registered streaming kernels (``stream_dot``,
    ``filter_pipe``, ``stream_join``) are bit-identical to their
    hand-written numpy oracles under **both** simulator engines
    (cycle + event, all fused modes) and **both** wave backends
    (numpy executor + Pallas ``run_plan``/``run_sequential``),
  * the wave plan's FIFO slot encoding holds its invariants
    (producer-before-consumer per token, bounded backpressure at the
    configured depth) via ``executor.validate_plan`` plus direct
    metadata checks,
  * the token protocol's edge cases: zero-trip producer instances
    still owe a token (the shared-depth init value), depth-1 queues
    ping-pong correctly under real backpressure (stall counters > 0),
    undersized depths and cyclic/backward/rate-mismatched/derived-use
    edge sets are rejected statically with named-edge diagnostics,
  * the diagnostics bugfix sweep: ``LossOfDecoupling`` joins *every*
    reason (not just the first), the simulator's fallback
    ``NotImplementedError`` names the **full** edge list, and
    ``VecCU.feed`` / ``record_cu_script`` raise a typed
    ``CUContractError`` instead of a bare assert,
  * a deterministic seed sweep over ``random_stream_program`` plus the
    hypothesis wrapper (tier1 / nightly profiles, the nightly CI
    stream-fuzz job raises the budget via ``HYPOTHESIS_PROFILE``).
"""

import numpy as np
import pytest

import loopir_strategies as strat
from repro.core import dae as daelib
from repro.core import executor, loopir as ir, programs, simulator
from repro.core import fifo as fifolib
from repro.kernels import wave_exec
from repro.kernels.dynloop import ref

if strat.HAVE_HYPOTHESIS:
    from hypothesis import given

# small scales: the cycle engine and interpret-mode Pallas both run in
# tier-1, so keep the request streams short
SMALL_SCALE = {"stream_dot": 12, "filter_pipe": 48, "stream_join": 32}


def _copies(arrays):
    return {k: v.copy() for k, v in arrays.items()}


def _oracle(name, arrays, params):
    """The hand-written second semantics (kernels/dynloop/ref.py)."""
    if name == "stream_dot":
        return {
            "out": ref.stream_dot_ref(
                arrays["a"], arrays["bv"], arrays["out"],
                params["nb"], params["k"],
            )
        }
    if name == "filter_pipe":
        return {"y": ref.filter_pipe_ref(arrays["x"], arrays["y"])}
    assert name == "stream_join"
    return {"z": ref.stream_join_ref(arrays["u"], arrays["w"], arrays["z"])}


def test_registry_streaming_set():
    assert programs.STREAM_KERNELS == (
        "stream_dot", "filter_pipe", "stream_join"
    )
    for name in programs.STREAM_KERNELS:
        assert programs.get(name).streaming
        assert not programs.get(name).speculative


@pytest.mark.parametrize("name", programs.STREAM_KERNELS)
def test_interpret_matches_handwritten_oracle(name):
    prog, arrays, params = programs.get(name).make(SMALL_SCALE[name])
    got = ir.interpret(prog, _copies(arrays), params)
    for k, v in _oracle(name, arrays, params).items():
        np.testing.assert_array_equal(got[k], v)


# ---------------------------------------------------------------------------
# engine differential: cycle vs event, all fused modes, exact arrays +
# matching cycle counts + balanced queue accounting
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", programs.STREAM_KERNELS)
def test_engines_differential(name):
    prog, arrays, params = programs.get(name).make(SMALL_SCALE[name])
    oracle = _oracle(name, arrays, params)
    for mode in ("LSQ", "FUS1", "FUS2"):
        results = {
            engine: simulator.simulate(
                prog, _copies(arrays), params, mode=mode, engine=engine
            )
            for engine in ("cycle", "event")
        }
        for engine, res in results.items():
            for k, v in oracle.items():
                np.testing.assert_array_equal(
                    res.arrays[k], v,
                    err_msg=f"{name}/{mode}/{engine} diverged ({k})",
                )
            assert res.fifo_stats, f"{name}: no FIFO accounting"
            for qs in res.fifo_stats:
                assert qs["pushed"] == qs["popped"] > 0
                assert qs["max_occupancy"] <= simulator.SimParams().fifo_depth
        assert results["cycle"].cycles == results["event"].cycles, (
            f"{name}/{mode}: engine cycle counts diverged"
        )
        assert (
            results["cycle"].fifo_stats[0]["pushed"]
            == results["event"].fifo_stats[0]["pushed"]
        )


# ---------------------------------------------------------------------------
# wave executor + Pallas backend: slot-encoded FIFO edges
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", programs.STREAM_KERNELS)
def test_wave_backends_differential(name):
    prog, arrays, params = programs.get(name).make(SMALL_SCALE[name])
    oracle = _oracle(name, arrays, params)
    waves_by_depth = {}
    for depth in (1, 2, 4):
        plan = executor.build_wave_plan(
            prog, _copies(arrays), params, fifo_depth=depth
        )
        executor.validate_plan(plan)
        waves_by_depth[depth] = plan.stats.n_waves
        assert plan.fifo_edges, f"{name}: plan lost its FIFO edges"
        for fe in plan.fifo_edges:
            assert fe["depth"] == depth
            assert fe["n_tokens"] > 0
            assert fe["push_op"] in plan.op_ids
            assert fe["pop_op"] in plan.op_ids
        res_np = executor.execute(
            prog, _copies(arrays), params, fifo_depth=depth
        )
        res_pl = wave_exec.run_plan(plan, arrays, interpret=True)
        res_sq = wave_exec.run_sequential(plan, arrays, check=True)
        assert res_pl.complete and res_sq.complete
        for k, v in oracle.items():
            for label, got in (
                ("numpy", res_np.arrays[k]),
                ("pallas", res_pl.arrays[k]),
                ("sequential", res_sq.arrays[k]),
            ):
                np.testing.assert_array_equal(
                    got, v,
                    err_msg=f"{name}@depth={depth}: {label} backend "
                    f"diverged from oracle ({k})",
                )
    # deeper queues can only relax slot WAW/WAR edges -> fewer waves;
    # depth 1 serializes hardest (the ping-pong schedule)
    assert waves_by_depth[1] >= waves_by_depth[2] >= waves_by_depth[4]
    assert waves_by_depth[1] > waves_by_depth[4]


# ---------------------------------------------------------------------------
# token-protocol edge cases
# ---------------------------------------------------------------------------


def _zero_trip_program():
    """Producer leaf trips 1, 0, 0, 0 across the outer instances: the
    zero-trip instances still owe a token (the shared-depth init)."""
    prog = ir.Program(
        "zero_trip_stream",
        loops=(
            ir.Loop("t", ir.Const(4), (
                ir.SetLocal("x", ir.Const(-1.0)),
                ir.Loop("p", ir.Bin("-", ir.Const(1), ir.Var("t")), (
                    ir.Load("ld_d", "d", ir.Var("t")),
                    ir.SetLocal("x", ir.LoadVal("ld_d") + 1.0),
                )),
                ir.Loop("c", ir.Const(1), (
                    ir.Load("ld_o", "o", ir.Var("t")),
                    ir.Store(
                        "st_o", "o", ir.Var("t"),
                        ir.LoadVal("ld_o") + ir.Local("x"),
                    ),
                )),
            )),
        ),
    )
    arrays = {
        "d": np.arange(4, dtype=np.float64),
        "o": np.zeros(4, dtype=np.float64),
    }
    return prog, arrays, {}


def test_zero_trip_producer_still_pushes():
    prog, arrays, params = _zero_trip_program()
    # instance 0 computes d[0]+1; instances 1..3 push the init value
    expect = np.array([1.0, -1.0, -1.0, -1.0])
    got = ir.interpret(prog, _copies(arrays), params)
    np.testing.assert_array_equal(got["o"], expect)
    for engine in ("cycle", "event"):
        res = simulator.simulate(
            prog, _copies(arrays), params, engine=engine
        )
        np.testing.assert_array_equal(res.arrays["o"], expect)
        (qs,) = res.fifo_stats
        assert qs["pushed"] == qs["popped"] == 4
    r = executor.execute(prog, _copies(arrays), params)
    executor.validate_plan(r.plan)
    np.testing.assert_array_equal(r.arrays["o"], expect)
    assert r.plan.fifo_edges[0]["n_tokens"] == 4


def _pingpong_program(n=8):
    """Load-free fast producer feeding a slow RMW consumer: at depth 1
    the producer must hit a full queue (real backpressure)."""
    prog = ir.Program(
        "pingpong_stream",
        loops=(
            ir.Loop("t", ir.Const(n), (
                ir.SetLocal("s", ir.Const(0.0)),
                ir.Loop("p", ir.Const(1), (
                    ir.SetLocal("s", ir.Var("t") * 2.0 + 1.0),
                )),
                ir.Loop("c", ir.Const(1), (
                    ir.Load("ld_o", "o", ir.Var("t")),
                    ir.Store(
                        "st_o", "o", ir.Var("t"),
                        ir.LoadVal("ld_o") + ir.Local("s"),
                    ),
                )),
            )),
        ),
    )
    return prog, {"o": np.zeros(n, dtype=np.float64)}, {}


def test_depth1_ping_pong_backpressure():
    prog, arrays, params = _pingpong_program()
    expect = np.arange(8, dtype=np.float64) * 2.0 + 1.0
    sim = simulator.SimParams(fifo_depth=1)
    for engine in ("cycle", "event"):
        res = simulator.simulate(
            prog, _copies(arrays), params, sim=sim, engine=engine
        )
        np.testing.assert_array_equal(res.arrays["o"], expect)
        (qs,) = res.fifo_stats
        assert qs["max_occupancy"] == 1
        assert qs["push_stalls"] > 0, (
            f"{engine}: depth-1 queue never backpressured the producer"
        )
    plan1 = executor.build_wave_plan(
        prog, _copies(arrays), params, fifo_depth=1
    )
    plan4 = executor.build_wave_plan(
        prog, _copies(arrays), params, fifo_depth=4
    )
    for plan in (plan1, plan4):
        executor.validate_plan(plan)
    assert plan1.stats.n_waves > plan4.stats.n_waves
    r = executor.execute(prog, _copies(arrays), params, fifo_depth=1)
    np.testing.assert_array_equal(r.arrays["o"], expect)


def test_undersized_depth_rejected_by_name():
    prog, arrays, params = _pingpong_program()
    edge = "(pe0 -> pe1, 's', shared=1)"
    with pytest.raises(
        fifolib.FifoUnsupportedError, match="undersized FIFO depth 0"
    ) as exc:
        simulator.simulate(
            prog, _copies(arrays), params,
            sim=simulator.SimParams(fifo_depth=0),
        )
    assert edge in str(exc.value)
    with pytest.raises(
        fifolib.FifoUnsupportedError, match="undersized FIFO depth 0"
    ) as exc:
        executor.build_wave_plan(
            prog, _copies(arrays), params, fifo_depth=0
        )
    assert edge in str(exc.value)


# ---------------------------------------------------------------------------
# static rejection diagnostics (never interpreted; shapes the token
# protocol cannot express must fail loudly with every edge named)
# ---------------------------------------------------------------------------


def _cyclic_program():
    """x and y stream into each other's PE: a 2-cycle in the edge graph
    deadlocks for any finite depth (no initial tokens)."""
    return ir.Program(
        "fifo_cycle",
        loops=(
            ir.Loop("t", ir.Const(2), (
                ir.SetLocal("x", ir.Const(0.0)),
                ir.Loop("a", ir.Const(1), (
                    ir.SetLocal("x", ir.Local("y") + 1.0),
                )),
                ir.SetLocal("y", ir.Const(0.0)),
                ir.Loop("b", ir.Const(1), (
                    ir.SetLocal("y", ir.Local("x") * 1.0),
                )),
            )),
        ),
    )


def test_deadlock_cycle_names_every_edge():
    prog = _cyclic_program()
    dres = daelib.decouple(prog)
    assert len(dres.fifo_edges) == 2
    with pytest.raises(fifolib.FifoDeadlockError, match="deadlock") as exc:
        fifolib.analyze_program(prog, dres)
    msg = str(exc.value)
    for p, c, name, d in dres.fifo_edges:
        assert f"(pe{p} -> pe{c}, {name!r}, shared={d})" in msg


def test_simulator_fallback_names_full_edge_list():
    """The bugfix pin: the NotImplementedError fallback must name EVERY
    discovered edge ``(prod_pe -> cons_pe, local, depth)``, not a
    prefix — here two edges exist but only one is malformed."""
    prog = ir.Program(
        "fifo_derived_use",
        loops=(
            ir.Loop("t", ir.Const(2), (
                ir.SetLocal("x", ir.Const(0.0)),
                ir.Loop("p1", ir.Const(1), (
                    ir.SetLocal("x", ir.Var("t") * 1.0),
                )),
                ir.SetLocal("y", ir.Const(0.0)),
                ir.Loop("p2", ir.Const(1), (
                    ir.SetLocal("y", ir.Var("t") + 2.0),
                )),
                ir.Loop("c", ir.Const(1), (
                    ir.SetLocal("d", ir.Local("y") * 2.0),
                    ir.Store(
                        "st", "o", ir.Var("t"),
                        ir.Local("x") + ir.Local("d"),
                    ),
                )),
            )),
        ),
    )
    arrays = {"o": np.zeros(2, dtype=np.float64)}
    dres = daelib.decouple(prog)
    assert len(dres.fifo_edges) == 2
    with pytest.raises(NotImplementedError) as exc:
        simulator.simulate(prog, arrays, {})
    msg = str(exc.value)
    for p, c, name, d in dres.fifo_edges:
        assert f"(pe{p} -> pe{c}, {name!r}, shared={d})" in msg, (
            f"fallback diagnostic dropped edge {name!r}: {msg}"
        )
    assert "derived" in msg
    # the cyclic shape takes the same fallback, with its own diagnostic
    with pytest.raises(NotImplementedError, match="deadlock"):
        simulator.simulate(
            _cyclic_program(), {"o": np.zeros(2)}, {}
        )


def test_unsupported_shapes_rejected():
    # backward edge: consumer leaf precedes the producer leaf
    back = ir.Program(
        "fifo_backward",
        loops=(
            ir.Loop("t", ir.Const(2), (
                ir.Loop("c", ir.Const(1), (
                    ir.Store("st", "o", ir.Var("t"), ir.Local("x")),
                )),
                ir.SetLocal("x", ir.Const(0.0)),
                ir.Loop("p", ir.Const(1), (
                    ir.SetLocal("x", ir.Var("t") * 1.0),
                )),
            )),
        ),
    )
    with pytest.raises(fifolib.FifoUnsupportedError, match="backward"):
        fifolib.analyze_program(back, daelib.decouple(back))

    # rate mismatch: producer leaf is one level deeper than the shared
    # scope, so it would push more than once per consumer pop
    rate = ir.Program(
        "fifo_rate",
        loops=(
            ir.Loop("t", ir.Const(2), (
                ir.SetLocal("x", ir.Const(0.0)),
                ir.Loop("mid", ir.Const(2), (
                    ir.Loop("p", ir.Const(1), (
                        ir.SetLocal("x", ir.Var("mid") * 1.0),
                    )),
                )),
                ir.Loop("c", ir.Const(1), (
                    ir.Store("st", "o", ir.Var("t"), ir.Local("x")),
                )),
            )),
        ),
    )
    with pytest.raises(
        fifolib.FifoUnsupportedError, match="rates would diverge"
    ):
        fifolib.analyze_program(rate, daelib.decouple(rate))


# ---------------------------------------------------------------------------
# diagnostics bugfix sweep: multi-reason LossOfDecoupling + typed CU
# contract errors
# ---------------------------------------------------------------------------


def test_loss_of_decoupling_reports_every_reason():
    """The join bugfix pin: a program losing decoupling through TWO
    expressions at once (inner trip AND store address both depend on a
    protected load) must surface both reasons, '; '-joined."""
    prog = ir.Program(
        "lod_two_reasons",
        loops=(
            ir.Loop("i", ir.Const(3), (
                ir.Load("ld_n", "lens", ir.Var("i")),
                ir.Loop("k", ir.LoadVal("ld_n"), (
                    ir.Load("ld_v", "vals", ir.Var("k")),
                    ir.Store(
                        "st", "A",
                        ir.LoadVal("ld_n") + ir.Var("k"),
                        ir.LoadVal("ld_v"),
                    ),
                )),
            )),
        ),
    )
    with pytest.raises(daelib.LossOfDecoupling) as exc:
        daelib.decouple(prog, speculation="off")
    msg = str(exc.value)
    assert "; " in msg, f"reasons were not joined: {msg}"
    assert msg.count("loss of decoupling") == 2
    assert "trip" in msg and "address of op 'st'" in msg
    # and "auto" still accepts it, keeping both reasons on the SpecInfo
    dres = daelib.decouple(prog, speculation="auto")
    (spec,) = dres.spec.values()
    assert len(spec.reasons) == 2


def test_cu_contract_errors_are_typed():
    # feed on a load-free VecCU: the engine delivered a value no load
    # requested — a typed internal-contract error, not a bare assert
    prog, arrays, params = strat.random_loadfree_cu_program(
        np.random.default_rng(7)
    )
    dres = daelib.decouple(prog)
    pe = dres.pes[0]
    cu = daelib.make_cu(pe, arrays, params)
    assert type(cu).__name__ == "VecCU"
    with pytest.raises(daelib.CUContractError, match="load-free"):
        cu.feed(1.0, 0)
    assert issubclass(daelib.CUContractError, RuntimeError)

    # script-recording a FIFO-coupled PE is timing-dependent: rejected
    # with the same typed error (the DSE planner relies on this)
    sprog, sarrays, sparams = programs.get("filter_pipe").make(16)
    sdres = daelib.decouple(sprog)
    fifo_pe = next(p for p in sdres.pes if p.fifo_in)
    with pytest.raises(daelib.CUContractError, match="FIFO-coupled"):
        daelib.record_cu_script(fifo_pe, sarrays, sparams, {})


# ---------------------------------------------------------------------------
# random stream programs: deterministic tier-1 sweep + hypothesis
# wrapper (the nightly stream-fuzz job raises the example budget)
# ---------------------------------------------------------------------------


def check_stream_program(pa):
    prog, arrays, params = pa
    oracle = ir.interpret(prog, _copies(arrays), params)
    dres = daelib.decouple(prog)
    assert dres.fifo_edges, "generator produced a non-streaming program"
    for engine in ("cycle", "event"):
        res = simulator.simulate(
            prog, _copies(arrays), params, engine=engine
        )
        for k, v in oracle.items():
            np.testing.assert_array_equal(
                res.arrays[k], v,
                err_msg=f"{engine} engine diverged ({k})",
            )
        for qs in res.fifo_stats:
            assert qs["pushed"] == qs["popped"]
    for depth in (1, 3):
        r = executor.execute(
            prog, _copies(arrays), params, fifo_depth=depth
        )
        executor.validate_plan(r.plan)
        for k, v in oracle.items():
            np.testing.assert_array_equal(
                r.arrays[k], v,
                err_msg=f"wave executor (depth={depth}) diverged ({k})",
            )


@pytest.mark.parametrize("seed", range(0, 30, 2))
def test_stream_programs_seeded(seed):
    check_stream_program(
        strat.random_stream_program(np.random.default_rng(seed))
    )


if strat.HAVE_HYPOTHESIS:

    class TestStreamHypothesis:
        @given(strat.stream_programs())
        def test_differential(self, pa):
            check_stream_program(pa)
