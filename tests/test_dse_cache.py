"""DSE result cache: hit/miss behaviour, bitwise round-trip, and
invalidation on parameter and code-version change (DESIGN.md §9)."""

import numpy as np
import pytest

from repro import dse
from repro.core import programs, simulator
from repro.dse import cache as cachelib

SPEC = dict(kernels=["RAWloop"], scales={"RAWloop": 48}, modes=("STA", "FUS2"))


def test_sweep_cold_then_warm(tmp_path):
    spec = dse.SweepSpec(**SPEC, sizings={"base": {}, "n4": {"burst_size": 4}})
    cold = dse.sweep(spec, cache_dir=str(tmp_path))
    assert cold.n_cache_hits == 0
    warm = dse.sweep(spec, cache_dir=str(tmp_path))
    assert warm.n_cache_hits == warm.n_unique_runs == cold.n_unique_runs
    for a, b in zip(cold.points, warm.points):
        assert a.result.cycles == b.result.cycles
        assert a.result.dram_bursts == b.result.dram_bursts
        assert a.result.dram_requests == b.result.dram_requests
        assert a.result.forwards == b.result.forwards
        for k in a.result.arrays:
            np.testing.assert_array_equal(
                a.result.arrays[k], b.result.arrays[k],
                err_msg="cache round-trip changed an array",
            )
    # cached results still match a fresh standalone call
    p = warm.points[-1].point
    prog, arrays, params = programs.get(p.kernel).make(p.scale)
    base = simulator.simulate(
        prog, arrays, params, mode=p.mode, sim=p.sim_params(),
        engine=p.engine, trace_mode=p.trace_mode,
    )
    assert base.cycles == warm.points[-1].result.cycles


def test_partial_warm_on_new_sizing(tmp_path):
    """Growing the grid only pays for the new points (incremental)."""
    small = dse.SweepSpec(**SPEC)
    dse.sweep(small, cache_dir=str(tmp_path))
    grown = dse.SweepSpec(**SPEC, sizings={"base": {}, "n4": {"burst_size": 4}})
    res = dse.sweep(grown, cache_dir=str(tmp_path))
    assert res.n_unique_runs == 4
    assert res.n_cache_hits == 2  # the original base-sizing runs


def test_key_sensitivity():
    prog, arrays, params = programs.get("RAWloop").make(32)
    base = cachelib.result_cache_key(prog, arrays, params, "FUS2", "event", ())
    # params change
    assert base != cachelib.result_cache_key(
        prog, arrays, {**params, "n": 16}, "FUS2", "event", ()
    )
    # array contents change
    arrays2 = {**arrays, "d0": arrays["d0"] + 1.0}
    assert base != cachelib.result_cache_key(
        prog, arrays2, params, "FUS2", "event", ()
    )
    # sizing / mode / engine class change
    assert base != cachelib.result_cache_key(
        prog, arrays, params, "FUS2", "event", (("burst_size", 4),)
    )
    assert base != cachelib.result_cache_key(
        prog, arrays, params, "FUS1", "event", ()
    )
    assert base != cachelib.result_cache_key(
        prog, arrays, params, "FUS2", "cycle", ()
    )
    # explicit code-version change
    assert base != cachelib.result_cache_key(
        prog, arrays, params, "FUS2", "event", (), version="not-this-code"
    )
    # structural program change
    prog2, _, _ = programs.get("WARloop").make(32)
    assert prog.fingerprint() != prog2.fingerprint()


def test_code_version_change_invalidates(tmp_path, monkeypatch):
    spec = dse.SweepSpec(**SPEC)
    first = dse.sweep(spec, cache_dir=str(tmp_path))
    assert first.n_cache_hits == 0
    # simulate editing the simulator/dse sources between sweeps
    monkeypatch.setattr(cachelib, "_CODE_VERSION", "f" * 64)
    again = dse.sweep(spec, cache_dir=str(tmp_path))
    assert again.n_cache_hits == 0  # every old entry invalidated
    for a, b in zip(first.points, again.points):
        assert a.result.cycles == b.result.cycles


def test_corrupt_entry_is_a_miss(tmp_path):
    cache = cachelib.ResultCache(str(tmp_path))
    prog, arrays, params = programs.get("RAWloop").make(32)
    key = cachelib.result_cache_key(prog, arrays, params, "FUS2", "event", ())
    (tmp_path / f"{key}.npz").write_bytes(b"not an npz")
    assert cache.get(key) is None
    res = simulator.simulate(prog, arrays, params, mode="FUS2")
    cache.put(key, res)
    got = cache.get(key)
    assert got is not None and got.cycles == res.cycles


def test_code_version_is_stable_and_source_sensitive():
    v1 = cachelib.code_version()
    assert v1 == cachelib.code_version()
    assert len(v1) == 64 and int(v1, 16) >= 0


def test_code_version_covers_every_core_module(monkeypatch):
    """The code-version hash must glob repro.core (it does — this pins
    it against regressing to a hard-coded file list): ADDING a module
    under core/, e.g. a new speculation pass, invalidates the key."""
    import os

    import repro.core

    root = os.path.dirname(repro.core.__file__)
    listed = {
        fn for fn in os.listdir(root) if fn.endswith(".py")
    }
    # sanity: the modules the simulator depends on are all picked up,
    # including the speculation module this guard was written for
    for mod in ("dae.py", "speculate.py", "engine_event.py", "schedule.py"):
        assert mod in listed
    monkeypatch.setattr(cachelib, "_CODE_VERSION", None)
    before = cachelib.code_version()
    tmp = os.path.join(root, "_tmp_code_version_probe.py")
    try:
        with open(tmp, "w") as f:
            f.write("# temporary module for test_dse_cache\n")
    except OSError:
        pytest.skip("package source tree is not writable")
    try:
        monkeypatch.setattr(cachelib, "_CODE_VERSION", None)
        after = cachelib.code_version()
    finally:
        os.unlink(tmp)
    assert before != after
    monkeypatch.setattr(cachelib, "_CODE_VERSION", None)
    assert cachelib.code_version() == before

    # speculation class is part of the entry key (off/auto share only
    # when the kernel cannot speculate — spec_class "-")
    prog, arrays, params = programs.get("RAWloop").make(32)
    base = cachelib.result_cache_key(prog, arrays, params, "FUS2", "event", ())
    assert base != cachelib.result_cache_key(
        prog, arrays, params, "FUS2", "event", (), speculation="auto"
    )
