"""Batch-vs-single exactness of the DSE sweep engine (DESIGN.md §9).

The contract under test: every sweep point's SimResult — cycles, DRAM
traffic, forwarding count, final arrays — is **bit-identical** to a
standalone ``simulate()`` call with the same settings, across all nine
Table-1 kernels, all four modes, both engines, both trace modes, and
multiple DU sizings; and neither dedup, nor trace/CU/oracle sharing,
nor worker parallelism, nor the result cache can change any value.
"""

import numpy as np
import pytest

from repro import dse
from repro.core import programs, simulator

# small enough to keep the (sweep + standalone re-run) matrix inside the
# tier-1 budget; every kernel still exercises its hazard structure
SCALES = {
    "RAWloop": 32, "WARloop": 32, "WAWloop": 32, "bnn": 16,
    "pagerank": 24, "fft": 64, "matpower": 16, "hist+add": 64,
    "tanh+spmv": 24,
}

SIZINGS = {"base": {}, "narrow": {"burst_size": 4, "dram_latency": 64}}


def _assert_point_matches_standalone(pr):
    p = pr.point
    prog, arrays, params = programs.get(p.kernel).make(p.scale)
    base = simulator.simulate(
        prog, arrays, params, mode=p.mode, sim=p.sim_params(),
        engine=p.engine, trace_mode=p.trace_mode,
    )
    got = pr.result
    assert got.cycles == base.cycles, (p, base.cycles, got.cycles)
    assert got.dram_bursts == base.dram_bursts, p
    assert got.dram_requests == base.dram_requests, p
    assert got.forwards == base.forwards, p
    assert set(got.arrays) == set(base.arrays), p
    for k in base.arrays:
        np.testing.assert_array_equal(
            got.arrays[k], base.arrays[k],
            err_msg=f"{p}: sweep diverged from standalone on array {k}",
        )


# kernels whose trace streams stress the compiled/interp front-end
# differently (CSR gathers, non-monotonic stores, multiplicative ivars):
# these also verify the interp-trace-mode points against standalone
# simulate(trace_mode="interp") — i.e. the planner's trace-mode dedup
_INTERP_KERNELS = ("bnn", "hist+add", "fft")


@pytest.mark.parametrize("kernel", programs.TABLE1)
def test_sweep_matches_standalone(kernel):
    """All four modes x two sizings (x two trace modes on the irregular
    kernels): the batched runner's shared artifacts (compiled traces,
    CU replay scripts, oracle, nodep bits, rank tables) must not change
    a bit vs standalone simulate()."""
    tms = ("auto", "interp") if kernel in _INTERP_KERNELS else ("auto",)
    spec = dse.SweepSpec(
        kernels=[kernel], scales=SCALES,
        modes=("STA", "LSQ", "FUS1", "FUS2"),
        trace_modes=tms,
        sizings=SIZINGS,
    )
    res = dse.sweep(spec, differential=True)
    assert res.n_points == 8 * len(tms)
    # trace modes dedup onto one run each: 4 modes x 2 sizings unique
    assert res.n_unique_runs == 8
    for pr in res.points:
        _assert_point_matches_standalone(pr)


def test_sweep_matches_standalone_cycle_engine():
    """The reference cycle engine through the batch runner (incl. the
    LSQ instance-window path with a shared rank table)."""
    spec = dse.SweepSpec(
        kernels=["RAWloop"], scales=SCALES,
        modes=("LSQ", "FUS2"), engines=("cycle",),
        sizings=SIZINGS,
    )
    res = dse.sweep(spec)
    for pr in res.points:
        _assert_point_matches_standalone(pr)


def test_sta_engine_dedup():
    """STA is engine-invariant: the planner collapses the engine axis
    and both points share one (identical) result."""
    spec = dse.SweepSpec(
        kernels=["WAWloop"], scales=SCALES, modes=("STA",),
        engines=("event", "cycle"),
    )
    res = dse.sweep(spec)
    assert res.n_points == 2 and res.n_unique_runs == 1
    a, b = res.points
    assert a.result is b.result
    _assert_point_matches_standalone(a)
    _assert_point_matches_standalone(b)


def test_workers_do_not_change_results():
    spec = dse.SweepSpec(
        kernels=["RAWloop", "hist+add", "tanh+spmv"], scales=SCALES,
        modes=("STA", "FUS2"), sizings=SIZINGS,
    )
    serial = dse.sweep(spec, workers=1)
    parallel = dse.sweep(spec, workers=2)
    for a, b in zip(serial.points, parallel.points):
        assert a.point == b.point
        assert a.result.cycles == b.result.cycles
        assert a.result.dram_bursts == b.result.dram_bursts
        assert a.result.forwards == b.result.forwards
        for k in a.result.arrays:
            np.testing.assert_array_equal(a.result.arrays[k], b.result.arrays[k])


def test_forward_slack_profile():
    """profile=True emits per-pair config-batched §5.5 slack rows with
    one fraction per FUS2 config, all within [0, 1]."""
    spec = dse.SweepSpec(
        kernels=["hist+add", "pagerank"], scales=SCALES,
        modes=("FUS2",), sizings=SIZINGS,
    )
    res = dse.sweep(spec, profile=True)
    assert res.profile, "expected §5.5 slack rows"
    for row in res.profile:
        assert len(row["configs"]) == 2  # two sizings
        assert len(row["slack_frac"]) == 2
        assert all(0.0 <= f <= 1.0 for f in row["slack_frac"])


def test_spec_canonicalization_and_keys():
    from repro.core.simulator import SimParams

    a = dse.SweepPoint("RAWloop", 32, sim={"burst_size": 4})
    b = dse.SweepPoint("RAWloop", 32, sim=(("burst_size", 4),))
    c = dse.SweepPoint("RAWloop", 32, sim=SimParams(burst_size=4))
    assert a.sim == b.sim == c.sim == (("burst_size", 4),)
    # defaults canonicalize away
    d = dse.SweepPoint("RAWloop", 32, sim={"burst_size": 16})
    assert d.sim == ()
    # trace_mode never enters the result key; engine only off STA
    e1 = dse.SweepPoint("RAWloop", 32, mode="FUS2", trace_mode="interp")
    e2 = dse.SweepPoint("RAWloop", 32, mode="FUS2", trace_mode="compiled")
    assert e1.result_key == e2.result_key
    s1 = dse.SweepPoint("RAWloop", 32, mode="STA", engine="cycle")
    s2 = dse.SweepPoint("RAWloop", 32, mode="STA", engine="event")
    assert s1.result_key == s2.result_key
    f1 = dse.SweepPoint("RAWloop", 32, mode="FUS2", engine="cycle")
    f2 = dse.SweepPoint("RAWloop", 32, mode="FUS2", engine="event")
    assert f1.result_key != f2.result_key


def test_sim_param_projection_dedup():
    """Overrides a mode never reads fold onto the same run — and the
    shared result still matches a standalone call carrying the
    'irrelevant' override (i.e. the projection table is sound)."""
    # FUS1 never forwards: forward_latency is irrelevant
    spec = dse.SweepSpec(
        kernels=["RAWloop"], scales=SCALES, modes=("FUS1",),
        sizings={"base": {}, "fwd9": {"forward_latency": 9}},
    )
    res = dse.sweep(spec)
    assert res.n_points == 2 and res.n_unique_runs == 1
    for pr in res.points:
        _assert_point_matches_standalone(pr)
    # LSQ forces burst size 1: burst_size is irrelevant
    spec = dse.SweepSpec(
        kernels=["RAWloop"], scales=SCALES, modes=("LSQ",),
        sizings={"base": {}, "b32": {"burst_size": 32}},
    )
    res = dse.sweep(spec)
    assert res.n_unique_runs == 1
    for pr in res.points:
        _assert_point_matches_standalone(pr)
    # dynamic engines never read the STA calibration knobs; STA never
    # reads the CU latency — a 2-sizing grid x 2 modes = 4 points but
    # only 3 distinct results (STA splits, FUS2 folds)
    spec = dse.SweepSpec(
        kernels=["RAWloop"], scales=SCALES, modes=("STA", "FUS2"),
        sizings={"base": {}, "cal": {"sta_mem_dep_ii": 99}},
    )
    res = dse.sweep(spec)
    assert res.n_points == 4 and res.n_unique_runs == 3
    for pr in res.points:
        _assert_point_matches_standalone(pr)


def test_strict_compiled_point_raises_like_standalone():
    """A trace_mode="compiled" point on a kernel outside the compiled
    subset must fail like the standalone call would: the sweep raises
    ``SweepGroupError`` naming the (kernel, scale, spec_class) group
    with the standalone ``TraceCompileError`` chained as its cause
    (local-carried CSR row pointers force the interpreter)."""
    from repro.core import loopir as ir
    from repro.core.schedule import TraceCompileError

    prog = ir.Program(
        "local_addr",
        loops=(
            ir.Loop("i", ir.Param("n", 0, 16), (
                ir.SetLocal("bin", ir.Read("d", ir.Var("i"), 0, 7)),
                ir.Load("ld_h", "h", ir.Local("bin")),
                ir.Store(
                    "st_h", "h", ir.Local("bin"), ir.LoadVal("ld_h") + 1.0
                ),
            )),
        ),
        params=("n",),
    )
    rng = np.random.default_rng(3)
    data = {
        "h": np.zeros(8),
        "d": rng.integers(0, 8, size=16).astype(np.float64),
    }
    programs.REGISTRY["_carried_test"] = programs.Bench(
        "_carried_test", lambda s: (prog, data, {"n": 16}), "O(n)", 16,
    )
    try:
        pt = dse.SweepPoint("_carried_test", 8, mode="FUS2", trace_mode="compiled")
        with pytest.raises(dse.SweepGroupError) as ei:
            dse.sweep([pt])
        assert "_carried_test" in str(ei.value)
        assert isinstance(ei.value.__cause__, TraceCompileError)
        # under "auto" the same kernel falls back per PE and runs fine
        res = dse.sweep([dse.SweepPoint("_carried_test", 8, mode="FUS2")])
        _assert_point_matches_standalone(res.points[0])
    finally:
        del programs.REGISTRY["_carried_test"]


# ---------------------------------------------------------------------------
# config-batched check_pair_batch: stacked configs == per-config calls
# ---------------------------------------------------------------------------


def test_check_pair_batch_config_axis_matches_per_config():
    from repro.core import du as dulib
    from repro.core import hazards as hz

    rng = np.random.default_rng(7)
    SEN = dulib.SENTINEL
    for trial in range(120):
        depth = int(rng.integers(1, 4))
        k = int(rng.integers(0, depth + 1))
        nonmono = sorted(
            int(d) for d in rng.choice(
                range(1, depth + 1),
                size=int(rng.integers(0, depth + 1)), replace=False,
            )
        )
        l_cands = [d for d in nonmono if d <= k]
        pair = hz.HazardPair(
            dst="a", src="b", kind="RAW", array="A",
            shared_depth=k, dst_before_src=bool(rng.integers(2)),
            wraparound=False, same_pe=bool(rng.integers(2)),
            use_frontier=bool(rng.integers(2)),
            l_depth=max(l_cands) if l_cands else None,
            lastiter_depths=tuple(d for d in nonmono if d > k),
            nodependence=bool(rng.integers(2)),
        )
        C = int(rng.integers(2, 5))
        m = int(rng.integers(1, 7))
        req_sched = rng.integers(0, 6, size=(m, depth)).astype(np.int64)
        req_addr = rng.integers(0, 10, size=m).astype(np.int64)
        f_sched = rng.integers(0, 6, size=(C, m, depth)).astype(np.int64)
        f_sched[rng.random(size=(C, m)) < 0.15] = SEN
        f_addr = rng.integers(-2, 12, size=(C, m)).astype(np.int64)
        f_addr[rng.random(size=(C, m)) < 0.15] = SEN
        f_last = rng.integers(0, 2, size=(C, m, depth)).astype(bool)
        bits = rng.integers(0, 2, size=m).astype(bool)
        nb = bits if pair.nodependence else None

        stacked = dulib.check_pair_batch(
            pair, req_sched, req_addr, None, True, nb,
            frontier=(f_sched, f_addr, f_last),
        )
        stacked = np.broadcast_to(stacked, (C, m))
        for c in range(C):
            single = dulib.check_pair_batch(
                pair, req_sched, req_addr, None, True, nb,
                frontier=(f_sched[c], f_addr[c], f_last[c]),
            )
            np.testing.assert_array_equal(
                stacked[c], np.broadcast_to(single, (m,)),
                err_msg=f"trial {trial} config {c}: stacked != per-config",
            )
