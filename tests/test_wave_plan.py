"""Wave-plan property suite: the invariants every ``WavePlan`` must
hold, pinned over random executable programs (DESIGN.md §2).

The exact per-(PE, dep-edge) partition replaced a per-PE barrier (a
store used to wait on *every* prior load of its PE); these properties
are what make that replacement safe and worthwhile:

  * **topological waves** — every request sits strictly after its
    same-address RAW/WAR/WAW predecessors and (for stores) after every
    load request feeding its value/guard, asserted here *independently*
    of ``executor.validate_plan`` (which is also run — the two
    implementations check each other),
  * **intra-wave conflict-freedom** — a backend may execute a wave in
    any internal order,
  * **never worse than the barrier** — per request, the exact
    partition's wave index is <= the old per-PE-barrier partition's
    (reimplemented here from the pre-change sweep): exactness can only
    remove edges,
  * **step coarsening is semantics-free** — ``batch_waves=False``
    degenerates steps to waves and the executed arrays are bit-equal,
  * **execution is exact** — the numpy wave backend matches the
    sequential oracle bit for bit.

The suite runs a deterministic seed sweep in tier-1 even without
hypothesis; with hypothesis the same cores run under the shared
profiles (tier1 / nightly, tests/loopir_strategies.py — the nightly CI
fuzz job raises the budget via ``HYPOTHESIS_PROFILE=nightly``).

The file also carries the backend differential for the three kernels
the barrier used to serialize (matpower, pagerank, spmv_ldtrip):
numpy backend vs Pallas ``run_plan`` vs ``run_sequential`` at two
scales, arrays exact, plus a regression pin on their wave counts.
"""

import numpy as np
import pytest

import loopir_strategies as strat
from repro.core import dae as daelib
from repro.core import executor, loopir as ir, programs
from repro.kernels import wave_exec

if strat.HAVE_HYPOTHESIS:
    from hypothesis import given


def _build(pa, **kw):
    prog, arrays, params = pa
    return executor.build_wave_plan(
        prog, {k: v.copy() for k, v in arrays.items()}, params, **kw
    )


# ---------------------------------------------------------------------------
# property cores (plain functions: deterministic sweep + hypothesis)
# ---------------------------------------------------------------------------


def check_topological_waves(plan):
    """Every dependence edge crosses strictly increasing waves, redone
    from the request streams without touching the plan's own sweep."""
    waves = plan.req_wave
    last_store: dict[int, int] = {}  # flat addr -> wave of last store
    loads_since: dict[int, int] = {}  # flat addr -> max load wave since
    load_wave: dict[str, list[int]] = {}
    for i in range(plan.n_requests):
        a = int(plan.req_flat[i])
        w = int(waves[i])
        op_id = plan.op_ids[plan.req_op[i]]
        if plan.req_store[i]:
            assert w > last_store.get(a, -1), "store not after last store"
            assert w > loads_since.get(a, -1), "store not after WAR loads"
            k = int(plan.req_ordinal[i])
            for ld, rows in plan.dep_maps[op_id].items():
                m = int(rows[k])
                if m >= 0:
                    assert w > load_wave[ld][m], (
                        f"store {op_id} not after its feeding {ld} load"
                    )
                else:
                    assert not plan.req_valid[i]
            if plan.req_valid[i]:
                last_store[a] = w
                loads_since.pop(a, None)
            else:
                last_store[a] = max(last_store.get(a, -1), w)
        else:
            assert w > last_store.get(a, -1), "load not after last store"
            loads_since[a] = max(loads_since.get(a, -1), w)
            load_wave.setdefault(op_id, []).append(w)


def check_conflict_free_waves(plan):
    """Within one wave no two requests share an address unless both are
    loads."""
    store_addrs: dict[int, set] = {}
    load_addrs: dict[int, set] = {}
    for i in range(plan.n_requests):
        w, a = int(plan.req_wave[i]), int(plan.req_flat[i])
        if plan.req_store[i]:
            assert a not in store_addrs.setdefault(w, set()), (
                "two stores share (wave, address)"
            )
            assert a not in load_addrs.get(w, ()), (
                "store shares (wave, address) with a load"
            )
            store_addrs[w].add(a)
        else:
            assert a not in store_addrs.get(w, ()), (
                "load shares (wave, address) with a store"
            )
            load_addrs.setdefault(w, set()).add(a)


def barrier_partition_waves(plan) -> np.ndarray:
    """The pre-change per-PE-barrier partition, reimplemented: a store
    waits on the max wave of *every* prior load of its PE, not just the
    loads feeding it. The comparison baseline for the exactness win."""
    op_pe = daelib.decouple(plan.program).op_to_pe
    n = plan.n_requests
    waves = np.zeros(n, dtype=np.int64)
    last_store: dict[int, int] = {}
    loads_since: dict[int, int] = {}
    pe_load_wave: dict[int, int] = {}
    for i in range(n):
        a = int(plan.req_flat[i])
        op_id = plan.op_ids[plan.req_op[i]]
        if plan.req_store[i]:
            w = max(
                last_store.get(a, -1) + 1,
                loads_since.get(a, -1) + 1,
                pe_load_wave.get(op_pe[op_id], -1) + 1,
            )
            if plan.req_valid[i]:
                last_store[a] = w
                loads_since.pop(a, None)
            else:
                last_store[a] = max(last_store.get(a, -1), w)
        else:
            w = last_store.get(a, -1) + 1
            loads_since[a] = max(loads_since.get(a, -1), w)
            pe = op_pe[op_id]
            pe_load_wave[pe] = max(pe_load_wave.get(pe, -1), w)
        waves[i] = w
    return waves


def check_plan_properties(pa):
    plan = _build(pa)
    executor.validate_plan(plan)
    check_topological_waves(plan)
    check_conflict_free_waves(plan)
    # exactness can only remove dependence edges, so per request the
    # new wave index never exceeds the old barrier partition's
    old = barrier_partition_waves(plan)
    assert np.all(plan.req_wave <= old), (
        "exact partition worse than the per-PE barrier"
    )
    # batching is pure coarsening: turning it off degenerates steps to
    # waves and changes nothing else
    plan_nb = _build(pa, batch_waves=False)
    np.testing.assert_array_equal(plan_nb.req_wave, plan.req_wave)
    np.testing.assert_array_equal(plan_nb.req_step, plan_nb.req_wave)
    assert plan_nb.stats.n_steps == plan_nb.stats.n_waves
    assert plan.stats.n_steps <= plan.stats.n_waves
    executor.validate_plan(plan_nb)


def check_execution_exact(pa):
    prog, arrays, params = pa
    oracle = ir.interpret(
        prog, {k: v.copy() for k, v in arrays.items()}, params
    )
    for batch in (True, False):
        res = executor.execute(
            prog, {k: v.copy() for k, v in arrays.items()}, params,
            batch_waves=batch,
        )
        for k in oracle:
            np.testing.assert_array_equal(
                res.arrays[k], oracle[k],
                err_msg=f"numpy wave backend (batch_waves={batch}) "
                f"diverged from oracle ({k})",
            )


# ---------------------------------------------------------------------------
# deterministic tier-1 sweep (runs without hypothesis)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(0, 40, 2))
def test_wave_plan_properties_seeded(seed):
    pa = strat.random_wave_program(np.random.default_rng(seed))
    check_plan_properties(pa)


@pytest.mark.parametrize("seed", range(1, 41, 2))
def test_wave_execution_exact_seeded(seed):
    pa = strat.random_wave_program(np.random.default_rng(seed))
    check_execution_exact(pa)


# ---------------------------------------------------------------------------
# hypothesis wrappers (budget from the shared tier1/nightly profiles)
# ---------------------------------------------------------------------------


if strat.HAVE_HYPOTHESIS:

    class TestWavePlanHypothesis:
        @given(strat.wave_programs())
        def test_plan_properties(self, pa):
            check_plan_properties(pa)

        @given(strat.wave_programs())
        def test_execution_exact(self, pa):
            check_execution_exact(pa)


# ---------------------------------------------------------------------------
# the three ex-serialized kernels: backend differential + wave-count pin
# ---------------------------------------------------------------------------

# two scales per kernel (small enough for interpret-mode Pallas in
# tier-1); the n_waves caps pin the exact partition's critical path —
# the old barrier produced ~n_requests/2 waves on these (parallelism
# 1.8-3.4x), so any regression toward it trips the cap immediately
FLOOR_KERNELS = {
    # (scale, wave cap): measured 27/29, 56/54, 15/17 — pinned at +~30%
    "matpower": ((16, 36), (32, 40)),
    "pagerank": ((24, 72), (48, 72)),
    "spmv_ldtrip": ((32, 20), (64, 24)),
}


@pytest.mark.parametrize("name", sorted(FLOOR_KERNELS))
def test_floor_kernel_backends_differential(name):
    bench = programs.get(name)
    spec = "auto" if bench.speculative else "off"
    for scale, wave_cap in FLOOR_KERNELS[name]:
        prog, arrays, params = bench.make(scale)
        oracle = ir.interpret(
            prog, {k: v.copy() for k, v in arrays.items()}, params
        )
        plan = executor.build_wave_plan(
            prog, arrays, params, speculation=spec
        )
        executor.validate_plan(plan)
        assert plan.stats.n_waves <= wave_cap, (
            f"{name}@{scale}: {plan.stats.n_waves} waves exceeds the "
            f"{wave_cap} regression cap — partition lost exactness"
        )
        res_np = executor.execute(
            prog, {k: v.copy() for k, v in arrays.items()}, params,
            speculation=spec,
        )
        res_pl = wave_exec.run_plan(plan, arrays, interpret=True)
        res_sq = wave_exec.run_sequential(plan, arrays, check=True)
        assert res_pl.complete and res_sq.complete
        for k in oracle:
            for label, got in (
                ("numpy", res_np.arrays[k]),
                ("pallas", res_pl.arrays[k]),
                ("sequential", res_sq.arrays[k]),
            ):
                np.testing.assert_array_equal(
                    got, oracle[k],
                    err_msg=f"{name}@{scale}: {label} backend diverged "
                    f"from oracle ({k})",
                )
