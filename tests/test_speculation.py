"""Speculative AGU with rollback-free squash (DESIGN.md §10).

Pins the loss-of-decoupling speculation subsystem end to end:

  * the four load-dependent kernels (``programs.SPEC_KERNELS``) run
    under ``speculation="auto"`` in every mode x engine, bit-identical
    to ``loopir.interpret`` AND to the independent numpy oracles in
    ``kernels/dynloop/ref.py``,
  * the predictor-conformance matrix: every ``dae.PREDICTORS`` value
    x both engines x every speculative kernel is arrays-exact, with
    engine cycle counts inside the documented drift envelope — the
    predictor knob moves *time*, never *values*,
  * the ``predictor`` knob is inert where speculation never fires:
    decoupled (Table-1) programs are bit-identical in cycles and
    arrays under every predictor value, and ``predictor="auto"``
    never loses to ``speculation="off"`` there,
  * ``SimResult.spec_stats`` has the documented shape (top-level,
    per-port and per-component-predictor counters),
  * ``speculation="off"`` still rejects, with diagnostics that name the
    consuming statement (op id / loop trip / AGU local) — the message
    shapes are part of the contract,
  * the §6 mis-speculation substrate speculation builds on: the
    interpreter's trace hook reports guarded-false stores with
    ``valid=False, value=None``; both engines preserve request
    existence for invalid stores (they occupy the stream and ACK
    without DRAM),
  * ``SpecPlan`` structure: epoch tags non-decreasing per stream,
    trigger/resolve consistency, predictor-zoo accounting (every
    occurrence either predicted or confidence-suppressed into a wait
    gate; phantoms only behind squash gates, capped by the run-ahead
    window),
  * the DSE axis: ``speculation`` expands in ``SweepSpec``; the result
    identity folds ``off``/``auto`` (and ``squash_latency``) for
    kernels that never speculate,
  * the random differential: generated load-dependent-trip programs
    plus stride-patterned and context-repeating pointer walks
    (tests/loopir_strategies.py) simulate oracle-exact in both engines
    under every predictor (deterministic seeds in tier-1; hypothesis
    strategies in the nightly predictor-fuzz job),
  * TABLE1 stays frozen at the paper's nine kernels (the registry may
    grow, the paper's evaluation set may not).
"""

import numpy as np
import pytest

import loopir_strategies as strat
from repro.core import dae as daelib
from repro.core import engine_event
from repro.core import loopir as ir
from repro.core import programs
from repro.core import schedule as schedlib
from repro.core import simulator
from repro.core import speculate
from repro.kernels.dynloop import ref as dynref

SCALES = {
    "spmv_ldtrip": 24, "bfs_front": 32, "chase_sum": 24,
    "strided_scan": 24,
}


def _simulate_spec(name, mode, engine, scale=None, **kw):
    prog, arrays, params = programs.get(name).make(scale or SCALES[name])
    res = simulator.simulate(
        prog, arrays, params, mode=mode, engine=engine,
        speculation="auto", validate=(mode != "STA"), **kw,
    )
    oracle = ir.interpret(prog, arrays, params)
    return res, oracle, (prog, arrays, params)


# ---------------------------------------------------------------------------
# kernel acceptance: every mode x engine, oracle- and ref-exact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ("cycle", "event"))
@pytest.mark.parametrize("mode", ("STA", "LSQ", "FUS1", "FUS2"))
@pytest.mark.parametrize("name", programs.SPEC_KERNELS)
def test_spec_kernels_all_modes_oracle_exact(name, mode, engine):
    res, oracle, _ = _simulate_spec(name, mode, engine)
    for k in oracle:
        np.testing.assert_array_equal(res.arrays[k], oracle[k], err_msg=k)


@pytest.mark.parametrize("name", programs.SPEC_KERNELS)
def test_spec_kernels_match_independent_refs(name):
    prog, arrays, params = programs.get(name).make(SCALES[name])
    final = ir.interpret(prog, arrays, params)
    if name == "spmv_ldtrip":
        rowlen, y = dynref.spmv_ldtrip_ref(
            arrays["deg"], arrays["rp"], arrays["cidx"], arrays["val"],
            arrays["x"],
        )
        np.testing.assert_allclose(final["rowlen"], rowlen, atol=1e-12)
        np.testing.assert_allclose(final["y"], y, atol=1e-12)
    elif name == "bfs_front":
        foff, visit = dynref.bfs_front_ref(
            arrays["off0"], arrays["front"], arrays["nodeval"],
            len(arrays["visit"]),
        )
        np.testing.assert_allclose(final["foff"], foff, atol=1e-12)
        np.testing.assert_allclose(final["visit"], visit, atol=1e-12)
    elif name == "chase_sum":
        out = dynref.chase_sum_ref(
            arrays["nxt"], arrays["w"], params["steps"]
        )
        np.testing.assert_allclose(final["out"], out, atol=1e-12)
    else:  # strided_scan
        out = dynref.strided_scan_ref(
            arrays["ptr"], arrays["w"], params["n"]
        )
        np.testing.assert_allclose(final["out"], out, atol=1e-12)


@pytest.mark.parametrize("name", programs.SPEC_KERNELS)
def test_spec_kernels_rejected_without_speculation(name):
    prog, arrays, params = programs.get(name).make(SCALES[name])
    with pytest.raises(daelib.LossOfDecoupling, match="loss of decoupling"):
        simulator.simulate(prog, arrays, params)


@pytest.mark.parametrize("name", programs.SPEC_KERNELS)
def test_spec_kernel_engines_agree(name):
    rc, oracle, _ = _simulate_spec(name, "FUS2", "cycle")
    re_, _, _ = _simulate_spec(name, "FUS2", "event")
    for k in oracle:
        np.testing.assert_array_equal(rc.arrays[k], re_.arrays[k])
    assert rc.squashed == re_.squashed
    assert rc.dram_requests == re_.dram_requests
    # same drift envelope as test_engine_diff (DESIGN.md §1.2)
    assert abs(rc.cycles - re_.cycles) <= max(2, int(0.02 * rc.cycles))


# ---------------------------------------------------------------------------
# predictor-conformance matrix: every predictor x both engines x every
# speculative kernel — arrays oracle-exact, engines agree on squash
# accounting and stay inside the cycle drift envelope
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("predictor", daelib.PREDICTORS)
@pytest.mark.parametrize("name", programs.SPEC_KERNELS)
def test_predictor_conformance_matrix(name, predictor):
    rc, oracle, _ = _simulate_spec(name, "FUS2", "cycle", predictor=predictor)
    re_, _, _ = _simulate_spec(name, "FUS2", "event", predictor=predictor)
    for k in oracle:
        np.testing.assert_array_equal(
            rc.arrays[k], oracle[k], err_msg=f"cycle/{predictor}/{k}"
        )
        np.testing.assert_array_equal(
            re_.arrays[k], oracle[k], err_msg=f"event/{predictor}/{k}"
        )
    # the predictor changes *when* gates resolve, never *what* commits:
    # both engines see the same gate schedule, hence the same squashes
    assert rc.squashed == re_.squashed
    assert rc.dram_requests == re_.dram_requests
    assert abs(rc.cycles - re_.cycles) <= max(2, int(0.02 * rc.cycles))
    assert rc.spec_stats["predictor"] == predictor
    assert re_.spec_stats["predictor"] == predictor


@pytest.mark.parametrize("name", programs.TABLE1)
def test_auto_predictor_never_loses_to_off_on_table1(name):
    """Decoupled kernels never open a gate, so the full zoo under
    ``auto`` costs exactly zero cycles over ``speculation="off"``."""
    scale = max(8, programs.get(name).default_scale // 8)
    prog, arrays, params = programs.get(name).make(scale)
    off = simulator.simulate(prog, arrays, params, speculation="off")
    auto = simulator.simulate(
        prog, arrays, params, speculation="auto", predictor="auto"
    )
    assert auto.cycles <= off.cycles
    assert auto.cycles == off.cycles  # stronger: a strict no-op
    assert auto.squashed == 0 and auto.spec_stats == {}
    for k in off.arrays:
        np.testing.assert_array_equal(off.arrays[k], auto.arrays[k])


@pytest.mark.parametrize("predictor", daelib.PREDICTORS)
def test_predictor_knob_inert_without_speculation(predictor):
    """Regression: on non-speculative programs every ``predictor=``
    value is bit-identical — the knob must not leak into decoupled
    scheduling."""
    prog, arrays, params = programs.get("RAWloop").make(48)
    base = simulator.simulate(prog, arrays, params)
    for spec in ("off", "auto"):
        res = simulator.simulate(
            prog, arrays, params, speculation=spec, predictor=predictor
        )
        assert res.cycles == base.cycles
        assert res.spec_stats == {}
        for k in base.arrays:
            np.testing.assert_array_equal(res.arrays[k], base.arrays[k])


def test_spec_stats_shape():
    """``SimResult.spec_stats`` is evidence surfaced to benchmarks and
    DSE rows — its key set (top-level, per-port, per-component) is a
    contract, pinned here for both engines."""
    top = {
        "predictor", "runahead", "predictions", "mispredictions",
        "wait_gates", "squash_gates", "gates", "phantom_requests",
        "phantom_capped", "cap_hits", "per_port", "by_predictor",
    }
    per_port = {"predictor", "predictions", "mispredictions", "waits"}
    by_pred = {"mispredictions", "wait_gates", "squashed", "cap_hits"}
    for engine in ("cycle", "event"):
        res, _, _ = _simulate_spec(
            "chase_sum", "FUS2", engine, predictor="auto"
        )
        s = res.spec_stats
        assert set(s) == top, engine
        assert s["predictor"] == "auto"
        assert s["runahead"] == simulator.SimParams().spec_runahead
        assert s["gates"] == s["wait_gates"] + s["squash_gates"]
        assert s["per_port"] and all(
            set(p) == per_port for p in s["per_port"].values()
        )
        # auto runs a tournament: component names appear in the stats
        assert s["by_predictor"] and all(
            set(v) == by_pred for v in s["by_predictor"].values()
        )
        assert set(s["by_predictor"]) <= {"last", "stride", "context"}
        for p in s["per_port"].values():
            assert p["predictor"] in ("last", "stride", "context")
        # a fixed-predictor run reports that component only
        res1, _, _ = _simulate_spec(
            "chase_sum", "FUS2", engine, predictor="stride"
        )
        assert res1.spec_stats["predictor"] == "stride"
        assert set(res1.spec_stats["by_predictor"]) <= {"stride"}


def test_trace_modes_on_spec_programs():
    """interp and auto share the speculative path; compiled refuses."""
    prog, arrays, params = programs.get("spmv_ldtrip").make(16)
    a = simulator.simulate(
        prog, arrays, params, speculation="auto", trace_mode="auto"
    )
    b = simulator.simulate(
        prog, arrays, params, speculation="auto", trace_mode="interp"
    )
    assert a.cycles == b.cycles and a.squashed == b.squashed
    with pytest.raises(schedlib.TraceCompileError, match="speculative AGU"):
        simulator.simulate(
            prog, arrays, params, speculation="auto", trace_mode="compiled"
        )


def test_speculation_auto_is_noop_on_decoupled_programs():
    prog, arrays, params = programs.get("RAWloop").make(64)
    assert daelib.decouple(prog, speculation="auto").spec == {}
    off = simulator.simulate(prog, arrays, params)
    auto = simulator.simulate(prog, arrays, params, speculation="auto")
    assert off.cycles == auto.cycles
    assert auto.squashed == 0
    for k in off.arrays:
        np.testing.assert_array_equal(off.arrays[k], auto.arrays[k])


@pytest.mark.parametrize("name", programs.SPEC_KERNELS)
def test_executor_runs_spec_kernels(name):
    from repro.core import executor

    prog, arrays, params = programs.get(name).make(SCALES[name])
    with pytest.raises(daelib.LossOfDecoupling):
        executor.execute(prog, arrays, params)
    ra = executor.execute(prog, arrays, params, speculation="auto")
    rb = executor.execute(
        prog, arrays, params, speculation="auto", trace_mode="interp"
    )
    oracle = ir.interpret(prog, arrays, params)
    for k in oracle:
        np.testing.assert_array_equal(ra.arrays[k], oracle[k])
    np.testing.assert_array_equal(ra.waves, rb.waves)


# ---------------------------------------------------------------------------
# LossOfDecoupling diagnostics name the consuming statement
# ---------------------------------------------------------------------------


def test_lod_message_names_trip_consumer():
    prog, arrays, params = programs.get("spmv_ldtrip").make(8)
    with pytest.raises(
        daelib.LossOfDecoupling,
        match=r"trip of loop 'k' depends on protected load\(s\) \['ld_len'\]",
    ):
        daelib.decouple(prog)


def test_lod_message_names_local_and_its_consumer():
    prog, arrays, params = programs.get("chase_sum").make(8)
    with pytest.raises(
        daelib.LossOfDecoupling,
        match=(
            r"AGU local 'cur' \(SetLocal feeding address of op 'ld_nxt'\) "
            r"depends on protected load\(s\) \['ld_nxt'\]"
        ),
    ):
        daelib.decouple(prog)


def test_lod_message_names_address_consumer():
    loop = ir.Loop("i", ir.Const(4), (
        ir.Load("ld_a", "x", ir.Var("i")),
        ir.Load("ld_b", "x", ir.LoadVal("ld_a")),
    ))
    prog = ir.Program("addr", loops=(loop,))
    with pytest.raises(
        daelib.LossOfDecoupling,
        match=r"address of op 'ld_b' depends on protected load\(s\) \['ld_a'\]",
    ):
        daelib.decouple(prog)


def test_cross_pe_load_dependence_always_rejects():
    prog = ir.Program("xpe", loops=(
        ir.Loop("i", ir.Const(2), (ir.Load("ld_a", "x", ir.Var("i")),)),
        ir.Loop("j", ir.Const(2), (
            ir.Load("ld_b", "x", ir.LoadVal("ld_a")),
        )),
    ))
    # both modes name the real blocker — "off" must not promise an
    # auto that would just re-reject (the predicted port has to live
    # in the PE whose AGU consumes it)
    for mode in ("off", "auto"):
        with pytest.raises(daelib.LossOfDecoupling, match="cross-PE"):
            daelib.decouple(prog, speculation=mode)


def test_self_bounding_trip_rejects_even_under_auto():
    from repro.core import executor

    prog = ir.Program("selftrip", loops=(
        ir.Loop("i", ir.Const(3), (
            ir.Loop("k", ir.LoadVal("ld_in"), (
                ir.Load("ld_in", "x", ir.Var("k")),
            )),
        )),
    ))
    with pytest.raises(daelib.LossOfDecoupling, match="cannot run ahead"):
        simulator.simulate(prog, {"x": np.zeros(4)}, {}, speculation="auto")
    # the wave executor raises the same documented rejection
    with pytest.raises(daelib.LossOfDecoupling, match="cannot run ahead"):
        executor.execute(prog, {"x": np.zeros(4)}, {}, speculation="auto")


def test_unrelated_keyerrors_are_not_masked_as_lod():
    """A typo'd Read array must surface as a plain KeyError, not be
    misattributed to the speculation subsystem's auto-reject."""
    prog = ir.Program("typo", loops=(
        ir.Loop("i", ir.Const(3), (
            ir.Load("ld_len", "lens", ir.Var("i")),
            ir.Loop("k", ir.LoadVal("ld_len"), (
                ir.Load("ld_x", "x", ir.Read("MISSING", ir.Var("k"))),
            )),
        )),
    ))
    arrays = {"lens": np.ones(3), "x": np.zeros(4)}
    with pytest.raises(KeyError, match="MISSING") as exc:
        simulator.simulate(prog, arrays, {}, speculation="auto")
    assert not isinstance(exc.value, daelib.LossOfDecoupling)


# ---------------------------------------------------------------------------
# §6 mis-speculation substrate (the contract speculation builds on)
# ---------------------------------------------------------------------------


def _guarded_program(n=8):
    prog = ir.Program("g", loops=(
        ir.Loop("i", ir.Param("n", 0, n), (
            ir.Load("ld_v", "v", ir.Var("i")),
            ir.Store(
                "st_v", "v", ir.Var("i"),
                ir.LoadVal("ld_v") * 2.0,
                guard=ir.Bin(">", ir.LoadVal("ld_v"), ir.Const(0.0)),
            ),
        )),
    ), params=("n",))
    v = np.array([1.0, -1.0, 2.0, -2.0, 3.0, -3.0, 4.0, -4.0][:n])
    return prog, {"v": v}, {"n": n}


def test_trace_hook_reports_invalid_stores():
    """§6: a guarded-false store is reported valid=False, value=None —
    the request exists even when the effect doesn't."""
    prog, arrays, params = _guarded_program()
    rows = []
    ir.interpret(
        prog, arrays, params,
        trace_hook=lambda *a: rows.append(a),
    )
    st = [r for r in rows if r[0] == "st_v"]
    assert len(st) == params["n"]  # every iteration produced a request
    for i, (_op, addr, is_store, valid, value) in enumerate(st):
        assert is_store and addr == i
        if i % 2 == 0:  # positive values: guard holds
            assert valid and value == arrays["v"][i] * 2.0
        else:
            assert valid is False and value is None


@pytest.mark.parametrize("engine", ("cycle", "event"))
def test_engines_preserve_invalid_request_existence(engine):
    """Both engines keep mis-speculated stores in the request stream:
    they issue, occupy the pending buffer, ACK without DRAM (Fig. 7)."""
    prog, arrays, params = _guarded_program()
    comp = simulator.Compiled(prog, forwarding=False)
    traces = schedlib.trace_program(prog, comp.dae, arrays, params)
    n = params["n"]
    assert traces["st_v"].n_req == n  # AGU emits all requests (§6)
    p = simulator.SimParams()
    if engine == "event":
        eng = engine_event.EventEngine(
            comp, traces, arrays, params, "FUS1", p
        )
        res = eng.run()
        port = eng.ports["st_v"]
        assert port.head == port.n == n  # all requests drained
        assert list(port.valid) == [i % 2 == 0 for i in range(n)]
    else:
        eng = simulator.Engine(comp, traces, arrays, params, "FUS1", p)
        res = eng.run()
        port = eng.ports["st_v"]
        assert port.exhausted and not port.pending
        assert port.acked_count == n
    # invalid stores never touched DRAM: store DRAM traffic = valid half
    assert res.dram_requests == n + n // 2
    oracle = ir.interpret(prog, arrays, params)
    np.testing.assert_array_equal(res.arrays["v"], oracle["v"])


# ---------------------------------------------------------------------------
# SpecPlan structure
# ---------------------------------------------------------------------------


def test_spec_plan_structure():
    prog, arrays, params = programs.get("spmv_ldtrip").make(32)
    dae = daelib.decouple(prog, speculation="auto")
    assert list(dae.spec) != []
    spec_out = []
    traces = schedlib.trace_program(
        prog, dae, arrays, params, spec_out=spec_out
    )
    plan = spec_out[0]
    assert isinstance(plan, speculate.SpecPlan)
    # every trip-load occurrence is either predicted or confidence-
    # suppressed into a wait gate — nothing falls through
    assert plan.predictions + plan.wait_gates == traces["ld_len"].n_req
    assert 0 < plan.mispredictions <= plan.predictions
    assert plan.n_gates == plan.mispredictions + plan.wait_gates
    assert plan.n_gates == len(plan.phantoms)
    # gate kinds partition the gates; phantoms only behind squashes
    kinds = [plan.gate_kind[g] for g in range(plan.n_gates)]
    assert kinds.count("squash") == plan.mispredictions
    assert kinds.count("wait") == plan.wait_gates
    for gid, lst in enumerate(plan.phantoms):
        if plan.gate_kind[gid] == "wait":
            assert lst == []
    # epoch tags are non-decreasing along every stream and only ever
    # point at allocated gates
    for op_id, g in plan.gates.items():
        assert len(g) == traces[op_id].n_req
        assert (np.diff(g) >= 0).all(), op_id
        assert g.max(initial=-1) < plan.n_gates
    # trigger/resolve consistency
    for gid, (op_id, k) in enumerate(plan.triggers):
        assert plan.resolve_of[op_id][k] == gid
    # phantom accounting matches the counters and respects the cap
    total = sum(c for lst in plan.phantoms for (_o, c, _s) in lst)
    assert total == plan.phantom_requests
    per_gate_op: dict = {}
    for gid, lst in enumerate(plan.phantoms):
        for op_id, c, _s in lst:
            per_gate_op[(gid, op_id)] = per_gate_op.get((gid, op_id), 0) + c
    assert all(c <= plan.runahead for c in per_gate_op.values())


def test_perfect_prediction_single_gate():
    """Uniform row lengths: only the cold-start prediction misses.

    Confidence gating shapes the trace: the cold miss (conf 4 -> 2)
    suppresses the next two occurrences into wait gates while the
    counter climbs back (3, then 4); the last three speculate and hit.
    """
    prog = ir.Program("uni", loops=(
        ir.Loop("i", ir.Const(6), (
            ir.Load("ld_len", "lens", ir.Var("i")),
            ir.Loop("k", ir.LoadVal("ld_len"), (
                ir.Load("ld_x", "x", ir.Var("k")),
            )),
        )),
    ))
    arrays = {"lens": np.full(6, 3.0), "x": np.zeros(8)}
    dae = daelib.decouple(prog, speculation="auto")
    spec_out = []
    schedlib.trace_program(prog, dae, arrays, {}, spec_out=spec_out)
    plan = spec_out[0]
    assert plan.predictions == 4  # occurrences 1, 4, 5, 6 speculate
    assert plan.mispredictions == 1  # 0.0 -> 3.0 cold start only
    assert plan.wait_gates == 2  # occurrences 2-3 suppressed
    assert plan.phantom_requests == 0  # under-prediction squashes nothing


# ---------------------------------------------------------------------------
# DSE axis
# ---------------------------------------------------------------------------


def test_result_key_folds_speculation_for_decoupled_kernels():
    from repro import dse

    a = dse.SweepPoint(kernel="RAWloop", scale=32, speculation="off")
    b = dse.SweepPoint(kernel="RAWloop", scale=32, speculation="auto")
    assert a.spec_class == b.spec_class == "-"
    assert a.result_key == b.result_key
    assert a.point_id != b.point_id  # still distinct requested points
    # squash_latency is projected out unless the point speculates
    c = dse.SweepPoint(
        kernel="RAWloop", scale=32, sim=(("squash_latency", 9),)
    )
    assert c.result_key == a.result_key
    d = dse.SweepPoint(kernel="spmv_ldtrip", scale=32, speculation="auto")
    e = dse.SweepPoint(
        kernel="spmv_ldtrip", scale=32, speculation="auto",
        sim=(("squash_latency", 9),),
    )
    assert d.spec_class == "auto"
    assert d.result_key != e.result_key


def test_result_key_folds_predictor_and_runahead():
    """The predictor/run-ahead axes share result identity with
    ``speculation``: folded to ``"-"`` wherever the knob cannot reach
    a gate, distinct where it can."""
    from repro import dse

    # non-speculating points: predictor and spec_runahead fold away
    a = dse.SweepPoint(kernel="RAWloop", scale=32, predictor="last")
    b = dse.SweepPoint(kernel="RAWloop", scale=32, predictor="context")
    assert a.predictor_class == b.predictor_class == "-"
    assert a.runahead_class == b.runahead_class == "-"
    assert a.result_key == b.result_key
    c = dse.SweepPoint(
        kernel="RAWloop", scale=32, sim=(("spec_runahead", 4),)
    )
    assert c.result_key == a.result_key
    # STA never consults the SpecPlan either, even on spec kernels
    s1 = dse.SweepPoint(
        kernel="spmv_ldtrip", scale=32, mode="STA",
        speculation="auto", predictor="last",
    )
    s2 = dse.SweepPoint(
        kernel="spmv_ldtrip", scale=32, mode="STA",
        speculation="auto", predictor="stride",
    )
    assert s1.predictor_class == s2.predictor_class == "-"
    assert s1.result_key == s2.result_key
    # speculating points: distinct predictors are distinct results...
    d = dse.SweepPoint(
        kernel="spmv_ldtrip", scale=32, speculation="auto",
        predictor="last",
    )
    e = dse.SweepPoint(
        kernel="spmv_ldtrip", scale=32, speculation="auto",
        predictor="stride",
    )
    assert d.predictor_class == "last" and e.predictor_class == "stride"
    assert d.result_key != e.result_key
    # ...and so are distinct run-ahead windows (default surfaces too)
    f = dse.SweepPoint(
        kernel="spmv_ldtrip", scale=32, speculation="auto",
        predictor="last", sim=(("spec_runahead", 4),),
    )
    assert d.runahead_class == simulator.SimParams().spec_runahead
    assert f.runahead_class == 4
    assert d.result_key != f.result_key


def test_planner_folds_predictor_axis_into_shared_runs():
    """A predictor sweep over {STA, FUS2} on a speculative kernel runs
    STA once: the planner groups by predictor *class*, so the four STA
    points share one group while FUS2 gets one per predictor."""
    from repro.dse import planner
    from repro.dse.spec import SweepSpec

    pts = SweepSpec(
        kernels=["spmv_ldtrip"], scales={"spmv_ldtrip": 16},
        modes=("STA", "FUS2"), speculations=("auto",),
        predictors=daelib.PREDICTORS,
    ).points()
    assert len(pts) == 2 * len(daelib.PREDICTORS)
    groups = planner.plan(pts)
    sta = [g for g in groups if all(r.rep.mode == "STA" for r in g.runs)]
    fus = [g for g in groups if all(r.rep.mode == "FUS2" for r in g.runs)]
    assert len(sta) == 1 and len(sta[0].runs) == 1  # one run serves all
    assert len(sta[0].runs[0].point_indices) == len(daelib.PREDICTORS)
    assert len(fus) == len(daelib.PREDICTORS)
    assert sorted(g.predictor for g in fus) == sorted(daelib.PREDICTORS)


def test_sweep_matches_standalone_on_spec_kernels():
    from repro import dse

    spec = dse.SweepSpec(
        kernels=["spmv_ldtrip", "bfs_front"],
        scales={"spmv_ldtrip": 16, "bfs_front": 24},
        modes=("STA", "FUS2"),
        speculations=("auto",),
        predictors=("last", "context"),
    )
    res = dse.sweep(spec, validate=True)
    for pr in res.points:
        p = pr.point
        prog, arrays, params = programs.get(p.kernel).make(p.scale)
        base = simulator.simulate(
            prog, arrays, params, mode=p.mode, sim=p.sim_params(),
            engine=p.engine, trace_mode=p.trace_mode,
            speculation=p.speculation, predictor=p.predictor,
        )
        assert base.cycles == pr.result.cycles, p
        assert base.squashed == pr.result.squashed
        for k in base.arrays:
            np.testing.assert_array_equal(base.arrays[k], pr.result.arrays[k])


# ---------------------------------------------------------------------------
# TABLE1 freeze (the paper's evaluation set may not silently grow)
# ---------------------------------------------------------------------------


def test_table1_is_frozen_and_registry_superset():
    assert programs.TABLE1 == (
        "RAWloop", "WARloop", "WAWloop", "bnn", "pagerank", "fft",
        "matpower", "hist+add", "tanh+spmv",
    )
    assert set(programs.TABLE1) <= set(programs.REGISTRY)
    # speculative kernels are registered but never in Table 1
    assert programs.SPEC_KERNELS != ()
    assert not set(programs.SPEC_KERNELS) & set(programs.TABLE1)
    for name in programs.TABLE1:
        assert not programs.REGISTRY[name].speculative


# ---------------------------------------------------------------------------
# random differential (nightly fuzz reuses the hypothesis wrapper)
# ---------------------------------------------------------------------------


def _check_spec_differential(pap):
    prog, arrays, params = pap
    dae = daelib.decouple(prog, speculation="auto")
    assert dae.spec, "generator must produce a speculative PE"
    with pytest.raises(daelib.LossOfDecoupling):
        daelib.decouple(prog)
    oracle = ir.interpret(prog, arrays, params)
    for engine in ("cycle", "event"):
        res = simulator.simulate(
            prog, arrays, params, mode="FUS2", engine=engine,
            speculation="auto", validate=True,
        )
        for k in oracle:
            np.testing.assert_array_equal(
                res.arrays[k], oracle[k], err_msg=f"{engine}/{k}"
            )


def _check_predictor_differential(pap):
    """Oracle-exactness under *every* predictor knob, both engines —
    the predictor changes the gate schedule, never the committed
    values (speculate.py's oracle-stream soundness argument)."""
    prog, arrays, params = pap
    dae = daelib.decouple(prog, speculation="auto")
    assert dae.spec, "generator must produce a speculative PE"
    oracle = ir.interpret(prog, arrays, params)
    for pred in daelib.PREDICTORS:
        for engine in ("cycle", "event"):
            res = simulator.simulate(
                prog, arrays, params, mode="FUS2", engine=engine,
                speculation="auto", predictor=pred, validate=True,
            )
            for k in oracle:
                np.testing.assert_array_equal(
                    res.arrays[k], oracle[k], err_msg=f"{pred}/{engine}/{k}"
                )


@pytest.mark.parametrize("seed", range(25))
def test_spec_differential_seeded(seed):
    _check_spec_differential(
        strat.random_spec_program(np.random.default_rng(2000 + seed))
    )


@pytest.mark.parametrize("seed", range(25))
def test_stride_predictor_differential_seeded(seed):
    _check_predictor_differential(
        strat.random_stride_spec_program(np.random.default_rng(3000 + seed))
    )


@pytest.mark.parametrize("seed", range(25))
def test_context_predictor_differential_seeded(seed):
    _check_predictor_differential(
        strat.random_context_spec_program(np.random.default_rng(4000 + seed))
    )


if strat.HAVE_HYPOTHESIS:
    from hypothesis import given

    @given(strat.spec_programs())
    def test_spec_differential(pap):
        _check_spec_differential(pap)

    @given(strat.stride_spec_programs())
    def test_stride_predictor_differential(pap):
        _check_predictor_differential(pap)

    @given(strat.context_spec_programs())
    def test_context_predictor_differential(pap):
        _check_predictor_differential(pap)
