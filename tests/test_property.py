"""Property-based tests (hypothesis): the hazard machinery preserves
sequential semantics on randomized monotonic loop programs, and the
compiler analyses are conservative."""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install .[test])",
)
from hypothesis import given, settings, strategies as st

from repro.core import cr, executor, loopir as ir, simulator
from repro.kernels.du_hazard.ref import hazard_frontier_ref


# ---------------------------------------------------------------------------
# random two-loop programs with monotonic (sorted) data-dependent streams
# ---------------------------------------------------------------------------


@st.composite
def fused_pair_program(draw):
    """Producer loop storing through a sorted index stream; consumer loop
    with load (+ optional store) through another sorted stream — the
    paper's Fig. 1 shape with randomized address distributions."""
    n1 = draw(st.integers(4, 24))
    n2 = draw(st.integers(4, 24))
    mem = draw(st.integers(8, 32))
    rng = np.random.default_rng(draw(st.integers(0, 2**31)))
    idx1 = np.sort(rng.integers(0, mem, size=n1)).astype(np.int64)
    idx2 = np.sort(rng.integers(0, mem, size=n2)).astype(np.int64)
    consumer_writes = draw(st.booleans())
    hint = ir.MonotonicHint(True, frozenset())

    body2 = [ir.Load("ld_c", "A", ir.Read("idx2", ir.Var("j")), hint=hint)]
    if consumer_writes:
        body2.append(
            ir.Store(
                "st_c", "A", ir.Read("idx2", ir.Var("j")),
                ir.LoadVal("ld_c") * 0.5 + 1.0, hint=hint,
            )
        )
    body2.append(
        ir.Store("st_out", "out", ir.Var("j"), ir.LoadVal("ld_c") + 2.0)
    )
    prog = ir.Program(
        "prop",
        loops=(
            ir.Loop("i", ir.Param("n1", 0, n1), (
                ir.Store(
                    "st_p", "A", ir.Read("idx1", ir.Var("i")),
                    ir.Read("vals", ir.Var("i")), hint=hint,
                ),
            )),
            ir.Loop("j", ir.Param("n2", 0, n2), tuple(body2)),
        ),
        params=("n1", "n2"),
    )
    arrays = {
        "A": rng.standard_normal(mem),
        "out": np.zeros(n2),
        "idx1": idx1,
        "idx2": idx2,
        "vals": rng.standard_normal(n1),
    }
    return prog, arrays, {"n1": n1, "n2": n2}


@settings(max_examples=25, deadline=None)
@given(
    fused_pair_program(),
    st.sampled_from(["LSQ", "FUS1", "FUS2"]),
    st.sampled_from(["cycle", "event"]),
)
def test_random_monotonic_programs_preserve_semantics(pa, mode, engine):
    prog, arrays, params = pa
    oracle = ir.interpret(prog, arrays, params)
    res = simulator.simulate(
        prog, arrays, params, mode=mode, validate=True, engine=engine
    )
    for k in oracle:
        np.testing.assert_allclose(res.arrays[k], oracle[k], atol=1e-9)


@settings(max_examples=25, deadline=None)
@given(fused_pair_program())
def test_wave_executor_random_programs(pa):
    prog, arrays, params = pa
    res = executor.execute(prog, arrays, params)  # asserts vs oracle inside
    oracle = ir.interpret(prog, arrays, params)
    for k in oracle:
        np.testing.assert_allclose(res.arrays[k], oracle[k], atol=1e-9)


# ---------------------------------------------------------------------------
# frontier merge == brute-force count (monotonicity insight, §3.1)
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(
    st.lists(st.integers(0, 100), min_size=1, max_size=64),
    st.lists(st.integers(0, 120), min_size=1, max_size=64),
)
def test_frontier_merge_equals_bruteforce(src, dst):
    import jax.numpy as jnp

    src_sorted = jnp.asarray(sorted(src), jnp.int32)
    dst_a = jnp.asarray(dst, jnp.int32)
    got = np.asarray(hazard_frontier_ref(src_sorted, dst_a))
    brute = np.array([sum(1 for s in sorted(src) if s <= d) for d in dst])
    np.testing.assert_array_equal(got, brute)


# ---------------------------------------------------------------------------
# §3.4.1 conservativeness: flagged-monotonic outer depths never reset
# ---------------------------------------------------------------------------


@st.composite
def affine_2d_addr(draw):
    stride_outer = draw(st.integers(0, 12))
    stride_inner = draw(st.integers(0, 4))
    trip_i = draw(st.integers(1, 6))
    trip_j = draw(st.integers(1, 6))
    base = draw(st.integers(0, 5))
    return stride_outer, stride_inner, trip_i, trip_j, base


@settings(max_examples=60, deadline=None)
@given(affine_2d_addr())
def test_non_monotonic_detection_conservative(params):
    so, si, ti, tj, base = params
    loops = (
        ir.Loop("i", ir.Param("TI", ti, ti), (
            ir.Loop("j", ir.Param("TJ", tj, tj), (
                ir.Load(
                    "ld", "A",
                    ir.Const(base) + ir.Var("i") * so + ir.Var("j") * si,
                ),
            )),
        )),
    )
    from repro.core import monotonic as mono

    prog = ir.Program("t", loops=loops)
    op, path = prog.mem_ops()[0]
    info = mono.analyze_op(op, path)

    # ground truth: enumerate the address stream
    addrs = [
        base + i * so + j * si for i in range(ti) for j in range(tj)
    ]
    truly_monotonic_outer = all(
        addrs[(i + 1) * tj] >= addrs[(i + 1) * tj - 1] for i in range(ti - 1)
    ) if ti > 1 else True

    # NEVER a false negative: if analysis says monotonic, it must be true
    if 1 not in info.non_monotonic:
        assert truly_monotonic_outer
    # innermost: si >= 0 always -> must be monotonic
    assert info.innermost_monotonic


# ---------------------------------------------------------------------------
# schedule counters never decrease; sentinel ordering
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(fused_pair_program())
def test_schedule_counters_monotone(pa):
    from repro.core import dae as daelib, schedule as schedlib

    prog, arrays, params = pa
    d = daelib.decouple(prog)
    traces = schedlib.trace_program(prog, d, arrays, params)
    for t in traces.values():
        for depth in range(t.depth):
            col = t.sched[:, depth]
            assert (np.diff(col) >= 0).all()


# ---------------------------------------------------------------------------
# compiled-trace invariants on the shared affine strategy
# (tests/loopir_strategies.py; the exact compiled-vs-interp differential
# lives in tests/test_trace_compile.py)
# ---------------------------------------------------------------------------


from loopir_strategies import affine_programs  # noqa: E402


# budget governed by the loopir_strategies profile (tier1 / nightly)
@given(affine_programs())
def test_compiled_schedule_invariants(pa):
    """Compiled traces satisfy the §4 schedule contract on random affine
    programs: per-depth counters never decrease within a stream, seq is
    strictly increasing per op, and every PE's seq numbers form one
    contiguous 0..n-1 interleave."""
    from repro.core import dae as daelib, schedule as schedlib

    prog, arrays, params = pa
    d = daelib.decouple(prog)
    traces = schedlib.trace_program(prog, d, arrays, params, mode="compiled")
    by_pe: dict[int, list] = {}
    for t in traces.values():
        for depth in range(t.depth):
            assert (np.diff(t.sched[:, depth]) >= 0).all()
        if t.n_req:
            assert (np.diff(t.seq) > 0).all()
        by_pe.setdefault(t.pe_id, []).append(t)
    for ts in by_pe.values():
        seqs = np.sort(np.concatenate([t.seq for t in ts]))
        np.testing.assert_array_equal(seqs, np.arange(len(seqs)))
