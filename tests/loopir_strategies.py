"""Shared random-program generators: LoopIR programs inside the
affine/compilable subset (core/affine.py).

Used by tests/test_trace_compile.py (the differential fuzz suite pinning
the compiled AGU/CU front-end to the interpreter bit for bit) and by
tests/test_property.py (schedule-invariant properties). The generator
deliberately covers the edge cases the trace compiler has to get right:

  * mixed-depth forests (parent-body ops before inner loops — the
    Fig. 3 'pending' assignment; statements *after* an inner loop are
    outside the decoupling contract and are not generated),
  * zero-trip loops (constant zero AND outer-var-dependent trips that
    go negative — ``range`` semantics clamp to empty),
  * params-dependent and Read-gather (CSR-style ragged) trip counts,
  * additive ivars with iteration-varying steps, multiplicative ivars
    with invariant steps (FFT's ``stride *= 2``),
  * unpredictable loops (lastIter hint degrades to 0),
  * data-dependent addresses through (nested) Read gathers.

The cores are plain ``numpy.random.Generator`` functions so the
differential suite runs even without hypothesis; when hypothesis is
available they are wrapped as strategies (``affine_programs()``,
``loadfree_cu_programs()``) drawing the seed, and two profiles are
registered: the default (tier-1 budget, untouched) and ``nightly``
(bigger example budget for the scheduled CI fuzz job, selected with
``HYPOTHESIS_PROFILE=nightly`` and typically ``--hypothesis-seed=random``).
"""

from __future__ import annotations

import os

import numpy as np

from repro.core import loopir as ir

try:  # hypothesis is an optional test dependency (pip install .[test])
    from hypothesis import settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

# read-only arrays every generated program may gather from
_N_IDX = 24


def _choice(rng, options):
    return options[int(rng.integers(0, len(options)))]


def _affine_term(rng, vars_visible: list[str]) -> ir.Expr:
    """A small affine term over the visible loop vars/ivars."""
    if not vars_visible:
        return ir.Const(int(rng.integers(0, 7)))
    v = ir.Var(_choice(rng, vars_visible))
    c = int(rng.integers(0, 5))
    k = int(rng.integers(1, 4))
    return v * k + c


def _addr_expr(rng, vars_visible: list[str]) -> ir.Expr:
    """Address/index expression: affine combo, optionally through a Read
    gather (bounded by %) or a nested gather-of-gather."""
    kind = _choice(rng, ["affine", "read", "nested", "param"])
    base = _affine_term(rng, vars_visible)
    if kind == "affine":
        return base + _affine_term(rng, vars_visible)
    if kind == "param":
        return base + ir.Param("P", 0, 8)
    idx = ir.Bin("%", base, ir.Const(_N_IDX))
    inner = ir.Read("idx_a", idx)
    if kind == "read":
        return inner + _affine_term(rng, vars_visible)
    return ir.Read("idx_b", ir.Bin("%", inner + base, ir.Const(_N_IDX)))


def _trip_expr(rng, outer_vars: list[str]) -> ir.Expr:
    kind = _choice(rng, ["const", "zero", "param", "outer", "read", "neg"])
    if kind == "const":
        return ir.Const(int(rng.integers(1, 5)))
    if kind == "zero":
        return ir.Const(0)
    if kind == "param":
        return ir.Param("P", 0, 8)
    if not outer_vars:  # outer/read/neg need an enclosing var
        return ir.Const(int(rng.integers(0, 4)))
    v = ir.Var(_choice(rng, outer_vars))
    if kind == "outer":
        return v + int(rng.integers(0, 3))
    if kind == "neg":
        # goes negative for later iterations -> range() clamps to empty
        return ir.Bin("-", ir.Const(int(rng.integers(0, 4))), v)
    return ir.Read("trips", ir.Bin("%", v, ir.Const(_N_IDX)))


def _base_arrays(rng) -> dict[str, np.ndarray]:
    return {
        "idx_a": rng.integers(0, 40, size=_N_IDX).astype(np.int64),
        "idx_b": rng.integers(0, 40, size=_N_IDX).astype(np.int64),
        "trips": rng.integers(0, 4, size=_N_IDX).astype(np.int64),
        "vals": rng.standard_normal(_N_IDX),
        "A": np.zeros(1, dtype=np.float64),  # never dereferenced in tracing
    }


def random_affine_program(rng, max_depth: int = 3):
    """A random loop forest inside the compiled subset, plus arrays and
    params. Every program decouples (no cross-PE locals, no LoadVals in
    addresses) and must compile exactly."""
    counter = {"loop": 0, "op": 0}
    arrays = _base_arrays(rng)
    params = {"P": int(rng.integers(0, 6))}

    def make_op(vars_visible):
        counter["op"] += 1
        oid = f"op{counter['op']}"
        addr = _addr_expr(rng, vars_visible)
        if rng.integers(0, 2):
            return ir.Store(oid, "A", addr, ir.Const(1.0))
        return ir.Load(oid, "A", addr)

    def make_ivars(var):
        ivars = []
        if rng.integers(0, 4) == 0:
            name = f"iv{counter['loop']}"
            if rng.integers(0, 2):
                # '+' ivar; step may vary with this loop's own var
                step = (
                    ir.Var(var) + int(rng.integers(0, 3))
                    if rng.integers(0, 2)
                    else ir.Const(int(rng.integers(0, 4)))
                )
                ivars.append(
                    ir.IVar(name, ir.Const(int(rng.integers(0, 4))), "+", step)
                )
            else:
                # '*' ivar: loop-invariant integer step (FFT-style)
                ivars.append(
                    ir.IVar(
                        name,
                        ir.Const(int(rng.integers(1, 3))),
                        "*",
                        ir.Const(int(rng.integers(2, 4))),
                    )
                )
        return ivars

    def make_loop(depth, outer_vars):
        counter["loop"] += 1
        var = f"v{counter['loop']}"
        ivars = make_ivars(var)
        visible = outer_vars + [var] + [iv.name for iv in ivars]
        body = []
        # ops at this depth, before any inner loop (parent-body 'pending')
        for _ in range(int(rng.integers(0, 3))):
            body.append(make_op(visible))
        if depth < max_depth and rng.integers(0, 3) > 0:
            # note: only *leading* parent-body ops — statements after an
            # inner loop are outside the decoupling contract (Fig. 3
            # replicates only the control of the leaf's own ancestors)
            for _ in range(int(rng.integers(1, 3))):
                body.append(make_loop(depth + 1, visible))
        if not any(isinstance(s, (ir.Load, ir.Store, ir.Loop)) for s in body):
            body.append(make_op(visible))
        return ir.Loop(
            var,
            _trip_expr(rng, outer_vars),
            tuple(body),
            ivars=tuple(ivars),
            predictable=bool(rng.integers(0, 2)),
        )

    loops = tuple(
        make_loop(1, []) for _ in range(int(rng.integers(1, 3)))
    )
    prog = ir.Program("fuzz", loops=loops, params=("P",))
    return prog, arrays, params


def random_spec_program(rng, max_rows: int = 6):
    """Random loss-of-decoupling programs: an inner trip count (and
    sometimes a store address) depends on a protected load value, so
    ``dae.decouple`` only admits them under ``speculation="auto"``
    (DESIGN.md §10). Length values repeat (a small pool) so the
    last-value predictor hits sometimes and misses sometimes — both
    squash paths get exercised. Used by the speculation differential
    in tests/test_speculation.py (deterministic seeds in tier-1, the
    hypothesis wrapper in the nightly fuzz job)."""
    rows = int(rng.integers(1, max_rows + 1))
    pool = [int(rng.integers(0, 4)) for _ in range(int(rng.integers(1, 3)))]
    lens = np.array(
        [pool[int(rng.integers(0, len(pool)))] for _ in range(rows)],
        dtype=np.float64,
    )
    arrays = {
        "lens": lens.copy(),
        "src": lens.copy(),
        "data": rng.standard_normal(64),
        "out": np.zeros(64, dtype=np.float64),
    }
    loops = []
    if rng.integers(0, 2):
        # producer publishes the lengths -> a cross-PE RAW into the
        # speculative consumer's trip load
        arrays["lens"] = np.zeros(rows, dtype=np.float64)
        loops.append(
            ir.Loop("p", ir.Const(rows), (
                ir.Store("st_lens", "lens", ir.Var("p"), ir.Read("src", ir.Var("p"))),
            ))
        )

    # trip: LoadVal, LoadVal + c, or LoadVal - 1 (may clamp to empty)
    lv = ir.LoadVal("ld_len")
    trip = _choice(rng, [lv, lv + int(rng.integers(1, 3)), ir.Bin("-", lv, ir.Const(1))])
    inner = [
        ir.Load("ld_d", "data", ir.Bin("%", ir.Var("k") * 3 + ir.Var("i"), ir.Const(64))),
    ]
    if rng.integers(0, 2):
        # load-dependent *address* as well: epoch-gated store stream
        st_addr = ir.Bin("%", lv * 2 + ir.Var("k"), ir.Const(64))
    else:
        st_addr = ir.Bin("%", ir.Var("i") * 5 + ir.Var("k"), ir.Const(64))
    inner.append(
        ir.Store("st_o", "out", st_addr, ir.LoadVal("ld_d") + 0.5)
    )
    loops.append(
        ir.Loop("i", ir.Const(rows), (
            ir.Load("ld_len", "lens", ir.Var("i")),
            ir.Loop("k", trip, tuple(inner),
                    predictable=bool(rng.integers(0, 2))),
        ))
    )
    prog = ir.Program("specfuzz", loops=tuple(loops))
    return prog, arrays, {}


def random_stride_spec_program(rng, max_n: int = 12):
    """Random loss-of-decoupling programs whose speculative load value
    stream is (mostly) *stride-patterned*: an AGU local walks a pointer
    array whose stored values form an arithmetic sequence — sometimes
    with injected irregularities (a few perturbed entries) so the
    stride predictor also mispredicts and recovers. Exercises the
    stride component of the predictor zoo plus confidence re-enable
    (DESIGN.md §10). The differential in tests/test_speculation.py runs
    these under every predictor knob."""
    n = int(rng.integers(3, max_n + 1))
    stride = int(rng.integers(1, 4))
    size = n * stride + 4
    ptr = np.arange(size, dtype=np.float64) + stride
    # optionally perturb a few entries on the walked path (still within
    # bounds): stride mispredicts there and must re-learn
    if rng.integers(0, 2):
        for _ in range(int(rng.integers(1, 3))):
            j = int(rng.integers(0, n)) * stride
            ptr[j] = float(int(rng.integers(0, size - 1)))
    arrays = {
        "ptr": ptr,
        "out": np.zeros(n, dtype=np.float64),
        "w": rng.standard_normal(size),
    }
    prog = ir.Program("stridefuzz", loops=(
        ir.Loop("o", ir.Const(1), (
            ir.SetLocal("cur", ir.Const(0)),
            ir.Loop("i", ir.Const(n), (
                ir.Load("ld_p", "ptr",
                        ir.Bin("%", ir.Local("cur"), ir.Const(size))),
                ir.SetLocal("cur", ir.LoadVal("ld_p")),
                ir.Store("st_o", "out", ir.Var("i"),
                         ir.Read("w", ir.Bin("%", ir.LoadVal("ld_p"),
                                             ir.Const(size)))
                         + ir.LoadVal("ld_p")),
            )),
        )),
    ))
    return prog, arrays, {}


def random_context_spec_program(rng, max_n: int = 8):
    """Random loss-of-decoupling programs whose speculative load value
    stream is *context-repeating*: a pointer cycle over a small node
    set, traversed several laps — the value following each value is a
    function of it, so the context-table predictor locks on after lap 1
    while last/stride keep missing. Sometimes the chain is re-linked
    mid-run (a node's successor rewritten before the walk by a producer
    loop) so the table also goes stale and re-learns. Exercises the
    context component of the predictor zoo (DESIGN.md §10)."""
    n = int(rng.integers(2, max_n + 1))
    laps = int(rng.integers(2, 5))
    steps = laps * n
    order = rng.permutation(n).astype(np.int64)
    nxt = np.empty(n, dtype=np.int64)
    nxt[order] = np.roll(order, -1)
    arrays = {
        "nxt": nxt.astype(np.float64),
        "out": np.zeros(steps, dtype=np.float64),
        "w": rng.standard_normal(n),
    }
    loops = []
    if rng.integers(0, 2):
        # producer rewrites one link before the walk (cross-PE RAW into
        # the speculative port's array): the walk sees the new chain
        j = int(rng.integers(0, n))
        arrays["fix"] = np.array([float(int(rng.integers(0, n)))])
        loops.append(ir.Loop("p", ir.Const(1), (
            ir.Store("st_fix", "nxt", ir.Var("p") + j,
                     ir.Read("fix", ir.Var("p"))),
        )))
    loops.append(ir.Loop("o", ir.Const(1), (
        ir.SetLocal("cur", ir.Const(0)),
        ir.Loop("i", ir.Const(steps), (
            ir.Load("ld_nxt", "nxt",
                    ir.Bin("%", ir.Local("cur"), ir.Const(n))),
            ir.SetLocal("cur", ir.LoadVal("ld_nxt")),
            ir.Store("st_o", "out", ir.Var("i"),
                     ir.Read("w", ir.Bin("%", ir.LoadVal("ld_nxt"),
                                         ir.Const(n)))
                     + ir.LoadVal("ld_nxt")),
        )),
    )))
    prog = ir.Program("ctxfuzz", loops=tuple(loops))
    return prog, arrays, {}


def random_wave_program(rng, max_depth: int = 2):
    """Random *executable* programs for the wave-plan property suite
    (tests/test_wave_plan.py): protected loads and stores over two
    arrays with affine and gathered (data-dependent) addresses, store
    values/§6 guards fed by LoadVals of same-body or ancestor-body
    loads, and the usual zero-trip/param/outer-dependent loop shapes.
    Unlike ``random_affine_program`` every address is bounded by
    construction (mod the array length), so the program interprets,
    decouples with speculation *off* (no LoadVal in addresses or
    trips) and builds a WavePlan end to end."""
    counter = {"loop": 0, "op": 0}
    mem = {"A": int(rng.integers(8, 33)), "B": int(rng.integers(8, 33))}
    arrays = {
        "A": rng.standard_normal(mem["A"]),
        "B": rng.standard_normal(mem["B"]),
        "idx_a": rng.integers(0, 64, size=_N_IDX).astype(np.int64),
        "trips": rng.integers(0, 4, size=_N_IDX).astype(np.int64),
        "vals": rng.standard_normal(_N_IDX),
    }
    params = {"P": int(rng.integers(0, 6))}

    def addr(vars_visible, arr):
        base = _affine_term(rng, vars_visible) + _affine_term(
            rng, vars_visible
        )
        if rng.integers(0, 2):
            base = ir.Read(
                "idx_a", ir.Bin("%", base, ir.Const(_N_IDX))
            ) + _affine_term(rng, vars_visible)
        return ir.Bin("%", base, ir.Const(mem[arr]))

    def make_op(vars_visible, loads):
        counter["op"] += 1
        oid = f"m{counter['op']}"
        arr = _choice(rng, ["A", "B"])
        a = addr(vars_visible, arr)
        if loads and rng.integers(0, 2):
            # store fed by a visible (same- or ancestor-body) load
            val = ir.LoadVal(_choice(rng, loads)) * 0.5 + float(
                rng.integers(0, 3)
            )
            if len(loads) > 1 and rng.integers(0, 2):
                val = val + ir.LoadVal(_choice(rng, loads))
            guard = None
            g = int(rng.integers(0, 3))
            if g == 1:
                guard = ir.Bin(
                    ">",
                    ir.Read("trips", ir.Bin(
                        "%", _affine_term(rng, vars_visible),
                        ir.Const(_N_IDX),
                    )),
                    ir.Const(int(rng.integers(0, 3))),
                )
            elif g == 2:
                guard = ir.Bin(
                    ">", ir.LoadVal(_choice(rng, loads)), ir.Const(0.0)
                )
            return ir.Store(oid, arr, a, val, guard=guard)
        if rng.integers(0, 2):
            loads.append(oid)
            return ir.Load(oid, arr, a)
        # load-free store (CU value chain)
        val = ir.Read(
            "vals",
            ir.Bin("%", _affine_term(rng, vars_visible), ir.Const(_N_IDX)),
        ) + float(rng.integers(0, 3))
        return ir.Store(oid, arr, a, val)

    def make_loop(depth, outer_vars, outer_loads):
        counter["loop"] += 1
        var = f"v{counter['loop']}"
        visible = outer_vars + [var]
        loads = list(outer_loads)  # ancestor-body loads stay visible
        body = [
            make_op(visible, loads) for _ in range(int(rng.integers(1, 4)))
        ]
        if depth < max_depth and rng.integers(0, 2):
            body.append(make_loop(depth + 1, visible, loads))
        return ir.Loop(
            var,
            _trip_expr(rng, outer_vars),
            tuple(body),
            predictable=bool(rng.integers(0, 2)),
        )

    loops = tuple(
        make_loop(1, [], []) for _ in range(int(rng.integers(1, 3)))
    )
    prog = ir.Program("wavefuzz", loops=loops, params=("P",))
    return prog, arrays, params


def random_loadfree_cu_program(rng, max_depth: int = 2):
    """Random programs whose PEs are all load-free value chains: stores
    with vectorizable values and (sometimes) §6 guards — the dae.VecCU
    subset, for the CU value-stream differential."""
    counter = {"loop": 0, "op": 0}
    arrays = _base_arrays(rng)
    params = {"P": int(rng.integers(0, 6))}

    def value_expr(vars_visible):
        kind = _choice(rng, ["const", "affine", "read", "unop"])
        if kind == "const":
            return ir.Const(float(rng.integers(-3, 4)))
        base = _affine_term(rng, vars_visible)
        if kind == "affine":
            return base * 2 + 1
        rd = ir.Read("vals", ir.Bin("%", base, ir.Const(_N_IDX)))
        if kind == "read":
            return rd + ir.Const(0.5)
        return ir.Un(_choice(rng, ["tanh", "relu", "abs", "sign"]), rd)

    def make_store(vars_visible):
        counter["op"] += 1
        oid = f"st{counter['op']}"
        guard = None
        if rng.integers(0, 2):
            g = ir.Read(
                "trips",
                ir.Bin("%", _affine_term(rng, vars_visible), ir.Const(_N_IDX)),
            )
            guard = ir.Bin(">", g, ir.Const(int(rng.integers(0, 4))))
        return ir.Store(
            oid, "A", _addr_expr(rng, vars_visible),
            value_expr(vars_visible), guard=guard,
        )

    def make_loop(depth, outer_vars):
        counter["loop"] += 1
        var = f"w{counter['loop']}"
        visible = outer_vars + [var]
        body = [make_store(visible) for _ in range(int(rng.integers(1, 3)))]
        if depth < max_depth and rng.integers(0, 2):
            body.append(make_loop(depth + 1, visible))
        return ir.Loop(
            var,
            _trip_expr(rng, outer_vars),
            tuple(body),
            predictable=bool(rng.integers(0, 2)),
        )

    loops = tuple(make_loop(1, []) for _ in range(int(rng.integers(1, 3))))
    prog = ir.Program("cufuzz", loops=loops, params=("P",))
    return prog, arrays, params


def random_stream_program(rng, max_stages: int = 3):
    """Random cross-PE FIFO streaming programs (DESIGN.md §11): a chain
    of 1..max_stages producer stages — sibling depth-1 leaves under one
    outer loop, each computing a scalar local (init at the shared depth,
    sometimes chained off the previous stage's streamed local, sometimes
    zero-trip so the init value becomes the token) — feeding a final
    read-modify-write consumer leaf whose store value (and sometimes §6
    guard) references one or more streamed locals directly. Every
    program passes ``fifo.analyze_program`` by construction: edges are
    forward, rates match (all leaves sit directly under the shared
    loop), and stores read streamed locals only directly."""
    n_stages = int(rng.integers(1, max_stages + 1))
    n_out = int(rng.integers(4, 13))
    arrays = {
        "data": rng.standard_normal(_N_IDX),
        "out": rng.standard_normal(n_out),
    }
    outer_trip = int(rng.integers(1, 5))

    def leaf_trip():
        kind = _choice(rng, ["one", "one", "small", "zero", "neg"])
        if kind == "one":
            return ir.Const(1)
        if kind == "small":
            return ir.Const(int(rng.integers(1, 4)))
        if kind == "zero":
            return ir.Const(0)
        # zero-trip for every outer iteration past the first
        return ir.Bin("-", ir.Const(1), ir.Var("t"))

    body = []
    op_n = [0]
    for s in range(n_stages):
        local = f"x{s}"
        body.append(ir.SetLocal(local, ir.Const(float(rng.integers(-2, 3)))))
        stage = []
        val = ir.Const(float(rng.integers(1, 3)))
        if rng.integers(0, 2):
            op_n[0] += 1
            lid = f"ld{op_n[0]}"
            stage.append(ir.Load(
                lid, "data",
                ir.Bin("%", ir.Var("t") * 3 + ir.Var(f"s{s}") + s,
                       ir.Const(_N_IDX)),
            ))
            val = ir.LoadVal(lid) * 0.5 + val
        if s > 0 and rng.integers(0, 2):
            # chain: this stage consumes the previous stage's stream
            val = val + ir.Local(f"x{s - 1}")
        if rng.integers(0, 2):
            val = val + ir.Local(local)  # accumulate across the leaf trip
        stage.append(ir.SetLocal(local, val))
        body.append(ir.Loop(f"s{s}", leaf_trip(), tuple(stage)))

    # final consumer: RMW on "out", value (and sometimes guard) over a
    # non-empty subset of the streamed locals
    used = sorted(
        set([int(rng.integers(0, n_stages))])
        | {s for s in range(n_stages) if rng.integers(0, 3) == 0}
    )
    sval = ir.LoadVal("ld_out") * 0.5
    for s in used:
        sval = sval + ir.Local(f"x{s}")
    guard = None
    if rng.integers(0, 2):
        guard = ir.Bin(">", ir.Local(f"x{used[-1]}"),
                       ir.Const(float(rng.integers(-1, 2))))
    addr = ir.Bin("%", ir.Var("t") * 2 + ir.Var("c"), ir.Const(n_out))
    body.append(ir.Loop("c", ir.Const(1), (
        ir.Load("ld_out", "out", addr),
        ir.Store("st_out", "out", addr, sval, guard=guard),
    )))
    prog = ir.Program(
        "streamfuzz",
        loops=(ir.Loop("t", ir.Const(outer_trip), tuple(body)),),
    )
    return prog, arrays, {}


if HAVE_HYPOTHESIS:
    # Example budgets come from profiles, NOT per-test @settings — a
    # pinned max_examples would silently override the nightly profile.
    settings.register_profile("tier1", max_examples=60, deadline=None)
    settings.register_profile("nightly", max_examples=250, deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "tier1"))

    @st.composite
    def affine_programs(draw, max_depth: int = 3):
        seed = draw(st.integers(0, 2**31))
        return random_affine_program(
            np.random.default_rng(seed), max_depth=max_depth
        )

    @st.composite
    def loadfree_cu_programs(draw, max_depth: int = 2):
        seed = draw(st.integers(0, 2**31))
        return random_loadfree_cu_program(
            np.random.default_rng(seed), max_depth=max_depth
        )

    @st.composite
    def spec_programs(draw, max_rows: int = 6):
        seed = draw(st.integers(0, 2**31))
        return random_spec_program(
            np.random.default_rng(seed), max_rows=max_rows
        )

    @st.composite
    def stride_spec_programs(draw, max_n: int = 12):
        seed = draw(st.integers(0, 2**31))
        return random_stride_spec_program(
            np.random.default_rng(seed), max_n=max_n
        )

    @st.composite
    def context_spec_programs(draw, max_n: int = 8):
        seed = draw(st.integers(0, 2**31))
        return random_context_spec_program(
            np.random.default_rng(seed), max_n=max_n
        )

    @st.composite
    def wave_programs(draw, max_depth: int = 2):
        seed = draw(st.integers(0, 2**31))
        return random_wave_program(
            np.random.default_rng(seed), max_depth=max_depth
        )

    @st.composite
    def stream_programs(draw, max_stages: int = 3):
        seed = draw(st.integers(0, 2**31))
        return random_stream_program(
            np.random.default_rng(seed), max_stages=max_stages
        )
