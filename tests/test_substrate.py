"""Substrate tests: data pipeline, optimizer, checkpointing, gradient
compression, fault tolerance."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.data.pipeline import DataConfig, ShardedLoader, shard_batch_at
from repro.distributed.fault import FaultConfig, FaultTolerantLoop
from repro.optim import adamw, compression


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_pipeline_deterministic():
    cfg = DataConfig(vocab=1000, seq_len=64, global_batch=8)
    a = shard_batch_at(cfg, step=3, shard=0, n_shards=1)
    b = shard_batch_at(cfg, step=3, shard=0, n_shards=1)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_pipeline_elastic_resharding():
    """The global stream is identical under any shard count."""
    cfg = DataConfig(vocab=1000, seq_len=64, global_batch=8)
    whole = shard_batch_at(cfg, 5, 0, 1)["tokens"]
    parts = np.concatenate(
        [shard_batch_at(cfg, 5, s, 4)["tokens"] for s in range(4)]
    )
    np.testing.assert_array_equal(whole, parts)


def test_pipeline_resume_state():
    cfg = DataConfig(vocab=100, seq_len=32, global_batch=2)
    l1 = ShardedLoader(cfg)
    next(l1)
    next(l1)
    state = l1.state()
    l2 = ShardedLoader(cfg)
    l2.restore(state)
    np.testing.assert_array_equal(next(l1)["tokens"], next(l2)["tokens"])


def test_pipeline_packing_structure():
    cfg = DataConfig(vocab=5000, seq_len=256, global_batch=1)
    row = shard_batch_at(cfg, 0, 0, 1)["tokens"][0]
    assert row[0] == cfg.bos
    assert (row == cfg.bos).sum() >= 1
    assert row.max() < cfg.vocab


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_quadratic_convergence():
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw.init_state(params)
    cfg = adamw.AdamWConfig(lr=0.2, weight_decay=0.0, warmup_steps=0,
                            total_steps=200, clip_norm=100.0)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw.apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adamw_clipping_and_schedule():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(adamw.lr_at(cfg, 0)) == 0.0
    assert float(adamw.lr_at(cfg, 10)) == pytest.approx(1.0, rel=0.01)
    assert float(adamw.lr_at(cfg, 100)) == pytest.approx(
        cfg.min_lr_ratio, rel=0.05
    )
    params = {"w": jnp.ones(4)}
    state = adamw.init_state(params)
    _, _, m = adamw.apply_updates(
        params, {"w": jnp.ones(4) * 1e6}, state, cfg
    )
    assert float(m["grad_norm"]) > cfg.clip_norm  # recorded pre-clip


def test_compression_error_feedback_unbiased():
    """EF quantization: accumulated updates converge to the true sum."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal(256) * 0.01)
    params = {"g": g_true}
    err = compression.init_error_state(params)
    total = np.zeros(256)
    for _ in range(50):
        comp, err = compression.ef_compress_grads({"g": g_true}, err)
        total += np.asarray(comp["g"])
    np.testing.assert_allclose(
        total / 50, np.asarray(g_true), atol=2e-4
    )


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4))}}
    ckpt.save(tree, str(tmp_path), step=7)
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, step = ckpt.restore(like, str(tmp_path))
    assert step == 7
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_atomic_no_partial(tmp_path):
    tree = {"a": jnp.arange(4.0)}
    ckpt.save(tree, str(tmp_path), step=1)
    # a stale tmp dir must never be picked up
    os.makedirs(str(tmp_path / "step_00000002.tmp"))
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_async_checkpointer_and_gc(tmp_path):
    saver = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        saver.save({"a": jnp.full((4,), float(s))}, s)
    saver.wait()
    assert ckpt.latest_step(str(tmp_path)) == 4
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 2
    restored, _ = ckpt.restore({"a": jnp.zeros(4)}, str(tmp_path))
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.full(4, 4.0))


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


def test_fault_loop_recovers_from_failures(tmp_path):
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=2)
    loader = ShardedLoader(cfg)
    fail_at = {5}
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        if calls["n"] in fail_at:
            raise RuntimeError("injected node failure")
        return {"w": state["w"] + 1}, {"loss": float(state["w"])}

    loop = FaultTolerantLoop(
        step_fn, {"w": 0}, loader,
        FaultConfig(checkpoint_dir=str(tmp_path), checkpoint_every=2,
                    backoff_s=0.0),
    )
    metrics = loop.run(10)
    assert len(metrics) == 10
    assert loop.recoveries == 1


def test_fault_loop_straggler_detection(tmp_path):
    import time

    cfg = DataConfig(vocab=100, seq_len=16, global_batch=2)
    loader = ShardedLoader(cfg)

    def step_fn(state, batch):
        if loader.step == 6:
            time.sleep(0.25)
        else:
            time.sleep(0.005)
        return state, {"loss": 0.0}

    loop = FaultTolerantLoop(
        step_fn, {}, loader,
        FaultConfig(checkpoint_dir=str(tmp_path), checkpoint_every=100),
    )
    loop.run(10)
    assert any(step == 5 for step, _ in loop.straggler_events)
