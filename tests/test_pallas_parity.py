"""Pallas wave-backend parity: the hardware execution path against the
simulator and the sequential oracle, across kernel × trace-mode ×
speculation, plus the WavePlan contract and the op-table factoring.

This is the conformance suite the backend's claim rests on (DESIGN.md
§2): "Pallas hardware path agrees with simulate()" — final arrays
bit-identical (assert_array_equal, not allclose), wave counts pinned
against ``executor.WaveStats``, §6 valid bits recomputed from op-table
guards and matched request-exact.

Scales are small (interpret-mode Pallas runs one kernel call per wave);
the full paper_table1 scales run nightly via
``benchmarks/bench_pallas.py`` (BENCH_PALLAS.json).
"""

import numpy as np
import pytest

from repro.core import executor, loopir as ir, optable, programs, simulator
from repro.kernels import wave_exec

SCALES = {
    "RAWloop": 96, "WARloop": 96, "WAWloop": 96,
    "bnn": 12, "pagerank": 16, "fft": 32, "matpower": 12,
    "hist+add": 96, "tanh+spmv": 64,
    "spmv_ldtrip": 24, "bfs_front": 48, "chase_sum": 32,
    "strided_scan": 24,
}

TRACE_MODES = {name: ("interp", "compiled") for name in programs.TABLE1}
# speculative streams are interpreter-built; "compiled" raises by design
TRACE_MODES.update({name: ("interp", "auto")
                    for name in programs.SPEC_KERNELS})

ALL_KERNELS = tuple(programs.TABLE1) + tuple(programs.SPEC_KERNELS)


def _make(name):
    bench = programs.get(name)
    prog, arrays, params = bench.make(SCALES[name])
    spec = "auto" if bench.speculative else "off"
    return prog, arrays, params, spec


# ---------------------------------------------------------------------------
# the full kernel × trace-mode matrix, arrays exact + waves pinned
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ALL_KERNELS)
def test_pallas_backend_matrix(name):
    prog, arrays, params, spec = _make(name)
    oracle = ir.interpret(prog, arrays, params)
    sim = simulator.simulate(prog, arrays, params, mode="FUS2",
                             engine="event", speculation=spec)
    ref_plan = None
    for tm in TRACE_MODES[name]:
        res = executor.execute(
            prog, arrays, params, trace_mode=tm, speculation=spec,
            backend="pallas",
        )
        for k in oracle:
            np.testing.assert_array_equal(
                res.arrays[k], oracle[k],
                err_msg=f"{name}/{tm}: backend != oracle on {k}",
            )
        for k in sim.arrays:
            np.testing.assert_array_equal(
                res.arrays[k], sim.arrays[k],
                err_msg=f"{name}/{tm}: backend != simulate() on {k}",
            )
        # wave counts pinned against WaveStats, identical across modes
        assert res.stats.n_waves == res.plan.stats.n_waves
        assert res.stats.n_requests == len(res.waves)
        if ref_plan is None:
            ref_plan = res.plan
        else:
            np.testing.assert_array_equal(
                res.plan.req_wave, ref_plan.req_wave,
                err_msg=f"{name}: wave partition diverged across "
                f"trace modes",
            )
            np.testing.assert_array_equal(
                res.plan.req_flat, ref_plan.req_flat,
                err_msg=f"{name}: addresses diverged across trace modes",
            )


@pytest.mark.parametrize("name", ALL_KERNELS)
def test_wave_plan_contract(name):
    """The WavePlan invariants every backend relies on (executor doc)."""
    prog, arrays, params, spec = _make(name)
    plan = executor.build_wave_plan(prog, arrays, params, speculation=spec)
    executor.validate_plan(plan)
    # §6 reference valid bits: loads always valid, invalid stores NaN
    assert np.all(plan.req_valid[~plan.req_store])
    bad = plan.req_store & ~plan.req_valid
    assert np.all(np.isnan(plan.req_value[bad]))
    # flat layout covers exactly the protected arrays, disjointly
    total = sum(len(arrays[a]) for a in plan.array_order)
    assert plan.mem_size == total
    for a in plan.array_order:
        assert 0 <= plan.base[a] <= plan.mem_size - len(arrays[a])


@pytest.mark.parametrize("name", ["tanh+spmv", "pagerank", "chase_sum"])
def test_numpy_and_pallas_backends_agree(name):
    prog, arrays, params, spec = _make(name)
    a = executor.execute(prog, arrays, params, speculation=spec,
                         backend="numpy")
    b = executor.execute(prog, arrays, params, speculation=spec,
                         backend="pallas")
    for k in a.arrays:
        np.testing.assert_array_equal(a.arrays[k], b.arrays[k])
    assert a.stats.n_waves == b.stats.n_waves


def test_unknown_backend_rejected():
    prog, arrays, params, _ = _make("RAWloop")
    with pytest.raises(ValueError, match="unknown backend"):
        executor.execute(prog, arrays, params, backend="fpga")


def test_non_f64_protected_arrays_rejected_up_front():
    """The flat image computes in f64; a narrower protected array would
    diverge from the oracle in the last ulp — clear error, not a
    divergence assert deep in the wave loop. Unprotected (Read) arrays
    keep their dtype."""
    prog, arrays, params, _ = _make("RAWloop")
    arrays = dict(arrays, A=arrays["A"].astype(np.float32))
    with pytest.raises(ValueError, match="float64 protected arrays"):
        executor.build_wave_plan(prog, arrays, params)
    # d0 is Read-only: any dtype is fine
    arrays2, _ = dict(_make("RAWloop")[1]), None
    arrays2["d0"] = arrays2["d0"].astype(np.float32)
    res = executor.execute(prog, arrays2, params, backend="pallas")
    oracle = ir.interpret(prog, arrays2, params)
    np.testing.assert_array_equal(res.arrays["B"], oracle["B"])


# ---------------------------------------------------------------------------
# op tables: the compute bodies factored out of the oracle
# ---------------------------------------------------------------------------


def test_op_tables_partial_evaluation_shape():
    """tanh+spmv: guarded store keeps only LoadVal-reachable residue in
    the closure; the §6 guard compiles; LoadVal-free operands become
    env slots."""
    prog, _, _, _ = _make("tanh+spmv")
    tables = optable.compile_store_tables(prog)
    t = tables["st_v"]
    assert t.deps == ("ld_v",)
    assert t.guard is not None
    assert t.env_exprs == ()  # tanh(LoadVal) has no CU-side operands
    t2 = tables["st_y"]
    assert set(t2.deps) == {"ld_y", "ld_vv"}
    assert len(t2.env_exprs) == 1  # R(val, e) — captured, not recomputed


def test_op_tables_gather_residue():
    """bfs_front: a Read indexed by a LoadVal stays a closure gather
    against a frozen array."""
    prog, _, _, _ = _make("bfs_front")
    tables = optable.compile_store_tables(prog)
    t = tables["st_v"]
    assert "nodeval" in t.frozen_reads
    assert t.deps == ("ld_n",)


def test_op_tables_reject_mutable_gather():
    """A load-dependent Read of a store-target array has no frozen
    snapshot — documented OpTableError."""
    from repro.core.loopir import (
        Const, Load, LoadVal, Loop, Param, Program, Read, Store, Var,
    )

    prog = Program(
        name="bad",
        loops=(
            Loop("i", Param("n", 0, 4), (
                Load("ld", "a", Var("i")),
                # value gathers a["ld"] — but "a" is also stored below
                Store("st", "a", Var("i"),
                      Read("a", LoadVal("ld")) + Const(1.0)),
            )),
        ),
        params=("n",),
    )
    with pytest.raises(optable.OpTableError, match="frozen snapshot"):
        optable.compile_store_tables(prog)


def test_guard_protected_env_capture():
    """§6: the guard may be the bounds check that makes the value
    operands evaluable — env-slot capture must not crash on (and must
    mask) guard-false rows whose operands are out of range."""
    from repro.core.loopir import (
        Bin, Const, Load, LoadVal, Loop, Param, Program, Read, Store, Var,
    )

    prog = Program(name="guarded_oob", loops=(
        Loop("i", Param("n", 0, 5), (
            Load("ld", "src", Var("i")),
            Store("st", "out", Var("i"),
                  Read("tab", Var("i")) + LoadVal("ld"),
                  guard=Bin("<", Var("i"), Const(3.0))),
        )),
    ), params=("n",))
    arrays = {"out": np.zeros(5), "src": np.arange(5, dtype=np.float64),
              "tab": np.arange(3, dtype=np.float64)}  # len 3 < trip 5
    oracle = ir.interpret(prog, arrays, {"n": 5})
    for backend in ("numpy", "pallas"):
        res = executor.execute(prog, arrays, {"n": 5}, backend=backend)
        np.testing.assert_array_equal(res.arrays["out"], oracle["out"])


def test_backend_recomputes_guards_not_oracle():
    """The §6 valid bits the backend scatters with come from op-table
    guard evaluation; corrupting the plan's reference valid stream must
    trip the divergence check, proving the backend computed its own."""
    prog, arrays, params, _ = _make("tanh+spmv")
    plan = executor.build_wave_plan(prog, arrays, params)
    stores = np.nonzero(plan.req_store & ~plan.req_valid)[0]
    assert len(stores), "tanh+spmv must have guard-failed stores"
    plan.req_valid[stores[0]] = True  # corrupt the reference
    with pytest.raises(AssertionError, match="guard diverged"):
        wave_exec.run_plan(plan, arrays)


def test_jnp_compute_mode_close():
    """The same closures run under jax.numpy (accelerator dtype rules):
    tolerance parity, not bit parity — documented tradeoff."""
    prog, arrays, params, _ = _make("pagerank")
    plan = executor.build_wave_plan(prog, arrays, params)
    oracle = ir.interpret(prog, arrays, params)
    res = wave_exec.run_plan(plan, arrays, compute="jnp", check=False)
    for k in oracle:
        # f32 closure arithmetic under default jax config — tolerance
        # parity is the most this mode claims
        np.testing.assert_allclose(res.arrays[k], oracle[k],
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# sequential baseline path
# ---------------------------------------------------------------------------


def test_sequential_path_exact_and_truncatable():
    prog, arrays, params, _ = _make("hist+add")
    plan = executor.build_wave_plan(prog, arrays, params)
    oracle = ir.interpret(prog, arrays, params)
    full = wave_exec.run_sequential(plan, arrays, check=True)
    assert full.complete and full.n_steps == plan.stats.n_requests
    for k in oracle:
        np.testing.assert_array_equal(full.arrays[k], oracle[k])
    part = wave_exec.run_sequential(plan, arrays, max_steps=7)
    assert not part.complete and part.n_steps == 7


def test_wave_backend_empty_program():
    prog = ir.Program(name="empty", loops=(), params=())
    res = executor.execute(prog, {"a": np.zeros(4)}, {}, backend="pallas")
    assert res.stats.n_requests == 0 and res.stats.n_waves == 0
    np.testing.assert_array_equal(res.arrays["a"], np.zeros(4))
