"""Sweep service contract: shard/merge, resume, streaming, retry
(DESIGN.md §13).

Pins, in order:

  * shard planning is deterministic and balanced, and every group is
    owned by exactly one shard;
  * ``merge_results`` over independently-run shards is bit-identical
    to the single-host sweep (points, cycles, arrays, group order),
    and rejects duplicate/missing shards;
  * streaming (``on_point`` / ``iter_points``) delivers every point
    exactly once, in completion order, with the same results as the
    batch return;
  * ``ParetoTracker`` prefix fronts equal the batch ``pareto_front``
    recompute at every prefix;
  * ``SweepStats`` counters cohere, and a warm-cache ``resume=True``
    run executes nothing;
  * a SIGKILLed sweep resumes from the surviving cache computing only
    the missing runs, bit-identical to uninterrupted (subprocess —
    spawn workers need a real ``__main__`` file);
  * a corrupt journal entry is skipped-and-counted, never fatal;
  * transient worker failures retry with backoff; permanent failures
    raise ``SweepGroupError`` naming the (kernel, scale, spec_class)
    group and the surviving cache state.
"""

import json
import os
import signal
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

from repro import dse
from repro.dse import runner as runner_mod
from repro.launch import analysis

# chase_sum is a speculative kernel (pointer chase): "auto" keeps it
# legal while folding to the "off" spec class on the other three, so
# both speculation classes are exercised without an illegal point
SPEC = dse.SweepSpec(
    kernels=("RAWloop", "hist+add", "tanh+spmv", "chase_sum"),
    scales={"RAWloop": 64, "hist+add": 48, "tanh+spmv": 16,
            "chase_sum": 32},
    modes=("STA", "FUS2"),
    speculations=("auto",),
    sizings={"base": {}, "narrow": {"burst_size": 4}},
)


def _sig(pr):
    if pr is None:
        return None
    return (
        pr.result.cycles, pr.result.dram_bursts,
        tuple(sorted(
            (k, v.tobytes()) for k, v in pr.result.arrays.items()
        )),
    )


def _same(a, b):
    assert len(a.points) == len(b.points)
    for pa, pb in zip(a.points, b.points):
        assert _sig(pa) == _sig(pb)


# -- shard planning ----------------------------------------------------------


def test_shard_plan_deterministic_and_balanced():
    p1 = dse.shard_plan(SPEC, 3)
    p2 = dse.shard_plan(SPEC, 3)
    assert p1 == p2
    assert p1.n_shards == 3
    assert len(p1.loads) == 3
    # LPT greedy: max load at most min load + the largest group
    assert max(p1.loads) - min(p1.loads) <= max(
        len(g.runs) for g in dse.plan(SPEC.points())
    )
    # every group owned exactly once
    owned = [i for s in range(3) for i in p1.groups_for(s)]
    assert sorted(owned) == list(range(len(p1.assignment)))
    with pytest.raises(ValueError):
        p1.groups_for(3)


# -- shard + merge bit-identity ----------------------------------------------


@pytest.mark.parametrize("n_shards", (2, 3))
def test_merge_equals_single_host(tmp_path, n_shards):
    whole = dse.sweep(SPEC, cache_dir=str(tmp_path / "whole"))
    shards = [
        dse.sweep_shard(
            SPEC, i, n_shards, cache_dir=str(tmp_path / f"s{i}")
        )
        for i in range(n_shards)
    ]
    merged = dse.merge_results(shards)
    _same(merged, whole)
    assert merged.stats.shard is None
    assert merged.stats.n_unique_runs == whole.stats.n_unique_runs
    assert [g["class_key"] for g in merged.groups] == [
        g["class_key"] for g in whole.groups
    ]
    volatile = ("cached", "run_wall_s")
    strip = lambda rows: [
        {k: v for k, v in r.items() if k not in volatile} for r in rows
    ]
    assert strip(merged.rows()) == strip(whole.rows())


def test_merge_rejects_duplicate_and_missing_shards():
    shards = [dse.sweep_shard(SPEC, i, 2) for i in range(2)]
    with pytest.raises(ValueError, match="duplicate shard"):
        dse.merge_results([shards[0], shards[0]])
    with pytest.raises(ValueError):
        dse.merge_results([shards[0]])
    with pytest.raises(ValueError):
        dse.merge_results([])


def test_merge_caches(tmp_path):
    a, b, dst = (str(tmp_path / d) for d in ("a", "b", "dst"))
    dse.sweep_shard(SPEC, 0, 2, cache_dir=a)
    dse.sweep_shard(SPEC, 1, 2, cache_dir=b)
    n = dse.merge_caches(dst, a, b)
    assert n > 0
    # the merged cache warm-serves the whole sweep
    res = dse.sweep(SPEC, cache_dir=dst, resume=True)
    assert res.stats.n_executed == 0
    assert res.stats.n_cache_hits == res.stats.n_unique_runs


# -- streaming ---------------------------------------------------------------


def test_on_point_and_iter_points_stream_everything():
    seen = []
    res = dse.sweep(SPEC, on_point=seen.append)
    assert len(seen) == len([p for p in res.points if p is not None])
    assert {id(p) for p in seen} == {id(p) for p in res.points}
    iterated = list(dse.iter_points(SPEC))
    assert len(iterated) == len(seen)
    by_id = {pr.point.point_id: _sig(pr) for pr in iterated}
    for pr in res.points:
        assert by_id[pr.point.point_id] == _sig(pr)


def test_pareto_tracker_prefix_equals_batch():
    rng = np.random.default_rng(7)
    tracker = analysis.ParetoTracker()
    rows = []
    for i in range(200):
        row = {"cycles": int(rng.integers(1, 40)),
               "dram_bursts": int(rng.integers(1, 40)), "i": i}
        rows.append(row)
        tracker.update(row)
        batch = [rows[j] for j in analysis.pareto_front(rows)]
        assert tracker.front() == batch, f"prefix {i}"
    assert tracker.n_seen == 200


# -- stats + resume ----------------------------------------------------------


def test_stats_cohere_and_warm_resume_executes_nothing(tmp_path):
    cache = str(tmp_path / "cache")
    cold = dse.sweep(SPEC, cache_dir=cache)
    st = cold.stats
    assert st.n_points == len(cold.points)
    assert st.n_cache_hits + st.n_executed == st.n_unique_runs
    assert st.n_executed == st.n_unique_runs  # cold: no hits
    assert st.journal_entries == 0 and st.journal_corrupt == 0
    assert st.wall_s > 0

    warm = dse.sweep(SPEC, cache_dir=cache, resume=True)
    wst = warm.stats
    assert wst.n_executed == 0
    assert wst.n_cache_hits == wst.n_unique_runs
    assert wst.n_resumed_runs == wst.n_unique_runs
    assert wst.journal_entries == st.n_unique_runs
    _same(warm, cold)


def test_resume_requires_cache_dir():
    with pytest.raises(ValueError, match="cache_dir"):
        dse.sweep(SPEC, resume=True)


def test_corrupt_journal_entry_skipped_not_fatal(tmp_path):
    cache = str(tmp_path / "cache")
    cold = dse.sweep(SPEC, cache_dir=cache)
    path = os.path.join(cache, dse.SweepJournal.FILENAME)
    with open(path, "a") as f:
        f.write("{truncated json\n")
        f.write("[1, 2, 3]\n")  # parseable but not a dict: also corrupt
    with pytest.warns(UserWarning, match="journal"):
        res = dse.sweep(SPEC, cache_dir=cache, resume=True)
    assert res.stats.journal_corrupt == 2
    assert res.stats.journal_entries == cold.stats.n_unique_runs
    assert res.stats.n_executed == 0
    _same(res, cold)


KILL_CHILD = textwrap.dedent("""
    import sys
    from repro import dse
    from tests.test_sweep_service import SPEC
    dse.sweep(SPEC, cache_dir=sys.argv[1], workers=1)
""")


def test_kill_resume_bit_identical(tmp_path):
    """SIGKILL a child sweep once its journal shows progress; the
    resumed run computes only the missing runs and matches the
    uninterrupted result bit-for-bit."""
    whole = dse.sweep(SPEC)
    cache = str(tmp_path / "cache")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", ".", env.get("PYTHONPATH", "")) if p
    )
    child = subprocess.Popen(
        [sys.executable, "-c", KILL_CHILD, cache],
        env=env, cwd=os.path.dirname(os.path.dirname(__file__)),
        stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL,
    )
    journal = os.path.join(cache, dse.SweepJournal.FILENAME)
    deadline = time.time() + 60.0
    while time.time() < deadline and child.poll() is None:
        if os.path.exists(journal):
            with open(journal) as f:
                if sum(1 for _ in f) >= 2:
                    break
        time.sleep(0.02)
    finished_early = child.poll() is not None
    if not finished_early:
        child.send_signal(signal.SIGKILL)
    child.wait()

    res = dse.sweep(SPEC, cache_dir=cache, resume=True)
    st = res.stats
    assert st.n_cache_hits + st.n_executed == st.n_unique_runs
    if not finished_early:
        assert st.n_resumed_runs >= 1
        assert st.n_executed >= 1
    _same(res, whole)


# -- retry + failure naming --------------------------------------------------


def test_transient_failure_retries_with_backoff(monkeypatch):
    calls = {"n": 0}
    orig = runner_mod._run_group_task

    def flaky(args):
        calls["n"] += 1
        if calls["n"] == 1:
            raise OSError("transient spawn failure")
        return orig(args)

    monkeypatch.setattr(runner_mod, "_run_group_task", flaky)
    res = dse.sweep(SPEC, retries=2, backoff_s=0.01)
    assert res.stats.n_retries == 1
    rec = res.stats.retries[0]
    assert rec["error"].startswith("OSError")
    assert rec["attempt"] == 1
    _same(res, dse.sweep(SPEC))


def test_permanent_failure_names_group_and_cache(tmp_path, monkeypatch):
    """A group that keeps failing raises SweepGroupError naming
    (kernel, scale, spec_class) and the surviving cache state, with the
    original error chained."""
    cache = str(tmp_path / "cache")
    dse.sweep(SPEC, cache_dir=cache)  # populate survivors

    orig = runner_mod._run_group_task

    def doomed(args):
        group = args[0]
        if group.kernel == "tanh+spmv":
            raise ValueError("engine exploded")
        return orig(args)

    monkeypatch.setattr(runner_mod, "_run_group_task", doomed)
    # differential=True changes the run signature vs the cached rows,
    # forcing real execution through the doomed path
    with pytest.raises(dse.SweepGroupError) as ei:
        dse.sweep(SPEC, cache_dir=cache, differential=True,
                  retries=0, backoff_s=0.0)
    msg = str(ei.value)
    assert "kernel='tanh+spmv'" in msg
    assert "scale=16" in msg
    assert "spec_class=" in msg
    assert "cache" in msg
    assert isinstance(ei.value.__cause__, ValueError)
