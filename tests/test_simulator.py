"""Cycle-simulator correctness (vs the sequential oracle) and the
paper's qualitative performance structure (Table 1 trends)."""

import numpy as np
import pytest

from repro.core import executor, loopir, programs, simulator

MODES = ("STA", "LSQ", "FUS1", "FUS2")
SCALE = 48


def _scale(name):
    return 64 if name == "fft" else SCALE


@pytest.mark.parametrize("name", programs.all_names())
@pytest.mark.parametrize("mode", MODES)
def test_matches_oracle(name, mode):
    prog, arrays, params = programs.get(name).make(_scale(name))
    oracle = loopir.interpret(prog, arrays, params)
    spec = "auto" if programs.get(name).speculative else "off"
    res = simulator.simulate(
        prog, arrays, params, mode=mode, validate=(mode != "STA"),
        speculation=spec,
    )
    for k in oracle:
        np.testing.assert_allclose(
            res.arrays[k], oracle[k], atol=1e-12,
            err_msg=f"{name}/{mode} diverged on array {k}",
        )


@pytest.mark.parametrize("name", ["RAWloop", "WARloop", "WAWloop"])
def test_fusion_beats_sequential_on_microbenchmarks(name):
    """Fig. 1(c): cross-loop overlap. FUS2 must beat LSQ (which
    sequentializes the loops) on every microbenchmark."""
    prog, arrays, params = programs.get(name).make(512)
    lsq = simulator.simulate(prog, arrays, params, mode="LSQ")
    fus = simulator.simulate(prog, arrays, params, mode="FUS2")
    assert fus.cycles < lsq.cycles


def test_forwarding_helps_intra_loop_raw():
    """§7.3.2: forwarding is crucial when the store and load are in the
    same loop (hist, matpower)."""
    for name in ("hist+add", "matpower"):
        prog, arrays, params = programs.get(name).make(_scale(name))
        f1 = simulator.simulate(prog, arrays, params, mode="FUS1")
        f2 = simulator.simulate(prog, arrays, params, mode="FUS2")
        assert f2.forwards > 0
        assert f2.cycles < f1.cycles, name


def test_speculation_tanh_spmv():
    """§6: the guarded store's requests are speculated; mis-speculated
    stores ACK without committing, and the final state is exact."""
    prog, arrays, params = programs.get("tanh+spmv").make(SCALE)
    res = simulator.simulate(prog, arrays, params, mode="FUS2", validate=True)
    oracle = loopir.interpret(prog, arrays, params)
    np.testing.assert_allclose(res.arrays["v"], oracle["v"], atol=1e-12)
    np.testing.assert_allclose(res.arrays["y"], oracle["y"], atol=1e-12)


def test_sta_fuses_independent_histograms():
    """STA's static fusion merges the two (hazard-free) histogram loops
    but can never fuse the dependent addition loop (§7.2)."""
    prog, arrays, params = programs.get("hist+add").make(SCALE)
    comp = simulator.Compiled(prog, forwarding=False)
    fuse = simulator._fusion_groups_sta(comp)
    pes = comp.dae.pes
    # hist1 and hist2 PEs fused; add loop separate
    assert fuse[pes[1].id] == fuse[pes[0].id]
    assert fuse[pes[2].id] != fuse[pes[0].id]


def test_dram_coalescing_counts():
    prog, arrays, params = programs.get("RAWloop").make(512)
    fus = simulator.simulate(prog, arrays, params, mode="FUS2")
    lsq = simulator.simulate(prog, arrays, params, mode="LSQ")
    # bursting LSU packs many requests per burst; LSQ bursts are single
    assert fus.dram_requests / max(fus.dram_bursts, 1) > 4
    assert lsq.dram_requests == lsq.dram_bursts


def test_wave_executor_matches_oracle_and_reports_parallelism():
    for name in programs.all_names():
        prog, arrays, params = programs.get(name).make(_scale(name))
        spec = "auto" if programs.get(name).speculative else "off"
        res = executor.execute(
            prog, arrays, params, speculation=spec
        )  # asserts internally
        assert res.stats.n_waves >= 1
        assert res.stats.parallelism >= 1.0
    # microbenchmark: two n-iteration loops collapse to O(1) waves
    prog, arrays, params = programs.get("WARloop").make(256)
    res = executor.execute(prog, arrays, params)
    assert res.stats.n_waves <= 4
