"""Per-architecture smoke tests: REDUCED configs of each family run one
forward/train step and one decode step on CPU, asserting output shapes
and finiteness (the assignment's smoke requirement). Full configs are
exercised only through the dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as configs
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)
ARCHS = configs.all_names()

# tier-1 keeps one representative per family cheap enough for the CI
# budget; the full sweep runs in the nightly slow tier (DESIGN.md §4)
def _tier1_subset(names, keep):
    missing = keep - set(names)
    assert not missing, (
        f"tier-1 keep-list names unknown archs {sorted(missing)}; "
        "update the keep set or tier-1 silently loses its smoke coverage"
    )
    return [
        n if n in keep else pytest.param(n, marks=pytest.mark.slow)
        for n in names
    ]


ARCHS_TRAIN = _tier1_subset(ARCHS, {"qwen3-14b"})
ARCHS_DECODE = _tier1_subset(
    ARCHS, {"qwen3-14b", "whisper-tiny", "falcon-mamba-7b"}
)


def _batch(cfg, b=2, s=64):
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    batch = {"tokens": tokens, "targets": tokens}
    if cfg.frontend:
        batch["frontend"] = (
            jax.random.normal(KEY, (b, cfg.frontend_len, cfg.d_model)) * 0.02
        )
    return batch


@pytest.mark.parametrize("name", ARCHS_TRAIN)
def test_train_step_smoke(name):
    cfg = configs.get(name).reduced()
    params = T.init_params(KEY, cfg, L.FP32)
    batch = _batch(cfg)
    loss, grads = jax.jit(
        jax.value_and_grad(lambda p: T.loss_fn(p, batch, cfg, L.FP32))
    )(params)
    assert np.isfinite(float(loss))
    assert 3.0 < float(loss) < 9.0  # ~ln(vocab) at init
    for g in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(g)).all()


@pytest.mark.parametrize("name", ARCHS_DECODE)
def test_decode_step_smoke(name):
    cfg = configs.get(name).reduced()
    params = T.init_params(KEY, cfg, L.FP32)
    b = 2
    cache = T.init_cache(cfg, b, 128, L.FP32)
    lengths = jnp.array([3, 7], jnp.int32)
    tok = jax.random.randint(KEY, (b, 1), 0, cfg.vocab)
    enc_out = None
    if cfg.enc_dec:
        frames = jax.random.normal(KEY, (b, cfg.frontend_len, cfg.d_model)) * 0.02
        enc_out = T._encode(params, frames, cfg, L.FP32)
    logits, new_cache = jax.jit(
        lambda p, t, c, l, e: T.decode_step(p, t, c, l, cfg, L.FP32, enc_out=e)
    )(params, tok, cache, lengths, enc_out)
    assert logits.shape == (b, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    # cache must actually change (the RAW frontier advanced)
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b_))
        for a, b_ in zip(jax.tree.leaves(cache), jax.tree.leaves(new_cache))
    )
    assert changed


@pytest.mark.slow
def test_decode_matches_forward_gqa():
    """Teacher-forced decode must reproduce the training forward's
    next-token logits (the KV frontier semantics are exact)."""
    cfg = configs.get("qwen3-14b").reduced()
    params = T.init_params(KEY, cfg, L.FP32)
    b, s = 1, 16
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    hidden = T.forward_hidden(params, tokens, cfg, L.FP32)
    w_out = params["lm_head"]
    ref_logits = hidden[:, -1].astype(jnp.float32) @ w_out.astype(jnp.float32)

    cache = T.init_cache(cfg, b, 32, L.FP32)
    lengths = jnp.zeros((b,), jnp.int32)
    for t in range(s):
        logits, cache = T.decode_step(
            params, tokens[:, t:t + 1], cache, lengths, cfg, L.FP32
        )
        lengths = lengths + 1
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(ref_logits), atol=2e-3, rtol=1e-3
    )


@pytest.mark.slow
def test_decode_matches_forward_mamba():
    """Chunked scan (train) == stepwise recurrence (decode)."""
    cfg = configs.get("falcon-mamba-7b").reduced()
    params = T.init_params(KEY, cfg, L.FP32)
    b, s = 1, 32
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    hidden = T.forward_hidden(params, tokens, cfg, L.FP32)
    w_out = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ref_logits = hidden[:, -1].astype(jnp.float32) @ w_out.astype(jnp.float32)

    cache = T.init_cache(cfg, b, s, L.FP32)
    lengths = jnp.zeros((b,), jnp.int32)
    for t in range(s):
        logits, cache = T.decode_step(
            params, tokens[:, t:t + 1], cache, lengths, cfg, L.FP32
        )
        lengths = lengths + 1
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(ref_logits), atol=2e-3, rtol=1e-3
    )


def test_gemma3_ring_buffer_cache_sizes():
    cfg = configs.get("gemma3-4b").reduced()
    cache = T.init_cache(cfg, batch=2, max_seq=128, dt=L.FP32)
    lk, _ = cache["local_kv"]
    gk, _ = cache["global_kv"]
    assert lk.shape[2] == cfg.sliding_window  # ring capacity == window
    assert gk.shape[2] == 128
    assert lk.shape[0] + gk.shape[0] == cfg.n_layers


def test_mla_cache_is_latent():
    cfg = configs.get("minicpm3-4b").reduced()
    cache = T.init_cache(cfg, batch=2, max_seq=64, dt=L.FP32)
    lat, kr = cache["mla"]
    assert lat.shape[-1] == cfg.kv_lora_rank  # latent, not per-head KV
    assert kr.shape[-1] == cfg.qk_rope_dim


@pytest.mark.slow
def test_mamba1_chunked_matches_stepwise():
    """The chunked selective scan (DESIGN.md §3.3 RAW chain) equals the
    recurrent decode step applied position by position."""
    import dataclasses

    cfg = dataclasses.replace(
        configs.get("falcon-mamba-7b").reduced(), ssm_chunk=8
    )
    di, n = cfg.expand * 16, cfg.ssm_state
    key = jax.random.PRNGKey(3)
    p = S.mamba_init(key, dataclasses.replace(cfg, d_model=16), L.FP32)
    b, s = 2, 32
    xi = jax.random.normal(key, (b, s, di)) * 0.5
    h0 = jnp.zeros((b, di, n), jnp.float32)
    y_chunk, h_chunk = S._mamba1_chunked(
        p, xi, dataclasses.replace(cfg, d_model=16), h0, 8
    )
    h = h0
    for t in range(s):
        y_t, h = S._mamba1_step(p, xi[:, t], h)
        np.testing.assert_allclose(
            np.asarray(y_chunk[:, t]), np.asarray(y_t), rtol=1e-4, atol=1e-5
        )
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h),
                               rtol=1e-4, atol=1e-5)


def test_mamba2_chunked_matches_stepwise():
    import dataclasses

    cfg = dataclasses.replace(
        configs.get("zamba2-7b").reduced(), ssm_chunk=8
    )
    d = 32
    cfg16 = dataclasses.replace(cfg, d_model=d)
    di, n = cfg.expand * d, cfg.ssm_state
    nh = di // S.MAMBA2_HEAD if di >= S.MAMBA2_HEAD else 1
    key = jax.random.PRNGKey(4)
    p = S.mamba_init(key, cfg16, L.FP32)
    b, s = 2, 32
    nh = di // S.MAMBA2_HEAD
    xr = jax.random.normal(key, (b, s, d)) * 0.5
    xh = jax.random.normal(jax.random.PRNGKey(5), (b, s, di)) * 0.5
    h0 = jnp.zeros((b, nh, S.MAMBA2_HEAD, n), jnp.float32)
    y_chunk, h_chunk = S._mamba2_chunked(p, xr, xh, cfg16, h0, 8)
    h = h0
    for t in range(s):
        y_t, h = S._mamba2_step(
            p, xr[:, t], xh[:, t].reshape(b, nh, S.MAMBA2_HEAD), h, n
        )
        np.testing.assert_allclose(
            np.asarray(y_chunk[:, t]),
            np.asarray(y_t.reshape(b, di)),
            rtol=1e-3, atol=1e-4,
        )
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h),
                               rtol=1e-3, atol=1e-4)


def test_n_params_scale():
    """Config parameter estimates land near the advertised model sizes."""
    approx = {
        "internvl2-76b": 76e9,
        "starcoder2-7b": 7e9,
        "qwen3-14b": 14e9,
        "falcon-mamba-7b": 7e9,
        "phi3.5-moe-42b-a6.6b": 42e9,
    }
    for name, target in approx.items():
        n = configs.get(name).n_params()
        assert 0.5 * target < n < 1.7 * target, (name, n)


def test_moe_active_params_below_total():
    cfg = configs.get("phi3.5-moe-42b-a6.6b")
    assert cfg.n_active_params() < 0.35 * cfg.n_params()
