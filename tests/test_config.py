"""RunConfig unification contract (DESIGN.md §13; ISSUE: api_redesign).

Pins, in order:

  * config-vs-kwargs bit-identity: ``simulate(config=RunConfig(...))``
    equals the legacy-kwarg spelling (cycles, arrays) across a
    kernel x mode x engine sample, and ``executor.execute`` likewise;
  * ``result_key`` derivation: ``SweepPoint.result_key`` equals
    ``dse.result_projection`` of the point's config — one projection;
  * conflict behavior: an explicit kwarg disagreeing with an explicit
    config raises ``ConfigConflict`` (and agreement passes through);
  * cache-key coverage: every RunConfig field either moves
    ``result_projection``'s output or is listed in
    ``RESULT_INERT_FIELDS`` with its inertness proof obligation;
  * vocabulary drift: the dependency-free ``core.config`` value tuples
    match their canonical homes (``dae.PREDICTORS`` etc.);
  * the ``dse.sweep(validate=)`` -> ``differential=`` deprecation shim.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import dae as daelib
from repro.core import executor
from repro.core import programs
from repro.core import schedule as schedlib
from repro.core import simulator
from repro.core.config import (
    ConfigConflict,
    RunConfig,
    PREDICTORS,
    TRACE_MODES,
    resolve,
)
from repro.core.simulator import SimParams
from repro import dse
from repro.dse.spec import RESULT_INERT_FIELDS, result_projection

SCALE = {
    "RAWloop": 64, "WARloop": 64, "WAWloop": 64, "hist+add": 48,
    "tanh+spmv": 32, "bnn": 16, "pagerank": 24, "fft": 32, "matpower": 16,
}


def _run(kernel, **kw):
    b = programs.get(kernel)
    prog, arrays, params = b.make(SCALE[kernel])
    return simulator.simulate(
        prog, {k: v.copy() for k, v in arrays.items()}, params, **kw
    )


# -- bit-identity ------------------------------------------------------------


@pytest.mark.parametrize("kernel", ("RAWloop", "hist+add", "tanh+spmv"))
@pytest.mark.parametrize("mode,engine", [
    ("STA", "event"), ("LSQ", "event"), ("FUS2", "event"), ("FUS2", "cycle"),
])
def test_simulate_config_bit_identical_to_kwargs(kernel, mode, engine):
    legacy = _run(kernel, mode=mode, engine=engine, trace_mode="auto")
    cfg = _run(kernel, config=RunConfig(mode=mode, engine=engine))
    assert legacy.cycles == cfg.cycles
    assert legacy.dram_bursts == cfg.dram_bursts
    assert set(legacy.arrays) == set(cfg.arrays)
    for k in legacy.arrays:
        assert np.array_equal(legacy.arrays[k], cfg.arrays[k])


def test_simulate_config_every_registered_kernel():
    """Acceptance pin: config spelling is bit-identical on every
    registered kernel (default FUS2/event point; speculative kernels
    run under speculation="auto")."""
    for name, bench in sorted(programs.REGISTRY.items()):
        scale = SCALE.get(name, max(bench.default_scale // 32, 8))
        prog, arrays, params = bench.make(scale)
        spec_knob = "auto" if bench.speculative else "off"
        legacy = simulator.simulate(
            prog, {k: v.copy() for k, v in arrays.items()}, params,
            mode="FUS2", speculation=spec_knob,
        )
        cfg = simulator.simulate(
            prog, {k: v.copy() for k, v in arrays.items()}, params,
            config=RunConfig(speculation=spec_knob),
        )
        assert legacy.cycles == cfg.cycles, name
        for k in legacy.arrays:
            assert np.array_equal(legacy.arrays[k], cfg.arrays[k]), name


def test_execute_config_bit_identical_to_kwargs():
    b = programs.get("hist+add")
    prog, arrays, params = b.make(48)
    legacy = executor.execute(
        prog, {k: v.copy() for k, v in arrays.items()}, params,
        trace_mode="interp", batch_waves=False,
    )
    cfg = executor.execute(
        prog, {k: v.copy() for k, v in arrays.items()}, params,
        config=RunConfig(trace_mode="interp", batch_waves=False),
    )
    for k in legacy.arrays:
        assert np.array_equal(legacy.arrays[k], cfg.arrays[k])
    assert legacy.waves.tolist() == cfg.waves.tolist()


def test_config_sim_overrides_flow_into_simparams():
    """config.fifo_depth/fifo_latency/spec_runahead act exactly like
    the matching sim= override."""
    via_sim = _run(
        "tanh+spmv", mode="FUS2",
        sim=SimParams(fifo_depth=2, fifo_latency=3),
    )
    via_cfg = _run(
        "tanh+spmv", config=RunConfig(fifo_depth=2, fifo_latency=3),
    )
    assert via_sim.cycles == via_cfg.cycles
    assert via_sim.fifo_stats == via_cfg.fifo_stats


# -- conflicts ---------------------------------------------------------------


def test_conflicting_kwarg_raises():
    with pytest.raises(ConfigConflict):
        _run("RAWloop", mode="STA", config=RunConfig(mode="FUS2"))
    prog, arrays, params = programs.get("RAWloop").make(32)
    with pytest.raises(ConfigConflict):
        executor.execute(
            prog, arrays, params, backend="pallas",
            config=RunConfig(backend="numpy"),
        )
    with pytest.raises(ConfigConflict):
        executor.build_wave_plan(
            prog, arrays, params, fifo_depth=8,
            config=RunConfig(fifo_depth=2),
        )


def test_agreeing_kwarg_passes():
    res = _run("RAWloop", mode="STA", config=RunConfig(mode="STA"))
    assert res.cycles == _run("RAWloop", mode="STA").cycles


def test_conflicting_sim_field_raises():
    with pytest.raises(ConfigConflict):
        _run(
            "tanh+spmv", sim=SimParams(fifo_depth=3),
            config=RunConfig(fifo_depth=2),
        )
    # sim left at default: config wins, no conflict
    res = _run(
        "tanh+spmv", sim=SimParams(), config=RunConfig(fifo_depth=2),
    )
    assert res.cycles == _run("tanh+spmv", config=RunConfig(fifo_depth=2)).cycles


def test_sweepspec_config_axis_conflict():
    with pytest.raises(ConfigConflict):
        dse.SweepSpec(
            kernels=("RAWloop",), modes=("STA",),
            config=RunConfig(mode="LSQ"),
        ).points()
    # defaulted axes collapse to the config's values
    pts = dse.SweepSpec(
        kernels=("RAWloop",), scales={"RAWloop": 32},
        config=RunConfig(mode="STA", engine="cycle"),
    ).points()
    assert len(pts) == 1
    assert pts[0].mode == "STA" and pts[0].engine == "cycle"


def test_invalid_values_rejected():
    with pytest.raises(ValueError):
        RunConfig(mode="FUS3")
    with pytest.raises(ValueError):
        RunConfig(predictor="psychic")
    with pytest.raises(ValueError):
        RunConfig(fifo_depth=0)
    with pytest.raises(TypeError):
        resolve("FUS2")  # config= must be a RunConfig


# -- result-key derivation ---------------------------------------------------


def test_result_key_delegates_to_projection():
    for pt in dse.SweepSpec(
        kernels=("RAWloop", "bnn"), scales={"RAWloop": 32, "bnn": 16},
        modes=("STA", "FUS2"), speculations=("off", "auto"),
        sizings={"base": {}, "deep": {"sta_mem_dep_ii": 99, "fifo_depth": 2}},
    ).points():
        assert pt.result_key == result_projection(
            pt.kernel, pt.scale, pt.config, pt.sim
        )


def test_every_config_field_keyed_or_inert():
    """Every RunConfig field must either move result_projection's
    output in some context, or be declared inert in
    RESULT_INERT_FIELDS — no third category, no silent drift when a
    field is added."""
    kernel, scale = "chase_sum", 32  # speculative kernel: all classes live
    assert programs.REGISTRY[kernel].speculative
    base = RunConfig(mode="FUS2", speculation="auto")
    # a non-default probe value per field
    probes = {
        "mode": "LSQ", "engine": "cycle", "trace_mode": "interp",
        "speculation": "off", "predictor": "stride", "spec_runahead": 3,
        "fifo_depth": 2, "fifo_latency": 5, "static_prune": True,
        "validate_hints": True, "backend": "pallas", "batch_waves": False,
        "symbolic_admission": False,
    }
    fields = {f.name for f in dataclasses.fields(RunConfig)}
    assert set(probes) == fields, "probe table out of date"
    keyed, inert = set(), set()
    ref = result_projection(kernel, scale, base)
    for name, probe in probes.items():
        mutated = dataclasses.replace(base, **{name: probe})
        if result_projection(kernel, scale, mutated) != ref:
            keyed.add(name)
        else:
            inert.add(name)
    assert inert == set(RESULT_INERT_FIELDS), (
        f"inert-field drift: projection says {sorted(inert)}, "
        f"RESULT_INERT_FIELDS says {sorted(RESULT_INERT_FIELDS)}"
    )
    assert keyed == fields - set(RESULT_INERT_FIELDS)


# -- vocabulary drift --------------------------------------------------------


def test_config_vocabularies_match_canonical_homes():
    assert PREDICTORS == daelib.PREDICTORS
    assert TRACE_MODES == schedlib.TRACE_MODES
    from repro.dse import spec as dsespec

    assert set(dsespec.MODES) == {"STA", "LSQ", "FUS1", "FUS2"}


# -- deprecation shim --------------------------------------------------------


def test_sweep_validate_deprecated_shim():
    spec = [dse.SweepPoint("RAWloop", 32, mode="FUS2")]
    with pytest.warns(DeprecationWarning, match="differential"):
        old = dse.sweep(spec, validate=True)
    new = dse.sweep(spec, differential=True)
    assert old.points[0].result.cycles == new.points[0].result.cycles
